"""Pattern AST / parser / DNF compiler tests (+ hypothesis properties)."""
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

from repro.core import pattern as pat


def test_parse_basic():
    p = pat.parse("l0 & !(l1 | l2)")
    assert pat.evaluate(p, frozenset({0})) is True
    assert pat.evaluate(p, frozenset({0, 1})) is False
    assert pat.evaluate(p, frozenset()) is False


def test_parse_words():
    p = pat.parse("0 AND NOT (1 OR 2)")
    q = pat.parse("l0 & !(l1 | l2)")
    for bits in range(8):
        present = frozenset(i for i in range(3) if bits & (1 << i))
        assert pat.evaluate(p, present) == pat.evaluate(q, present)


def test_parse_errors():
    with pytest.raises(ValueError):
        pat.parse("l0 &")
    with pytest.raises(ValueError):
        pat.parse("(l0")


def test_dnf_simple():
    terms = pat.to_dnf(pat.parse("l0 & l1"))
    assert len(terms) == 1
    assert terms[0].require == frozenset({0, 1})
    assert terms[0].forbid == frozenset()


def test_dnf_not_of_and():
    # ¬(a ∧ b) = ¬a ∨ ¬b
    terms = pat.to_dnf(pat.parse("!(l0 & l1)"))
    assert len(terms) == 2
    assert all(not t.require for t in terms)


def test_dnf_drops_contradictions():
    terms = pat.to_dnf(pat.parse("l0 & !l0"))
    assert terms == []


def test_lcr_pattern():
    p = pat.lcr([0, 2], 4)           # allowed {0,2} of 4 labels
    assert pat.evaluate(p, frozenset({0, 2})) is True
    assert pat.evaluate(p, frozenset({0, 1})) is False


# ------------------------------------------------------------ hypothesis
@st.composite
def patterns(draw, depth=0):
    if depth > 3 or draw(st.booleans()):
        lbl = pat.Label(draw(st.integers(0, 4)))
        return pat.Not(lbl) if draw(st.booleans()) else lbl
    kind = draw(st.sampled_from(["and", "or", "not"]))
    if kind == "not":
        return pat.Not(draw(patterns(depth=depth + 1)))
    kids = draw(st.lists(patterns(depth=depth + 1), min_size=1, max_size=3))
    return (pat.And if kind == "and" else pat.Or)(tuple(kids))


@hp.given(patterns())
@hp.settings(max_examples=100, deadline=None)
def test_dnf_equivalent_to_pattern(p):
    terms = pat.to_dnf(p)
    assert pat.dnf_equivalent(p, terms, 5)


# --------------------------------------------------- API edges & errors
def test_unparse_roundtrip():
    for txt in ("l0", "!(l1)", "l0 & !(l1 | l2)", "(l0 | l1) & l2"):
        p = pat.parse(txt)
        assert pat.canonical_key(pat.parse(pat.unparse(p))) == \
            pat.canonical_key(p)


def test_helper_constructors():
    p = pat.and_(pat.label(0), pat.or_(pat.label(1), pat.label(2)))
    assert pat.evaluate(p, frozenset({0, 2})) is True
    assert pat.evaluate(p, frozenset({0})) is False


def test_non_pattern_rejected():
    with pytest.raises(TypeError):
        pat.evaluate("l0", frozenset())
    with pytest.raises(TypeError):
        pat.canonicalize(42)
    with pytest.raises(TypeError):
        pat.unparse(None)


def test_parse_error_messages():
    # a bad character must raise, not hang the tokenizer (replicas parse
    # patterns straight off the fleet wire)
    with pytest.raises(ValueError, match="bad character"):
        pat.parse("l0 & %")
    with pytest.raises(ValueError, match="trailing"):
        pat.parse("l0 l1")      # juxtaposition is RPQ syntax, not pattern
    with pytest.raises(ValueError, match="expected"):
        pat.parse("(l0 | l1 l2)")
    with pytest.raises(ValueError, match="unexpected end"):
        pat.parse("(l0 & l1")


def test_dnf_blowup_capped():
    # (l0|l1) & (l2|l3) & … distributes to 2^9 = 512 incomparable terms
    p = pat.And(tuple(pat.Or((pat.Label(2 * i), pat.Label(2 * i + 1)))
                      for i in range(9)))
    with pytest.raises(ValueError, match="blow-up"):
        pat.to_dnf(p, max_terms=256)

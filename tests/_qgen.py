"""Shared mixed-PCR query generator for the distributed test legs.

Side-effect-free on purpose: ``tests/multidevice_check.py`` mutates
``XLA_FLAGS`` before importing jax, so it cannot import the pytest
modules — both it and ``tests/test_distributed.py`` import this instead,
keeping the in-process and subprocess legs on the same query
distribution.
"""
from repro.core import pattern as pat
from repro.core import rpq


def random_rpq(rng, n_labels, depth=3, star_bias=0.35):
    """One random RPQ AST: bounded depth, biased toward stars and
    nested alternation (the shapes that stress closure absorption and
    the product executor's round count).  Occasionally emits an
    out-of-alphabet atom (``l{n_labels}``) so empty-/unmatchable-
    language regexes are in-distribution."""
    def go(depth):
        if depth <= 0 or rng.random() < 0.25:
            if rng.random() < 0.06:     # out-of-alphabet: unmatchable
                return rpq.Sym(int(n_labels))
            return rpq.Sym(int(rng.integers(n_labels)))
        roll = rng.random()
        if roll < star_bias:
            body = go(depth - 1)
            k = rng.random()
            return (rpq.Star(body) if k < 0.6 else
                    rpq.Plus(body) if k < 0.8 else rpq.Opt(body))
        if roll < star_bias + 0.35:
            n = int(rng.integers(2, 4))
            return rpq.Alt(tuple(go(depth - 1) for _ in range(n)))
        n = int(rng.integers(2, 4))
        return rpq.Cat(tuple(go(depth - 1) for _ in range(n)))
    return go(depth)


def rpq_queries(rng, g, n, depth=3):
    """n random (u, v, rpq) triples mirroring ``mixed_queries``'s vertex
    distribution (~1 in 5 self-queries), regexes small enough for the
    32-state Glushkov cap."""
    qs = []
    while len(qs) < n:
        r = random_rpq(rng, g.n_labels, depth=depth)
        try:
            rpq.compile_nfa(r, g.n_labels)
        except ValueError:      # > 31 label occurrences: re-draw
            continue
        u = int(rng.integers(g.n_vertices))
        v = u if rng.integers(5) == 0 else int(rng.integers(g.n_vertices))
        qs.append((u, v, r))
    return qs


def mixed_queries(rng, g, n):
    """n random (u, v, pattern) triples: AND / OR / NOT / mixed terms,
    with ~1 in 5 self-queries (only cycles through u can satisfy)."""
    qs = []
    for _ in range(n):
        u = int(rng.integers(g.n_vertices))
        v = u if rng.integers(5) == 0 else int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=2, replace=False).tolist()
        p = [pat.all_of(labs), pat.any_of(labs), pat.none_of(labs),
             pat.parse(f"l{labs[0]} & !l{labs[1]}")][int(rng.integers(4))]
        qs.append((u, v, p))
    return qs

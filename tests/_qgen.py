"""Shared mixed-PCR query generator for the distributed test legs.

Side-effect-free on purpose: ``tests/multidevice_check.py`` mutates
``XLA_FLAGS`` before importing jax, so it cannot import the pytest
modules — both it and ``tests/test_distributed.py`` import this instead,
keeping the in-process and subprocess legs on the same query
distribution.
"""
from repro.core import pattern as pat


def mixed_queries(rng, g, n):
    """n random (u, v, pattern) triples: AND / OR / NOT / mixed terms,
    with ~1 in 5 self-queries (only cycles through u can satisfy)."""
    qs = []
    for _ in range(n):
        u = int(rng.integers(g.n_vertices))
        v = u if rng.integers(5) == 0 else int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=2, replace=False).tolist()
        p = [pat.all_of(labs), pat.any_of(labs), pat.none_of(labs),
             pat.parse(f"l{labs[0]} & !l{labs[1]}")][int(rng.integers(4))]
        qs.append((u, v, p))
    return qs

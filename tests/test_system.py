"""End-to-end behaviour tests for the paper's system.

The full loop: graph -> TDR index -> mixed PCR query batch -> answers,
checked bit-for-bit against the DFS oracle; plus the query-engine scaling
stats the paper's §VI narrative depends on (false-queries cheaper than
true-queries; group pruning effective on sparse graphs)."""
import numpy as np
import pytest

from repro.core import (dfs_baseline, graph as G, pattern as pat,
                        tdr_build, tdr_query)


@pytest.fixture(scope="module")
def medium():
    g = G.erdos_renyi(300, 2.0, 8, seed=42)
    idx = tdr_build.build_index(
        g, tdr_build.TDRConfig(vtx_bits=128, g_max=4, k=3))
    return g, idx


def test_end_to_end_mixed_batch(medium):
    g, idx = medium
    rng = np.random.default_rng(0)
    queries = []
    for i in range(60):
        u = int(rng.integers(g.n_vertices))
        v = int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=3, replace=False).tolist()
        p = [pat.all_of(labs[:2]), pat.any_of(labs), pat.none_of(labs[:1]),
             pat.parse(f"(l{labs[0]} | l{labs[1]}) & !l{labs[2]}")][i % 4]
        queries.append((u, v, p))
    stats = tdr_query.QueryStats()
    got = tdr_query.answer_batch(idx, queries, stats=stats)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    assert got.tolist() == want
    assert stats.n_queries == 60


def test_index_is_refutation_machine(medium):
    """Paper §VI-C: TDR is designed for answering false queries — the
    filter cascade should resolve a large share of unreachable pairs
    without any exact search."""
    g, idx = medium
    rng = np.random.default_rng(1)
    queries = []
    for _ in range(100):
        u = int(rng.integers(g.n_vertices))
        v = int(rng.integers(g.n_vertices))
        queries.append((u, v, pat.none_of([0])))
    stats = tdr_query.QueryStats()
    tdr_query.answer_batch(idx, queries, stats=stats)
    assert stats.filter_false >= stats.n_jobs * 0.3, stats


def test_fixpoint_rounds_bounded(medium):
    g, idx = medium
    assert 0 < idx.fixpoint_rounds <= g.n_vertices


def test_index_size_scales_linearly(medium):
    """TDR's whole point: O(V) index vs the O(V^2) closure.  At small V the
    per-vertex constant dominates, so assert the *growth rate*: doubling V
    must grow the index ~2x (not 4x)."""
    from repro.core import graph as G, tdr_build
    cfg = tdr_build.TDRConfig(vtx_bits=128, g_max=4, k=3)
    s1 = tdr_build.build_index(G.erdos_renyi(300, 2.0, 8, seed=1),
                               cfg).size_bytes()
    s2 = tdr_build.build_index(G.erdos_renyi(600, 2.0, 8, seed=1),
                               cfg).size_bytes()
    assert s2 < 2.8 * s1
    # and the closure row for a paper-scale graph would dwarf it:
    v_paper = 200_000
    closure_bytes = v_paper * v_paper / 8
    projected_tdr = s2 / 600 * v_paper
    assert projected_tdr < closure_bytes / 100


def test_lm_end_to_end():
    """One reduced LM: train 2 steps, then greedy-decode a few tokens."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.data import DataConfig, batch_for_step
    from repro.models import decode_step, init_params, prefill
    from repro.train import (AdamWConfig, init_train_state,
                             make_train_step)
    cfg = C.get("musicgen-large").reduced()
    dc = DataConfig(task="lm", vocab=cfg.vocab, seq_len=32, global_batch=4,
                    n_media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    for i in range(2):
        state, metrics = step(state, batch_for_step(dc, i))
    assert bool(jnp.isfinite(metrics["loss"]))

    batch = batch_for_step(dc, 0)
    last, cache = prefill(cfg, state["params"], batch["tokens"],
                          batch["media"], max_len=40)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    outs = []
    for _ in range(4):
        logits, cache = decode_step(cfg, state["params"], cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    assert all(o.shape == (4,) for o in outs)

"""Packed-word engine: backend bit-equality, bool-plane oracle equivalence,
planner/executor vs the DFS oracle, and kernel load-bearing-ness."""
import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

import jax.numpy as jnp

from repro.core import (bitset, dfs_baseline, engine, graph as G,
                        pattern as pat, tdr_build, tdr_query)

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)
BACKENDS = ("segment", "pallas")


# ----------------------------------------------------- primitive equality
@hp.given(seed=st.integers(0, 10_000))
@hp.settings(max_examples=10, deadline=None)
def test_segment_or_words_matches_bool_plane(seed):
    rng = np.random.default_rng(seed)
    e, nbits, s = 64, 70, 17
    vals = rng.random((e, nbits)) < 0.15
    seg = rng.integers(0, s, size=e)
    want = np.asarray(bitset.pack_bits(bitset.segment_or(
        jnp.asarray(vals), jnp.asarray(seg), num_segments=s)))
    got = np.asarray(bitset.segment_or_words(
        jnp.asarray(bitset.pack_bits_np(vals)), jnp.asarray(seg),
        num_segments=s, chunk_words=1))
    np.testing.assert_array_equal(got, want)


@hp.given(seed=st.integers(0, 10_000), kind=st.sampled_from(["er", "pa"]))
@hp.settings(max_examples=8, deadline=None)
def test_engine_closure_matches_dfs_oracle(seed, kind):
    """Both backends' packed closure == the per-vertex DFS reachable set."""
    g = G.random_graph(kind, 40, 2.0, 4, seed=seed)
    _, _, disc = tdr_build.dfs_intervals(g)
    rows = tdr_build._vertex_bit_rows(CFG, disc)
    rows_packed = jnp.asarray(bitset.pack_bits_np(rows))
    results = {}
    for backend in BACKENDS:
        eng = engine.make_engine(g, backend=backend)
        base = eng.propagate(rows_packed)
        r, _ = eng.closure(base)
        results[backend] = np.asarray(r)
    np.testing.assert_array_equal(results["segment"], results["pallas"])
    for u in range(0, g.n_vertices, 7):
        reach = dfs_baseline.reachable_set(g, u)
        want = np.zeros(CFG.vtx_bits, dtype=bool)
        for v in np.flatnonzero(reach):
            want |= rows[v]
        got = np.unpackbits(results["segment"][u].view(np.uint8),
                            bitorder="little")[:CFG.vtx_bits].astype(bool)
        np.testing.assert_array_equal(got, want)


@hp.given(seed=st.integers(0, 10_000), kind=st.sampled_from(["er", "pa"]))
@hp.settings(max_examples=6, deadline=None)
def test_build_index_backend_bit_equality(seed, kind):
    g = G.random_graph(kind, 50, 2.2, 5, seed=seed)
    idx = {b: tdr_build.build_index(g, CFG, backend=b) for b in BACKENDS}
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
        np.testing.assert_array_equal(
            np.asarray(getattr(idx["segment"], f)),
            np.asarray(getattr(idx["pallas"], f)), err_msg=f)
    assert idx["segment"].fixpoint_rounds == idx["pallas"].fixpoint_rounds


# --------------------------------------------------- planner + executor
def _random_queries(rng, g, n):
    qs = []
    for _ in range(n):
        u, v = int(rng.integers(g.n_vertices)), int(rng.integers(
            g.n_vertices))
        if rng.integers(5) == 0:
            v = u   # self-queries: only cycles through u can satisfy
        kind = rng.integers(5)
        labs = rng.choice(g.n_labels, size=min(2, g.n_labels),
                          replace=False).tolist()
        if kind == 0:
            p = pat.all_of(labs)
        elif kind == 1:
            p = pat.any_of(labs)
        elif kind == 2:
            p = pat.none_of(labs)
        elif kind == 3:
            p = pat.parse(f"l{labs[0]} & !l{labs[-1]}")
        else:
            p = pat.lcr(labs, g.n_labels)
        qs.append((u, v, p))
    return qs


@hp.given(seed=st.integers(0, 10_000), kind=st.sampled_from(["er", "pa"]))
@hp.settings(max_examples=8, deadline=None)
def test_answer_batch_matches_oracle_both_backends(seed, kind):
    rng = np.random.default_rng(seed)
    g = G.random_graph(kind, 40, 2.0, 4, seed=seed)
    idx = tdr_build.build_index(g, CFG)
    queries = _random_queries(rng, g, 20)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    for backend in BACKENDS:
        got = tdr_query.answer_batch(idx, queries, backend=backend)
        assert got.tolist() == want, backend


@hp.given(seed=st.integers(0, 10_000), kind=st.sampled_from(["er", "pa"]))
@hp.settings(max_examples=6, deadline=None)
def test_exact_modes_bit_equal(seed, kind):
    """The corridor-compacted and bidirectional-full executors must be
    bit-equal to the DFS oracle *and* to the retained PR-1 full-graph
    executor (exact_mode="legacy"), including forbidden-label patterns
    and u==v cycle queries."""
    rng = np.random.default_rng(seed)
    g = G.random_graph(kind, 45, 2.3, 4, seed=seed)
    idx = tdr_build.build_index(g, CFG)
    queries = _random_queries(rng, g, 20)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    legacy = tdr_query.answer_batch(idx, queries, backend="segment",
                                    exact_mode="legacy").tolist()
    assert legacy == want
    for mode in ("auto", "compact", "full"):
        got = tdr_query.answer_batch(idx, queries, backend="segment",
                                     exact_mode=mode).tolist()
        assert got == want == legacy, mode


def test_exact_modes_bit_equal_pallas():
    """Same bit-equality through the pallas (interpret) matmul executors:
    compacted per-chunk sub-adjacency and device-built full corridor."""
    for seed in (2, 9):
        rng = np.random.default_rng(seed)
        g = G.random_graph("pa", 40, 2.5, 4, seed=seed)
        idx = tdr_build.build_index(g, CFG)
        queries = _random_queries(rng, g, 15)
        want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
        for mode in ("compact", "full", "legacy"):
            got = tdr_query.answer_batch(idx, queries, backend="pallas",
                                         exact_mode=mode).tolist()
            assert got == want, (seed, mode)


def test_self_cycle_queries_exact():
    """u==v with required labels is satisfiable only by a cycle through
    u collecting them — exact on every executor path."""
    g = G.Graph.from_edges(
        5, 2, [(0, 1, 0), (1, 2, 1), (2, 0, 0), (3, 4, 1)])
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=32))
    for mode in ("auto", "compact", "full", "legacy"):
        assert tdr_query.answer(idx, 0, 0, pat.all_of([0, 1]),
                                exact_mode=mode) is True
        assert tdr_query.answer(idx, 3, 3, pat.all_of([1]),
                                exact_mode=mode) is False


def test_corridor_compaction_prunes_and_lazy_stats():
    """On a sparse graph the corridor must actually shrink the expansion
    (occupancy < 1), and QueryStats fetches round counters lazily."""
    g = G.erdos_renyi(120, 1.2, 4, seed=5)
    idx = tdr_build.build_index(g, CFG)
    rng = np.random.default_rng(5)
    queries = _random_queries(rng, g, 40)
    stats = tdr_query.QueryStats()
    got = tdr_query.answer_batch(idx, queries, backend="segment",
                                 stats=stats)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    assert got.tolist() == want
    assert stats.exact_jobs > 0
    assert stats.corridor_total > 0
    assert stats.corridor_occupancy < 1.0, \
        "sparse corridors should compact below full V"
    # lazy round counters: stored as device scalars, summed on access
    assert isinstance(stats.exact_rounds, int)
    assert stats.exact_rounds > 0
    assert stats.phase1_s > 0 and stats.phase2_s > 0


def test_incidence_plan_matches_bruteforce():
    """One- and two-level padded incidence reduce to the same segment OR
    (two-level triggers on the pa graph's hub tail)."""
    rng = np.random.default_rng(0)
    levels_seen = set()
    for kind in ("er", "pa"):
        g = G.random_graph(kind, 400, 4.0, 4, seed=0)
        keys = np.asarray(g.indices)
        plan = G.incidence_plan(keys, g.n_vertices, g.n_edges)
        levels_seen.add(len(plan))
        val = rng.integers(0, 2 ** 32, (g.n_edges + 1, 2),
                           dtype=np.uint32)
        val[-1] = 0
        cur = val
        for level in plan:
            nxt = np.zeros((level.shape[0], 2), np.uint32)
            for i in range(level.shape[0]):
                for j in level[i]:
                    if j < cur.shape[0]:
                        nxt[i] |= cur[j]
            cur = np.concatenate([nxt, np.zeros((1, 2), np.uint32)])
        want = np.zeros((g.n_vertices, 2), np.uint32)
        for e in range(g.n_edges):
            want[keys[e]] |= val[e]
        np.testing.assert_array_equal(cur[:g.n_vertices], want, err_msg=kind)
    assert levels_seen == {1, 2}, \
        "expected er to stay one-level and pa's hubs to trigger two-level"


def test_special_labels_multiword():
    """The vectorized forbidden-label extraction must read every word of
    the packed raw plane (labels >= 32 live past the first uint32)."""
    g = G.erdos_renyi(30, 2.0, 70, seed=0)
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=64))
    qs = [(0, 5, pat.none_of([0, 33, 69])), (1, 7, pat.all_of([2, 40])),
          (2, 9, pat.parse("l5 & !l64"))]
    plan = tdr_query.compile_queries(idx, qs)
    ex = tdr_query.ExactExecutor(idx, idx.engine("segment"))
    jobs = np.arange(plan.n_jobs)
    assert ex.special_labels(plan, jobs) == (0, 2, 5, 33, 40, 64, 69)
    # single-job slices see only their own labels
    assert ex.special_labels(plan, np.array([0])) == (0, 33, 69)


def test_query_plan_is_packed_and_padded():
    g = G.fig2_example()
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=32))
    plan = tdr_query.compile_queries(
        idx, [(0, 5, pat.all_of([1, 3])), (0, 4, pat.none_of([0, 1]))])
    assert plan.req_w.dtype == np.uint32
    assert plan.forb_raw_w.dtype == np.uint32
    assert plan.full_mask.tolist() == [3, 0]
    padded = plan.pad_to(16)
    assert padded.n_jobs == 16 and padded.qid[-1] == -1
    assert padded.n_queries == plan.n_queries


def test_index_arrays_are_packed_words():
    """No [V, nbits] bool plane at rest: every index array is uint32."""
    g = G.erdos_renyi(60, 2.0, 4, seed=0)
    idx = tdr_build.build_index(g, CFG)
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
        arr = getattr(idx, f)
        assert arr.dtype == jnp.uint32, f
    assert idx.vtx_words.dtype == np.uint32
    assert idx.h_vtx.shape[-1] == bitset.n_words(CFG.vtx_bits)


# ----------------------------------------------- kernels are load-bearing
def test_pallas_backend_invokes_bitset_matmul():
    from repro.kernels import ops
    g = G.erdos_renyi(50, 2.5, 4, seed=7)

    before = ops.KERNEL_INVOCATIONS["bitset_matmul"]
    idx = tdr_build.build_index(g, CFG, backend="pallas")
    after_build = ops.KERNEL_INVOCATIONS["bitset_matmul"]
    assert after_build > before, "build fixpoint skipped the Pallas kernel"

    # a query mix that cannot all be resolved by phase 1 filters
    rng = np.random.default_rng(0)
    queries = _random_queries(rng, g, 30)
    stats = tdr_query.QueryStats()
    tdr_query.answer_batch(idx, queries, backend="pallas", stats=stats)
    after_query = ops.KERNEL_INVOCATIONS["bitset_matmul"]
    assert stats.exact_jobs > 0, "no job reached phase 2; pick other seeds"
    assert after_query > after_build, \
        "exact expansion skipped the Pallas kernel"


def test_segment_backend_uses_no_pallas_kernel():
    from repro.kernels import ops
    g = G.erdos_renyi(40, 2.0, 4, seed=1)
    before = dict(ops.KERNEL_INVOCATIONS)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    tdr_query.answer_batch(
        idx, _random_queries(np.random.default_rng(1), g, 10),
        backend="segment")
    assert dict(ops.KERNEL_INVOCATIONS) == before


# ------------------------------------------------------ backend selection
def test_backend_env_override(monkeypatch):
    # env replaces the default resolution only ...
    monkeypatch.setenv(engine.ENV_BACKEND, "pallas")
    assert engine.resolve_backend("auto") == "pallas"
    assert engine.resolve_backend("") == "pallas"
    # ... but never an explicitly requested backend (sweeps stay truthful)
    assert engine.resolve_backend("segment") == "segment"
    monkeypatch.setenv(engine.ENV_BACKEND, "segment")
    assert engine.resolve_backend("pallas") == "pallas"
    assert engine.resolve_backend("auto") == "segment"
    monkeypatch.delenv(engine.ENV_BACKEND)
    assert engine.resolve_backend("auto") in BACKENDS
    with pytest.raises(ValueError):
        engine.resolve_backend("mxu")


def test_pallas_auto_fallback_on_dense_cap():
    g = G.erdos_renyi(64, 2.0, 4, seed=0)
    with pytest.warns(UserWarning, match="falling back"):
        eng = engine.make_engine(
            g, config=engine.EngineConfig(backend="pallas",
                                          max_dense_bytes=64))
    assert eng.backend == "segment"


def test_label_adjacency_cache_is_bounded():
    g = G.erdos_renyi(40, 2.0, 8, seed=0)
    eng = engine.make_engine(g, backend="pallas")
    for l in range(8):
        eng.label_class_adjacency((l,))
    assert len(eng._label_adj) <= engine.Engine.LABEL_ADJ_CACHE


def test_executor_falls_back_when_class_set_blows_cap():
    """Per-batch label-class matrices over the dense cap must not OOM the
    pallas backend: the batch expands via segment rounds, bit-identically."""
    g = G.erdos_renyi(40, 2.5, 6, seed=3)
    idx = tdr_build.build_index(g, CFG)
    rng = np.random.default_rng(3)
    queries = _random_queries(rng, g, 15)
    want = tdr_query.answer_batch(idx, queries, backend="segment").tolist()
    kw = (g.n_vertices + 31) // 32
    cap = 2 * g.n_vertices * kw * 4   # fits the base matrix, not C+1 classes
    cfg = engine.EngineConfig(backend="pallas", max_dense_bytes=cap)
    with pytest.warns(UserWarning, match="segment path"):
        got = tdr_query.answer_batch(idx, queries, engine_config=cfg)
    assert got.tolist() == want


def test_index_caches_engines_and_adjacency():
    g = G.erdos_renyi(30, 2.0, 4, seed=0)
    idx = tdr_build.build_index(g, CFG, backend="pallas")
    assert idx.engine("pallas") is idx.engine("pallas")
    a1 = idx.adj_packed()
    a2 = idx.engine().adjacency()
    # adjacency row u must contain exactly u's successors
    adj = np.asarray(a1)
    bits = np.unpackbits(adj.view(np.uint8), axis=1, bitorder="little")
    for u in range(g.n_vertices):
        np.testing.assert_array_equal(
            np.flatnonzero(bits[u][:g.n_vertices]),
            np.unique(g.successors(u)))


def test_vtx_packed_cached_plainly():
    g = G.erdos_renyi(20, 1.5, 3, seed=0)
    idx = tdr_build.build_index(g, CFG)
    p1 = idx.vtx_packed
    assert idx.vtx_packed is p1                 # cached attribute, no hack
    np.testing.assert_array_equal(
        np.asarray(p1), bitset.pack_bits_np(idx.vtx_bit_rows))

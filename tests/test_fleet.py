"""Replicated serving fleet: shared-log multi-reader semantics, the
in-process follower (``QueryServer.follow``), and router placement.

The reader-visibility contract under test: a ``deltalog.LogReader``
yields exactly the records a recovering writer would replay as
committed — complete, CRC-valid, dense-LSN — in order, each exactly
once, across concurrent appends, torn in-flight tails (fault-injected
mid-write crashes), and ``truncate_upto`` compaction.  On top of that,
a follower replica must serve answers equal to the DFS oracle at its
*exact* applied LSN, and the multi-process fleet (subprocess replicas,
SIGKILL, re-spawn) is exercised end to end by ``tests/fleet_check.py``.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import faultinject
from repro.core import deltalog, dfs_baseline, graph as G
from repro.core import pattern as pat, tdr_build
from repro.launch import fleet as fleet_mod, serve
from repro.launch.router import FleetRouter

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)
N_V, N_L = 24, 4


def R(*rows):
    """Edge rows as the int64 ``[N, 3]`` arrays the log stores."""
    return np.asarray(rows, dtype=np.int64).reshape(-1, 3)


def lsns(recs):
    return [lsn for lsn, _, _ in recs]


# ------------------------------------------------------- reader basics
def test_reader_tails_exactly_once(tmp_path):
    """Two independent readers over one log each see every committed
    record exactly once, in order, as the writer appends."""
    log = deltalog.DeltaLog(str(tmp_path / "wal"))
    r1 = deltalog.LogReader(str(tmp_path / "wal"))
    r2 = deltalog.LogReader(str(tmp_path / "wal"))
    assert r1.poll() == [] and r2.poll() == []
    log.append(R((0, 1, 0)), R())
    log.append(R((1, 2, 1)), R((0, 1, 0)))
    got1 = r1.poll()
    assert lsns(got1) == [1, 2]
    assert np.array_equal(got1[1][1], R((1, 2, 1)))
    assert np.array_equal(got1[1][2], R((0, 1, 0)))
    assert r1.poll() == []          # nothing new: cursor advanced
    log.append(R((2, 3, 2)), R())
    assert lsns(r1.poll()) == [3]
    # the second reader was never polled: it now sees all three at once
    assert lsns(r2.poll()) == [1, 2, 3]
    # max_records bounds a poll without losing records
    r3 = deltalog.LogReader(str(tmp_path / "wal"))
    assert lsns(r3.poll(max_records=2)) == [1, 2]
    assert lsns(r3.poll()) == [3]
    log.close()


def test_reader_seek_and_after_lsn(tmp_path):
    log = deltalog.DeltaLog(str(tmp_path / "wal"))
    for i in range(4):
        log.append(R((i, i + 1, 0)), R())
    r = deltalog.LogReader(str(tmp_path / "wal"), after_lsn=2)
    assert lsns(r.poll()) == [3, 4]
    r.seek(1)       # re-deliver (the failed-apply rewind path)
    assert lsns(r.poll()) == [2, 3, 4]
    log.close()


def test_reader_concurrent_writer_two_tails(tmp_path):
    """Concurrent writer + two tailing readers: each reader sees the
    dense committed sequence in order, records only ever at or at most
    one past the writer's ack frontier (an fsync'd append whose
    ``append`` call hasn't returned yet)."""
    path = str(tmp_path / "wal")
    log = deltalog.DeltaLog(path)
    n_total, acked = 60, []

    def writer():
        for i in range(n_total):
            lsn = log.append(R((i % N_V, (i + 1) % N_V, i % N_L)), R())
            acked.append(lsn)
            if i % 7 == 0:
                time.sleep(0.001)

    seen = {0: [], 1: []}
    errs = []

    def tail(k):
        r = deltalog.LogReader(path)
        try:
            while len(seen[k]) < n_total:
                for lsn, _, _ in r.poll():
                    frontier = len(acked)
                    assert lsn <= frontier + 1, \
                        f"reader saw lsn {lsn}, writer acked {frontier}"
                    seen[k].append(lsn)
        except Exception as exc:  # noqa: BLE001 — re-raised in the test
            errs.append(exc)

    threads = [threading.Thread(target=tail, args=(k,)) for k in seen]
    for t in threads:
        t.start()
    writer()
    for t in threads:
        t.join(timeout=60)
    log.close()
    assert not errs, errs
    assert seen[0] == list(range(1, n_total + 1))
    assert seen[1] == list(range(1, n_total + 1))


# ------------------------------------------------- torn tails, faults
def _ops_per(tmp_path, n_appends):
    """Mutating-I/O ops for ``DeltaLog() + n appends`` (deterministic)."""
    plan = faultinject.FaultPlan(kind="count")
    with faultinject.inject(plan):
        log = deltalog.DeltaLog(str(tmp_path / "probe.wal"))
        for i in range(n_appends):
            log.append(R((i, i + 1, 0)), R())
    log.close()
    return plan.count


def test_reader_never_yields_torn_tail(tmp_path):
    """A writer crash mid-append leaves a torn record on disk; no poll
    ever yields it — and after writer recovery (which truncates the
    tear) the reader picks up the *recommitted* LSN exactly once."""
    path = str(tmp_path / "wal")
    # crash on the first mutating op of the 3rd append: its torn write
    plan = faultinject.FaultPlan(nth=_ops_per(tmp_path, 2) + 1,
                                 kind="kill", partial_frac=0.5)
    with faultinject.inject(plan):
        log = deltalog.DeltaLog(path)
        log.append(R((0, 1, 0)), R())
        log.append(R((1, 2, 1)), R())
        with pytest.raises(OSError):
            log.append(R((2, 3, 2)), R())
    assert plan.fired
    r = deltalog.LogReader(path)
    assert lsns(r.poll()) == [1, 2]     # the torn lsn-3 is invisible
    assert r.poll() == []               # reads as "in progress", waits
    # writer recovery truncates the tear and commits a different lsn 3
    log2 = deltalog.DeltaLog(path)
    assert log2.last_lsn == 2
    log2.append(R((9, 10, 3)), R())
    got = r.poll()
    assert lsns(got) == [3]
    assert np.array_equal(got[0][1], R((9, 10, 3)))
    log2.close()


def test_reader_torn_mid_append_window(tmp_path):
    """Polls racing a single in-flight append: whatever prefix of the
    record bytes is visible, the reader reports nothing new rather than
    garbage (simulated by truncating a copy at every byte length)."""
    path = str(tmp_path / "wal")
    log = deltalog.DeltaLog(path)
    log.append(R((0, 1, 0)), R())
    base_len = os.path.getsize(path)
    log.append(R((1, 2, 1), (2, 3, 2)), R((0, 1, 0)))
    full = open(path, "rb").read()
    log.close()
    torn = str(tmp_path / "torn.wal")
    for cut in range(base_len, len(full)):
        with open(torn, "wb") as f:
            f.write(full[:cut])
        r = deltalog.LogReader(torn)
        assert lsns(r.poll()) == [1], f"cut at {cut} bytes"


def test_reader_detects_mid_log_corruption(tmp_path):
    """A payload-CRC failure *behind* later records can't be an
    in-flight append: typed ``LogCorrupt``, never bad data."""
    path = str(tmp_path / "wal")
    log = deltalog.DeltaLog(path)
    hdr = os.path.getsize(path)
    log.append(R((0, 1, 0)), R())
    first_end = os.path.getsize(path)
    log.append(R((1, 2, 1)), R())
    log.close()
    data = bytearray(open(path, "rb").read())
    data[first_end - 3] ^= 0xFF         # flip a byte in record 1's payload
    with open(path, "wb") as f:
        f.write(bytes(data))
    r = deltalog.LogReader(path)
    with pytest.raises(deltalog.LogCorrupt):
        r.poll()
    assert hdr < first_end              # sanity: we hit a payload byte


def test_reader_pop_tail_retreat_is_corrupt(tmp_path):
    """``pop_tail`` under an active reader violates append-is-commit:
    a tip retreat below the cursor raises ``LogCorrupt``."""
    path = str(tmp_path / "wal")
    log = deltalog.DeltaLog(path)
    log.append(R((0, 1, 0)), R())
    lsn = log.append(R((1, 2, 1)), R())
    r = deltalog.LogReader(path)
    assert lsns(r.poll()) == [1, 2]
    log.pop_tail(lsn)
    with pytest.raises(deltalog.LogCorrupt):
        r.poll()
    log.close()


# ----------------------------------------------------------- compaction
def test_reader_cursor_survives_compaction(tmp_path):
    """``truncate_upto`` at/behind the cursor is invisible to the
    reader; past the cursor it raises ``LogCompactedPast`` so the
    replica re-bootstraps from a snapshot."""
    path = str(tmp_path / "wal")
    log = deltalog.DeltaLog(path)
    for i in range(6):
        log.append(R((i, i + 1, 0)), R())
    r = deltalog.LogReader(path)
    assert lsns(r.poll(max_records=4)) == [1, 2, 3, 4]
    log.truncate_upto(3)                # behind the cursor: harmless
    assert lsns(r.poll()) == [5, 6]
    log.append(R((6, 7, 0)), R())
    assert lsns(r.poll()) == [7]
    # a reader still at lsn 2 needed records the compaction dropped
    behind = deltalog.LogReader(path, after_lsn=2)
    with pytest.raises(deltalog.LogCompactedPast):
        behind.poll()
    # fresh attach: probe succeeds on a compacted log (no cursor check),
    # base_lsn tells the caller which snapshot generation it needs
    fresh = deltalog.LogReader(path)
    assert fresh.base_lsn == 3
    fresh.seek(3)
    assert lsns(fresh.poll()) == [4, 5, 6, 7]
    log.close()


# ------------------------------------------------- in-process follower
@pytest.mark.parametrize("backend", ["segment"])
def test_follower_tails_and_stamps_exact_lsn(backend, tmp_path):
    """A ``QueryServer.follow`` replica over a shared store applies the
    writer's published sequence, answers with the oracle of the graph
    *at its stamped read LSN*, blocks consistent reads via
    ``wait_for_lsn``, and refuses local writes."""
    d = str(tmp_path / "store")
    rng = np.random.default_rng(3)
    g = G.random_graph("er", N_V, 2.0, N_L, seed=3)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    fleet_mod.init_store(idx, d)
    writer = fleet_mod.FleetWriter(d)
    srv = serve.QueryServer.follow(d, backend=backend, poll_s=0.01)
    srv.start()
    try:
        with pytest.raises(RuntimeError):
            srv.submit_update([(0, 1, 0)], [])
        graphs = [g]
        qs = []
        for i in range(6):
            u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
            labs = rng.choice(N_L, size=2, replace=False).tolist()
            qs.append((u, v, [pat.all_of(labs), pat.any_of(labs),
                              pat.none_of(labs)][i % 3]))
        for step in range(4):
            add, rem = [], []
            for _ in range(2):
                u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
                if u != v:
                    add.append((u, v, int(rng.integers(N_L))))
            lsn = writer.publish(add, rem)
            graphs.append(writer.graph)
            assert srv.wait_for_lsn(lsn, timeout=60), \
                f"follower stuck below lsn {lsn}"
            for u, v, p in qs:
                ans, alsn = srv.submit(u, v, p,
                                       with_lsn=True).result(timeout=60)
                assert alsn >= lsn
                want = dfs_baseline.answer_pcr(graphs[alsn], u, v, p)
                assert ans == want, (step, u, v, ans, want)
        assert srv.stats.applied_lsn == writer.last_lsn
    finally:
        srv.stop()
        writer.close()


@pytest.mark.parametrize("backend", ["segment"])
def test_follower_survives_writer_compaction(backend, tmp_path):
    """The writer checkpoints + compacts; a follower that is behind the
    compaction point re-bootstraps from the new snapshot and keeps
    serving (the ``LogCompactedPast`` → ``_refollow`` path)."""
    d = str(tmp_path / "store")
    g = G.random_graph("er", N_V, 2.0, N_L, seed=5)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    fleet_mod.init_store(idx, d)
    writer = fleet_mod.FleetWriter(d)
    for i in range(3):
        writer.publish([(i, i + 10, i % N_L)], [])
    cur = tdr_build.build_index(writer.graph, CFG, layout=idx.disc,
                                backend=backend)
    assert writer.checkpoint(cur) == 3
    # the log is truncated only up to the *previous* snapshot (kept as
    # a corruption fallback): the base advances on the next checkpoint
    assert writer.log.base_lsn == 0
    for i in range(3):
        writer.publish([(i + 3, i + 13, i % N_L)], [])
    cur = tdr_build.build_index(writer.graph, CFG, layout=idx.disc,
                                backend=backend)
    assert writer.checkpoint(cur) == 6
    assert writer.log.base_lsn == 3     # records <= 3 really dropped
    # a follower attaching *after* compaction must pick the new snapshot
    srv = serve.QueryServer.follow(d, backend=backend, poll_s=0.01)
    srv.start()
    try:
        lsn = writer.publish([(20, 21, 0)], [])
        assert srv.wait_for_lsn(lsn, timeout=60)
        ans, alsn = srv.submit(20, 21, pat.any_of([0]),
                               with_lsn=True).result(timeout=60)
        assert alsn >= lsn and ans is True or ans == \
            dfs_baseline.answer_pcr(writer.graph, 20, 21, pat.any_of([0]))
    finally:
        srv.stop()
        writer.close()


# ------------------------------------------------------ process fleet
@pytest.mark.slow
def test_fleet_subprocess_sigkill_smoke():
    """Real multi-process fleet: ``tests/fleet_check.py`` runs router +
    3 replica processes, SIGKILLs a replica and the writer mid-stream,
    and asserts every answer equals the DFS oracle at its read LSN
    (also the CI ``fleet`` job's standalone leg)."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, os.path.join(here, "fleet_check.py"),
         "segment"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "fleet check OK" in r.stdout

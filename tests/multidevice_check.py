"""Standalone multi-device check (NOT collected by pytest directly —
``tests/test_distributed.py`` spawns it in a subprocess, and CI runs it as
its own leg).

Runs on 8 fake host-platform devices and asserts the three distributed
acceptance criteria:

* the vertex-sharded ``build_index(graph, cfg, mesh=...)`` is bit-identical
  to the single-device build on every index plane, on both a 1-D and a
  2-axis mesh (multi-axis gather ordering), with V not divisible by the
  device count (padding path);
* the sharded ``answer_batch(..., mesh=...)`` matches the DFS oracle on a
  mixed PCR query suite (AND / OR / NOT / mixed terms, self-queries);
* the per-round exchange payload is packed uint32 — every all-gather in
  the compiled HLO of the distributed closure carries ``u32`` operands,
  never a ``pred``/``u8`` bool plane.

jax locks the device count on first init, so the flag must be set before
the import — which is why this lives in its own process.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from _qgen import mixed_queries  # noqa: E402
from repro.core import (dfs_baseline, distributed, graph as G,  # noqa: E402
                        tdr_build)


def main() -> None:
    n_dev = jax.device_count()
    assert n_dev >= 4, f"need a >=4-device mesh, got {n_dev}"
    devs = np.array(jax.devices())
    mesh1 = Mesh(devs.reshape(n_dev), ("data",))
    mesh2 = Mesh(devs.reshape(2, n_dev // 2), ("pod", "data"))

    # V=57 is not divisible by 8: the vertex-padding path is exercised
    g = G.random_graph("pa", 57, 2.3, 4, seed=3)
    cfg = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)
    ref = tdr_build.build_index(g, cfg, backend="segment")
    for mesh in (mesh1, mesh2):
        got = tdr_build.build_index(g, cfg, mesh=mesh)
        for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{f} on mesh {dict(mesh.shape)}")
        assert got.fixpoint_rounds == ref.fixpoint_rounds
        print(f"[ok] sharded build bit-identical on {dict(mesh.shape)}")

    # distributed closure: converged, aligned with the build fixpoint
    _, _, disc = tdr_build.dfs_intervals(g)
    words = tdr_build._vertex_bit_words(cfg, disc)
    eng = ref.engine("segment")
    import jax.numpy as jnp
    want_r, _ = eng.closure(eng.propagate(jnp.asarray(words)))
    got_r = distributed.distributed_closure(g, words, mesh1)
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want_r))
    print("[ok] distributed closure == single-device engine closure")

    # delta-row exchange: bit-identical for any budget, including budgets
    # far below the per-device row count (carry-over path) and on the
    # 2-axis mesh (flat device-id computation)
    for mesh, budget in ((mesh1, 1), (mesh1, 3), (mesh1, 64), (mesh2, 2)):
        got_d = distributed.distributed_closure(g, words, mesh,
                                                row_budget=budget)
        np.testing.assert_array_equal(
            np.asarray(got_d), np.asarray(want_r),
            err_msg=f"delta exchange budget={budget} "
                    f"mesh={dict(mesh.shape)}")
    print("[ok] delta-row exchange bit-identical at budgets 1/3/64 + 2-axis")

    rng = np.random.default_rng(0)
    queries = mixed_queries(rng, g, 24)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    for backend in ("segment", "pallas"):
        ans = distributed.answer_batch(got, queries, mesh=mesh1,
                                       backend=backend)
        assert ans.tolist() == want, \
            f"sharded answer_batch ({backend}) != DFS oracle"
    print("[ok] sharded answer_batch matches the DFS oracle, both backends")

    # exchange payload: packed uint32 words only, no bool plane
    for name, low in (
            ("1d", distributed.lower_distributed_closure(
                mesh1, 64, 16, 64, 4)),
            ("2d", distributed.lower_distributed_closure_2d(
                mesh1, 64, 16, 256, 4, word_shards=4))):
        ag = [ln for ln in low.compile().as_text().splitlines()
              if "all-gather" in ln]
        assert ag, f"{name}: no all-gather in the distributed closure HLO"
        for ln in ag:
            assert "u32[" in ln, f"{name}: unpacked all-gather: {ln}"
            assert "pred[" not in ln and "u8[" not in ln, \
                f"{name}: bool-plane all-gather: {ln}"
        print(f"[ok] {name} exchange: {len(ag)} all-gathers, all packed u32")

    print("multidevice check OK")


if __name__ == "__main__":
    main()

"""Semiring engine: boolean bit-identity + dist/witness/count oracles.

Three contracts, one per instantiation of the generalized fixpoint core:

* ``BOOLEAN`` — the generic paths must be *bit-identical* to the
  pre-refactor packed-uint32 engine.  Asserted two ways: the semiring
  methods trace to literally the same jaxpr as the hand-coded OR idioms,
  and ``closure(sr=BOOLEAN)`` planes equal the default closure on both
  backends (which in turn equal the DFS oracle).

* ``DIST16`` — ``tdr_query.dist_batch`` / ``witness`` equal the
  product-graph BFS oracle (``dfs_baseline.shortest_pcr``) on random
  graphs x patterns x backends, including ``u == v``, unreachable pairs,
  and k-hop bounds; every witness path replays through
  ``verify_witness`` and has exactly the oracle's length (200+ cases).

* ``COUNT`` — ``tdr_query.count_routes`` equals the layered walk-count
  DP with saturating add, including cap-saturation cases; ``closure``
  refuses the non-idempotent carrier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _qgen import mixed_queries
from repro.core import dfs_baseline, engine, graph as G, pattern as pat
from repro.core import tdr_build, tdr_query
from repro.core.semiring import (BOOLEAN, COUNT, COUNT_CAP, DIST8, DIST16,
                                 Semiring, by_name)
from repro.kernels import ops

BACKENDS = ("segment", "pallas")


# ---------------------------------------------------------------------------
# boolean bit-identity
# ---------------------------------------------------------------------------

def test_boolean_methods_trace_to_packed_or_idioms():
    """The BOOLEAN branch of every semiring method emits the *same jaxpr*
    as the pre-refactor hand-coded packed-OR code — the generic engine
    cannot drift from the bit-plane layout without failing here."""
    r = jnp.zeros((8, 4), jnp.uint32)
    u = jnp.ones((8, 4), jnp.uint32)

    def hand_accumulate(r, u):
        new = u & ~r
        return r | new, jnp.any(new != 0)

    assert str(jax.make_jaxpr(BOOLEAN.accumulate)(r, u)) == \
        str(jax.make_jaxpr(hand_accumulate)(r, u))
    assert str(jax.make_jaxpr(BOOLEAN.combine)(r, u)) == \
        str(jax.make_jaxpr(lambda a, b: a | b)(r, u))
    assert str(jax.make_jaxpr(BOOLEAN.extend)(r)) == \
        str(jax.make_jaxpr(lambda a: a)(r))


@pytest.mark.parametrize("backend", BACKENDS)
def test_boolean_closure_bit_identical(backend):
    """closure(sr=BOOLEAN) == closure() == DFS reachability, per backend."""
    g = G.random_graph("pa", 50, 2.0, 4, seed=5)
    eng = engine.make_engine(g, backend=backend)
    v_n = g.n_vertices
    kw = eng.adjacency().shape[1]
    base = jnp.asarray(np.eye(v_n, kw * 32, dtype=np.uint8).reshape(
        v_n, kw, 32) << np.arange(32, dtype=np.uint32)).sum(
            axis=2, dtype=jnp.uint32)
    dflt, _ = eng.closure(base)
    gen, _ = eng.closure(base, sr=BOOLEAN)
    np.testing.assert_array_equal(np.asarray(dflt), np.asarray(gen))
    got = np.asarray(dflt)
    for u in range(0, v_n, 11):
        reach = dfs_baseline.reachable_set(g, u)
        reach[u] = True  # closure seeds the diagonal
        bits = np.unpackbits(got[u].view(np.uint8),
                             bitorder="little")[:v_n].astype(bool)
        np.testing.assert_array_equal(bits, reach)


def test_semiring_registry_and_scalars():
    assert by_name("boolean") is BOOLEAN
    assert by_name("count") is COUNT
    with pytest.raises(ValueError):
        by_name("tropical-float")
    assert DIST16.inf == 65535 and DIST8.inf == 255
    assert DIST16.zero == DIST16.inf and DIST16.one == 0
    assert COUNT.zero == 0 and COUNT.one == 1 and COUNT.cap == COUNT_CAP
    with pytest.raises(ValueError):
        BOOLEAN.inf
    with pytest.raises(ValueError):
        COUNT.accumulate(jnp.zeros(2, jnp.uint32), jnp.ones(2, jnp.uint32))


# ---------------------------------------------------------------------------
# lane kernels: pallas(interpret) == ref, per semiring op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sr", [DIST16, DIST8, COUNT],
                         ids=lambda s: s.name)
def test_lane_matmul_matches_ref(sr):
    """Interpret-mode lane kernel == jnp reference, saturation included."""
    rng = np.random.default_rng(int(sr.cap) + len(sr.name))
    m, k, w = 24, 37, 6
    a = np.asarray(bitset_pack(rng.random((m, k)) < 0.3))
    hi = sr.zero if sr.op == "min" else max(sr.cap, 1)
    x = rng.integers(0, hi + 1, size=(k, w)).astype(np.dtype(sr.dtype_name))
    # the kernel takes a word-aligned K; pad rows carry no adjacency bits
    xp = np.pad(x, ((0, a.shape[1] * 32 - k), (0, 0)))
    got = ops.frontier_step_lanes(jnp.asarray(a), jnp.asarray(xp),
                                  op=sr.op, cap=sr.cap, mode="interpret")
    ref = ops.frontier_step_lanes(jnp.asarray(a), jnp.asarray(xp),
                                  op=sr.op, cap=sr.cap, mode="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and both equal a dense numpy evaluation of the semiring product
    ab = np.unpackbits(a.view(np.uint8), axis=1,
                       bitorder="little")[:, :k].astype(bool)
    want = np.zeros((m, w), dtype=x.dtype)
    for i in range(m):
        sel = x[ab[i]]
        if sr.op == "min":
            want[i] = sel.min(axis=0) if sel.size else sr.zero
        else:
            want[i] = np.minimum(
                sel.sum(axis=0, dtype=np.uint64),
                np.uint64(sr.cap)).astype(x.dtype) if sel.size else 0
    np.testing.assert_array_equal(np.asarray(ref), want)


def bitset_pack(rows: np.ndarray) -> np.ndarray:
    from repro.core import bitset
    return bitset.pack_bits_np(np.asarray(rows, dtype=bool))


@pytest.mark.parametrize("sr", [DIST16, COUNT], ids=lambda s: s.name)
def test_closure_matmul_rows_extend(sr):
    """_matmul_rows applies extend after the lane reduce: for DIST the
    result is 1 + min over selected rows (saturating); for COUNT it is
    the capped sum unchanged."""
    a = bitset_pack(np.array([[1, 1, 0], [0, 0, 0]], dtype=bool))
    x = jnp.asarray(np.array([[3], [5], [9]], dtype=sr.dtype_name))
    out = np.asarray(engine._matmul_rows(jnp.asarray(a), x, "ref", sr=sr))
    if sr.op == "min":
        assert out.tolist() == [[4], [sr.zero]]  # min(3,5)+1; empty -> INF
    else:
        assert out.tolist() == [[8], [0]]


def test_closure_refuses_count():
    g = G.erdos_renyi(10, 1.0, 2, seed=0)
    eng = engine.make_engine(g, backend="segment")
    with pytest.raises(ValueError, match="idempotent"):
        eng.closure(jnp.zeros((10, 1), jnp.uint32), sr=COUNT)


# ---------------------------------------------------------------------------
# dist: oracle equality across graphs x patterns x backends
# ---------------------------------------------------------------------------

def _graphs():
    return [G.random_graph(kind, 48, deg, 4, seed=s)
            for (kind, deg, s) in
            (("er", 1.6, 1), ("er", 2.4, 2), ("pa", 2.0, 3), ("pa", 3.0, 4))]


@pytest.mark.parametrize("backend", BACKENDS)
def test_dist_matches_bfs_oracle(backend):
    g = _graphs()[0 if backend == "segment" else 2]
    idx = tdr_build.build_index(g)
    rng = np.random.default_rng(21)
    qs = mixed_queries(rng, g, 40)
    got = tdr_query.dist_batch(idx, qs, backend=backend)
    want = [dfs_baseline.shortest_pcr(g, u, v, p) for (u, v, p) in qs]
    assert got.tolist() == want
    # k-hop bound: answers prune to -1 beyond k, never change below it
    for k in (0, 1, 3):
        gk = tdr_query.dist_batch(idx, qs, k=k, backend=backend)
        wk = [d if 0 <= d <= k else -1 for d in want]
        assert gk.tolist() == wk


def test_dist_exact_modes_agree():
    g = _graphs()[1]
    idx = tdr_build.build_index(g)
    qs = mixed_queries(np.random.default_rng(8), g, 24)
    want = tdr_query.dist_batch(idx, qs, exact_mode="full").tolist()
    for mode in ("auto", "compact"):
        assert tdr_query.dist_batch(idx, qs, exact_mode=mode).tolist() == want
    assert want == [dfs_baseline.shortest_pcr(g, u, v, p) for u, v, p in qs]


def test_dist_edge_cases():
    g = _graphs()[0]
    idx = tdr_build.build_index(g)
    true_p = pat.none_of([])
    assert tdr_query.dist(idx, 3, 3, true_p) == 0          # empty walk
    assert tdr_query.dist(idx, 3, 3, pat.all_of([0])) != 0  # must move
    # an unreachable pair: fabricate one via a label every edge forbids
    assert tdr_query.dist(idx, 0, 1,
                          pat.none_of(list(range(g.n_labels)))) == -1


# ---------------------------------------------------------------------------
# witness: 200+ randomized cases, path-valid + oracle-shortest
# ---------------------------------------------------------------------------

def test_witness_matches_oracle_200_cases():
    """Every witness replays edge-by-edge through the graph and has
    exactly the oracle's shortest length; unreachable pairs return None.
    4 graphs x 60 queries = 240 randomized cases (same padded V so the
    forward-parent DP compiles once per state count)."""
    rng = np.random.default_rng(99)
    reachable = 0
    for gi, g in enumerate(_graphs()):
        idx = tdr_build.build_index(g)
        backend = "pallas" if gi == 3 else "segment"
        for (u, v, p) in mixed_queries(rng, g, 60):
            want = dfs_baseline.shortest_pcr(g, u, v, p)
            path = tdr_query.witness(idx, u, v, p, backend=backend,
                                     exact_mode="full")
            if want < 0:
                assert path is None, (gi, u, v, p)
            else:
                reachable += 1
                # witness() itself re-verifies and raises on mismatch;
                # assert the contract independently here anyway.
                assert len(path) == want, (gi, u, v, p)
                assert dfs_baseline.verify_witness(g, u, v, p, path)
    assert reachable >= 40  # the pools genuinely exercise the DP


def test_witness_trivial_and_compact():
    g = _graphs()[2]
    idx = tdr_build.build_index(g)
    assert tdr_query.witness(idx, 7, 7, pat.none_of([])) == []
    qs = mixed_queries(np.random.default_rng(12), g, 12)
    for (u, v, p) in qs:   # corridor compaction never changes witnesses
        full = tdr_query.witness(idx, u, v, p, exact_mode="full")
        auto = tdr_query.witness(idx, u, v, p, exact_mode="auto")
        if full is None:
            assert auto is None
        else:
            assert len(auto) == len(full)
            assert dfs_baseline.verify_witness(g, u, v, p, auto)


# ---------------------------------------------------------------------------
# count: bounded walk DP with saturating add
# ---------------------------------------------------------------------------

def _single_term_queries(rng, g, n):
    out = []
    while len(out) < n:
        for (u, v, p) in mixed_queries(rng, g, n):
            if len(pat.to_dnf(p)) == 1:
                out.append((u, v, p))
    return out[:n]


@pytest.mark.parametrize("backend", BACKENDS)
def test_count_routes_matches_oracle(backend):
    g = _graphs()[1 if backend == "segment" else 3]
    idx = tdr_build.build_index(g)
    rng = np.random.default_rng(31)
    for (u, v, p) in _single_term_queries(rng, g, 20):
        for hops in (0, 2, 5):
            want = dfs_baseline.count_routes(g, u, v, p, hops=hops,
                                             cap=COUNT_CAP)
            got = tdr_query.count_routes(idx, u, v, p, hops=hops,
                                         backend=backend)
            assert got == want, (u, v, p, hops)


def test_count_saturates_at_cap():
    """A tiny cap forces clamping; per-round saturating add must equal
    the oracle's clamped total on every query (associativity of the
    saturating monoid — the property the per-round clamp relies on)."""
    g = _graphs()[3]
    idx = tdr_build.build_index(g)
    rng = np.random.default_rng(44)
    sat = 0
    for (u, v, p) in _single_term_queries(rng, g, 15):
        want = dfs_baseline.count_routes(g, u, v, p, hops=8, cap=7)
        got = tdr_query.count_routes(idx, u, v, p, hops=8, cap=7)
        assert got == want, (u, v, p)
        sat += want == 7
    assert sat >= 1  # the cap actually bites somewhere


def test_count_rejects_multi_term():
    g = _graphs()[0]
    idx = tdr_build.build_index(g)
    with pytest.raises(ValueError, match="single"):
        tdr_query.count_routes(idx, 0, 1, pat.any_of([0, 1]), hops=3)


# ---------------------------------------------------------------------------
# mixed-kind batches through one plan
# ---------------------------------------------------------------------------

def test_answer_mixed_aligns_kinds():
    g = _graphs()[2]
    idx = tdr_build.build_index(g)
    rng = np.random.default_rng(55)
    base = mixed_queries(rng, g, 24)
    kinds = ["bool", "dist", "witness", "count"]
    queries, want = [], []
    for i, (u, v, p) in enumerate(base):
        k = kinds[i % 4]
        if k == "count" and len(pat.to_dnf(p)) != 1:
            k = "dist"
        queries.append((u, v, p, k))
        if k == "bool":
            want.append(dfs_baseline.answer_pcr(g, u, v, p))
        elif k == "dist":
            want.append(dfs_baseline.shortest_pcr(g, u, v, p))
        elif k == "witness":
            want.append(dfs_baseline.shortest_pcr(g, u, v, p))
        else:
            want.append(dfs_baseline.count_routes(g, u, v, p, hops=6,
                                                  cap=COUNT_CAP))
    got = tdr_query.answer_mixed(idx, queries, hops=6)
    assert len(got) == len(queries)
    for (q, w, a) in zip(queries, want, got):
        if q[3] == "witness":
            if w < 0:
                assert a is None
            else:
                assert len(a) == w
                assert dfs_baseline.verify_witness(g, q[0], q[1], q[2], a)
        else:
            assert a == w, (q, w, a)


def test_compile_queries_validates_kind():
    g = _graphs()[0]
    idx = tdr_build.build_index(g)
    with pytest.raises(ValueError, match="kind"):
        tdr_query.compile_queries(idx, [(0, 1, pat.all_of([0]), "fuzzy")])
    plan = tdr_query.compile_queries(
        idx, [(0, 1, pat.all_of([0]), "dist"), (1, 2, pat.all_of([1]))])
    assert plan.kinds and plan.kinds[-1] == "bool"
    with pytest.raises(ValueError, match="answer_mixed"):
        tdr_query.answer_plan(idx, plan)

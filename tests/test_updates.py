"""Incremental TDR maintenance: bit-identity + serving-consistency tests.

The contract under test: ``tdr_build.update_index`` over **any** random
interleaving of edge insertions and deletions — including re-insertion
of a removed edge, label changes (remove ``(u,v,l1)`` + add ``(u,v,l2)``),
no-op adds/removes, and both the row-patch and full-tail incremental
paths — must leave **every index plane** bit-identical to a from-scratch
``build_index`` on the final graph pinned to the same hash layout
(``layout=index.disc``).  On top of that, queries against an updated
index must match the DFS oracle on the post-update graph, and a served
query stream straddling a ``submit_update`` must never see a stale
result: requests submitted before the update see the old graph, requests
submitted after it see the new one.

The interleaving counts (``N_INTERLEAVINGS``) are sized so CI runs 200+
random interleavings across the two engine backends.
"""
import numpy as np
import pytest

from repro.core import dfs_baseline, engine as engine_mod, graph as G
from repro.core import pattern as pat, tdr_build, tdr_query
from repro.launch import serve

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)

# every array the index stores — the query-visible planes plus the
# incremental-maintenance state the next update chains from
PLANES = ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in", "push",
          "pop", "g_count", "base_v", "base_l", "base_r", "r_vtx",
          "r_lab", "r_in", "d_vtx", "d_lab")

N_INTERLEAVINGS = {"segment": 150, "pallas": 60}
N_V, N_L = 28, 4


def assert_planes_equal(a, b, ctx=""):
    for p in PLANES:
        x, y = np.asarray(getattr(a, p)), np.asarray(getattr(b, p))
        assert np.array_equal(x, y), \
            f"{ctx}: plane {p} differs ({int((x != y).sum())} cells)"
    assert np.array_equal(a.vtx_words, b.vtx_words), ctx
    assert np.array_equal(np.asarray(a.disc), np.asarray(b.disc)), ctx


def _edges_of(g):
    return list(zip(g.src.tolist(), g.indices.tolist(), g.labels.tolist()))


def _random_step(rng, g):
    """One random update step: a mix of inserts, deletes, re-inserts,
    label changes, and deliberate no-ops."""
    add, rem = [], []
    edges = _edges_of(g)
    for _ in range(int(rng.integers(1, 4))):
        kind = int(rng.integers(5))
        if kind <= 1 or not edges:            # plain insert
            u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
            if u != v:
                add.append((u, v, int(rng.integers(N_L))))
        elif kind == 2:                        # plain delete
            rem.append(edges[int(rng.integers(len(edges)))])
        elif kind == 3:                        # label change on one edge
            u, v, l = edges[int(rng.integers(len(edges)))]
            rem.append((u, v, l))
            add.append((u, v, int((l + 1) % N_L)))
        else:                                  # no-op add of existing edge
            add.append(edges[int(rng.integers(len(edges)))])
    if rng.integers(4) == 0 and rem:           # re-insertion
        add.append(rem[0])
    return add, rem


def _mixed_queries(rng, g, n=8):
    qs = []
    for i in range(n):
        u, v = int(rng.integers(g.n_vertices)), int(rng.integers(
            g.n_vertices))
        labs = rng.choice(g.n_labels, size=2, replace=False).tolist()
        p = [pat.all_of(labs), pat.any_of(labs), pat.none_of(labs),
             pat.parse(f"l{labs[0]} & !l{labs[1]}")][i % 4]
        qs.append((u, v, p))
    return qs


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_update_interleavings_bit_identical(backend):
    """update_index over random insert/delete interleavings ==
    build_index(final graph, layout=frozen) on every plane, and query
    answers match the DFS oracle on the final graph."""
    n = N_INTERLEAVINGS[backend]
    for trial in range(n):
        rng = np.random.default_rng(1000 + trial)
        g = G.random_graph(["er", "pa"][trial % 2], N_V, 2.0, N_L,
                           seed=trial)
        idx0 = tdr_build.build_index(g, CFG, backend=backend)
        cur, curg = idx0, g
        steps = int(rng.integers(1, 4))
        for _ in range(steps):
            add, rem = _random_step(rng, curg)
            delta = curg.apply_updates(add, rem)
            # threshold 2.0 forces the incremental path (the default-
            # threshold rebuild fallback has its own test below)
            cur = tdr_build.update_index(cur, delta, backend=backend,
                                         rebuild_threshold=2.0)
            curg = delta.graph
        ref = tdr_build.build_index(curg, CFG, layout=idx0.disc,
                                    backend=backend)
        assert_planes_equal(cur, ref, f"{backend} trial={trial}")
        if trial % 10 == 0:
            qs = _mixed_queries(rng, curg)
            got = tdr_query.answer_batch(cur, qs, backend=backend)
            want = [dfs_baseline.answer_pcr(curg, u, v, p)
                    for u, v, p in qs]
            assert got.tolist() == want, f"{backend} trial={trial}"


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_update_threshold_paths_agree(backend):
    """Row-patch, full-tail, and rebuild fallback all produce the same
    bits; UpdateStats reports which path ran."""
    rng = np.random.default_rng(5)
    g = G.random_graph("er", N_V, 2.0, N_L, seed=5)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    add, rem = _random_step(rng, g)
    delta = g.apply_updates(add, rem)
    if delta.n_changes == 0:
        pytest.skip("degenerate step")
    outs = {}
    for name, thresh in [("patch", 2.0), ("rebuild", 0.0)]:
        st = tdr_build.UpdateStats()
        outs[name] = tdr_build.update_index(idx, delta, backend=backend,
                                            rebuild_threshold=thresh,
                                            stats=st)
        assert st.mode == ("rebuild" if name == "rebuild"
                           else "incremental"), st
    ref = tdr_build.build_index(delta.graph, CFG, layout=idx.disc,
                                backend=backend)
    assert_planes_equal(outs["patch"], ref, "patch")
    assert_planes_equal(outs["rebuild"], ref, "rebuild")


def test_update_noop_and_validation():
    g = G.fig2_example()
    idx = tdr_build.build_index(g, CFG)
    st = tdr_build.UpdateStats()
    # adding an existing edge / removing a missing one is a no-op and
    # returns the index object unchanged
    same = tdr_build.update_index(idx, edges_added=[(0, 1, 0)],
                                  edges_removed=[(9, 0, 0)], stats=st)
    assert same is idx and st.mode == "noop"
    with pytest.raises(ValueError):
        g.apply_updates([(0, 99, 0)])
    with pytest.raises(ValueError):
        g.apply_updates([(0, 1, 99)])
    with pytest.raises(TypeError):
        tdr_build.update_index(idx, delta=[(0, 1, 0)])
    # a foreign-universe delta is rejected
    other = G.erdos_renyi(5, 1.0, 2, seed=0)
    with pytest.raises(ValueError):
        tdr_build.update_index(idx, other.apply_updates([(0, 1, 0)]))


def test_apply_updates_set_semantics():
    g = G.fig2_example()
    # remove + re-add the same edge in one batch -> net no-op
    d = g.apply_updates([(0, 1, 0)], [(0, 1, 0)])
    assert d.n_changes == 0 and d.graph.n_edges == g.n_edges
    # effective delta filters no-ops; duplicates collapse
    d = g.apply_updates([(2, 7, 3), (2, 7, 3), (0, 1, 0)], [(5, 9, 2)])
    assert d.added.tolist() == [[2, 7, 3]]
    assert d.removed.tolist() == [[5, 9, 2]]
    # parallel labels are distinct edges: removing one keeps the other
    d2 = g.apply_updates([], [(0, 2, 0)])
    assert (0, 2, 1) in _edges_of(d2.graph)
    assert (0, 2, 0) not in _edges_of(d2.graph)


def test_layout_pin_matches_chain_from_empty_regions():
    """Chained updates through structurally drastic states (vertex loses
    all out-edges, then regains) stay bit-identical."""
    g = G.fig2_example()
    idx0 = tdr_build.build_index(g, CFG)
    out0 = [(0, v, l) for v, l in zip(*g.out_edges(0))]
    d1 = g.apply_updates([], out0)            # strip all of v0's edges
    i1 = tdr_build.update_index(idx0, d1, rebuild_threshold=2.0)
    d2 = d1.graph.apply_updates(out0, [])     # regain them
    i2 = tdr_build.update_index(i1, d2, rebuild_threshold=2.0)
    ref1 = tdr_build.build_index(d1.graph, CFG, layout=idx0.disc)
    ref2 = tdr_build.build_index(d2.graph, CFG, layout=idx0.disc)
    assert_planes_equal(i1, ref1, "stripped")
    assert_planes_equal(i2, ref2, "regained")


@pytest.mark.parametrize("reverse", [False, True])
def test_engine_apply_delta_patches_adjacency(reverse):
    """Engine.apply_delta's row-patched dense adjacency == repacking the
    new graph from scratch."""
    rng = np.random.default_rng(11)
    g = G.random_graph("er", N_V, 2.0, N_L, seed=11)
    eng = engine_mod.make_engine(g, backend="pallas")
    _ = eng.adjacency(reverse=reverse)        # populate the cache
    add, rem = _random_step(rng, g)
    delta = g.apply_updates(add, rem)
    eng2 = eng.apply_delta(delta.graph, delta.added, delta.removed)
    got = np.asarray(eng2.adjacency(reverse=reverse))
    want = engine_mod.pack_adjacency_np(delta.graph, reverse=reverse)
    assert np.array_equal(got, want)


def test_served_stream_straddling_update_never_stale():
    """Requests submitted before submit_update see the old graph;
    requests submitted after it see the new one — checked against the
    DFS oracle on each graph, with the result cache enabled so stale
    cache hits would be caught."""
    g0 = G.random_graph("er", 48, 1.8, N_L, seed=21)
    idx = tdr_build.build_index(g0, CFG)
    rng = np.random.default_rng(22)
    pool = _mixed_queries(rng, g0, n=24)
    add = [(int(rng.integers(48)), int(rng.integers(48)),
            int(rng.integers(N_L))) for _ in range(4)]
    add = [(u, v, l) for (u, v, l) in add if u != v]
    rem = _edges_of(g0)[:2]
    g1 = g0.apply_updates(add, rem).graph

    with serve.QueryServer(idx, result_cache=64, max_wait_ms=0.5) as srv:
        pre = [srv.submit(u, v, p) for (u, v, p) in pool]
        st = srv.submit_update(add, rem, timeout=60)
        assert st.mode in ("incremental", "rebuild")
        post = [srv.submit(u, v, p) for (u, v, p) in pool]
        pre_ans = [f.result(timeout=60) for f in pre]
        post_ans = [f.result(timeout=60) for f in post]
        # repeats after the update must also re-resolve freshly (cache
        # was invalidated at the barrier, then repopulated post-update)
        again = [srv.submit(u, v, p).result(timeout=60)
                 for (u, v, p) in pool]
        assert srv.stats.updates == 1
    assert pre_ans == [dfs_baseline.answer_pcr(g0, u, v, p)
                       for (u, v, p) in pool]
    want1 = [dfs_baseline.answer_pcr(g1, u, v, p) for (u, v, p) in pool]
    assert post_ans == want1
    assert again == want1


def test_update_on_unstarted_server_with_queued_requests_raises():
    """Requests queued before the first start() are owed pre-update
    answers; with no scheduler to quiesce, submit_update must refuse
    rather than swap under them.  An idle stopped server swaps inline."""
    g = G.fig2_example()
    idx = tdr_build.build_index(g, CFG)
    srv = serve.QueryServer(idx)
    fut = srv.submit(0, 5, pat.all_of([1, 3]))   # queues unserved
    with pytest.raises(RuntimeError):
        srv.submit_update([(4, 0, 3)], [])
    assert srv.index is idx and not fut.done()
    # drain the queued request, then the inline-swap path works
    srv.start()
    assert fut.result(timeout=60) is True
    srv.stop()
    srv.submit_update([(4, 0, 3)], [])
    assert srv.index.graph.n_edges == g.n_edges + 1


def test_sequential_updates_through_server():
    """Several submit_update calls in a row keep serving correct (each
    chains off the previous swapped index)."""
    g = G.random_graph("er", 40, 1.5, N_L, seed=31)
    idx = tdr_build.build_index(g, CFG)
    rng = np.random.default_rng(32)
    with serve.QueryServer(idx, result_cache=32) as srv:
        curg = g
        for step in range(3):
            add, rem = _random_step(rng, curg)
            curg = curg.apply_updates(add, rem).graph
            srv.submit_update(add, rem, timeout=60)
            qs = _mixed_queries(rng, curg, n=8)
            got = [srv.submit(u, v, p).result(timeout=60)
                   for (u, v, p) in qs]
            want = [dfs_baseline.answer_pcr(curg, u, v, p)
                    for (u, v, p) in qs]
            assert got == want, f"step {step}"
        assert srv.stats.updates == 3

"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,k,w", [
    (8, 32, 1), (16, 64, 2), (50, 96, 3), (130, 256, 5), (1, 32, 1),
    (257, 160, 7), (64, 1024, 4),
    # the fully-occupied default tile + non-tile-multiple wide shapes
    # (the vectorized column-broadcast inner loop's padding paths)
    (128, 128, 128), (100, 224, 40), (70, 64, 33),
])
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_bitset_matmul_sweep(m, k, w, density):
    a_bool = RNG.random((m, k)) < density
    x = RNG.integers(0, 2 ** 32, size=(k, w), dtype=np.uint32)
    a_packed = jnp.asarray(bitset.pack_bits_np(a_bool))
    xj = jnp.asarray(x)
    want = np.asarray(ref.bitset_matmul_ref(a_packed, xj))
    got = np.asarray(ops.frontier_step(a_packed, xj, mode="interpret"))
    np.testing.assert_array_equal(got, want)


def test_frontier_step_tiles_passthrough():
    """Explicit (ti, tk, tw) overrides reach the kernel and stay exact at
    shapes that are not multiples of the requested tiles."""
    a_bool = RNG.random((90, 160)) < 0.1
    x = RNG.integers(0, 2 ** 32, size=(160, 5), dtype=np.uint32)
    a_packed = jnp.asarray(bitset.pack_bits_np(a_bool))
    xj = jnp.asarray(x)
    want = np.asarray(ref.bitset_matmul_ref(a_packed, xj))
    for tiles in [(32, 64, 2), (64, 160, 5), (128, 32, 8)]:
        got = np.asarray(ops.frontier_step(a_packed, xj, mode="interpret",
                                           tiles=tiles))
        np.testing.assert_array_equal(got, want, err_msg=str(tiles))


def test_bitset_matmul_mxu_path():
    a_bool = RNG.random((40, 96)) < 0.2
    x = RNG.integers(0, 2 ** 32, size=(96, 3), dtype=np.uint32)
    a_packed = jnp.asarray(bitset.pack_bits_np(a_bool))
    want = np.asarray(ref.bitset_matmul_ref(a_packed, jnp.asarray(x)))
    got = np.asarray(ops.frontier_step(a_packed, jnp.asarray(x),
                                       mode="mxu"))
    np.testing.assert_array_equal(got, want)


def test_bitset_matmul_tiling_variants():
    from repro.kernels.bitset_matmul import bitset_matmul
    a_bool = RNG.random((100, 128)) < 0.1
    x = RNG.integers(0, 2 ** 32, size=(128, 6), dtype=np.uint32)
    a_packed = jnp.asarray(bitset.pack_bits_np(a_bool))
    want = np.asarray(ref.bitset_matmul_ref(a_packed, jnp.asarray(x)))
    for ti, tk, tw in [(32, 32, 2), (128, 64, 3), (8, 128, 6)]:
        got = np.asarray(bitset_matmul(a_packed, jnp.asarray(x), ti=ti,
                                       tk=tk, tw=tw, interpret=True))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("j,g,k,wv,wl", [
    (5, 2, 1, 1, 1), (37, 4, 3, 3, 2), (128, 4, 2, 8, 2), (1, 1, 4, 2, 2),
])
def test_way_filter_sweep(j, g, k, wv, wl):
    hv = RNG.integers(0, 2 ** 32, (j, g, wv), dtype=np.uint32)
    hl = RNG.integers(0, 2 ** 32, (j, g, wl), dtype=np.uint32)
    vv = RNG.integers(0, 2 ** 32, (j, g, k, wv), dtype=np.uint32)
    vl = RNG.integers(0, 2 ** 32, (j, g, k, wl), dtype=np.uint32)
    vb = (RNG.integers(0, 2 ** 32, (j, wv), dtype=np.uint32)
          & RNG.integers(0, 2 ** 32, (j, wv), dtype=np.uint32)
          & RNG.integers(0, 2 ** 32, (j, wv), dtype=np.uint32))
    rq = (RNG.integers(0, 2 ** 32, (j, wl), dtype=np.uint32)
          & RNG.integers(0, 2 ** 32, (j, wl), dtype=np.uint32))
    fb = RNG.integers(0, 2 ** 32, (j, wl), dtype=np.uint32)
    npl = np.zeros(wl, np.uint32)
    npl[-1] = 1 << 31
    args = [jnp.asarray(v) for v in (hv, hl, vv, vl, vb, rq, fb, npl)]
    want = np.asarray(ops.filter_ways(*args, mode="ref"))
    got = np.asarray(ops.filter_ways(*args, mode="interpret"))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,w", [(1, 1), (77, 9), (600, 3)])
def test_popcount_sweep(n, w):
    x = RNG.integers(0, 2 ** 32, (n, w), dtype=np.uint32)
    want = np.asarray(ops.popcount(jnp.asarray(x), mode="ref"))
    got = np.asarray(ops.popcount(jnp.asarray(x), mode="interpret"))
    np.testing.assert_array_equal(got, want)
    # cross-check against numpy
    expect = np.array([bin(int(v)).count("1") for row in x for v in row]
                      ).reshape(n, w).sum(-1)
    np.testing.assert_array_equal(want, expect)


def test_frontier_step_is_one_bfs_round():
    """Kernel semantics == one BFS frontier expansion on a real graph."""
    from repro.core import graph as G
    g = G.erdos_renyi(64, 3.0, 2, seed=0)
    adj = np.zeros((64, 64), dtype=bool)
    adj[g.src, g.indices] = True
    a_packed = jnp.asarray(bitset.pack_bits_np(adj))
    # frontier = identity bits: after one step, row u = successors of u
    eye = np.eye(64, dtype=bool)
    x = jnp.asarray(bitset.pack_bits_np(eye))
    out = np.asarray(ops.frontier_step(a_packed, x, mode="interpret"))
    out_bool = np.unpackbits(
        out.view(np.uint8), axis=1, bitorder="little")[:, :64].astype(bool)
    np.testing.assert_array_equal(out_bool, adj)

"""Standalone replicated-fleet fault smoke (NOT collected by pytest
directly — ``tests/test_fleet.py`` spawns it as a slow test, and the CI
``fleet`` job runs it as its own leg).

One shared store, a router over **3 replica processes**, and a single
writer publishing a *deterministic* update stream, so the graph as of
every LSN is known in the parent.  Three legs per backend:

1. **Replica SIGKILL mid-stream** — queries are submitted continuously
   while the writer publishes; one replica is SIGKILLed with requests
   in flight.  Every answer (re-dispatched or not) must equal the DFS
   oracle *at its read LSN* — zero wrong answers — and the fleet must
   evict and re-spawn the victim.
2. **Consistent reads at a pinned LSN** — answers routed with
   ``min_lsn=L`` carry ``lsn >= L`` and are bit-identical to a single
   caught-up in-process follower (``QueryServer.follow``) asked the
   same questions.  An **RPQ sub-leg** routes ``kind="rpq"`` regex
   queries over the same wire (regex-text serialization round-trips
   through the replicas) and checks each LSN-stamped answer against the
   product-graph oracle at its read LSN.
3. **Writer SIGKILL** — a writer subprocess is SIGKILLed mid-publish; a
   new ``FleetWriter`` attaches to the store (torn tail truncated, as
   single-process recovery would), resumes the stream, and the replicas
   keep serving oracle-correct answers through the hand-off.

Run directly (both backends)::

    PYTHONPATH=src python tests/fleet_check.py
"""
import os
import signal
import subprocess
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

from repro.core import dfs_baseline, graph as G  # noqa: E402
from repro.core import pattern as pat, rpq, tdr_build  # noqa: E402
from repro.launch import fleet as fleet_mod, serve  # noqa: E402
from repro.launch.router import FleetRouter  # noqa: E402

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)
N_V, N_L, N_STEPS = 24, 4, 24
N_REPLICAS = 3


def make_plan(seed: int):
    """Deterministic update stream: ``graphs[k]`` is the graph with the
    first ``k`` published updates applied — identical everywhere."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=seed)
    rng = np.random.default_rng(seed + 1)
    graphs, steps = [g], []
    for _ in range(N_STEPS):
        cur = graphs[-1]
        edges = list(zip(cur.src.tolist(), cur.indices.tolist(),
                         cur.labels.tolist()))
        add, rem = [], []
        for _ in range(int(rng.integers(1, 4))):
            kind = int(rng.integers(3))
            if kind <= 1 or not edges:
                u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
                if u != v:
                    add.append((u, v, int(rng.integers(N_L))))
            else:
                rem.append(edges[int(rng.integers(len(edges)))])
        steps.append((add, rem))
        graphs.append(cur.apply_updates(add, rem).graph)
    return graphs, steps


def query_pool(seed: int, n: int = 8):
    rng = np.random.default_rng(seed + 2)
    qs = []
    for i in range(n):
        u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
        labs = rng.choice(N_L, size=2, replace=False).tolist()
        p = [pat.all_of(labs), pat.any_of(labs), pat.none_of(labs),
             pat.parse(f"l{labs[0]} & !l{labs[1]}")][i % 4]
        qs.append((u, v, p))
    return qs


def rpq_pool():
    """Fixed regex pool with labels < N_L: lowered ((a|b)* → the LCR
    plan path), product-route (order-constrained), and unmatchable."""
    return [
        (0, 7, rpq.parse("(l0 | l1)*")),
        (3, 3, rpq.parse("l2*")),
        (1, 9, rpq.parse("l0 . (l1 | l2)*")),
        (5, 5, rpq.parse("l3 . l0")),
        (2, 11, rpq.parse("(l0 | l1 | l2 | l3)+")),
        (4, 8, rpq.parse("l1 . l2")),
    ]


def leg_rpq(router, writer, graphs):
    """Route kind="rpq" queries through the fleet wire; every stamped
    answer must equal the product-graph oracle at its read LSN, and
    pinned reads must carry lsn >= the pin."""
    L = writer.last_lsn
    futs = [(u, v, r, router.submit(u, v, r, kind="rpq"))
            for u, v, r in rpq_pool()]
    futs += [(u, v, r, router.submit(u, v, r, kind="rpq", min_lsn=L,
                                     lsn_timeout=240))
             for u, v, r in rpq_pool()[:3]]
    for i, (u, v, r, f) in enumerate(futs):
        ans, lsn = f.result(timeout=300)
        if i >= len(rpq_pool()):
            assert lsn >= L, f"pinned rpq read served at lsn {lsn} < {L}"
        want = dfs_baseline.answer_rpq(graphs[lsn], u, v, r)
        assert ans == want, \
            f"rpq: ({u},{v},{rpq.unparse(r)}) at lsn={lsn}: " \
            f"got {ans!r}, oracle {want!r}"
    return len(futs)


def check_at_lsn(graphs, u, v, p, ans, lsn, ctx):
    want = dfs_baseline.answer_pcr(graphs[lsn], u, v, p)
    assert ans == want, \
        f"{ctx}: ({u},{v},{pat.unparse(p)}) at lsn={lsn}: " \
        f"got {ans!r}, oracle {want!r}"


def writer_worker(directory: str, seed: int, first_step: int) -> None:
    """Leg-3 subprocess body: attach a writer and publish the tail of
    the deterministic stream, printing each acked LSN.  The parent
    SIGKILLs us mid-stream — no cleanup of any kind runs."""
    _, steps = make_plan(seed)
    w = fleet_mod.FleetWriter(directory)
    print("READY", flush=True)
    for add, rem in steps[first_step:]:
        lsn = w.publish(add, rem)
        print(f"LSN {lsn}", flush=True)
        time.sleep(0.05)
    print("DONE", flush=True)


def leg_replica_kill(router, flt, writer, graphs, steps, qs, n_pub):
    """Publish ``n_pub`` updates while streaming queries; SIGKILL one
    replica with requests in flight.  Zero wrong answers allowed."""
    ev0, rs0 = flt.evictions, flt.respawns
    results = []   # (u, v, p, future)
    victim = flt.members()[0]
    for j in range(n_pub):
        writer.publish(*steps[writer.last_lsn])
        for u, v, p in qs:
            results.append((u, v, p, router.submit(u, v, p)))
        if j == n_pub // 2:
            victim.kill()   # mid-stream, answers in flight
    for u, v, p in qs:     # post-kill traffic
        results.append((u, v, p,
                        router.submit(u, v, p, min_lsn=writer.last_lsn,
                                      lsn_timeout=240)))
    for u, v, p, f in results:
        ans, lsn = f.result(timeout=300)
        check_at_lsn(graphs, u, v, p, ans, lsn, "replica-kill")
    deadline = time.monotonic() + 120
    while len(flt.members()) < N_REPLICAS:
        assert time.monotonic() < deadline, "re-spawn never became ready"
        time.sleep(0.1)
    assert flt.evictions > ev0, "victim was never evicted"
    assert flt.respawns > rs0, "victim was never re-spawned"
    return len(results)


def leg_consistent_reads(router, backend, directory, writer, graphs, qs):
    """Pinned reads at the tip LSN, bit-identical to one caught-up
    in-process follower asked the same questions."""
    L = writer.last_lsn
    futs = [(u, v, p, router.submit(u, v, p, min_lsn=L,
                                    lsn_timeout=240))
            for u, v, p in qs]
    ref = serve.QueryServer.follow(directory, backend=backend)
    ref.start()
    try:
        assert ref.wait_for_lsn(L, timeout=120), "follower never caught up"
        for u, v, p, f in futs:
            ans, lsn = f.result(timeout=300)
            assert lsn >= L, f"consistent read served at lsn {lsn} < {L}"
            check_at_lsn(graphs, u, v, p, ans, lsn, "consistent-read")
            ref_ans, ref_lsn = ref.submit(u, v, p,
                                          with_lsn=True).result(timeout=300)
            assert ref_lsn >= L
            assert ans == ref_ans, \
                f"fleet {ans!r} != caught-up follower {ref_ans!r}"
    finally:
        ref.stop()
    return len(futs)


def leg_writer_kill(router, directory, graphs, steps, qs, seed,
                    first_step):
    """SIGKILL the writer process mid-publish; attach a fresh writer,
    resume the stream, and keep reading correctly throughout."""
    here = os.path.abspath(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(here)), "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, here, "--writer", directory, str(seed),
         str(first_step)],
        env=env, stdout=subprocess.PIPE, text=True)
    acked, killed = first_step, False
    for line in proc.stdout:
        line = line.strip()
        if line.startswith("LSN"):
            acked = int(line.split()[1])
            if acked >= first_step + 3:
                proc.send_signal(signal.SIGKILL)  # no cleanup runs
                killed = True
                break
        if line == "DONE":
            break
    proc.wait(timeout=60)
    assert killed, "writer finished before the kill"

    # reads stay correct while the writer seat is empty
    for u, v, p in qs[:4]:
        ans, lsn = router.submit(u, v, p).result(timeout=300)
        check_at_lsn(graphs, u, v, p, ans, lsn, "writer-dead")

    # the new writer sees the acked prefix (+ at most one in-flight
    # append the kill let land) and resumes the deterministic stream
    w2 = fleet_mod.FleetWriter(directory)
    try:
        k = w2.last_lsn
        assert k in (acked, acked + 1), \
            f"recovered writer at lsn {k}, acked {acked}"
        assert np.array_equal(w2.graph.indices, graphs[k].indices)
        assert np.array_equal(w2.graph.labels, graphs[k].labels)
        lsn2 = w2.publish(*steps[k])
        futs = [(u, v, p, router.submit(u, v, p, min_lsn=lsn2,
                                        lsn_timeout=240))
                for u, v, p in qs]
        for u, v, p, f in futs:
            ans, lsn = f.result(timeout=300)
            assert lsn >= lsn2
            check_at_lsn(graphs, u, v, p, ans, lsn, "writer-handoff")
    finally:
        w2.close()
    return k


def run_one(backend: str, workdir: str, seed: int) -> None:
    d = os.path.join(workdir, f"fleet-{backend}")
    graphs, steps = make_plan(seed)
    qs = query_pool(seed)
    idx0 = tdr_build.build_index(graphs[0], CFG, backend=backend)
    fleet_mod.init_store(idx0, d)
    writer = fleet_mod.FleetWriter(d)
    n_answers = 0
    with fleet_mod.Fleet(d, N_REPLICAS, backend, hb_s=0.1) as flt:
        router = FleetRouter(flt)
        n_answers += leg_replica_kill(router, flt, writer, graphs,
                                      steps, qs, n_pub=6)
        n_answers += leg_consistent_reads(router, backend, d, writer,
                                          graphs, qs)
        n_answers += leg_rpq(router, writer, graphs)
        first_step = writer.last_lsn
        writer.close()   # single-writer seat: release before the worker
        k = leg_writer_kill(router, d, graphs, steps, qs, seed,
                            first_step)
        print(f"[fleet] {backend}: {n_answers} streamed answers "
              f"oracle-correct at their read LSNs, "
              f"evictions={flt.evictions} respawns={flt.respawns} "
              f"redispatched={router.redispatched}, writer handed "
              f"off at lsn={k}")


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "--writer":
        writer_worker(sys.argv[2], int(sys.argv[3]), int(sys.argv[4]))
        return
    import tempfile
    backends = sys.argv[1:] or ["segment", "pallas"]
    with tempfile.TemporaryDirectory() as workdir:
        for backend in backends:
            run_one(backend, workdir, seed=9)
    print("fleet check OK")


if __name__ == "__main__":
    main()

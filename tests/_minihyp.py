"""Minimal stand-in for the ``hypothesis`` API this test-suite uses.

Vendored so tier-1 collection and execution work on clean containers
without hypothesis installed (``requirements-dev.txt`` installs the real
library; when importable it is preferred — see the guarded imports in
``test_pattern.py`` / ``test_tdr.py`` / ``test_engine.py``).

Implements just what the suite needs: ``@given(*strategies, **strategies)``
stacked with ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``booleans`` / ``sampled_from`` / ``lists`` / ``composite``
strategies.  Examples are drawn from a fixed-seed RNG, so runs are
deterministic (no shrinking, no database — falsifying examples are printed
in the failure message instead).
"""
from __future__ import annotations

import random

__version__ = "0.0-minihyp"

_BASE_SEED = 0x7D12


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example(self, rng: random.Random):
        return self._draw_fn(rng)


class strategies:
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return Strategy(draw)

    @staticmethod
    def composite(fn):
        """``@st.composite`` — ``fn(draw, *args, **kw)`` becomes a factory
        returning a Strategy (supports recursive use, as in test_pattern)."""
        def make(*args, **kwargs):
            def draw_fn(rng):
                def draw(strategy: Strategy):
                    return strategy.example(rng)
                return fn(draw, *args, **kwargs)
            return Strategy(draw_fn)
        return make


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._minihyp_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*pos_strats: Strategy, **kw_strats: Strategy):
    def deco(fn):
        cfg = getattr(fn, "_minihyp_settings", {"max_examples": 20})

        def wrapper():
            rng = random.Random(_BASE_SEED)
            for ex in range(cfg["max_examples"]):
                args = [s.example(rng) for s in pos_strats]
                kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"minihyp: falsified on example {ex}: "
                        f"args={args!r} kwargs={kwargs!r}: {e}") from e

        # plain no-arg signature so pytest doesn't treat the strategy
        # names as fixtures (deliberately no functools.wraps)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco

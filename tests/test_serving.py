"""Serving scheduler: property tests against direct ``answer_batch``.

The contract under test: **any** arrival order, batch-boundary split,
result-cache state, duplicate mix (including ``u == v`` self-queries and
repeated identical requests) must produce answers bit-identical to one
direct ``answer_batch`` call over the same queries.  The scheduler's
batching is driven deterministically here — ``_serve_batch`` on explicit
splits — plus one threaded end-to-end pass through ``submit`` to cover
the queue/condvar path.  Plan canonicalization gets its own equivalence
property (hash-consing must never change semantics).
"""
import threading

import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

from repro.core import dfs_baseline, graph as G, pattern as pat
from repro.core import rpq, tdr_build, tdr_query
from repro.launch import serve

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)

# built lazily at module scope (not a fixture) so the @given property
# tests can use it too — the minihyp fallback's wrappers take no
# arguments, so fixtures and strategies cannot mix there
_CACHE: dict = {}


def _served_graph():
    if "gi" not in _CACHE:
        g = G.random_graph("er", 40, 2.0, 4, seed=7)
        _CACHE["gi"] = (g, tdr_build.build_index(g, CFG))
    return _CACHE["gi"]


@pytest.fixture(scope="module")
def served_graph():
    return _served_graph()


def _query_pool(g, seed: int, n: int = 24):
    """Mixed pool: all families, u==v self-queries, repeated patterns."""
    rng = np.random.default_rng(seed)
    pool = []
    for i in range(n):
        u = int(rng.integers(g.n_vertices))
        v = u if i % 6 == 5 else int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=2, replace=False).tolist()
        kind = i % 5
        if kind == 0:
            p = pat.all_of(labs)
        elif kind == 1:
            p = pat.any_of(labs)
        elif kind == 2:
            p = pat.none_of(labs)
        elif kind == 3:
            p = pat.parse(f"l{labs[0]} & !l{labs[1]}")
        else:
            p = pat.lcr(labs, g.n_labels)
        pool.append((u, v, p))
    return pool


def _drive(server, requests):
    """Feed requests through the scheduler core on explicit batch
    boundaries (deterministic, no timing): returns per-request answers."""
    futs = []
    for batch in requests:
        reqs = []
        for (u, v, p) in batch:
            rows = tdr_query.pattern_rows(server.index, p,
                                          server.config.max_m)
            req = serve._Request(u, v, p, (u, v, pat.canonical_key(p)),
                                 rows.n_terms)
            reqs.append(req)
            futs.append(req.future)
        server._serve_batch(reqs)
    return [f.result(timeout=30) for f in futs]


@hp.given(seed=st.integers(0, 10_000),
          splits=st.lists(st.integers(1, 8), min_size=1, max_size=6),
          dup=st.booleans(), cache=st.booleans())
@hp.settings(max_examples=12, deadline=None)
def test_any_split_matches_direct(seed, splits, dup, cache):
    """Arrival order + batch-boundary splits + cache state never change
    answers vs a single direct answer_batch call."""
    g, idx = _served_graph()
    rng = np.random.default_rng(seed)
    pool = _query_pool(g, seed)
    order = rng.permutation(len(pool)).tolist()
    if dup:   # duplicates, some landing in the same batch, some across
        order = order + order[::2]
    queries = [pool[i] for i in order]

    server = serve.QueryServer(idx, result_cache=64 if cache else 0)
    # split the stream on the drawn boundaries (cycled until exhausted)
    batches, i, si = [], 0, 0
    while i < len(queries):
        n = splits[si % len(splits)]
        batches.append(queries[i:i + n])
        i += n
        si += 1
    got = _drive(server, batches)
    want = tdr_query.answer_batch(idx, queries).tolist()
    assert got == want
    # a replay over a warm result cache must also agree
    if cache:
        again = _drive(server, [queries])
        assert again == want


def test_dedup_and_cache_counted(served_graph):
    g, idx = served_graph
    q = _query_pool(g, 3)[0]
    server = serve.QueryServer(idx, result_cache=16)
    got = _drive(server, [[q, q, q]])
    assert got == [got[0]] * 3
    assert server.stats.dedup_hits == 2
    before = server.stats.cache_hits
    got2 = _drive(server, [[q]])
    assert got2 == [got[0]]
    assert server.stats.cache_hits == before + 1


def test_threaded_submit_matches_direct(served_graph):
    """End-to-end through submit(): concurrent clients, real scheduler
    thread, mixed duplicates — equal to the direct call."""
    g, idx = served_graph
    pool = _query_pool(g, 11, n=30)
    want = tdr_query.answer_batch(idx, pool).tolist()
    with serve.QueryServer(idx, max_wait_ms=1.0, result_cache=32) as srv:
        srv.warmup(pool[:8])
        results = {}
        lock = threading.Lock()

        def client(ids):
            for i in ids:
                u, v, p = pool[i]
                got = srv.submit(u, v, p).result(timeout=60)
                with lock:
                    results.setdefault(i, []).append(got)

        shards = [list(range(j, len(pool), 4)) + [0, 1] for j in range(4)]
        threads = [threading.Thread(target=client, args=(s,))
                   for s in shards]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for i, vals in results.items():
        assert all(v == want[i] for v in vals), (i, vals, want[i])


def test_admission_control(served_graph):
    g, idx = served_graph
    q = _query_pool(g, 5)[0]
    server = serve.QueryServer(idx, max_queue=2, result_cache=0)
    # scheduler not started: the queue fills and non-blocking submits shed
    server.submit(*q, block=False)
    server.submit(*q, block=False)
    with pytest.raises(serve.QueueFull):
        server.submit(*q, block=False)
    assert server.stats.rejected == 1
    with pytest.raises(serve.QueueFull):
        server.submit(*q, block=True, timeout=0.01)
    # draining on start answers the backlog
    server.start()
    server.stop(drain=True)


def test_pinned_plan_matches_unpinned(served_graph):
    """pin_m / special_labels pins change shapes, never answers."""
    g, idx = served_graph
    pool = _query_pool(g, 17)
    plan = tdr_query.compile_queries(idx, pool)
    want = tdr_query.answer_plan(idx, plan).tolist()
    for pin_m in (1, 2, 4):
        got = tdr_query.answer_plan(
            idx, plan, pin_m=pin_m,
            special_labels=tuple(range(g.n_labels)),
            exact_mode="full").tolist()
        assert got == want
    oracle = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in pool]
    assert want == oracle


def test_canonicalize_equivalence():
    """Hash-consing: canonical form is interned, key-stable, and
    semantically identical to the original pattern."""
    rng = np.random.default_rng(0)

    def rand_pat(depth=3):
        k = int(rng.integers(4)) if depth else 0
        if k == 0:
            return pat.label(int(rng.integers(4)))
        if k == 1:
            return pat.not_(rand_pat(depth - 1))
        kids = tuple(rand_pat(depth - 1)
                     for _ in range(int(rng.integers(1, 4))))
        return pat.And(kids) if k == 2 else pat.Or(kids)

    import itertools
    for _ in range(60):
        p = rand_pat()
        c = pat.canonicalize(p)
        assert pat.canonicalize(c) is pat.canonicalize(p)
        assert pat.canonical_key(c) == pat.canonical_key(p)
        labs = sorted(pat.labels_of(p))
        for bits in itertools.product((False, True), repeat=len(labs)):
            present = frozenset(l for l, b in zip(labs, bits) if b)
            assert pat.evaluate(p, present) == pat.evaluate(c, present)


def test_mixed_kind_load_no_recompile(served_graph):
    """Satellite contract: after a warmup pool covering every query kind,
    sustained mixed-kind traffic (bool/dist/witness/count/rpq, duplicate
    and fresh keys alike) adds ZERO jit cache entries — every kind's
    bucket grid is pinned up front — and every answer equals its oracle.
    Also pins the per-kind result-cache key: a dist hit must not serve a
    bool request for the same (u, v, pattern)."""
    from repro.core import engine as engine_mod

    g, idx = served_graph
    pool = _query_pool(g, 23, n=20)
    single = [q for q in pool if len(pat.to_dnf(q[2])) == 1]
    # rpq pool: lowered ((a|b)* rides answer_plan) and product-route
    # (order-constrained) regexes, plus u==v ε and unmatchable shapes —
    # few distinct keys so one scheduler batch stays inside the warmed
    # job buckets
    rpq_pool = [
        (0, 7, rpq.parse("(l0 | l1)*")),
        (3, 3, rpq.parse("l2*")),
        (1, 9, rpq.parse("l0 . (l1 | l2)*")),
        (5, 5, rpq.parse("l3 . l0")),
        (2, 11, rpq.parse("(l0 | l1 | l2 | l3)+")),
        (4, 8, rpq.parse("l1 . l2 . l3")),
        (6, 6, rpq.parse("l0?")),
        (0, 13, rpq.Sym(g.n_labels)),          # unmatchable atom
    ]
    with serve.QueryServer(idx, max_wait_ms=1.0, result_cache=64) as srv:
        srv.warmup(pool)
        n0 = engine_mod.jit_cache_entries()
        rng = np.random.default_rng(23)
        futs = []
        for i in range(60):
            u, v, p = pool[int(rng.integers(len(pool)))]
            kd = ("bool", "dist", "witness")[i % 3]
            futs.append(((u, v, p, kd), srv.submit(u, v, p, kind=kd)))
        for (u, v, p) in single[:6]:
            futs.append(((u, v, p, "count"),
                         srv.submit(u, v, p, kind="count", hops=4)))
        for i in range(20):
            u, v, r = rpq_pool[int(rng.integers(len(rpq_pool)))]
            futs.append(((u, v, r, "rpq"),
                         srv.submit(u, v, r, kind="rpq")))
        for (u, v, p, kd), f in futs:
            got = f.result(timeout=60)
            if kd == "bool":
                assert got == dfs_baseline.answer_pcr(g, u, v, p)
            elif kd == "dist":
                assert got == dfs_baseline.shortest_pcr(g, u, v, p)
            elif kd == "witness":
                want = dfs_baseline.shortest_pcr(g, u, v, p)
                if want < 0:
                    assert got is None
                else:
                    assert len(got) == want
                    assert dfs_baseline.verify_witness(g, u, v, p, got)
            elif kd == "rpq":
                assert got == dfs_baseline.answer_rpq(g, u, v, p), \
                    (u, v, rpq.unparse(p))
            else:
                assert got == dfs_baseline.count_routes(
                    g, u, v, p, hops=4, cap=32767)
        assert engine_mod.jit_cache_entries() == n0, \
            "mixed-kind load recompiled after warmup"
        # an rpq submit takes a regex AST, not a pattern — rejected on
        # the caller thread like every other submit-time contract
        with pytest.raises(ValueError, match="rpq"):
            srv.submit(0, 1, pat.label(0), kind="rpq")
        # result-cache keys carry the kind: same (u,v,p) under two kinds
        # is two distinct entries with kind-correct answers
        u, v, p = pool[0]
        b = srv.submit(u, v, p, kind="bool").result(timeout=60)
        d = srv.submit(u, v, p, kind="dist").result(timeout=60)
        assert isinstance(b, (bool, np.bool_)) and isinstance(d, int)
        assert b == (d >= 0)
        # count on a multi-term pattern is rejected on the caller thread
        multi = next(q for q in pool if len(pat.to_dnf(q[2])) > 1)
        with pytest.raises(ValueError, match="single"):
            srv.submit(*multi, kind="count", hops=2)
        with pytest.raises(ValueError, match="kind"):
            srv.submit(u, v, p, kind="fuzzy")


def test_plan_cache_hits(served_graph):
    g, idx = served_graph
    p = pat.all_of([0, 1])
    stats = tdr_query.QueryStats()
    tdr_query.compile_queries(idx, [(0, 1, p), (2, 3, p), (1, 1, p)],
                              stats=stats)
    assert stats.plan_lookups == 3
    assert stats.plan_misses <= 1   # one DNF expansion serves all three

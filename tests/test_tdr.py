"""TDR index + query engine: paper examples, oracle equivalence,
filter soundness, distributed build (hypothesis property tests)."""
import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

from repro.core import (dfs_baseline, graph as G, lcr, pattern as pat,
                        tdr_build, tdr_query)

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)


@pytest.fixture(scope="module")
def fig2():
    g = G.fig2_example()
    return g, tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=32,
                                                           g_max=2, k=2))


def test_paper_example1(fig2):
    g, idx = fig2
    # v0 -(b AND d)-> v5 : true via path a,d,b
    assert tdr_query.answer(idx, 0, 5, pat.all_of([1, 3])) is True
    # v0 -NOT{a,b}-> v4 : false (all paths to v4 carry b)
    assert tdr_query.answer(idx, 0, 4, pat.none_of([0, 1])) is False


def test_paper_example3(fig2):
    g, idx = fig2
    assert tdr_query.answer(idx, 7, 4, pat.none_of([0])) is False
    assert tdr_query.answer(idx, 0, 6, pat.all_of([1, 4])) is True


def test_self_query(fig2):
    g, idx = fig2
    assert tdr_query.answer(idx, 3, 3, pat.none_of([0])) is True
    assert tdr_query.answer(idx, 3, 3, pat.all_of([0])) is False


def _random_queries(rng, g, n):
    qs = []
    for _ in range(n):
        u, v = int(rng.integers(g.n_vertices)), int(rng.integers(
            g.n_vertices))
        kind = rng.integers(5)
        labs = rng.choice(g.n_labels, size=min(2, g.n_labels),
                          replace=False).tolist()
        if kind == 0:
            p = pat.all_of(labs)
        elif kind == 1:
            p = pat.any_of(labs)
        elif kind == 2:
            p = pat.none_of(labs)
        elif kind == 3:
            p = pat.parse(f"l{labs[0]} & !l{labs[-1]}")
        else:
            p = pat.lcr(labs, g.n_labels)
        qs.append((u, v, p))
    return qs


@hp.given(seed=st.integers(0, 10_000), kind=st.sampled_from(["er", "pa"]))
@hp.settings(max_examples=15, deadline=None)
def test_tdr_matches_oracle(seed, kind):
    rng = np.random.default_rng(seed)
    g = G.random_graph(kind, 40, 2.0, 4, seed=seed)
    idx = tdr_build.build_index(g, CFG)
    queries = _random_queries(rng, g, 20)
    got = tdr_query.answer_batch(idx, queries)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    assert got.tolist() == want


@hp.given(seed=st.integers(0, 10_000))
@hp.settings(max_examples=10, deadline=None)
def test_filters_are_sound(seed):
    """Phase-1 filters alone (UNKNOWN -> true) must over-approximate: never
    reject a truly-reachable query."""
    rng = np.random.default_rng(seed)
    g = G.erdos_renyi(40, 2.5, 4, seed=seed)
    idx = tdr_build.build_index(g, CFG)
    queries = _random_queries(rng, g, 20)
    upper = tdr_query.answer_batch(idx, queries, filters_only=True)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    for ub, w in zip(upper.tolist(), want):
        if w:
            assert ub, "filter cascade produced a false negative"


def test_stats_pruning_happens():
    g = G.erdos_renyi(60, 1.2, 4, seed=3)   # sparse -> most pairs failing
    idx = tdr_build.build_index(g, CFG)
    rng = np.random.default_rng(0)
    queries = _random_queries(rng, g, 60)
    stats = tdr_query.QueryStats()
    tdr_query.answer_batch(idx, queries, stats=stats)
    assert stats.filter_false > 0          # the index prunes something
    assert stats.exact_jobs < stats.n_jobs


def test_lcr_translation_matches_oracle():
    g = G.erdos_renyi(40, 2.0, 4, seed=9)
    idx = tdr_build.build_index(g, CFG)
    rng = np.random.default_rng(1)
    queries = []
    for _ in range(20):
        u, v = int(rng.integers(40)), int(rng.integers(40))
        allowed = rng.choice(4, size=2, replace=False).tolist()
        queries.append((u, v, allowed))
    got = lcr.answer_lcr_batch(idx, queries)
    want = [dfs_baseline.answer_lcr(g, u, v, set(a)) for u, v, a in queries]
    assert got.tolist() == want


def test_p2h_lite_matches_oracle():
    g = G.erdos_renyi(25, 1.5, 3, seed=4)
    full = lcr.P2HLite.build(g)
    rng = np.random.default_rng(2)
    for _ in range(30):
        u, v = int(rng.integers(25)), int(rng.integers(25))
        allowed = rng.choice(3, size=2, replace=False).tolist()
        assert full.query(u, v, allowed) == dfs_baseline.answer_lcr(
            g, u, v, set(allowed))


def test_index_size_accounting():
    g = G.erdos_renyi(100, 3.0, 4, seed=0)
    idx = tdr_build.build_index(g, CFG)
    logical = idx.size_bytes(logical=True)
    dense = idx.size_bytes(logical=False)
    assert 0 < logical <= dense


def test_distributed_closure_matches_oracle():
    """Converged packed-word closure == the tdr_build fixpoint semantics:
    R[u] = OR over v with u →+ v of bits(v) — the vertex's own seed bits
    are NOT included unless u lies on a cycle (no rounds= guess, no
    rows[u] OR papering over the old self-seed mismatch)."""
    import jax
    from jax.sharding import Mesh
    from repro.core import distributed
    g = G.erdos_renyi(50, 2.0, 4, seed=1)
    cfg = tdr_build.TDRConfig(vtx_bits=64)
    _, _, disc = tdr_build.dfs_intervals(g)
    words = tdr_build._vertex_bit_words(cfg, disc)
    rows = tdr_build._vertex_bit_rows(cfg, disc)
    mesh = Mesh(np.array(jax.devices()).reshape(1,), ("data",))
    rvec = np.asarray(distributed.distributed_closure(g, words, mesh))
    for u in range(0, 50, 7):
        reach = dfs_baseline.reachable_set(g, u)
        want = np.zeros(cfg.vtx_bits, dtype=bool)
        for v in np.flatnonzero(reach):
            want |= rows[v]
        got = np.unpackbits(rvec[u].view(np.uint8),
                            bitorder="little")[:64].astype(bool)
        assert (want == got).all()


def test_distributed_closure_rejects_bool_planes():
    """The bool-plane exchange is retired: packed uint32 words only."""
    import jax
    import pytest as pt
    from jax.sharding import Mesh
    from repro.core import distributed
    g = G.erdos_renyi(10, 1.5, 2, seed=0)
    mesh = Mesh(np.array(jax.devices()).reshape(1,), ("data",))
    with pt.raises(TypeError, match="packed uint32"):
        distributed.distributed_closure(
            g, np.zeros((10, 32), dtype=bool), mesh)


def test_hash_schedule_never_wraps():
    """All n_hashes Bloom position arrays must be pairwise distinct — the
    pre-fix key schedule wrapped at 4 hashes (ks[(i-1) % 3]), making hash
    4 duplicate hash 1 bit-for-bit with zero added selectivity."""
    disc = np.arange(200, dtype=np.int64)
    for scheme in ("dfs-block", "mult"):
        cfg = tdr_build.TDRConfig(vtx_bits=256, n_hashes=8,
                                  hash_scheme=scheme)
        pos = tdr_build._vertex_hash_positions(cfg, disc)
        assert len(pos) == 8
        for i in range(len(pos)):
            for j in range(i + 1, len(pos)):
                assert not np.array_equal(pos[i], pos[j]), (scheme, i, j)
    # the first four hashes (the pre-fix reach) are frozen: same keys
    ks = tdr_build._hash_keys(3)
    assert [int(k) for k in ks] == [0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F,
                                    0x165667B19E3779F9]

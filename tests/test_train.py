"""Training substrate: learning, determinism, microbatching, checkpointing,
fault tolerance (checkpoint-restart reproduces the run)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import Checkpointer
from repro.data import DataConfig, batch_for_step
from repro.models import init_params
from repro.train import AdamWConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def small_setup():
    cfg = C.get("phi3-mini-3.8b").reduced()
    dc = DataConfig(task="copy", vocab=cfg.vocab, seq_len=32,
                    global_batch=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, dc, params


def test_loss_decreases(small_setup):
    cfg, dc, params = small_setup
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        lr=3e-3, warmup_steps=10, decay_steps=300)))
    losses = []
    for i in range(250):
        state, m = step(state, batch_for_step(dc, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(task="lm", vocab=64, seq_len=16, global_batch=8)
    a = batch_for_step(dc, 7)
    b = batch_for_step(dc, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(dc, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard slicing partitions the global batch
    s0 = batch_for_step(dc, 7, shard=(0, 2))["tokens"]
    s1 = batch_for_step(dc, 7, shard=(1, 2))["tokens"]
    np.testing.assert_array_equal(np.concatenate([s0, s1]), a["tokens"])


def test_microbatch_equivalence(small_setup):
    """grad accumulation over 2 microbatches == single batch step (same
    data, same update) within fp tolerance."""
    cfg, dc, params = small_setup
    s1 = init_train_state(cfg, params)
    s2 = jax.tree.map(lambda x: x, s1)
    opt = AdamWConfig(lr=1e-3)
    step1 = jax.jit(make_train_step(cfg, opt, n_microbatches=1))
    step2 = jax.jit(make_train_step(cfg, opt, n_microbatches=2))
    batch = batch_for_step(dc, 0)
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    p1 = jax.tree.leaves(s1["params"])
    p2 = jax.tree.leaves(s2["params"])
    worst = max(float(jnp.abs(a - b).max()) for a, b in zip(p1, p2))
    assert worst < 5e-3, worst


def test_checkpoint_roundtrip_and_gc(small_setup):
    cfg, dc, params = small_setup
    state = init_train_state(cfg, params)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        assert ck.all_steps() == [3, 4]            # gc keeps last 2
        step, restored = ck.restore(state)
        assert step == 4
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(small_setup):
    cfg, dc, params = small_setup
    state = init_train_state(cfg, params)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=True)
        ck.save(10, state)
        ck.wait()
        assert ck.latest_step() == 10


def test_restart_reproduces_run(small_setup):
    """Fault tolerance: train 6 steps; or crash at 3 + restore + 3 more ->
    identical params (deterministic pipeline + checkpoint)."""
    cfg, dc, params = small_setup
    opt = AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))

    # uninterrupted
    state = init_train_state(cfg, params)
    for i in range(6):
        state, _ = step(state, batch_for_step(dc, i))
    ref = jax.tree.leaves(state["params"])

    # interrupted at step 3
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        state2 = init_train_state(cfg, params)
        for i in range(3):
            state2, _ = step(state2, batch_for_step(dc, i))
        ck.save(3, state2)
        del state2                                  # "crash"
        _, state3 = ck.restore(init_train_state(cfg, params))
        for i in range(3, 6):
            state3, _ = step(state3, batch_for_step(dc, i))
    got = jax.tree.leaves(state3["params"])
    for a, b in zip(ref, got):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_bf16_moments_option(small_setup):
    cfg, dc, params = small_setup
    opt = AdamWConfig(lr=1e-3, moment_dtype="bfloat16")
    state = init_train_state(cfg, params, opt)
    assert jax.tree.leaves(state["opt"]["m"])[0].dtype == jnp.bfloat16
    step = jax.jit(make_train_step(cfg, opt))
    state, m = step(state, batch_for_step(dc, 0))
    assert bool(jnp.isfinite(m["loss"]))


def test_lr_schedule_shape():
    from repro.train import optimizer
    opt = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(optimizer.schedule(opt, jnp.int32(s)))
           for s in (0, 5, 10, 50, 100, 1000)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[-1] == pytest.approx(0.1)

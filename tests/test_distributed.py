"""Distributed TDR: sharded build/query equivalence.

Fast legs run on a 1-device mesh in-process; the real multi-device leg
spawns ``tests/multidevice_check.py`` in a subprocess with 8 fake
host-platform devices (jax locks the device count at first init, so it
cannot run in this process).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from _qgen import mixed_queries as _mixed_queries
from repro.core import (dfs_baseline, distributed, graph as G,
                        tdr_build, tdr_query)

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)


def _mesh1():
    return Mesh(np.array(jax.devices()).reshape(-1), ("data",))


def test_sharded_build_bit_identical():
    """distributed.build_index == tdr_build.build_index on every plane
    (1-device mesh; the >=4-device leg is the subprocess check)."""
    g = G.random_graph("pa", 57, 2.3, 4, seed=3)
    ref = tdr_build.build_index(g, CFG, backend="segment")
    got = tdr_build.build_index(g, CFG, mesh=_mesh1())
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f)
    assert got.fixpoint_rounds == ref.fixpoint_rounds
    np.testing.assert_array_equal(np.asarray(got.push),
                                  np.asarray(ref.push))
    np.testing.assert_array_equal(got.vtx_words, ref.vtx_words)


def test_sharded_answer_batch_matches_oracle():
    g = G.random_graph("er", 48, 2.2, 4, seed=7)
    mesh = _mesh1()
    idx = tdr_build.build_index(g, CFG, mesh=mesh)
    rng = np.random.default_rng(7)
    queries = _mixed_queries(rng, g, 24)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
    got = distributed.answer_batch(idx, queries, mesh=mesh,
                                   backend="segment")
    assert got.tolist() == want
    # and bit-identical to the meshless driver on the same index
    local = tdr_query.answer_batch(idx, queries, backend="segment")
    assert got.tolist() == local.tolist()


def test_filter_cascade_sharded_matches_local():
    g = G.random_graph("er", 40, 2.0, 4, seed=5)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    rng = np.random.default_rng(5)
    plan = tdr_query.compile_queries(idx, _mixed_queries(rng, g, 20))
    mesh = _mesh1()
    jp = -(-plan.n_jobs // mesh.devices.size) * mesh.devices.size
    plan_p = plan.pad_to(max(jp, 16))
    import jax.numpy as jnp
    sat_out, sat_in = idx.summary_flags_dev()
    want = np.asarray(tdr_query._filter_cascade(
        jnp.asarray(plan_p.u), jnp.asarray(plan_p.v),
        jnp.asarray(plan_p.req_w), jnp.asarray(plan_p.forb_w),
        tdr_query._null_words_dev(idx.cfg),
        idx.vtx_packed, idx.h_vtx, idx.h_lab, idx.v_vtx, idx.v_lab,
        idx.n_out, idx.n_in, sat_out, sat_in, idx.push, idx.pop,
        k=idx.cfg.k, mode="ref"))
    got = distributed.filter_cascade_sharded(idx, plan_p, mesh, "ref")
    np.testing.assert_array_equal(got, want)


def test_sharded_build_edgeless_graph():
    """An edgeless graph must build (every shard slot is padding), and
    still match the single-device planes bit-for-bit."""
    g = G.Graph.from_edges(6, 2, [])
    ref = tdr_build.build_index(g, CFG, backend="segment")
    got = tdr_build.build_index(g, CFG, mesh=_mesh1())
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f)


def test_partition_graph_covers_every_edge():
    g = G.random_graph("pa", 33, 2.5, 3, seed=1)
    for by in ("src", "dst"):
        v_pad, ed = distributed.partition_graph(g, 4, by=by)
        per = v_pad // 4
        own = g.src if by == "src" else np.asarray(g.indices)
        other = np.asarray(g.indices) if by == "src" else g.src
        seen = set()
        for s in range(4):
            for k in np.flatnonzero(ed.valid[s]):
                e = int(ed.eidx[s, k])
                assert e not in seen
                seen.add(e)
                assert own[e] == ed.local[s, k] + s * per
                assert other[e] == ed.remote[s, k]
        assert len(seen) == g.n_edges


@pytest.mark.slow
def test_multidevice_subprocess():
    """The >=4-device acceptance leg: 8 fake host-platform devices in a
    fresh process (device count locks at jax init)."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    r = subprocess.run(
        [sys.executable, os.path.join(here, "multidevice_check.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "multidevice check OK" in r.stdout

"""RPQ front-end: oracle-first property suite.

Layered the way the executors were built (the oracle lands first and is
itself cross-checked before anything downstream leans on it):

1. ``dfs_baseline.answer_rpq`` (product-graph BFS) vs brute-force path
   enumeration at tiny sizes — the oracle is tested, not assumed.
2. Front-end algebra: parse/unparse round-trip fuzz (precedence and
   parenthesization edge cases), canonicalize idempotence + language
   preservation, the Glushkov NFA vs the independent span matcher.
3. The DNF-lowering rewriter: every regex it claims index-expressible is
   *language-equal* to its lowering on all words up to length 4; the
   inexpressible shapes must return None (route to the product
   executor) — no silent wrong-fragment lowering.
4. The executors: ``rpq_batch`` equals the oracle across graphs ×
   backends × exact modes × u==v × unreachable × empty-language regexes
   (>= 200 generated cases), LCR-as-RPQ matches the existing LCR path
   bit-for-bit, and ``answer_mixed`` routes kind="rpq" correctly.
"""
import itertools

import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

import _qgen
from repro.core import dfs_baseline, graph as G, pattern as pat, rpq
from repro.core import tdr_build, tdr_query

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)

# built lazily at module scope so @given property tests can share them
# (minihyp wrappers take no arguments — fixtures and strategies can't mix)
_CACHE: dict = {}


def _graphs():
    if "gs" not in _CACHE:
        _CACHE["gs"] = [
            G.random_graph("er", 40, 2.0, 4, seed=7),
            G.random_graph("pa", 30, 2.5, 3, seed=11),
        ]
    return _CACHE["gs"]


def _index(gi: int, backend: str):
    key = ("idx", gi, backend)
    if key not in _CACHE:
        _CACHE[key] = tdr_build.build_index(_graphs()[gi], CFG,
                                            backend=backend)
    return _CACHE[key]


def _rand_rpq(rng, n_labels, depth=3):
    return _qgen.random_rpq(rng, n_labels, depth=depth)


# ------------------------------------------------- 1. the oracle itself
def _enumerate_words(g, u, v, max_len):
    """Every label word along some u→v path of length <= max_len (walks,
    so cycles re-enter; bounded length keeps it finite)."""
    words = set()
    stack = [(u, ())]
    while stack:
        x, w = stack.pop()
        if x == v:
            words.add(w)
        if len(w) == max_len:
            continue
        for i in range(int(g.indptr[x]), int(g.indptr[x + 1])):
            stack.append((int(g.indices[i]), w + (int(g.labels[i]),)))
    return words


@hp.given(seed=st.integers(0, 10_000))
@hp.settings(max_examples=20, deadline=None)
def test_oracle_vs_brute_force_enumeration(seed):
    """answer_rpq on tiny graphs == "some enumerated path word matches",
    for regexes whose shortest accepted word is short enough that the
    length-6 enumeration horizon is conclusive when it says True."""
    rng = np.random.default_rng(seed)
    g = G.random_graph("er", int(rng.integers(4, 13)), 1.5, 3,
                       seed=int(rng.integers(1000)))
    r = _rand_rpq(rng, g.n_labels, depth=2)
    u, v = int(rng.integers(g.n_vertices)), int(rng.integers(g.n_vertices))
    words = _enumerate_words(g, u, v, max_len=6)
    brute = any(rpq.matches(r, w) for w in words)
    got = dfs_baseline.answer_rpq(g, u, v, r)
    if brute:
        assert got, f"oracle missed a length<=6 witness for " \
            f"({u},{v},{rpq.unparse(r)})"
    elif not got:
        pass        # agree on False
    else:
        # oracle says True via a path longer than the horizon: verify by
        # re-running the enumeration one notch deeper before accepting
        deeper = _enumerate_words(g, u, v, max_len=10)
        assert any(rpq.matches(r, w) for w in deeper), \
            f"oracle claims True with no witness <= 10 for " \
            f"({u},{v},{rpq.unparse(r)})"


def test_oracle_fixed_cases():
    """Hand-checkable product-BFS cases: order sensitivity, ε, cycles."""
    g = G.Graph.from_edges(4, 2, [(0, 1, 0), (1, 2, 1), (2, 0, 0)])
    assert dfs_baseline.answer_rpq(g, 0, 2, rpq.parse("l0 . l1"))
    assert not dfs_baseline.answer_rpq(g, 0, 2, rpq.parse("l1 . l0"))
    assert dfs_baseline.answer_rpq(g, 0, 0, rpq.parse("l0*"))      # ε
    assert not dfs_baseline.answer_rpq(g, 0, 0, rpq.parse("l1+"))
    assert dfs_baseline.answer_rpq(g, 0, 0, rpq.parse("(l0.l1.l0)+"))
    assert not dfs_baseline.answer_rpq(g, 0, 2, rpq.parse("l0 . l0"))


# --------------------------------------- 2. front-end algebra + the NFA
@hp.given(seed=st.integers(0, 100_000))
@hp.settings(max_examples=100, deadline=None)
def test_parse_unparse_roundtrip(seed):
    rng = np.random.default_rng(seed)
    r = _rand_rpq(rng, 4, depth=4)
    text = rpq.unparse(r)
    back = rpq.parse(text)
    assert back == r, f"{text!r} reparsed as {rpq.unparse(back)!r}"
    assert rpq.canonical_key(back) == rpq.canonical_key(r)


def test_parse_precedence_and_parens():
    # concatenation binds tighter than |, postfix tighter than both
    assert rpq.parse("l0 | l1 . l2") == rpq.Alt(
        (rpq.Sym(0), rpq.Cat((rpq.Sym(1), rpq.Sym(2)))))
    assert rpq.parse("(l0 | l1) . l2") == rpq.Cat(
        (rpq.Alt((rpq.Sym(0), rpq.Sym(1))), rpq.Sym(2)))
    assert rpq.parse("l0 . l1*") == rpq.Cat(
        (rpq.Sym(0), rpq.Star(rpq.Sym(1))))
    assert rpq.parse("(l0 . l1)*") == rpq.Star(
        rpq.Cat((rpq.Sym(0), rpq.Sym(1))))
    assert rpq.parse("l0*+?") == rpq.Opt(rpq.Plus(rpq.Star(rpq.Sym(0))))
    assert rpq.parse("l0 l1") == rpq.parse("l0 . l1")   # juxtaposition
    assert rpq.parse("0 1") == rpq.parse("l0 . l1")     # bare digits
    for bad in ("", "l0 |", "(l0", "l0)", "*l0", "l0 & l1", "lx"):
        with pytest.raises(ValueError):
            rpq.parse(bad)


@hp.given(seed=st.integers(0, 100_000))
@hp.settings(max_examples=60, deadline=None)
def test_canonicalize_idempotent_language_preserving(seed):
    rng = np.random.default_rng(seed)
    r = _rand_rpq(rng, 3, depth=3)
    c = rpq.canonicalize(r)
    assert rpq.canonicalize(c) is rpq.canonicalize(r)   # interned + stable
    assert rpq.canonical_key(c) == rpq.canonical_key(r)
    for n in range(4):
        for w in itertools.product(range(3), repeat=n):
            assert rpq.matches(c, w) == rpq.matches(r, w), \
                f"canonicalize changed L({rpq.unparse(r)}) at {w}"


@hp.given(seed=st.integers(0, 100_000))
@hp.settings(max_examples=60, deadline=None)
def test_nfa_equals_span_matcher(seed):
    """compile_nfa (what every executor runs) vs the independent span
    matcher, all words up to length 4."""
    rng = np.random.default_rng(seed)
    r = _rand_rpq(rng, 3, depth=3)
    nfa = rpq.compile_nfa(r, 3)
    assert nfa.nullable == rpq.matches(r, ())
    assert bool(nfa.accept & 1) == nfa.nullable
    for n in range(5):
        for w in itertools.product(range(3), repeat=n):
            s = np.uint32(nfa.start)
            for a in w:
                ns = np.uint32(0)
                for q in range(nfa.n_states):
                    if (int(s) >> q) & 1:
                        ns |= nfa.tab[a][q]
                s = ns
            assert bool(int(s) & nfa.accept) == rpq.matches(r, w)


def test_nfa_state_cap():
    wide = rpq.Cat(tuple(rpq.Sym(0) for _ in range(40)))
    with pytest.raises(ValueError, match="at most"):
        rpq.compile_nfa(wide, 2)


# ------------------------------------------------------- 3. the rewriter
@hp.given(seed=st.integers(0, 100_000))
@hp.settings(max_examples=80, deadline=None)
def test_rewriter_language_equality(seed):
    """Whenever the rewriter claims a regex is index-expressible, the
    lowering must be language-EQUAL on every word up to length 4 (both
    directions — a word matches the regex iff its label set satisfies
    the pattern).  Not just agreement on sampled graphs."""
    rng = np.random.default_rng(seed)
    n_l = 3
    r = _rand_rpq(rng, n_l, depth=3)
    p = rpq.lower_to_pattern(r, n_l)
    if p is None:
        return
    for n in range(5):
        for w in itertools.product(range(n_l), repeat=n):
            want = rpq.matches(r, w)
            got = pat.evaluate(p, frozenset(w))
            assert got == want, \
                f"lowering {pat.unparse(p)!r} of {rpq.unparse(r)!r} " \
                f"differs at word {w}"


def test_rewriter_fragment_boundaries():
    """The expressible fragment is exactly unions of single-atom stars;
    order/count-constrained shapes must route to the product executor."""
    n_l = 4
    expressible = ["l0*", "(l0|l1)*", "(l0|l1)* | l2*", "(l0*)*",
                   "(l0* | l1)*", "l0* | l0*"]
    for s in expressible:
        assert rpq.lower_to_pattern(rpq.parse(s), n_l) is not None, s
    inexpressible = ["l0", "l0 . l1", "(l0.l1)*", "l0+", "l0?",
                     "l0* . l1*", "l0 | l1*", "(l0|l1.l2)*"]
    for s in inexpressible:
        assert rpq.lower_to_pattern(rpq.parse(s), n_l) is None, s
    # ... and the executor really does give them product-graph answers
    # (the property suite below covers this across random cases; here we
    # pin one order-sensitive pair an LCR-style lowering would conflate)
    g = G.Graph.from_edges(3, 2, [(0, 1, 0), (1, 2, 1)])
    idx = tdr_build.build_index(g, CFG)
    assert tdr_query.answer_rpq(idx, 0, 2, rpq.parse("l0 . l1"))
    assert not tdr_query.answer_rpq(idx, 0, 2, rpq.parse("l1 . l0"))


def test_lcr_as_rpq_bit_for_bit():
    """(a|b|…)* asked as an RPQ returns the same array as the existing
    LCR pattern path — same planner, same caches, same engine."""
    for gi, g in enumerate(_graphs()):
        idx = _index(gi, "segment")
        rng = np.random.default_rng(100 + gi)
        rpq_qs, pat_qs = [], []
        for _ in range(20):
            u = int(rng.integers(g.n_vertices))
            v = int(rng.integers(g.n_vertices))
            labs = sorted(set(rng.integers(0, g.n_labels, size=2).tolist()))
            rpq_qs.append((u, v, rpq.lcr(labs, g.n_labels)))
            pat_qs.append((u, v, pat.lcr(labs, g.n_labels)))
        got = tdr_query.rpq_batch(idx, rpq_qs)
        want = tdr_query.answer_batch(idx, pat_qs)
        assert got.tolist() == want.tolist()
        oracle = [dfs_baseline.answer_pcr(g, u, v, p)
                  for u, v, p in pat_qs]
        assert got.tolist() == oracle


# ------------------------------------------------------ 4. the executors
def _case_pool(gi, seed, n):
    g = _graphs()[gi]
    rng = np.random.default_rng(seed)
    qs = _qgen.rpq_queries(rng, g, n)
    # make sure the advertised edge cases are represented every run
    qs.append((0, 0, rpq.parse("l0*")))                  # ε at u == v
    qs.append((0, 0, rpq.parse("l0 . l1")))              # u == v, no ε
    qs.append((0, g.n_vertices - 1,
               rpq.Sym(g.n_labels)))                     # unmatchable atom
    qs.append((1, 2, rpq.Star(rpq.Sym(g.n_labels))))     # ε-only language
    return qs


def test_executor_vs_oracle_200_cases():
    """The acceptance sweep: >= 200 generated (graph, query) cases per
    backend, both graphs, mixed expressible/product routes, compared to
    the product-BFS oracle."""
    total = 0
    for backend in ("segment", "pallas"):
        for gi, g in enumerate(_graphs()):
            idx = _index(gi, backend)
            qs = _case_pool(gi, seed=1000 + gi, n=110)
            want = [dfs_baseline.answer_rpq(g, u, v, r) for u, v, r in qs]
            got = tdr_query.rpq_batch(idx, qs, backend=backend)
            assert got.tolist() == want, \
                [(u, v, rpq.unparse(r))
                 for (u, v, r), a, b in zip(qs, got.tolist(), want)
                 if a != b][:5]
            total += len(qs)
    assert total >= 200 * 2     # >= 200 per backend


def test_exact_modes_agree():
    gi = 0
    g = _graphs()[gi]
    idx = _index(gi, "segment")
    qs = _case_pool(gi, seed=5, n=24)
    want = [dfs_baseline.answer_rpq(g, u, v, r) for u, v, r in qs]
    for mode in ("auto", "compact", "full"):
        got = tdr_query.rpq_batch(idx, qs, exact_mode=mode)
        assert got.tolist() == want, mode
    with pytest.raises(ValueError, match="exact_mode"):
        tdr_query.rpq_batch(idx, qs, exact_mode="legacy")


def test_answer_mixed_routes_rpq():
    gi = 0
    g = _graphs()[gi]
    idx = _index(gi, "segment")
    rng = np.random.default_rng(9)
    mixed = []
    for i, (u, v, p) in enumerate(_qgen.mixed_queries(rng, g, 12)):
        mixed.append((u, v, p, ("bool", "dist")[i % 2]))
    for (u, v, r) in _qgen.rpq_queries(rng, g, 12):
        mixed.append((u, v, r, "rpq"))
    res = tdr_query.answer_mixed(idx, mixed)
    for (q, got) in zip(mixed, res):
        u, v, x, kd = q
        if kd == "bool":
            assert got == dfs_baseline.answer_pcr(g, u, v, x)
        elif kd == "dist":
            assert got == dfs_baseline.shortest_pcr(g, u, v, x)
        else:
            assert got == dfs_baseline.answer_rpq(g, u, v, x)


def test_compile_queries_rejects_rpq_kind():
    idx = _index(0, "segment")
    with pytest.raises(ValueError, match="rpq"):
        tdr_query.compile_queries(idx, [(0, 1, pat.label(0), "rpq")])


def test_rpq_rows_cached():
    idx = _index(0, "segment")
    stats = tdr_query.QueryStats()
    r1 = rpq.parse("l0 . (l1 | l2)*")
    r2 = rpq.parse("l0 (l2 | l1)*")     # same canonical form
    tdr_query.rpq_rows(idx, r1, stats=stats)
    tdr_query.rpq_rows(idx, r2, stats=stats)
    assert stats.plan_lookups == 2
    assert stats.plan_misses <= 1
    rows = tdr_query.rpq_rows(idx, r1)
    assert rows.lowered is None and rows.feasible
    assert rows.n_terms == 1


# --------------------------------------------------- API edges & errors
def test_constructor_helpers_and_nullable():
    assert rpq.cat(rpq.sym(0)) == rpq.Sym(0)     # single-kid cat collapses
    r2 = rpq.cat(rpq.sym(0), rpq.sym(1))
    assert isinstance(r2, rpq.Cat) and not rpq.nullable(r2)
    assert rpq.nullable(rpq.star(rpq.sym(0)))
    assert rpq.nullable(rpq.opt(rpq.sym(1)))
    assert not rpq.nullable(rpq.plus(rpq.sym(1)))
    assert rpq.nullable(rpq.plus(rpq.star(rpq.sym(0))))   # Plus defers
    assert not rpq.nullable(rpq.sym(0))
    assert rpq.nullable(rpq.cat(rpq.star(rpq.sym(0)), rpq.opt(rpq.sym(1))))
    assert rpq.nullable(rpq.alt(rpq.sym(0), rpq.star(rpq.sym(1))))


def test_canonicalize_error_branches():
    with pytest.raises(ValueError, match="negative"):
        rpq.canonicalize(rpq.Sym(-1))
    with pytest.raises(ValueError, match="empty concat"):
        rpq.canonicalize(rpq.Cat(()))
    with pytest.raises(ValueError, match="empty alt"):
        rpq.canonicalize(rpq.Alt(()))
    with pytest.raises(TypeError):
        rpq.canonicalize("l0")
    assert rpq.canonicalize(rpq.Cat((rpq.Sym(3),))) == rpq.Sym(3)


def test_parse_truncated_input():
    with pytest.raises(ValueError, match="unexpected end"):
        rpq.parse("(l0 | l1")
    with pytest.raises(ValueError, match="bad character"):
        rpq.parse("l0 & l1")     # & is pattern syntax, not RPQ syntax


def test_approx_pattern_max_require_truncates_soundly():
    r = rpq.parse("l0 . l1 . l2 . l3")
    full, feas = rpq.approx_pattern(r, 6)
    trunc, feas2 = rpq.approx_pattern(r, 6, max_require=2)
    assert feas and feas2
    # dropping requirements only weakens the filter: anything the full
    # over-approximation accepts, the truncated one must accept too
    for bits in range(1 << 6):
        w = frozenset(i for i in range(6) if bits & (1 << i))
        if pat.evaluate(full, w):
            assert pat.evaluate(trunc, w)

"""Per-arch smoke tests (reduced configs) + family-specific invariants.

Every assigned architecture: one forward and one train step on CPU with
shape/NaN assertions; decode == full-forward equivalence; SSM formulation
cross-checks (chunked vs scan).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.data import DataConfig, batch_for_step

ARCHS = C.list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    media = (jax.random.normal(KEY, (b, cfg.n_media_tokens, cfg.d_model))
             if cfg.n_media_tokens else None)
    return toks, media


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = C.get(arch).reduced()
    params = init_params(cfg, KEY)
    toks, media = _batch(cfg)
    logits, aux, _ = forward(cfg, params, toks, media)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = C.get(arch).reduced()
    params = init_params(cfg, KEY)
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    toks, media = _batch(cfg)
    batch = {"tokens": toks}
    if media is not None:
        batch["media"] = media
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state["params"])[0]
    assert not bool(jnp.allclose(before, after))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = C.get(arch).reduced()
    params = init_params(cfg, KEY)
    b, s = 2, 16
    toks, media = _batch(cfg, b, s + 3)
    full, _, _ = forward(cfg, params, toks, media)
    last, cache = prefill(cfg, params, toks[:, :s], media, max_len=s + 3)
    errs = [float(jnp.abs(last - full[:, s - 1]).max())]
    for t in range(s, s + 3):
        lg, cache = decode_step(cfg, params, cache, toks[:, t])
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 5e-3, f"{arch}: decode drifts {max(errs)}"


def test_rwkv_chunked_equals_scan():
    cfg = C.get("rwkv6-3b").reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    a, _, _ = forward(cfg, params, toks, rwkv_chunked=False)
    b, _, _ = forward(cfg, params, toks, rwkv_chunked=True)
    assert float(jnp.abs(a - b).max()) < 1e-4


def test_mamba_chunk_invariance():
    import dataclasses
    cfg = C.get("zamba2-1.2b").reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    a, _, _ = forward(cfg, params, toks)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=8)
    b, _, _ = forward(cfg2, params, toks)
    assert float(jnp.abs(a - b).max()) < 1e-4


def test_gemma3_local_global_striping():
    from repro.models.transformer import layer_flags
    cfg = C.get("gemma3-27b")
    use_window, thetas = layer_flags(cfg)
    uw = np.asarray(use_window)
    # globals at layer idx % 6 == 5 -> 10 of 62; the rest local
    assert uw.sum() == 62 - 10
    assert not uw[5] and uw[0]          # every 6th layer is global
    th = np.asarray(thetas)
    assert th[5] == 1_000_000.0 and th[0] == 10_000.0


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor, the MoE drops tokens (and stays
    finite) — the large-scale configuration."""
    import dataclasses
    cfg = dataclasses.replace(C.get("dbrx-132b").reduced(),
                              capacity_factor=0.5)
    params = init_params(cfg, KEY)
    toks, _ = _batch(cfg)
    logits, aux, _ = forward(cfg, params, toks)
    assert bool(jnp.isfinite(logits).all())


def test_media_injection_changes_output():
    cfg = C.get("phi-3-vision-4.2b").reduced()
    params = init_params(cfg, KEY)
    toks, media = _batch(cfg)
    a, _, _ = forward(cfg, params, toks, media)
    b, _, _ = forward(cfg, params, toks, media * 2.0)
    assert float(jnp.abs(a - b).max()) > 0  # frontend stub is live


def test_param_count_tracks_config():
    for arch in ("phi3-mini-3.8b", "dbrx-132b", "deepseek-v2-236b",
                 "gemma3-27b"):
        cfg = C.get(arch)
        n = cfg.n_params()
        expect = float(arch.rsplit("-", 1)[-1].rstrip("b").replace("b", ""))
        expect = {"phi3-mini-3.8b": 3.8e9, "dbrx-132b": 132e9,
                  "deepseek-v2-236b": 236e9, "gemma3-27b": 27e9}[arch]
        assert 0.6 * expect < n < 1.45 * expect, (arch, n, expect)
        assert cfg.n_active_params() <= n

"""Deterministic I/O fault injection for the durability stack.

``repro.core.snapshot`` and ``repro.core.deltalog`` route every file
open and fsync through module-level ``_OPEN``/``_FSYNC`` seams; this
harness patches both modules at once and counts *mutating* I/O
operations (write / fsync / truncate) across them, firing one planned
fault on the Nth such call:

* ``kind="fail"`` — the Nth op raises ``OSError`` once; every later op
  succeeds.  Models a transient failure the bounded retry in
  ``QueryServer.submit_update`` should absorb.
* ``kind="kill"`` — the Nth write persists only a prefix of its buffer
  (``partial_frac``) and then *every* subsequent seamed op raises
  ``SimulatedCrash``.  Models the process dying mid-I/O: rollback paths
  cannot run against the dead "disk", so torn bytes stay on disk exactly
  as a real crash would leave them.  Recovery happens after the
  ``inject`` context exits, against the real filesystem.
* ``kind="corrupt"`` — the Nth *write* flips one byte of its buffer and
  then succeeds, silently.  Models bit rot that only checksums can
  catch.  (Only writes are counted for this kind; a corrupted fsync is
  not a thing.)
* ``kind="count"`` — no fault; ``plan.count`` after the run tells a
  sweep how many boundaries there are to kill at.

Usage::

    plan = FaultPlan(nth=3, kind="kill")
    with inject(plan):
        ...   # the 3rd mutating I/O call dies mid-write
    assert plan.fired
    server = QueryServer.recover(persist_dir)   # real I/O again

Only files opened *through the seams while the context is active* are
wrapped; handles opened before (or after) the context behave normally,
which is what lets a "healed" server resume appending to the same log
after a transient fault test.
"""
import contextlib
import os

from repro.core import deltalog, snapshot

_MODULES = (snapshot, deltalog)


class SimulatedCrash(OSError):
    """The injected crash point was reached; everything after it is the
    process being dead — no seamed I/O succeeds again until the
    ``inject`` context exits."""


class FaultPlan:
    KINDS = ("count", "fail", "kill", "corrupt")

    def __init__(self, nth: int = 0, kind: str = "count", *,
                 partial_frac: float = 0.5, flip_byte: int = 0xFF):
        if kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        self.nth = int(nth)
        self.kind = kind
        self.partial_frac = partial_frac
        self.flip_byte = flip_byte
        self.count = 0           # mutating ops seen so far
        self.fired = False       # the planned fault has triggered

    # -- internal hooks ------------------------------------------------
    @property
    def dead(self) -> bool:
        return self.kind == "kill" and self.fired

    def _tick(self, is_write: bool) -> bool:
        """Count one mutating op; True iff it is the one to fault."""
        if self.kind == "corrupt" and not is_write:
            return False
        self.count += 1
        if self.kind != "count" and self.nth and self.count == self.nth:
            self.fired = True
            return True
        return False


class _FaultyFile:
    """Proxy over a real file object applying ``FaultPlan`` to mutating
    calls (write/truncate; fsync is seamed separately).  Reads pass
    through — until the plan is dead, at which point *everything*
    raises."""

    def __init__(self, f, plan: FaultPlan):
        self._f = f
        self._plan = plan

    def _check_dead(self):
        if self._plan.dead:
            raise SimulatedCrash("simulated crash: disk is gone")

    def write(self, data):
        self._check_dead()
        if self._plan._tick(is_write=True):
            kind = self._plan.kind
            if kind == "fail":
                raise OSError("injected transient write failure")
            if kind == "kill":
                keep = int(len(data) * self._plan.partial_frac)
                self._f.write(data[:keep])
                self._f.flush()   # the torn prefix reaches the "disk"
                raise SimulatedCrash(
                    f"simulated crash mid-write ({keep}/{len(data)} "
                    "bytes persisted)")
            if kind == "corrupt":
                data = bytearray(data)
                pos = len(data) // 2
                data[pos] ^= self._plan.flip_byte or 0xFF
                return self._f.write(bytes(data))
        return self._f.write(data)

    def truncate(self, size=None):
        self._check_dead()
        if self._plan._tick(is_write=False):
            raise OSError("injected truncate failure")
        return self._f.truncate(size)

    def read(self, *a):
        self._check_dead()
        return self._f.read(*a)

    def flush(self):
        self._check_dead()
        return self._f.flush()

    def seek(self, *a):
        self._check_dead()
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def fileno(self):
        return self._f.fileno()

    def close(self):
        # closing a dead file is allowed (cleanup paths run in-process
        # even though the simulated machine is gone)
        return self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Patch the ``_OPEN``/``_FSYNC`` seams of every durability module
    to run ``plan``; restore the real I/O functions on exit."""
    saved = [(m, m._OPEN, m._FSYNC) for m in _MODULES]

    def faulty_open(path, mode="r", *args, **kwargs):
        if plan.dead:
            raise SimulatedCrash("simulated crash: disk is gone")
        return _FaultyFile(open(path, mode, *args, **kwargs), plan)

    def faulty_fsync(fd):
        if plan.dead:
            raise SimulatedCrash("simulated crash: disk is gone")
        if plan._tick(is_write=False):
            if plan.kind == "kill":
                raise SimulatedCrash("simulated crash at fsync")
            raise OSError("injected transient fsync failure")
        return os.fsync(fd)

    for m in _MODULES:
        m._OPEN, m._FSYNC = faulty_open, faulty_fsync
    try:
        yield plan
    finally:
        for m, o, s in saved:
            m._OPEN, m._FSYNC = o, s


def count_ops(fn) -> int:
    """Run ``fn`` under a fault-free counting plan; returns how many
    mutating I/O ops it performed — the sweep range for kill-at-every-
    boundary tests."""
    plan = FaultPlan(kind="count")
    with inject(plan):
        fn()
    return plan.count

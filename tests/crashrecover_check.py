"""Standalone kill-and-recover smoke (NOT collected by pytest directly —
``tests/test_recovery.py`` spawns it as a slow test, and the CI recovery
job runs it as its own leg).

A worker subprocess builds a TDR index, attaches persistence
(``QueryServer.persist_to``), and applies a *deterministic* stream of
logged updates, printing the LSN it acked after each one.  The parent
SIGKILLs it mid-stream — a real process death, no in-process cleanup of
any kind — then recovers from the persist directory and asserts:

* the recovered graph is exactly the deterministic graph after
  ``applied_lsn`` updates — the acked prefix (the kill may or may not
  have let one in-flight append land; both are valid prefixes);
* every index plane is bit-identical to a from-scratch layout-pinned
  ``build_index`` on that graph;
* PCR answers on the recovered index match the DFS oracle.

Run directly (both backends)::

    PYTHONPATH=src python tests/crashrecover_check.py
"""
import os
import signal
import subprocess
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

from repro.core import dfs_baseline, graph as G  # noqa: E402
from repro.core import pattern as pat, tdr_build, tdr_query  # noqa: E402
from repro.launch import serve  # noqa: E402

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)
N_V, N_L, N_STEPS = 24, 4, 40
KILL_AFTER_LSN = 3          # let a few updates ack before the SIGKILL

PLANES = ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in", "push",
          "pop", "g_count", "base_v", "base_l", "base_r", "r_vtx",
          "r_lab", "r_in", "d_vtx", "d_lab")


def make_plan(seed: int):
    """Deterministic update stream: ``(g0, [graph after step 1..N],
    [(add, rem), ...])`` — identical in parent and worker."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=seed)
    rng = np.random.default_rng(seed + 1)
    graphs, steps = [g], []
    for _ in range(N_STEPS):
        cur = graphs[-1]
        edges = list(zip(cur.src.tolist(), cur.indices.tolist(),
                         cur.labels.tolist()))
        add, rem = [], []
        for _ in range(int(rng.integers(1, 4))):
            kind = int(rng.integers(3))
            if kind <= 1 or not edges:
                u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
                if u != v:
                    add.append((u, v, int(rng.integers(N_L))))
            else:
                rem.append(edges[int(rng.integers(len(edges)))])
        steps.append((add, rem))
        graphs.append(cur.apply_updates(add, rem).graph)
    return graphs, steps


def worker(directory: str, seed: int, backend: str) -> None:
    graphs, steps = make_plan(seed)
    idx = tdr_build.build_index(graphs[0], CFG, backend=backend)
    srv = serve.QueryServer(idx, backend=backend, compact_every=3)
    srv.persist_to(directory)
    print("READY", flush=True)
    for add, rem in steps:
        srv.submit_update(add, rem)
        print(f"LSN {srv.stats.applied_lsn}", flush=True)
    print("DONE", flush=True)   # the parent should have killed us by now


def run_one(backend: str, workdir: str, seed: int) -> None:
    d = os.path.join(workdir, f"crash-{backend}")
    here = os.path.abspath(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(here)), "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, here, "--worker", d, str(seed), backend],
        env=env, stdout=subprocess.PIPE, text=True)
    killed = False
    deadline = time.monotonic() + 600
    for line in proc.stdout:
        line = line.strip()
        if line.startswith("LSN") and \
                int(line.split()[1]) >= KILL_AFTER_LSN:
            # SIGKILL: no atexit, no finally, no flush — the on-disk
            # state is whatever the fsyncs made durable
            proc.send_signal(signal.SIGKILL)
            killed = True
            break
        if line == "DONE" or time.monotonic() > deadline:
            break
    proc.wait(timeout=60)
    assert killed, f"worker finished before the kill ({backend})"

    graphs, _ = make_plan(seed)
    rec = serve.QueryServer.recover(d, backend=backend)
    try:
        k = rec.stats.applied_lsn
        assert k >= KILL_AFTER_LSN, f"lost acked updates: lsn={k}"
        ref_g = graphs[k]
        assert np.array_equal(rec.index.graph.indices, ref_g.indices)
        assert np.array_equal(rec.index.graph.labels, ref_g.labels)
        idx0 = tdr_build.build_index(graphs[0], CFG, backend=backend)
        ref = tdr_build.build_index(ref_g, CFG, layout=idx0.disc,
                                    backend=backend)
        for p in PLANES:
            x = np.asarray(getattr(rec.index, p))
            y = np.asarray(getattr(ref, p))
            assert np.array_equal(x, y), f"{backend}: plane {p} differs"
        rng = np.random.default_rng(seed + 2)
        qs = []
        for i in range(8):
            u, v = int(rng.integers(N_V)), int(rng.integers(N_V))
            labs = rng.choice(N_L, size=2, replace=False).tolist()
            qs.append((u, v, [pat.all_of(labs), pat.any_of(labs),
                              pat.none_of(labs)][i % 3]))
        got = tdr_query.answer_batch(rec.index, qs, backend=backend)
        want = [dfs_baseline.answer_pcr(ref_g, u, v, p) for u, v, p in qs]
        assert got.tolist() == want, f"{backend}: oracle mismatch"
    finally:
        rec.close_persistence()
    print(f"[crashrecover] {backend}: killed at lsn>={KILL_AFTER_LSN}, "
          f"recovered lsn={k}, planes + oracle OK")


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        worker(sys.argv[2], int(sys.argv[3]), sys.argv[4])
        return
    import tempfile
    backends = sys.argv[1:] or ["segment", "pallas"]
    with tempfile.TemporaryDirectory() as workdir:
        for backend in backends:
            run_one(backend, workdir, seed=12)
    print("crashrecover check OK")


if __name__ == "__main__":
    main()

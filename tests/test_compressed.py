"""Two-level compressed planes: codec round-trips on degenerate shapes,
patch-vs-fresh canonical equality, the block operand and its sparse
closure path, and the compressed cache carried across 100+ random
``update_index`` interleavings on both backends.

The contract under test is bit-identity everywhere: ``decompress`` must
reproduce the dense plane exactly, ``patch_rows``/``patch_blocks`` must
land in the same canonical form a fresh ``compress`` of the patched
dense plane would, the block-sparse closure must equal the dense
fixpoint word-for-word, and an index's cached compressed planes must
stay equal to fresh compressions of its dense planes after any update.
"""
import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # clean container: vendored fallback (see _minihyp.py)
    import _minihyp as hp
    st = hp.strategies

import jax.numpy as jnp

from repro.core import compressed as C, engine, graph as G, tdr_build
from repro.kernels import ops
from test_updates import N_L, N_V, _random_step

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)


def _mix_rows(rng, n, w, nbits, p_zero=0.3, p_one=0.3):
    """Random packed rows with a heavy mix of all-zero / all-one rows —
    the distribution the two-level layout is built for."""
    masks = C._valid_masks(w, nbits)
    rows = (rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
            & masks[None, :])
    u = rng.random(n)
    rows[u < p_zero] = 0
    rows[u > 1 - p_one] = masks[None, :]
    return rows


# ------------------------------------------------------- row-level codec
@pytest.mark.parametrize("shape,nbits", [
    ((0, 3), None),      # empty graph: a plane with zero rows
    ((1, 1), 1),         # V=1, a single valid bit
    ((5, 2), 37),        # valid bits not a multiple of the word size
    ((7, 2), 63),        # partial tail word
    ((4, 3, 2), 64),     # leading plane dims (V, g_max, W)
])
def test_roundtrip_degenerate_shapes(shape, nbits):
    rng = np.random.default_rng(sum(shape))
    w = shape[-1]
    n = int(np.prod(shape[:-1]))
    plane = _mix_rows(rng, n, w, nbits or w * 32).reshape(shape)
    c = C.compress(plane, nbits=nbits)
    np.testing.assert_array_equal(c.decompress(), plane)
    assert c.shape == shape


def test_roundtrip_uniform_planes():
    masks = C._valid_masks(2, 50)
    zeros = np.zeros((6, 2), np.uint32)
    ones = np.broadcast_to(masks, (6, 2)).copy()
    for plane, state in ((zeros, C.ALL_ZERO), (ones, C.ALL_ONE)):
        c = C.compress(plane, nbits=50)
        np.testing.assert_array_equal(c.decompress(), plane)
        assert (c.row_states == state).all()
        assert c.pool.size == 0          # uniform rows never hit the pool
        assert c.nbytes < c.dense_nbytes


@hp.given(seed=st.integers(0, 10_000))
@hp.settings(max_examples=25, deadline=None)
def test_patch_rows_matches_fresh_compress(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 40))
    w = int(rng.integers(1, 5))
    nbits = int(rng.integers(1, w * 32 + 1))
    rows = _mix_rows(rng, n, w, nbits)
    c = C.compress(rows, nbits=nbits)
    np.testing.assert_array_equal(c.decompress(), rows)

    sel = rng.choice(n, size=int(rng.integers(0, n + 1)), replace=False)
    new = _mix_rows(rng, sel.size, w, nbits)
    rows2 = rows.copy()
    rows2[sel] = new
    c2 = c.patch_rows(sel, new)
    np.testing.assert_array_equal(c2.decompress(), rows2)
    # canonical form, not just bit-identity: a patched layout must be
    # indistinguishable from a fresh compression (same_as compares the
    # state arrays and pool directly)
    assert c2.same_as(C.compress(rows2, nbits=nbits))


# ----------------------------------------------------- block-level codec
@pytest.mark.parametrize("m,kw,nbits,br,bw", [
    (1, 1, 1, 8, 1),     # single row, single valid bit
    (5, 2, 37, 8, 1),    # row tail: m not a multiple of br
    (16, 4, 128, 4, 2),  # multi-word blocks, exact grid
    (9, 3, 70, 8, 1),    # both tails partial
])
def test_blocks_roundtrip(m, kw, nbits, br, bw):
    rng = np.random.default_rng(m * 31 + kw)
    a = _mix_rows(rng, m, kw, nbits)
    c = C.compress_blocks(a, br=br, bw=bw, nbits=nbits)
    np.testing.assert_array_equal(C.decompress_blocks(c), a)
    zeros = np.zeros_like(a)
    cz = C.compress_blocks(zeros, br=br, bw=bw, nbits=nbits)
    np.testing.assert_array_equal(C.decompress_blocks(cz), zeros)
    assert cz.n_mixed == 0


@hp.given(seed=st.integers(0, 10_000))
@hp.settings(max_examples=20, deadline=None)
def test_patch_blocks_matches_fresh(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 30))
    kw = int(rng.integers(1, 4))
    nbits = int(rng.integers(1, kw * 32 + 1))
    a = _mix_rows(rng, m, kw, nbits)
    c = C.compress_blocks(a, nbits=nbits)
    sel = np.sort(rng.choice(m, size=int(rng.integers(1, m + 1)),
                             replace=False))
    new = _mix_rows(rng, sel.size, kw, nbits)
    a2 = a.copy()
    a2[sel] = new
    c2 = C.patch_blocks(c, sel, new)
    np.testing.assert_array_equal(C.decompress_blocks(c2), a2)
    fresh = C.compress_blocks(a2, nbits=nbits)
    assert int(c2.n_mixed) == int(fresh.n_mixed)
    np.testing.assert_array_equal(np.asarray(c2.states),
                                  np.asarray(fresh.states))


# --------------------------------------------------- sparse closure paths
def _closure_base(g, eng):
    _, _, disc = tdr_build.dfs_intervals(g)
    return eng.propagate(jnp.asarray(tdr_build._vertex_bit_words(CFG,
                                                                 disc)))


def test_blocksparse_closure_bit_identical_pallas():
    """Explicit sparse=True on the pallas backend runs the block-sparse
    kernel (the counter bumps at trace time, so it is asserted once over
    the session-unique shapes) and matches the dense fixpoint exactly;
    the default policy under interpret routes dense and leaves it cold."""
    n0 = ops.KERNEL_INVOCATIONS["block_sparse_matmul"]
    for kind in ("er", "pa"):
        g = G.random_graph(kind, 96, 3.0, 8, seed=3)
        eng = engine.make_engine(g, backend="pallas")
        base = _closure_base(g, eng)
        r_dense, _ = eng.closure(base, sparse=False)
        r_sparse, _ = eng.closure(base, sparse=True)
        np.testing.assert_array_equal(np.asarray(r_sparse),
                                      np.asarray(r_dense), err_msg=kind)
        n1 = ops.KERNEL_INVOCATIONS["block_sparse_matmul"]
        assert n1 > n0, "sparse closure never traced the sparse kernel"
        r_def, _ = eng.closure(base)
        np.testing.assert_array_equal(np.asarray(r_def),
                                      np.asarray(r_dense), err_msg=kind)
        if eng.interpret:
            # default policy routes interpret-mode closures dense: no
            # new sparse-kernel trace may appear
            assert ops.KERNEL_INVOCATIONS["block_sparse_matmul"] == n1


@pytest.mark.parametrize("kind", ["er", "pa"])
def test_segment_sparse_closure_bit_identical(kind):
    """The two-stage frontier-compacted segment closure (dense jitted
    rounds, then compacted sparse tail) == the plain dense fixpoint."""
    for seed in range(4):
        g = G.random_graph(kind, 120, 2.5, 6, seed=seed)
        eng = engine.make_engine(g, backend="segment")
        base = _closure_base(g, eng)
        r_dense, _ = eng.closure(base, sparse=False)
        r_sparse, _ = eng.closure(base, sparse=True)
        np.testing.assert_array_equal(
            np.asarray(r_sparse), np.asarray(r_dense),
            err_msg=f"{kind} seed={seed}")


def test_saturated_closure_rows_all_one():
    """With more vertices than Bloom bits, dense-graph closure rows
    saturate; the level-1 summary must flag exactly those rows."""
    g = G.random_graph("er", 80, 8.0, 4, seed=0)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    flags = idx.summary_flags()
    n_out = np.asarray(idx.n_out)
    masks = C._valid_masks(n_out.shape[-1], CFG.vtx_bits)
    want = (n_out == masks[None, :]).all(axis=1)
    np.testing.assert_array_equal(flags["sat_out"], want)
    assert want.any(), "no saturated row — graph too sparse for the test"


# ---------------------------------------- cache carry across update chains
N_TRIALS = {"segment": 70, "pallas": 40}


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_compressed_cache_tracks_update_interleavings(backend):
    """Seed the compressed-plane cache, then chain random update steps:
    after every ``update_index`` the carried cache must decompress
    bit-identically to — and be in the same canonical form as — a fresh
    compression of every dense plane."""
    for trial in range(N_TRIALS[backend]):
        rng = np.random.default_rng(7000 + trial)
        g = G.random_graph(["er", "pa"][trial % 2], N_V, 2.0, N_L,
                           seed=trial)
        cur = tdr_build.build_index(g, CFG, backend=backend)
        cur.compressed_planes()       # seed the cache so updates carry it
        curg = g
        for _ in range(int(rng.integers(1, 4))):
            add, rem = _random_step(rng, curg)
            delta = curg.apply_updates(add, rem)
            cur = tdr_build.update_index(cur, delta, backend=backend,
                                         rebuild_threshold=2.0)
            curg = delta.graph
            comp = cur.compressed_planes()
            for name, (arr, nbits) in cur.plane_specs().items():
                dense = np.asarray(arr)
                np.testing.assert_array_equal(
                    comp[name].decompress(), dense,
                    err_msg=f"{backend} trial={trial} plane={name}")
                assert comp[name].same_as(C.compress(dense, nbits=nbits)), \
                    f"{backend} trial={trial} plane={name}: non-canonical"

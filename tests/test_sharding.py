"""Sharding rules + small-mesh lowering (the 1-device analogue of the
512-device dry-run; the full meshes are exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.launch import sharding
from repro.models import init_params, pspec
from repro.models import model as model_lib
from repro.train import AdamWConfig, init_train_state, make_train_step


def tiny_mesh():
    return Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", C.list_archs())
def test_param_specs_cover_tree(arch):
    cfg = C.get(arch)
    mesh = tiny_mesh()
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = sharding.param_specs(cfg, shapes, mesh)
    flat_s, tdef_s = jax.tree.flatten(specs,
                                      is_leaf=lambda x: isinstance(x, P))
    flat_p, tdef_p = jax.tree.flatten(shapes)
    assert tdef_s == tdef_p
    for spec, leaf in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        # big matrices must actually be sharded somewhere
        if leaf.size > 4_000_000:
            assert any(a is not None for a in spec), (arch, leaf.shape)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "dbrx-132b",
                                  "zamba2-1.2b", "rwkv6-3b"])
def test_train_step_lowers_on_mesh(arch):
    """Reduced config, 1x1 mesh: same code path as the 512-chip dry-run."""
    cfg = C.get(arch).reduced()
    mesh = tiny_mesh()
    params_shape = jax.eval_shape(lambda k: init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    state_shape = jax.eval_shape(
        lambda k: init_train_state(cfg, init_params(cfg, k)),
        jax.random.PRNGKey(0))
    specs = sharding.state_specs(cfg, state_shape, mesh)
    sds = sharding.sds_with_sharding(state_shape,
                                     sharding.to_named(specs, mesh))
    toks = jax.ShapeDtypeStruct(
        (4, 32), jnp.int32,
        sharding=NamedSharding(mesh, P(("data",), None)))
    batch = {"tokens": toks}
    if cfg.n_media_tokens:
        batch["media"] = jax.ShapeDtypeStruct(
            (4, cfg.n_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(("data",), None, None)))
    step = make_train_step(cfg, AdamWConfig(), n_microbatches=2)
    with pspec.use_mesh(mesh, pspec.default_mapping(False)), mesh:
        lowered = jax.jit(step, donate_argnums=0).lower(sds, batch)
        compiled = lowered.compile()
    assert compiled.memory_analysis() is not None


@pytest.mark.parametrize("arch", ["gemma3-27b", "deepseek-v2-236b",
                                  "rwkv6-3b"])
def test_decode_lowers_on_mesh(arch):
    cfg = C.get(arch).reduced()
    mesh = tiny_mesh()
    params_shape = jax.eval_shape(lambda k: init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    p_specs = sharding.param_specs(cfg, params_shape, mesh)
    p_sds = sharding.sds_with_sharding(params_shape,
                                       sharding.to_named(p_specs, mesh))
    cache_shape = jax.eval_shape(lambda: model_lib.init_cache(cfg, 4, 64))
    c_specs = sharding.cache_specs(cfg, cache_shape, mesh, 4)
    c_sds = sharding.sds_with_sharding(cache_shape,
                                       sharding.to_named(c_specs, mesh))
    toks = jax.ShapeDtypeStruct((4,), jnp.int32,
                                sharding=NamedSharding(mesh, P(("data",))))

    def fn(params, cache, tokens):
        return model_lib.decode_step(cfg, params, cache, tokens)

    with pspec.use_mesh(mesh, pspec.default_mapping(False)), mesh:
        compiled = jax.jit(fn, donate_argnums=1).lower(
            p_sds, c_sds, toks).compile()
    assert compiled is not None


def test_pspec_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert pspec.constrain(x, "batch", None) is x


def test_pspec_divisibility_guard():
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    with pspec.use_mesh(mesh, {"heads": "model"}):
        x = jnp.ones((3, 5))
        y = pspec.constrain(x, "heads", None)   # 3 % 1 == 0 -> fine
        assert y.shape == x.shape


def test_mesh_factory_requires_devices():
    from repro.launch import mesh as mesh_lib
    with pytest.raises(RuntimeError):
        mesh_lib.make_production_mesh()   # 1 CPU device < 256

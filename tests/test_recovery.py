"""Durability: snapshot/restore, write-ahead log, crash recovery.

The contract under test, end to end: for **any** interleaving of
build / update / crash — the crash injected at any mutating I/O
boundary via ``tests/faultinject.py`` — recovery (newest valid snapshot
+ delta-log replay through ``update_index``) yields an index
bit-identical to a from-scratch ``build_index`` on the graph as of the
last acked update, or of the one in-flight update the crash interrupted
(an fsync'd-but-unacked append may legitimately survive).  Nothing else
is acceptable: a corrupted snapshot or log must raise its typed error
(``SnapshotCorrupt``/``SnapshotVersionMismatch``/``LogCorrupt``) —
never load garbage — and a faulted update must leave the server
answering reads in degraded mode on the last-good index.
"""
import os
import threading
import time

import numpy as np
import pytest

import faultinject
from repro.core import deltalog, dfs_baseline, graph as G
from repro.core import pattern as pat, snapshot, tdr_build, tdr_query
from repro.launch import serve

CFG = tdr_build.TDRConfig(vtx_bits=64, g_max=4, k=3)

PLANES = ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in", "push",
          "pop", "g_count", "base_v", "base_l", "base_r", "r_vtx",
          "r_lab", "r_in", "d_vtx", "d_lab")

# randomized build/update/crash interleavings per backend (105 total —
# the acceptance floor is 100 across both)
N_CRASH_TRIALS = {"segment": 70, "pallas": 35}
N_V, N_L = 24, 4


def assert_planes_equal(a, b, ctx=""):
    for p in PLANES:
        x, y = np.asarray(getattr(a, p)), np.asarray(getattr(b, p))
        assert np.array_equal(x, y), \
            f"{ctx}: plane {p} differs ({int((x != y).sum())} cells)"
    assert np.array_equal(a.vtx_words, b.vtx_words), ctx
    assert np.array_equal(np.asarray(a.disc), np.asarray(b.disc)), ctx


def _random_step(rng, g):
    """One random update batch: inserts, deletes, label changes."""
    add, rem = [], []
    edges = list(zip(g.src.tolist(), g.indices.tolist(),
                     g.labels.tolist()))
    for _ in range(int(rng.integers(1, 4))):
        kind = int(rng.integers(4))
        if kind <= 1 or not edges:
            u, v = int(rng.integers(g.n_vertices)), \
                int(rng.integers(g.n_vertices))
            if u != v:
                add.append((u, v, int(rng.integers(g.n_labels))))
        elif kind == 2:
            rem.append(edges[int(rng.integers(len(edges)))])
        else:
            u, v, l = edges[int(rng.integers(len(edges)))]
            rem.append((u, v, l))
            add.append((u, v, int((l + 1) % g.n_labels)))
    return add, rem


def _oracle_queries(rng, g, n=6):
    qs = []
    for i in range(n):
        u, v = int(rng.integers(g.n_vertices)), \
            int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=2, replace=False).tolist()
        p = [pat.all_of(labs), pat.any_of(labs), pat.none_of(labs),
             pat.parse(f"l{labs[0]} & !l{labs[1]}")][i % 4]
        qs.append((u, v, p))
    return qs


def _check_oracle(idx, g, rng, backend):
    qs = _oracle_queries(rng, g)
    got = tdr_query.answer_batch(idx, qs, backend=backend)
    want = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in qs]
    assert got.tolist() == want


# ------------------------------------------------------------ snapshot
@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_snapshot_roundtrip_bit_identical(backend, tmp_path):
    """save → load restores every plane, the frozen layout, and the
    maintenance state: the restored index answers like the original and
    chains ``update_index`` bit-identically to a layout-pinned rebuild."""
    rng = np.random.default_rng(0)
    g = G.random_graph("er", N_V, 2.0, N_L, seed=0)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    path = str(tmp_path / "snap.tdr")
    n_bytes = snapshot.save_index(idx, path, lsn=17)
    assert n_bytes == os.path.getsize(path)
    assert snapshot.peek_lsn(path) == 17
    idx2, lsn = snapshot.load_index(path)
    assert lsn == 17
    assert_planes_equal(idx, idx2, "roundtrip")
    assert np.array_equal(idx.lab_slot, idx2.lab_slot)
    # the compressed-plane cache is seeded from the validated sections
    c1, c2 = idx.compressed_planes(), idx2.compressed_planes()
    assert all(c1[k].same_as(c2[k]) for k in c1)
    _check_oracle(idx2, g, rng, backend)
    # restored index updates exactly like the one that was saved
    add, rem = _random_step(rng, g)
    delta = idx2.graph.apply_updates(add, rem)
    upd = tdr_build.update_index(idx2, delta, backend=backend)
    ref = tdr_build.build_index(delta.graph, CFG, layout=idx.disc,
                                backend=backend)
    assert_planes_equal(upd, ref, "update-after-restore")


def test_snapshot_corruption_always_typed(tmp_path):
    """Random byte flips and truncations anywhere in a snapshot raise a
    typed ``SnapshotError`` — a damaged file is never loaded."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=1)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    path = str(tmp_path / "snap.tdr")
    snapshot.save_index(idx, path, lsn=1)
    orig = open(path, "rb").read()
    rng = np.random.default_rng(2)
    bad = str(tmp_path / "bad.tdr")
    for trial in range(60):
        data = bytearray(orig)
        pos = int(rng.integers(len(data)))
        data[pos] ^= int(rng.integers(1, 256))
        with open(bad, "wb") as f:
            f.write(bytes(data))
        with pytest.raises(snapshot.SnapshotError):
            snapshot.load_index(bad)
    for trial in range(20):
        cut = int(rng.integers(0, len(orig)))
        with open(bad, "wb") as f:
            f.write(orig[:cut])
        with pytest.raises(snapshot.SnapshotError):
            snapshot.load_index(bad)


def test_snapshot_version_gate(tmp_path):
    g = G.fig2_example()
    idx = tdr_build.build_index(g, CFG)
    path = str(tmp_path / "snap.tdr")
    snapshot.save_index(idx, path)
    data = bytearray(open(path, "rb").read())
    # bump the container version word (little-endian u32 after magic)
    data[len(snapshot.MAGIC)] = snapshot.VERSION + 1
    with open(path, "wb") as f:
        f.write(bytes(data))
    with pytest.raises(snapshot.SnapshotVersionMismatch):
        snapshot.load_index(path)


# ----------------------------------------------------------- delta log
def _three_record_log(path):
    rng = np.random.default_rng(3)
    log = deltalog.DeltaLog(path)
    recs = []
    for _ in range(3):
        a = rng.integers(0, 20, size=(int(rng.integers(1, 4)), 3)
                         ).astype(np.int64)
        r = rng.integers(0, 20, size=(int(rng.integers(0, 2)), 3)
                         ).astype(np.int64)
        log.append(a, r)
        recs.append((a, r))
    log.close()
    return recs


def test_log_corruption_always_typed(tmp_path):
    """Any byte flip in a complete log file raises ``LogCorrupt`` on
    open; a truncation yields exactly the longest valid record prefix."""
    path = str(tmp_path / "wal")
    recs = _three_record_log(path)
    orig = open(path, "rb").read()
    rng = np.random.default_rng(4)
    bad = str(tmp_path / "bad.wal")
    for trial in range(60):
        data = bytearray(orig)
        pos = int(rng.integers(len(data)))
        data[pos] ^= int(rng.integers(1, 256))
        with open(bad, "wb") as f:
            f.write(bytes(data))
        with pytest.raises(deltalog.LogCorrupt):
            deltalog.DeltaLog(bad)

    hdr_len = len(deltalog.FILE_MAGIC) + deltalog._FHEAD.size
    # record boundaries in the pristine file
    probe = deltalog.DeltaLog(path)
    bounds = [r.offset for r in probe.records] + [len(orig)]
    probe.close()
    for trial in range(20):
        cut = int(rng.integers(0, len(orig)))
        with open(bad, "wb") as f:
            f.write(orig[:cut])
        if cut < hdr_len:
            with pytest.raises(deltalog.LogCorrupt):
                deltalog.DeltaLog(bad)
            continue
        log = deltalog.DeltaLog(bad)
        survive = sum(1 for b in bounds[1:] if b <= cut)
        got = list(log.replay(0))
        assert len(got) == survive
        for (lsn, a, r), (ea, er) in zip(got, recs):
            assert np.array_equal(a, ea) and np.array_equal(r, er)
        log.close()


def test_log_torn_tail_truncated_prior_replay(tmp_path):
    """A torn final record (crash mid-append) is cut on open; every
    prior record replays; appends resume at the right LSN."""
    path = str(tmp_path / "wal")
    recs = _three_record_log(path)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 5)   # tear into record 3
    log = deltalog.DeltaLog(path)
    assert log.truncated_bytes > 0
    assert [lsn for lsn, _, _ in log.replay(0)] == [1, 2]
    assert log.last_lsn == 2
    assert log.append(recs[2][0], recs[2][1]) == 3
    log.close()
    # the re-appended log is fully valid again
    log = deltalog.DeltaLog(path)
    assert log.truncated_bytes == 0 and log.last_lsn == 3
    log.close()


def test_log_compaction_preserves_position(tmp_path):
    """truncate_upto drops folded records but the base LSN survives a
    reopen — a fully compacted log still knows where the sequence is."""
    path = str(tmp_path / "wal")
    _three_record_log(path)
    log = deltalog.DeltaLog(path)
    assert log.truncate_upto(3) == 3
    assert log.base_lsn == 3 and len(log) == 0
    log.close()
    log = deltalog.DeltaLog(path)
    assert log.base_lsn == 3 and log.last_lsn == 3
    assert log.append(np.zeros((1, 3), np.int64),
                      np.zeros((0, 3), np.int64)) == 4
    log.close()


# ------------------------------------------------- crash interleavings
def _run_crash_trial(backend, trial, workdir):
    """One randomized build/update/crash interleaving; returns True if
    the injected fault actually fired."""
    rng = np.random.default_rng(7000 + trial)
    g = G.random_graph(["er", "pa"][trial % 2], N_V, 2.0, N_L, seed=trial)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    d = os.path.join(workdir, f"t{trial}")
    srv = serve.QueryServer(
        idx, backend=backend, update_retries=0,
        compact_every=int(rng.integers(0, 3)))

    graphs = [g]                 # graph after each *attempted* update
    acked = 0
    persist_ok = False
    plan = faultinject.FaultPlan(nth=int(rng.integers(1, 15)),
                                 kind="kill")
    with faultinject.inject(plan):
        try:
            srv.persist_to(d)
            persist_ok = True
            for step in range(int(rng.integers(1, 5))):
                add, rem = _random_step(rng, graphs[-1])
                cand = graphs[-1].apply_updates(add, rem).graph
                graphs.append(cand)
                srv.submit_update(add, rem)
                acked += 1
        except (serve.UpdateFailed, OSError):
            pass
    srv.close_persistence()

    if not persist_ok:
        # crash during the initial checkpoint: either nothing durable
        # exists yet (typed refusal) or the snapshot landed just before
        # the crash (e.g. at the directory fsync) and recovery yields
        # exactly the initial graph — never anything in between
        try:
            rec = serve.QueryServer.recover(d, backend=backend)
        except (serve.RecoveryError, deltalog.LogCorrupt):
            return plan.fired
        try:
            ref = tdr_build.build_index(g, CFG, layout=idx.disc,
                                        backend=backend)
            assert_planes_equal(rec.index, ref,
                                f"trial {trial} (persist crash)")
        finally:
            rec.close_persistence()
        return plan.fired

    rec = serve.QueryServer.recover(d, backend=backend)
    try:
        # acked state always survives; the one in-flight update may too
        # (its append can be durable before the ack) — nothing else
        allowed = {acked}
        if plan.fired and len(graphs) > acked + 1:
            allowed.add(acked + 1)
        match = None
        for k in sorted(allowed):
            if rec.index.graph.n_edges == graphs[k].n_edges and \
                    np.array_equal(rec.index.graph.indices,
                                   graphs[k].indices) and \
                    np.array_equal(rec.index.graph.labels,
                                   graphs[k].labels):
                match = k
                break
        assert match is not None, \
            f"trial {trial}: recovered graph is none of {sorted(allowed)}"
        ref = tdr_build.build_index(graphs[match], CFG, layout=idx.disc,
                                    backend=backend)
        assert_planes_equal(rec.index, ref,
                            f"trial {trial} (k={match}, acked={acked})")
        assert rec.stats.applied_lsn == match
        if trial % 10 == 0:
            _check_oracle(rec.index, graphs[match], rng, backend)
    finally:
        rec.close_persistence()
    return plan.fired


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_crash_interleavings_recover_bit_identical(backend, tmp_path):
    fired = 0
    n = N_CRASH_TRIALS[backend]
    for trial in range(n):
        fired += bool(_run_crash_trial(backend, trial, str(tmp_path)))
    # the nth-op draw must actually be exercising crashes, not just
    # running the clean path n times
    assert fired > n // 3, f"only {fired}/{n} trials crashed"


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_kill_at_every_io_boundary(backend, tmp_path):
    """Deterministic sweep: the same persist + update + checkpoint
    pipeline is killed at every mutating I/O call it makes; every
    recovery lands on an acked (or acked+1) prefix, bit-identically."""
    g = G.random_graph("er", 20, 2.0, N_L, seed=42)
    idx = tdr_build.build_index(g, CFG, backend=backend)
    rng = np.random.default_rng(43)
    steps = [_random_step(rng, g) for _ in range(2)]

    def scenario(d, plan):
        srv = serve.QueryServer(idx, backend=backend, update_retries=0)
        graphs, acked, persist_ok = [g], 0, False
        with faultinject.inject(plan):
            try:
                srv.persist_to(d)
                persist_ok = True
                for add, rem in steps:
                    graphs.append(
                        graphs[-1].apply_updates(add, rem).graph)
                    srv.submit_update(add, rem)
                    acked += 1
                srv.checkpoint()
            except (serve.UpdateFailed, OSError):
                pass
        srv.close_persistence()
        return graphs, acked, persist_ok

    probe = faultinject.FaultPlan(kind="count")
    scenario(str(tmp_path / "probe"), probe)
    total = probe.count
    assert total >= 6, f"scenario only made {total} I/O calls"

    for nth in range(1, total + 1):
        d = str(tmp_path / f"n{nth}")
        plan = faultinject.FaultPlan(nth=nth, kind="kill")
        graphs, acked, persist_ok = scenario(d, plan)
        assert plan.fired, f"nth={nth} never fired (total={total})"
        if not persist_ok:
            try:
                rec = serve.QueryServer.recover(d, backend=backend)
            except (serve.RecoveryError, deltalog.LogCorrupt):
                continue
            try:
                ref = tdr_build.build_index(g, CFG, layout=idx.disc,
                                            backend=backend)
                assert_planes_equal(rec.index, ref,
                                    f"nth={nth} (persist crash)")
            finally:
                rec.close_persistence()
            continue
        rec = serve.QueryServer.recover(d, backend=backend)
        try:
            allowed = [acked] + \
                ([acked + 1] if len(graphs) > acked + 1 else [])
            match = next(
                (k for k in allowed
                 if np.array_equal(rec.index.graph.indices,
                                   graphs[k].indices)
                 and np.array_equal(rec.index.graph.labels,
                                    graphs[k].labels)), None)
            assert match is not None, f"nth={nth}: not a valid prefix"
            ref = tdr_build.build_index(graphs[match], CFG,
                                        layout=idx.disc, backend=backend)
            assert_planes_equal(rec.index, ref, f"nth={nth}")
        finally:
            rec.close_persistence()


# -------------------------------------------------- serving integration
def test_transient_fault_absorbed_by_retry(tmp_path):
    """A single transient I/O failure is retried away: the update acks,
    nothing degrades, and the log position is exactly one ahead."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=5)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    srv = serve.QueryServer(idx, backend="segment", update_retries=2,
                            retry_backoff_s=0.001)
    srv.persist_to(str(tmp_path / "p"))
    plan = faultinject.FaultPlan(nth=1, kind="fail")
    with faultinject.inject(plan):
        srv.submit_update([(0, 1, 0)], [])
    assert plan.fired
    assert srv.stats.update_retries >= 1
    assert not srv.stats.degraded and srv.stats.update_failures == 0
    assert srv.stats.applied_lsn == 1 and srv._log.last_lsn == 1
    srv.close_persistence()


def test_degraded_mode_keeps_serving_last_good(tmp_path):
    """An update that exhausts its retries raises ``UpdateFailed`` and
    flips degraded; reads keep answering correctly on the last-good
    index; the next successful update clears degraded and recovery
    agrees with the live server."""
    rng = np.random.default_rng(6)
    g = G.random_graph("er", N_V, 2.0, N_L, seed=6)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    srv = serve.QueryServer(idx, backend="segment", update_retries=1,
                            retry_backoff_s=0.001)
    d = str(tmp_path / "p")
    srv.persist_to(d)
    with srv:
        plan = faultinject.FaultPlan(nth=1, kind="kill")
        with faultinject.inject(plan):
            with pytest.raises(serve.UpdateFailed):
                srv.submit_update([(0, 1, 0)], [])
        assert srv.stats.degraded and srv.stats.update_failures == 1
        # reads still served, and against the *pre-fault* graph
        qs = _oracle_queries(rng, g)
        got = [srv.submit(u, v, p).result(timeout=30) for u, v, p in qs]
        assert got == [dfs_baseline.answer_pcr(g, u, v, p)
                       for u, v, p in qs]
        assert srv.stats.applied_lsn == 0
        # healed: the next update applies and clears degraded
        srv.submit_update([(0, 1, 0)], [])
        assert not srv.stats.degraded
        assert srv.stats.applied_lsn == 1
        live = srv.index
    srv.close_persistence()
    rec = serve.QueryServer.recover(d, backend="segment")
    assert_planes_equal(rec.index, live, "recover-after-degraded")
    assert rec.stats.applied_lsn == 1
    rec.close_persistence()


def test_barrier_withdrawal_no_deadlock_no_reorder(tmp_path):
    """Satellite regression: a timed-out (withdrawn) update barrier
    must free its queue slot (unblocking backpressured submits), pop
    its write-ahead record, and leave LSN order intact for the next
    update.  A stale-LSN barrier smuggled into the queue is refused."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=8)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    srv = serve.QueryServer(idx, backend="segment", max_queue=4,
                            max_wait_ms=0.5)
    d = str(tmp_path / "p")
    srv.persist_to(d)
    gate = threading.Event()
    orig_serve = srv._serve_batch

    def gated(batch):
        gate.wait(30)
        return orig_serve(batch)

    srv._serve_batch = gated
    p0 = pat.any_of([0, 1])
    with srv:
        first = srv.submit(0, 1, p0)        # scheduler blocks in gated
        for _ in range(100):
            with srv._lock:
                if not srv._queue:
                    break
            time.sleep(0.01)
        upd_err: list = []

        def slow_update():
            try:
                srv.submit_update([(2, 3, 1)], [], timeout=0.3)
            except BaseException as e:   # noqa: BLE001
                upd_err.append(e)

        t_upd = threading.Thread(target=slow_update)
        t_upd.start()
        # wait for the barrier to occupy its queue slot
        for _ in range(100):
            with srv._lock:
                if srv._queue:
                    break
            time.sleep(0.01)
        # fill the queue to max_queue on top of the barrier
        filled = 0
        while True:
            try:
                srv.submit(filled % N_V, (filled + 1) % N_V, p0,
                           block=False)
                filled += 1
            except serve.QueueFull:
                break
        assert filled == srv.config.max_queue - 1
        # this submit must unblock when the barrier is withdrawn — the
        # regression: a withdrawn barrier that never notified _not_full
        # left it waiting for an unrelated dequeue
        blocked_done = threading.Event()

        def blocked_submit():
            srv.submit(1, 2, p0, block=True, timeout=30)
            blocked_done.set()

        t_blk = threading.Thread(target=blocked_submit)
        t_blk.start()
        t_upd.join(timeout=30)
        assert not t_upd.is_alive(), "submit_update deadlocked"
        assert upd_err and isinstance(upd_err[0], TimeoutError)
        # the write-ahead record of the withdrawn update was popped
        assert srv._log.last_lsn == 0
        blocked_done.wait(30)
        assert blocked_done.is_set(), \
            "backpressured submit deadlocked after withdrawal"
        gate.set()
        first.result(timeout=30)
        # the next update reuses the freed LSN and applies in order
        srv.submit_update([(2, 3, 1)], [], timeout=30)
        assert srv.stats.applied_lsn == 1
        # defense in depth: a stale-LSN barrier is refused, not swapped
        stale = serve._UpdateBarrier(srv.index, lsn=srv.stats.applied_lsn)
        with srv._lock:
            srv._queue.append(stale)
            srv._not_empty.notify()
        assert stale.event.wait(30)
        assert stale.exc is not None
        live = srv.index
    srv.close_persistence()
    rec = serve.QueryServer.recover(d, backend="segment")
    assert_planes_equal(rec.index, live, "recover-after-withdrawal")
    rec.close_persistence()


# ------------------------------------------------------------- recover
def test_recover_falls_back_to_older_snapshot(tmp_path):
    """Corrupting the newest snapshot falls recovery back to the
    retained previous one + a longer replay; corrupting both refuses
    with ``RecoveryError``."""
    g = G.random_graph("er", N_V, 2.0, N_L, seed=10)
    idx = tdr_build.build_index(g, CFG, backend="segment")
    srv = serve.QueryServer(idx, backend="segment")
    d = str(tmp_path / "p")
    srv.persist_to(d)
    rng = np.random.default_rng(11)
    for _ in range(3):
        add, rem = _random_step(rng, srv.index.graph)
        srv.submit_update(add, rem)
    srv.checkpoint()   # retains snapshot lsn=0 and snapshot lsn=3
    live = srv.index
    srv.close_persistence()
    snaps = serve._snapshot_files(d)
    assert len(snaps) == 2
    newest = snaps[-1][1]
    data = bytearray(open(newest, "rb").read())
    data[len(data) // 2] ^= 0x5A
    with open(newest, "wb") as f:
        f.write(bytes(data))
    rec = serve.QueryServer.recover(d, backend="segment")
    assert_planes_equal(rec.index, live, "fallback-snapshot")
    assert rec.stats.applied_lsn == 3
    rec.close_persistence()
    oldest = snaps[0][1]
    data = bytearray(open(oldest, "rb").read())
    data[len(data) // 2] ^= 0x5A
    with open(oldest, "wb") as f:
        f.write(bytes(data))
    with pytest.raises(serve.RecoveryError):
        serve.QueryServer.recover(d, backend="segment")


def test_recover_refuses_compaction_gap(tmp_path):
    """A snapshot older than the log's compacted base cannot seed a
    replay — typed refusal, not a silently wrong index."""
    g = G.fig2_example()
    idx = tdr_build.build_index(g, CFG)
    d = tmp_path / "p"
    d.mkdir()
    snapshot.save_index(idx, str(d / "snapshot-0000000000000000.tdr"),
                        lsn=0)
    log = deltalog.DeltaLog(str(d / serve.LOG_NAME))
    for _ in range(3):
        log.append(np.array([[0, 1, 0]], np.int64),
                   np.zeros((0, 3), np.int64))
    log.truncate_upto(2)       # base_lsn=2 > snapshot lsn=0: gap
    log.close()
    with pytest.raises(serve.RecoveryError):
        serve.QueryServer.recover(str(d))


def test_recover_empty_dir(tmp_path):
    with pytest.raises(serve.RecoveryError):
        serve.QueryServer.recover(str(tmp_path / "nowhere"))


@pytest.mark.slow
def test_sigkill_subprocess_recovers():
    """Real process death: ``tests/crashrecover_check.py`` SIGKILLs a
    persisting worker mid-update-stream and recovers from whatever the
    fsyncs made durable (also the CI recovery job's standalone leg)."""
    import subprocess
    import sys
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, os.path.join(here, "crashrecover_check.py"),
         "segment"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "crashrecover check OK" in r.stdout

"""Serve step: batched single-token decode (greedy or temperature sampling).

``make_serve_step(cfg)`` returns ``(params, cache, tokens, key) ->
(next_tokens, logits, cache)`` — the exact computation the ``decode_32k`` /
``long_500k`` dry-run cells lower.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step


def make_serve_step(cfg: ModelConfig, *, temperature: float = 0.0):
    def serve_step(params: dict, cache: dict, tokens: jax.Array,
                   key: Optional[jax.Array] = None):
        logits, cache = decode_step(cfg, params, cache, tokens)
        if temperature <= 0.0 or key is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        return nxt, logits, cache

    return serve_step

"""The paper's own engine config: distributed TDR index build dry-run.

Production sizing: a twitter-scale digraph (|V|=42M, |E|=632M) with 256-bit
Bloom ways, vertex-partitioned over the full mesh.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class TDRGraphConfig:
    name: str = "tdr-graph"
    n_vertices: int = 41_652_231         # twitter (paper Table II)
    n_edges: int = 632_007_285
    vtx_bits: int = 256
    rounds: int = 16                     # fixpoint rounds lowered


CONFIG = TDRGraphConfig()

"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    n_experts=160, experts_per_token=6, n_shared_experts=2,
    d_ff_expert=1536,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)

"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    block_type="mamba2", ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6,
)

"""Model/config schema for the assigned architecture pool.

One frozen dataclass covers every family (dense / moe / hybrid / ssm / vlm /
audio); family-specific fields are zero/None when unused.  Each
``configs/<arch>.py`` exports ``CONFIG`` (the exact published shape) and the
registry in ``configs/__init__.py`` resolves ``--arch`` ids.  ``reduced()``
yields the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # --- attention pattern (gemma3 local:global striping) ---
    sliding_window: int = 0          # 0 = full attention
    local_per_global: int = 0        # e.g. 5 -> L,L,L,L,L,G repeating
    rope_theta_global: float = 0.0   # gemma3 uses 1M for global layers

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (deepseek-v2) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid ---
    block_type: str = "attn"         # attn | mamba2 | rwkv6
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0       # zamba2: shared attn block every N layers

    # --- modality frontend stubs (vlm/audio) ---
    frontend: Optional[str] = None   # 'vision' | 'audio'
    n_media_tokens: int = 0

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.block_type in ("mamba2", "rwkv6") and \
            self.hybrid_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Archs eligible for the long_500k shape (ssm / hybrid)."""
        return self.block_type in ("mamba2", "rwkv6")

    def n_params(self) -> int:
        """Parameter count (used for MODEL_FLOPS = 6·N·D roofline term)."""
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.block_type == "attn" or self.hybrid_attn_every:
            if self.mla:
                q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads \
                    * (self.qk_nope_dim + self.qk_rope_dim)
                kv = d * (self.kv_lora_rank + self.qk_rope_dim) \
                    + self.kv_lora_rank * self.n_heads \
                    * (self.qk_nope_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                attn = q + kv + o
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
        else:
            attn = 0
        if self.block_type == "mamba2":
            d_in = self.ssm_expand * d
            # in_proj [d, 2*d_in + 2n + P] + out_proj [d_in, d] + conv
            p_heads = d_in // self.ssm_head_dim
            ssm = d * (2 * d_in + 2 * self.ssm_state + p_heads) \
                + d_in * d \
                + self.conv_kernel * (d_in + 2 * self.ssm_state)
        elif self.block_type == "rwkv6":
            lora = max(32, d // 16)
            # time-mix: 5 d·d (r,k,v,g,o) + decay lora; channel-mix:
            # w_r d·d + w_k d·F + w_v F·d
            ssm = 5 * d * d + 2 * d * lora + d * d + 2 * d * self.d_ff
        else:
            ssm = 0
        if self.is_moe:
            ff = self.n_experts * 3 * d * self.d_ff_expert \
                + self.n_shared_experts * 3 * d * self.d_ff_expert \
                + d * self.n_experts
        else:
            ff = 3 * d * self.d_ff
        if self.block_type == "attn":
            per_layer = attn + ff
        else:
            # ssm / rwkv blocks carry no separate SwiGLU (rwkv's
            # channel-mix is inside `ssm`; zamba2's MLP lives in the
            # shared attention block)
            per_layer = ssm
        total = emb + l * per_layer
        if self.hybrid_attn_every:
            # one shared attention block (+ its mlp), reused
            shared_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d + 3 * d * self.d_ff
            total += shared_attn
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k only)."""
        if not self.is_moe:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        routed_all = self.n_experts * 3 * d * self.d_ff_expert
        routed_act = self.experts_per_token * 3 * d * self.d_ff_expert
        return int(self.n_params() - l * (routed_all - routed_act))

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 + (2 if self.hybrid_attn_every
                                             else 0)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # no token dropping at smoke scale, so decode == forward exactly
            capacity_factor=float(min(self.n_experts, 4))
            / max(1, min(self.experts_per_token, 2)),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=64 if self.d_ff_expert else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 32),
            hybrid_attn_every=min(self.hybrid_attn_every, 2)
            if self.hybrid_attn_every else 0,
            n_media_tokens=min(self.n_media_tokens, 8),
            dtype="float32",
        )


# ----------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

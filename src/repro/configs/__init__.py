"""Config registry: ``get(arch_id)`` resolves ``--arch`` names."""
from . import base
from .base import ModelConfig, InputShape, SHAPES

from . import (dbrx_132b, deepseek_v2_236b, gemma3_27b, musicgen_large,
               phi3_mini_3p8b, phi3_vision_4p2b, rwkv6_3b,
               zamba2_1p2b, tdr_graph)

REGISTRY = {
    "phi-3-vision-4.2b": phi3_vision_4p2b.CONFIG,
    "gemma3-27b": gemma3_27b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3p8b.CONFIG,
    "zamba2-1.2b": zamba2_1p2b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
}

TDR_GRAPH = tdr_graph.CONFIG


def get(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs():
    return sorted(REGISTRY)

"""Query placement for the replicated serving fleet.

``FleetRouter`` sits between clients and a ``launch.fleet.Fleet``:
``submit`` returns a ``Future`` resolving to ``(answer, lsn)`` where
``lsn`` is the exact applied LSN of the replica index the answer was
computed against.  Placement policy:

* **Load balancing** — among eligible replicas, pick the one with the
  fewest router-inflight requests (heartbeat queue depth breaks ties),
  so a replica stalled behind a log-apply barrier naturally sheds load.
* **Consistent reads** (``min_lsn=L``) — eligible replicas are those
  whose last advertised LSN is already >= L; if none has caught up yet
  the router *redirects* to the highest-LSN replica and lets the
  replica-side ``QueryServer.wait_for_lsn`` hold the query until the
  tail applies L (the router never busy-waits).  The answer is then
  bit-identical to a single caught-up ``QueryServer``: same record
  sequence, same ``update_index`` path, same engine contract.
* **At-least-once dispatch** — a replica dying (SIGKILL, eviction)
  with requests in flight hands them back via ``Fleet.on_orphans``;
  the router re-dispatches each to a surviving replica, up to
  ``max_attempts``.  Reads are idempotent, so re-execution is safe; a
  request exhausting its attempts fails with ``ReplicaDied``.
"""
from __future__ import annotations

import itertools
import threading
from concurrent.futures import Future

from repro.core import pattern as pat
from repro.core import rpq as rpq_mod
from repro.launch.fleet import Fleet, FleetUnavailable, Replica, ReplicaDied


class _Pending:
    __slots__ = ("rid", "wire", "future", "attempts", "replica")

    def __init__(self, rid: int, wire: dict, future: Future):
        self.rid = rid
        self.wire = wire
        self.future = future
        self.attempts = 0
        self.replica: Replica | None = None


class FleetRouter:
    """Thin, stateless-per-request front door over a ``Fleet``."""

    def __init__(self, fleet: Fleet, *, max_attempts: int = 3):
        self.fleet = fleet
        self.max_attempts = int(max_attempts)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._inflight: dict[int, _Pending] = {}
        self.redispatched = 0
        self.redirected = 0     # consistent reads sent to a catching-up replica
        fleet.on_orphans = self._on_orphans
        # route answers arriving on each replica's reader thread back
        # into router futures (on top of the fleet's health handling)
        self._base_on_event = fleet._on_event

        def on_event(replica: Replica, msg: dict) -> None:
            self._base_on_event(replica, msg)
            if msg.get("ev") == "ans":
                self._resolve(msg)
        for r in fleet.members(ready_only=False):
            r._on_event = on_event
        self._on_event = on_event
        # new spawns need the same hook: wrap the fleet's spawner
        orig_spawn = fleet._spawn_locked

        def spawn_locked():
            r = orig_spawn()
            r._on_event = on_event
            return r
        fleet._spawn_locked = spawn_locked

    # -------------------------------------------------------------- submit
    def submit(self, u: int, v: int, p: pat.Pattern, *,
               kind: str = "bool", hops: int = 8, k: int | None = None,
               min_lsn: int = 0, lsn_timeout: float = 60.0) -> Future:
        """Route one PCR query; the future resolves to ``(answer, lsn)``
        with ``lsn >= min_lsn`` guaranteed for consistent reads.  For
        ``kind="rpq"`` the query ``p`` is a ``repro.core.rpq`` regex AST
        (serialized as regex text on the wire) rather than a pattern."""
        rid = next(self._ids)
        ptxt = rpq_mod.unparse(p) if kind == "rpq" else pat.unparse(p)
        wire = {"op": "q", "id": rid, "u": int(u), "v": int(v),
                "p": ptxt, "kind": kind, "hops": int(hops)}
        if k is not None:
            wire["k"] = int(k)
        if min_lsn:
            wire["min_lsn"] = int(min_lsn)
            wire["lsn_timeout"] = float(lsn_timeout)
        pending = _Pending(rid, wire, Future())
        with self._lock:
            self._inflight[rid] = pending
        self._dispatch(pending)
        return pending.future

    def _pick(self, min_lsn: int) -> Replica:
        members = self.fleet.members()
        if not members:
            raise FleetUnavailable("no live replicas")
        caught_up = [r for r in members if r.lsn >= min_lsn]
        pool = caught_up or members
        if not caught_up:
            # redirect: highest-LSN replica blocks server-side via
            # wait_for_lsn until the tail applies min_lsn
            best = max(r.lsn for r in members)
            pool = [r for r in members if r.lsn == best]
            self.redirected += 1
        loads = {id(r): 0 for r in pool}
        with self._lock:
            for pend in self._inflight.values():
                if pend.replica is not None and id(pend.replica) in loads:
                    loads[id(pend.replica)] += 1
        return min(pool, key=lambda r: (loads[id(r)], r.queued))

    def _dispatch(self, pending: _Pending) -> None:
        while True:
            pending.attempts += 1
            if pending.attempts > self.max_attempts:
                self._fail(pending, ReplicaDied(
                    f"request {pending.rid} failed on "
                    f"{self.max_attempts} replicas"))
                return
            try:
                replica = self._pick(pending.wire.get("min_lsn", 0))
            except FleetUnavailable as exc:
                self._fail(pending, exc)
                return
            pending.replica = replica
            replica.pending[pending.rid] = pending
            if replica.send(pending.wire):
                return
            # pipe already broken — the reader thread will orphan
            # whatever was registered; retry against another member now
            replica.pending.pop(pending.rid, None)

    def _fail(self, pending: _Pending, exc: Exception) -> None:
        with self._lock:
            self._inflight.pop(pending.rid, None)
        if not pending.future.done():
            pending.future.set_exception(exc)

    # ------------------------------------------------------------- resolve
    def _resolve(self, msg: dict) -> None:
        rid = int(msg["id"])
        with self._lock:
            pending = self._inflight.pop(rid, None)
        if pending is None or pending.future.done():
            return
        if pending.replica is not None:
            pending.replica.pending.pop(rid, None)
        if msg.get("ok"):
            val = msg["val"]
            if isinstance(val, list):   # witness path edges over JSON
                val = [tuple(e) for e in val]
            pending.future.set_result((val, int(msg["lsn"])))
        else:
            pending.future.set_exception(
                RuntimeError(f"replica error: {msg.get('err')}"))

    def _on_orphans(self, orphans: list) -> None:
        """A replica died with these requests in flight: re-dispatch
        each to a survivor (reads are idempotent)."""
        for pending in orphans:
            if pending.future.done():
                continue
            self.redispatched += 1
            self._dispatch(pending)

    # -------------------------------------------------------------- status
    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

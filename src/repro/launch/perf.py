import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: run one named iteration on one of the three
chosen cells, record the roofline before/after into
experiments/perf_iterations.json.

Iterations (see ARCHITECTURE.md §Perf for the hypothesis log):
  rwkv-chunked     rwkv6-3b × train_4k with the chunked WKV6 formulation
  rwkv-chunk-mxu   + bf16 intra-chunk matmuls
  ds-micro8        deepseek-v2 × train_4k with shardable microbatches
  ds-policy        + checkpoint policy saving expert matmuls
  tdr-2d           tdr-graph closure with 2-D (vertex × word) partitioning

Usage: PYTHONPATH=src python -m repro.launch.perf --iter rwkv-chunked
"""
import argparse
import json
import time

import jax
import numpy as np

import repro.configs as configs
from repro.launch import mesh as mesh_lib
from repro.launch.dryrun import run_cell
from repro.utils import hlo as hlo_lib
from repro.utils import roofline as roof_lib

OUT = "experiments/perf_iterations.json"


def record(name: str, rec: dict) -> None:
    data = {"iterations": {}}
    if os.path.exists(OUT):
        data = json.load(open(OUT))
    data["iterations"][name] = rec
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    json.dump(data, open(OUT, "w"), indent=1)
    ro = rec.get("roofline", {})
    print(f"[perf] {name}: compute={ro.get('compute_s', 0):.3f}s "
          f"memory={ro.get('memory_s', 0):.3f}s "
          f"collective={ro.get('collective_s', 0):.3f}s "
          f"dom={ro.get('dominant')} mfu={ro.get('mfu', 0):.4f}")


def run_tdr_variant(two_d: bool, word_shards: int = 8) -> dict:
    from repro.core import distributed
    the_mesh = mesh_lib.make_production_mesh()
    gcfg = configs.TDR_GRAPH
    n_dev = the_mesh.devices.size
    if two_d:
        v_shards = n_dev // word_shards
        e_max = -(-gcfg.n_edges // v_shards)
        lowered = distributed.lower_distributed_closure_2d(
            the_mesh, gcfg.n_vertices, e_max, gcfg.vtx_bits, gcfg.rounds,
            word_shards=word_shards)
    else:
        e_max = -(-gcfg.n_edges // n_dev)
        lowered = distributed.lower_distributed_closure(
            the_mesh, gcfg.n_vertices, e_max, gcfg.vtx_bits, gcfg.rounds)
    t0 = time.time()
    compiled = lowered.compile()
    dt = time.time() - t0
    cost = hlo_lib.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    roof = roof_lib.Roofline.from_cost(
        cost, chips=n_dev,
        model_flops=float(gcfg.n_edges) * (gcfg.vtx_bits // 32)
        * gcfg.rounds)
    return {
        "cell": "tdr-graph", "variant": "2d" if two_d else "1d",
        "compile_s": round(dt, 2),
        "memory": {"temp_gb": mem.temp_size_in_bytes / 1e9,
                   "argument_gb": mem.argument_size_in_bytes / 1e9},
        "hlo": {"flops_per_chip": cost.flops,
                "hbm_bytes_per_chip": cost.hbm_bytes,
                "collective_bytes_per_chip": cost.collective_bytes,
                "collectives": dict(cost.collectives)},
        "roofline": roof.as_dict(),
    }


def run_rwkv_dp() -> dict:
    """§Perf iteration R4: rwkv6 train as 256-way pure DP + ZeRO-1.

    RWKV6's 40 heads don't divide the 16-wide model axis, so TP never
    sharded its state ops anyway — it only added per-layer all-reduces.
    Re-map: batch over (data×model) = 256-way DP, params replicated
    (bf16, 6.2 GB/chip), optimizer state ZeRO-1-sharded over all 256
    chips.  Predicted: TP all-reduces vanish, per-chip activation traffic
    ÷16; gradient all-reduce (2×12 GB f32) becomes the collective term.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import sharding as shlib
    from repro.models import init_params as initp, pspec
    from repro.train import make_train_step
    from repro.train.train_step import init_train_state
    from repro.train import AdamWConfig
    from repro.configs.base import SHAPES

    arch, shape_name = "rwkv6-3b", "train_4k"
    cfg = configs.get(arch)
    sh = SHAPES[shape_name]
    the_mesh = mesh_lib.make_production_mesh()
    dm = ("data", "model")
    n_ways = 256

    def zero1_spec(leaf) -> "P":
        dims = list(leaf.shape)
        for i, d in enumerate(dims):
            if d % n_ways == 0:
                spec = [None] * len(dims)
                spec[i] = dm
                return P(*spec)
        return P(*([None] * len(dims)))

    state_shape = jax.eval_shape(
        lambda k: init_train_state(cfg, initp(cfg, k)),
        jax.random.PRNGKey(0))
    p_repl = jax.tree.map(lambda l: P(*([None] * l.ndim)),
                          state_shape["params"])
    opt_master = jax.tree.map(zero1_spec, state_shape["opt"]["master"])
    s_specs = {"params": p_repl,
               "opt": {"master": opt_master, "m": opt_master,
                       "v": opt_master, "count": P()}}
    sds = shlib.sds_with_sharding(state_shape,
                                  shlib.to_named(s_specs, the_mesh))
    toks = jax.ShapeDtypeStruct(
        (sh.global_batch, sh.seq_len), jnp.int32,
        sharding=NamedSharding(the_mesh, P(dm, None)))
    # n_microbatches=1: with 256-way DP every microbatch must keep >=256
    # rows (the D0/D1 lesson, applied)
    step = make_train_step(cfg, AdamWConfig(), n_microbatches=1,
                           remat=True, rwkv_chunked=True)
    mapping = {"batch": dm, "heads": None, "kv": None, "vocab": None,
               "ff": None, "experts": None, "embed": None, "seq": None}
    t0 = time.time()
    with pspec.use_mesh(the_mesh, mapping), the_mesh:
        lowered = jax.jit(step, donate_argnums=0).lower(
            sds, {"tokens": toks})
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = hlo_lib.analyze(compiled.as_text())
    mf = roof_lib.model_flops_train(
        cfg.n_active_params(), sh.global_batch * sh.seq_len)
    roofl = roof_lib.Roofline.from_cost(cost, chips=256, model_flops=mf)
    return {
        "arch": arch, "shape": shape_name, "mesh": "single", "chips": 256,
        "variant": "dp256-zero1", "compile_s": round(dt, 2),
        "memory": {"peak_gb": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes) / 1e9,
                   "temp_gb": mem.temp_size_in_bytes / 1e9,
                   "argument_gb": mem.argument_size_in_bytes / 1e9},
        "hlo": {"flops_per_chip": cost.flops,
                "hbm_bytes_per_chip": cost.hbm_bytes,
                "collective_bytes_per_chip": cost.collective_bytes,
                "collectives": dict(cost.collectives),
                "top_collectives": cost.top_collectives[:8],
                "top_memory": cost.top_memory[:8]},
        "roofline": roofl.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iter", required=True)
    args = ap.parse_args()
    it = args.iter

    if it == "rwkv-chunked":
        rec = run_cell("rwkv6-3b", "train_4k", "single",
                       extra={"rwkv_chunked": True})
    elif it == "ds-micro8":
        rec = run_cell("deepseek-v2-236b", "train_4k", "single",
                       extra={"n_microbatches": 8})
    elif it == "ds-micro16":
        rec = run_cell("deepseek-v2-236b", "train_4k", "single",
                       extra={"n_microbatches": 16})
    elif it == "ds-policy":
        rec = run_cell("deepseek-v2-236b", "train_4k", "single",
                       extra={"n_microbatches": 8, "remat_policy": "dots"})
    elif it == "gemma3-decode-window":
        rec = run_cell("gemma3-27b", "decode_32k", "single")
    elif it == "tdr-1d":
        rec = run_tdr_variant(False)
    elif it == "tdr-2d":
        rec = run_tdr_variant(True)
    elif it == "tdr-2d-w4":
        rec = run_tdr_variant(True, word_shards=4)
    elif it == "rwkv-dp":
        rec = run_rwkv_dp()
    else:
        raise SystemExit(f"unknown iteration {it}")
    record(it, rec)


if __name__ == "__main__":
    main()

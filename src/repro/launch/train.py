"""Fault-tolerant training driver.

Single-host runnable (reduced configs on CPU) with the fleet-scale control
flow: deterministic step-keyed data, periodic async checkpoints,
resume-from-latest on startup, bounded per-step retries, and an optional
failure injector that proves recovery works end-to-end
(``--fail-at-step N`` kills the step once; the driver restores and the run
converges to the same weights as an uninterrupted run — asserted in
tests/test_train.py::test_restart_reproduces_run).

Usage (the (b) end-to-end example driver wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
      --reduced --steps 300 --ckpt-dir /tmp/ckpt --task copy
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import Checkpointer
from repro.data import DataConfig, batch_for_step
from repro.models import init_params
from repro.train import AdamWConfig, init_train_state, make_train_step


class SimulatedFailure(RuntimeError):
    pass


def train_loop(cfg, dc: DataConfig, opt: AdamWConfig, steps: int,
               ckpt: Checkpointer, *, ckpt_every: int = 50,
               fail_at_step: int = -1, log_every: int = 20,
               max_retries: int = 3) -> dict:
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params, opt)
    start = 0
    if ckpt.latest_step() is not None:
        start, state = ckpt.restore(state)
        print(f"[train] resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt))

    failed_once = False
    step = start
    while step < steps:
        batch = batch_for_step(dc, step)
        for attempt in range(max_retries):
            try:
                if step == fail_at_step and not failed_once:
                    failed_once = True
                    raise SimulatedFailure(f"injected failure @ {step}")
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                dt = time.time() - t0
                break
            except SimulatedFailure as e:
                print(f"[train] {e} -> restoring last checkpoint")
                if ckpt.latest_step() is not None:
                    step, state = ckpt.restore(state)
                    print(f"[train] recovered at step {step}")
                else:
                    params = init_params(cfg, jax.random.PRNGKey(0))
                    state = init_train_state(cfg, params, opt)
                    step = 0
                batch = batch_for_step(dc, step)
        else:
            raise RuntimeError(f"step {step} failed {max_retries} times")
        step += 1
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f}"
                  f" gnorm={float(metrics['grad_norm']):.3f}"
                  f" {dt*1e3:.0f}ms", flush=True)
        if step % ckpt_every == 0:
            ckpt.save(step, state)
    ckpt.save(steps, state)
    ckpt.wait()   # final save must land before the caller tears down
    return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--task", default="copy", choices=["copy", "lm"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dc = DataConfig(task=args.task, vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch,
                    n_media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    opt = AdamWConfig(lr=args.lr, warmup_steps=20, decay_steps=args.steps)
    ckpt = Checkpointer(args.ckpt_dir, keep=3, async_save=True)
    train_loop(cfg, dc, opt, args.steps, ckpt,
               ckpt_every=args.ckpt_every, fail_at_step=args.fail_at_step)
    print("[train] done")


if __name__ == "__main__":
    main()

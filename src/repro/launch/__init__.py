from . import mesh, sharding

"""Per-arch sharding rules: DP/FSDP over ``data`` (+ pure DP over ``pod``),
TP over ``model``, EP (experts) over ``model``, SP (sequence) over ``data``
for batch-1 long-context caches.

Rules are name-based over the param tree so every family shares one rule
table.  Optimizer state inherits the spec of its parameter.  Pods hold full
parameter replicas (gradient all-reduce crosses pods once per step over
DCN); FSDP/ZeRO shards params+optimizer over the intra-pod ``data`` axis.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from . import mesh as mesh_lib

# weight classes by leaf name
_UP = {"wq", "wk", "wv", "w_gate", "w_up", "wq_a", "wq_b", "wkv_a",
       "wkv_b", "in_proj", "w_r", "w_k", "w_g"}
_DOWN = {"wo", "w_down", "out_proj", "w_o", "w_v"}
_REPL = {"q_norm", "kv_norm", "ln", "ln1", "ln2", "ln_a", "ln_f", "ln_x",
         "norm", "mu", "w0", "dt_bias", "a_log", "d_skip", "u", "conv_b",
         "final_norm", "count", "conv_w"}


def _leaf_spec(path: tuple, leaf, fsdp: str, tp: str) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1]
    nd = leaf.ndim
    none = (None,) * nd

    if name in ("router", "w_lora_a"):        # [L, D, small]
        return P(None, fsdp, None) if nd == 3 else P(fsdp, None)
    if name == "w_lora_b":                    # [L, small, D]
        return P(None, None, fsdp) if nd == 3 else P(None, fsdp)
    if name == "tok":
        # vocab over TP; D replicated so the token gather stays local per
        # vocab shard (one all-reduce over model); the table is small
        # relative to HBM once vocab-sharded.
        return P(tp, None)
    if name == "unembed":
        return P(tp, fsdp)
    if name in _REPL or nd <= 1:
        return P(*none)
    if name in _UP:
        if nd == 4:  # MoE expert stacks [L, E, D, F] -> EP over tp
            return P(None, tp, fsdp, None)
        if nd == 3 and "blocks" in names:      # [L, in, out]
            return P(None, fsdp, tp)
        if nd == 3:                            # MoE without L? [E, D, F]
            return P(tp, fsdp, None)
        return P(fsdp, tp)                     # shared blocks [in, out]
    if name in _DOWN:
        if nd == 4:
            return P(None, tp, None, fsdp)
        if nd == 3 and "blocks" in names:
            return P(None, tp, fsdp)
        if nd == 3:
            return P(tp, None, fsdp)
        return P(tp, fsdp)
    return P(*none)


def param_specs(cfg: ModelConfig, params_shape: Any, the_mesh) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (eval_shape output)."""
    fsdp = mesh_lib.fsdp_axis(the_mesh)
    tp = mesh_lib.tp_axis(the_mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, fsdp, tp), params_shape)


def state_specs(cfg: ModelConfig, state_shape: Any, the_mesh) -> Any:
    """Train-state specs: opt master/m/v inherit the param spec."""
    p_spec = param_specs(cfg, state_shape["params"], the_mesh)
    return {
        "params": p_spec,
        "opt": {
            "master": p_spec, "m": p_spec, "v": p_spec,
            "count": P(),
        },
    }


def batch_specs(cfg: ModelConfig, the_mesh, *, with_media: bool) -> Any:
    b_ax = mesh_lib.batch_axes(the_mesh)
    spec = {"tokens": P(b_ax, None)}
    if with_media:
        spec["media"] = P(b_ax, None, None)
    return spec


def cache_specs(cfg: ModelConfig, cache_shape: Any, the_mesh,
                batch: int) -> Any:
    """Decode-cache specs.  batch>1: shard B over (pod, data), heads/experts
    over model.  batch==1 (long_500k): sequence-parallel — shard the cache
    time axis over ``data`` instead."""
    b_ax = mesh_lib.batch_axes(the_mesh)
    tp = mesh_lib.tp_axis(the_mesh)
    sp = mesh_lib.fsdp_axis(the_mesh)
    big_b = batch > 1

    def spec_of(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        nd = leaf.ndim
        if name == "index":
            return P()
        if name in ("k", "v", "attn_k", "attn_v"):
            # [L/G, B, T, KV, hd]
            return P(None, b_ax, None, tp, None) if big_b \
                else P(None, None, sp, tp, None)
        if name == "c_kv":        # [L, B, T, ckv]
            return P(None, b_ax, None, None) if big_b \
                else P(None, None, sp, None)
        if name == "k_rope":      # [L, B, T, 1, dr]
            return P(None, b_ax, None, None, None) if big_b \
                else P(None, None, sp, None, None)
        if name == "h":           # [L, B, P, N, hd]
            return P(None, b_ax, tp, None, None) if big_b \
                else P(None, None, tp, None, None)
        if name == "conv":        # [L, B, K-1, C]
            return P(None, b_ax, None, tp) if big_b \
                else P(None, None, None, tp)
        if name == "s":           # [L, B, H, hd, hd]
            return P(None, b_ax, tp, None, None) if big_b \
                else P(None, None, tp, None, None)
        if name in ("last_tm", "last_cm"):   # [L, B, D]
            return P(None, b_ax, None) if big_b else P(None, None, tp)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


def _axis_size(the_mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= the_mesh.shape[a]
        return n
    return the_mesh.shape[entry]


def sanitize_specs(spec_tree: Any, shape_tree: Any, the_mesh) -> Any:
    """Null out spec entries whose dimension doesn't divide the axis size
    (e.g. 8 KV heads on a 16-wide model axis)."""
    def fix(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        out = []
        for dim, e in zip(leaf.shape, entries):
            out.append(e if dim % _axis_size(the_mesh, e) == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(spec_tree: Any, the_mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(the_mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sds_with_sharding(shape_tree: Any, sharding_tree: Any) -> Any:
    """ShapeDtypeStruct pytree carrying shardings (for .lower())."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree, sharding_tree)

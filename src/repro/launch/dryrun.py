import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 fake host devices.  (Everything
else in the repo — tests, benches, examples — sees the real single CPU.)

Per cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. assembles sharded ShapeDtypeStruct inputs via ``input_specs()``
     (no allocation anywhere),
  3. lowers + compiles the step function (train_step for train_4k,
     prefill for prefill_32k, a greedy decode_step for decode shapes),
  4. records ``memory_analysis()`` (fits-per-chip proof),
     loop-aware HLO costs (utils/hlo.py) and the three roofline terms,
  5. dumps everything to JSON for ARCHITECTURE.md.

Also lowers the paper's own engine (``--arch tdr-graph``): the distributed
TDR closure fixpoint on the full mesh — vertex-sharded with the per-round
exchange as packed uint32 closure words (the runtime build/query paths in
``repro.core.distributed`` converge via an all-reduced changed flag; the
lowering here keeps a static round count so the HLO cost accounting sees
a fixed trip count).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out experiments/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.launch import mesh as mesh_lib
from repro.launch import sharding
from repro.models import model as model_lib
from repro.models import init_params, pspec
from repro.models import decode_step
from repro.train import AdamWConfig, make_train_step
from repro.train.train_step import init_train_state
from repro.utils import hlo as hlo_lib
from repro.utils import roofline as roof_lib

# per-arch microbatch counts for train_4k (memory lever; tuned so the
# per-chip footprint clears 16 GB — see ARCHITECTURE.md §Dry-run)
# NOTE: microbatch rows (global_batch / n_micro) must stay divisible by
# the batch-axis size (16 single-pod, 32 multi-pod) or activations lose
# their data sharding and replicate -- measured as a 2.5x collective blow-up
# on deepseek (ARCHITECTURE.md §Perf, iteration D1).
TRAIN_MICROBATCHES = {
    "gemma3-27b": 8, "dbrx-132b": 8, "deepseek-v2-236b": 8,
    "phi3-mini-3.8b": 8,
    "phi-3-vision-4.2b": 8, "musicgen-large": 8, "zamba2-1.2b": 8,
    "rwkv6-3b": 4,
}

# bf16 Adam moments for the 100B+ models (standard at this scale; the
# master weights stay f32) -- ARCHITECTURE.md §Dry-run documents the choice
BF16_MOMENT_ARCHS = {"dbrx-132b", "deepseek-v2-236b"}


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def input_specs(arch: str, shape_name: str, the_mesh) -> dict:
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no alloc)
    for every input of the cell's step function."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    dt = jnp.dtype(cfg.dtype)
    b_ax = mesh_lib.batch_axes(the_mesh)
    ns = lambda spec: NamedSharding(the_mesh, spec)

    tokens_sds = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32,
        sharding=ns(P(b_ax, None)))
    out = {"tokens": tokens_sds}
    if cfg.n_media_tokens:
        out["media"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_media_tokens, cfg.d_model), dt,
            sharding=ns(P(b_ax, None, None)))

    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    p_specs = sharding.sanitize_specs(
        sharding.param_specs(cfg, params_shape, the_mesh), params_shape,
        the_mesh)
    out["params"] = sharding.sds_with_sharding(
        params_shape, sharding.to_named(p_specs, the_mesh))

    if shape.kind == "train":
        opt_cfg0 = AdamWConfig(
            moment_dtype="bfloat16" if arch in BF16_MOMENT_ARCHS
            else "float32")
        state_shape = jax.eval_shape(
            lambda k: init_train_state(cfg, init_params(cfg, k), opt_cfg0),
            jax.random.PRNGKey(0))
        s_specs = sharding.sanitize_specs(
            sharding.state_specs(cfg, state_shape, the_mesh), state_shape,
            the_mesh)
        out["state"] = sharding.sds_with_sharding(
            state_shape, sharding.to_named(s_specs, the_mesh))
    if shape.kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: model_lib.init_cache(cfg, shape.global_batch,
                                         shape.seq_len))
        c_specs = sharding.sanitize_specs(
            sharding.cache_specs(cfg, cache_shape, the_mesh,
                                 shape.global_batch), cache_shape, the_mesh)
        out["cache"] = sharding.sds_with_sharding(
            cache_shape, sharding.to_named(c_specs, the_mesh))
        out["step_tokens"] = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=ns(P(b_ax if shape.global_batch > 1 else None)))
    return out


def applicable(arch: str, shape_name: str) -> bool:
    cfg = configs.get(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False  # full-attention archs skip (see ARCHITECTURE.md)
    return True


def lower_cell(arch: str, shape_name: str, the_mesh, *,
               rwkv_chunked: bool = False, extra: Optional[dict] = None):
    """Returns (lowered, n_tokens, model_flops)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name, the_mesh)
    extra = extra or {}

    if shape.kind == "train":
        n_micro = extra.get("n_microbatches",
                            TRAIN_MICROBATCHES.get(arch, 4))
        opt_cfg = AdamWConfig(
            moment_dtype="bfloat16" if arch in BF16_MOMENT_ARCHS
            else "float32")
        step = make_train_step(cfg, opt_cfg,
                               n_microbatches=n_micro, remat=True,
                               remat_policy=extra.get("remat_policy", ""),
                               rwkv_chunked=rwkv_chunked)
        batch = {"tokens": specs["tokens"]}
        if "media" in specs:
            batch["media"] = specs["media"]
        fn = jax.jit(step, donate_argnums=0)
        with pspec.use_mesh(the_mesh, pspec.default_mapping(
                "pod" in the_mesh.axis_names)), the_mesh:
            lowered = fn.lower(specs["state"], batch)
        n_tokens = shape.global_batch * shape.seq_len
        mf = roof_lib.model_flops_train(cfg.n_active_params(), n_tokens)
    elif shape.kind == "prefill":
        def prefill_fn(params, tokens, media=None):
            return model_lib.prefill(cfg, params, tokens, media,
                                     max_len=shape.seq_len)
        args = [specs["params"], specs["tokens"]]
        if "media" in specs:
            args.append(specs["media"])
        fn = jax.jit(prefill_fn)
        with pspec.use_mesh(the_mesh, pspec.default_mapping(
                "pod" in the_mesh.axis_names)), the_mesh:
            lowered = fn.lower(*args)
        n_tokens = shape.global_batch * shape.seq_len
        mf = roof_lib.model_flops_forward(cfg.n_active_params(), n_tokens)
    else:  # decode: greedy single-token step over the model's decode cell
        def decode_fn(params, cache, tokens):
            logits, cache = decode_step(cfg, params, cache, tokens)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        fn = jax.jit(decode_fn, donate_argnums=1)
        with pspec.use_mesh(the_mesh, pspec.default_mapping(
                "pod" in the_mesh.axis_names)), the_mesh:
            lowered = fn.lower(specs["params"], specs["cache"],
                               specs["step_tokens"])
        n_tokens = shape.global_batch  # one token per sequence
        mf = roof_lib.model_flops_forward(cfg.n_active_params(), n_tokens)
    return lowered, n_tokens, mf


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             analyze: bool = True, extra: Optional[dict] = None,
             hlo_dir: Optional[str] = None) -> dict:
    t0 = time.time()
    the_mesh = mesh_lib.make_production_mesh(
        multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(the_mesh.shape.values())))
    lowered, n_tokens, model_flops = lower_cell(
        arch, shape_name, the_mesh,
        rwkv_chunked=(extra or {}).get("rwkv_chunked", False), extra=extra)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_gb": (mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes) / 1e9,
        },
        "xla_cost": {k: v for k, v in compiled.cost_analysis().items()
                     if k in ("flops", "bytes accessed")},
    }
    if analyze:
        text = compiled.as_text()
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    hlo_dir, f"{arch}__{shape_name}__{mesh_kind}.txt.gz"),
                    "wt") as f:
                f.write(text)
        cost = hlo_lib.analyze(text)
        roof = roof_lib.Roofline.from_cost(cost, chips=chips,
                                           model_flops=model_flops)
        rec["hlo"] = {
            "flops_per_chip": cost.flops,
            "hbm_bytes_per_chip": cost.hbm_bytes,
            "collective_bytes_per_chip": cost.collective_bytes,
            "collectives": dict(cost.collectives),
            "collective_counts": dict(cost.collective_counts),
            "top_collectives": cost.top_collectives[:8],
            "top_memory": cost.top_memory[:8],
        }
        rec["roofline"] = roof.as_dict()
    return rec


def run_tdr_cell(mesh_kind: str) -> dict:
    """Dry-run the paper's engine: distributed closure on the full mesh.

    The lowered fixpoint exchanges packed uint32 words (V × W × 4 bytes
    per round over the gather axis); ``rounds`` is static here purely for
    cost accounting — see ``distributed.lower_distributed_closure``.
    """
    from repro.core import distributed
    t0 = time.time()
    the_mesh = mesh_lib.make_production_mesh(
        multi_pod=(mesh_kind == "multi"))
    gcfg = configs.TDR_GRAPH
    n_shards = the_mesh.devices.size
    e_max = -(-gcfg.n_edges // n_shards)
    lowered = distributed.lower_distributed_closure(
        the_mesh, gcfg.n_vertices, e_max, gcfg.vtx_bits, gcfg.rounds)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = hlo_lib.analyze(compiled.as_text())
    chips = int(n_shards)
    roof = roof_lib.Roofline.from_cost(
        cost, chips=chips,
        # "model flops" for the engine: one OR-op per (edge × word) per
        # round, expressed in flop-equivalents
        model_flops=float(gcfg.n_edges) * (gcfg.vtx_bits // 32)
        * gcfg.rounds)
    return {
        "arch": "tdr-graph", "shape": f"V{gcfg.n_vertices}", "mesh":
        mesh_kind, "chips": chips,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {"temp_gb": mem.temp_size_in_bytes / 1e9,
                   "argument_gb": mem.argument_size_in_bytes / 1e9},
        "hlo": {"flops_per_chip": cost.flops,
                "hbm_bytes_per_chip": cost.hbm_bytes,
                "collective_bytes_per_chip": cost.collective_bytes,
                "collectives": dict(cost.collectives)},
        "roofline": roof.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--hlo-dir", default="")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    archs = configs.list_archs() if args.arch == "all" \
        else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    results, failures = [], []
    for mesh_kind in meshes:
        for arch in archs:
            if arch == "tdr-graph":
                results.append(run_tdr_cell(mesh_kind))
                continue
            for shape_name in shapes:
                if not applicable(arch, shape_name):
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_kind, "skipped":
                                    "long_500k: full-attention arch"})
                    continue
                tag = f"{arch} × {shape_name} × {mesh_kind}"
                try:
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   hlo_dir=args.hlo_dir or None)
                    r = rec.get("roofline", {})
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"peak={rec['memory']['peak_gb']:.2f}GB/chip "
                          f"dom={r.get('dominant')} "
                          f"mfu={r.get('mfu', 0):.3f}", flush=True)
                    results.append(rec)
                except Exception as e:  # noqa
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    failures.append({"cell": tag,
                                     "error": traceback.format_exc()})
                    if not args.continue_on_error:
                        raise
        if "tdr-graph" not in archs and args.arch == "all":
            results.append(run_tdr_cell(mesh_kind))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"wrote {args.out}: {len(results)} cells, "
          f"{len(failures)} failures")


if __name__ == "__main__":
    main()

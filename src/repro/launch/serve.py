"""Batched decode serving driver.

Continuous-batching-lite: requests are gathered into fixed slot batches,
prefilled together, then decoded step-by-step with greedy/temperature
sampling; finished slots free for new requests.  Runs the reduced configs
on CPU; the full configs are the ``decode_*`` dry-run cells.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
      --reduced --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import DataConfig, batch_for_step
from repro.models import init_params, prefill
from repro.train import make_serve_step


def serve_batch(cfg, params, prompts: jax.Array, media, new_tokens: int,
                temperature: float = 0.0):
    b, s = prompts.shape
    serve = make_serve_step(cfg, temperature=temperature)
    step_fn = jax.jit(serve)
    last, cache = prefill(cfg, params, prompts, media,
                          max_len=s + new_tokens)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    outs = [tok]
    key = jax.random.PRNGKey(0)
    for i in range(new_tokens - 1):
        key, sub = jax.random.split(key)
        tok, _, cache = step_fn(params, cache, tok, sub)
        outs.append(tok)
    return jnp.stack(outs, axis=1)          # [B, new_tokens]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(task="lm", vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.requests,
                    n_media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    batch = batch_for_step(dc, 0)
    t0 = time.time()
    out = serve_batch(cfg, params, batch["tokens"], batch.get("media"),
                      args.new_tokens, args.temperature)
    dt = time.time() - t0
    total = args.requests * args.new_tokens
    print(f"[serve] {args.requests} requests x {args.new_tokens} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", np.asarray(out[0])[:12].tolist())


if __name__ == "__main__":
    main()

"""Continuous micro-batching PCR query server over the TDR index.

Online counterpart of ``tdr_query.answer_batch``: asynchronously arriving
``(u, v, pattern)`` requests are coalesced into shape-bucketed batches and
answered through ``tdr_query.answer_plan``, amortizing plan compilation,
phase-1 cascade dispatch, and phase-2 expansion across every request in
flight.  The design goal is **zero jit recompiles at steady state**:

* **Job-budget coalescing.**  The scheduler drains the queue until the
  *job* (DNF-term) budget ``ServeConfig.max_jobs`` is met or the batching
  window ``max_wait_ms`` closes.  Term counts are known at submit time for
  free — ``tdr_query.pattern_rows`` resolves each pattern against the
  hash-consed plan cache — so a batch never overflows its top bucket.
  (One exception: a single request with more DNF terms than ``max_jobs``
  is served alone; it pads past the warmed grid and is counted in
  ``ServeStats.overflow_batches`` rather than silently recompiling.)
* **Bucket-grid shapes.**  ``answer_plan`` pads the job axis onto the
  ``{2^k, 3·2^(k-1)}`` grid (``QueryPlan.pad_to`` / ``graph.pad_bucket``);
  ``warmup`` pre-compiles every bucket of the grid up to ``max_jobs`` by
  replaying probe queries padded to each size.
* **Pinned statics.**  The two content-dependent jit statics are pinned
  from the warmup sample: ``pin_m`` fixes the packed subset-state width
  and (``pin_labels``) the special-label-class set is fixed for the
  ``pallas`` backend's per-class adjacency — so batch composition changes
  array *contents*, never shapes.  ``exact_mode`` defaults to ``"full"``:
  serving trades the corridor-compaction win for hard shape stability and
  zero per-batch host compaction work (the corridor still masks compute
  on device).
* **Caching.**  A bounded result cache keyed ``(u, v, canonical pattern,
  kind, bound)`` resolves repeats without touching the queue; duplicates
  *within* a batch collapse onto one plan row set (fan-out at
  completion).  The kind lives in the key — a boolean hit can never
  answer a distance query — and the per-index plan-row LRU is
  partitioned by kind the same way (``tdr_query.pattern_rows``).
* **Query kinds.**  ``submit(..., kind=...)`` accepts every
  ``tdr_query.QUERY_KINDS`` member: "bool" batches through
  ``answer_plan`` as before; "dist" requests batch through
  ``tdr_query.dist_batch`` grouped by their k-bound (k itself is traced
  — varying it never recompiles); "witness" and "count" run per request
  through ``tdr_query.witness`` / ``count_routes``.  All ride the same
  micro-batching scheduler, warmup pins, and result cache.
* **Backpressure / admission control.**  The queue is bounded
  (``max_queue``): blocking submits wait for room (closed-loop clients),
  non-blocking submits raise ``QueueFull`` so open-loop front-ends can
  shed load instead of growing an unbounded backlog.
* **Live graph updates.**  ``submit_update`` applies edge
  insertions/deletions through ``tdr_build.update_index`` while serving
  continues on the old (immutable) index, then enqueues a FIFO barrier:
  the scheduler finishes every batch submitted before the update, swaps
  the index, and drops the ``(u, v, pattern)`` result cache (the
  per-index plan-row LRU is invalidated with it — the new index starts
  with an empty ``pattern_rows`` cache).  Queries submitted after
  ``submit_update`` returns are therefore always answered — and cached —
  against the post-update graph; queries submitted before it see the
  pre-update graph.  No batch ever straddles the swap.
* **Durability.**  ``persist_to(dir)`` checkpoints the index
  (``repro.core.snapshot``) and attaches a write-ahead delta log
  (``repro.core.deltalog``): updates append their effective delta —
  fsync'd, CRC-framed — *before* the barrier swap, so
  ``QueryServer.recover(dir)`` after a crash replays snapshot + log into
  a state bit-identical to a rebuild of the final graph.  Transient
  update failures get bounded retry-with-backoff; exhausted retries
  raise ``UpdateFailed`` and flip ``ServeStats.degraded`` while reads
  keep being answered from the last-good index.  ``compact_every``
  checkpoints periodically, truncating the log.

``ServeStats.applied_lsn`` exposes the served index's log position for
replica routing.

``repro.core.engine.jit_cache_entries`` counts compiled variants across
the whole hot path; the serving benchmark asserts its delta over the
measurement window is zero.

  PYTHONPATH=src python -m repro.launch.serve --vertices 2000 \
      --requests 2000 --clients 32
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import os
import re
import threading
import time
from concurrent.futures import Future
from typing import Sequence

import numpy as np

from repro.core import deltalog as deltalog_mod
from repro.core import engine as engine_mod
from repro.core import graph as graph_mod
from repro.core import pattern as pat
from repro.core import rpq as rpq_mod
from repro.core import snapshot as snapshot_mod
from repro.core import tdr_build, tdr_query

LOG_NAME = "deltas.wal"
_SNAP_RE = re.compile(r"snapshot-(\d+)\.tdr")


class QueueFull(RuntimeError):
    """Admission control: the server's request queue is at ``max_queue``."""


class UpdateFailed(RuntimeError):
    """An update exhausted its retries (or its barrier died) without
    applying: the server keeps answering reads against the last-good
    index in degraded mode (``ServeStats.degraded``)."""


class RecoveryError(RuntimeError):
    """``QueryServer.recover`` could not reconstruct a served index from
    the persist directory (no usable snapshot, or the delta log was
    compacted past every snapshot that validates)."""


def _snapshot_files(directory: str) -> list[tuple[int, str]]:
    """``(lsn, path)`` of every snapshot in ``directory``, ascending."""
    out = []
    for name in os.listdir(directory):
        m = _SNAP_RE.fullmatch(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    out.sort()
    return out


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_jobs: int = 256          # job-axis coalescing budget (grid top)
    min_bucket: int = 16         # lowest job bucket (answer_plan's floor)
    max_wait_ms: float = 2.0     # batching window after the first arrival
    max_queue: int = 4096        # queued requests before backpressure
    result_cache: int = 4096     # (u, v, pattern) entries; 0 disables
    backend: str | None = None   # engine backend (None = contract default)
    exact_mode: str = "full"     # hard shape stability (module docstring)
    max_m: int = 4
    pin_labels: bool = True      # pin the label-class set at warmup
    exact_chunk: int = 32
    # dirty-set fraction beyond which submit_update falls back to a full
    # (layout-pinned) rebuild — see tdr_build.update_index
    update_rebuild_threshold: float = 0.5
    # durability (active once persist_to/recover attaches a directory):
    # snapshot + compact the delta log every N applied updates (0 = only
    # on explicit checkpoint()); bounded retry-with-backoff for
    # transient update/log failures before declaring the update failed
    compact_every: int = 0
    update_retries: int = 2
    retry_backoff_s: float = 0.05


@dataclasses.dataclass
class ServeStats:
    submitted: int = 0
    served: int = 0              # requests answered via a batch
    batches: int = 0
    jobs: int = 0                # plan rows over all served batches
    cache_hits: int = 0          # resolved from the result cache
    dedup_hits: int = 0          # collapsed onto an in-batch duplicate
    rejected: int = 0            # non-blocking submits shed by admission
    unpinned_batches: int = 0    # batches whose m exceeded the warmup pin
    updates: int = 0             # graph updates applied (submit_update)
    # batches padded past the warmed bucket grid (a single request with
    # more DNF terms than max_jobs is still served, alone, but may
    # compile a fresh bucket — visible here, not silently)
    overflow_batches: int = 0
    # durability: highest LSN whose update the served index reflects
    # (replica routing reads this), whether the last update failed and
    # reads are being answered from the last-good index, and the
    # retry/checkpoint bookkeeping behind those two
    applied_lsn: int = 0
    degraded: bool = False
    update_failures: int = 0
    update_retries: int = 0
    snapshots: int = 0
    checkpoint_failures: int = 0
    query_stats: "tdr_query.QueryStats" = dataclasses.field(
        default_factory=tdr_query.QueryStats)

    @property
    def mean_batch(self) -> float:
        return self.served / self.batches if self.batches else 0.0


#: result-cache miss sentinel: cached values include falsy answers
#: (witness None is *not* cached-able, dist -1 and count 0 are)
_MISS = object()


class _Request:
    __slots__ = ("u", "v", "pattern", "rkey", "terms", "kind", "hops",
                 "k", "with_lsn", "t_submit", "future")

    def __init__(self, u, v, pattern, rkey, terms, kind="bool", hops=8,
                 k=None, with_lsn=False):
        self.u = u
        self.v = v
        self.pattern = pattern
        self.rkey = rkey
        self.terms = terms
        self.kind = kind
        self.hops = hops
        self.k = k
        self.with_lsn = with_lsn
        self.t_submit = time.perf_counter()
        self.future: Future = Future()


class _UpdateBarrier:
    """Queue sentinel carrying a pre-built index: the scheduler serves
    everything queued ahead of it on the old index, then swaps and clears
    the result cache — the quiesce point of ``submit_update``.  ``lsn``
    is the write-ahead log position of the update (None when persistence
    is off); the scheduler refuses a swap that would move ``applied_lsn``
    backwards."""
    __slots__ = ("index", "lsn", "event", "exc")

    def __init__(self, index, lsn=None):
        self.index = index
        self.lsn = lsn
        self.event = threading.Event()
        self.exc: BaseException | None = None


def _resolve(fut: Future, value=None, exc: BaseException | None = None):
    """Complete a future a client may cancel concurrently: the
    check-then-act window of ``cancelled()`` + ``set_result`` would raise
    InvalidStateError out of the scheduler thread."""
    try:
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(value)
    except Exception:   # cancelled (or already resolved) — client's loss
        pass


def bucket_grid(lo: int, hi: int) -> list[int]:
    """The ``{2^k, 3·2^(k-1)}`` job buckets from ``lo`` up to covering
    ``hi`` (the shapes ``answer_plan`` can produce for this server)."""
    grid = []
    b = graph_mod.pad_bucket(lo, lo=lo)
    while True:
        grid.append(b)
        if b >= hi:
            return grid
        b = graph_mod.pad_bucket(b + 1, lo=lo)


class QueryServer:
    """Continuous micro-batching scheduler bound to one ``TDRIndex``.

    ``submit`` hands back a ``concurrent.futures.Future[bool]``; a daemon
    scheduler thread coalesces the queue into job-budgeted batches and
    answers them through the plan cache + ``answer_plan``.  Use as a
    context manager, or ``start()``/``stop()`` explicitly."""

    def __init__(self, index: "tdr_build.TDRIndex",
                 config: ServeConfig | None = None, **overrides):
        cfg = config or ServeConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.index = index
        self.config = cfg
        self.stats = ServeStats()
        self._queue: collections.deque[_Request] = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._results: collections.OrderedDict = collections.OrderedDict()
        self._update_lock = threading.Lock()   # serializes submit_update
        self._running = False
        self._stopped = False
        self._drain = True
        self._thread: threading.Thread | None = None
        self._pin_m: int | None = None
        self._special: tuple[int, ...] | None = None
        self._warmed_to = 0
        # durability state — attached by persist_to()/recover()
        self._log: "deltalog_mod.DeltaLog | None" = None
        self._persist_dir: str | None = None
        self._updates_since_snap = 0
        # replication state — attached by follow(): a read-only tailing
        # cursor over a log some *other* process appends to, plus the
        # maintenance thread applying what it yields.  _applied_cond
        # broadcasts every applied_lsn advance (wait_for_lsn).
        self._reader: "deltalog_mod.LogReader | None" = None
        self._poll_s = 0.05
        self._following = False
        self._tail_thread: threading.Thread | None = None
        self._applied_cond = threading.Condition(self._lock)

    def memory_stats(self) -> dict:
        """Resident index footprint: per-plane dense vs compressed bytes
        and the overall ratio (``TDRIndex.index_memory_stats``).  Reads
        the live index reference, so the numbers track update barriers."""
        return self.index.index_memory_stats()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "QueryServer":
        if self._thread is not None:
            return self
        self._running = True
        self._stopped = False
        self._thread = threading.Thread(target=self._loop,
                                        name="tdr-serve", daemon=True)
        self._thread.start()
        if self._reader is not None and self._tail_thread is None:
            # follower replica: tail the shared log alongside serving
            self._following = True
            self._tail_thread = threading.Thread(
                target=self._tail_loop, name="tdr-follow", daemon=True)
            self._tail_thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler.  ``drain`` serves whatever is queued first;
        otherwise queued futures are cancelled.  Later ``submit`` calls
        raise (their futures could never resolve) until ``start`` again."""
        tail = self._tail_thread
        if tail is not None:
            # stop tailing first, while the scheduler is still alive to
            # process any barrier the tail thread is waiting on
            self._following = False
            tail.join()
            self._tail_thread = None
        thread = self._thread
        if thread is None:
            return
        with self._lock:
            self._drain = drain
            self._running = False
            self._stopped = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        thread.join()
        self._thread = None
        with self._lock:
            leftovers = list(self._queue)
            self._queue.clear()
        for req in leftovers:
            if isinstance(req, _UpdateBarrier):
                # the update's waiter must not hang on a dead scheduler
                req.exc = RuntimeError(
                    "QueryServer stopped before the update was applied")
                req.event.set()
            else:
                req.future.cancel()

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- submit
    def submit(self, u: int, v: int, p: pat.Pattern, *,
               kind: str = "bool", hops: int = 8, k: int | None = None,
               block: bool = True, timeout: float | None = None,
               with_lsn: bool = False) -> Future:
        """Enqueue one PCR query; the future resolves per ``kind``:
        bool ("bool"), int hop distance, -1 unreachable ("dist", optional
        k-hop bound ``k``), an edge-list witness path / [] / None
        ("witness"), a saturating walk count over <= ``hops`` hops
        ("count", single-DNF-term patterns only — rejected here, in the
        caller's thread, not on the scheduler), or bool for "rpq" —
        whose ``p`` is a ``repro.core.rpq`` AST, not a pattern.

        ``block=True`` waits for queue room (backpressure, closed-loop
        clients); ``block=False`` raises ``QueueFull`` immediately when
        the queue is at ``max_queue`` (admission control, open-loop
        front-ends).

        ``with_lsn=True`` resolves the future to ``(answer, lsn)``
        instead — the ``applied_lsn`` of the index the answer was
        computed against (exact: no batch straddles an index swap, and
        the result cache is dropped at every swap).  Fleet replicas use
        this to stamp each answer with its read LSN."""
        cfg = self.config
        if kind not in tdr_query.QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {tdr_query.QUERY_KINDS}")
        # resolving the pattern against the plan cache here (caller's
        # thread) keeps DNF work off the scheduler thread and gives the
        # term count the job-budget coalescer needs.  RPQ queries carry
        # a regex AST instead of a pattern: same caller-thread compile
        # (Glushkov NFA + lowering), same per-index LRU.
        if kind == "rpq":
            if isinstance(p, (pat.Label, pat.Not, pat.And, pat.Or)):
                raise ValueError(
                    "kind='rpq' queries take a repro.core.rpq AST, not "
                    "a pattern (use rpq.parse / rpq.lcr)")
            rows = tdr_query.rpq_rows(self.index, p, cfg.max_m)
            ckey = rpq_mod.canonical_key(p)
        else:
            rows = tdr_query.pattern_rows(self.index, p, cfg.max_m,
                                          kind=kind)
            ckey = pat.canonical_key(p)
        if kind == "count" and rows.n_terms != 1:
            raise ValueError(
                f"count queries need a single-DNF-term pattern, got "
                f"{rows.n_terms} terms")
        # the answer depends on the kind and its bound, so both live in
        # the cache key — a boolean hit can never answer a distance query
        bound = int(hops) if kind == "count" else \
            (None if k is None else int(k)) if kind == "dist" else None
        rkey = (int(u), int(v), ckey, kind, bound)
        req = _Request(int(u), int(v), p, rkey, rows.n_terms, kind,
                       int(hops), k, with_lsn)
        with self._lock:
            if self._stopped:
                # enqueueing into a dead queue would leave the future
                # unresolved forever (requests *before* the first start()
                # are fine: they queue until the scheduler spins up)
                raise RuntimeError("QueryServer is stopped")
            self.stats.submitted += 1
            if cfg.result_cache:
                hit = self._results.get(rkey, _MISS)
                if hit is not _MISS:
                    self._results.move_to_end(rkey)
                    self.stats.cache_hits += 1
                    # cached answers are valid for the *current* index
                    # (the cache is cleared at every swap), so the
                    # current applied_lsn is an exact read LSN
                    req.future.set_result(
                        (hit, self.stats.applied_lsn) if with_lsn
                        else hit)
                    return req.future
            deadline = None if timeout is None else \
                time.perf_counter() + timeout
            while len(self._queue) >= cfg.max_queue:
                if not block or not self._running:
                    self.stats.rejected += 1
                    raise QueueFull(
                        f"queue at max_queue={cfg.max_queue}")
                rem = None if deadline is None else \
                    deadline - time.perf_counter()
                if rem is not None and rem <= 0:
                    self.stats.rejected += 1
                    raise QueueFull(
                        f"queue at max_queue={cfg.max_queue} "
                        f"(timed out after {timeout}s)")
                self._not_full.wait(rem)
            self._queue.append(req)
            self._not_empty.notify()
        return req.future

    # -------------------------------------------------------------- updates
    def submit_update(self, edges_added=(), edges_removed=(), *,
                      rebuild_threshold: float | None = None,
                      timeout: float | None = None
                      ) -> "tdr_build.UpdateStats":
        """Apply a live graph update; blocks until the server serves from
        the updated index.  Returns the ``tdr_build.UpdateStats`` of the
        maintenance call (mode, dirty/patched rows, warm rounds).

        The new index is built *outside* the scheduler (serving continues
        on the old, immutable index), then a FIFO barrier quiesces the
        scheduler: every request submitted before this call is answered
        on the pre-update graph, the index swaps, and the ``(u, v, key)``
        result cache is dropped along with the per-index plan-row LRU
        (the swapped-in index starts with an empty ``pattern_rows``
        cache).  Requests submitted after this method returns are always
        answered against the post-update graph.  Concurrent updates are
        serialized.  On a stopped server with an empty queue the swap
        applies inline; with requests already queued it raises instead —
        those requests are owed pre-update answers and there is no
        scheduler to quiesce.  On timeout the barrier is withdrawn (the
        update provably did not and will not apply — including its log
        record, which is popped) unless the scheduler already holds it,
        in which case the imminent swap is waited out.

        With persistence attached (``persist_to``/``recover``) the
        effective delta is appended to the write-ahead log *before* the
        barrier swap, so an acked update is always recoverable; index
        maintenance and the log append each get
        ``ServeConfig.update_retries`` retries with exponential backoff,
        and exhausting them raises ``UpdateFailed`` while the server
        keeps answering reads on the last-good index
        (``ServeStats.degraded``)."""
        cfg = self.config
        if self._reader is not None:
            raise RuntimeError(
                "follower replicas apply updates from the shared log; "
                "publish through the fleet writer instead")
        st = tdr_build.UpdateStats()
        with self._update_lock:
            # self.index is stable here: it only changes at *our* barrier
            delta = self.index.graph.apply_updates(edges_added,
                                                   edges_removed)
            lsn = None
            try:
                new_idx = self._with_retries(
                    lambda: tdr_build.update_index(
                        self.index, delta, backend=cfg.backend,
                        rebuild_threshold=(
                            cfg.update_rebuild_threshold
                            if rebuild_threshold is None
                            else rebuild_threshold),
                        stats=st))
                if self._log is not None:
                    # write-ahead ordering: the delta is durable before
                    # any served state can change (a crash between here
                    # and the swap replays it on recovery — the acked-
                    # or-acked-plus-one invariant)
                    lsn = self._with_retries(
                        lambda: self._log.append(delta.added,
                                                 delta.removed))
            except Exception as exc:
                with self._lock:
                    self.stats.degraded = True
                    self.stats.update_failures += 1
                raise UpdateFailed(
                    f"update failed after {cfg.update_retries + 1} "
                    "attempts; serving continues on the last-good "
                    "index") from exc
            bar = _UpdateBarrier(new_idx, lsn)
            inline = False
            with self._lock:
                if self._thread is None:
                    if self._queue:
                        # requests queued before the first start() must
                        # see the pre-update graph (the documented
                        # ordering), and with no scheduler there is
                        # nothing to quiesce them against
                        if lsn is not None:
                            self._log.pop_tail(lsn)
                        raise RuntimeError(
                            "submit_update on a stopped QueryServer with "
                            "queued requests; start() it first")
                    # idle stopped server: swap inline
                    self.index = new_idx
                    self._results.clear()
                    self._note_applied(lsn)
                    inline = True
                else:
                    self._queue.append(bar)
                    self._not_empty.notify()
            if inline:
                self._maybe_compact()
                return st
            if not bar.event.wait(timeout):
                # withdraw the barrier if it is still queued — leaving it
                # behind would let a *later* update (built from the
                # un-swapped index) overwrite this one's edges when both
                # barriers eventually process
                with self._lock:
                    try:
                        self._queue.remove(bar)
                        withdrawn = True
                        # the barrier held a max_queue slot: wake any
                        # submit blocked on backpressure, or it stalls
                        # until the next unrelated dequeue
                        self._not_full.notify_all()
                    except ValueError:
                        withdrawn = False   # already popped by scheduler
                if withdrawn:
                    if lsn is not None:
                        # under _update_lock no later append exists, so
                        # the record is provably the log tail — recovery
                        # must not replay an update that never applied
                        self._log.pop_tail(lsn)
                    raise TimeoutError(
                        f"update barrier not reached within {timeout}s; "
                        "update withdrawn")
                # the scheduler holds it: the swap is imminent — wait it
                # out so the update's effects are never in doubt
                bar.event.wait()
            if bar.exc is not None:
                # the scheduler refused the swap (or died holding the
                # barrier): roll the write-ahead record back so the log
                # never runs ahead of an update that was not applied
                if lsn is not None:
                    try:
                        self._log.pop_tail(lsn)
                    except Exception:
                        pass
                with self._lock:
                    self.stats.degraded = True
                    self.stats.update_failures += 1
                raise bar.exc
            with self._lock:
                self._note_applied(lsn)
            self._maybe_compact()
        return st

    def _with_retries(self, fn):
        """Run ``fn`` with ``ServeConfig.update_retries`` bounded retries
        and exponential backoff — transient maintenance/I/O failures
        (e.g. a momentarily full disk) don't immediately degrade."""
        cfg = self.config
        attempt = 0
        while True:
            try:
                return fn()
            except Exception:
                if attempt >= cfg.update_retries:
                    raise
                with self._lock:
                    self.stats.update_retries += 1
                time.sleep(cfg.retry_backoff_s * (2.0 ** attempt))
                attempt += 1

    def _note_applied(self, lsn: int | None) -> None:
        """Bookkeeping for a successfully applied update (caller holds
        ``_lock``): a success always clears degraded mode."""
        self.stats.updates += 1
        self.stats.degraded = False
        if lsn is not None:
            self.stats.applied_lsn = lsn
            self._applied_cond.notify_all()

    def wait_for_lsn(self, lsn: int, timeout: float | None = None) -> bool:
        """Block until the served index reflects log position ``lsn``
        (``applied_lsn >= lsn``); False on timeout.  The replica-side
        half of a consistent read: the router picks a replica believed
        caught up, the replica holds the query here if its heartbeat
        was stale."""
        with self._lock:
            return self._applied_cond.wait_for(
                lambda: self.stats.applied_lsn >= lsn, timeout)

    # ----------------------------------------------------------- durability
    def persist_to(self, directory: str) -> int:
        """Enable durability: checkpoint the current index into
        ``directory`` and attach the write-ahead delta log.

        Writes ``snapshot-<lsn>.tdr`` (see ``repro.core.snapshot``) and
        opens/creates ``deltas.wal``; every subsequent ``submit_update``
        appends its effective delta to the log *before* the index swap,
        so ``QueryServer.recover(directory)`` reconstructs the served
        state after a crash.  Existing log records (from a prior run of
        this same server) are folded into the snapshot and compacted
        away.  Returns the snapshot's LSN."""
        with self._update_lock:
            if self._log is not None:
                raise RuntimeError(
                    f"persistence already attached to {self._persist_dir}")
            os.makedirs(directory, exist_ok=True)
            log = deltalog_mod.DeltaLog(os.path.join(directory, LOG_NAME))
            self._log = log
            self._persist_dir = directory
            with self._lock:
                # the live index reflects everything this server has
                # applied; pin the snapshot at the log head
                self.stats.applied_lsn = log.last_lsn
            return self._checkpoint_locked()

    @classmethod
    def recover(cls, directory: str, config: ServeConfig | None = None,
                **overrides) -> "QueryServer":
        """Reconstruct a server from a persist directory after a crash:
        load the newest snapshot that validates, replay delta-log records
        with LSN beyond it through ``tdr_build.update_index`` (bit-
        identical to a layout-pinned rebuild of the final graph), and
        return a stopped server with persistence attached — ``start()``
        it to serve.  Falls back to older snapshots on ``SnapshotError``;
        raises ``RecoveryError`` when no snapshot can bridge to the
        (possibly compacted) log, and ``deltalog.LogCorrupt`` when the
        log itself fails validation."""
        cfg = config or ServeConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        snaps = _snapshot_files(directory) if os.path.isdir(directory) \
            else []
        if not snaps:
            raise RecoveryError(f"no snapshots in {directory!r}")
        log = deltalog_mod.DeltaLog(os.path.join(directory, LOG_NAME))
        try:
            idx, snap_lsn = cls._newest_valid_snapshot(directory,
                                                       log.base_lsn)
            applied = snap_lsn
            for lsn, added, removed in log.replay(after_lsn=snap_lsn):
                delta = idx.graph.apply_updates(added, removed)
                idx = tdr_build.update_index(
                    idx, delta, backend=cfg.backend,
                    rebuild_threshold=cfg.update_rebuild_threshold)
                applied = lsn
        except BaseException:
            log.close()
            raise
        server = cls(idx, cfg)
        server._log = log
        server._persist_dir = directory
        server.stats.applied_lsn = applied
        return server

    @staticmethod
    def _newest_valid_snapshot(directory: str, min_lsn: int):
        """``(index, lsn)`` from the newest snapshot that validates and
        sits at or past ``min_lsn`` (the log's base — an older snapshot
        cannot bridge a compacted log).  Falls back across snapshots on
        ``SnapshotError``; raises ``RecoveryError`` when none works."""
        snaps = _snapshot_files(directory) if os.path.isdir(directory) \
            else []
        if not snaps:
            raise RecoveryError(f"no snapshots in {directory!r}")
        problems = []
        for _, path in reversed(snaps):   # newest first
            try:
                idx, snap_lsn = snapshot_mod.load_index(path)
            except snapshot_mod.SnapshotError as exc:
                problems.append(f"{os.path.basename(path)}: {exc}")
                continue
            if snap_lsn < min_lsn:
                # the log was compacted past this snapshot — records it
                # needs no longer exist, it cannot seed a replay
                problems.append(
                    f"{os.path.basename(path)}: snapshot lsn {snap_lsn} "
                    f"predates compacted log base {min_lsn}")
                continue
            return idx, snap_lsn
        raise RecoveryError("no usable snapshot: " + "; ".join(problems))

    # ---------------------------------------------------------- follower
    @classmethod
    def follow(cls, directory: str, config: ServeConfig | None = None,
               *, poll_s: float = 0.05, **overrides) -> "QueryServer":
        """Bootstrap a read replica over a *shared* persist directory:
        restore the newest valid snapshot, replay the delta log behind
        it through a read-only ``deltalog.LogReader``, and return a
        stopped server whose ``start()`` both serves queries and keeps
        tailing the log (polling every ``poll_s``) — each new record a
        single writer appends is applied through ``update_index`` behind
        the usual quiesce barrier, and ``ServeStats.applied_lsn``
        advertises the replica's log position for router placement.

        The replica never writes to the shared store: ``submit_update``
        is refused (updates flow writer → log → every replica), and
        compaction by the writer is survived by re-bootstrapping from
        the newest snapshot when the log base passes the cursor."""
        cfg = config or ServeConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        reader = deltalog_mod.LogReader(
            os.path.join(directory, LOG_NAME))
        idx, applied = cls._bootstrap_replica(directory, cfg, reader)
        server = cls(idx, cfg)
        server._reader = reader
        server._persist_dir = directory
        server._poll_s = poll_s
        server.stats.applied_lsn = applied
        return server

    @classmethod
    def _bootstrap_replica(cls, directory: str, cfg: ServeConfig,
                           reader: "deltalog_mod.LogReader"):
        """Newest valid snapshot at/past the log base, plus a replay of
        the reader to the current tip.  Returns ``(index, applied_lsn)``
        with the reader's cursor left at ``applied_lsn``."""
        idx, snap_lsn = cls._newest_valid_snapshot(directory,
                                                   reader.base_lsn)
        reader.seek(snap_lsn)
        applied = snap_lsn
        while True:
            recs = reader.poll()
            if not recs:
                return idx, applied
            for lsn, added, removed in recs:
                delta = idx.graph.apply_updates(added, removed)
                idx = tdr_build.update_index(
                    idx, delta, backend=cfg.backend,
                    rebuild_threshold=cfg.update_rebuild_threshold)
                applied = lsn

    def _tail_loop(self) -> None:
        """Follower maintenance thread: poll the shared log, apply each
        new record behind a barrier.  Failures never kill the thread —
        the replica flips ``ServeStats.degraded``, keeps answering reads
        from the last-good index, and retries (the record is re-delivered
        by rewinding the cursor), exactly the submit_update degraded-mode
        contract in replicated form."""
        err_sleep = min(1.0, 10 * self._poll_s)
        while self._following:
            try:
                recs = self._reader.poll()
            except deltalog_mod.LogCompactedPast:
                # the writer compacted past our cursor: the records we
                # need are gone — re-bootstrap from the newest snapshot
                try:
                    self._refollow()
                except Exception:
                    with self._lock:
                        self.stats.degraded = True
                    time.sleep(err_sleep)
                continue
            except Exception:
                with self._lock:
                    self.stats.degraded = True
                time.sleep(err_sleep)
                continue
            applied_all = True
            for lsn, added, removed in recs:
                if not self._following:
                    return
                try:
                    if not self._apply_replicated(lsn, added, removed):
                        return   # scheduler is shutting down
                except Exception:
                    # rewind so the record is re-delivered next poll
                    self._reader.seek(lsn - 1)
                    with self._lock:
                        self.stats.degraded = True
                        self.stats.update_failures += 1
                    applied_all = False
                    time.sleep(err_sleep)
                    break
            if not recs and applied_all:
                time.sleep(self._poll_s)

    def _apply_replicated(self, lsn: int, added, removed) -> bool:
        """Apply one shared-log record on a follower: the maintenance +
        barrier machinery of ``submit_update`` minus the write-ahead
        append (the record came *from* the log — it is already durable).
        False when the server is stopping underneath us."""
        cfg = self.config
        with self._update_lock:
            if lsn <= self.stats.applied_lsn:
                return True   # overlap after a snapshot re-bootstrap
            delta = self.index.graph.apply_updates(added, removed)
            new_idx = self._with_retries(
                lambda: tdr_build.update_index(
                    self.index, delta, backend=cfg.backend,
                    rebuild_threshold=cfg.update_rebuild_threshold))
            return self._swap_in(new_idx, lsn)

    def _refollow(self) -> None:
        """Recover from ``LogCompactedPast``: rebuild the served state
        from the newest snapshot + log replay and swap it in as one
        barriered update (the reader's cursor lands on the new tip)."""
        cfg = self.config
        with self._update_lock:
            idx, applied = self._bootstrap_replica(self._persist_dir,
                                                   cfg, self._reader)
            if applied > self.stats.applied_lsn:
                self._swap_in(idx, applied)

    def _swap_in(self, new_idx, lsn: int) -> bool:
        """Swap ``new_idx`` in at ``lsn`` through the scheduler barrier
        (inline when no scheduler runs); caller holds ``_update_lock``.
        False when the scheduler died before reaching the barrier."""
        bar = _UpdateBarrier(new_idx, lsn)
        with self._lock:
            if self._thread is None:
                self.index = new_idx
                self._results.clear()
                self._note_applied(lsn)
                return True
            self._queue.append(bar)
            self._not_empty.notify()
        bar.event.wait()
        if bar.exc is not None:
            return False
        with self._lock:
            self._note_applied(lsn)
        return True

    def checkpoint(self) -> int:
        """Snapshot the currently served index and compact the delta log
        (records the snapshot folds in are dropped; the previous
        snapshot is retained as a corruption fallback).  Returns the
        snapshot's LSN."""
        with self._update_lock:
            if self._log is None:
                raise RuntimeError(
                    "persistence is not attached; call persist_to() first")
            return self._checkpoint_locked()

    def close_persistence(self) -> None:
        """Detach the delta log (closing its file handle); later updates
        are no longer write-ahead logged."""
        with self._update_lock:
            if self._log is not None:
                self._log.close()
                self._log = None
                self._persist_dir = None

    def _checkpoint_locked(self) -> int:
        """Checkpoint under ``_update_lock``: ``self.index`` cannot swap
        while held, so index and ``applied_lsn`` are a consistent pair."""
        with self._lock:
            idx, lsn = self.index, self.stats.applied_lsn
        path = os.path.join(self._persist_dir, f"snapshot-{lsn:016d}.tdr")
        snapshot_mod.save_index(idx, path, lsn=lsn)
        with self._lock:
            self.stats.snapshots += 1
        self._updates_since_snap = 0
        # keep the two newest snapshots (fallback if the newest ever
        # fails validation) and drop log records both have folded in
        snaps = _snapshot_files(self._persist_dir)
        for _, old in snaps[:-2]:
            os.unlink(old)
        self._log.truncate_upto(snaps[-2:][0][0])
        return lsn

    def _maybe_compact(self) -> None:
        """Periodic checkpoint driver (holds ``_update_lock``): every
        ``compact_every`` applied updates.  A failed checkpoint never
        fails the update that triggered it — the update is already
        durable in the log — it only defers compaction."""
        if self._log is None:
            return
        self._updates_since_snap += 1
        every = self.config.compact_every
        if not every or self._updates_since_snap < every:
            return
        try:
            self._checkpoint_locked()
        except Exception:
            with self._lock:
                self.stats.checkpoint_failures += 1
            self._updates_since_snap = every   # retry on the next update

    # --------------------------------------------------------------- warmup
    def warmup(self, sample: Sequence[tuple[int, int, pat.Pattern]],
               ) -> int:
        """Pre-compile the serving shapes from a representative sample.

        1. Answers the whole sample once, learning the pins: ``pin_m`` =
           the widest require-set seen, and (``pin_labels``) the
           special-label-class set over the sample's plan rows.
        2. Picks *probe* queries — ones the filter cascade left for
           phase 2 (``QueryStats.exact_qids``) — and replays them padded
           to **every** bucket of the job grid up to ``max_jobs``, so
           both the cascade and the expansion entry points compile at
           every shape live traffic can produce.

        Returns the number of compiled variants added (a second warmup
        with the same sample returns 0)."""
        cfg = self.config
        idx = self.index
        n0 = engine_mod.jit_cache_entries()
        plan = tdr_query.compile_queries(idx, sample, max_m=cfg.max_m)
        self._pin_m = int((plan.req_labels >= 0).sum(axis=1).max(initial=0))
        if cfg.pin_labels and plan.n_jobs:
            eng = idx.engine(cfg.backend)
            ex = tdr_query._executor(idx, eng)
            self._special = ex.special_labels(
                plan, np.arange(plan.n_jobs, dtype=np.int64))
        qstats = tdr_query.QueryStats()
        self._answer(list(sample), stats=qstats)

        # probe set: phase-2 survivors, capped to the smallest bucket so
        # every padded replay keeps the same pending content
        probes, jobs = [], 0
        for qi in qstats.exact_qids:
            u, v, p = sample[qi][:3]
            t = tdr_query.pattern_rows(idx, p, cfg.max_m).n_terms
            if jobs + t > cfg.min_bucket:
                break
            probes.append((u, v, p))
            jobs += t
        if not probes and len(sample):
            probes = list(sample[:1])
        pplan = tdr_query.compile_queries(idx, probes, max_m=cfg.max_m)
        top = graph_mod.pad_bucket(cfg.max_jobs, lo=cfg.min_bucket)
        for b in bucket_grid(cfg.min_bucket, top):
            if b < pplan.n_jobs:
                continue
            tdr_query.answer_plan(
                idx, pplan.pad_to(b), exact_chunk=cfg.exact_chunk,
                backend=cfg.backend, exact_mode=cfg.exact_mode,
                special_labels=self._special, pin_m=self._pin_m,
                pad_lo=cfg.min_bucket)
        self._warmed_to = top

        # pre-compile the non-boolean kinds.  Their executors run at
        # *fixed* shapes under the serving pins — dist chunks the job
        # axis to exact_chunk, witness/count are per-query — and their
        # bounds (k, hops) are traced, so one probe per kind covers
        # every batch composition live traffic can produce.
        if probes:
            u0, v0, p0 = probes[0]
            common = dict(max_m=cfg.max_m, backend=cfg.backend,
                          exact_mode=self._kind_mode(), pin_m=self._pin_m)
            tdr_query.dist_batch(idx, [(u0, v0, p0)], k=1,
                                 exact_chunk=cfg.exact_chunk,
                                 special_labels=self._special, **common)
            tdr_query.witness(idx, u0, v0, p0, **common)
            for q in probes + list(sample):
                cu, cv, cp = q[0], q[1], q[2]
                if len(pat.to_dnf(cp)) == 1:   # count: single-term only
                    tdr_query.count_routes(idx, cu, cv, cp, hops=1,
                                           **common)
                    break
            # rpq: lowered regexes ride the answer_plan shapes warmed
            # above; the product executor runs at fixed shapes under
            # "full" mode (job axis padded to exact_chunk, full-graph
            # corridor), so one product-route probe compiles both its
            # phases.  The probe is (a|…)+ at u0==u0: inexpressible
            # (Plus, not Star), not nullable (no ε pre-answer), and its
            # over-approximation is label-free, so the filter cascade
            # cannot prune it — the NFA executor is guaranteed to run.
            n_l = idx.graph.n_labels
            rdemo = rpq_mod.plus(rpq_mod.alt(
                *(rpq_mod.Sym(i) for i in range(n_l))))
            # q_unroll pinned: the compiled NFA shapes must not depend
            # on which regexes a live batch happens to hold
            tdr_query.rpq_batch(idx, [(u0, u0, rdemo)],
                                exact_chunk=cfg.exact_chunk,
                                special_labels=self._special,
                                pad_lo=cfg.min_bucket, q_unroll=32,
                                **common)
        return engine_mod.jit_cache_entries() - n0

    # ------------------------------------------------------------ scheduler
    def _loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            if isinstance(batch, _UpdateBarrier):
                # quiesce point: every pre-update batch has been served
                # by this thread already — swap and invalidate.  The
                # monotonic-LSN check is defense in depth: updates are
                # serialized and barriers FIFO, so a regressing LSN here
                # means a withdrawn barrier leaked back in — refuse the
                # swap rather than serve a stale index as current.
                with self._lock:
                    if batch.lsn is not None and \
                            batch.lsn <= self.stats.applied_lsn:
                        batch.exc = RuntimeError(
                            f"update barrier lsn {batch.lsn} <= applied "
                            f"lsn {self.stats.applied_lsn}: out-of-order "
                            "swap refused")
                    else:
                        self.index = batch.index
                        self._results.clear()
                        if batch.lsn is not None:
                            self.stats.applied_lsn = batch.lsn
                            self._applied_cond.notify_all()
                batch.event.set()
                continue
            if batch:
                try:
                    self._serve_batch(batch)
                except Exception as exc:  # noqa: BLE001 — the scheduler
                    # thread must never die silently: fail this batch's
                    # futures and keep serving
                    for req in batch:
                        _resolve(req.future, exc=exc)

    def _next_batch(self) -> "list[_Request] | _UpdateBarrier | None":
        """Block for the next coalesced batch (None = shut down).

        Drains until the job budget is met or ``max_wait_ms`` has passed
        since the first request of the batch — the continuous-batching
        tradeoff between latency (short wait) and amortization (full
        buckets).  An ``_UpdateBarrier`` at the queue head is returned
        alone (once everything ahead of it has been batched), so no
        batch ever straddles an index swap."""
        cfg = self.config
        with self._lock:
            while not self._queue:
                if not self._running:
                    return None
                self._not_empty.wait()
            if not self._running and not self._drain:
                return None
            deadline = time.perf_counter() + cfg.max_wait_ms * 1e-3
            batch: list[_Request] = []
            jobs = 0
            while True:
                while self._queue:
                    nxt = self._queue[0]
                    if isinstance(nxt, _UpdateBarrier):
                        if batch:   # serve what precedes the barrier first
                            self._not_full.notify_all()
                            return batch
                        self._queue.popleft()
                        self._not_full.notify_all()
                        return nxt
                    if batch and jobs + nxt.terms > cfg.max_jobs:
                        self._not_full.notify_all()
                        return batch
                    self._queue.popleft()
                    batch.append(nxt)
                    jobs += nxt.terms
                    if jobs >= cfg.max_jobs:
                        self._not_full.notify_all()
                        return batch
                self._not_full.notify_all()
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._running:
                    return batch
                self._not_empty.wait(rem)

    def _serve_batch(self, batch: list[_Request]) -> None:
        """Answer one coalesced batch: dedup → plan-cache compile →
        per-kind executors → fan results out to futures + result cache."""
        cfg = self.config
        uniq: dict = {}   # rkey -> (u, v, pattern, kind, hops, k)
        fanout: dict = collections.defaultdict(list)
        cached: list[tuple[_Request, object]] = []
        jobs_total = 0
        with self._lock:
            # the whole batch is served against self.index as of here —
            # swaps happen only on this (scheduler) thread, so this LSN
            # is the exact read position of every answer below
            lsn = self.stats.applied_lsn
            for req in batch:
                if cfg.result_cache:
                    hit = self._results.get(req.rkey, _MISS)
                    if hit is not _MISS:
                        self._results.move_to_end(req.rkey)
                        self.stats.cache_hits += 1
                        cached.append((req, hit))
                        continue
                if req.rkey in fanout:
                    self.stats.dedup_hits += 1
                else:
                    jobs_total += req.terms
                fanout[req.rkey].append(req)
                uniq.setdefault(req.rkey, (req.u, req.v, req.pattern,
                                           req.kind, req.hops, req.k))
        for req, hit in cached:
            _resolve(req.future, (hit, lsn) if req.with_lsn else hit)
        if not uniq:
            return
        keys = list(uniq)
        try:
            answers = self._answer_keys(keys, uniq)
        except Exception as exc:  # noqa: BLE001 — surface on the futures
            for k in keys:
                for req in fanout[k]:
                    _resolve(req.future, exc=exc)
            return
        with self._lock:
            self.stats.batches += 1
            self.stats.served += sum(len(v) for v in fanout.values())
            self.stats.jobs += jobs_total
            if self._warmed_to and jobs_total and \
                    graph_mod.pad_bucket(jobs_total, lo=cfg.min_bucket) \
                    > self._warmed_to:
                self.stats.overflow_batches += 1
            if cfg.result_cache:
                for k in keys:
                    while len(self._results) >= cfg.result_cache:
                        self._results.popitem(last=False)
                    self._results[k] = answers[k]
        for k in keys:
            for req in fanout[k]:
                _resolve(req.future,
                         (answers[k], lsn) if req.with_lsn
                         else answers[k])

    def _answer_keys(self, keys: list, uniq: dict) -> dict:
        """Run every kind's executor over its slice of the unique keys.
        Bool queries batch through ``answer_plan``; dist queries batch
        per k-bound (k is traced, so the groups share one compile); rpq
        queries batch through ``rpq_batch`` (lowered ones ride the same
        ``answer_plan`` shapes as bool traffic, product-route ones the
        fixed ``exact_chunk`` NFA shapes); witness/count run per query
        at fixed single-query shapes."""
        cfg = self.config
        qstats = self.stats.query_stats
        out: dict = {}
        bool_keys = [kk for kk in keys if uniq[kk][3] == "bool"]
        if bool_keys:
            ans = self._answer([uniq[kk][:3] for kk in bool_keys],
                               stats=qstats)
            out.update(zip(bool_keys, (bool(a) for a in ans)))
        dist_groups: dict = collections.defaultdict(list)
        for kk in keys:
            if uniq[kk][3] == "dist":
                dist_groups[uniq[kk][5]].append(kk)
        common = dict(max_m=cfg.max_m, backend=cfg.backend,
                      exact_mode=self._kind_mode(), pin_m=self._pin_m,
                      stats=qstats)
        for kb, group in dist_groups.items():
            ds = tdr_query.dist_batch(
                self.index, [uniq[kk][:3] for kk in group], k=kb,
                exact_chunk=cfg.exact_chunk,
                special_labels=self._special, **common)
            out.update(zip(group, (int(d) for d in ds)))
        rpq_keys = [kk for kk in keys if uniq[kk][3] == "rpq"]
        if rpq_keys:
            ans = tdr_query.rpq_batch(
                self.index, [uniq[kk][:3] for kk in rpq_keys],
                exact_chunk=cfg.exact_chunk,
                special_labels=self._special,
                pad_lo=cfg.min_bucket, q_unroll=32, **common)
            out.update(zip(rpq_keys, (bool(a) for a in ans)))
        for kk in keys:
            u, v, p, kd, hops, _ = uniq[kk]
            if kd == "witness":
                out[kk] = tdr_query.witness(self.index, u, v, p, **common)
            elif kd == "count":
                out[kk] = tdr_query.count_routes(self.index, u, v, p,
                                                 hops=hops, **common)
        return out

    def _kind_mode(self) -> str:
        """The non-boolean executors reject "legacy" — fall back to the
        shape-stable full-graph mode the server defaults to anyway."""
        return self.config.exact_mode \
            if self.config.exact_mode != "legacy" else "full"

    def _answer(self, queries, stats=None) -> np.ndarray:
        cfg = self.config
        plan = tdr_query.compile_queries(self.index, queries,
                                         max_m=cfg.max_m, stats=stats)
        if self._pin_m is not None:
            m = int((plan.req_labels >= 0).sum(axis=1).max(initial=0))
            if m > self._pin_m:
                self.stats.unpinned_batches += 1
        return tdr_query.answer_plan(
            self.index, plan, exact_chunk=cfg.exact_chunk, stats=stats,
            backend=cfg.backend, exact_mode=cfg.exact_mode,
            special_labels=self._special, pin_m=self._pin_m,
            pad_lo=cfg.min_bucket)


# ------------------------------------------------------------------- demo
def percentile(xs: list[float], q: float) -> float:
    """np.percentile with an empty-list guard — same estimator as the
    benchmark rows, so demo and CI-gated numbers are comparable."""
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def mixed_pool(g, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pool = []
    for i in range(n):
        u = int(rng.integers(g.n_vertices))
        v = int(rng.integers(g.n_vertices))
        labs = rng.choice(g.n_labels, size=min(3, g.n_labels),
                          replace=False).tolist()
        p = [pat.all_of(labs[:2]), pat.any_of(labs),
             pat.none_of(labs[:2]),
             pat.parse(f"(l{labs[0]} | l{labs[1]}) & !l{labs[-1]}")][i % 4]
        pool.append((u, v, p))
    return pool


def main() -> None:
    ap = argparse.ArgumentParser(
        description="TDR query-serving demo: closed-loop clients against "
                    "the micro-batching scheduler")
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--degree", type=float, default=1.5)
    ap.add_argument("--labels", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2_000)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--backend", default=None)
    args = ap.parse_args()

    g = graph_mod.erdos_renyi(args.vertices, args.degree, args.labels,
                              seed=0)
    print(f"[serve] ER graph |V|={g.n_vertices} |E|={g.n_edges}")
    t0 = time.perf_counter()
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                backend=args.backend)
    print(f"[serve] index build {time.perf_counter() - t0:.2f}s")

    pool = mixed_pool(g, 256)
    with QueryServer(idx, backend=args.backend) as server:
        mem = server.memory_stats()
        print(f"[serve] index planes "
              f"{mem['dense_bytes'] / 1e6:.1f} MB dense -> "
              f"{mem['compressed_bytes'] / 1e6:.1f} MB compressed "
              f"({mem['ratio']:.2f}x)")
        t0 = time.perf_counter()
        added = server.warmup(pool)
        print(f"[serve] warmup {time.perf_counter() - t0:.2f}s "
              f"({added} jit variants compiled)")

        n0 = engine_mod.jit_cache_entries()
        lat: list[float] = []
        lat_lock = threading.Lock()
        rng = np.random.default_rng(1)
        order = rng.integers(0, len(pool), size=args.requests)
        split = np.array_split(order, args.clients)

        def client(ids):
            for i in ids:
                u, v, p = pool[int(i)]
                t = time.perf_counter()
                server.submit(u, v, p).result()
                with lat_lock:
                    lat.append(time.perf_counter() - t)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(ids,))
                   for ids in split]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        st = server.stats
        print(f"[serve] {args.requests} requests / {args.clients} clients "
              f"in {wall:.2f}s = {args.requests / wall:.0f} q/s")
        print(f"[serve] p50={percentile(lat, 50) * 1e3:.1f}ms "
              f"p95={percentile(lat, 95) * 1e3:.1f}ms "
              f"p99={percentile(lat, 99) * 1e3:.1f}ms "
              f"mean_batch={st.mean_batch:.1f} "
              f"cache_hits={st.cache_hits} dedup={st.dedup_hits}")
        print(f"[serve] recompiles after warmup: "
              f"{engine_mod.jit_cache_entries() - n0}")


if __name__ == "__main__":
    main()

"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module never touches jax device state.  The dry-run forces 512 host-platform
devices *before* importing jax; tests and benches see the real single CPU
device and build their own tiny meshes.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def fsdp_axis(mesh) -> str:
    return "data"

"""Replicated PCR serving fleet over a shared delta log.

The multi-process tier above ``launch.serve``: one **writer** publishes
``GraphDelta`` batches to a shared, LSN-sequenced write-ahead log
(``repro.core.deltalog``), and N **replica** processes each serve reads
from their own snapshot-restored ``QueryServer`` in follower mode
(``QueryServer.follow``) — bootstrapping from the newest snapshot in the
shared directory, tailing the log through ``update_index``, and
advertising their applied LSN.  A thin ``launch.router.FleetRouter``
load-balances submits by per-replica queue depth and implements
consistent reads ("answer as of LSN >= L").

Consistency contract (ARCHITECTURE.md §Replicated fleet):

* **ack = commit.**  ``FleetWriter.publish`` returns once the record is
  fsync'd in the log; every replica applies exactly the committed
  record sequence in order (a torn in-flight append is invisible to
  ``deltalog.LogReader``), so any replica's served graph is always a
  *prefix* of the published sequence — the single-process
  acked/acked+1 invariant, replicated.
* **Read LSN is exact.**  Every answer is stamped with the
  ``applied_lsn`` of the index it was computed against
  (``submit(with_lsn=True)``); a consistent read at LSN >= L routed by
  the router is bit-identical to a single caught-up ``QueryServer``.
* **Crash = restart.**  A SIGKILLed replica loses nothing shared: the
  fleet evicts it (pipe EOF or heartbeat timeout) and can re-spawn a
  replacement that bootstraps from the newest snapshot + log tail.  A
  SIGKILLed *writer* leaves at worst a torn tail that both a new
  ``FleetWriter`` (via ``DeltaLog`` open) and every reader ignore.

Processes talk over the replica's stdin/stdout as newline-delimited
JSON (patterns ride as ``pattern.unparse`` text): parent → replica
``{"op": "q" | "warm" | "stop", ...}``; replica → parent
``{"ev": "ready" | "hb" | "ans" | "warmed", ...}``.  Heartbeats carry
the applied LSN and local queue depth.

Worker entry point (spawned by ``Fleet``, or by hand for debugging)::

    PYTHONPATH=src python -m repro.launch.fleet --replica DIR \
        [--backend segment] [--poll 0.02] [--hb 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np

from repro.core import deltalog as deltalog_mod
from repro.core import pattern as pat
from repro.core import rpq as rpq_mod
from repro.core import snapshot as snapshot_mod
from repro.launch import serve


class ReplicaDied(RuntimeError):
    """The replica process went away (SIGKILL, crash, or eviction)
    before answering — the router re-dispatches the request."""


class FleetUnavailable(RuntimeError):
    """No live replica can take the request (all dead, or none can
    reach the requested LSN within the deadline)."""


def init_store(index, directory: str, *, lsn: int = 0) -> str:
    """Create a shared fleet store: ``snapshot-<lsn>.tdr`` of ``index``
    plus an (empty, or pre-existing) delta log replicas will tail.
    Returns the snapshot path."""
    os.makedirs(directory, exist_ok=True)
    log = deltalog_mod.DeltaLog(os.path.join(directory, serve.LOG_NAME))
    lsn = max(int(lsn), log.last_lsn)
    log.close()
    path = os.path.join(directory, f"snapshot-{lsn:016d}.tdr")
    snapshot_mod.save_index(index, path, lsn=lsn)
    return path


class FleetWriter:
    """The fleet's single writer: owns the shared log and the current
    graph, publishes effective deltas.  ``publish`` returning *is* the
    commit point — the record is fsync'd and every replica will apply
    it.  Attaching to an existing store (e.g. after a writer crash)
    reconstructs the current graph from the newest snapshot + log
    replay; any torn tail a dead writer left is truncated by the
    ``DeltaLog`` open, exactly as single-process recovery does."""

    def __init__(self, directory: str):
        self.directory = directory
        self.log = deltalog_mod.DeltaLog(
            os.path.join(directory, serve.LOG_NAME))
        idx, snap_lsn = serve.QueryServer._newest_valid_snapshot(
            directory, self.log.base_lsn)
        g = idx.graph
        for _lsn, added, removed in self.log.replay(after_lsn=snap_lsn):
            g = g.apply_updates(added, removed).graph
        self.graph = g
        self._lock = threading.Lock()

    @property
    def last_lsn(self) -> int:
        return self.log.last_lsn

    def publish(self, edges_added=(), edges_removed=()) -> int:
        """Durably append one update; returns its LSN.  No-op deltas
        still consume an LSN (replicas apply them trivially), so the
        caller can always pin reads to the returned position."""
        with self._lock:
            delta = self.graph.apply_updates(edges_added, edges_removed)
            lsn = self.log.append(delta.added, delta.removed)
            self.graph = delta.graph
            return lsn

    def checkpoint(self, index) -> int:
        """Publish a new snapshot of ``index`` (which must be the index
        of the writer's current graph) and compact the log up to it,
        keeping the previous snapshot as a corruption fallback.
        Replicas whose cursor predates the compaction point re-bootstrap
        from this snapshot (``QueryServer._refollow``)."""
        with self._lock:
            lsn = self.log.last_lsn
            path = os.path.join(self.directory,
                                f"snapshot-{lsn:016d}.tdr")
            snapshot_mod.save_index(index, path, lsn=lsn)
            snaps = serve._snapshot_files(self.directory)
            for _, old in snaps[:-2]:
                os.unlink(old)
            self.log.truncate_upto(snaps[-2:][0][0])
            return lsn

    def close(self) -> None:
        self.log.close()


# --------------------------------------------------------------- replica
def _jsonable(val):
    """Answers over the wire: numpy scalars → Python, witness edge
    tuples → lists."""
    if isinstance(val, (bool, int, float, str)) or val is None:
        return val
    if isinstance(val, (np.bool_, np.integer)):
        return val.item()
    if isinstance(val, (list, tuple)):
        return [_jsonable(v) for v in val]
    return val


def replica_worker(directory: str, backend: str | None, poll_s: float,
                   hb_s: float) -> None:
    """Replica process body: follow the shared store, serve queries from
    stdin, heartbeat the applied LSN on stdout.  Exits on ``stop`` or
    stdin EOF (parent death)."""
    out_lock = threading.Lock()

    def emit(obj) -> None:
        with out_lock:
            sys.stdout.write(json.dumps(obj) + "\n")
            sys.stdout.flush()

    server = serve.QueryServer.follow(directory, backend=backend,
                                      poll_s=poll_s)
    server.start()
    stop_ev = threading.Event()

    def heartbeat() -> None:
        while not stop_ev.wait(hb_s):
            st = server.stats
            emit({"ev": "hb", "lsn": st.applied_lsn,
                  "queued": len(server._queue),
                  "degraded": st.degraded, "pid": os.getpid()})

    def answer(rid: int, msg: dict) -> None:
        try:
            kind = msg.get("kind", "bool")
            # rpq queries ship as regex text, every other kind as
            # pattern text — the kind field picks the parser
            p = rpq_mod.parse(msg["p"]) if kind == "rpq" \
                else pat.parse(msg["p"])
            min_lsn = int(msg.get("min_lsn") or 0)
            if min_lsn and not server.wait_for_lsn(
                    min_lsn, timeout=msg.get("lsn_timeout", 60.0)):
                raise TimeoutError(
                    f"replica did not reach lsn {min_lsn} "
                    f"(at {server.stats.applied_lsn})")
            fut = server.submit(
                int(msg["u"]), int(msg["v"]), p, kind=kind,
                hops=int(msg.get("hops", 8)),
                k=msg.get("k"), with_lsn=True)
        except Exception as exc:  # noqa: BLE001 — goes on the wire
            emit({"ev": "ans", "id": rid, "ok": False, "err": repr(exc)})
            return

        def done(f):
            try:
                val, lsn = f.result()
                emit({"ev": "ans", "id": rid, "ok": True,
                      "val": _jsonable(val), "lsn": lsn})
            except Exception as exc:  # noqa: BLE001
                emit({"ev": "ans", "id": rid, "ok": False,
                      "err": repr(exc)})
        fut.add_done_callback(done)

    hb = threading.Thread(target=heartbeat, daemon=True)
    hb.start()
    emit({"ev": "ready", "lsn": server.stats.applied_lsn,
          "pid": os.getpid()})
    try:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            msg = json.loads(line)
            op = msg.get("op")
            if op == "q":
                rid = int(msg["id"])
                if msg.get("min_lsn"):
                    # a pinned read may have to wait for the log tail —
                    # off the stdin thread so later requests still flow
                    threading.Thread(target=answer, args=(rid, msg),
                                     daemon=True).start()
                else:
                    answer(rid, msg)
            elif op == "warm":
                # pre-compile the serving shapes by answering the pool
                # once; replies when every future resolved
                futs = [server.submit(int(u), int(v), pat.parse(ptxt))
                        for u, v, ptxt in msg["qs"]]
                for f in futs:
                    f.result(timeout=600)
                emit({"ev": "warmed", "lsn": server.stats.applied_lsn})
            elif op == "stop":
                break
    finally:
        stop_ev.set()
        server.stop(drain=False)


class Replica:
    """Parent-side handle on one replica subprocess: the JSON pipe, its
    reader thread, pending request futures, and liveness/LSN state."""

    def __init__(self, directory: str, backend: str | None = None, *,
                 poll_s: float = 0.02, hb_s: float = 0.25,
                 name: str = "replica",
                 on_event=None, on_death=None):
        self.name = name
        self.lsn = -1            # last heartbeat/ready/answer LSN
        self.queued = 0
        self.ready = False
        self.alive = True
        self.last_hb = time.monotonic()
        self.pending: dict[int, object] = {}   # id -> router request
        self._on_event = on_event
        self._on_death = on_death
        self._wlock = threading.Lock()
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "repro.launch.fleet",
               "--replica", directory, "--poll", str(poll_s),
               "--hb", str(hb_s)]
        if backend:
            cmd += ["--backend", backend]
        self.proc = subprocess.Popen(
            cmd, env=env, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=None, text=True, bufsize=1)
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"{name}-rx", daemon=True)
        self._reader.start()

    @property
    def pid(self) -> int:
        return self.proc.pid

    def _read_loop(self) -> None:
        try:
            for line in self.proc.stdout:
                line = line.strip()
                if not line:
                    continue
                try:
                    msg = json.loads(line)
                except ValueError:
                    continue   # stray non-protocol output
                self.last_hb = time.monotonic()
                ev = msg.get("ev")
                if ev in ("hb", "ready", "warmed"):
                    self.lsn = max(self.lsn, int(msg.get("lsn", -1)))
                    self.queued = int(msg.get("queued", 0))
                    if ev == "ready":
                        self.ready = True
                if self._on_event is not None:
                    self._on_event(self, msg)
        finally:
            self._mark_dead()

    def _mark_dead(self) -> None:
        if not self.alive:
            return
        self.alive = False
        orphans = list(self.pending.values())
        self.pending.clear()
        if self._on_death is not None:
            self._on_death(self, orphans)

    def send(self, msg: dict) -> bool:
        """One protocol line to the replica; False if the pipe is gone
        (the reader thread will mark the replica dead)."""
        try:
            with self._wlock:
                self.proc.stdin.write(json.dumps(msg) + "\n")
                self.proc.stdin.flush()
            return True
        except (BrokenPipeError, OSError, ValueError):
            return False

    def kill(self) -> None:
        """SIGKILL — the fault-injection path (no cleanup of any kind
        runs in the replica; eviction happens via pipe EOF)."""
        try:
            self.proc.send_signal(signal.SIGKILL)
        except OSError:
            pass

    def stop(self, timeout: float = 30.0) -> None:
        self.send({"op": "stop"})
        try:
            self.proc.stdin.close()
        except OSError:
            pass
        try:
            self.proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait(timeout=10)


class Fleet:
    """Replica lifecycle manager: spawns N replicas over one shared
    store, watches health (pipe EOF fast path, heartbeat-staleness slow
    path), evicts dead replicas, and — with ``respawn=True`` — replaces
    them with a fresh process bootstrapped from the newest snapshot.
    Query placement lives in ``launch.router.FleetRouter``."""

    def __init__(self, directory: str, n: int,
                 backend: str | None = None, *, respawn: bool = True,
                 poll_s: float = 0.02, hb_s: float = 0.25,
                 hb_timeout_s: float = 15.0):
        self.directory = directory
        self.backend = backend
        self.n = int(n)
        self.respawn = respawn
        self.poll_s = poll_s
        self.hb_s = hb_s
        self.hb_timeout_s = hb_timeout_s
        self._members: list[Replica] = []
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._stopping = False
        self._spawned = 0
        self._monitor: threading.Thread | None = None
        self.evictions = 0
        self.respawns = 0
        # router hooks (set by FleetRouter.attach)
        self.on_membership = None    # fn() — replica set / lsn changed
        self.on_orphans = None       # fn(list) — requests needing redispatch

    # ----------------------------------------------------------- lifecycle
    def start(self, ready_timeout_s: float = 300.0) -> "Fleet":
        with self._lock:
            for _ in range(self.n):
                self._members.append(self._spawn_locked())
        deadline = time.monotonic() + ready_timeout_s
        for r in list(self._members):
            while r.alive and not r.ready:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"{r.name} not ready within {ready_timeout_s}s")
                time.sleep(0.05)
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="fleet-monitor",
                                         daemon=True)
        self._monitor.start()
        return self

    def _spawn_locked(self) -> Replica:
        self._spawned += 1
        return Replica(self.directory, self.backend,
                       poll_s=self.poll_s, hb_s=self.hb_s,
                       name=f"replica-{self._spawned}",
                       on_event=self._on_event,
                       on_death=self._on_death)

    def stop(self) -> None:
        with self._lock:
            self._stopping = True
            members = list(self._members)
            self._cond.notify_all()
        for r in members:
            r.stop()
        if self._monitor is not None:
            self._monitor.join(timeout=10)
            self._monitor = None

    def __enter__(self) -> "Fleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- health
    def _on_event(self, replica: Replica, msg: dict) -> None:
        if msg.get("ev") in ("hb", "ready") and self.on_membership:
            self.on_membership()

    def _on_death(self, replica: Replica, orphans: list) -> None:
        """Reader-thread EOF (or monitor eviction): drop the member,
        hand its in-flight requests back for redispatch, re-spawn."""
        with self._lock:
            if replica in self._members:
                self._members.remove(replica)
                self.evictions += 1
                if self.respawn and not self._stopping:
                    self._members.append(self._spawn_locked())
                    self.respawns += 1
        if self.on_membership:
            self.on_membership()
        if orphans and self.on_orphans:
            self.on_orphans(orphans)

    def _monitor_loop(self) -> None:
        """Slow-path health: a replica whose process died without pipe
        EOF, or whose heartbeats stopped (hung), is evicted here."""
        while True:
            with self._lock:
                if self._stopping:
                    return
                members = list(self._members)
            now = time.monotonic()
            for r in members:
                if not r.alive:
                    continue
                hung = r.ready and now - r.last_hb > self.hb_timeout_s
                if r.proc.poll() is not None or hung:
                    if hung:
                        r.kill()
                    r._mark_dead()
            time.sleep(self.hb_s)

    # -------------------------------------------------------------- state
    def members(self, ready_only: bool = True) -> list[Replica]:
        with self._lock:
            return [r for r in self._members
                    if r.alive and (r.ready or not ready_only)]

    def max_lsn(self) -> int:
        return max([r.lsn for r in self.members()] or [-1])

    def warm(self, queries, timeout_s: float = 600.0) -> None:
        """Broadcast a warm pool (each replica answers it once, compiling
        its serving shapes); blocks until every live replica confirms."""
        wire = [[int(u), int(v), pat.unparse(p)] for u, v, p in queries]
        waiting = {}
        ev = threading.Event()

        def on_warmed(replica, msg):
            if msg.get("ev") == "warmed":
                waiting.pop(id(replica), None)
                if not waiting:
                    ev.set()

        members = self.members()
        restore = {}
        for r in members:
            waiting[id(r)] = r
            prev = restore[id(r)] = r._on_event

            def chained(rep, msg, prev=prev):
                if prev:
                    prev(rep, msg)
                on_warmed(rep, msg)
            r._on_event = chained
            r.send({"op": "warm", "qs": wire})
        deadline = time.monotonic() + timeout_s
        while waiting and time.monotonic() < deadline:
            # a replica dying mid-warm must not hang the fleet
            for key, r in list(waiting.items()):
                if not r.alive:
                    waiting.pop(key, None)
            if ev.wait(0.1):
                break
        for r in members:
            r._on_event = restore[id(r)]


# ------------------------------------------------------------ CLI worker
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--replica", metavar="DIR", required=True,
                    help="shared fleet store to follow")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--poll", type=float, default=0.02,
                    help="log tail poll interval (s)")
    ap.add_argument("--hb", type=float, default=0.25,
                    help="heartbeat interval (s)")
    args = ap.parse_args()
    replica_worker(args.replica, args.backend, args.poll, args.hb)


if __name__ == "__main__":
    main()

"""Regular path queries (RPQ) — AST, parser, Glushkov NFA, DNF lowering.

Where ``pattern.py`` constrains the *set* of labels on a path, an RPQ
constrains their *order*: a path answers ``(u, v, r)`` iff the label
word ``L(p) = l_1 l_2 … l_k`` read along some u→v path is a member of
the regular language of ``r``.  This strictly extends the paper's LCR
comparison — the LCR allowed-set A is exactly the single-star regex
``(a_1|…|a_m)*`` — and matches the product-automaton formulation of
BitPath (Atre et al.) and the recursive label-concatenated index
fragment analysis of Zhang et al.

Two executors share this module:

* the **index-expressible fragment** — alternations of single-atom
  stars, ``A_1* | A_2* | …`` with each ``A_i`` a label alternation —
  lowers *exactly* onto ``pattern.py`` DNF terms via ``lower_to_pattern``
  (``w ∈ A* ⟺ set(w) ⊆ A``), so those queries ride the existing TDR
  filter cascade and phase-2 subset-state engine untouched;
* everything else compiles to a **Glushkov NFA** (``compile_nfa``: no
  ε-transitions, ≤ 32 states packed one ``uint32`` per (vertex, job)
  lane) and runs the automaton-product bidirectional expansion in
  ``tdr_query.rpq_batch``, pruned by the *over-approximation*
  ``approx_pattern`` — a single DNF term that is implied by (but does
  not imply) the RPQ, so only cascade-FALSE verdicts are sound.

Canonicalization mirrors ``pattern.py``: flatten/dedup/sort where the
algebra allows (alternation — but *not* concatenation, which is ordered),
star-absorption rewrites (``(x*)* → x*``, ``(x?)* → x*``,
``(a*|b)* → (a|b)*``), hash-consing behind an interning cap, and a
stable ``canonical_key`` string the serving layer uses for its
kind-keyed plan/result caches.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Union

import numpy as np

from repro.core import pattern as pat

#: hard ceiling on Glushkov states (start + one per label occurrence) so
#: an NFA subset fits one uint32 lane in the product-graph planes.
MAX_STATES = 32


# ------------------------------------------------------------------- AST
@dataclasses.dataclass(frozen=True)
class Sym:
    """One edge with label ``index`` (an atom of the regex)."""
    index: int


@dataclasses.dataclass(frozen=True)
class Cat:
    """Concatenation ``r_1 · r_2 · …`` — ordered, never commuted."""
    children: tuple


@dataclasses.dataclass(frozen=True)
class Alt:
    """Alternation ``r_1 | r_2 | …`` — flattened/deduped/sorted."""
    children: tuple


@dataclasses.dataclass(frozen=True)
class Star:
    child: "Rpq"


@dataclasses.dataclass(frozen=True)
class Plus:
    child: "Rpq"


@dataclasses.dataclass(frozen=True)
class Opt:
    child: "Rpq"


Rpq = Union[Sym, Cat, Alt, Star, Plus, Opt]


def sym(i: int) -> Rpq:
    return Sym(int(i))


def cat(*rs: Rpq) -> Rpq:
    rs = tuple(rs)
    return rs[0] if len(rs) == 1 else Cat(rs)


def alt(*rs: Rpq) -> Rpq:
    rs = tuple(rs)
    return rs[0] if len(rs) == 1 else Alt(rs)


def star(r: Rpq) -> Rpq:
    return Star(r)


def plus(r: Rpq) -> Rpq:
    return Plus(r)


def opt(r: Rpq) -> Rpq:
    return Opt(r)


def lcr(allowed, n_labels: int) -> Rpq:
    """The LCR allowed-set ``A`` as a regex: ``(a_1|…|a_m)*``."""
    del n_labels  # symmetry with pattern.lcr; the star needs no alphabet
    return Star(alt(*[Sym(int(a)) for a in sorted(set(allowed))]))


def alphabet(r: Rpq) -> FrozenSet[int]:
    """Every label that can appear in some word of ``L(r)``."""
    if isinstance(r, Sym):
        return frozenset((r.index,))
    if isinstance(r, (Cat, Alt)):
        out: FrozenSet[int] = frozenset()
        for c in r.children:
            out |= alphabet(c)
        return out
    return alphabet(r.child)


def nullable(r: Rpq) -> bool:
    """True iff the empty word ε ∈ L(r) — i.e. ``u == v`` answers True."""
    if isinstance(r, Sym):
        return False
    if isinstance(r, Cat):
        return all(nullable(c) for c in r.children)
    if isinstance(r, Alt):
        return any(nullable(c) for c in r.children)
    if isinstance(r, Plus):
        return nullable(r.child)
    return True  # Star, Opt


def required_alphabet(r: Rpq) -> FrozenSet[int]:
    """Labels present in *every* word of ``L(r)`` (structural lower
    bound, used as the require-side of ``approx_pattern``).  Sound by
    construction: Cat unions (every factor contributes), Alt intersects
    (any branch may be taken), Star/Opt require nothing (ε is a word),
    Plus requires what its body requires."""
    if isinstance(r, Sym):
        return frozenset((r.index,))
    if isinstance(r, Cat):
        out: FrozenSet[int] = frozenset()
        for c in r.children:
            out |= required_alphabet(c)
        return out
    if isinstance(r, Alt):
        sets = [required_alphabet(c) for c in r.children]
        out = sets[0]
        for s in sets[1:]:
            out &= s
        return out
    if isinstance(r, Plus):
        return required_alphabet(r.child)
    return frozenset()  # Star, Opt: ε kills every requirement


# --------------------------------------------- canonical form + interning
_intern: dict = {}
_INTERN_CAP = 1 << 16


def _strip_closure(c: Rpq) -> Rpq:
    """Inside a star, one top-level closure/option is absorbed:
    ``(x*)* = (x?)* = (x+)* = x*`` and ``(…|x*|…)* = (…|x|…)*``."""
    if isinstance(c, (Star, Plus, Opt)):
        return _strip_closure(c.child)
    if isinstance(c, Alt):
        return Alt(tuple(_strip_closure(g) for g in c.children))
    return c


def _canon(r: Rpq):
    """Canonical (node, key) of ``r``.  Keys: ``l3`` / ``.(k,…)`` /
    ``|(k,…)`` / ``*(k)`` / ``+(k)`` / ``?(k)``."""
    if isinstance(r, Sym):
        if r.index < 0:
            raise ValueError(f"negative label {r.index}")
        return r, f"l{r.index}"
    if isinstance(r, Cat):
        kids = []
        for c in r.children:
            cc, _ = _canon(c)
            if isinstance(cc, Cat):             # flatten, order preserved
                kids.extend(cc.children)
            else:
                kids.append(cc)
        if not kids:
            raise ValueError("empty concatenation")
        if len(kids) == 1:
            return _canon(kids[0])
        keys = [_canon(c)[1] for c in kids]
        return Cat(tuple(kids)), f".({','.join(keys)})"
    if isinstance(r, Alt):
        kids: dict = {}
        for c in r.children:
            cc, ck = _canon(c)
            if isinstance(cc, Alt):             # flatten nested same-op
                for gc in cc.children:
                    kids.setdefault(_canon(gc)[1], gc)
            else:
                kids.setdefault(ck, cc)         # dedup by key
        if not kids:
            raise ValueError("empty alternation")
        if len(kids) == 1:
            (ck, cc), = kids.items()            # single child unwraps
            return cc, ck
        keys = sorted(kids)
        return Alt(tuple(kids[k] for k in keys)), f"|({','.join(keys)})"
    if isinstance(r, Star):
        cc, _ = _canon(r.child)
        cc, ck = _canon(_strip_closure(cc))     # (x*)* → x*, (a*|b)* → (a|b)*
        return Star(cc), f"*({ck})"
    if isinstance(r, Plus):
        cc, ck = _canon(r.child)
        if isinstance(cc, (Star, Opt)):         # (x*)+ = x*, (x?)+ = x*
            return _canon(Star(cc.child))
        if isinstance(cc, Plus):                # (x+)+ = x+
            cc, ck = cc.child, _canon(cc.child)[1]
        return Plus(cc), f"+({ck})"
    if isinstance(r, Opt):
        cc, ck = _canon(r.child)
        if isinstance(cc, Star):                # (x*)? = x*
            return cc, ck
        if isinstance(cc, Plus):                # (x+)? = x*
            return _canon(Star(cc.child))
        if isinstance(cc, Opt):                 # (x?)? = x?
            cc, ck = cc.child, _canon(cc.child)[1]
        return Opt(cc), f"?({ck})"
    raise TypeError(r)


def canonicalize(r: Rpq) -> Rpq:
    """Canonical, hash-consed form of ``r`` (same language as ``r``)."""
    node, key = _canon(r)
    hit = _intern.get(key)
    if hit is not None:
        return hit
    if len(_intern) < _INTERN_CAP:
        _intern[key] = node
    return node


def canonical_key(r: Rpq) -> str:
    """Stable string key of the canonical form (plan/result cache key)."""
    return _canon(r)[1]


# ------------------------------------------------------------ wire format
def unparse(r: Rpq) -> str:
    """Infix text ``parse`` accepts: ``(l0|l1)* . l2+``.  Parenthesizes
    by precedence (alternation < concatenation < postfix closures), so
    ``parse(unparse(r))`` is structurally equal to ``r`` up to
    canonicalization — the fleet wire contract."""
    def go(r: Rpq, prec: int) -> str:
        if isinstance(r, Sym):
            return f"l{r.index}"
        if isinstance(r, Alt):
            s = " | ".join(go(c, 1) for c in r.children)
            return f"({s})" if prec > 0 else s
        if isinstance(r, Cat):
            s = " . ".join(go(c, 2) for c in r.children)
            return f"({s})" if prec > 1 else s
        mark = {Star: "*", Plus: "+", Opt: "?"}[type(r)]
        return f"{go(r.child, 3)}{mark}"
    return go(r, 0)


def _tokenise(text: str):
    tokens, i = [], 0
    while i < len(text):
        ch = text[i]
        if ch.isspace():
            i += 1
        elif ch in "|.*+?()":
            tokens.append(ch)
            i += 1
        elif ch == "l" and i + 1 < len(text) and text[i + 1].isdigit():
            j = i + 1
            while j < len(text) and text[j].isdigit():
                j += 1
            tokens.append(text[i:j])
            i = j
        elif ch.isdigit():
            j = i
            while j < len(text) and text[j].isdigit():
                j += 1
            tokens.append("l" + text[i:j])
            i = j
        else:
            raise ValueError(f"bad character {ch!r} in RPQ {text!r}")
    return tokens


def parse(text: str) -> Rpq:
    """Parse ``"(l0 | l1)* . l2"`` into an AST.  Concatenation binds
    tighter than ``|``; postfix ``*``/``+``/``?`` tighter still; the
    ``.`` separator is optional (``l0 l1`` ≡ ``l0 . l1``)."""
    tokens = _tokenise(text)
    pos = 0

    def peek():
        return tokens[pos] if pos < len(tokens) else None

    def take(expected=None):
        nonlocal pos
        if pos >= len(tokens):
            raise ValueError("unexpected end of RPQ")
        tok = tokens[pos]
        if expected is not None and tok != expected:
            raise ValueError(f"expected {expected!r}, got {tok!r}")
        pos += 1
        return tok

    def parse_alt():
        parts = [parse_cat()]
        while peek() == "|":
            take("|")
            parts.append(parse_cat())
        return parts[0] if len(parts) == 1 else Alt(tuple(parts))

    def parse_cat():
        parts = [parse_postfix()]
        while True:
            tok = peek()
            if tok == ".":
                take(".")
                parts.append(parse_postfix())
            elif tok == "(" or (tok is not None and tok.startswith("l")):
                parts.append(parse_postfix())   # juxtaposition
            else:
                break
        return parts[0] if len(parts) == 1 else Cat(tuple(parts))

    def parse_postfix():
        node = parse_atom()
        while peek() in ("*", "+", "?"):
            node = {"*": Star, "+": Plus, "?": Opt}[take()](node)
        return node

    def parse_atom():
        tok = peek()
        if tok == "(":
            take("(")
            node = parse_alt()
            take(")")
            return node
        if tok is None:
            raise ValueError("unexpected end of RPQ")
        take()
        if tok.startswith("l") and tok[1:].isdigit():
            return Sym(int(tok[1:]))
        raise ValueError(f"bad token {tok!r}")

    node = parse_alt()
    if pos != len(tokens):
        raise ValueError(f"trailing tokens: {tokens[pos:]}")
    return node


# ------------------------------------------------------------ Glushkov NFA
@dataclasses.dataclass(frozen=True)
class Nfa:
    """Glushkov automaton of one RPQ, dense-table form for the engine.

    States are ``0`` (start) plus one per ``Sym`` occurrence, ``n_states
    <= 32`` so a state subset packs into one uint32.  ``tab[a][q]`` is
    the bitmask of states reached from ``q`` on label ``a``;
    ``rtab[a][p]`` the reverse relation (states that reach ``p`` on
    ``a``) for the backward frontier.  No ε-transitions: ``nullable``
    alone decides the empty word (``u == v`` queries)."""
    n_states: int
    n_labels: int
    nullable: bool
    accept: int                 # uint32 bitmask of accepting states
    tab: np.ndarray             # [n_labels, 32] uint32
    rtab: np.ndarray            # [n_labels, 32] uint32

    @property
    def start(self) -> int:
        return 1                # bit 0


def compile_nfa(r: Rpq, n_labels: int) -> Nfa:
    """Glushkov construction: position automaton over the ``Sym``
    occurrences of ``r``.  Raises if ``r`` has 32+ occurrences (a subset
    must fit one uint32 plane lane).  Labels ``>= n_labels`` cannot
    label any graph edge, so their transitions are simply dropped —
    the sub-language using them is unmatchable."""
    positions: list = []        # position id-1 -> label

    def build(r: Rpq):
        """Return (nullable, first, last, follow-pairs) with positions
        numbered 1.. in occurrence order."""
        if isinstance(r, Sym):
            positions.append(r.index)
            p = len(positions)  # ids start at 1; 0 is the start state
            return False, {p}, {p}, []
        if isinstance(r, Cat):
            nul, first, last, fol = True, set(), set(), []
            for c in r.children:
                cn, cf, cl, cfol = build(c)
                fol += cfol
                fol += [(q, p) for q in last for p in cf]
                if nul:
                    first |= cf
                last = (last | cl) if cn else cl
                nul = nul and cn
            return nul, first, last, fol
        if isinstance(r, Alt):
            nul, first, last, fol = False, set(), set(), []
            for c in r.children:
                cn, cf, cl, cfol = build(c)
                nul = nul or cn
                first |= cf
                last |= cl
                fol += cfol
            return nul, first, last, fol
        cn, cf, cl, cfol = build(r.child)
        if isinstance(r, Opt):
            return True, cf, cl, cfol
        loop = [(q, p) for q in cl for p in cf]
        if isinstance(r, Star):
            return True, cf, cl, cfol + loop
        return cn, cf, cl, cfol + loop      # Plus

    nul, first, last, fol = build(r)
    n_states = len(positions) + 1
    if n_states > MAX_STATES:
        raise ValueError(
            f"RPQ has {len(positions)} label occurrences; the packed "
            f"product executor supports at most {MAX_STATES - 1}")
    tab = np.zeros((n_labels, MAX_STATES), dtype=np.uint32)
    rtab = np.zeros((n_labels, MAX_STATES), dtype=np.uint32)

    def link(q: int, p: int) -> None:
        a = positions[p - 1]
        if a < n_labels:
            tab[a][q] |= np.uint32(1 << p)
            rtab[a][p] |= np.uint32(1 << q)

    for p in first:
        link(0, p)
    for q, p in set(fol):
        link(q, p)
    accept = (1 if nul else 0)
    for p in last:
        accept |= 1 << p
    return Nfa(n_states=n_states, n_labels=int(n_labels), nullable=nul,
               accept=accept, tab=tab, rtab=rtab)


# --------------------------------------------- reference matcher (oracle)
def matches(r: Rpq, word) -> bool:
    """Span-based regex membership, independent of ``compile_nfa`` —
    the cross-check the NFA (and everything downstream of it) is tested
    against.  O(|r| · |word|²) sets of end positions."""
    word = tuple(int(a) for a in word)
    n = len(word)

    def ends(r: Rpq, starts: frozenset) -> frozenset:
        """End positions of matches of ``r`` beginning at any of
        ``starts``."""
        if isinstance(r, Sym):
            return frozenset(i + 1 for i in starts
                             if i < n and word[i] == r.index)
        if isinstance(r, Cat):
            cur = starts
            for c in r.children:
                cur = ends(c, cur)
            return cur
        if isinstance(r, Alt):
            out: frozenset = frozenset()
            for c in r.children:
                out |= ends(c, starts)
            return out
        if isinstance(r, Opt):
            return starts | ends(r.child, starts)
        # Star / Plus: closure of the child relation
        seen = ends(r.child, starts)
        frontier = seen
        while frontier:
            nxt = ends(r.child, frontier) - seen
            seen |= nxt
            frontier = nxt
        return seen | starts if isinstance(r, Star) else seen

    return n in ends(r, frozenset((0,)))


# ------------------------------------------------------- DNF lowering
def _star_body_labels(body: Rpq):
    """Labels of a star body that is a ``Sym`` or an ``Alt`` of ``Sym``s;
    None if the body is anything richer."""
    if isinstance(body, Sym):
        return (body.index,)
    if isinstance(body, Alt) and all(isinstance(c, Sym)
                                     for c in body.children):
        return tuple(c.index for c in body.children)
    return None


def lower_to_pattern(r: Rpq, n_labels: int):
    """Exact DNF lowering of the index-expressible fragment, or None.

    Expressible: ``A_1* | A_2* | … | A_k*`` (each ``A_i`` a label or a
    label alternation), including the bare single star — the RPQ
    spelling of (a union of) LCR queries.  Exactness: a word lies in
    ``A*`` iff its letter *set* is a subset of ``A``, which is precisely
    ``pattern.lcr(A)``'s one DNF term (require=∅, forbid=ζ∖A); order
    never matters inside a single star of atoms, so nothing richer than
    set logic is being smuggled through.  Labels >= ``n_labels`` cannot
    label a graph edge and are dropped from the allowed set (the words
    using them are unmatchable).  Anything outside the fragment returns
    None and must run the automaton-product executor."""
    r = canonicalize(r)
    stars = r.children if isinstance(r, Alt) else (r,)
    terms = []
    for s in stars:
        if not isinstance(s, Star):
            return None
        labs = _star_body_labels(s.child)
        if labs is None:
            return None
        allowed = sorted(a for a in set(labs) if a < n_labels)
        terms.append(pat.lcr(allowed, n_labels))
    return pat.canonicalize(terms[0] if len(terms) == 1
                            else pat.Or(tuple(terms)))


def approx_pattern(r: Rpq, n_labels: int, max_require: int | None = None):
    """Set-logic over-approximation of ``r`` for the TDR filter cascade:
    a single-term pattern implied by the RPQ, so a FALSE verdict on it
    refutes the RPQ (order-blind, so TRUE proves nothing).  Returns
    ``(pattern, feasible)``: ``feasible=False`` means some *required*
    label cannot exist on any edge (``>= n_labels``) — no non-empty
    path matches, and only ε (``u == v`` + nullable) can answer True."""
    req = sorted(required_alphabet(r))
    if any(a >= n_labels for a in req):
        return pat.And(()), False
    if max_require is not None and len(req) > max_require:
        req = req[:max_require]     # dropping requirements is sound
    allowed = {a for a in alphabet(r) if a < n_labels}
    banned = sorted(set(range(n_labels)) - allowed)
    parts = [pat.label(a) for a in req] + \
        [pat.not_(pat.label(b)) for b in banned]
    return pat.canonicalize(pat.And(tuple(parts))), True

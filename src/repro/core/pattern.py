"""Composite patterns (paper Def. 3) — AST, parser, DNF compiler.

A pattern is a propositional formula over edge labels; a path ``p`` satisfies
it iff the *set* ``S(L(p))`` of labels on the path makes the formula true
(labels present = true).  Answering PCR queries is NP-hard (paper Thm. 1,
reduction from SAT — each SAT variable maps to the presence/absence of a
label on the solution path), which is why the engine uses a lossy index as a
refutation cascade and reserves exact product-graph search for survivors.

The DNF compiler normalises any pattern into ``⋁ terms``, each term a pair
``(require, forbid)`` of label sets: a set S satisfies the term iff
``require ⊆ S`` and ``forbid ∩ S = ∅``.  The paper's query families map to:

* AND-query  ``AND{l_i}``  -> one term, require={l_i}, forbid=∅
* OR-query   ``OR{l_i}``   -> one term per label
* NOT-query  ``NOT{l_i}``  -> one term, require=∅, forbid={l_i}
  (the paper reads ``NOT`` as "all listed labels absent")
* LCR(allowed A)           -> one term, require=∅, forbid=ζ∖A

Canonicalization / hash-consing: ``canonicalize`` rewrites any pattern
into a structurally canonical form (children flattened, deduped, sorted;
double negation removed; single-child And/Or unwrapped) and interns the
result, so two syntactically different spellings of the same composite
pattern share one AST object and one ``canonical_key`` string.  The
serving layer keys its plan and result caches on that string, and
``to_dnf`` memoizes per canonical form — repeated query shapes skip DNF
expansion (and, one layer up, planning) entirely.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import FrozenSet, Sequence, Union


# ------------------------------------------------------------------- AST
@dataclasses.dataclass(frozen=True)
class Label:
    index: int


@dataclasses.dataclass(frozen=True)
class Not:
    child: "Pattern"


@dataclasses.dataclass(frozen=True)
class And:
    children: tuple


@dataclasses.dataclass(frozen=True)
class Or:
    children: tuple


Pattern = Union[Label, Not, And, Or]


def label(i: int) -> Pattern:
    """Atomic pattern: a path must carry an edge with label id ``i``."""
    return Label(i)


def and_(*ps: Pattern) -> Pattern:
    """Conjunction node over already-built pattern ASTs."""
    return And(tuple(ps))


def or_(*ps: Pattern) -> Pattern:
    """Disjunction node over already-built pattern ASTs."""
    return Or(tuple(ps))


def not_(p: Pattern) -> Pattern:
    """Negation node (the NOT operator of the paper's pattern algebra)."""
    return Not(p)


def all_of(labels: Sequence[int]) -> Pattern:
    """AND-query: the path must carry *every* label id in ``labels``."""
    return And(tuple(Label(i) for i in labels))


def any_of(labels: Sequence[int]) -> Pattern:
    """OR-query: the path must carry *some* label id in ``labels``."""
    return Or(tuple(Label(i) for i in labels))


def none_of(labels: Sequence[int]) -> Pattern:
    """NOT-query: the path must avoid *every* label id in ``labels``."""
    return And(tuple(Not(Label(i)) for i in labels))


def lcr(allowed: Sequence[int], n_labels: int) -> Pattern:
    """LCR(allowed) as a PCR pattern: every non-allowed label is forbidden."""
    banned = sorted(set(range(n_labels)) - set(allowed))
    if not banned:
        return And(())  # trivially true
    return none_of(banned)


# ------------------------------------------------------------------ eval
def evaluate(p: Pattern, present: FrozenSet[int]) -> bool:
    """Truth value of the pattern under a label-set assignment (oracle)."""
    if isinstance(p, Label):
        return p.index in present
    if isinstance(p, Not):
        return not evaluate(p.child, present)
    if isinstance(p, And):
        return all(evaluate(c, present) for c in p.children)
    if isinstance(p, Or):
        return any(evaluate(c, present) for c in p.children)
    raise TypeError(p)


def labels_of(p: Pattern) -> FrozenSet[int]:
    """Set of label ids mentioned anywhere in the pattern AST."""
    if isinstance(p, Label):
        return frozenset((p.index,))
    if isinstance(p, Not):
        return labels_of(p.child)
    return frozenset(itertools.chain.from_iterable(
        labels_of(c) for c in p.children))


# ------------------------------------------------- canonical form / intern
# key -> interned canonical AST.  Bounded: past the cap new forms are
# still canonicalized but returned un-interned (correctness is structural
# equality, interning only makes repeats cheap), so adversarial traffic
# cannot grow the table without bound.
_INTERN_CAP = 1 << 16
_intern: dict = {}


def _canon(p: Pattern) -> tuple[Pattern, str]:
    """(canonical node, canonical key).  Keys are unambiguous serialized
    forms — ``l3``, ``!(k)``, ``&(k1,k2)``, ``|(k1,k2)`` — usable both as
    cache keys and as the total order for sorting And/Or children."""
    if isinstance(p, Label):
        return p, f"l{p.index}"
    if isinstance(p, Not):
        child, ck = _canon(p.child)
        if isinstance(child, Not):          # ¬¬x = x
            return _canon(child.child)
        return Not(child), f"!({ck})"
    if isinstance(p, (And, Or)):
        op, mark = (And, "&") if isinstance(p, And) else (Or, "|")
        kids: dict[str, Pattern] = {}
        for c in p.children:
            cc, ck = _canon(c)
            if isinstance(cc, op):          # flatten nested same-op
                for gc in cc.children:
                    gcc, gck = _canon(gc)
                    kids.setdefault(gck, gcc)
            else:
                kids.setdefault(ck, cc)     # dedup by key
        if len(kids) == 1:
            (ck, cc), = kids.items()        # single child unwraps
            return cc, ck
        keys = sorted(kids)
        node = op(tuple(kids[k] for k in keys))
        return node, f"{mark}({','.join(keys)})"
    raise TypeError(p)


def canonicalize(p: Pattern) -> Pattern:
    """Canonical, hash-consed form of ``p`` (semantically equal to ``p``).

    Repeated calls with structurally equal inputs return the *same*
    object, so identity comparison and dict hashing over canonical
    patterns are cheap."""
    node, key = _canon(p)
    hit = _intern.get(key)
    if hit is not None:
        return hit
    if len(_intern) < _INTERN_CAP:
        _intern[key] = node
    return node


def canonical_key(p: Pattern) -> str:
    """Stable string key of the canonical form (plan/result cache key)."""
    return _canon(p)[1]


def unparse(p: Pattern) -> str:
    """Render ``p`` as infix text that ``parse`` accepts —
    ``parse(unparse(p))`` is structurally equal to ``p`` up to
    canonicalization, which is what wire protocols (the fleet's
    replica pipes) need to ship patterns between processes."""
    if isinstance(p, Label):
        return f"l{p.index}"
    if isinstance(p, Not):
        return f"!({unparse(p.child)})"
    if isinstance(p, (And, Or)):
        sep = " & " if isinstance(p, And) else " | "
        return "(" + sep.join(unparse(c) for c in p.children) + ")"
    raise TypeError(p)


# ---------------------------------------------------------------- parser
def parse(text: str) -> Pattern:
    """Parse ``"0 & !(1 | 2)"`` / ``"l0 AND NOT (l1 OR l2)"`` into an AST."""
    tokens = _tokenise(text)
    pos = 0

    def peek():
        return tokens[pos] if pos < len(tokens) else None

    def take(expected=None):
        nonlocal pos
        if pos >= len(tokens):
            raise ValueError("unexpected end of pattern")
        tok = tokens[pos]
        if expected is not None and tok != expected:
            raise ValueError(f"expected {expected!r}, got {tok!r}")
        pos += 1
        return tok

    def parse_or():
        node = parse_and()
        parts = [node]
        while peek() == "|":
            take("|")
            parts.append(parse_and())
        return parts[0] if len(parts) == 1 else Or(tuple(parts))

    def parse_and():
        node = parse_unary()
        parts = [node]
        while peek() == "&":
            take("&")
            parts.append(parse_unary())
        return parts[0] if len(parts) == 1 else And(tuple(parts))

    def parse_unary():
        tok = peek()
        if tok == "!":
            take("!")
            return Not(parse_unary())
        if tok == "(":
            take("(")
            node = parse_or()
            take(")")
            return node
        if tok is None:
            raise ValueError("unexpected end of pattern")
        take()
        if tok.startswith("l") and tok[1:].isdigit():
            return Label(int(tok[1:]))
        if tok.isdigit():
            return Label(int(tok))
        raise ValueError(f"bad token {tok!r}")

    node = parse_or()
    if pos != len(tokens):
        raise ValueError(f"trailing tokens: {tokens[pos:]}")
    return node


def _tokenise(text: str) -> list[str]:
    subst = {"AND": "&", "OR": "|", "NOT": "!", "and": "&", "or": "|",
             "not": "!"}
    out, i = [], 0
    while i < len(text):
        ch = text[i]
        if ch.isspace():
            i += 1
        elif ch in "&|!()":
            out.append(ch)
            i += 1
        else:
            j = i
            while j < len(text) and (text[j].isalnum() or text[j] == "_"):
                j += 1
            if j == i:      # non-word, non-operator char: never advances
                raise ValueError(f"bad character {ch!r} in pattern {text!r}")
            word = text[i:j]
            out.append(subst.get(word, word))
            i = j
    return out


# ------------------------------------------------------------------- DNF
@dataclasses.dataclass(frozen=True)
class DnfTerm:
    require: FrozenSet[int]
    forbid: FrozenSet[int]

    def satisfied_by(self, present: FrozenSet[int]) -> bool:
        return self.require <= present and not (self.forbid & present)


_DNF_CACHE_CAP = 4096
_dnf_cache: dict = {}


def to_dnf(p: Pattern, max_terms: int = 256) -> list[DnfTerm]:
    """Disjunctive normal form as (require, forbid) terms.

    Contradictory terms are dropped; terms subsumed by a weaker term are
    pruned.  ``max_terms`` bounds the classical DNF blow-up.  Results are
    memoized per canonical form, so repeated query shapes expand once.
    """
    key = (canonical_key(p), max_terms)
    hit = _dnf_cache.get(key)
    if hit is not None:
        return list(hit)
    out = _to_dnf_uncached(canonicalize(p), max_terms)
    if len(_dnf_cache) >= _DNF_CACHE_CAP:
        _dnf_cache.clear()
    _dnf_cache[key] = tuple(out)
    return out


def _to_dnf_uncached(p: Pattern, max_terms: int) -> list[DnfTerm]:
    terms = _dnf(p)
    # drop contradictions
    terms = [t for t in terms if not (t.require & t.forbid)]
    # subsumption: t1 subsumes t2 if t1.require ⊆ t2.require and
    # t1.forbid ⊆ t2.forbid (t1 is weaker -> keep t1, drop t2)
    kept: list[DnfTerm] = []
    for t in sorted(terms, key=lambda t: (len(t.require), len(t.forbid))):
        if not any(k.require <= t.require and k.forbid <= t.forbid
                   for k in kept):
            kept.append(t)
    if len(kept) > max_terms:
        raise ValueError(f"DNF blow-up: {len(kept)} terms > {max_terms}")
    return kept


def _dnf(p: Pattern) -> list[DnfTerm]:
    if isinstance(p, Label):
        return [DnfTerm(frozenset((p.index,)), frozenset())]
    if isinstance(p, Not):
        c = p.child
        if isinstance(c, Label):
            return [DnfTerm(frozenset(), frozenset((c.index,)))]
        if isinstance(c, Not):
            return _dnf(c.child)
        if isinstance(c, And):   # ¬(A∧B) = ¬A ∨ ¬B
            return _dnf(Or(tuple(Not(x) for x in c.children)))
        if isinstance(c, Or):    # ¬(A∨B) = ¬A ∧ ¬B
            return _dnf(And(tuple(Not(x) for x in c.children)))
        raise TypeError(c)
    if isinstance(p, Or):
        out: list[DnfTerm] = []
        for c in p.children:
            out.extend(_dnf(c))
        return out if p.children else [  # empty OR == false
        ]
    if isinstance(p, And):
        acc = [DnfTerm(frozenset(), frozenset())]
        for c in p.children:
            nxt: list[DnfTerm] = []
            for t1 in acc:
                for t2 in _dnf(c):
                    nxt.append(DnfTerm(t1.require | t2.require,
                                       t1.forbid | t2.forbid))
            acc = nxt
        return acc
    raise TypeError(p)


def dnf_equivalent(p: Pattern, terms: Sequence[DnfTerm],
                   n_labels: int) -> bool:
    """Brute-force equivalence check (used by property tests)."""
    labels = sorted(labels_of(p))
    for bits in itertools.product((False, True), repeat=len(labels)):
        present = frozenset(l for l, b in zip(labels, bits) if b)
        want = evaluate(p, present)
        got = any(t.satisfied_by(present) for t in terms)
        if want != got:
            return False
    return True

"""Append-only write-ahead log of ``GraphDelta`` batches.

The durability counterpart of ``repro.core.snapshot``: a snapshot pins
the expensive build at some log sequence number (LSN), and this log
records every graph update applied after it, so recovery is

    load latest valid snapshot  +  replay records with LSN > snapshot's
    through ``tdr_build.update_index``

which is bit-identical to a layout-pinned rebuild of the final graph
(the ``update_index`` contract).  Framing is crash-safe by construction:

* **File header.**  8-byte magic plus a CRC'd base LSN — the sequence
  number the log starts *after* (advanced by compaction), so an empty
  compacted log still knows its position in the sequence across
  restarts.
* **Record layout.**  ``magic u32 | header_crc u32 | lsn u64 |
  payload_len u32 | payload_crc u32 | payload`` — the header CRC covers
  ``(lsn, payload_len)`` so a flipped length byte can never silently
  misparse the stream, and the payload CRC covers the delta arrays.
* **Torn-tail truncation.**  Appends write sequentially, so a crash
  mid-append leaves a strict prefix of the record at the tail.  On open
  the log scans forward; an *incomplete* tail record (header shorter
  than 24 bytes, or a CRC-validated length that runs past EOF) is
  physically truncated away and every prior record replays.  Any other
  framing or CRC failure — a complete record that doesn't check out —
  raises ``LogCorrupt``: bit rot is detected, never replayed.
* **LSNs are dense and strictly increasing.**  ``append`` assigns
  ``last + 1`` (or validates a caller-provided LSN); the scanner rejects
  out-of-order records.  ``pop_tail`` removes exactly the newest record
  — the rollback hook for a write-ahead append whose apply was
  withdrawn — and ``truncate_upto`` drops the records a new snapshot
  has folded in (compaction), atomically.
* **fsync'd.**  Every append flushes and fsyncs before returning, so an
  acked update survives the process.
* **Multi-reader tailing.**  ``LogReader`` gives other *processes* a
  read-only cursor over the same file: replicas of a serving fleet tail
  the log a single writer appends to, each yielding exactly the records
  a recovering writer would replay as committed (torn in-flight appends
  are never yielded), and surviving ``truncate_upto`` compaction as
  long as their cursor is at or past the compaction point.

``append``/``replay`` speak ``(added, removed)`` int64 ``[N, 3]`` edge
arrays — exactly the effective-delta form of ``graph.GraphDelta``.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib

import numpy as np

FILE_MAGIC = b"TDRWAL\x01\n"
REC_MAGIC = 0x7D31A106
_HEAD = struct.Struct("<IIQII")   # magic, header_crc, lsn, plen, pcrc
_FHEAD = struct.Struct("<QI")     # base_lsn, crc(base_lsn)

# injectable I/O seams for the fault-injection harness
# (tests/faultinject.py patches these to fail/short-write/corrupt the
# Nth call; production code always goes through them)
_OPEN = open
_FSYNC = os.fsync


class LogCorrupt(RuntimeError):
    """A complete log record failed framing/CRC validation (bit rot,
    overwrite, or interleaved garbage) — replay must not proceed."""


class LogCompactedPast(RuntimeError):
    """A reader's cursor fell behind ``truncate_upto`` compaction: the
    records it still needs no longer exist.  The reader must
    re-bootstrap from a snapshot at or past the log's new base LSN."""


def _crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _head_crc(lsn: int, plen: int) -> int:
    return _crc(struct.pack("<QI", lsn, plen))


def _file_header(base_lsn: int) -> bytes:
    return FILE_MAGIC + _FHEAD.pack(base_lsn,
                                    _crc(struct.pack("<Q", base_lsn)))


def _encode_payload(added: np.ndarray, removed: np.ndarray) -> bytes:
    a = np.ascontiguousarray(added, dtype=np.int64).reshape(-1, 3)
    r = np.ascontiguousarray(removed, dtype=np.int64).reshape(-1, 3)
    return (struct.pack("<II", a.shape[0], r.shape[0])
            + a.tobytes() + r.tobytes())


def _decode_payload(data: bytes) -> tuple[np.ndarray, np.ndarray]:
    if len(data) < 8:
        raise LogCorrupt("log payload shorter than its counts")
    na, nr = struct.unpack_from("<II", data, 0)
    need = 8 + 24 * (na + nr)
    if len(data) != need:
        raise LogCorrupt(
            f"log payload length {len(data)} != declared {need}")
    a = np.frombuffer(data, dtype=np.int64, count=3 * na,
                      offset=8).reshape(na, 3)
    r = np.frombuffer(data, dtype=np.int64, count=3 * nr,
                      offset=8 + 24 * na).reshape(nr, 3)
    return a, r


def _encode_record(lsn: int, added, removed) -> bytes:
    payload = _encode_payload(np.asarray(added), np.asarray(removed))
    return _HEAD.pack(REC_MAGIC, _head_crc(lsn, len(payload)), lsn,
                      len(payload), _crc(payload)) + payload


@dataclasses.dataclass(frozen=True)
class LogRecord:
    lsn: int
    offset: int      # byte offset of the record header in the file
    length: int      # total record bytes (header + payload)
    added: np.ndarray
    removed: np.ndarray


class DeltaLog:
    """One append-only delta log file (see module docstring).

    Opening scans and validates the whole file: ``records`` holds every
    durable record in LSN order, ``truncated_bytes`` reports how much
    torn tail (if any) was cut.  The instance keeps the file handle open
    in append position; ``append``/``pop_tail``/``truncate_upto`` keep
    the in-memory record list and the file in lockstep.
    """

    def __init__(self, path: str, *, create: bool = True):
        self.path = path
        self.records: list[LogRecord] = []
        self.base_lsn = 0
        self.truncated_bytes = 0
        exists = os.path.exists(path)
        if not exists and not create:
            raise FileNotFoundError(path)
        if not exists:
            with _OPEN(path, "wb") as f:
                f.write(_file_header(0))
                f.flush()
                _FSYNC(f.fileno())
        self._scan()
        self._fh = _OPEN(path, "r+b")
        self._fh.seek(0, os.SEEK_END)

    # ------------------------------------------------------------- scan
    def _scan(self) -> None:
        with _OPEN(self.path, "rb") as f:
            data = f.read()
        hdr_len = len(FILE_MAGIC) + _FHEAD.size
        if len(data) < hdr_len:
            raise LogCorrupt("log file shorter than its header")
        if data[:len(FILE_MAGIC)] != FILE_MAGIC:
            raise LogCorrupt("bad magic: not a TDR delta log")
        base, bcrc = _FHEAD.unpack_from(data, len(FILE_MAGIC))
        if bcrc != _crc(struct.pack("<Q", base)):
            raise LogCorrupt("log base-LSN header failed its CRC")
        pos = hdr_len
        records: list[LogRecord] = []
        last_lsn = base
        self.truncated_bytes = 0
        while pos < len(data):
            remaining = len(data) - pos
            if remaining < _HEAD.size:
                break   # torn header at the tail
            magic, hcrc, lsn, plen, pcrc = _HEAD.unpack_from(data, pos)
            if magic != REC_MAGIC:
                raise LogCorrupt(
                    f"record at offset {pos}: bad record magic")
            if hcrc != _head_crc(lsn, plen):
                raise LogCorrupt(
                    f"record at offset {pos}: header failed its CRC")
            if _HEAD.size + plen > remaining:
                break   # torn payload at the tail (length is CRC-trusted)
            payload = data[pos + _HEAD.size:pos + _HEAD.size + plen]
            if _crc(payload) != pcrc:
                raise LogCorrupt(
                    f"record lsn={lsn} at offset {pos}: payload failed "
                    f"its CRC")
            if lsn != last_lsn + 1:
                raise LogCorrupt(
                    f"record at offset {pos}: LSN {lsn} after "
                    f"{last_lsn} (log must be dense and increasing)")
            added, removed = _decode_payload(payload)
            records.append(LogRecord(lsn=int(lsn), offset=pos,
                                     length=_HEAD.size + plen,
                                     added=added, removed=removed))
            last_lsn = int(lsn)
            pos += _HEAD.size + plen
        if pos < len(data):
            # physically drop the torn tail so appends restart cleanly
            self.truncated_bytes = len(data) - pos
            with _OPEN(self.path, "r+b") as f:
                f.truncate(pos)
                f.flush()
                _FSYNC(f.fileno())
        self.base_lsn = int(base)
        self.records = records

    # ------------------------------------------------------------ state
    @property
    def last_lsn(self) -> int:
        return self.records[-1].lsn if self.records else self.base_lsn

    def __len__(self) -> int:
        return len(self.records)

    # ----------------------------------------------------------- append
    def append(self, added, removed, *, lsn: int | None = None) -> int:
        """Durably append one delta; returns its LSN.

        The record is fully written, flushed, and fsync'd before this
        returns — write-ahead ordering means callers append *before*
        applying the update to any served state.  On any I/O failure the
        file is rolled back (best effort) to the pre-append length so
        the live log never carries a half-record, and the exception
        propagates.
        """
        nxt = self.last_lsn + 1
        if lsn is None:
            lsn = nxt
        elif lsn != nxt:
            raise ValueError(f"append lsn {lsn} != expected {nxt}")
        rec = _encode_record(lsn, added, removed)
        off = self._fh.tell()
        try:
            self._fh.write(rec)
            self._fh.flush()
            _FSYNC(self._fh.fileno())
        except BaseException:
            try:    # keep the live handle consistent after a failed write
                self._fh.truncate(off)
                self._fh.seek(off)
            except OSError:
                pass
            raise
        a, r = _decode_payload(rec[_HEAD.size:])
        self.records.append(LogRecord(lsn=lsn, offset=off,
                                      length=len(rec), added=a,
                                      removed=r))
        return lsn

    def pop_tail(self, lsn: int) -> None:
        """Remove the newest record iff it carries ``lsn`` — the
        rollback for a write-ahead append whose apply was withdrawn
        (e.g. an update barrier that timed out before the swap)."""
        if not self.records or self.records[-1].lsn != lsn:
            raise ValueError(
                f"pop_tail({lsn}): tail is "
                f"{self.records[-1].lsn if self.records else None}")
        rec = self.records.pop()
        self._fh.truncate(rec.offset)
        self._fh.seek(rec.offset)
        self._fh.flush()
        _FSYNC(self._fh.fileno())

    # ----------------------------------------------------------- replay
    def replay(self, after_lsn: int = 0):
        """Yield ``(lsn, added, removed)`` for records with
        ``lsn > after_lsn``, in order."""
        for rec in self.records:
            if rec.lsn > after_lsn:
                yield rec.lsn, rec.added, rec.removed

    # ------------------------------------------------------- compaction
    def truncate_upto(self, lsn: int) -> int:
        """Drop records with LSN <= ``lsn`` (a new snapshot folded them
        in) and advance the base LSN; returns how many were dropped.
        Atomic: the survivors are rewritten to a temp file that replaces
        the log."""
        lsn = min(int(lsn), self.last_lsn)
        if lsn <= self.base_lsn:
            return 0
        keep = [r for r in self.records if r.lsn > lsn]
        n_before = len(self.records)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        try:
            with _OPEN(tmp, "wb") as f:
                f.write(_file_header(lsn))
                for rec in keep:
                    f.write(_encode_record(rec.lsn, rec.added,
                                           rec.removed))
                f.flush()
                _FSYNC(f.fileno())
            self._fh.close()
            self._fh = None
            os.replace(tmp, self.path)
        finally:
            # whichever version survived on disk (the replace is atomic),
            # rescan it and leave the instance with a live append handle
            # — a failed compaction must not brick the log
            if os.path.exists(tmp):
                os.unlink(tmp)
            if self._fh is None:
                self._scan()
                self._fh = _OPEN(self.path, "r+b")
                self._fh.seek(0, os.SEEK_END)
        return n_before - len(keep)

    # ---------------------------------------------------------- cleanup
    def close(self) -> None:
        if getattr(self, "_fh", None) is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "DeltaLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LogReader:
    """Read-only tailing cursor over a ``DeltaLog`` file — the
    multi-process counterpart of ``DeltaLog.replay`` for replicas that
    follow a log another process is appending to.

    The reader never mutates the file: it re-reads and re-validates on
    every ``poll`` (logs stay small under compaction, so the simplicity
    is worth the O(file) scan) and yields exactly the records a
    recovering *writer* would replay as committed:

    * A record is yielded only once its framing and both CRCs validate
      and its LSN extends the dense sequence — the same acceptance rule
      as ``DeltaLog._scan``.
    * A **torn tail** (a record the writer is still appending, or that a
      writer crash left half-written) is never yielded: an incomplete
      header, a CRC-trusted length running past EOF, or a payload-CRC
      failure *at end of file* all read as "in progress" and the poll
      simply stops there.  A payload-CRC failure with further bytes
      behind it cannot be an in-flight append and raises ``LogCorrupt``.
    * **Compaction-safe.**  ``truncate_upto`` atomically replaces the
      file; the reader detects the new base LSN and resumes at its
      cursor — records above the compaction point are yielded exactly
      once.  If compaction advanced *past* the cursor the needed records
      are gone and ``poll`` raises ``LogCompactedPast`` (re-bootstrap
      from a snapshot).
    * A log whose tip *retreated* below the cursor with the same base
      (the writer rolled back via ``pop_tail`` a record this reader
      already consumed) raises ``LogCorrupt`` — single-writer fleets
      must treat ``append`` as commit for reader correctness.

    ``seek(after_lsn)`` repositions the cursor (e.g. to re-deliver a
    record whose apply failed)."""

    def __init__(self, path: str, *, after_lsn: int = 0):
        self.path = path
        self.lsn = int(after_lsn)   # last consumed LSN (cursor)
        self.base_lsn = 0
        self.last_seen_lsn = 0      # log tip observed by the last poll
        self._probe()               # validate header + learn base_lsn

    def _probe(self) -> None:
        """Validate the file header and refresh ``base_lsn`` without
        touching the cursor — safe on a log compacted past the cursor
        (callers pick a snapshot >= ``base_lsn``, then ``seek``)."""
        with open(self.path, "rb") as f:
            head = f.read(len(FILE_MAGIC) + _FHEAD.size)
        if len(head) < len(FILE_MAGIC) + _FHEAD.size:
            raise LogCorrupt("log file shorter than its header")
        if head[:len(FILE_MAGIC)] != FILE_MAGIC:
            raise LogCorrupt("bad magic: not a TDR delta log")
        base, bcrc = _FHEAD.unpack_from(head, len(FILE_MAGIC))
        if bcrc != _crc(struct.pack("<Q", base)):
            raise LogCorrupt("log base-LSN header failed its CRC")
        self.base_lsn = int(base)

    def seek(self, after_lsn: int) -> None:
        """Reposition the cursor: the next ``poll`` re-delivers records
        with LSN > ``after_lsn``."""
        self.lsn = int(after_lsn)

    def poll(self, max_records: int | None = None
             ) -> list[tuple[int, np.ndarray, np.ndarray]]:
        """Return ``(lsn, added, removed)`` for every durable record
        beyond the cursor (possibly none), advancing the cursor past
        what is returned."""
        with open(self.path, "rb") as f:
            data = f.read()
        hdr_len = len(FILE_MAGIC) + _FHEAD.size
        if len(data) < hdr_len:
            raise LogCorrupt("log file shorter than its header")
        if data[:len(FILE_MAGIC)] != FILE_MAGIC:
            raise LogCorrupt("bad magic: not a TDR delta log")
        base, bcrc = _FHEAD.unpack_from(data, len(FILE_MAGIC))
        if bcrc != _crc(struct.pack("<Q", base)):
            raise LogCorrupt("log base-LSN header failed its CRC")
        self.base_lsn = int(base)
        if base > self.lsn:
            raise LogCompactedPast(
                f"log compacted to base {base} past reader cursor "
                f"{self.lsn}")
        out: list[tuple[int, np.ndarray, np.ndarray]] = []
        pos = hdr_len
        prev = int(base)
        while pos < len(data):
            remaining = len(data) - pos
            if remaining < _HEAD.size:
                break   # in-flight append: torn header
            magic, hcrc, lsn, plen, pcrc = _HEAD.unpack_from(data, pos)
            if magic != REC_MAGIC:
                raise LogCorrupt(
                    f"record at offset {pos}: bad record magic")
            if hcrc != _head_crc(lsn, plen):
                raise LogCorrupt(
                    f"record at offset {pos}: header failed its CRC")
            end = pos + _HEAD.size + plen
            if end > len(data):
                break   # in-flight append: torn payload
            payload = data[pos + _HEAD.size:end]
            if _crc(payload) != pcrc:
                if end == len(data):
                    # contents may lag the visible file length while the
                    # writer's single append is still landing — wait
                    break
                raise LogCorrupt(
                    f"record lsn={lsn} at offset {pos}: payload failed "
                    f"its CRC mid-log")
            if lsn != prev + 1:
                raise LogCorrupt(
                    f"record at offset {pos}: LSN {lsn} after {prev} "
                    f"(log must be dense and increasing)")
            prev = int(lsn)
            if lsn > self.lsn and \
                    (max_records is None or len(out) < max_records):
                out.append((int(lsn), *_decode_payload(payload)))
            pos = end
        if prev < self.lsn:
            raise LogCorrupt(
                f"log tip {prev} retreated below reader cursor "
                f"{self.lsn} (pop_tail under an active reader?)")
        self.last_seen_lsn = prev
        if out:
            self.lsn = out[-1][0]
        return out

"""Checksummed snapshot serialization of a built ``TDRIndex``.

The TDR build is the expensive step of the whole system (full
label×vertex reachability, §IV), which makes losing a built index on
restart the most expensive failure a serving fleet can have.  This
module makes the index a durable on-disk artifact:

* **Versioned container.**  An 8-byte magic + u32 format version guard
  the file; a future layout change bumps ``VERSION`` and old readers
  raise ``SnapshotVersionMismatch`` instead of misparsing.
* **Per-section CRC.**  Every array travels as its own section with a
  crc32 recorded in the header; the header itself is CRC'd, and the
  magic/version words are covered by plain equality.  A truncated,
  bit-flipped, or torn-renamed file is *detected* (``SnapshotCorrupt``)
  — never silently served.
* **Compressed planes.**  Index planes are stored in the two-level
  compressed form of ``repro.core.compressed`` (2-bit row/word states +
  mixed-word pool), so snapshots inherit the ~4.5–5x size win over the
  dense packed planes, and ``load_index`` seeds the index's
  compressed-plane cache from the very objects it read — the restored
  index starts with its summary flags and memory stats for free.
* **Maintenance state included.**  ``disc`` (the frozen hash layout),
  the one-hop base planes, converged closures, and vertical planes all
  round-trip, so a restored index chains ``tdr_build.update_index``
  exactly like the index that was saved: snapshot + delta-log replay
  (``repro.core.deltalog``) is bit-identical to a layout-pinned rebuild
  of the final graph.
* **Atomic writes.**  ``save_index`` writes to a temp file, fsyncs,
  then renames into place (and fsyncs the directory), so a crash during
  save leaves either the old snapshot or the new one — never a partial
  file under the final name.

``save_index(index, path, lsn=...)`` / ``load_index(path)`` are the
whole API; the ``lsn`` rides in the header so recovery knows which
delta-log records are already folded in.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib

import jax.numpy as jnp
import numpy as np

from . import compressed as compressed_mod
from .graph import Graph
from .tdr_build import TDRConfig, TDRIndex

MAGIC = b"TDRSNAP\x01"
VERSION = 1

# injectable I/O seams for the fault-injection harness
# (tests/faultinject.py patches these to fail/short-write/corrupt the
# Nth call; production code always goes through them)
_OPEN = open
_FSYNC = os.fsync


class SnapshotError(RuntimeError):
    """Base class of every typed snapshot failure."""


class SnapshotCorrupt(SnapshotError):
    """Magic/CRC/length validation failed: the file is truncated, bit-
    flipped, or not a snapshot at all.  Never load past this."""


class SnapshotVersionMismatch(SnapshotError):
    """The file is a well-formed snapshot of an incompatible format
    version (or config schema) — rebuild or migrate, don't guess."""


# every plane a snapshot may carry (the union of TDRIndex.plane_specs
# and TDRIndex.aux_plane_specs keys) — load rejects anything else
_PLANE_NAMES = frozenset({
    "h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in",
    "r_vtx", "r_lab", "r_in",
    "base_v", "base_l", "base_r", "d_vtx", "d_lab",
})


def _crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


class _Writer:
    """Accumulates named array sections; each remembers dtype, shape,
    byte offset (into the payload), length, and crc32."""

    def __init__(self):
        self.sections: list[dict] = []
        self.chunks: list[bytes] = []
        self.offset = 0

    def add(self, name: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        data = arr.tobytes()
        self.sections.append({
            "name": name, "dtype": arr.dtype.str,
            "shape": list(arr.shape), "offset": self.offset,
            "length": len(data), "crc": _crc(data)})
        self.chunks.append(data)
        self.offset += len(data)


class _Reader:
    """Validated section reads out of an in-memory payload."""

    def __init__(self, payload: bytes, sections: list[dict]):
        self.payload = payload
        self.by_name = {s["name"]: s for s in sections}

    def get(self, name: str) -> np.ndarray:
        sec = self.by_name.get(name)
        if sec is None:
            raise SnapshotCorrupt(f"snapshot is missing section {name!r}")
        lo, hi = sec["offset"], sec["offset"] + sec["length"]
        if hi > len(self.payload):
            raise SnapshotCorrupt(
                f"section {name!r} extends past end of file "
                f"(truncated snapshot?)")
        data = self.payload[lo:hi]
        if _crc(data) != sec["crc"]:
            raise SnapshotCorrupt(f"section {name!r} failed its CRC check")
        return np.frombuffer(data, dtype=np.dtype(sec["dtype"])).reshape(
            sec["shape"])


def _compressed_sections(w: _Writer, name: str,
                         c: compressed_mod.CompressedPlanes) -> dict:
    """Emit one compressed plane as five array sections + header meta."""
    w.add(f"{name}.row_states", c.row_states)
    w.add(f"{name}.mix_rows", c.mix_rows)
    w.add(f"{name}.word_states", c.word_states)
    w.add(f"{name}.pool", c.pool)
    w.add(f"{name}.pool_off", c.pool_off)
    return {"shape": list(c.shape), "nbits": c.nbits}


def _read_compressed(r: _Reader, name: str,
                     meta: dict) -> compressed_mod.CompressedPlanes:
    return compressed_mod.CompressedPlanes(
        shape=tuple(meta["shape"]), nbits=int(meta["nbits"]),
        row_states=r.get(f"{name}.row_states"),
        mix_rows=r.get(f"{name}.mix_rows"),
        word_states=r.get(f"{name}.word_states"),
        pool=r.get(f"{name}.pool"),
        pool_off=r.get(f"{name}.pool_off"))


def save_index(index: TDRIndex, path: str, *, lsn: int = 0) -> int:
    """Serialize ``index`` to ``path`` atomically; returns bytes written.

    ``lsn`` marks the last delta-log sequence number already folded into
    these planes (0 for a fresh build): recovery replays only records
    with a greater LSN.  Planes are stored two-level compressed; the
    maintenance state (``disc``, base/closure/vertical planes) rides
    along when present so the restored index updates incrementally.
    """
    w = _Writer()
    g = index.graph
    for name in ("indptr", "indices", "labels"):
        w.add(name, getattr(g, name))
    for name in ("push", "pop", "g_count"):
        w.add(name, np.asarray(getattr(index, name)))
    w.add("vtx_words", index.vtx_words)
    w.add("lab_slot", index.lab_slot)
    if index.disc is not None:
        w.add("disc", np.asarray(index.disc, dtype=np.int32))

    comp_cache = index.compressed_planes()   # h_vtx..r_in, canonical form
    specs = dict(index.plane_specs())
    specs.update(index.aux_plane_specs())
    planes: dict = {}
    for name, (arr, nbits) in specs.items():
        c = comp_cache.get(name) or compressed_mod.compress(
            np.asarray(arr), nbits=nbits)
        planes[name] = _compressed_sections(w, name, c)

    header = {
        "version": VERSION,
        "lsn": int(lsn),
        "cfg": dataclasses.asdict(index.cfg),
        "graph": {"n_vertices": g.n_vertices, "n_labels": g.n_labels},
        "fixpoint_rounds": int(index.fixpoint_rounds),
        "planes": planes,
        "sections": w.sections,
    }
    hdr = json.dumps(header, separators=(",", ":")).encode()
    blob = (MAGIC + struct.pack("<III", VERSION, len(hdr), _crc(hdr))
            + hdr + b"".join(w.chunks))

    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with _OPEN(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            _FSYNC(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".",
                  os.O_RDONLY)
    try:
        _FSYNC(dfd)
    finally:
        os.close(dfd)
    return len(blob)


def peek_lsn(path: str) -> int:
    """Header-only read: the LSN a snapshot was taken at (validated
    magic/version/header CRC, but section payloads untouched)."""
    with _OPEN(path, "rb") as f:
        data = f.read()
    header, _ = _parse_header(data)
    return int(header["lsn"])


def _parse_header(data: bytes) -> tuple[dict, bytes]:
    if len(data) < len(MAGIC) + 12:
        raise SnapshotCorrupt("file too short to be a snapshot")
    if data[:len(MAGIC)] != MAGIC:
        raise SnapshotCorrupt("bad magic: not a TDR snapshot")
    version, hlen, hcrc = struct.unpack_from("<III", data, len(MAGIC))
    if version != VERSION:
        raise SnapshotVersionMismatch(
            f"snapshot format v{version}, this reader is v{VERSION}")
    off = len(MAGIC) + 12
    hdr = data[off:off + hlen]
    if len(hdr) < hlen:
        raise SnapshotCorrupt("truncated snapshot header")
    if _crc(hdr) != hcrc:
        raise SnapshotCorrupt("snapshot header failed its CRC check")
    try:
        header = json.loads(hdr)
    except ValueError as e:
        raise SnapshotCorrupt(f"unparseable snapshot header: {e}") from e
    if header.get("version") != VERSION:
        raise SnapshotVersionMismatch(
            "header/trailer version disagreement")
    return header, data[off + hlen:]


def load_index(path: str) -> tuple[TDRIndex, int]:
    """Deserialize a snapshot; returns ``(index, lsn)``.

    Every section is CRC-validated before use; any mismatch raises
    ``SnapshotCorrupt`` (or ``SnapshotVersionMismatch`` for a format
    bump) — a damaged snapshot is *never* partially loaded.  The
    restored index is bit-identical to the one saved, carries the full
    maintenance state, and starts with its compressed-plane cache
    populated from the sections just read.
    """
    with _OPEN(path, "rb") as f:
        data = f.read()
    header, payload = _parse_header(data)
    r = _Reader(payload, header["sections"])

    try:
        cfg = TDRConfig(**header["cfg"])
    except TypeError as e:
        raise SnapshotVersionMismatch(
            f"snapshot config schema mismatch: {e}") from e
    gmeta = header["graph"]
    graph = Graph(int(gmeta["n_vertices"]), int(gmeta["n_labels"]),
                  r.get("indptr"), r.get("indices"), r.get("labels"))
    if graph.indptr.shape != (graph.n_vertices + 1,):
        raise SnapshotCorrupt("graph indptr shape mismatch")

    planes_meta = header["planes"]
    dense: dict = {}
    comp: dict = {}
    for name, meta in planes_meta.items():
        if name not in _PLANE_NAMES:
            raise SnapshotCorrupt(f"unknown plane section {name!r}")
        c = _read_compressed(r, name, meta)
        dense[name] = c.decompress()
        comp[name] = c
    for name in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"):
        if name not in dense:
            raise SnapshotCorrupt(f"snapshot is missing plane {name!r}")

    def dev(name):
        return jnp.asarray(dense[name]) if name in dense else None

    idx = TDRIndex(
        cfg=cfg, graph=graph,
        h_vtx=dev("h_vtx"), h_lab=dev("h_lab"),
        v_vtx=dev("v_vtx"), v_lab=dev("v_lab"),
        n_out=dev("n_out"), n_in=dev("n_in"),
        push=jnp.asarray(r.get("push")), pop=jnp.asarray(r.get("pop")),
        g_count=jnp.asarray(r.get("g_count")),
        vtx_words=r.get("vtx_words"), lab_slot=r.get("lab_slot"),
        fixpoint_rounds=int(header.get("fixpoint_rounds", 0)),
        disc=(r.get("disc") if "disc" in r.by_name else None),
        base_v=dev("base_v"), base_l=dev("base_l"), base_r=dev("base_r"),
        r_vtx=dev("r_vtx"), r_lab=dev("r_lab"), r_in=dev("r_in"),
        d_vtx=dev("d_vtx"), d_lab=dev("d_lab"))
    # seed the compressed-plane cache with the canonical objects we just
    # validated — only the planes plane_specs() tracks (the maintenance
    # planes are not part of the cached/query-visible set)
    idx._comp = {k: v for k, v in comp.items()
                 if k in idx.plane_specs()}
    return idx, int(header["lsn"])

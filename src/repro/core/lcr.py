"""LCR queries on top of the PCR engine (+ a P2H-style full-index baseline).

LCR(u, v, A) — "is v reachable from u using only labels in A?" — translates
to the PCR pattern ``⋀_{l ∉ A} ¬l`` (paper §VI-C translates the other way
round when comparing against P2H+/PDU).  The baseline here, ``P2HLite``,
mirrors what P2H+ stores: for every vertex the full set of reachable
vertices together with the *minimal* label sets of connecting paths.  It is
exact and O(1)-ish at query time but exponential to build — which is the
paper's whole point, and exactly what ``benchmarks/index_cost.py`` measures.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Sequence

import numpy as np

from . import pattern as pat
from .graph import Graph
from .tdr_build import TDRIndex
from . import tdr_query


def answer_lcr_batch(index: TDRIndex,
                     queries: Sequence[tuple[int, int, Sequence[int]]],
                     **kw) -> np.ndarray:
    """Answer LCR queries (u, v, allowed-labels) via the PCR engine."""
    n_labels = index.graph.n_labels
    pcr = [(u, v, pat.lcr(sorted(allowed), n_labels))
           for (u, v, allowed) in queries]
    return tdr_query.answer_batch(index, pcr, **kw)


# ------------------------------------------------------------ P2H baseline
def _minimal(sets: set[FrozenSet[int]]) -> set[FrozenSet[int]]:
    out: set[FrozenSet[int]] = set()
    for s in sorted(sets, key=len):
        if not any(t < s or t == s for t in out):
            out.add(s)
    return out


@dataclasses.dataclass
class P2HLite:
    """Full reachability index: per source, minimal label sets per target.

    ``out[u][v]`` = antichain of minimal label sets of u→v paths.  Build is
    a label-set worklist fixpoint — complete, and deliberately as expensive
    as full indices are (the paper's Table IV story).
    """
    out: list[dict[int, set[FrozenSet[int]]]]

    @staticmethod
    def build(graph: Graph, max_sets_per_pair: int = 64) -> "P2HLite":
        v_n = graph.n_vertices
        out: list[dict[int, set[FrozenSet[int]]]] = [dict() for _ in range(v_n)]
        # initialise with direct edges
        work = set()
        for u in range(v_n):
            dsts, labs = graph.out_edges(u)
            for v, l in zip(dsts.tolist(), labs.tolist()):
                s = frozenset((l,))
                cur = out[u].setdefault(v, set())
                if s not in cur:
                    cur.add(s)
                    work.add(u)
            for v in out[u]:
                out[u][v] = _minimal(out[u][v])
        # propagate: out[u] ← minimal(out[u] ∪ {l∪s : (u,w,l), s∈out[w]})
        rev = graph.reverse()
        changed = True
        while changed:
            changed = False
            for u in range(v_n):
                dsts, labs = graph.out_edges(u)
                new: dict[int, set[FrozenSet[int]]] = {}
                for w, l in zip(dsts.tolist(), labs.tolist()):
                    for v, sets in out[w].items():
                        for s in sets:
                            ns = s | {l}
                            new.setdefault(v, set()).add(ns)
                for v, sets in new.items():
                    cur = out[u].setdefault(v, set())
                    before = frozenset(cur)
                    merged = _minimal(cur | sets)
                    if len(merged) > max_sets_per_pair:
                        merged = set(sorted(merged, key=len)
                                     [:max_sets_per_pair])
                    if frozenset(merged) != before:
                        out[u][v] = merged
                        changed = True
        return P2HLite(out)

    def query(self, u: int, v: int, allowed: Sequence[int]) -> bool:
        if u == v:
            return True      # empty path (matches the PCR semantics)
        a = frozenset(allowed)
        return any(s <= a for s in self.out[u].get(v, ()))

    def size_bytes(self) -> int:
        total = 0
        for d in self.out:
            for v, sets in d.items():
                total += 8  # vertex id + header
                total += sum(8 + 4 * len(s) for s in sets)
        return total

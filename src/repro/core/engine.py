"""Packed-word semiring closure engine — one core shared by build & query.

Everything the TDR pipeline computes — index construction (§IV Alg. 1),
vertical k-level propagation, and the query-side product-graph expansion
(§V Alg. 2) — is one primitive applied in different shapes:

    out[a] = (+)_{(a,b) ∈ E} extend(x[b])     (semiring propagate)

The fixpoint/propagate cores are parameterized by a ``repro.core.semiring``
instance (static under jit, so each algebra compiles its own
specialization).  The default — and the only carrier the index planes
use — is ``semiring.BOOLEAN``: packed uint32 words, (+) = OR, extend =
identity, whose generic code path emits *exactly* the traced ops of the
pre-refactor OR engine (bit-identity asserted in tests/test_semiring.py).
``DIST16``/``DIST8`` (min-plus over saturating unsigned lanes) and
``COUNT`` (saturating add, non-idempotent — ``closure`` refuses it) drive
the witness/distance/route-count query kinds in ``tdr_query``.

This module provides the primitive **end-to-end on packed uint32 words**
for the boolean carrier (32 graph bits per lane element; no ``[V, nbits]``
boolean plane at rest) behind a pluggable backend:

* ``segment`` — reference backend; chunked ``segment_max`` over word-chunk
  transients (``bitset.segment_or_words``).  Works on any jax backend and
  any graph size; the default off-TPU.
* ``pallas``  — routes every fixpoint round / frontier expansion through
  ``repro.kernels.bitset_matmul`` on a packed adjacency bit-matrix
  (``[V, ceil(V/32)]`` uint32, bit j of row i == edge i→j).  Real kernel on
  TPU, interpret mode elsewhere.  Dense ``V×V/8`` bytes, so the engine
  auto-falls back to ``segment`` above ``EngineConfig.max_dense_bytes``.

Backend selection contract (see ARCHITECTURE.md):

1. An explicitly requested backend ("segment" | "pallas") always wins.
2. The ``REPRO_ENGINE_BACKEND`` environment variable replaces the default
   resolution when the request is "auto"/unset.
3. "auto" resolves to ``pallas`` on TPU, ``segment`` elsewhere.
4. A ``pallas`` request that cannot be honoured (adjacency over the dense
   cap) falls back to ``segment`` with a warning — never an error.

Both backends are bit-exact (property-tested against each other and the
bool-plane oracle in ``tests/test_engine.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from .compressed import BlockCompressed, compress_blocks, patch_blocks
from .graph import Graph, csr_row_edges, pad_bucket
from .semiring import BOOLEAN, Semiring

ENV_BACKEND = "REPRO_ENGINE_BACKEND"
BACKENDS = ("segment", "pallas")


def resolve_backend(requested: str = "auto") -> str:
    """Resolve a backend name per the selection contract above.

    The ``REPRO_ENGINE_BACKEND`` environment variable replaces the
    *default* ("auto"/empty) resolution only — an explicitly requested
    backend wins, so backend sweeps and bit-equality comparisons cannot be
    silently collapsed onto one backend by ambient environment."""
    req = requested or "auto"
    if req == "auto":
        req = os.environ.get(ENV_BACKEND, "").strip() or "auto"
    if req == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "segment"
    if req not in BACKENDS:
        raise ValueError(
            f"unknown engine backend {req!r}; expected one of "
            f"{('auto',) + BACKENDS}")
    return req


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "auto"        # "auto" | "segment" | "pallas"
    bit_chunk: int = 64          # transient chunk width (bits) for segment ORs
    interpret: bool | None = None  # pallas interpret; None -> off-TPU only
    max_dense_bytes: int = 1 << 28  # pallas dense-adjacency cap (auto-fallback)
    sparse: bool = True          # block-sparse closure fixpoints (both backends)
    block_rows: int = 8          # row-block height of the block-sparse operand
    block_words: int = 1         # word-block width  (8x1 = 8x32-bit blocks)
    sparse_dense_frac: float = 0.5  # segment: frontier fraction -> dense round

    @property
    def chunk_words(self) -> int:
        return max(1, self.bit_chunk // bitset.WORD)


# ------------------------------------------------------- adjacency packing
def pack_adjacency_np(graph: Graph, *, reverse: bool = False) -> np.ndarray:
    """Packed adjacency bit-matrix uint32 ``[V, ceil(V/32)]``.

    Forward: bit v of row u == edge u→v (the closure/propagate operand).
    Reverse: bit u of row v == edge u→v.
    """
    v_n = graph.n_vertices
    kw = bitset.n_words(v_n)
    a = np.zeros((v_n, kw), dtype=np.uint32)
    src, dst = graph.src, graph.indices
    rows, cols = (dst, src) if reverse else (src, dst)
    bitset.set_bits_np(a, (rows,), cols)
    return a


def pack_label_class_edges_np(src: np.ndarray, dst: np.ndarray,
                              labels: np.ndarray, n_vertices: int,
                              special_labels, *,
                              reverse: bool = True) -> np.ndarray:
    """Per-label-class packed adjacency ``[C+1, V, ceil(V/32)]`` from raw
    edge arrays (used for per-chunk corridor-compacted subgraphs as well
    as the whole graph).

    One bit-matrix per *special* label (labels that some pending query
    requires or forbids) plus a final **neutral** class OR-ing every edge
    whose label is special for nobody — those edges behave identically for
    all queries (always allowed, subset-bit 0), so one matmul covers them.
    """
    kw = bitset.n_words(n_vertices)
    special = list(special_labels)
    out = np.zeros((len(special) + 1, n_vertices, kw), dtype=np.uint32)
    rows, cols = (dst, src) if reverse else (src, dst)
    cls = np.full(labels.shape[0], len(special), dtype=np.int64)
    for i, l in enumerate(special):
        cls[labels == l] = i
    bitset.set_bits_np(out, (cls, rows), cols)
    return out


def pack_label_class_adjacency_np(graph: Graph, special_labels,
                                  *, reverse: bool = True) -> np.ndarray:
    """Whole-graph wrapper over ``pack_label_class_edges_np``."""
    return pack_label_class_edges_np(graph.src, graph.indices, graph.labels,
                                     graph.n_vertices, special_labels,
                                     reverse=reverse)


# --------------------------------------------------------- jitted closures
@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_words",
                                             "max_iters", "sr"))
def _closure_segment(base: jax.Array, gather_idx: jax.Array,
                     scatter_idx: jax.Array, *, num_segments: int,
                     chunk_words: int, max_iters: int,
                     sr: Semiring = BOOLEAN):
    """lfp(R = base (+) A⊗R) via packed segment reductions.

    ``sr`` is static: the boolean instantiation traces the exact
    pre-refactor ops (``segment_or_words`` + the ``upd & ~r`` changed-flag
    idiom live inside ``sr.segment_combine``/``sr.accumulate``)."""

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        upd = sr.segment_combine(sr.extend(r[gather_idx]), scatter_idx,
                                 num_segments=num_segments,
                                 chunk_words=chunk_words)
        # boolean: the changed flag falls out of the round's own OR
        r, changed = sr.accumulate(r, upd)
        return r, changed, it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


def _matmul_rows(adj: jax.Array, x: jax.Array, mode: str,
                 tiles: tuple[int, int, int] | None = None,
                 sr: Semiring = BOOLEAN) -> jax.Array:
    """``(+)_j adj[i,j] (x) x[j]`` with x's row count padded to adj's bit
    width (the packed adjacency is word-aligned: K = ceil(V/32)*32 >= V;
    pad rows carry no adjacency bits, so the pad value never selects)."""
    from repro.kernels import ops  # deferred: kernels import repro.core
    k = adj.shape[1] * bitset.WORD
    if x.shape[0] < k:
        x = jnp.concatenate(
            [x, jnp.zeros((k - x.shape[0],) + x.shape[1:], x.dtype)], axis=0)
    if sr.packed:
        return ops.frontier_step(adj, x, mode=mode, tiles=tiles)
    return sr.extend(ops.frontier_step_lanes(adj, x, op=sr.op, cap=sr.cap,
                                             mode=mode, tiles=tiles))


@functools.partial(jax.jit, static_argnames=("max_iters", "mode", "sr"))
def _closure_matmul(base: jax.Array, adj: jax.Array, *, max_iters: int,
                    mode: str, sr: Semiring = BOOLEAN):
    """Same fixpoint with rounds routed through the Pallas kernels
    (``bitset_matmul`` for the packed boolean carrier, ``lane_matmul``
    for lane carriers)."""

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        upd = _matmul_rows(adj, r, mode, sr=sr)
        # boolean: the changed flag falls out of the round's own OR
        r, changed = sr.accumulate(r, upd)
        return r, changed, it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


@functools.partial(jax.jit, static_argnames=("mode", "max_iters"))
def _closure_blocksparse(base: jax.Array, comp: BlockCompressed, *,
                         mode: str, max_iters: int):
    """Delta-form fixpoint over the block-compressed adjacency.

    Each round expands only the *newly set* rows (``new``): since the lfp
    is unique and OR distributes, ``R ∨ A⊗new`` reaches the same fixpoint
    as ``R ∨ A⊗R`` — and a shrinking frontier means the per-round k-block
    any-bit summary goes dark block by block, which is exactly what the
    kernel's ZERO/dead-block skip turns into saved work."""
    from repro.kernels import ops  # deferred: kernels import repro.core

    def expand(x):  # x row-padding to the block grid happens in the kernel
        return ops.frontier_step_sparse(comp, x, mode=mode)

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, new, _, it = state
        nxt = expand(new) & ~r
        return r | nxt, nxt, jnp.any(nxt != 0), it + 1

    r, _, _, rounds = jax.lax.while_loop(
        cond, body, (base, base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_words",
                                             "max_iters", "max_active"))
def _closure_segment_until_sparse(base: jax.Array, gather_idx: jax.Array,
                                  scatter_idx: jax.Array, *,
                                  num_segments: int, chunk_words: int,
                                  max_iters: int, max_active: int):
    """Dense segment rounds in ONE jitted while_loop, exiting early once
    the frontier (rows with fresh bits) shrinks to ``max_active`` rows.

    The host frontier loop pays a device→host sync every round to learn
    the active set; while the frontier covers most of the graph those
    syncs cost more than the edge work they could save, so this stage
    burns through the high-occupancy rounds sync-free and hands the
    small-frontier tail (``(r, new, rounds)``) to the compacted gathers.
    """

    def cond(state):
        _, _, n_act, it = state
        return jnp.logical_and(n_act > max_active, it < max_iters)

    def body(state):
        r, _, _, it = state
        upd = bitset.segment_or_words(r[gather_idx], scatter_idx,
                                      num_segments=num_segments,
                                      chunk_words=chunk_words)
        new = upd & ~r
        n_act = jnp.sum(jnp.any(new != 0, axis=-1).astype(jnp.int32))
        return r | new, new, n_act, it + 1

    r, new, _, rounds = jax.lax.while_loop(
        cond, body,
        (base, base, jnp.int32(num_segments + 1), jnp.int32(0)))
    return r, new, rounds


@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_words"))
def _sparse_segment_round(x: jax.Array, gather_idx: jax.Array,
                          scatter_idx: jax.Array, *, num_segments: int,
                          chunk_words: int) -> jax.Array:
    """One frontier-compacted semiring round: gather/scatter over the
    *active* edge subset only.  Padding slots gather a zero row (index
    ``V`` of the extended table) and scatter to the dropped out-of-range
    segment, so bucket-padded edge counts keep jit signatures stable."""
    x_ext = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    return bitset.segment_or_words(x_ext[gather_idx], scatter_idx,
                                   num_segments=num_segments,
                                   chunk_words=chunk_words)


# ------------------------------------------------- mesh-aware entry points
# These run *inside* ``shard_map`` blocks (repro.core.distributed): the
# vertex dimension is 1-D partitioned over the flattened mesh axes, each
# device owns a contiguous block of rows, and the only cross-device traffic
# is the all_gather of the packed uint32 closure words — no ``[V, nbits]``
# boolean plane ever crosses devices.


def all_gather_words(x_local: jax.Array, axis_names) -> jax.Array:
    """Gather shard-local packed rows into the full table ``[V, W]``.

    Gathers the innermost mesh axis first so the flattened ordering matches
    the axis-major shard numbering of a ``P(axis_names)`` leading-dim spec.
    The payload stays packed uint32 end-to-end.
    """
    full = x_local
    for ax in reversed(tuple(axis_names)):
        full = jax.lax.all_gather(full, axis_name=ax, tiled=True)
    return full


def propagate_sharded(x_local: jax.Array, gather_idx: jax.Array,
                      scatter_idx: jax.Array, valid_words: jax.Array,
                      axis_names, *, num_segments: int,
                      chunk_words: int) -> jax.Array:
    """One sharded semiring round ``out[a] = OR_{(a,b)} x[b]`` (packed).

    ``gather_idx`` holds the *global* remote endpoint of each shard-owned
    edge (indexing the all_gathered table), ``scatter_idx`` the shard-local
    owned endpoint, and ``valid_words`` an all-ones/all-zeros uint32 mask
    zeroing the padding slots of the static edge layout.
    """
    full = all_gather_words(x_local, axis_names)
    vals = full[gather_idx] & valid_words
    return bitset.segment_or_words(vals, scatter_idx,
                                   num_segments=num_segments,
                                   chunk_words=chunk_words)


def closure_sharded(base: jax.Array, step, axis_names, *, max_iters: int):
    """lfp(R = base ∨ step(R)) over shard-local rows; returns (R, rounds).

    Same ``upd & ~r`` changed-flag idiom as ``_closure_segment``, but the
    flag is all-reduced over the mesh every round so every device stops at
    the same globally-converged round — callers never guess a round count.
    """

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        new = step(r) & ~r
        changed = jax.lax.psum(jnp.any(new != 0).astype(jnp.int32),
                               tuple(axis_names)) > 0
        return r | new, changed, it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


def closure_sharded_delta(base: jax.Array, gather_idx: jax.Array,
                          scatter_idx: jax.Array, valid_words: jax.Array,
                          axis_names, *, per: int, v_pad: int,
                          chunk_words: int, row_budget: int,
                          max_iters: int):
    """Delta-row exchange fixpoint: ship *changed rows*, not the table.

    The row-granular analogue of the two-level compressed planes: each
    device keeps a pending bitmap (level-1 summary — which of its rows
    carry bits the mesh has not seen; an unchanged row is an ALL_ZERO
    delta and never crosses the wire) and per round ships at most
    ``row_budget`` pending rows as a sentinel-padded ``(global id,
    packed payload)`` pair (the level-2 pool).  Receivers scatter the
    shipped rows into a zeroed table and run the ordinary local packed
    OR-reduction, so per-round exchange traffic is
    ``budget × (W + 1)`` words instead of ``per × W``.

    Rows left over when the budget binds stay pending and ship on later
    rounds; a row whose content changes after shipping re-enters the
    bitmap.  Every changed row therefore ships eventually, and because
    the OR fixpoint is monotone with a unique least solution, the result
    is **bit-identical** to ``closure_sharded`` over the dense exchange —
    an overflowing budget costs extra rounds, never bits.  Convergence is
    the all-reduced "any row still pending" flag.

    Returns ``(r_local, rounds)`` like ``closure_sharded``.
    """
    axes = tuple(axis_names)
    budget = min(row_budget, per)
    w = base.shape[1]
    lane = jnp.arange(per, dtype=jnp.int32)
    flat = jnp.int32(0)
    for ax in axes:  # outer-major, matching the P(axes) shard numbering
        flat = flat * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    row0 = flat * per

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, pend, _, it = state
        # first `budget` pending local rows (sentinel `per` pads the tail)
        ship = jax.lax.sort(jnp.where(pend, lane, jnp.int32(per)))[:budget]
        live = ship < per
        r_ext = jnp.concatenate([r, jnp.zeros((1, w), r.dtype)])
        payload = r_ext[ship]                       # sentinel -> zero row
        gid = jnp.where(live, ship + row0, jnp.int32(v_pad))
        gids = all_gather_words(gid, axes)          # [S*B]
        pays = all_gather_words(payload, axes)      # [S*B, W]
        # real global ids are distinct within a round (each row ships only
        # from its owner); sentinel slots all write the zero row
        tbl = jnp.zeros((v_pad + 1, w), r.dtype).at[gids].set(pays)[:v_pad]
        upd = bitset.segment_or_words(
            tbl[gather_idx] & valid_words, scatter_idx,
            num_segments=per, chunk_words=chunk_words)
        new = upd & ~r
        shipped = jnp.zeros(per + 1, bool).at[ship].set(True)[:per]
        pend = (pend & ~shipped) | jnp.any(new != 0, axis=1)
        changed = jax.lax.psum(jnp.any(pend).astype(jnp.int32), axes) > 0
        return r | new, pend, changed, it + 1

    pend0 = jnp.any(base != 0, axis=1)
    r, _, _, rounds = jax.lax.while_loop(
        cond, body, (base, pend0, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


# ------------------------------------------------------------------ engine
class Engine:
    """OR-semiring propagation over one graph, packed words in/out.

    Holds the device-resident edge lists and (for the ``pallas`` backend)
    the packed adjacency bit-matrices, so repeated build/query calls reuse
    the same operands and jit caches.
    """

    def __init__(self, graph: Graph, config: EngineConfig = EngineConfig()):
        backend = resolve_backend(config.backend)
        kw = bitset.n_words(graph.n_vertices)
        dense_bytes = graph.n_vertices * kw * 4
        if backend == "pallas" and dense_bytes > config.max_dense_bytes:
            warnings.warn(
                f"engine: dense adjacency needs {dense_bytes/1e6:.0f} MB "
                f"(> max_dense_bytes={config.max_dense_bytes/1e6:.0f} MB); "
                "falling back to the segment backend", stacklevel=2)
            backend = "segment"
        self.graph = graph
        self.config = config
        self.backend = backend
        self.interpret = (jax.default_backend() != "tpu"
                          if config.interpret is None else config.interpret)
        self.edge_src = jnp.asarray(graph.src)
        self.edge_dst = jnp.asarray(graph.indices)
        self._adj: dict[bool, jax.Array] = {}
        self._bcomp: dict[bool, BlockCompressed] = {}
        self._label_adj: dict[tuple, jax.Array] = {}
        self._rev_graph: Graph | None = None

    # ------------------------------------------------------------ operands
    @property
    def matmul_mode(self) -> str:
        """kernels.ops mode implementing this engine's matmul calls."""
        return "interpret" if self.interpret else "pallas"

    @property
    def kernel_mode(self) -> str:
        """kernels.ops mode for auxiliary fused kernels (way_filter &c.)."""
        return self.matmul_mode if self.backend == "pallas" else "ref"

    # distinct special-label sets whose class matrices stay resident; the
    # per-set footprint is (C+1) dense adjacencies, so the cache is a small
    # LRU rather than unbounded under varied query traffic
    LABEL_ADJ_CACHE = 4

    def can_pack_dense(self, n_matrices: int = 1) -> bool:
        """Would ``n_matrices`` dense adjacency bit-matrices fit the cap?"""
        kw = bitset.n_words(self.graph.n_vertices)
        return (n_matrices * self.graph.n_vertices * kw * 4
                <= self.config.max_dense_bytes)

    def adjacency(self, *, reverse: bool = False) -> jax.Array:
        """Cached packed adjacency bit-matrix ``[V, ceil(V/32)]``."""
        if reverse not in self._adj:
            self._adj[reverse] = jnp.asarray(
                pack_adjacency_np(self.graph, reverse=reverse))
        return self._adj[reverse]

    def block_adjacency(self, *, reverse: bool = False) -> BlockCompressed:
        """Cached block-compressed adjacency (the sparse-closure operand).

        ZERO blocks cost 2 bits, so for the sparse graphs the paper
        targets this is E-proportional storage where the dense bit-matrix
        is V²-proportional — it is what lifts the closure operand past
        ``max_dense_bytes``-scale vertex counts."""
        if reverse not in self._bcomp:
            self._bcomp[reverse] = compress_blocks(
                pack_adjacency_np(self.graph, reverse=reverse),
                br=self.config.block_rows, bw=self.config.block_words,
                nbits=self.graph.n_vertices)
        return self._bcomp[reverse]

    def label_class_adjacency(self, special_labels, *,
                              reverse: bool = True) -> jax.Array:
        """Per-label-class adjacency ``[C+1, V, Kw]`` (LRU-cached).

        ``reverse=True`` (bit j of row i == edge j→i) drives forward
        frontier expansion; ``reverse=False`` drives the backward frontier
        of the bidirectional executor."""
        labels = tuple(sorted(set(int(l) for l in special_labels)))
        key = (labels, reverse)
        if key in self._label_adj:
            self._label_adj[key] = self._label_adj.pop(key)  # refresh LRU
        else:
            while len(self._label_adj) >= self.LABEL_ADJ_CACHE:
                self._label_adj.pop(next(iter(self._label_adj)))
            self._label_adj[key] = jnp.asarray(
                pack_label_class_adjacency_np(self.graph, labels,
                                              reverse=reverse))
        return self._label_adj[key]

    # ---------------------------------------------------------- primitives
    def segment_or(self, values: jax.Array, segment_ids: jax.Array,
                   num_segments: int) -> jax.Array:
        """OR-reduce packed rows by arbitrary segment ids (projections)."""
        return bitset.segment_or_words(values, segment_ids,
                                       num_segments=num_segments,
                                       chunk_words=self.config.chunk_words)

    def propagate(self, x: jax.Array, *, reverse: bool = False,
                  sr: Semiring = BOOLEAN) -> jax.Array:
        """One semiring round: ``out[a] = (+)_{(a,b)} extend(x[b])``.

        ``sr=BOOLEAN`` (default) is the packed OR round of PR 1-7,
        bit-identical to the pre-refactor engine; min-plus/count carriers
        run one lane per column of ``x``."""
        if self.backend == "pallas":
            return _matmul_rows(self.adjacency(reverse=reverse), x,
                                self.matmul_mode, sr=sr)
        gather = self.edge_dst if not reverse else self.edge_src
        scatter = self.edge_src if not reverse else self.edge_dst
        if sr.packed:
            return self.segment_or(x[gather], scatter, self.graph.n_vertices)
        return sr.segment_combine(sr.extend(x[gather]), scatter,
                                  num_segments=self.graph.n_vertices)

    def closure(self, base: jax.Array, *, reverse: bool = False,
                max_iters: int | None = None,
                sparse: bool | None = None,
                sr: Semiring = BOOLEAN) -> tuple[jax.Array, int]:
        """Least fixpoint ``R = base (+) propagate(R)``; returns (R, rounds).

        ``base`` is packed uint32 ``[V, W]``.  The lfp is unique, so any
        seed between the true base and the fixpoint converges to the same
        bits — incremental maintenance (``tdr_build.update_index``) leans
        on this by re-entering the closure from the *previous* converged
        state plus a delta, which typically terminates in 1-2 rounds
        instead of a diameter's worth.

        ``sparse`` routes the fixpoint through the block-sparse path: the
        block-compressed adjacency and delta-frontier rounds on
        ``pallas``, frontier-compacted edge gathers on ``segment``.  Both
        are bit-identical to the dense fixpoint — sparsity only changes
        which work is skipped.  The default (``None`` +
        ``EngineConfig.sparse``) engages it only where skipping pays:
        always on ``segment``, and on ``pallas`` only under the real TPU
        lowering — in interpret mode the per-grid-step Python dispatch
        dwarfs any skipped block, so the dense kernel is faster there
        (pass ``sparse=True`` to force the block-sparse path anyway,
        e.g. for equivalence tests).

        ``sr`` selects the semiring.  Fixpoints need an idempotent (+)
        (the convergence predicate compares successive planes), so the
        COUNT carrier is refused — route counting is a *bounded* DP in
        ``tdr_query.count_routes``.  Non-packed carriers always run the
        dense cores (the frontier/block-sparse machinery is specific to
        the packed boolean layout)."""
        max_iters = max_iters or self.graph.n_vertices
        if not sr.idempotent:
            raise ValueError(
                f"closure needs an idempotent semiring, got {sr.name}; "
                "use a bounded DP (tdr_query.count_routes) instead")
        if sparse is None:
            sparse = self.config.sparse and (
                self.backend == "segment" or not self.interpret)
        if not sr.packed:
            if self.backend == "pallas":
                return _closure_matmul(base, self.adjacency(reverse=reverse),
                                       max_iters=max_iters,
                                       mode=self.matmul_mode, sr=sr)
            gather = self.edge_dst if not reverse else self.edge_src
            scatter = self.edge_src if not reverse else self.edge_dst
            return _closure_segment(base, gather, scatter,
                                    num_segments=self.graph.n_vertices,
                                    chunk_words=self.config.chunk_words,
                                    max_iters=max_iters, sr=sr)
        if self.backend == "pallas":
            if sparse:
                return _closure_blocksparse(
                    base, self.block_adjacency(reverse=reverse),
                    mode=self.matmul_mode, max_iters=max_iters)
            return _closure_matmul(base, self.adjacency(reverse=reverse),
                                   max_iters=max_iters,
                                   mode=self.matmul_mode)
        if sparse:
            return self._closure_segment_frontier(base, reverse=reverse,
                                                  max_iters=max_iters)
        gather = self.edge_dst if not reverse else self.edge_src
        scatter = self.edge_src if not reverse else self.edge_dst
        return _closure_segment(base, gather, scatter,
                                num_segments=self.graph.n_vertices,
                                chunk_words=self.config.chunk_words,
                                max_iters=max_iters)

    def _gather_csr(self, reverse: bool) -> Graph:
        """CSR grouped by each round's *gather* endpoint: forward
        propagation gathers ``x[dst]``, so its edge subsets come from the
        edge-reversed CSR (and vice versa)."""
        if reverse:
            return self.graph
        if self._rev_graph is None:
            self._rev_graph = self.graph.reverse()
        return self._rev_graph

    def _closure_segment_frontier(self, base: jax.Array, *, reverse: bool,
                                  max_iters: int) -> tuple[jax.Array, int]:
        """Host-driven delta fixpoint for the segment backend: each round
        gathers only edges incident to the still-active frontier rows
        (bucket-padded so the jit-shape count stays logarithmic), falling
        back to a full dense round while the frontier covers more than
        ``sparse_dense_frac`` of the vertices."""
        v = self.graph.n_vertices
        g = self._gather_csr(reverse)
        thresh = int(self.config.sparse_dense_frac * v)
        gather = self.edge_dst if not reverse else self.edge_src
        scatter = self.edge_src if not reverse else self.edge_dst
        # stage 1: high-occupancy rounds run dense inside one jitted loop
        # (no per-round host sync); it exits when the frontier thins out
        r, new, rounds_d = _closure_segment_until_sparse(
            jnp.asarray(base), gather, scatter, num_segments=v,
            chunk_words=self.config.chunk_words, max_iters=max_iters,
            max_active=thresh)
        rounds = int(rounds_d)
        # stage 2: small-frontier tail — compacted edge gathers, one
        # device→host sync per round to learn the active set
        while rounds < max_iters:
            act = np.flatnonzero(np.asarray(jnp.any(new != 0, axis=-1)))
            if act.size == 0:
                break
            rounds += 1
            if act.size > thresh:
                # the frontier can re-widen (a hub lighting up its whole
                # out-neighbourhood); fall back to a dense round
                upd = self.propagate(new, reverse=reverse)
            else:
                counts = (g.indptr[act + 1] - g.indptr[act]).astype(np.int64)
                gat = np.repeat(act.astype(np.int64), counts)
                scat = g.indices[csr_row_edges(g.indptr, act)].astype(
                    np.int64)
                b = pad_bucket(max(gat.size, 1), lo=32)
                gat_p = np.full(b, v, dtype=np.int64)
                gat_p[:gat.size] = gat
                scat_p = np.full(b, v, dtype=np.int64)  # dropped segment
                scat_p[:scat.size] = scat
                upd = _sparse_segment_round(
                    new, jnp.asarray(gat_p), jnp.asarray(scat_p),
                    num_segments=v, chunk_words=self.config.chunk_words)
            nxt = upd & ~r
            r = r | nxt
            new = nxt
        return r, rounds

    # ------------------------------------------------------------- updates
    def apply_delta(self, graph: Graph, added: np.ndarray,
                    removed: np.ndarray) -> "Engine":
        """New engine over the post-update ``graph`` (same vertex set),
        reusing this engine's resolved backend/config.

        Any cached dense adjacency bit-matrix is *patched*, not repacked:
        only the rows whose edge set changed (sources for the forward
        matrix, destinations for the reverse one) are re-derived from the
        new CSR and scattered in on device — O(|touched rows|) transfer
        instead of O(V·V/8).  Label-class adjacency caches are dropped
        (they rebuild lazily on the next query batch)."""
        if graph.n_vertices != self.graph.n_vertices:
            raise ValueError("apply_delta requires a fixed vertex set")
        new = object.__new__(Engine)
        new.graph = graph
        new.config = self.config
        new.backend = self.backend
        new.interpret = self.interpret
        new.edge_src = jnp.asarray(graph.src)
        new.edge_dst = jnp.asarray(graph.indices)
        new._adj = {}
        new._bcomp = {}
        new._label_adj = {}
        new._rev_graph = None
        rev_csr = None

        def touched_rows(reverse: bool) -> np.ndarray:
            col = 1 if reverse else 0
            return np.unique(np.concatenate(
                [added[:, col], removed[:, col]])).astype(np.int64)

        def patched_row_bits(reverse: bool, rows: np.ndarray,
                             kw: int) -> np.ndarray:
            nonlocal rev_csr
            if reverse and rev_csr is None:
                rev_csr = graph.reverse()
            g = rev_csr if reverse else graph
            counts = (g.indptr[rows + 1] - g.indptr[rows]).astype(np.int64)
            pos = np.repeat(np.arange(rows.shape[0]), counts)
            eidx = csr_row_edges(g.indptr, rows)
            rowbits = np.zeros((rows.shape[0], kw), dtype=np.uint32)
            bitset.set_bits_np(rowbits, (pos,), g.indices[eidx])
            return rowbits

        for reverse, adj in self._adj.items():
            rows = touched_rows(reverse)
            if rows.size == 0:
                new._adj[reverse] = adj
                continue
            rowbits = patched_row_bits(reverse, rows, adj.shape[1])
            new._adj[reverse] = adj.at[jnp.asarray(rows)].set(
                jnp.asarray(rowbits))
        for reverse, comp in self._bcomp.items():
            rows = touched_rows(reverse)
            if rows.size == 0:
                new._bcomp[reverse] = comp
                continue
            rowbits = patched_row_bits(reverse, rows, comp.shape[1])
            new._bcomp[reverse] = patch_blocks(comp, rows, rowbits)
        return new


def jit_cache_entries() -> int:
    """Total compiled-variant count across the packed-word hot path.

    Sums the jit caches of every jitted entry point in the engine, the
    query planner/executor, the bitset primitives, and the kernel surface.
    The serving benchmark snapshots this after warmup and asserts a zero
    delta over the measurement window — steady-state traffic on the
    bucket grid must never recompile.
    """
    import sys

    from repro.core import bitset as bitset_mod, tdr_query
    from repro.kernels import (bitset_matmul, block_sparse, ops,
                               pattern_filter, popcount)
    total = 0
    for mod in (sys.modules[__name__], bitset_mod, tdr_query, ops,
                bitset_matmul, block_sparse, pattern_filter, popcount):
        for obj in vars(mod).values():
            size = getattr(obj, "_cache_size", None)
            if callable(size):
                total += int(size())
    return total


def make_engine(graph: Graph, backend: str | None = None,
                config: EngineConfig | None = None) -> Engine:
    """Engine factory: ``backend`` shorthand overrides ``config.backend``."""
    cfg = config or EngineConfig()
    if backend is not None:
        cfg = dataclasses.replace(cfg, backend=backend)
    return Engine(graph, cfg)

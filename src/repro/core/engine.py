"""Packed-word OR-semiring closure engine — one core shared by build & query.

Everything the TDR pipeline computes — index construction (§IV Alg. 1),
vertical k-level propagation, and the query-side product-graph expansion
(§V Alg. 2) — is one primitive applied in different shapes:

    out[a] = OR_{(a,b) ∈ E} x[b]          (boolean-OR semiring propagate)

This module provides that primitive **end-to-end on packed uint32 words**
(32 graph bits per lane element; no ``[V, nbits]`` boolean plane at rest)
behind a pluggable backend:

* ``segment`` — reference backend; chunked ``segment_max`` over word-chunk
  transients (``bitset.segment_or_words``).  Works on any jax backend and
  any graph size; the default off-TPU.
* ``pallas``  — routes every fixpoint round / frontier expansion through
  ``repro.kernels.bitset_matmul`` on a packed adjacency bit-matrix
  (``[V, ceil(V/32)]`` uint32, bit j of row i == edge i→j).  Real kernel on
  TPU, interpret mode elsewhere.  Dense ``V×V/8`` bytes, so the engine
  auto-falls back to ``segment`` above ``EngineConfig.max_dense_bytes``.

Backend selection contract (see ARCHITECTURE.md):

1. An explicitly requested backend ("segment" | "pallas") always wins.
2. The ``REPRO_ENGINE_BACKEND`` environment variable replaces the default
   resolution when the request is "auto"/unset.
3. "auto" resolves to ``pallas`` on TPU, ``segment`` elsewhere.
4. A ``pallas`` request that cannot be honoured (adjacency over the dense
   cap) falls back to ``segment`` with a warning — never an error.

Both backends are bit-exact (property-tested against each other and the
bool-plane oracle in ``tests/test_engine.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from .graph import Graph, csr_row_edges

ENV_BACKEND = "REPRO_ENGINE_BACKEND"
BACKENDS = ("segment", "pallas")


def resolve_backend(requested: str = "auto") -> str:
    """Resolve a backend name per the selection contract above.

    The ``REPRO_ENGINE_BACKEND`` environment variable replaces the
    *default* ("auto"/empty) resolution only — an explicitly requested
    backend wins, so backend sweeps and bit-equality comparisons cannot be
    silently collapsed onto one backend by ambient environment."""
    req = requested or "auto"
    if req == "auto":
        req = os.environ.get(ENV_BACKEND, "").strip() or "auto"
    if req == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "segment"
    if req not in BACKENDS:
        raise ValueError(
            f"unknown engine backend {req!r}; expected one of "
            f"{('auto',) + BACKENDS}")
    return req


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "auto"        # "auto" | "segment" | "pallas"
    bit_chunk: int = 64          # transient chunk width (bits) for segment ORs
    interpret: bool | None = None  # pallas interpret; None -> off-TPU only
    max_dense_bytes: int = 1 << 28  # pallas dense-adjacency cap (auto-fallback)

    @property
    def chunk_words(self) -> int:
        return max(1, self.bit_chunk // bitset.WORD)


# ------------------------------------------------------- adjacency packing
def pack_adjacency_np(graph: Graph, *, reverse: bool = False) -> np.ndarray:
    """Packed adjacency bit-matrix uint32 ``[V, ceil(V/32)]``.

    Forward: bit v of row u == edge u→v (the closure/propagate operand).
    Reverse: bit u of row v == edge u→v.
    """
    v_n = graph.n_vertices
    kw = bitset.n_words(v_n)
    a = np.zeros((v_n, kw), dtype=np.uint32)
    src, dst = graph.src, graph.indices
    rows, cols = (dst, src) if reverse else (src, dst)
    bitset.set_bits_np(a, (rows,), cols)
    return a


def pack_label_class_edges_np(src: np.ndarray, dst: np.ndarray,
                              labels: np.ndarray, n_vertices: int,
                              special_labels, *,
                              reverse: bool = True) -> np.ndarray:
    """Per-label-class packed adjacency ``[C+1, V, ceil(V/32)]`` from raw
    edge arrays (used for per-chunk corridor-compacted subgraphs as well
    as the whole graph).

    One bit-matrix per *special* label (labels that some pending query
    requires or forbids) plus a final **neutral** class OR-ing every edge
    whose label is special for nobody — those edges behave identically for
    all queries (always allowed, subset-bit 0), so one matmul covers them.
    """
    kw = bitset.n_words(n_vertices)
    special = list(special_labels)
    out = np.zeros((len(special) + 1, n_vertices, kw), dtype=np.uint32)
    rows, cols = (dst, src) if reverse else (src, dst)
    cls = np.full(labels.shape[0], len(special), dtype=np.int64)
    for i, l in enumerate(special):
        cls[labels == l] = i
    bitset.set_bits_np(out, (cls, rows), cols)
    return out


def pack_label_class_adjacency_np(graph: Graph, special_labels,
                                  *, reverse: bool = True) -> np.ndarray:
    """Whole-graph wrapper over ``pack_label_class_edges_np``."""
    return pack_label_class_edges_np(graph.src, graph.indices, graph.labels,
                                     graph.n_vertices, special_labels,
                                     reverse=reverse)


# --------------------------------------------------------- jitted closures
@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_words",
                                             "max_iters"))
def _closure_segment(base: jax.Array, gather_idx: jax.Array,
                     scatter_idx: jax.Array, *, num_segments: int,
                     chunk_words: int, max_iters: int):
    """lfp(R = base ∨ OR_{(a,b)} R[b]) via packed segment reductions."""

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        upd = bitset.segment_or_words(r[gather_idx], scatter_idx,
                                      num_segments=num_segments,
                                      chunk_words=chunk_words)
        new = upd & ~r   # the changed flag falls out of the round's own OR
        return r | new, jnp.any(new != 0), it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


def _matmul_rows(adj: jax.Array, x: jax.Array, mode: str,
                 tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """``OR_j adj[i,j] & x[j]`` with x's row count padded to adj's bit width
    (the packed adjacency is word-aligned: K = ceil(V/32)*32 >= V)."""
    from repro.kernels import ops  # deferred: kernels import repro.core
    k = adj.shape[1] * bitset.WORD
    if x.shape[0] < k:
        x = jnp.concatenate(
            [x, jnp.zeros((k - x.shape[0],) + x.shape[1:], x.dtype)], axis=0)
    return ops.frontier_step(adj, x, mode=mode, tiles=tiles)


@functools.partial(jax.jit, static_argnames=("max_iters", "mode"))
def _closure_matmul(base: jax.Array, adj: jax.Array, *, max_iters: int,
                    mode: str):
    """Same fixpoint with rounds routed through ``kernels.bitset_matmul``."""

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        upd = _matmul_rows(adj, r, mode)
        new = upd & ~r   # the changed flag falls out of the round's own OR
        return r | new, jnp.any(new != 0), it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


# ------------------------------------------------- mesh-aware entry points
# These run *inside* ``shard_map`` blocks (repro.core.distributed): the
# vertex dimension is 1-D partitioned over the flattened mesh axes, each
# device owns a contiguous block of rows, and the only cross-device traffic
# is the all_gather of the packed uint32 closure words — no ``[V, nbits]``
# boolean plane ever crosses devices.


def all_gather_words(x_local: jax.Array, axis_names) -> jax.Array:
    """Gather shard-local packed rows into the full table ``[V, W]``.

    Gathers the innermost mesh axis first so the flattened ordering matches
    the axis-major shard numbering of a ``P(axis_names)`` leading-dim spec.
    The payload stays packed uint32 end-to-end.
    """
    full = x_local
    for ax in reversed(tuple(axis_names)):
        full = jax.lax.all_gather(full, axis_name=ax, tiled=True)
    return full


def propagate_sharded(x_local: jax.Array, gather_idx: jax.Array,
                      scatter_idx: jax.Array, valid_words: jax.Array,
                      axis_names, *, num_segments: int,
                      chunk_words: int) -> jax.Array:
    """One sharded semiring round ``out[a] = OR_{(a,b)} x[b]`` (packed).

    ``gather_idx`` holds the *global* remote endpoint of each shard-owned
    edge (indexing the all_gathered table), ``scatter_idx`` the shard-local
    owned endpoint, and ``valid_words`` an all-ones/all-zeros uint32 mask
    zeroing the padding slots of the static edge layout.
    """
    full = all_gather_words(x_local, axis_names)
    vals = full[gather_idx] & valid_words
    return bitset.segment_or_words(vals, scatter_idx,
                                   num_segments=num_segments,
                                   chunk_words=chunk_words)


def closure_sharded(base: jax.Array, step, axis_names, *, max_iters: int):
    """lfp(R = base ∨ step(R)) over shard-local rows; returns (R, rounds).

    Same ``upd & ~r`` changed-flag idiom as ``_closure_segment``, but the
    flag is all-reduced over the mesh every round so every device stops at
    the same globally-converged round — callers never guess a round count.
    """

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        new = step(r) & ~r
        changed = jax.lax.psum(jnp.any(new != 0).astype(jnp.int32),
                               tuple(axis_names)) > 0
        return r | new, changed, it + 1

    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (base, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


# ------------------------------------------------------------------ engine
class Engine:
    """OR-semiring propagation over one graph, packed words in/out.

    Holds the device-resident edge lists and (for the ``pallas`` backend)
    the packed adjacency bit-matrices, so repeated build/query calls reuse
    the same operands and jit caches.
    """

    def __init__(self, graph: Graph, config: EngineConfig = EngineConfig()):
        backend = resolve_backend(config.backend)
        kw = bitset.n_words(graph.n_vertices)
        dense_bytes = graph.n_vertices * kw * 4
        if backend == "pallas" and dense_bytes > config.max_dense_bytes:
            warnings.warn(
                f"engine: dense adjacency needs {dense_bytes/1e6:.0f} MB "
                f"(> max_dense_bytes={config.max_dense_bytes/1e6:.0f} MB); "
                "falling back to the segment backend", stacklevel=2)
            backend = "segment"
        self.graph = graph
        self.config = config
        self.backend = backend
        self.interpret = (jax.default_backend() != "tpu"
                          if config.interpret is None else config.interpret)
        self.edge_src = jnp.asarray(graph.src)
        self.edge_dst = jnp.asarray(graph.indices)
        self._adj: dict[bool, jax.Array] = {}
        self._label_adj: dict[tuple, jax.Array] = {}

    # ------------------------------------------------------------ operands
    @property
    def matmul_mode(self) -> str:
        """kernels.ops mode implementing this engine's matmul calls."""
        return "interpret" if self.interpret else "pallas"

    @property
    def kernel_mode(self) -> str:
        """kernels.ops mode for auxiliary fused kernels (way_filter &c.)."""
        return self.matmul_mode if self.backend == "pallas" else "ref"

    # distinct special-label sets whose class matrices stay resident; the
    # per-set footprint is (C+1) dense adjacencies, so the cache is a small
    # LRU rather than unbounded under varied query traffic
    LABEL_ADJ_CACHE = 4

    def can_pack_dense(self, n_matrices: int = 1) -> bool:
        """Would ``n_matrices`` dense adjacency bit-matrices fit the cap?"""
        kw = bitset.n_words(self.graph.n_vertices)
        return (n_matrices * self.graph.n_vertices * kw * 4
                <= self.config.max_dense_bytes)

    def adjacency(self, *, reverse: bool = False) -> jax.Array:
        """Cached packed adjacency bit-matrix ``[V, ceil(V/32)]``."""
        if reverse not in self._adj:
            self._adj[reverse] = jnp.asarray(
                pack_adjacency_np(self.graph, reverse=reverse))
        return self._adj[reverse]

    def label_class_adjacency(self, special_labels, *,
                              reverse: bool = True) -> jax.Array:
        """Per-label-class adjacency ``[C+1, V, Kw]`` (LRU-cached).

        ``reverse=True`` (bit j of row i == edge j→i) drives forward
        frontier expansion; ``reverse=False`` drives the backward frontier
        of the bidirectional executor."""
        labels = tuple(sorted(set(int(l) for l in special_labels)))
        key = (labels, reverse)
        if key in self._label_adj:
            self._label_adj[key] = self._label_adj.pop(key)  # refresh LRU
        else:
            while len(self._label_adj) >= self.LABEL_ADJ_CACHE:
                self._label_adj.pop(next(iter(self._label_adj)))
            self._label_adj[key] = jnp.asarray(
                pack_label_class_adjacency_np(self.graph, labels,
                                              reverse=reverse))
        return self._label_adj[key]

    # ---------------------------------------------------------- primitives
    def segment_or(self, values: jax.Array, segment_ids: jax.Array,
                   num_segments: int) -> jax.Array:
        """OR-reduce packed rows by arbitrary segment ids (projections)."""
        return bitset.segment_or_words(values, segment_ids,
                                       num_segments=num_segments,
                                       chunk_words=self.config.chunk_words)

    def propagate(self, x: jax.Array, *, reverse: bool = False) -> jax.Array:
        """One semiring round: ``out[a] = OR_{(a,b)} x[b]`` (packed)."""
        if self.backend == "pallas":
            return _matmul_rows(self.adjacency(reverse=reverse), x,
                                self.matmul_mode)
        gather = self.edge_dst if not reverse else self.edge_src
        scatter = self.edge_src if not reverse else self.edge_dst
        return self.segment_or(x[gather], scatter, self.graph.n_vertices)

    def closure(self, base: jax.Array, *, reverse: bool = False,
                max_iters: int | None = None) -> tuple[jax.Array, int]:
        """Least fixpoint ``R = base ∨ propagate(R)``; returns (R, rounds).

        ``base`` is packed uint32 ``[V, W]``.  The lfp is unique, so any
        seed between the true base and the fixpoint converges to the same
        bits — incremental maintenance (``tdr_build.update_index``) leans
        on this by re-entering the closure from the *previous* converged
        state plus a delta, which typically terminates in 1-2 rounds
        instead of a diameter's worth."""
        max_iters = max_iters or self.graph.n_vertices
        if self.backend == "pallas":
            return _closure_matmul(base, self.adjacency(reverse=reverse),
                                   max_iters=max_iters,
                                   mode=self.matmul_mode)
        gather = self.edge_dst if not reverse else self.edge_src
        scatter = self.edge_src if not reverse else self.edge_dst
        return _closure_segment(base, gather, scatter,
                                num_segments=self.graph.n_vertices,
                                chunk_words=self.config.chunk_words,
                                max_iters=max_iters)

    # ------------------------------------------------------------- updates
    def apply_delta(self, graph: Graph, added: np.ndarray,
                    removed: np.ndarray) -> "Engine":
        """New engine over the post-update ``graph`` (same vertex set),
        reusing this engine's resolved backend/config.

        Any cached dense adjacency bit-matrix is *patched*, not repacked:
        only the rows whose edge set changed (sources for the forward
        matrix, destinations for the reverse one) are re-derived from the
        new CSR and scattered in on device — O(|touched rows|) transfer
        instead of O(V·V/8).  Label-class adjacency caches are dropped
        (they rebuild lazily on the next query batch)."""
        if graph.n_vertices != self.graph.n_vertices:
            raise ValueError("apply_delta requires a fixed vertex set")
        new = object.__new__(Engine)
        new.graph = graph
        new.config = self.config
        new.backend = self.backend
        new.interpret = self.interpret
        new.edge_src = jnp.asarray(graph.src)
        new.edge_dst = jnp.asarray(graph.indices)
        new._adj = {}
        new._label_adj = {}
        rev_csr = None
        for reverse, adj in self._adj.items():
            col = 1 if reverse else 0
            rows = np.unique(np.concatenate(
                [added[:, col], removed[:, col]])).astype(np.int64)
            if rows.size == 0:
                new._adj[reverse] = adj
                continue
            if reverse and rev_csr is None:
                rev_csr = graph.reverse()
            g = rev_csr if reverse else graph
            counts = (g.indptr[rows + 1] - g.indptr[rows]).astype(np.int64)
            pos = np.repeat(np.arange(rows.shape[0]), counts)
            eidx = csr_row_edges(g.indptr, rows)
            rowbits = np.zeros((rows.shape[0], adj.shape[1]),
                               dtype=np.uint32)
            bitset.set_bits_np(rowbits, (pos,), g.indices[eidx])
            new._adj[reverse] = adj.at[jnp.asarray(rows)].set(
                jnp.asarray(rowbits))
        return new


def jit_cache_entries() -> int:
    """Total compiled-variant count across the packed-word hot path.

    Sums the jit caches of every jitted entry point in the engine, the
    query planner/executor, the bitset primitives, and the kernel surface.
    The serving benchmark snapshots this after warmup and asserts a zero
    delta over the measurement window — steady-state traffic on the
    bucket grid must never recompile.
    """
    import sys

    from repro.core import bitset as bitset_mod, tdr_query
    from repro.kernels import (bitset_matmul, ops, pattern_filter,
                               popcount)
    total = 0
    for mod in (sys.modules[__name__], bitset_mod, tdr_query, ops,
                bitset_matmul, pattern_filter, popcount):
        for obj in vars(mod).values():
            size = getattr(obj, "_cache_size", None)
            if callable(size):
                total += int(size())
    return total


def make_engine(graph: Graph, backend: str | None = None,
                config: EngineConfig | None = None) -> Engine:
    """Engine factory: ``backend`` shorthand overrides ``config.backend``."""
    cfg = config or EngineConfig()
    if backend is not None:
        cfg = dataclasses.replace(cfg, backend=backend)
    return Engine(graph, cfg)

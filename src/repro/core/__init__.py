"""TDR core — the paper's contribution as a composable JAX library.

Public API::

    from repro.core import graph, pattern, tdr_build, tdr_query

    g   = graph.erdos_renyi(200_000, 6, 32)
    idx = tdr_build.build_index(g, tdr_build.TDRConfig())
    ans = tdr_query.answer_batch(idx, [(u, v, pattern.parse("l0 & !l3"))])
"""
from . import bitset, deltalog, dfs_baseline, distributed, engine, graph
from . import lcr, pattern, snapshot, tdr_build, tdr_query
from .deltalog import DeltaLog, LogCorrupt
from .engine import Engine, EngineConfig, make_engine, resolve_backend
from .snapshot import (SnapshotCorrupt, SnapshotVersionMismatch,
                       load_index, save_index)
from .graph import Graph, erdos_renyi, fig2_example, preferential_attachment
from .pattern import parse, all_of, any_of, none_of, lcr as lcr_pattern
from .tdr_build import TDRConfig, TDRIndex, build_index
from .tdr_query import QueryPlan, QueryStats, answer, answer_batch

__all__ = [
    "Graph", "TDRConfig", "TDRIndex", "QueryPlan", "QueryStats",
    "Engine", "EngineConfig", "make_engine", "resolve_backend",
    "build_index", "answer", "answer_batch", "parse",
    "all_of", "any_of", "none_of", "lcr_pattern",
    "erdos_renyi", "preferential_attachment", "fig2_example",
    "DeltaLog", "LogCorrupt", "SnapshotCorrupt",
    "SnapshotVersionMismatch", "load_index", "save_index",
    "bitset", "deltalog", "dfs_baseline", "distributed", "engine",
    "graph", "lcr", "pattern", "snapshot", "tdr_build", "tdr_query",
]

"""Answering PCR queries with the TDR index (paper §V, Alg. 2) — batched.

The paper's Alg. 2 interleaves pruning with a DFS.  Here the same logic is a
**planner/executor split**, both halves batched over the whole query set and
running end-to-end on packed uint32 words through ``repro.core.engine``:

Planner — ``compile_queries`` flattens DNF terms into a fully vectorized
``QueryPlan``: packed required/forbidden label-slot planes, packed raw
forbidden-label rows, and padded required-label ids.  No per-edge or
per-vertex host arrays — everything edge-indexed is derived on device by
the executor via label gathers (no ``elab == l`` Python scans, no
``[Q, E]`` host-side dense masks).  Per-pattern rows are cached on the
index keyed by the hash-consed canonical pattern (``pattern_rows``), so
repeated query shapes skip DNF expansion and plane scatters; callers that
manage their own plans and job-axis padding (``repro.launch.serve``) use
``answer_plan`` directly.

Phase 1 — *filter cascade* (pure index math, no traversal):
  * ``u == v``            -> TRUE iff the term requires no labels
  * ``bits(v) ⊄ N_out(u)``-> FALSE   (paper: VertexReach)
  * ``bits(u) ⊄ N_in(v)`` -> FALSE   (paper: VertexReach, reverse)
  * interval ancestor + unconstrained term -> TRUE (paper: early stopping)
  * per-way group pruning via ``kernels.ops.filter_ways`` (the fused
    Pallas cascade on TPU / ref oracle elsewhere); no surviving way -> FALSE
  * everything else -> UNKNOWN, goes to phase 2.

Phase 2 — *corridor-compacted bidirectional expansion* for survivors only.
The paper's two-dimensional filters confine any u→v path to the Bloom
corridor ``V_out(u) ∩ V_in(v)``; the executor turns that pruning into a
*compute* restriction, not just an output mask:

  * **Compaction** — per job chunk (32 queries wide by default), the
    corridor rows are unioned into an active vertex set, renumbered
    into an induced subgraph (edge lists / padded-incidence gather
    matrices for the segment backend, packed per-label-class
    sub-adjacency bit-matrices for the ``pallas`` backend).  ``|V'|``
    and ``|E'|`` are padded to ``{2^k, 3·2^(k-1)}`` buckets so jit
    shapes stay stable and recompiles stay bounded; when the corridor
    is near-total the chunk runs on cached full-graph operands instead
    (corridor mask built on device, no host membership round-trip).
  * **Bidirectional meet-in-the-middle** — a forward frontier of
    seen-subset states expands from ``u`` while a backward frontier of
    states co-reachable to ``v`` expands from ``v``, both as ``[V', Q]``
    packed state-subset bitfields (bit s of word (x, q) == "query q can
    stand at x having seen required-subset s" / "can reach v collecting
    s").  A query finishes as soon as some vertex holds forward state s₁
    and backward state s₂ with ``s₁ | s₂ == full_mask`` — roughly half
    the rounds of one-directional expansion.  Finished queries' columns
    are frozen by a per-query done mask, and the fixpoint's ``changed``
    flag falls out of the round's own new-bit computation (``upd & ~f``)
    instead of a second full-frontier compare.
  * One round is a packed gather + per-edge constant-mask-shift subset
    transition + OR-reduction over padded in/out-incidence (segment
    backend), or one ``kernels.bitset_matmul`` per label class per
    direction (``pallas`` backend).

The expansion is the same boolean-semiring product the index build uses
and the corridor is sound (every vertex of a u→v path lies in it), so
answers stay exact: property tests assert bit-equality with the DFS
oracle and with the retained PR-1 full-graph executor (``exact_mode=
"legacy"``).  Chunks are dispatched without host syncs and collected
once at the end; ``QueryStats`` fetches round counters lazily.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
import warnings
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from . import engine as engine_mod
from . import graph as graph_mod
from . import pattern as pat
from . import rpq as rpq_mod
from . import dfs_baseline as dfs_mod
from .semiring import COUNT_CAP, DIST16
from .tdr_build import TDRIndex, _null_words

FALSE, TRUE, UNKNOWN = 0, 1, 2

_FULL = jnp.uint32(0xFFFFFFFF)

EXACT_MODES = ("auto", "compact", "full", "legacy")

#: query kinds the planner emits (one per query): boolean reachability,
#: shortest pattern-constrained hop distance, an actual witness path,
#: bounded label-distinct route counting, and regular path queries.
#: ``answer_plan`` serves "bool"; the semiring executors at the bottom of
#: this module serve dist/witness/count; "rpq" queries carry a
#: ``repro.core.rpq`` AST instead of a pattern and run ``rpq_batch``.
QUERY_KINDS = ("bool", "dist", "witness", "count", "rpq")


# ------------------------------------------------------------------ plans
@dataclasses.dataclass
class QueryPlan:
    """Planner output: one flattened DNF-term job per row, packed planes.

    ``req_w``/``forb_w`` are label-*slot* planes (the index's Bloom space,
    used by the filter cascade); ``forb_raw_w`` is packed over raw label
    ids — the executor's edge-forbid test must be exact, and slot hashing
    may collide when ``n_labels > lab_slots``.
    """
    qid: np.ndarray         # int32 [J] query id (-1 = padding row)
    u: np.ndarray           # int32 [J]
    v: np.ndarray           # int32 [J]
    req_w: np.ndarray       # uint32 [J, Wl]   required label-slot plane
    forb_w: np.ndarray      # uint32 [J, Wl]   forbidden label-slot plane
    forb_raw_w: np.ndarray  # uint32 [J, WL]   raw forbidden labels (packed)
    req_labels: np.ndarray  # int32 [J, max_m] raw required ids, -1 padded
    full_mask: np.ndarray   # int32 [J]        target subset state
    n_queries: int
    max_m: int
    # per-*query* kind (one of QUERY_KINDS); () means all-"bool".  Kinds
    # ride on the plan so mixed batches partition once, at the driver.
    kinds: tuple = ()

    @property
    def n_jobs(self) -> int:
        return int(self.qid.shape[0])

    def pad_to(self, jp: int) -> "QueryPlan":
        """Pad the job axis (padding rows: qid=-1 self-queries, empty
        pattern -> TRUE in the cascade but never landing in answers)."""
        j = self.n_jobs
        if jp <= j:
            return self
        p = jp - j

        def zrows(a):
            return np.concatenate(
                [a, np.zeros((p,) + a.shape[1:], dtype=a.dtype)])

        return QueryPlan(
            qid=np.concatenate([self.qid, np.full(p, -1, np.int32)]),
            u=zrows(self.u), v=zrows(self.v),
            req_w=zrows(self.req_w), forb_w=zrows(self.forb_w),
            forb_raw_w=zrows(self.forb_raw_w),
            req_labels=np.concatenate(
                [self.req_labels, np.full((p, self.max_m), -1, np.int32)]),
            full_mask=zrows(self.full_mask),
            n_queries=self.n_queries, max_m=self.max_m, kinds=self.kinds)


@dataclasses.dataclass
class QueryStats:
    n_queries: int = 0
    n_jobs: int = 0
    filter_false: int = 0
    filter_true: int = 0
    exact_jobs: int = 0
    plan_lookups: int = 0      # pattern-plan cache probes (compile_queries)
    plan_misses: int = 0       # ... that had to run DNF + plane scatters
    # query ids that reached phase 2 in the last answer_plan call (the
    # serving warmup uses these as expansion-compiling probe queries)
    exact_qids: list = dataclasses.field(default_factory=list, repr=False)
    corridor_active: int = 0   # Σ |V'| over dispatched phase-2 chunks
    corridor_total: int = 0    # Σ |V|  over dispatched phase-2 chunks
    saturated_chunks: int = 0  # chunks whose probe the summaries answered
    phase1_s: float = 0.0      # planner + filter cascade wall time
    phase2_s: float = 0.0      # exact expansion wall time (incl. collect)
    # device round counters, fetched lazily on first .exact_rounds access
    # so dispatching chunks never blocks on a per-chunk host sync
    _round_parts: list = dataclasses.field(default_factory=list, repr=False)

    @property
    def exact_rounds(self) -> int:
        self._round_parts[:] = [int(r) for r in self._round_parts]
        return sum(self._round_parts)

    @property
    def corridor_occupancy(self) -> float:
        """Mean |V'|/|V| over phase-2 chunks (1.0 when nothing compacted)."""
        if not self.corridor_total:
            return 1.0
        return self.corridor_active / self.corridor_total


class PatternRows(NamedTuple):
    """Per-pattern compiled plan rows (one row per DNF term) — everything
    in a ``QueryPlan`` that does not depend on the endpoints, so one cache
    entry serves every (u, v) pair asking the same composite pattern."""
    req_w: np.ndarray       # uint32 [T, Wl]
    forb_w: np.ndarray      # uint32 [T, Wl]
    forb_raw_w: np.ndarray  # uint32 [T, WL]
    req_labels: np.ndarray  # int32 [T, max_m]
    full_mask: np.ndarray   # int32 [T]

    @property
    def n_terms(self) -> int:
        return int(self.full_mask.shape[0])


PLAN_CACHE_CAP = 4096   # canonical patterns retained per index

# guards the per-index plan-cache dicts: the serving layer resolves
# patterns from many client threads concurrently with the scheduler
# thread, and the LRU pop/reinsert refresh is not atomic under the GIL
_plan_cache_lock = threading.Lock()


def _compile_pattern_rows(index: TDRIndex, p: pat.Pattern,
                          max_m: int) -> PatternRows:
    """Compile one pattern's DNF terms into packed plan rows."""
    cfg = index.cfg
    wl = bitset.n_words(cfg.lab_bits)
    wraw = bitset.n_words(max(index.graph.n_labels, 1))
    terms = pat.to_dnf(p)
    t_n = len(terms)
    req_w = np.zeros((t_n, wl), dtype=np.uint32)
    forb_w = np.zeros((t_n, wl), dtype=np.uint32)
    forb_raw_w = np.zeros((t_n, wraw), dtype=np.uint32)
    req_labels = np.full((t_n, max_m), -1, dtype=np.int32)
    full_mask = np.zeros(t_n, dtype=np.int32)
    req_j, req_l, forb_j, forb_l = [], [], [], []
    for j, term in enumerate(terms):
        if len(term.require) > max_m:
            raise ValueError(
                f"term with {len(term.require)} required labels exceeds "
                f"max_m={max_m}; decompose the pattern")
        rl = sorted(term.require)
        req_j += [j] * len(rl); req_l += rl
        forb_j += [j] * len(term.forbid); forb_l += sorted(term.forbid)
        req_labels[j, :len(rl)] = rl
        full_mask[j] = (1 << len(rl)) - 1
    if req_j:
        rj = np.asarray(req_j); rl = np.asarray(req_l, np.int64)
        bitset.set_bits_np(req_w, (rj,), index.lab_slot[rl])
    if forb_j:
        fj = np.asarray(forb_j); fl = np.asarray(forb_l, np.int64)
        bitset.set_bits_np(forb_w, (fj,), index.lab_slot[fl])
        bitset.set_bits_np(forb_raw_w, (fj,), fl)
    return PatternRows(req_w, forb_w, forb_raw_w, req_labels, full_mask)


def pattern_rows(index: TDRIndex, p: pat.Pattern, max_m: int = 4,
                 stats: "QueryStats | None" = None,
                 kind: str = "bool") -> PatternRows:
    """Cached plan rows for one pattern (hash-consed canonical key).

    The cache lives on the index (rows bake in ``lab_slot`` and the label
    word widths) and is a bounded LRU, so steady query traffic with
    repeated composite patterns skips DNF expansion and plane construction
    entirely — the serving layer leans on this for its plan cache.
    ``stats`` counts the lookup (and the miss, if any) exactly.  ``kind``
    partitions the LRU per query kind: the row *content* is
    kind-independent, but a shared entry must never let one kind's
    eviction/refresh pattern alias another's (the serving layer keys its
    result cache the same way)."""
    key = (pat.canonical_key(p), max_m, kind)
    if stats is not None:
        stats.plan_lookups += 1
    with _plan_cache_lock:
        cache = getattr(index, "_plan_cache", None)
        if cache is None:
            cache = {}
            index._plan_cache = cache
        rows = cache.get(key)
        if rows is not None:
            cache[key] = cache.pop(key)     # refresh LRU position
            return rows
    if stats is not None:
        stats.plan_misses += 1
    # DNF expansion + plane scatters run outside the lock (a slow first
    # compile of one pattern must not stall every other submitter)
    rows = _compile_pattern_rows(index, pat.canonicalize(p), max_m)
    with _plan_cache_lock:
        while len(cache) >= PLAN_CACHE_CAP:
            cache.pop(next(iter(cache)))
        cache[key] = rows
    return rows


def compile_queries(index: TDRIndex,
                    queries: Sequence[tuple[int, int, pat.Pattern]],
                    max_m: int = 4,
                    stats: "QueryStats | None" = None) -> QueryPlan:
    """Compile (u, v, pattern[, kind]) tuples into a ``QueryPlan``.

    Per-pattern rows come from the hash-consed plan cache
    (``pattern_rows``); only the endpoint columns and query-id row map are
    assembled fresh, so batches dominated by repeated patterns plan in
    O(n_queries) numpy concatenation.  The optional fourth element is one
    of ``QUERY_KINDS`` (default "bool"); it does not change the plan rows,
    only which executor the driver routes the query to."""
    cfg = index.cfg
    wl = bitset.n_words(cfg.lab_bits)
    wraw = bitset.n_words(max(index.graph.n_labels, 1))
    kinds = []
    norm = []
    for q in queries:
        kind = q[3] if len(q) > 3 else "bool"
        if kind not in QUERY_KINDS:
            raise ValueError(
                f"unknown query kind {kind!r}; expected one of "
                f"{QUERY_KINDS}")
        if kind == "rpq":
            raise ValueError(
                "kind='rpq' queries carry a repro.core.rpq AST, not a "
                "pattern; route them through rpq_batch / answer_mixed")
        kinds.append(kind)
        norm.append((q[0], q[1], q[2]))
    queries = norm
    rows_per_q = [pattern_rows(index, p, max_m, stats=stats)
                  for (_, _, p) in queries]
    counts = np.asarray([r.n_terms for r in rows_per_q], dtype=np.int64)

    def cat(name, empty_cols):
        parts = [getattr(r, name) for r in rows_per_q if r.n_terms]
        if not parts:
            dt = np.int32 if name in ("req_labels", "full_mask") else \
                np.uint32
            shape = (0,) if name == "full_mask" else (0, empty_cols)
            return np.zeros(shape, dtype=dt)
        return np.concatenate(parts)

    uv = np.asarray([(u, v) for (u, v, _) in queries],
                    dtype=np.int32).reshape(len(queries), 2)
    qid = np.repeat(np.arange(len(queries), dtype=np.int32), counts)
    return QueryPlan(
        qid=qid,
        u=np.repeat(uv[:, 0], counts),
        v=np.repeat(uv[:, 1], counts),
        req_w=cat("req_w", wl), forb_w=cat("forb_w", wl),
        forb_raw_w=cat("forb_raw_w", wraw),
        req_labels=cat("req_labels", max_m),
        full_mask=cat("full_mask", 0),
        n_queries=len(queries), max_m=max_m,
        kinds=tuple(kinds) if any(k != "bool" for k in kinds) else ())


# ----------------------------------------------------------- phase 1 (jit)
@functools.partial(jax.jit, static_argnames=("k", "mode"))
def _filter_cascade(u, v, req_w, forb_w, null_w,
                    vtx_packed, h_vtx, h_lab, v_vtx, v_lab,
                    n_out, n_in, sat_out, sat_in, push, pop,
                    *, k: int, mode: str):
    """Vectorised filter cascade -> verdict [J] in {FALSE, TRUE, UNKNOWN}.

    All label planes arrive packed; the per-way group predicate runs through
    ``kernels.ops.filter_ways`` (fused Pallas kernel / ref oracle).

    ``sat_out``/``sat_in`` are the level-1 row summaries of the compressed
    ``N_out``/``N_in`` planes (bool [V]): an ALL_ONE row contains every
    Bloom pattern, so its membership test is answered by the summary bit —
    bit-identical by construction, and on saturated traffic the word-level
    containment scan contributes nothing."""
    from repro.kernels import ops  # deferred: kernels import repro.core

    vbits = vtx_packed[v]            # [J, Wv]
    ubits = vtx_packed[u]

    req_empty = jnp.all(req_w == 0, axis=-1)
    forb_empty = jnp.all(forb_w == 0, axis=-1)

    # u == v: empty path
    same = u == v
    true_same = same & req_empty

    # global membership filters (sound negatives); summary-first: a
    # saturated row answers TRUE without the word-level containment
    topo_out = sat_out[u] | bitset.words_contain(n_out[u], vbits)
    topo_in = sat_in[v] | bitset.words_contain(n_in[v], ubits)
    topo_maybe = topo_out & topo_in

    # interval: DFS-forest ancestor => topologically reachable (sound positive)
    anc = (push[u] < push[v]) & (pop[v] < pop[u])
    true_anc = anc & req_empty & forb_empty & ~same

    # ---- per-way group pruning (fused kernel) ----
    way_ok = ops.filter_ways(h_vtx[u], h_lab[u], v_vtx[u], v_lab[u],
                             vbits, req_w, forb_w, null_w, mode=mode)
    any_way = jnp.any(way_ok, axis=-1)

    maybe = topo_maybe & (any_way | same)
    verdict = jnp.where(true_same | true_anc, TRUE,
                        jnp.where(maybe, UNKNOWN, FALSE))
    # u==v with required labels: no path; it's FALSE only if no self-loop
    # cycle can satisfy -- conservative: keep UNKNOWN path for same-vertex
    # queries with labels (cycles through u can satisfy the pattern).
    verdict = jnp.where(same & ~req_empty,
                        jnp.where(any_way, UNKNOWN, FALSE), verdict)
    return verdict


# ----------------------------------------------------------- phase 2 (jit)
def _state_has_masks(n_states: int, max_m: int) -> np.ndarray:
    """HAS[i] = packed mask of subset-states whose bit i is set."""
    has = np.zeros(max(max_m, 1), dtype=np.uint32)
    for i in range(max_m):
        for s in range(n_states):
            if (s >> i) & 1:
                has[i] |= np.uint32(1) << np.uint32(s)
    return has


def _sup_table(n_states: int) -> np.ndarray:
    """SUP[t] = packed mask of subset-states s with ``s ⊇ t``."""
    sup = np.zeros(n_states, dtype=np.uint32)
    for t in range(n_states):
        for s in range(n_states):
            if s & t == t:
                sup[t] |= np.uint32(1) << np.uint32(s)
    return sup


def _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed):
    """Packed Bloom corridor ``V_out(u) ∩ V_in(v)`` as a [V, Q] word mask
    (all-ones where vertex x may lie on a u→v path)."""
    q_n = u.shape[0]
    cor = (bitset.words_contain(n_out_u[:, None, :], vtx_packed[None, :, :]) &
           bitset.words_contain(n_in_v[:, None, :], vtx_packed[None, :, :]))
    cor = cor.at[jnp.arange(q_n), v].set(True)
    cor = cor.at[jnp.arange(q_n), u].set(True)
    return bitset.full_words_where(cor.T)                # [V, Q]


class PlanDevice(NamedTuple):
    """Device-resident mirror of the plan's job-axis arrays — transferred
    once per batch; chunks ship only their job-id rows and gather in-jit.
    (A NamedTuple so jit treats it as a pytree of arrays.)"""
    u: Any
    v: Any
    req_labels: Any
    forb_raw_w: Any
    full_mask: Any


@jax.jit
def _corridor_member(jobs, plan_u, plan_v, n_out, n_in, vtx_packed):
    """Corridor membership bool [J, V] (endpoints always members)."""
    u, v = plan_u[jobs], plan_v[jobs]
    mem = (bitset.words_contain(n_out[u][:, None, :], vtx_packed[None, :, :])
           & bitset.words_contain(n_in[v][:, None, :],
                                  vtx_packed[None, :, :]))
    iota = jnp.arange(u.shape[0])
    return mem.at[iota, v].set(True).at[iota, u].set(True)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _corridor_chunk_counts(jobs, plan_u, plan_v, n_out, n_in, vtx_packed,
                           *, chunk: int):
    """Exact per-chunk corridor-*union* size int32 [J/chunk] (the
    compaction probe: one tiny transfer instead of shipping [J, V]
    membership to the host; per-job sums would badly over-estimate the
    union when corridors overlap)."""
    mem = _corridor_member(jobs, plan_u, plan_v, n_out, n_in, vtx_packed)
    union = mem.reshape(-1, chunk, mem.shape[1]).any(axis=1)
    return union.sum(axis=1, dtype=jnp.int32)


def _transition(val, has, sh):
    """Apply subset transition ``s -> s | m`` to packed state bitfields.

    ``has`` masks the state bits whose subset already contains the edge's
    required label (they stay); the rest shift up by ``sh = 2^i`` (setting
    bit i of the subset index).  ``has = ~0, sh = 0`` is the identity."""
    return (val & has) | ((val & ~has) << sh)


def _edge_state_masks(lab, req_labels, forb_raw_w, n_states: int, max_m: int,
                      neutral=None):
    """Per-(edge|class, query) transition operands ``(allow, has, sh)``.

    ``lab`` is the per-edge (or per-label-class) raw label id; ``neutral``
    marks class rows that merge all labels special for nobody (always
    allowed, identity transition).  The forbid test reads the *raw* packed
    forbidden rows — slot hashing may collide and the exact phase must not
    over-forbid."""
    q_n = req_labels.shape[0]
    labx = jnp.maximum(lab, 0)
    okbit = (forb_raw_w[:, labx >> 5] >>
             (labx & 31).astype(jnp.uint32)[None, :]) & 1       # [Q, E|C]
    allow_b = okbit == 0
    if neutral is not None:
        allow_b = neutral[None, :] | allow_b
    allow = bitset.full_words_where(allow_b).T                  # [E|C, Q]
    has_c = _state_has_masks(n_states, max_m)
    has = jnp.full((lab.shape[0], q_n), _FULL, jnp.uint32)
    sh = jnp.zeros((lab.shape[0], q_n), jnp.uint32)
    for i in range(max_m):  # static unroll; require-sets hold distinct labels
        match = req_labels[:, i][None, :] == lab[:, None]
        if neutral is not None:
            match = match & ~neutral[:, None]
        has = jnp.where(match, jnp.uint32(has_c[i]), has)
        sh = jnp.where(match, jnp.uint32(1 << i), sh)
    return allow, has, sh


def _sup_need(full_mask, n_states: int):
    """sup_need[s1, q] = packed mask of backward states completing s1 to
    ``full_mask[q]`` (s2 with ``s1 | s2 ⊇ full``)."""
    sup = jnp.asarray(_sup_table(n_states))
    rows = [sup[full_mask & ((n_states - 1) & ~s1)]
            for s1 in range(n_states)]
    return jnp.stack(rows)                                      # [S, Q]


def _meet(f, b, sup_need):
    """done[q] = ∃ vertex x, states s1 ∈ f[x,q], s2 ∈ b[x,q] with
    ``s1 | s2 == full_mask[q]`` (the bidirectional termination test)."""
    n_states = sup_need.shape[0]
    shifts = jnp.arange(n_states, dtype=jnp.uint32)
    fb = (f[None, :, :] >> shifts[:, None, None]) & jnp.uint32(1)  # [S,V,Q]
    hit = (b[None, :, :] & sup_need[:, None, :]) != 0
    return jnp.any((fb != 0) & hit, axis=(0, 1))


def _bidi_loop(f0, b0, push_f, push_b, cor_w, sup_need, max_rounds: int):
    """Alternating bidirectional fixpoint.  One iteration = one forward +
    one backward expansion; a query's columns freeze once it meets, and
    ``changed`` is derived from the rounds' own new bits (``upd & ~f``) —
    no second full-frontier compare."""

    def cond(st):
        _, _, done, (cf, cb), it = st
        return (cf | cb) & ~jnp.all(done) & (it < max_rounds)

    def body(st):
        f, b, done, (cf, cb), it = st
        mask = cor_w & bitset.full_words_where(~done)[None, :]
        # a direction whose last push added nothing is at its fixpoint
        # (monotone, and the live mask only shrinks) — skip its push
        new_f = jax.lax.cond(cf, lambda a: push_f(a) & mask & ~a,
                             jnp.zeros_like, f)
        f = f | new_f
        new_b = jax.lax.cond(cb, lambda a: push_b(a) & mask & ~a,
                             jnp.zeros_like, b)
        b = b | new_b
        done = done | _meet(f, b, sup_need)
        return (f, b, done,
                (jnp.any(new_f != 0), jnp.any(new_b != 0)), it + 1)

    st0 = (f0, b0, _meet(f0, b0, sup_need),
           (jnp.bool_(True), jnp.bool_(True)), jnp.int32(0))
    _, _, done, _, rounds = jax.lax.while_loop(cond, body, st0)
    return done, rounds


def _bidi_segment_core(su, sv, req_labels, forb_raw_w, full_mask, cor_w,
                       sub_lab, sub_src, sub_dst, ids_in, ids_out,
                       n_states: int, max_m: int, max_rounds: int,
                       chunk_words: int):
    """Segment-backend bidirectional fixpoint over a (sub)graph's edge
    lists.  ``ids_in`` / ``ids_out`` are padded incidence gather matrices
    (edge ids grouped by dst / src, ``E'`` = sentinel pointing at an
    appended zero row) — when they are ``None`` the OR-reduction falls
    back to packed segment reductions (hub-skewed graphs where padding
    would blow the cap)."""
    q_n = su.shape[0]
    v_p = cor_w.shape[0]
    allow, has, sh = _edge_state_masks(sub_lab, req_labels, forb_raw_w,
                                       n_states, max_m)
    sup_need = _sup_need(full_mask, n_states)
    iota = jnp.arange(q_n)
    f0 = jnp.zeros((v_p, q_n), jnp.uint32).at[su, iota].set(jnp.uint32(1))
    b0 = jnp.zeros((v_p, q_n), jnp.uint32).at[sv, iota].set(jnp.uint32(1))

    def reduce_cols(val, ids):
        # per-incidence-column gathers accumulate without the [V', D, Q]
        # transient a single 3D gather would materialize (3× faster on CPU)
        out = val[ids[:, 0]]
        for j in range(1, ids.shape[1]):  # static unroll over D columns
            out = out | val[ids[:, j]]
        return out

    def push(frontier, gather_idx, ids, scatter_idx):
        val = _transition(frontier[gather_idx] & allow, has, sh)  # [E', Q]
        if ids is None:
            return bitset.segment_or_words(val, scatter_idx,
                                           num_segments=v_p,
                                           chunk_words=chunk_words)
        val = jnp.concatenate(
            [val, jnp.zeros((1, q_n), jnp.uint32)], axis=0)
        for level in ids:   # 1 level, or virtual-row split on heavy tails
            val = reduce_cols(val, level)
        return val                                               # [V', Q]

    return _bidi_loop(
        f0, b0,
        lambda f: push(f, sub_src, ids_in, sub_dst),
        lambda b: push(b, sub_dst, ids_out, sub_src),
        cor_w, sup_need, max_rounds)


def _job_rows(jobs, dev: PlanDevice, m_eff: int):
    """Gather a chunk's plan rows on device (jobs is the only transfer)."""
    return (dev.req_labels[jobs][:, :m_eff], dev.forb_raw_w[jobs],
            dev.full_mask[jobs])


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "chunk_words"))
def _expand_bidi(jobs, dev, su, sv, cor, sub_lab, sub_src, sub_dst,
                 ids_in, ids_out, *, n_states: int, max_m: int,
                 max_rounds: int, chunk_words: int):
    """Compacted-subgraph entry: ``cor`` is the per-query corridor
    membership bool [V', Q] extracted on the host during compaction;
    ``su``/``sv`` are the renumbered endpoints."""
    req_labels, forb_raw_w, full_mask = _job_rows(jobs, dev, max_m)
    return _bidi_segment_core(
        su, sv, req_labels, forb_raw_w, full_mask,
        bitset.full_words_where(cor), sub_lab, sub_src, sub_dst,
        ids_in, ids_out, n_states, max_m, max_rounds, chunk_words)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "chunk_words"))
def _expand_bidi_full(jobs, dev, n_out, n_in, vtx_packed, sub_lab,
                      sub_src, sub_dst, ids_in, ids_out, *, n_states: int,
                      max_m: int, max_rounds: int, chunk_words: int):
    """Full-graph entry for near-total corridors: endpoints and corridor
    mask both derived on device — no host membership round-trip."""
    req_labels, forb_raw_w, full_mask = _job_rows(jobs, dev, max_m)
    u, v = dev.u[jobs], dev.v[jobs]
    cor_w = _corridor_mask(u, v, n_out[u], n_in[v], vtx_packed)
    return _bidi_segment_core(
        u, v, req_labels, forb_raw_w, full_mask, cor_w, sub_lab,
        sub_src, sub_dst, ids_in, ids_out, n_states, max_m, max_rounds,
        chunk_words)


def _bidi_matmul_core(su, sv, adj_rev, adj_fwd, class_label, req_labels,
                      forb_raw_w, full_mask, cor_w, n_states: int,
                      max_m: int, max_rounds: int, mode: str):
    """Pallas-backend bidirectional fixpoint: one ``bitset_matmul`` per
    label class per direction per round, on packed (sub-)adjacency
    bit-matrices (forward frontier uses the reverse matrices, backward the
    forward ones)."""
    q_n = su.shape[0]
    v_p = cor_w.shape[0]
    neutral = class_label < 0
    allow, has, sh = _edge_state_masks(class_label, req_labels, forb_raw_w,
                                       n_states, max_m, neutral=neutral)
    sup_need = _sup_need(full_mask, n_states)
    iota = jnp.arange(q_n)
    f0 = jnp.zeros((v_p, q_n), jnp.uint32).at[su, iota].set(jnp.uint32(1))
    b0 = jnp.zeros((v_p, q_n), jnp.uint32).at[sv, iota].set(jnp.uint32(1))

    def push(frontier, adj_set):
        # scan (not unroll) over label classes: one kernel call *site* per
        # direction keeps the while-loop body's XLA program small — an
        # unrolled 2·(C+1) pallas calls per round made compiles explode
        def body(upd, operand):
            adj_c, allow_c, has_c, sh_c = operand
            y = engine_mod._matmul_rows(adj_c, frontier, mode)[:v_p]
            return upd | _transition(y & allow_c[None, :],
                                     has_c[None, :], sh_c[None, :]), None
        upd, _ = jax.lax.scan(body, jnp.zeros_like(frontier),
                              (adj_set, allow, has, sh))
        return upd

    return _bidi_loop(
        f0, b0,
        lambda f: push(f, adj_rev),
        lambda b: push(b, adj_fwd),
        cor_w, sup_need, max_rounds)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "mode"))
def _expand_bidi_matmul(jobs, dev, su, sv, adj_rev, adj_fwd, class_label,
                        cor, *, n_states: int, max_m: int, max_rounds: int,
                        mode: str):
    """Compacted-subgraph entry (``cor`` = membership bool [V', Q])."""
    req_labels, forb_raw_w, full_mask = _job_rows(jobs, dev, max_m)
    return _bidi_matmul_core(
        su, sv, adj_rev, adj_fwd, class_label, req_labels, forb_raw_w,
        full_mask, bitset.full_words_where(cor), n_states, max_m,
        max_rounds, mode)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "mode"))
def _expand_bidi_matmul_full(jobs, dev, adj_rev, adj_fwd, class_label,
                             n_out, n_in, vtx_packed, *, n_states: int,
                             max_m: int, max_rounds: int, mode: str):
    """Full-graph entry: corridor mask built on device from the Blooms."""
    req_labels, forb_raw_w, full_mask = _job_rows(jobs, dev, max_m)
    u, v = dev.u[jobs], dev.v[jobs]
    cor_w = _corridor_mask(u, v, n_out[u], n_in[v], vtx_packed)
    return _bidi_matmul_core(
        su=u, sv=v, adj_rev=adj_rev, adj_fwd=adj_fwd,
        class_label=class_label, req_labels=req_labels,
        forb_raw_w=forb_raw_w, full_mask=full_mask, cor_w=cor_w,
        n_states=n_states, max_m=max_m, max_rounds=max_rounds, mode=mode)


# ------------------------------------------------- legacy (PR-1) executors
def _expand_loop(f0, upd_of, v, full_mask, max_rounds):
    """One-directional fixpoint driver (retained full-V path): iterate
    until every query's target state bit is set, nothing changes, or
    ``max_rounds`` is hit.  Finished queries' columns freeze and the
    ``changed`` flag is derived from the round's own new bits."""
    q_n = v.shape[0]

    def done_of(f):
        return (f[v, jnp.arange(q_n)] >>
                full_mask.astype(jnp.uint32)) & 1 == 1

    def cond(state):
        _, done, changed, it = state
        return changed & ~jnp.all(done) & (it < max_rounds)

    def body(state):
        f, done, _, it = state
        live = bitset.full_words_where(~done)[None, :]
        new = upd_of(f) & ~f & live
        f = f | new
        return f, done | done_of(f), jnp.any(new != 0), it + 1

    st0 = (f0, done_of(f0), jnp.bool_(True), jnp.int32(0))
    f, done, _, rounds = jax.lax.while_loop(cond, body, st0)
    return done, rounds


@functools.partial(jax.jit, static_argnames=("v_n", "n_states", "max_m",
                                             "max_rounds", "chunk_words"))
def _expand_segment(u, v, req_labels, forb_raw_w, full_mask,
                    n_out_u, n_in_v, vtx_packed, elab, edge_src, edge_dst,
                    *, v_n: int, n_states: int, max_m: int, max_rounds: int,
                    chunk_words: int):
    """Legacy segment executor: full-graph frontier [V, Q]; one round =
    gather, per-edge transition, packed segment-OR scatter."""
    q_n = u.shape[0]
    cor_mask = _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed)
    allow, has, sh = _edge_state_masks(elab, req_labels, forb_raw_w,
                                       n_states, max_m)

    f0 = jnp.zeros((v_n, q_n), jnp.uint32)
    f0 = f0.at[u, jnp.arange(q_n)].set(jnp.uint32(1))   # state ∅ at source

    def upd_of(f):
        val = _transition(f[edge_src] & allow, has, sh)         # [E, Q]
        upd = bitset.segment_or_words(val, edge_dst, num_segments=v_n,
                                      chunk_words=chunk_words)
        return upd & cor_mask

    return _expand_loop(f0, upd_of, v, full_mask, max_rounds)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "mode"))
def _expand_matmul(u, v, class_adj, class_label, req_labels, forb_raw_w,
                   full_mask, n_out_u, n_in_v, vtx_packed, *,
                   n_states: int, max_m: int, max_rounds: int, mode: str):
    """Legacy pallas executor: one ``bitset_matmul`` per label class per
    round on the packed full-graph reverse adjacency."""
    q_n = u.shape[0]
    cor_mask = _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed)
    neutral = class_label < 0
    allow, has, sh = _edge_state_masks(class_label, req_labels, forb_raw_w,
                                       n_states, max_m, neutral=neutral)

    v_n = vtx_packed.shape[0]
    f0 = jnp.zeros((v_n, q_n), jnp.uint32)
    f0 = f0.at[u, jnp.arange(q_n)].set(jnp.uint32(1))

    def upd_of(f):
        upd = jnp.zeros_like(f)
        for c in range(class_adj.shape[0]):  # static unroll, C small
            y = engine_mod._matmul_rows(class_adj[c], f, mode)[:v_n]
            upd = upd | _transition(y & allow[c][None, :],
                                    has[c][None, :], sh[c][None, :])
        return upd & cor_mask

    return _expand_loop(f0, upd_of, v, full_mask, max_rounds)


# ---------------------------------------------------------------- executor
@dataclasses.dataclass
class ChunkResult:
    """Un-synced result of one dispatched chunk (device handles)."""
    jobs: np.ndarray        # padded job ids [Q]
    real_n: int
    reached: Any            # device (or host) bool [Q]
    rounds: Any             # device int32 scalar (or int)
    n_active: int = 0       # |V'| this chunk ran on
    v_total: int = 0        # |V| of the full graph


class ExactExecutor:
    """Persistent phase-2 executor bound to one (index, engine) pair.

    Holds the device-resident operands (edge lists, label rows, Blooms,
    cached full-graph incidence) plus host mirrors for per-chunk corridor
    compaction, and keeps the jitted expansion entry points warm across
    ``answer_batch`` calls.  Chunk shapes (|V'|, |E'|, incidence width)
    are padded to power-of-two buckets so recompiles stay bounded.
    ``dispatch_chunk`` never blocks: it returns device handles that the
    driver collects once all chunks are in flight."""

    # cap on the padded-incidence gather transient (bytes); beyond it the
    # round falls back to packed segment reductions (extreme hub skew)
    GATHER_BYTES_CAP = 1 << 28

    def __init__(self, index: TDRIndex, eng: "engine_mod.Engine"):
        self.index = index
        self.engine = eng
        g = index.graph
        self.elab = jnp.asarray(g.labels)
        self.src_np = g.src
        self.dst_np = np.asarray(g.indices)
        self.lab_np = np.asarray(g.labels)
        self._full_inc: tuple | None = None   # cached full-graph incidence

    def special_labels(self, plan: QueryPlan,
                       jobs: np.ndarray) -> tuple[int, ...]:
        """Labels some pending job requires or forbids (the matmul backend
        gets one adjacency class per special label + one neutral)."""
        req = plan.req_labels[jobs]
        spec = set(int(l) for l in req[req >= 0])
        forb = np.bitwise_or.reduce(plan.forb_raw_w[jobs], axis=0)
        bits = np.unpackbits(forb.astype("<u4").view(np.uint8),
                             bitorder="little")
        spec.update(np.flatnonzero(bits).tolist())
        return tuple(sorted(spec))

    def eff_states(self, plan: QueryPlan, jobs: np.ndarray,
                   pin_m: int | None = None) -> tuple[int, int]:
        """(m_eff, n_states) for the pending set: the widest require-set
        actually present, not the plan-level ``max_m`` cap.  ``pin_m``
        (serving) raises it to a fixed floor so steady traffic keeps one
        static state width per chunk shape instead of recompiling per
        batch composition."""
        m_eff = int((plan.req_labels[jobs] >= 0).sum(axis=1).max(initial=0))
        if pin_m is not None:
            m_eff = min(max(m_eff, pin_m), plan.max_m)
        return m_eff, 1 << m_eff

    # ------------------------------------------------------------ planning
    def _sliced_corridor(self, dev: PlanDevice, jobs: np.ndarray, fn,
                         out: np.ndarray) -> np.ndarray:
        """Run a per-job corridor jit over bounded-shape job slices."""
        idx = self.index
        p_n = len(jobs)
        step = 256
        for c0 in range(0, p_n, step):
            sl = jobs[c0:c0 + step]
            jp = graph_mod.pad_pow2(len(sl), lo=16)
            pj = np.concatenate(
                [sl, np.full(jp - len(sl), sl[0], sl.dtype)])
            res = np.asarray(fn(
                jnp.asarray(pj.astype(np.int32)), dev.u, dev.v,
                idx.n_out, idx.n_in, idx.vtx_packed))
            out[c0:c0 + step] = res[:len(sl)]
        return out

    def chunk_union_counts(self, dev: PlanDevice, jobs: np.ndarray,
                           chunk: int) -> np.ndarray:
        """Exact corridor-union size per ``chunk``-sized job group (the
        cheap compaction probe).  Tail groups are padded with their own
        first job so the union is not polluted across chunks."""
        idx = self.index
        starts = range(0, len(jobs), chunk)
        out = np.empty(len(starts), dtype=np.int32)
        step = max(chunk, (256 // chunk) * chunk)
        padded = []
        for c0 in starts:
            grp = jobs[c0:c0 + chunk]
            if len(grp) < chunk:
                grp = np.concatenate(
                    [grp, np.full(chunk - len(grp), grp[0], grp.dtype)])
            padded.append(grp)
        pj = np.concatenate(padded)
        for i0 in range(0, len(pj), step):
            sl = pj[i0:i0 + step]
            if len(sl) < step:   # pad with whole dummy chunks of sl[0]
                sl = np.concatenate(
                    [sl, np.full(step - len(sl), sl[0], sl.dtype)])
            res = np.asarray(_corridor_chunk_counts(
                jnp.asarray(sl.astype(np.int32)), dev.u, dev.v,
                idx.n_out, idx.n_in, idx.vtx_packed, chunk=chunk))
            n = min(len(res), len(out) - i0 // chunk)
            out[i0 // chunk:i0 // chunk + n] = res[:n]
        return out

    def corridor_members(self, dev: PlanDevice,
                         jobs: np.ndarray) -> np.ndarray:
        """Corridor membership bool [P, V] (fetched only for the jobs of
        chunks that will actually compact)."""
        return self._sliced_corridor(
            dev, jobs, _corridor_member,
            np.empty((len(jobs), self.index.graph.n_vertices), dtype=bool))

    # ------------------------------------------------------------ dispatch
    def dispatch_chunk(self, plan: QueryPlan, dev: PlanDevice | None,
                       jobs: np.ndarray,
                       member: np.ndarray | None, special: tuple[int, ...],
                       mode: str, pin_m: int | None = None) -> ChunkResult:
        """Dispatch one padded chunk of pending jobs -> ``ChunkResult``
        holding un-synced device handles."""
        if mode == "legacy":
            reached, rounds = self._run_legacy(plan, jobs, special)
            return ChunkResult(jobs, len(jobs), reached, rounds,
                               self.index.graph.n_vertices,
                               self.index.graph.n_vertices)
        return self._run_bidi(plan, dev, jobs, member, special, mode, pin_m)

    def _run_bidi(self, plan: QueryPlan, dev: PlanDevice,
                  jobs: np.ndarray,
                  member: np.ndarray | None, special: tuple[int, ...],
                  mode: str, pin_m: int | None = None) -> ChunkResult:
        """``member is None`` -> full-graph bidi (corridor built on
        device); else corridor compaction over the member rows."""
        idx, eng = self.index, self.engine
        g = idx.graph
        q_n = len(jobs)
        v_n = g.n_vertices
        m_eff, n_states = self.eff_states(plan, jobs, pin_m)
        if n_states > 32:
            raise ValueError(
                f"max_m={m_eff} needs {n_states} subset states; the packed "
                "executor holds at most 32 (max_m <= 5)")

        compacted = member is not None
        if compacted:
            active = member.any(axis=0)
            n_sub = int(active.sum())
            v_p = graph_mod.pad_bucket(n_sub, lo=32)
            if v_p >= v_n and mode == "auto":
                compacted = False   # probe over-estimated; run full
        if compacted:
            sub_ids, renum, s, d, l = graph_mod.induced_edges(
                g, active, src=self.src_np)
            if s.shape[0] == 0:
                # corridor holds no edges: only the empty path exists, and
                # phase 1 already answered those — nothing is reachable
                return ChunkResult(jobs, q_n, np.zeros(q_n, bool), 0,
                                   n_sub, v_n)
            cor = np.zeros((v_p, q_n), dtype=bool)
            cor[:n_sub] = member[:, sub_ids].T
            su = renum[plan.u[jobs]]
            sv = renum[plan.v[jobs]]
        else:
            # endpoints resolve on device (dev.u[jobs]) in the full path
            n_sub = v_p = v_n
            s, d, l = self.src_np, self.dst_np, self.lab_np

        max_rounds = v_p * n_states + 1
        jobs_j = jnp.asarray(jobs.astype(np.int32))

        use_matmul = eng.backend == "pallas"
        if use_matmul:
            kw = bitset.n_words(v_p)
            n_mats = 2 * (len(special) + 1)
            if n_mats * v_p * kw * 4 > eng.config.max_dense_bytes:
                warnings.warn(
                    f"engine: {n_mats} label-class adjacency matrices "
                    "exceed max_dense_bytes; expanding this chunk via the "
                    "segment path", stacklevel=3)
                use_matmul = False

        if use_matmul:
            class_label = jnp.asarray(np.asarray(special + (-1,), np.int32))
            if compacted:
                adj_rev = jnp.asarray(engine_mod.pack_label_class_edges_np(
                    s, d, l, v_p, special, reverse=True))
                adj_fwd = jnp.asarray(engine_mod.pack_label_class_edges_np(
                    s, d, l, v_p, special, reverse=False))
                reached, rounds = _expand_bidi_matmul(
                    jobs_j, dev, jnp.asarray(su), jnp.asarray(sv),
                    adj_rev, adj_fwd, class_label, jnp.asarray(cor),
                    n_states=n_states, max_m=m_eff, max_rounds=max_rounds,
                    mode=eng.matmul_mode)
            else:
                adj_rev = eng.label_class_adjacency(special, reverse=True)
                adj_fwd = eng.label_class_adjacency(special, reverse=False)
                reached, rounds = _expand_bidi_matmul_full(
                    jobs_j, dev, adj_rev, adj_fwd, class_label, idx.n_out,
                    idx.n_in, idx.vtx_packed, n_states=n_states,
                    max_m=m_eff, max_rounds=max_rounds,
                    mode=eng.matmul_mode)
            return ChunkResult(jobs, q_n, reached, rounds, n_sub, v_n)

        if compacted:
            e_real = s.shape[0]
            e_p = graph_mod.pad_bucket(e_real, lo=32)
            if e_p > e_real:   # bucket |E'|; padding rows duplicate edge 0
                rep = e_p - e_real
                s = np.concatenate([s, np.repeat(s[:1], rep)])
                d = np.concatenate([d, np.repeat(d[:1], rep)])
                l = np.concatenate([l, np.repeat(l[:1], rep)])
            ids_in = graph_mod.incidence_plan(d[:e_real], v_p, e_p)
            ids_out = graph_mod.incidence_plan(s[:e_real], v_p, e_p)
            lab_j, s_j, d_j = jnp.asarray(l), jnp.asarray(s), jnp.asarray(d)
            # extreme skew beyond what the virtual-row split absorbs:
            # over the cap, skip the device transfer and fall back to
            # packed segment reductions on the same edge arrays
            if (sum(a.size for a in ids_in + ids_out) * q_n * 4
                    > self.GATHER_BYTES_CAP):
                in_j = out_j = None
            else:
                in_j = tuple(jnp.asarray(a) for a in ids_in)
                out_j = tuple(jnp.asarray(a) for a in ids_out)
        else:
            lab_j, s_j, d_j, in_j, out_j = self._full_incidence()
            if (sum(a.size for a in in_j + out_j) * q_n * 4
                    > self.GATHER_BYTES_CAP):
                in_j = out_j = None
        kw = dict(n_states=n_states, max_m=m_eff, max_rounds=max_rounds,
                  chunk_words=eng.config.chunk_words)
        if compacted:
            reached, rounds = _expand_bidi(
                jobs_j, dev, jnp.asarray(su), jnp.asarray(sv),
                jnp.asarray(cor), lab_j, s_j, d_j, in_j, out_j, **kw)
        else:
            reached, rounds = _expand_bidi_full(
                jobs_j, dev, idx.n_out, idx.n_in, idx.vtx_packed,
                lab_j, s_j, d_j, in_j, out_j, **kw)
        return ChunkResult(jobs, q_n, reached, rounds, n_sub, v_n)

    def _full_incidence(self):
        """Cached full-graph operand tuple for near-total corridors."""
        if self._full_inc is None:
            g = self.index.graph
            e_n = g.n_edges
            ids_in = graph_mod.incidence_plan(self.dst_np, g.n_vertices,
                                              e_n)
            ids_out = graph_mod.incidence_plan(self.src_np, g.n_vertices,
                                               e_n)
            self._full_inc = (
                self.elab, self.engine.edge_src, self.engine.edge_dst,
                tuple(jnp.asarray(a) for a in ids_in),
                tuple(jnp.asarray(a) for a in ids_out))
        return self._full_inc

    def _run_legacy(self, plan: QueryPlan, jobs: np.ndarray,
                    special: tuple[int, ...]):
        """PR-1 one-directional full-graph expansion (kept as comparison
        oracle and ``exact_mode="legacy"``)."""
        idx, eng = self.index, self.engine
        g = idx.graph
        n_states = 1 << plan.max_m
        if n_states > 32:
            raise ValueError(
                f"max_m={plan.max_m} needs {n_states} subset states; the "
                "packed executor holds at most 32 (max_m <= 5)")
        max_rounds = g.n_vertices * n_states + 1
        uu = jnp.asarray(plan.u[jobs])
        vv = jnp.asarray(plan.v[jobs])
        req_labels = jnp.asarray(plan.req_labels[jobs])
        forb_raw_w = jnp.asarray(plan.forb_raw_w[jobs])
        full_mask = jnp.asarray(plan.full_mask[jobs])
        n_out_u, n_in_v = idx.n_out[uu], idx.n_in[vv]
        use_matmul = eng.backend == "pallas"
        if use_matmul and not eng.can_pack_dense(len(special) + 1):
            # the class-matrix set would blow the dense cap the engine
            # promised to respect — run this batch's rounds as packed
            # segment reductions instead (same bits, no dense operand)
            warnings.warn(
                f"engine: {len(special) + 1} label-class adjacency "
                "matrices exceed max_dense_bytes; expanding this batch "
                "via the segment path", stacklevel=3)
            use_matmul = False
        if use_matmul:
            class_adj = eng.label_class_adjacency(special)
            class_label = jnp.asarray(np.asarray(special + (-1,), np.int32))
            reached, rounds = _expand_matmul(
                uu, vv, class_adj, class_label, req_labels, forb_raw_w,
                full_mask, n_out_u, n_in_v, idx.vtx_packed,
                n_states=n_states, max_m=plan.max_m, max_rounds=max_rounds,
                mode=eng.matmul_mode)
        else:
            reached, rounds = _expand_segment(
                uu, vv, req_labels, forb_raw_w, full_mask, n_out_u, n_in_v,
                idx.vtx_packed, self.elab, eng.edge_src, eng.edge_dst,
                v_n=g.n_vertices, n_states=n_states, max_m=plan.max_m,
                max_rounds=max_rounds,
                chunk_words=eng.config.chunk_words)
        return reached, rounds


def _executor(index: TDRIndex, eng: "engine_mod.Engine") -> ExactExecutor:
    ex = getattr(eng, "_executor", None)
    if ex is None or ex.index is not index:
        ex = ExactExecutor(index, eng)
        eng._executor = ex
    return ex


# ----------------------------------------------------------------- driver
@functools.lru_cache(maxsize=8)
def _null_words_dev(cfg) -> jax.Array:
    """Device copy of the packed NULL plane (keyed by the frozen config)."""
    return jnp.asarray(_null_words(cfg))


def answer_batch(index: TDRIndex,
                 queries: Sequence[tuple[int, int, pat.Pattern]],
                 *, max_m: int = 4, exact_chunk: int = 32,
                 stats: QueryStats | None = None,
                 filters_only: bool = False,
                 backend: str | None = None,
                 exact_mode: str = "auto",
                 engine_config: "engine_mod.EngineConfig | None" = None,
                 mesh=None) -> np.ndarray:
    """Answer a batch of PCR queries.  Returns bool [n_queries].

    Compilation goes through the hash-consed pattern-plan cache
    (``pattern_rows``); answering is ``answer_plan`` — callers that manage
    their own plans and padding (the serving scheduler) use that entry
    point directly.
    """
    t0 = time.perf_counter()
    plan = compile_queries(index, queries, max_m=max_m, stats=stats)
    return answer_plan(index, plan, exact_chunk=exact_chunk, stats=stats,
                       filters_only=filters_only, backend=backend,
                       exact_mode=exact_mode, engine_config=engine_config,
                       mesh=mesh, _t0=t0)


def answer_plan(index: TDRIndex, plan: QueryPlan,
                *, exact_chunk: int = 32,
                stats: QueryStats | None = None,
                filters_only: bool = False,
                backend: str | None = None,
                exact_mode: str = "auto",
                engine_config: "engine_mod.EngineConfig | None" = None,
                mesh=None,
                special_labels: Sequence[int] | None = None,
                pin_m: int | None = None,
                pad_lo: int = 16,
                _t0: float | None = None) -> np.ndarray:
    """Answer a compiled ``QueryPlan``.  Returns bool [plan.n_queries].

    ``backend``/``engine_config`` select the packed-word engine backend for
    phase 2 (and the kernel mode for phase 1); default follows the
    ``repro.core.engine`` contract.  ``exact_mode`` picks the phase-2
    executor: "auto" (bidirectional, corridor-compacted whenever the
    padded corridor bucket is smaller than V), "compact" (force
    compaction), "full" (bidirectional on the full graph), or "legacy"
    (the retained PR-1 one-directional executor).

    The job axis is padded onto the ``{2^k, 3·2^(k-1)}`` bucket grid
    (``graph.pad_bucket``, via ``QueryPlan.pad_to``; ``pad_lo`` is the
    grid floor — the serving scheduler passes its own so its warmed grid
    and live batches agree), so jit shapes under varying batch sizes stay
    on a logarithmic grid of variants.  The
    serving scheduler pre-compiles that grid and pins the two
    content-dependent statics — ``pin_m`` fixes the subset-state width,
    ``special_labels`` fixes the label-class set (it is unioned with the
    labels the batch actually needs, so a pin can widen but never break
    correctness) — which makes steady-state traffic recompile-free.

    ``mesh`` (a ``jax.sharding.Mesh``) distributes the batch: the phase-1
    cascade runs with the job axis sharded over every device
    (``repro.core.distributed.filter_cascade_sharded``; the index planes
    are broadcast, the plan rows are the only sharded traffic) and
    compacted phase-2 expansion chunks are round-robined across the
    mesh's devices — chunk dispatch never blocks, so devices expand
    concurrently, while full-graph chunks stay with the shared V-sized
    operands on the lead device.  Answers are bit-identical to the
    single-device path.
    """
    if plan.max_m > 5:
        raise ValueError(
            f"max_m={plan.max_m}: the packed executor holds subset states "
            "in one uint32 bitfield, so at most 5 required labels per term "
            "(32 states); decompose the pattern")
    if exact_mode not in EXACT_MODES:
        raise ValueError(f"unknown exact_mode {exact_mode!r}; expected one "
                         f"of {EXACT_MODES}")
    if any(k != "bool" for k in plan.kinds):
        raise ValueError(
            "answer_plan serves kind='bool' plans only; route mixed-kind "
            "batches through answer_mixed (or dist_batch / witness / "
            "count_routes directly)")
    t0 = _t0 if _t0 is not None else time.perf_counter()
    eng = index.engine(backend, engine_config)
    stats = stats if stats is not None else QueryStats()
    stats.n_queries += plan.n_queries
    stats.n_jobs += plan.n_jobs
    answers = np.zeros(plan.n_queries, dtype=bool)
    if plan.n_jobs == 0:
        return answers

    # pad the job axis onto the bucket grid so jit shapes stay stable
    # (and, under a mesh, further to a multiple of the device count)
    plan_p = plan.pad_to(graph_mod.pad_bucket(plan.n_jobs, lo=pad_lo))
    if mesh is not None:
        n_dev = mesh.devices.size
        plan_p = plan_p.pad_to(-(-plan_p.n_jobs // n_dev) * n_dev)
    pd_u, pd_v = jnp.asarray(plan_p.u), jnp.asarray(plan_p.v)
    if mesh is not None:
        from . import distributed as dist_mod  # deferred: imports us back
        verdict = dist_mod.filter_cascade_sharded(index, plan_p, mesh,
                                                  eng.kernel_mode)
    else:
        sat_out_d, sat_in_d = index.summary_flags_dev()
        verdict = np.asarray(_filter_cascade(
            pd_u, pd_v,
            jnp.asarray(plan_p.req_w), jnp.asarray(plan_p.forb_w),
            _null_words_dev(index.cfg),
            index.vtx_packed, index.h_vtx, index.h_lab, index.v_vtx,
            index.v_lab, index.n_out, index.n_in, sat_out_d, sat_in_d,
            index.push, index.pop, k=index.cfg.k, mode=eng.kernel_mode))

    real = plan_p.qid >= 0
    stats.filter_false += int(((verdict == FALSE) & real).sum())
    stats.filter_true += int(((verdict == TRUE) & real).sum())
    np.logical_or.at(answers, plan_p.qid[(verdict == TRUE) & real], True)
    stats.phase1_s += time.perf_counter() - t0

    pending = np.flatnonzero((verdict == UNKNOWN) & real)
    # jobs whose query is already TRUE need no exact work
    pending = pending[~answers[plan_p.qid[pending]]]
    if filters_only:
        # treat UNKNOWN as reachable (upper bound) -- used to measure the
        # cascade's pruning power in benchmarks
        np.logical_or.at(answers, plan_p.qid[pending], True)
        return answers
    stats.exact_jobs += len(pending)
    stats.exact_qids = np.unique(plan_p.qid[pending]).tolist()
    if len(pending) == 0:
        return answers

    t1 = time.perf_counter()
    ex = _executor(index, eng)
    v_n = index.graph.n_vertices
    special = ex.special_labels(plan_p, pending)
    if special_labels is not None:
        # a serving pin fixes the label-class set (stable operand shapes,
        # resident adjacency cache); union keeps it sound if traffic ever
        # needs a label outside the pin
        special = tuple(sorted(set(int(l) for l in special_labels)
                               | set(special)))
    dev = None
    if exact_mode != "legacy":
        dev = PlanDevice(pd_u, pd_v, jnp.asarray(plan_p.req_labels),
                         jnp.asarray(plan_p.forb_raw_w),
                         jnp.asarray(plan_p.full_mask))

    # chunk layout + compaction probe: per-job corridor sizes cost one tiny
    # device round-trip; full [P, V] membership is fetched only for the
    # jobs of chunks that will actually compact
    starts = list(range(0, len(pending), exact_chunk))
    if exact_mode == "legacy" or exact_mode == "full":
        compact_flags = [False] * len(starts)
    elif exact_mode == "compact":
        compact_flags = [True] * len(starts)
    else:
        # summary-first probe skip: a chunk whose every job has ALL_ONE
        # N_out[u] and N_in[v] rows (level-1 summaries of the compressed
        # planes) has corridor == full V *exactly*, so the probe would
        # always pick the full-graph path — settle those chunks from the
        # host flags and probe only the rest (whole chunks, in order, so
        # ``chunk_union_counts``'s sequential grouping stays aligned)
        flags = index.summary_flags()
        jsat = (flags["sat_out"][plan_p.u[pending]]
                & flags["sat_in"][plan_p.v[pending]])
        sat_chunks = [bool(jsat[c0:c0 + exact_chunk].all())
                      for c0 in starts]
        stats.saturated_chunks += sum(sat_chunks)
        compact_flags = [False] * len(starts)
        probe_starts = [c0 for c0, s in zip(starts, sat_chunks) if not s]
        if probe_starts:
            probe_jobs = np.concatenate(
                [pending[c0:c0 + exact_chunk] for c0 in probe_starts])
            unions = ex.chunk_union_counts(dev, probe_jobs, exact_chunk)
            for c0, u in zip(probe_starts, unions):
                compact_flags[c0 // exact_chunk] = (
                    graph_mod.pad_bucket(int(u), lo=32) < v_n)
    member = None
    mem_off = {}
    if any(compact_flags):
        cjobs = np.concatenate(
            [pending[c0:c0 + exact_chunk]
             for c0, flag in zip(starts, compact_flags) if flag])
        member = ex.corridor_members(dev, cjobs)
        off = 0
        for c0, flag in zip(starts, compact_flags):
            if flag:
                n = len(pending[c0:c0 + exact_chunk])
                mem_off[c0] = (off, off + n)
                off += n

    # dispatch every chunk, then collect once — no per-chunk host sync.
    # Under a mesh, *compacted* chunks round-robin over its devices:
    # their operands (induced subgraph, membership rows) are per-chunk
    # host data that must transfer anyway, so spreading them is pure
    # concurrency (dispatch is async).  Full-graph chunks stay on the
    # lead device, where the V-sized shared operands (index planes,
    # cached incidence / class adjacency) already live — round-robining
    # those would re-ship the whole index every chunk.
    devices = list(mesh.devices.flat) if mesh is not None else [None]
    results = []
    rr = 0
    for c0, flag in zip(starts, compact_flags):
        jobs = pending[c0:c0 + exact_chunk]
        real_n = len(jobs)
        rows = member[slice(*mem_off[c0])] if flag else None
        if real_n < exact_chunk:   # pad to a stable jit shape
            jobs = np.concatenate(
                [jobs, np.full(exact_chunk - real_n, jobs[0], np.int64)])
            if rows is not None:
                rows = np.concatenate(
                    [rows, np.repeat(rows[:1], exact_chunk - real_n,
                                     axis=0)])
        dev_i = devices[0] if mesh is None or not flag \
            else devices[rr % len(devices)]
        rr += flag
        if dev_i is None:
            res = ex.dispatch_chunk(plan_p, dev, jobs, rows, special,
                                    exact_mode, pin_m)
        else:
            with jax.default_device(dev_i):
                res = ex.dispatch_chunk(plan_p, dev, jobs, rows, special,
                                        exact_mode, pin_m)
        res.real_n = real_n
        results.append(res)
    for res in results:
        reached = np.asarray(res.reached)[:res.real_n]
        hit = res.jobs[:res.real_n][reached]
        np.logical_or.at(answers, plan_p.qid[hit], True)
        stats._round_parts.append(res.rounds)
        stats.corridor_active += res.n_active
        stats.corridor_total += res.v_total
    stats.phase2_s += time.perf_counter() - t1
    return answers


def answer(index: TDRIndex, u: int, v: int, p: pat.Pattern, **kw) -> bool:
    """Single-query convenience wrapper over ``answer_batch``."""
    return bool(answer_batch(index, [(u, v, p)], **kw)[0])


# ------------------------------------------- semiring query kinds (PR 8)
# The executors below answer the non-boolean QUERY_KINDS over the same
# corridor-compacted subgraphs phase 2 uses, but with a (min, +) distance
# DP ("dist"/"witness", uint16 lanes saturating at DIST_INF) or a
# saturating route-count DP ("count", uint32 lanes clamped at ``cap``)
# instead of the packed boolean closure.  Product-graph states are the
# same (vertex, seen-required-subset) pairs; the carrier is a dense
# [V', J, S] lane plane rather than one packed uint32 bitfield.
#
# Soundness of reusing the corridor: every vertex on a u→v walk is both
# reachable from u and co-reachable to v, so it lies in the true
# corridor, of which the Bloom corridor N_out(u) ∩ N_in(v) is a
# superset — compaction never cuts a path or a counted walk.

#: distance-plane INF (the uint16 carrier's saturation point)
DIST_INF = int(np.iinfo(np.uint16).max)

# int32 INF sentinel for the bidirectional meet arithmetic: large enough
# to dominate any real distance (<= DIST_INF - 1), small enough that
# sentinel + sentinel cannot wrap int32
_DBIG = 1 << 24


def _edge_dist_ops(lab, req_labels, forb_raw_w, max_m: int,
                   evalid=None, neutral=None):
    """Per-(job, edge|class) DP operands: ``allow`` bool [J, E] (edge
    usable for the job) and ``sh`` int32 [J, E] (the subset bit the edge's
    label sets, 0 if not required).  ``evalid`` masks bucket-padding edge
    rows — duplicated edges are harmless for the idempotent boolean
    closure but would double-count in the sum DP and must never relax a
    distance either.  ``neutral`` marks merged label-class rows (always
    allowed, no subset bit), as in ``_edge_state_masks``."""
    labx = jnp.maximum(lab, 0)
    okbit = (forb_raw_w[:, labx >> 5] >>
             (labx & 31).astype(jnp.uint32)[None, :]) & 1        # [J, E|C]
    allow = okbit == 0
    if neutral is not None:
        allow = allow | neutral[None, :]
    if evalid is not None:
        allow = allow & evalid[None, :]
    sh = jnp.zeros((req_labels.shape[0], lab.shape[0]), jnp.int32)
    for i in range(max_m):  # static unroll; require-sets hold distinct ids
        match = req_labels[:, i][:, None] == lab[None, :]
        if neutral is not None:
            match = match & ~neutral[None, :]
        sh = jnp.where(match, jnp.int32(1 << i), sh)
    return allow, sh


def _dist_meet(df, db, full_mask, best, n_states: int):
    """best[j] = min over vertices x and state pairs (s1, s2) with
    ``s1 | s2 == full_mask[j]`` of ``df[x,j,s1] + db[x,j,s2]`` — the
    distance analogue of the boolean ``_meet``: min over the corridor
    instead of an existence test."""
    dfi = jnp.where(df == DIST_INF, _DBIG, df.astype(jnp.int32))
    dbi = jnp.where(db == DIST_INF, _DBIG, db.astype(jnp.int32))
    s_idx = jnp.arange(n_states, dtype=jnp.int32)
    for s1 in range(n_states):  # static unroll, S <= 32
        valid = (jnp.int32(s1) | s_idx)[None, :] == full_mask[:, None]
        tot = dfi[:, :, s1][:, :, None] + dbi                   # [V', J, S]
        tot = jnp.where(valid[None, :, :], tot, _DBIG)
        best = jnp.minimum(best, tot.min(axis=(0, 2)))
    return best


def _dist_bidi_loop(df0, db0, push_f, push_b, full_mask, it_cap,
                    n_states: int, max_rounds: int):
    """Alternating bidirectional (min, +) fixpoint.  A job is done once
    its best meet value is <= 2·it: after ``it`` rounds each plane holds
    every product-distance <= it exactly, so any path of length
    L <= 2·it has already met — the best is provably final.  ``it_cap``
    is *traced* (k-hop-bounded queries stop at ceil(k/2) rounds without
    a recompile per k)."""
    j_n = df0.shape[1]
    best0 = _dist_meet(df0, db0, full_mask,
                       jnp.full(j_n, _DBIG, jnp.int32), n_states)

    def cond(st):
        _, _, best, cf, cb, it = st
        done = best <= 2 * it
        return ((cf | cb) & ~jnp.all(done)
                & (it < max_rounds) & (it < it_cap))

    def body(st):
        df, db, best, cf, cb, it = st
        # a direction whose last push relaxed nothing is at its fixpoint
        updf = jax.lax.cond(cf, push_f,
                            lambda a: jnp.full_like(a, DIST_INF), df)
        ndf = jnp.minimum(df, updf)
        updb = jax.lax.cond(cb, push_b,
                            lambda a: jnp.full_like(a, DIST_INF), db)
        ndb = jnp.minimum(db, updb)
        best = _dist_meet(ndf, ndb, full_mask, best, n_states)
        return (ndf, ndb, best, jnp.any(ndf != df), jnp.any(ndb != db),
                it + 1)

    st0 = (df0, db0, best0, jnp.bool_(True), jnp.bool_(True),
           jnp.int32(0))
    _, _, best, _, _, rounds = jax.lax.while_loop(cond, body, st0)
    return best, rounds


@functools.partial(jax.jit, static_argnames=("v_p", "n_states", "max_m",
                                             "max_rounds"))
def _dist_bidi(su, sv, req_labels, forb_raw_w, full_mask, sub_src,
               sub_dst, sub_lab, evalid, it_cap, *, v_p: int,
               n_states: int, max_m: int, max_rounds: int):
    """Segment-family bidirectional distance core over a (sub)graph's
    edge lists: one round = lane gather, per-edge subset transition
    (take the min of "already had the label" and "just gained it"),
    saturating +1, segment-min scatter."""
    j_n = su.shape[0]
    allow, sh = _edge_dist_ops(sub_lab, req_labels, forb_raw_w, max_m,
                               evalid=evalid)
    allowT = allow.T[:, :, None]                                # [E, J, 1]
    shT = sh.T[:, :, None]
    s_idx = jnp.arange(n_states, dtype=jnp.int32)
    iota = jnp.arange(j_n)
    inf = jnp.uint16(DIST_INF)

    def push(dist, gat, scat):
        rows = dist[gat]                                        # [E, J, S]
        alt = jnp.take_along_axis(rows, s_idx[None, None, :] ^ shT,
                                  axis=2)
        ok = ((s_idx[None, None, :] & shT) == shT) & allowT
        val = jnp.where(ok, jnp.minimum(rows, alt), inf)
        val = val + (val < inf).astype(jnp.uint16)   # saturating +1
        return jax.ops.segment_min(val, scat, num_segments=v_p)

    df0 = jnp.full((v_p, j_n, n_states), DIST_INF,
                   jnp.uint16).at[su, iota, 0].set(0)
    db0 = jnp.full((v_p, j_n, n_states), DIST_INF,
                   jnp.uint16).at[sv, iota, 0].set(0)
    return _dist_bidi_loop(
        df0, db0,
        lambda d: push(d, sub_src, sub_dst),
        lambda d: push(d, sub_dst, sub_src),
        full_mask, it_cap, n_states, max_rounds)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "mode"))
def _dist_bidi_matmul(su, sv, req_labels, forb_raw_w, full_mask, adj_rev,
                      adj_fwd, class_label, it_cap, *, n_states: int,
                      max_m: int, max_rounds: int, mode: str):
    """Pallas-backend distance core: one ``kernels.lane_matmul`` (min
    combine) per label class per direction per round, the distance plane
    flattened to [V', J·S] lanes.  ``_matmul_rows`` applies the DIST16
    extend (saturating +1) after each matmul; min is monotone, so
    extend-after-reduce equals extend-before-reduce and the per-class
    results combine by plain lane-min."""
    j_n = su.shape[0]
    v_p = adj_rev.shape[1]
    neutral = class_label < 0
    allow, sh = _edge_dist_ops(class_label, req_labels, forb_raw_w, max_m,
                               neutral=neutral)
    s_idx = jnp.arange(n_states, dtype=jnp.int32)
    iota = jnp.arange(j_n)
    inf = jnp.uint16(DIST_INF)

    def push(dist, adj_set):
        flat = dist.reshape(v_p, j_n * n_states)

        def body(upd, operand):
            adj_c, allow_c, sh_c = operand          # [V', Kw], [J], [J]
            y = engine_mod._matmul_rows(
                adj_c, flat, mode, sr=DIST16)[:v_p].reshape(
                    v_p, j_n, n_states)
            shc = sh_c[None, :, None]
            alt = jnp.take_along_axis(y, s_idx[None, None, :] ^ shc,
                                      axis=2)
            ok = (((s_idx[None, None, :] & shc) == shc)
                  & allow_c[None, :, None])
            return jnp.minimum(upd, jnp.where(ok, jnp.minimum(y, alt),
                                              inf)), None

        upd, _ = jax.lax.scan(
            body, jnp.full((v_p, j_n, n_states), DIST_INF, jnp.uint16),
            (adj_set, allow.T, sh.T))
        return upd

    df0 = jnp.full((v_p, j_n, n_states), DIST_INF,
                   jnp.uint16).at[su, iota, 0].set(0)
    db0 = jnp.full((v_p, j_n, n_states), DIST_INF,
                   jnp.uint16).at[sv, iota, 0].set(0)
    return _dist_bidi_loop(
        df0, db0,
        lambda d: push(d, adj_rev),
        lambda d: push(d, adj_fwd),
        full_mask, it_cap, n_states, max_rounds)


@functools.partial(jax.jit, static_argnames=("v_p", "n_states", "max_m",
                                             "max_rounds"))
def _dist_forward_parents(su, req_labels, forb_raw_w, sub_src, sub_dst,
                          sub_lab, evalid, *, v_p: int, n_states: int,
                          max_m: int, max_rounds: int):
    """Single-term forward distance DP with parent-edge planes.

    Unit weights make the DP BFS-layered — a cell's first finite write is
    its final distance — so recording a parent only on ``winner`` cells
    (``upd < dist``) is exact.  Parent recovery is two-pass: the round's
    arriving values are compared against the winning value and the
    minimal matching edge id is scattered (no value<<shift|id packing,
    which would overflow int32 on large |V'|·S).  Per-edge parent
    scatters are inherently edge-indexed, so witness extraction uses this
    segment core on both backends."""
    allow, sh = _edge_dist_ops(sub_lab, req_labels[None, :],
                               forb_raw_w[None, :], max_m, evalid=evalid)
    allow = allow[0][:, None]                                   # [E, 1]
    sh = sh[0][:, None]
    s_idx = jnp.arange(n_states, dtype=jnp.int32)
    inf = jnp.uint16(DIST_INF)
    eids = jnp.arange(sub_lab.shape[0], dtype=jnp.int32)[:, None]
    d0 = jnp.full((v_p, n_states), DIST_INF, jnp.uint16).at[su, 0].set(0)
    p0 = jnp.full((v_p, n_states), -1, jnp.int32)

    def cond(st):
        _, _, ch, it = st
        return ch & (it < max_rounds)

    def body(st):
        d, par, _, it = st
        rows = d[sub_src]                                       # [E, S]
        alt = jnp.take_along_axis(rows, s_idx[None, :] ^ sh, axis=1)
        ok = ((s_idx[None, :] & sh) == sh) & allow
        val = jnp.where(ok, jnp.minimum(rows, alt), inf)
        val = val + (val < inf).astype(jnp.uint16)
        upd = jax.ops.segment_min(val, sub_dst, num_segments=v_p)
        winner = upd < d                  # first discovery == final dist
        match = (val == upd[sub_dst]) & (val < inf)
        cand = jnp.where(match, eids, jnp.int32(1 << 30))
        parc = jax.ops.segment_min(cand, sub_dst, num_segments=v_p)
        par = jnp.where(winner, parc, par)
        return jnp.minimum(d, upd), par, jnp.any(winner), it + 1

    d, par, _, rounds = jax.lax.while_loop(
        cond, body, (d0, p0, jnp.bool_(True), jnp.int32(0)))
    return d, par, rounds


@functools.partial(jax.jit, static_argnames=("v_p", "n_states", "max_m",
                                             "cap"))
def _count_forward(su, sv, req_labels, forb_raw_w, full_mask, sub_src,
                   sub_dst, sub_lab, evalid, hops, *, v_p: int,
                   n_states: int, max_m: int, cap: int):
    """Bounded route-count DP: w[x, j, s] = number of length-r walks
    from u reaching x having seen subset s, every partial sum clamped at
    ``cap``.  A target state s collects from s (label already seen) and
    — when the edge's label is required, ``sh > 0`` — from s^sh, summing
    both; ``hops`` is traced (``fori_loop``), so the bound changes
    without a recompile.  Saturating add of non-negative values is
    associative, so per-edge clamp + segment-sum + clamp equals clamping
    the true total (the dfs_baseline oracle's semantics exactly)."""
    j_n = su.shape[0]
    allow, sh = _edge_dist_ops(sub_lab, req_labels, forb_raw_w, max_m,
                               evalid=evalid)
    allowT = allow.T[:, :, None]
    shT = sh.T[:, :, None]
    s_idx = jnp.arange(n_states, dtype=jnp.int32)
    iota = jnp.arange(j_n)
    capv = jnp.uint32(cap)
    w0 = jnp.zeros((v_p, j_n, n_states),
                   jnp.uint32).at[su, iota, 0].set(1)
    total0 = jnp.where((su == sv) & (full_mask == 0), jnp.uint32(1),
                       jnp.uint32(0))   # the empty walk

    def body(_, st):
        w, total = st
        rows = w[sub_src]                                       # [E, J, S]
        alt = jnp.take_along_axis(rows, s_idx[None, None, :] ^ shT,
                                  axis=2)
        contrib = rows + jnp.where(shT > 0, alt, 0)
        ok = ((s_idx[None, None, :] & shT) == shT) & allowT
        val = jnp.where(ok, jnp.minimum(contrib, capv), jnp.uint32(0))
        wn = jnp.minimum(
            jax.ops.segment_sum(val, sub_dst, num_segments=v_p), capv)
        total = jnp.minimum(total + wn[sv, iota, full_mask], capv)
        return wn, total

    _, total = jax.lax.fori_loop(0, hops, body, (w0, total0))
    return total


class _KindChunk(NamedTuple):
    """Host-side operands of one compacted (or full-graph) DP chunk."""
    v_p: int                    # padded vertex bucket
    su: np.ndarray              # renumbered sources int32 [J]
    sv: np.ndarray              # renumbered targets int32 [J]
    src: np.ndarray             # edge sources int32 [E'] (bucket-padded)
    dst: np.ndarray             # edge targets int32 [E']
    lab: np.ndarray             # edge labels int32 [E']
    evalid: np.ndarray          # bool [E'], False on padding rows
    sub_ids: np.ndarray | None  # local -> original vertex ids (None=full)
    n_sub: int                  # |V'| before padding


def _kind_chunk(index: TDRIndex, ex: ExactExecutor, plan: QueryPlan,
                dev: PlanDevice, jobs: np.ndarray,
                exact_mode: str) -> _KindChunk:
    """Corridor-compact one job chunk for the lane DPs (same probe and
    bucket discipline as ``ExactExecutor._run_bidi``, but edge padding
    rows are *masked* via ``evalid`` instead of relying on idempotence)."""
    g = index.graph
    v_n = g.n_vertices
    compact = exact_mode in ("auto", "compact")
    if compact:
        member = ex.corridor_members(dev, jobs)
        active = member.any(axis=0)
        n_sub = int(active.sum())
        if (exact_mode == "auto"
                and graph_mod.pad_bucket(max(n_sub, 1), lo=32) >= v_n):
            compact = False
    if compact:
        sub_ids, renum, s, d, l = graph_mod.induced_edges(
            g, active, src=ex.src_np)
        su = renum[plan.u[jobs]].astype(np.int32)
        sv = renum[plan.v[jobs]].astype(np.int32)
        v_p = graph_mod.pad_bucket(max(n_sub, 1), lo=32)
    else:
        sub_ids = None
        n_sub = v_p = v_n
        s, d, l = ex.src_np, ex.dst_np, ex.lab_np
        su = plan.u[jobs].astype(np.int32)
        sv = plan.v[jobs].astype(np.int32)
    e_real = int(s.shape[0])
    e_p = graph_mod.pad_bucket(max(e_real, 1), lo=32)
    evalid = np.zeros(e_p, dtype=bool)
    evalid[:e_real] = True
    if e_p > e_real:
        rep = e_p - e_real
        if e_real:
            s = np.concatenate([s, np.repeat(s[:1], rep)])
            d = np.concatenate([d, np.repeat(d[:1], rep)])
            l = np.concatenate([l, np.repeat(l[:1], rep)])
        else:   # corridor holds no edges: DP sees an empty, masked bucket
            s = np.zeros(e_p, np.int32)
            d = np.zeros(e_p, np.int32)
            l = np.zeros(e_p, np.int32)
    return _KindChunk(v_p, su, sv, np.ascontiguousarray(s),
                      np.ascontiguousarray(d), np.ascontiguousarray(l),
                      evalid, sub_ids, n_sub)


def dist_batch(index: TDRIndex,
               queries: Sequence[tuple[int, int, pat.Pattern]],
               *, k: int | None = None, max_m: int = 4,
               exact_chunk: int = 32, backend: str | None = None,
               exact_mode: str = "auto",
               engine_config: "engine_mod.EngineConfig | None" = None,
               special_labels: Sequence[int] | None = None,
               pin_m: int | None = None,
               stats: QueryStats | None = None) -> np.ndarray:
    """Shortest pattern-constrained hop distances.  Returns int64
    [n_queries]; -1 = unreachable (or farther than ``k`` when a k-hop
    bound is given — the bound also caps the DP at ceil(k/2) rounds,
    traced, so varying k never recompiles).

    Multi-term patterns take the min over terms.  ``exact_mode`` follows
    ``answer_plan`` minus "legacy"; on the pallas backend chunks run the
    per-label-class ``lane_matmul`` core when the class matrices fit the
    engine's dense budget, else the segment core (bit-equal results)."""
    if exact_mode not in ("auto", "compact", "full"):
        raise ValueError(f"unknown exact_mode {exact_mode!r} for dist; "
                         "expected auto | compact | full")
    t0 = time.perf_counter()
    plan = compile_queries(index, queries, max_m=max_m, stats=stats)
    eng = index.engine(backend, engine_config)
    stats = stats if stats is not None else QueryStats()
    stats.n_queries += plan.n_queries
    stats.n_jobs += plan.n_jobs
    out = np.full(plan.n_queries, -1, np.int64)
    if plan.n_jobs == 0:
        return out
    ex = _executor(index, eng)
    jobs_all = np.arange(plan.n_jobs)
    m_eff, n_states = ex.eff_states(plan, jobs_all, pin_m)
    if n_states > 32:
        raise ValueError(
            f"max_m={m_eff} needs {n_states} subset states; the lane "
            "executor holds at most 32 (max_m <= 5)")
    dev = PlanDevice(jnp.asarray(plan.u), jnp.asarray(plan.v),
                     jnp.asarray(plan.req_labels),
                     jnp.asarray(plan.forb_raw_w),
                     jnp.asarray(plan.full_mask))
    best_j = np.full(plan.n_jobs, _DBIG, np.int64)
    for c0 in range(0, plan.n_jobs, exact_chunk):
        jobs = jobs_all[c0:c0 + exact_chunk]
        real_n = len(jobs)
        if real_n < exact_chunk:   # pad to a stable jit shape
            jobs = np.concatenate(
                [jobs, np.full(exact_chunk - real_n, jobs[0])])
        ch = _kind_chunk(index, ex, plan, dev, jobs, exact_mode)
        max_rounds = ch.v_p * n_states + 1
        it_cap = jnp.int32(max_rounds if k is None
                           else max(-(-int(k) // 2), 0))
        req = jnp.asarray(plan.req_labels[jobs][:, :m_eff])
        frw = jnp.asarray(plan.forb_raw_w[jobs])
        fm = jnp.asarray(plan.full_mask[jobs])
        su, sv = jnp.asarray(ch.su), jnp.asarray(ch.sv)
        best = rounds = None
        if eng.backend == "pallas":
            special = ex.special_labels(plan, jobs)
            if special_labels is not None:
                special = tuple(sorted(
                    set(int(l) for l in special_labels) | set(special)))
            kw_b = bitset.n_words(ch.v_p)
            n_mats = 2 * (len(special) + 1)
            if n_mats * ch.v_p * kw_b * 4 <= eng.config.max_dense_bytes:
                class_label = jnp.asarray(
                    np.asarray(special + (-1,), np.int32))
                if ch.sub_ids is None:
                    adj_rev = eng.label_class_adjacency(special,
                                                        reverse=True)
                    adj_fwd = eng.label_class_adjacency(special,
                                                       reverse=False)
                else:
                    # padding rows duplicate edge 0: the same bit set
                    # twice — idempotent in a packed bit-matrix
                    adj_rev = jnp.asarray(
                        engine_mod.pack_label_class_edges_np(
                            ch.src, ch.dst, ch.lab, ch.v_p, special,
                            reverse=True))
                    adj_fwd = jnp.asarray(
                        engine_mod.pack_label_class_edges_np(
                            ch.src, ch.dst, ch.lab, ch.v_p, special,
                            reverse=False))
                best_d, rounds = _dist_bidi_matmul(
                    su, sv, req, frw, fm, adj_rev, adj_fwd, class_label,
                    it_cap, n_states=n_states, max_m=m_eff,
                    max_rounds=max_rounds, mode=eng.matmul_mode)
                best = np.asarray(best_d)
        if best is None:
            best_d, rounds = _dist_bidi(
                su, sv, req, frw, fm, jnp.asarray(ch.src),
                jnp.asarray(ch.dst), jnp.asarray(ch.lab),
                jnp.asarray(ch.evalid), it_cap, v_p=ch.v_p,
                n_states=n_states, max_m=m_eff, max_rounds=max_rounds)
            best = np.asarray(best_d)
        best_j[jobs[:real_n]] = best[:real_n]
        stats._round_parts.append(rounds)
        stats.corridor_active += ch.n_sub
        stats.corridor_total += index.graph.n_vertices
    bq = np.full(plan.n_queries, _DBIG, np.int64)
    np.minimum.at(bq, plan.qid, best_j)
    reach = bq < _DBIG
    out[reach] = bq[reach]
    if k is not None:
        out[out > int(k)] = -1
    stats.exact_jobs += plan.n_jobs
    stats.phase2_s += time.perf_counter() - t0
    return out


def dist(index: TDRIndex, u: int, v: int, p: pat.Pattern, **kw) -> int:
    """Single-query shortest pattern-constrained distance (hops), -1 if
    unreachable — convenience wrapper over ``dist_batch``."""
    return int(dist_batch(index, [(u, v, p)], **kw)[0])


def witness(index: TDRIndex, u: int, v: int, p: pat.Pattern,
            *, max_m: int = 4, backend: str | None = None,
            exact_mode: str = "auto",
            engine_config: "engine_mod.EngineConfig | None" = None,
            pin_m: int | None = None,
            stats: QueryStats | None = None
            ) -> list[tuple[int, int, int]] | None:
    """An actual shortest witness path for a PCR query.

    Returns a list of ``(x, y, label)`` edges chaining u→v whose label
    set satisfies ``p`` and whose length equals the exact shortest
    pattern-constrained distance; ``[]`` when the empty path answers
    (u == v and some term requires nothing); ``None`` when unreachable.
    Every returned path is replayed against the raw graph through
    ``dfs_baseline.verify_witness`` before it leaves this function."""
    if exact_mode not in ("auto", "compact", "full"):
        raise ValueError(f"unknown exact_mode {exact_mode!r} for witness; "
                         "expected auto | compact | full")
    plan = compile_queries(index, [(u, v, p)], max_m=max_m, stats=stats)
    if plan.n_jobs == 0:
        return None
    eng = index.engine(backend, engine_config)
    ex = _executor(index, eng)
    jobs = np.arange(plan.n_jobs)
    m_eff, n_states = ex.eff_states(plan, jobs, pin_m)
    if n_states > 32:
        raise ValueError(
            f"max_m={m_eff} needs {n_states} subset states; the lane "
            "executor holds at most 32 (max_m <= 5)")
    dev = PlanDevice(jnp.asarray(plan.u), jnp.asarray(plan.v),
                     jnp.asarray(plan.req_labels),
                     jnp.asarray(plan.forb_raw_w),
                     jnp.asarray(plan.full_mask))
    ch = _kind_chunk(index, ex, plan, dev, jobs, exact_mode)
    max_rounds = ch.v_p * n_states + 1
    src_j, dst_j = jnp.asarray(ch.src), jnp.asarray(ch.dst)
    lab_j, ev_j = jnp.asarray(ch.lab), jnp.asarray(ch.evalid)
    best_t = -1
    best_len = None
    planes: list = []
    for t in range(plan.n_jobs):   # term shapes identical -> one compile
        dplane, par, _ = _dist_forward_parents(
            jnp.int32(int(ch.su[t])),
            jnp.asarray(plan.req_labels[t, :m_eff]),
            jnp.asarray(plan.forb_raw_w[t]), src_j, dst_j, lab_j, ev_j,
            v_p=ch.v_p, n_states=n_states, max_m=m_eff,
            max_rounds=max_rounds)
        planes.append((dplane, par))
        d_t = int(np.asarray(
            dplane[int(ch.sv[t]), int(plan.full_mask[t])]))
        if d_t < DIST_INF and (best_len is None or d_t < best_len):
            best_t, best_len = t, d_t
    if best_len is None:
        return None
    if best_len == 0:
        return []
    dn = np.asarray(planes[best_t][0]).astype(np.int64)
    pn = np.asarray(planes[best_t][1])
    req = plan.req_labels[best_t]
    x = int(ch.sv[best_t])
    state = int(plan.full_mask[best_t])
    path: list[tuple[int, int, int]] = []
    while dn[x, state] > 0:
        e = int(pn[x, state])
        px, lx = int(ch.src[e]), int(ch.lab[e])
        shx = 0
        for i in range(m_eff):
            if int(req[i]) == lx:
                shx = 1 << i
        want = dn[x, state] - 1
        nxt = None
        # the pre-edge state dropped the edge's subset bit, or already
        # had the label; either predecessor one hop closer is valid
        for so in ([state, state ^ shx] if shx else [state]):
            if dn[px, so] == want:
                nxt = so
                break
        if nxt is None:
            raise RuntimeError("witness backtrack: broken parent chain "
                               f"at vertex {x}, state {state}")
        path.append((px, x, lx))
        x, state = px, nxt
    path.reverse()
    if ch.sub_ids is not None:   # map compacted ids back to the graph
        path = [(int(ch.sub_ids[a]), int(ch.sub_ids[b]), l)
                for (a, b, l) in path]
    if len(path) != best_len or not dfs_mod.verify_witness(
            index.graph, u, v, p, path):
        raise RuntimeError("witness verification failed: extracted path "
                           "does not replay on the graph")
    return path


def count_routes(index: TDRIndex, u: int, v: int, p: pat.Pattern,
                 *, hops: int, cap: int = COUNT_CAP, max_m: int = 4,
                 backend: str | None = None, exact_mode: str = "auto",
                 engine_config: "engine_mod.EngineConfig | None" = None,
                 pin_m: int | None = None,
                 stats: QueryStats | None = None) -> int:
    """Number of pattern-satisfying u→v walks of length <= ``hops``,
    saturating at ``cap`` (``semiring.COUNT_CAP`` by default).

    Walks, not simple paths — a cycle counts per traversal, exactly the
    product-graph DP the ``dfs_baseline.count_routes`` oracle runs.
    Single-DNF-term patterns only: terms of a composite pattern overlap,
    so a per-term sum would double-count (the same restriction as the
    oracle).  ``hops`` is traced — varying it never recompiles."""
    if exact_mode not in ("auto", "compact", "full"):
        raise ValueError(f"unknown exact_mode {exact_mode!r} for count; "
                         "expected auto | compact | full")
    terms = pat.to_dnf(p)
    if len(terms) != 1:
        raise ValueError(
            f"count_routes needs a single-DNF-term pattern, got "
            f"{len(terms)} terms")
    plan = compile_queries(index, [(u, v, p)], max_m=max_m, stats=stats)
    eng = index.engine(backend, engine_config)
    ex = _executor(index, eng)
    jobs = np.arange(plan.n_jobs)
    m_eff, n_states = ex.eff_states(plan, jobs, pin_m)
    if n_states > 32:
        raise ValueError(
            f"max_m={m_eff} needs {n_states} subset states; the lane "
            "executor holds at most 32 (max_m <= 5)")
    dev = PlanDevice(jnp.asarray(plan.u), jnp.asarray(plan.v),
                     jnp.asarray(plan.req_labels),
                     jnp.asarray(plan.forb_raw_w),
                     jnp.asarray(plan.full_mask))
    ch = _kind_chunk(index, ex, plan, dev, jobs, exact_mode)
    if ch.src.shape[0] * cap >= 1 << 32:
        raise ValueError(
            f"cap={cap} with {ch.src.shape[0]} edges could wrap the "
            "uint32 count accumulator; lower the cap")
    total = _count_forward(
        jnp.asarray(ch.su), jnp.asarray(ch.sv),
        jnp.asarray(plan.req_labels[:, :m_eff]),
        jnp.asarray(plan.forb_raw_w), jnp.asarray(plan.full_mask),
        jnp.asarray(ch.src), jnp.asarray(ch.dst), jnp.asarray(ch.lab),
        jnp.asarray(ch.evalid), jnp.int32(int(hops)), v_p=ch.v_p,
        n_states=n_states, max_m=m_eff, cap=int(cap))
    return int(np.asarray(total)[0])


# ------------------------------------------------ RPQ executor (PR 10)
# Regular path queries constrain the label *order* along a path, which
# the subset-state planes above cannot express.  The fragment that DNF
# lowering can absorb exactly (unions of single-atom stars — the RPQ
# spelling of LCR) rides ``answer_plan`` untouched; everything else runs
# the same corridor-compacted bidirectional expansion generalized from
# subset-states to Glushkov NFA states: the ``[V', J]`` packed plane's
# uint32 holds "NFA states reachable at vertex x" (forward) / "states
# from which (v, accept) is reachable" (backward), per-edge transitions
# come from the dense per-job ``[L, 32]`` NFA tables, and a query meets
# as soon as some vertex holds ``f & b != 0``.  The TDR filter cascade
# still prunes via the regex's label over-approximation — but only a
# FALSE verdict is sound (set logic is order-blind), so the cascade runs
# ``filters_only`` and survivors go to the product executor.


class RpqRows(NamedTuple):
    """Per-regex compiled operands (endpoint-independent, cached like
    ``PatternRows`` under the same LRU with kind="rpq" keys)."""
    tab: np.ndarray             # uint32 [L, 32]  forward NFA table
    rtab: np.ndarray            # uint32 [L, 32]  reverse NFA table
    accept: int                 # uint32 accept-state bitmask
    nullable: bool              # ε ∈ L(r): u == v answers True
    nfa_states: int             # Glushkov state count (<= 32)
    lowered: Any                # exact pattern.Pattern lowering, or None
    approx: Any                 # over-approximation pattern (prune only)
    feasible: bool              # False: some required label can't exist
    alpha: tuple                # in-graph alphabet (pallas label classes)

    @property
    def n_terms(self) -> int:
        return 1                # one product-executor job per query


def _compile_rpq_rows(index: TDRIndex, r, max_m: int) -> RpqRows:
    n_labels = index.graph.n_labels
    nfa = rpq_mod.compile_nfa(r, n_labels)
    lowered = rpq_mod.lower_to_pattern(r, n_labels)
    approx, feasible = rpq_mod.approx_pattern(r, n_labels,
                                              max_require=max_m)
    alpha = tuple(sorted(a for a in rpq_mod.alphabet(r) if a < n_labels))
    return RpqRows(tab=nfa.tab, rtab=nfa.rtab, accept=int(nfa.accept),
                   nullable=bool(nfa.nullable), nfa_states=nfa.n_states,
                   lowered=lowered, approx=approx, feasible=feasible,
                   alpha=alpha)


def rpq_rows(index: TDRIndex, r, max_m: int = 4,
             stats: "QueryStats | None" = None) -> RpqRows:
    """Cached compiled operands for one RPQ (hash-consed canonical key,
    same bounded LRU and lock discipline as ``pattern_rows``)."""
    key = (rpq_mod.canonical_key(r), max_m, "rpq")
    if stats is not None:
        stats.plan_lookups += 1
    with _plan_cache_lock:
        cache = getattr(index, "_plan_cache", None)
        if cache is None:
            cache = {}
            index._plan_cache = cache
        rows = cache.get(key)
        if rows is not None:
            cache[key] = cache.pop(key)     # refresh LRU position
            return rows
    if stats is not None:
        stats.plan_misses += 1
    # NFA construction + lowering run outside the lock (pattern_rows'
    # compile-outside-lock idiom)
    rows = _compile_rpq_rows(index, rpq_mod.canonicalize(r), max_m)
    with _plan_cache_lock:
        while len(cache) >= PLAN_CACHE_CAP:
            cache.pop(next(iter(cache)))
        cache[key] = rows
    return rows


def _nfa_apply(masks, tab_e, q_u: int = 32):
    """Union of ``tab_e[..., q]`` over the set bits q of ``masks`` — one
    NFA step applied to a packed state-subset plane.  Static ``q_u``-way
    unroll (the chunk's NFAs use only states < q_u, so higher bits are
    provably never set); linearity over union (δ(S₁∪S₂, a) = δ(S₁,a) ∪
    δ(S₂,a)) is what lets the push below OR-gather neighbours *before*
    applying the transition table."""
    out = jnp.zeros_like(masks)
    for q in range(q_u):
        hit = ((masks >> q) & jnp.uint32(1)) != 0
        out = out | jnp.where(hit, tab_e[..., q], jnp.uint32(0))
    return out


def _rpq_sup_need(q_n: int):
    """``_meet``'s sup_need specialized to the NFA meet: forward state q
    completes with exactly backward state q, so done ⟺ f & b != 0."""
    bits = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.broadcast_to(bits[:, None], (32, q_n))


@functools.partial(jax.jit, static_argnames=("v_p", "max_rounds",
                                             "chunk_words", "q_u"))
def _rpq_bidi(su, sv, tabs, rtabs, accept, sub_src, sub_dst, sub_lab,
              evalid, ids_in, ids_out, *, v_p: int, max_rounds: int,
              chunk_words: int, q_u: int = 32):
    """Segment-backend product-graph fixpoint over a (sub)graph's edge
    lists.  One round = lane gather, per-edge NFA transition from the
    job's dense table, OR-reduction over the padded in/out incidence
    (``ids_in``/``ids_out``, sentinel = the appended zero row; padding
    edges are simply never referenced).  When the incidence is ``None``
    (degree skew beyond the gather cap) the reduction falls back to
    packed segment ORs with explicit ``evalid`` masking — a padding
    edge would inject fake word letters; unlike the idempotent subset-
    state closure, a fabricated edge changes the language.

    ``q_u`` (static) caps the NFA-apply unroll: every NFA in the chunk
    has <= q_u states, so bits >= q_u are never set in any plane and
    the sliced per-edge tables stay exact."""
    q_n = su.shape[0]
    iota = jnp.arange(q_n)
    f0 = jnp.zeros((v_p, q_n), jnp.uint32).at[su, iota].set(jnp.uint32(1))
    b0 = jnp.zeros((v_p, q_n), jnp.uint32).at[sv, iota].set(accept)
    tab_e = jnp.transpose(tabs[:, sub_lab, :q_u], (1, 0, 2))  # [E',J,q_u]
    rtab_e = jnp.transpose(rtabs[:, sub_lab, :q_u], (1, 0, 2))
    ev = evalid[:, None]
    cor_w = jnp.full((v_p, q_n), _FULL)

    def reduce_cols(val, ids):
        # per-column gathers accumulate without the [V', D, J] transient
        # a single 3D gather would materialize (same idiom as the
        # boolean core: 3× faster on CPU than scatter-reduce)
        out = val[ids[:, 0]]
        for j in range(1, ids.shape[1]):  # static unroll over D columns
            out = out | val[ids[:, j]]
        return out

    def push(frontier, gat, te, scat, ids):
        val = _nfa_apply(frontier[gat], te, q_u)             # [E', J]
        if ids is None:
            val = jnp.where(ev, val, jnp.uint32(0))
            return bitset.segment_or_words(val, scat, num_segments=v_p,
                                           chunk_words=chunk_words)
        val = jnp.concatenate(
            [val, jnp.zeros((1, q_n), jnp.uint32)], axis=0)
        for level in ids:   # 1 level, or virtual-row split on heavy tails
            val = reduce_cols(val, level)
        return val                                           # [V', J]

    return _bidi_loop(
        f0, b0,
        lambda f: push(f, sub_src, tab_e, sub_dst, ids_in),
        lambda b: push(b, sub_dst, rtab_e, sub_src, ids_out),
        cor_w, _rpq_sup_need(q_n), max_rounds)


@functools.partial(jax.jit, static_argnames=("max_rounds", "mode", "q_u"))
def _rpq_bidi_matmul(su, sv, tabs, rtabs, accept, adj_rev, adj_fwd,
                     class_label, *, max_rounds: int, mode: str,
                     q_u: int = 32):
    """Pallas-backend product-graph fixpoint: one ``bitset_matmul`` per
    label class per direction per round.  Every in-graph alphabet label
    of the chunk gets its own class; the merged neutral class carries a
    zero transition table — sound because a label outside every job's
    alphabet has an all-zero NFA table row anyway (no word of the
    language uses it)."""
    q_n = su.shape[0]
    v_p = adj_rev.shape[1]
    iota = jnp.arange(q_n)
    f0 = jnp.zeros((v_p, q_n), jnp.uint32).at[su, iota].set(jnp.uint32(1))
    b0 = jnp.zeros((v_p, q_n), jnp.uint32).at[sv, iota].set(accept)
    labx = jnp.maximum(class_label, 0)
    live = (class_label >= 0)[:, None, None]
    tab_cls = jnp.where(live,
                        jnp.transpose(tabs[:, labx, :q_u], (1, 0, 2)),
                        jnp.uint32(0))                      # [C+1, J, q_u]
    rtab_cls = jnp.where(live,
                         jnp.transpose(rtabs[:, labx, :q_u], (1, 0, 2)),
                         jnp.uint32(0))
    cor_w = jnp.full((v_p, q_n), _FULL)

    def push(frontier, adj_set, tab_set):
        # scan over label classes (one kernel call site per direction,
        # as in _bidi_matmul_core)
        def body(upd, operand):
            adj_c, tab_c = operand                  # [V', Kw], [J, 32]
            y = engine_mod._matmul_rows(adj_c, frontier, mode)[:v_p]
            return upd | _nfa_apply(y, tab_c[None, :, :], q_u), None
        upd, _ = jax.lax.scan(body, jnp.zeros_like(frontier),
                              (adj_set, tab_set))
        return upd

    return _bidi_loop(
        f0, b0,
        lambda f: push(f, adj_rev, tab_cls),
        lambda b: push(b, adj_fwd, rtab_cls),
        cor_w, _rpq_sup_need(q_n), max_rounds)


def rpq_batch(index: TDRIndex, queries: Sequence[tuple], *,
              max_m: int = 4, exact_chunk: int = 32,
              backend: str | None = None, exact_mode: str = "auto",
              engine_config: "engine_mod.EngineConfig | None" = None,
              special_labels: Sequence[int] | None = None,
              pin_m: int | None = None, pad_lo: int = 16,
              q_unroll: int | None = None,
              stats: QueryStats | None = None) -> np.ndarray:
    """Answer ``(u, v, rpq)`` regular path queries.  Returns bool [n].

    ``q_unroll`` pins the static NFA state-unroll width (a power of two
    in 4..32).  ``None`` derives the tightest width from each chunk's
    regexes — small automata run up to 8x fewer per-edge table ops; a
    serving layer pins 32 so the compiled shape never depends on which
    regexes a batch happens to hold.

    Three routes, all oracle-equal to ``dfs_baseline.answer_rpq``:

    * **lowered** — regexes in the index-expressible fragment
      (``rpq.lower_to_pattern``) become plain PCR queries and take
      ``answer_plan`` *bit-for-bit* with the equivalent composite
      pattern (an LCR asked as ``(a|b|…)*`` shares plans, caches, and
      answers with the LCR asked directly);
    * **infeasible** — a required label no graph edge can carry: only
      the empty path remains, so the answer is ``u == v and ε ∈ L(r)``;
    * **product** — everything else: the filter cascade on the regex's
      over-approximation pattern prunes (FALSE verdicts only — TRUE is
      order-blind and proves nothing), survivors run the corridor-
      compacted automaton-product expansion on either backend.
    """
    if exact_mode not in ("auto", "compact", "full"):
        raise ValueError(f"unknown exact_mode {exact_mode!r} for rpq; "
                         "expected auto | compact | full")
    if q_unroll is not None and q_unroll not in (4, 8, 16, 32):
        raise ValueError(f"q_unroll must be a power of two in 4..32, "
                         f"got {q_unroll!r}")
    t0 = time.perf_counter()
    eng = index.engine(backend, engine_config)
    stats = stats if stats is not None else QueryStats()
    out = np.zeros(len(queries), dtype=bool)
    if not queries:
        return out
    rows = [rpq_rows(index, r, max_m, stats=stats)
            for (_, _, r) in queries]

    low_ix = [i for i, rw in enumerate(rows) if rw.lowered is not None]
    if low_ix:
        lowq = [(queries[i][0], queries[i][1], rows[i].lowered)
                for i in low_ix]
        plan = compile_queries(index, lowq, max_m=max_m, stats=stats)
        ans = answer_plan(index, plan, exact_chunk=exact_chunk,
                          stats=stats, backend=backend,
                          exact_mode=exact_mode,
                          engine_config=engine_config,
                          special_labels=special_labels, pin_m=pin_m,
                          pad_lo=pad_lo)
        out[low_ix] = ans

    hard_ix = [i for i, rw in enumerate(rows) if rw.lowered is None]
    # ε answers need no path; infeasible regexes allow nothing else
    for i in list(hard_ix):
        u, v, _ = queries[i][:3]
        if u == v and rows[i].nullable:
            out[i] = True
            hard_ix.remove(i)
        elif not rows[i].feasible:
            hard_ix.remove(i)       # out[i] stays False
    if not hard_ix:
        return out

    # phase 1: the cascade on the over-approximation — a FALSE verdict
    # refutes the RPQ (every matching word satisfies the approximation);
    # filters_only returns the sound upper bound TRUE ∪ UNKNOWN
    approxq = [(queries[i][0], queries[i][1], rows[i].approx)
               for i in hard_ix]
    aplan = compile_queries(index, approxq, max_m=max_m, stats=stats)
    ub = answer_plan(index, aplan, exact_chunk=exact_chunk, stats=stats,
                     filters_only=True, backend=backend,
                     exact_mode=exact_mode, engine_config=engine_config,
                     special_labels=special_labels, pin_m=pin_m,
                     pad_lo=pad_lo)
    pos_of = {i: k for k, i in enumerate(hard_ix)}  # aplan job per query
    hard_ix = [i for i, alive in zip(hard_ix, ub) if alive]
    if not hard_ix:
        return out

    # phase 2: automaton-product expansion.  The approx plan is single-
    # term per query (its job k is approxq position k), so it doubles as
    # the endpoint plan and the Bloom-corridor compaction source.
    t1 = time.perf_counter()
    ex = _executor(index, eng)
    jobs_all = np.asarray([pos_of[i] for i in hard_ix], dtype=np.int64)
    dev = PlanDevice(jnp.asarray(aplan.u), jnp.asarray(aplan.v),
                     jnp.asarray(aplan.req_labels),
                     jnp.asarray(aplan.forb_raw_w),
                     jnp.asarray(aplan.full_mask))
    done_all = np.zeros(len(jobs_all), dtype=bool)
    for c0 in range(0, len(jobs_all), exact_chunk):
        jobs = jobs_all[c0:c0 + exact_chunk]
        real_n = len(jobs)
        if real_n < exact_chunk:    # pad to a stable jit shape
            jobs = np.concatenate(
                [jobs, np.full(exact_chunk - real_n, jobs[0])])
        ch = _kind_chunk(index, ex, aplan, dev, jobs, exact_mode)
        qrows = [rows[hard_ix[c0 + (j if j < real_n else 0)]]
                 for j in range(len(jobs))]
        if q_unroll is None:
            q_u = 4
            while q_u < max(rw.nfa_states for rw in qrows):
                q_u *= 2
        else:
            q_u = q_unroll
        max_rounds = ch.v_p * q_u + 1    # product-graph diameter bound
        tabs = jnp.asarray(np.stack([rw.tab for rw in qrows]))
        rtabs = jnp.asarray(np.stack([rw.rtab for rw in qrows]))
        accept = jnp.asarray(
            np.asarray([rw.accept for rw in qrows], np.uint32))
        su, sv = jnp.asarray(ch.su), jnp.asarray(ch.sv)
        done = rounds = None
        if eng.backend == "pallas" and ch.evalid.any():
            # per-alphabet-label classes; the merged neutral class has a
            # zero NFA table.  Skipped when the corridor held no real
            # edges — the packed fake 0→0 edge would fabricate a letter.
            special = set()
            for rw in qrows:
                special.update(rw.alpha)
            if special_labels is not None:
                special.update(int(l) for l in special_labels
                               if 0 <= int(l) < index.graph.n_labels)
            special = tuple(sorted(special))
            kw_b = bitset.n_words(ch.v_p)
            n_mats = 2 * (len(special) + 1)
            if n_mats * ch.v_p * kw_b * 4 <= eng.config.max_dense_bytes:
                class_label = jnp.asarray(
                    np.asarray(special + (-1,), np.int32))
                if ch.sub_ids is None:
                    adj_rev = eng.label_class_adjacency(special,
                                                        reverse=True)
                    adj_fwd = eng.label_class_adjacency(special,
                                                        reverse=False)
                else:
                    adj_rev = jnp.asarray(
                        engine_mod.pack_label_class_edges_np(
                            ch.src, ch.dst, ch.lab, ch.v_p, special,
                            reverse=True))
                    adj_fwd = jnp.asarray(
                        engine_mod.pack_label_class_edges_np(
                            ch.src, ch.dst, ch.lab, ch.v_p, special,
                            reverse=False))
                done_d, rounds = _rpq_bidi_matmul(
                    su, sv, tabs, rtabs, accept, adj_rev, adj_fwd,
                    class_label, max_rounds=max_rounds,
                    mode=eng.matmul_mode, q_u=q_u)
                done = np.asarray(done_d)
        if done is None:
            # padded-incidence gathers replace the scatter segment-OR
            # (built from the real edges only, so padding rows need no
            # mask on this path); degree skew past the cap falls back
            e_real = int(ch.evalid.sum())
            e_p = int(ch.src.shape[0])
            ids_in = ids_out = None
            if e_real:
                plan_in = graph_mod.incidence_plan(
                    ch.dst[:e_real], ch.v_p, e_p)
                plan_out = graph_mod.incidence_plan(
                    ch.src[:e_real], ch.v_p, e_p)
                gb = sum(a.size for a in plan_in + plan_out) * \
                    len(jobs) * 4
                if gb <= ExactExecutor.GATHER_BYTES_CAP:
                    ids_in = tuple(jnp.asarray(a) for a in plan_in)
                    ids_out = tuple(jnp.asarray(a) for a in plan_out)
            done_d, rounds = _rpq_bidi(
                su, sv, tabs, rtabs, accept, jnp.asarray(ch.src),
                jnp.asarray(ch.dst), jnp.asarray(ch.lab),
                jnp.asarray(ch.evalid), ids_in, ids_out, v_p=ch.v_p,
                max_rounds=max_rounds,
                chunk_words=eng.config.chunk_words, q_u=q_u)
            done = np.asarray(done_d)
        done_all[c0:c0 + real_n] = done[:real_n]
        stats._round_parts.append(rounds)
        stats.corridor_active += ch.n_sub
        stats.corridor_total += index.graph.n_vertices
    for i, d in zip(hard_ix, done_all):
        out[i] = bool(d)
    stats.exact_jobs += len(jobs_all)
    stats.phase2_s += time.perf_counter() - t1
    stats.phase1_s += t1 - t0
    return out


def answer_rpq(index: TDRIndex, u: int, v: int, r, **kw) -> bool:
    """Single-query convenience wrapper over ``rpq_batch``."""
    return bool(rpq_batch(index, [(u, v, r)], **kw)[0])


def answer_mixed(index: TDRIndex, queries: Sequence[tuple], *,
                 hops: int = 8, k: int | None = None,
                 cap: int = COUNT_CAP, max_m: int = 4,
                 backend: str | None = None, exact_mode: str = "auto",
                 engine_config: "engine_mod.EngineConfig | None" = None,
                 stats: QueryStats | None = None) -> list:
    """Answer a mixed-kind batch of ``(u, v, pattern[, kind])`` queries.

    Results align with the input order: bool for "bool", int distance
    (-1 unreachable) for "dist", an edge list / [] / None for "witness",
    an int for "count" (bounded by ``hops``, clamped at ``cap``), and
    bool for "rpq" (whose third element is a ``repro.core.rpq`` AST
    rather than a pattern).  Same-kind queries batch together;
    "witness"/"count" run per query."""
    kinds = [(q[3] if len(q) > 3 else "bool") for q in queries]
    for kd in kinds:
        if kd not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kd!r}; expected one "
                             f"of {QUERY_KINDS}")
    common = dict(max_m=max_m, backend=backend, exact_mode=exact_mode,
                  engine_config=engine_config, stats=stats)
    results: list = [None] * len(queries)
    bool_ix = [i for i, kd in enumerate(kinds) if kd == "bool"]
    if bool_ix:
        ans = answer_batch(index, [queries[i][:3] for i in bool_ix],
                           **common)
        for i, a in zip(bool_ix, ans):
            results[i] = bool(a)
    rpq_ix = [i for i, kd in enumerate(kinds) if kd == "rpq"]
    if rpq_ix:
        # the third element is a repro.core.rpq AST, not a pattern —
        # compile_queries would reject it, so partition before batching
        ans = rpq_batch(index, [queries[i][:3] for i in rpq_ix], **common)
        for i, a in zip(rpq_ix, ans):
            results[i] = bool(a)
    dist_ix = [i for i, kd in enumerate(kinds) if kd == "dist"]
    if dist_ix:
        ds = dist_batch(index, [queries[i][:3] for i in dist_ix], k=k,
                        **common)
        for i, dv in zip(dist_ix, ds):
            results[i] = int(dv)
    for i, kd in enumerate(kinds):
        if kd == "witness":
            results[i] = witness(index, *queries[i][:3], **common)
        elif kd == "count":
            results[i] = count_routes(index, *queries[i][:3], hops=hops,
                                      cap=cap, **common)
    return results

"""Answering PCR queries with the TDR index (paper §V, Alg. 2) — batched.

The paper's Alg. 2 interleaves pruning with a DFS.  Here the same logic is a
**planner/executor split**, both halves batched over the whole query set and
running end-to-end on packed uint32 words through ``repro.core.engine``:

Planner — ``compile_queries`` flattens DNF terms into a fully vectorized
``QueryPlan``: packed required/forbidden label-slot planes, packed raw
forbidden-label rows, and padded required-label ids.  No per-edge or
per-vertex host arrays — everything edge-indexed is derived on device by
the executor via label gathers (no ``elab == l`` Python scans, no
``[Q, E]`` host-side dense masks).

Phase 1 — *filter cascade* (pure index math, no traversal):
  * ``u == v``            -> TRUE iff the term requires no labels
  * ``bits(v) ⊄ N_out(u)``-> FALSE   (paper: VertexReach)
  * ``bits(u) ⊄ N_in(v)`` -> FALSE   (paper: VertexReach, reverse)
  * interval ancestor + unconstrained term -> TRUE (paper: early stopping)
  * per-way group pruning via ``kernels.ops.filter_ways`` (the fused
    Pallas cascade on TPU / ref oracle elsewhere); no surviving way -> FALSE
  * everything else -> UNKNOWN, goes to phase 2.

Phase 2 — *exact product-graph expansion* for survivors only, run by a
persistent jitted executor.  The frontier is a ``[V, Q]`` array of packed
state-subset bitfields (bit s of word (x, q) == "query q can stand at x
having seen required-subset s"); one round is the engine's OR-semiring
propagate with per-edge state transitions done as constant-mask shifts on
the packed field, confined to the Bloom *corridor* ``V_out(u) ∩ V_in(v)``
(packed).  With the ``pallas`` backend a round is one
``kernels.bitset_matmul`` per label class (per special label + one matrix
for all neutral labels).  The expansion is the same boolean-semiring
product the index build uses, so answers are exact: property tests assert
bit-equality with the DFS oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from . import engine as engine_mod
from . import pattern as pat
from .tdr_build import TDRIndex, _null_words

FALSE, TRUE, UNKNOWN = 0, 1, 2

_FULL = jnp.uint32(0xFFFFFFFF)


# ------------------------------------------------------------------ plans
@dataclasses.dataclass
class QueryPlan:
    """Planner output: one flattened DNF-term job per row, packed planes.

    ``req_w``/``forb_w`` are label-*slot* planes (the index's Bloom space,
    used by the filter cascade); ``forb_raw_w`` is packed over raw label
    ids — the executor's edge-forbid test must be exact, and slot hashing
    may collide when ``n_labels > lab_slots``.
    """
    qid: np.ndarray         # int32 [J] query id (-1 = padding row)
    u: np.ndarray           # int32 [J]
    v: np.ndarray           # int32 [J]
    req_w: np.ndarray       # uint32 [J, Wl]   required label-slot plane
    forb_w: np.ndarray      # uint32 [J, Wl]   forbidden label-slot plane
    forb_raw_w: np.ndarray  # uint32 [J, WL]   raw forbidden labels (packed)
    req_labels: np.ndarray  # int32 [J, max_m] raw required ids, -1 padded
    full_mask: np.ndarray   # int32 [J]        target subset state
    n_queries: int
    max_m: int

    @property
    def n_jobs(self) -> int:
        return int(self.qid.shape[0])

    def pad_to(self, jp: int) -> "QueryPlan":
        """Pad the job axis (padding rows: qid=-1 self-queries, empty
        pattern -> TRUE in the cascade but never landing in answers)."""
        j = self.n_jobs
        if jp <= j:
            return self
        p = jp - j

        def zrows(a):
            return np.concatenate(
                [a, np.zeros((p,) + a.shape[1:], dtype=a.dtype)])

        return QueryPlan(
            qid=np.concatenate([self.qid, np.full(p, -1, np.int32)]),
            u=zrows(self.u), v=zrows(self.v),
            req_w=zrows(self.req_w), forb_w=zrows(self.forb_w),
            forb_raw_w=zrows(self.forb_raw_w),
            req_labels=np.concatenate(
                [self.req_labels, np.full((p, self.max_m), -1, np.int32)]),
            full_mask=zrows(self.full_mask),
            n_queries=self.n_queries, max_m=self.max_m)


@dataclasses.dataclass
class QueryStats:
    n_queries: int = 0
    n_jobs: int = 0
    filter_false: int = 0
    filter_true: int = 0
    exact_jobs: int = 0
    exact_rounds: int = 0


def compile_queries(index: TDRIndex,
                    queries: Sequence[tuple[int, int, pat.Pattern]],
                    max_m: int = 4) -> QueryPlan:
    """Compile (u, v, pattern) triples into a vectorized ``QueryPlan``.

    DNF expansion walks the pattern ASTs (inherently per-term Python); all
    plane construction from the flattened term lists is vectorized numpy
    scatters into packed words.
    """
    cfg = index.cfg
    n_lab = index.graph.n_labels
    wl = bitset.n_words(cfg.lab_bits)
    wraw = bitset.n_words(max(n_lab, 1))

    qid, us, vs = [], [], []
    req_j, req_l = [], []      # flattened (job, label) pairs
    forb_j, forb_l = [], []
    req_rows = []              # per-job sorted required ids
    for qi, (u, v, p) in enumerate(queries):
        for term in pat.to_dnf(p):
            if len(term.require) > max_m:
                raise ValueError(
                    f"term with {len(term.require)} required labels exceeds "
                    f"max_m={max_m}; decompose the pattern")
            j = len(qid)
            qid.append(qi); us.append(u); vs.append(v)
            rl = sorted(term.require)
            req_rows.append(rl)
            req_j += [j] * len(rl); req_l += rl
            forb_j += [j] * len(term.forbid); forb_l += sorted(term.forbid)

    j_n = len(qid)
    req_w = np.zeros((j_n, wl), dtype=np.uint32)
    forb_w = np.zeros((j_n, wl), dtype=np.uint32)
    forb_raw_w = np.zeros((j_n, wraw), dtype=np.uint32)
    req_labels = np.full((j_n, max_m), -1, dtype=np.int32)
    full_mask = np.zeros(j_n, dtype=np.int32)
    if req_j:
        rj = np.asarray(req_j); rl = np.asarray(req_l, np.int64)
        bitset.set_bits_np(req_w, (rj,), index.lab_slot[rl])
    if forb_j:
        fj = np.asarray(forb_j); fl = np.asarray(forb_l, np.int64)
        bitset.set_bits_np(forb_w, (fj,), index.lab_slot[fl])
        bitset.set_bits_np(forb_raw_w, (fj,), fl)
    for j, rl in enumerate(req_rows):
        req_labels[j, :len(rl)] = rl
        full_mask[j] = (1 << len(rl)) - 1

    return QueryPlan(
        qid=np.asarray(qid, np.int32).reshape(j_n),
        u=np.asarray(us, np.int32).reshape(j_n),
        v=np.asarray(vs, np.int32).reshape(j_n),
        req_w=req_w, forb_w=forb_w, forb_raw_w=forb_raw_w,
        req_labels=req_labels, full_mask=full_mask,
        n_queries=len(queries), max_m=max_m)


# ----------------------------------------------------------- phase 1 (jit)
@functools.partial(jax.jit, static_argnames=("k", "mode"))
def _filter_cascade(u, v, req_w, forb_w, null_w,
                    vtx_packed, h_vtx, h_lab, v_vtx, v_lab,
                    n_out, n_in, push, pop, *, k: int, mode: str):
    """Vectorised filter cascade -> verdict [J] in {FALSE, TRUE, UNKNOWN}.

    All label planes arrive packed; the per-way group predicate runs through
    ``kernels.ops.filter_ways`` (fused Pallas kernel / ref oracle)."""
    from repro.kernels import ops  # deferred: kernels import repro.core

    vbits = vtx_packed[v]            # [J, Wv]
    ubits = vtx_packed[u]

    req_empty = jnp.all(req_w == 0, axis=-1)
    forb_empty = jnp.all(forb_w == 0, axis=-1)

    # u == v: empty path
    same = u == v
    true_same = same & req_empty

    # global membership filters (sound negatives)
    topo_out = bitset.words_contain(n_out[u], vbits)
    topo_in = bitset.words_contain(n_in[v], ubits)
    topo_maybe = topo_out & topo_in

    # interval: DFS-forest ancestor => topologically reachable (sound positive)
    anc = (push[u] < push[v]) & (pop[v] < pop[u])
    true_anc = anc & req_empty & forb_empty & ~same

    # ---- per-way group pruning (fused kernel) ----
    way_ok = ops.filter_ways(h_vtx[u], h_lab[u], v_vtx[u], v_lab[u],
                             vbits, req_w, forb_w, null_w, mode=mode)
    any_way = jnp.any(way_ok, axis=-1)

    maybe = topo_maybe & (any_way | same)
    verdict = jnp.where(true_same | true_anc, TRUE,
                        jnp.where(maybe, UNKNOWN, FALSE))
    # u==v with required labels: no path; it's FALSE only if no self-loop
    # cycle can satisfy -- conservative: keep UNKNOWN path for same-vertex
    # queries with labels (cycles through u can satisfy the pattern).
    verdict = jnp.where(same & ~req_empty,
                        jnp.where(any_way, UNKNOWN, FALSE), verdict)
    return verdict


# ----------------------------------------------------------- phase 2 (jit)
def _state_has_masks(n_states: int, max_m: int) -> np.ndarray:
    """HAS[i] = packed mask of subset-states whose bit i is set."""
    has = np.zeros(max_m, dtype=np.uint32)
    for i in range(max_m):
        for s in range(n_states):
            if (s >> i) & 1:
                has[i] |= np.uint32(1) << np.uint32(s)
    return has


def _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed):
    """Packed Bloom corridor ``V_out(u) ∩ V_in(v)`` as a [V, Q] word mask
    (all-ones where vertex x may lie on a u→v path)."""
    q_n = u.shape[0]
    cor = (bitset.words_contain(n_out_u[:, None, :], vtx_packed[None, :, :]) &
           bitset.words_contain(n_in_v[:, None, :], vtx_packed[None, :, :]))
    cor = cor.at[jnp.arange(q_n), v].set(True)
    cor = cor.at[jnp.arange(q_n), u].set(True)
    return jnp.where(cor.T, _FULL, jnp.uint32(0))        # [V, Q]


def _transition(val, has, sh):
    """Apply subset transition ``s -> s | m`` to packed state bitfields.

    ``has`` masks the state bits whose subset already contains the edge's
    required label (they stay); the rest shift up by ``sh = 2^i`` (setting
    bit i of the subset index).  ``has = ~0, sh = 0`` is the identity."""
    return (val & has) | ((val & ~has) << sh)


def _expand_loop(f0, round_, v, full_mask, max_rounds):
    """Shared fixpoint driver: iterate ``round_`` until every query's target
    state bit is set, nothing changes, or ``max_rounds`` is hit."""
    q_n = v.shape[0]

    def done_of(f):
        return (f[v, jnp.arange(q_n)] >>
                full_mask.astype(jnp.uint32)) & 1 == 1

    def cond(state):
        f, prev_f, it, _ = state
        changed = jnp.any(f != prev_f)
        return jnp.logical_and(changed, jnp.logical_and(
            ~jnp.all(done_of(f)), it < max_rounds))

    def body(state):
        f, _, it, _ = state
        nf = round_(f)
        return nf, f, it + 1, done_of(nf)

    f1 = round_(f0)
    f, _, rounds, _ = jax.lax.while_loop(
        cond, body, (f1, f0, jnp.int32(1), done_of(f1)))
    return done_of(f), rounds


@functools.partial(jax.jit, static_argnames=("v_n", "n_states", "max_m",
                                             "max_rounds", "chunk_words"))
def _expand_segment(u, v, req_labels, forb_raw_w, full_mask,
                    n_out_u, n_in_v, vtx_packed, elab, edge_src, edge_dst,
                    *, v_n: int, n_states: int, max_m: int, max_rounds: int,
                    chunk_words: int):
    """Segment-backend executor: frontier [V, Q] packed state bitfields;
    one round = gather, per-edge transition, packed segment-OR scatter."""
    q_n = u.shape[0]
    cor_mask = _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed)

    # per-(edge, query) masks from label gathers (exact raw-label forbid)
    okbit = (forb_raw_w[:, elab >> 5] >>
             (elab & 31).astype(jnp.uint32)[None, :]) & 1       # [Q, E]
    allow = jnp.where(okbit == 0, _FULL, jnp.uint32(0)).T       # [E, Q]
    has_c = _state_has_masks(n_states, max_m)
    has = jnp.full((elab.shape[0], q_n), _FULL, jnp.uint32)
    sh = jnp.zeros((elab.shape[0], q_n), jnp.uint32)
    for i in range(max_m):  # static unroll; require-sets hold distinct labels
        match = req_labels[:, i][None, :] == elab[:, None]      # [E, Q]
        has = jnp.where(match, jnp.uint32(has_c[i]), has)
        sh = jnp.where(match, jnp.uint32(1 << i), sh)

    f0 = jnp.zeros((v_n, q_n), jnp.uint32)
    f0 = f0.at[u, jnp.arange(q_n)].set(jnp.uint32(1))   # state ∅ at source

    def round_(f):
        val = _transition(f[edge_src] & allow, has, sh)         # [E, Q]
        upd = bitset.segment_or_words(val, edge_dst, num_segments=v_n,
                                      chunk_words=chunk_words)
        return f | (upd & cor_mask)

    return _expand_loop(f0, round_, v, full_mask, max_rounds)


@functools.partial(jax.jit, static_argnames=("n_states", "max_m",
                                             "max_rounds", "mode"))
def _expand_matmul(u, v, class_adj, class_label, req_labels, forb_raw_w,
                   full_mask, n_out_u, n_in_v, vtx_packed, *,
                   n_states: int, max_m: int, max_rounds: int, mode: str):
    """Pallas-backend executor: one ``bitset_matmul`` per label class per
    round on the packed reverse adjacency (class = one special label that
    some query requires/forbids, or the merged neutral rest)."""
    q_n = u.shape[0]
    cor_mask = _corridor_mask(u, v, n_out_u, n_in_v, vtx_packed)

    # per-(class, query) masks; the last class is neutral (label -1):
    # always allowed, identity transition
    lab = class_label                                           # [C]
    labx = jnp.maximum(lab, 0)
    okbit = (forb_raw_w[:, labx >> 5] >>
             (labx & 31).astype(jnp.uint32)[None, :]) & 1       # [Q, C]
    neutral = (lab < 0)[None, :]
    allow = jnp.where(neutral | (okbit == 0), _FULL, jnp.uint32(0)).T
    has_c = _state_has_masks(n_states, max_m)
    has = jnp.full((lab.shape[0], q_n), _FULL, jnp.uint32)
    sh = jnp.zeros((lab.shape[0], q_n), jnp.uint32)
    for i in range(max_m):
        match = (req_labels[:, i][None, :] == lab[:, None]) & ~neutral.T
        has = jnp.where(match, jnp.uint32(has_c[i]), has)
        sh = jnp.where(match, jnp.uint32(1 << i), sh)

    v_n = vtx_packed.shape[0]
    f0 = jnp.zeros((v_n, q_n), jnp.uint32)
    f0 = f0.at[u, jnp.arange(q_n)].set(jnp.uint32(1))

    def round_(f):
        upd = jnp.zeros_like(f)
        for c in range(class_adj.shape[0]):  # static unroll, C small
            y = engine_mod._matmul_rows(class_adj[c], f, mode)[:v_n]
            upd = upd | _transition(y & allow[c][None, :],
                                    has[c][None, :], sh[c][None, :])
        return f | (upd & cor_mask)

    return _expand_loop(f0, round_, v, full_mask, max_rounds)


# ---------------------------------------------------------------- executor
class ExactExecutor:
    """Persistent phase-2 executor bound to one (index, engine) pair.

    Holds the device-resident operands (edge lists, label rows, Blooms) and
    keeps the jitted expansion entry points warm across ``answer_batch``
    calls; chunking pads to stable shapes so recompiles only happen when
    the chunk size or the special-label set changes."""

    def __init__(self, index: TDRIndex, eng: "engine_mod.Engine"):
        self.index = index
        self.engine = eng
        self.elab = jnp.asarray(index.graph.labels)

    def special_labels(self, plan: QueryPlan,
                       jobs: np.ndarray) -> tuple[int, ...]:
        """Labels some pending job requires or forbids (the matmul backend
        gets one adjacency class per special label + one neutral)."""
        req = plan.req_labels[jobs]
        spec = set(int(l) for l in req[req >= 0])
        forb = np.bitwise_or.reduce(plan.forb_raw_w[jobs], axis=0)
        for w, word in enumerate(forb):
            for b in range(32):
                if (int(word) >> b) & 1:
                    spec.add(w * 32 + b)
        return tuple(sorted(spec))

    def run_chunk(self, plan: QueryPlan, jobs: np.ndarray,
                  special: tuple[int, ...]) -> tuple[np.ndarray, int]:
        """Expand one padded chunk of pending jobs -> (reached, rounds)."""
        idx, eng = self.index, self.engine
        g = idx.graph
        n_states = 1 << plan.max_m
        if n_states > 32:
            raise ValueError(
                f"max_m={plan.max_m} needs {n_states} subset states; the "
                "packed executor holds at most 32 (max_m <= 5)")
        max_rounds = g.n_vertices * n_states + 1
        uu = jnp.asarray(plan.u[jobs])
        vv = jnp.asarray(plan.v[jobs])
        req_labels = jnp.asarray(plan.req_labels[jobs])
        forb_raw_w = jnp.asarray(plan.forb_raw_w[jobs])
        full_mask = jnp.asarray(plan.full_mask[jobs])
        n_out_u, n_in_v = idx.n_out[uu], idx.n_in[vv]
        use_matmul = eng.backend == "pallas"
        if use_matmul and not eng.can_pack_dense(len(special) + 1):
            # the class-matrix set would blow the dense cap the engine
            # promised to respect — run this batch's rounds as packed
            # segment reductions instead (same bits, no dense operand)
            warnings.warn(
                f"engine: {len(special) + 1} label-class adjacency "
                "matrices exceed max_dense_bytes; expanding this batch "
                "via the segment path", stacklevel=3)
            use_matmul = False
        if use_matmul:
            class_adj = eng.label_class_adjacency(special)
            class_label = jnp.asarray(np.asarray(special + (-1,), np.int32))
            reached, rounds = _expand_matmul(
                uu, vv, class_adj, class_label, req_labels, forb_raw_w,
                full_mask, n_out_u, n_in_v, idx.vtx_packed,
                n_states=n_states, max_m=plan.max_m, max_rounds=max_rounds,
                mode=eng.matmul_mode)
        else:
            reached, rounds = _expand_segment(
                uu, vv, req_labels, forb_raw_w, full_mask, n_out_u, n_in_v,
                idx.vtx_packed, self.elab, eng.edge_src, eng.edge_dst,
                v_n=g.n_vertices, n_states=n_states, max_m=plan.max_m,
                max_rounds=max_rounds,
                chunk_words=eng.config.chunk_words)
        return np.asarray(reached), int(rounds)


def _executor(index: TDRIndex, eng: "engine_mod.Engine") -> ExactExecutor:
    ex = getattr(eng, "_executor", None)
    if ex is None or ex.index is not index:
        ex = ExactExecutor(index, eng)
        eng._executor = ex
    return ex


# ----------------------------------------------------------------- driver
def _pad_pow2(n: int, lo: int = 16) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def answer_batch(index: TDRIndex,
                 queries: Sequence[tuple[int, int, pat.Pattern]],
                 *, max_m: int = 4, exact_chunk: int = 16,
                 stats: QueryStats | None = None,
                 filters_only: bool = False,
                 backend: str | None = None,
                 engine_config: "engine_mod.EngineConfig | None" = None
                 ) -> np.ndarray:
    """Answer a batch of PCR queries.  Returns bool [n_queries].

    ``backend``/``engine_config`` select the packed-word engine backend for
    phase 2 (and the kernel mode for phase 1); default follows the
    ``repro.core.engine`` contract.
    """
    if max_m > 5:
        raise ValueError(
            f"max_m={max_m}: the packed executor holds subset states in one "
            "uint32 bitfield, so at most 5 required labels per term (32 "
            "states); decompose the pattern")
    eng = index.engine(backend, engine_config)
    plan = compile_queries(index, queries, max_m=max_m)
    stats = stats if stats is not None else QueryStats()
    stats.n_queries += plan.n_queries
    stats.n_jobs += plan.n_jobs
    answers = np.zeros(plan.n_queries, dtype=bool)
    if plan.n_jobs == 0:
        return answers

    # pad the job axis to a power of two so jit shapes stay stable
    plan_p = plan.pad_to(_pad_pow2(plan.n_jobs))
    null_w = jnp.asarray(_null_words(index.cfg))
    verdict = np.asarray(_filter_cascade(
        jnp.asarray(plan_p.u), jnp.asarray(plan_p.v),
        jnp.asarray(plan_p.req_w), jnp.asarray(plan_p.forb_w), null_w,
        index.vtx_packed, index.h_vtx, index.h_lab, index.v_vtx,
        index.v_lab, index.n_out, index.n_in, index.push, index.pop,
        k=index.cfg.k, mode=eng.kernel_mode))

    real = plan_p.qid >= 0
    stats.filter_false += int(((verdict == FALSE) & real).sum())
    stats.filter_true += int(((verdict == TRUE) & real).sum())
    np.logical_or.at(answers, plan_p.qid[(verdict == TRUE) & real], True)

    pending = np.flatnonzero((verdict == UNKNOWN) & real)
    # jobs whose query is already TRUE need no exact work
    pending = pending[~answers[plan_p.qid[pending]]]
    if filters_only:
        # treat UNKNOWN as reachable (upper bound) -- used to measure the
        # cascade's pruning power in benchmarks
        np.logical_or.at(answers, plan_p.qid[pending], True)
        return answers
    stats.exact_jobs += len(pending)
    if len(pending) == 0:
        return answers

    ex = _executor(index, eng)
    special = ex.special_labels(plan_p, pending)
    for c0 in range(0, len(pending), exact_chunk):
        jobs = pending[c0:c0 + exact_chunk]
        real_n = len(jobs)
        if real_n < exact_chunk:   # pad to a stable jit shape
            jobs = np.concatenate(
                [jobs, np.full(exact_chunk - real_n, jobs[0], np.int64)])
        reached, rounds = ex.run_chunk(plan_p, jobs, special)
        stats.exact_rounds += rounds
        hit = jobs[:real_n][reached[:real_n]]
        np.logical_or.at(answers, plan_p.qid[hit], True)
    return answers


def answer(index: TDRIndex, u: int, v: int, p: pat.Pattern, **kw) -> bool:
    return bool(answer_batch(index, [(u, v, p)], **kw)[0])

"""Answering PCR queries with the TDR index (paper §V, Alg. 2) — batched.

The paper's Alg. 2 interleaves pruning with a DFS.  On TPU we split the same
logic into two phases, both batched over the whole query set:

Phase 1 — *filter cascade* (pure index math, no traversal):
  * ``u == v``            -> TRUE iff the term requires no labels
  * ``bits(v) ⊄ N_out(u)``-> FALSE   (paper: VertexReach)
  * ``bits(u) ⊄ N_in(v)`` -> FALSE   (paper: VertexReach, reverse)
  * interval ancestor + unconstrained term -> TRUE (paper: early stopping)
  * per-way group pruning: way g survives iff
      - ``bits(v) ⊆ H_vtx[u,g]``          (target may be in the way)
      - ``req    ⊆ H_lab[u,g]``           (required labels may appear)
      - no vertical level ℓ<k refutes it: a level refutes when *every*
        real label at hop ℓ+1 is forbidden while v provably was not reached
        within ℓ hops (paper: path-index pruning / early stopping)
    no surviving way -> FALSE
  * everything else -> UNKNOWN, goes to phase 2.

Phase 2 — *exact product-graph expansion* for survivors only: frontier over
states ``(vertex, subset of required labels seen)`` with forbidden edges
deleted and the frontier confined to the Bloom *corridor*
``V_out(u) ∩ V_in(v)`` (the index applied inside the search — the paper's
VertexReach at every step, vectorised).  The expansion is the same
boolean-semiring product the index build uses, so answers are exact:
property tests assert bit-equality with the DFS oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from . import pattern as pat
from .graph import Graph
from .tdr_build import TDRIndex

FALSE, TRUE, UNKNOWN = 0, 1, 2


# ------------------------------------------------------------------- jobs
@dataclasses.dataclass
class QueryBatch:
    """One flattened DNF-term job per row."""
    qid: np.ndarray        # [J] query id
    u: np.ndarray          # [J]
    v: np.ndarray          # [J]
    req_plane: np.ndarray  # bool [J, lab_bits]  required-label slots
    forb_plane: np.ndarray # bool [J, lab_bits]  forbidden-label slots
    req_labels: np.ndarray # int32 [J, max_m]    raw label ids, -1 padded
    forb_raw: np.ndarray   # bool [J, L]         raw forbidden labels
    n_queries: int


@dataclasses.dataclass
class QueryStats:
    n_queries: int = 0
    n_jobs: int = 0
    filter_false: int = 0
    filter_true: int = 0
    exact_jobs: int = 0
    exact_rounds: int = 0


def compile_queries(index: TDRIndex,
                    queries: Sequence[tuple[int, int, pat.Pattern]],
                    max_m: int = 4) -> QueryBatch:
    cfg = index.cfg
    n_lab = index.graph.n_labels
    qid, us, vs, reqp, forbp, reql, forbr = [], [], [], [], [], [], []
    for qi, (u, v, p) in enumerate(queries):
        for term in pat.to_dnf(p):
            if len(term.require) > max_m:
                raise ValueError(
                    f"term with {len(term.require)} required labels exceeds "
                    f"max_m={max_m}; decompose the pattern")
            rp = np.zeros(cfg.lab_bits, dtype=bool)
            fp = np.zeros(cfg.lab_bits, dtype=bool)
            fr = np.zeros(n_lab, dtype=bool)
            for l in term.require:
                rp[index.lab_slot[l]] = True
            for l in term.forbid:
                fp[index.lab_slot[l]] = True
                fr[l] = True
            rl = sorted(term.require) + [-1] * (max_m - len(term.require))
            qid.append(qi); us.append(u); vs.append(v)
            reqp.append(rp); forbp.append(fp); reql.append(rl); forbr.append(fr)
    if not qid:  # all-false patterns
        return QueryBatch(np.zeros(0, np.int32), np.zeros(0, np.int32),
                          np.zeros(0, np.int32),
                          np.zeros((0, cfg.lab_bits), bool),
                          np.zeros((0, cfg.lab_bits), bool),
                          np.zeros((0, max_m), np.int32),
                          np.zeros((0, n_lab), bool), len(queries))
    return QueryBatch(np.asarray(qid, np.int32), np.asarray(us, np.int32),
                      np.asarray(vs, np.int32),
                      np.stack(reqp), np.stack(forbp),
                      np.asarray(reql, np.int32), np.stack(forbr),
                      len(queries))


# ----------------------------------------------------------- phase 1 (jit)
@functools.partial(jax.jit, static_argnames=("k",))
def _filter_cascade(u, v, req_plane, forb_plane, null_plane,
                    vtx_rows_packed, h_vtx, h_lab, v_vtx, v_lab,
                    n_out, n_in, push, pop, *, k: int):
    """Vectorised filter cascade -> verdict [J] in {FALSE, TRUE, UNKNOWN}."""
    req_w = bitset.pack_bits(req_plane)
    forb_w = bitset.pack_bits(forb_plane)
    vbits = vtx_rows_packed[v]            # [J, Wv]
    ubits = vtx_rows_packed[u]

    req_empty = jnp.all(~req_plane, axis=-1)
    forb_empty = jnp.all(~forb_plane, axis=-1)

    # u == v: empty path
    same = u == v
    true_same = same & req_empty

    # global membership filters (sound negatives)
    topo_out = bitset.words_contain(n_out[u], vbits)
    topo_in = bitset.words_contain(n_in[v], ubits)
    topo_maybe = topo_out & topo_in

    # interval: DFS-forest ancestor => topologically reachable (sound positive)
    anc = (push[u] < push[v]) & (pop[v] < pop[u])
    true_anc = anc & req_empty & forb_empty & ~same

    # ---- per-way group pruning ----
    hv = h_vtx[u]                          # [J, G, Wv]
    hl = h_lab[u]                          # [J, G, Wl]
    way_has_target = bitset.words_contain(hv, vbits[:, None, :])
    way_has_req = bitset.words_contain(hl, req_w[:, None, :])

    # vertical refutation per level
    vl = v_lab[u]                          # [J, G, k, Wl]
    vv = v_vtx[u]                          # [J, G, k, Wv]
    # level blocked: every *real* label at hop l+1 is forbidden (the NULL
    # bit marks paths that already ended -- those cannot continue either,
    # so it is excluded from the "still traversable" test)
    blocked = jnp.all(
        (vl & ~forb_w[:, None, None, :] & ~null_plane[None, None, None, :])
        == 0, axis=-1)                     # [J, G, k]
    # v reached within <= l hops? (levels 0..l-1)
    reached = bitset.words_contain(vv, vbits[:, None, None, :])  # [J,G,k]
    reached_upto = jnp.cumsum(reached.astype(jnp.int32), axis=-1) > 0
    # refute at level l: blocked[l] and not reached within l hops
    not_reached_before = jnp.concatenate(
        [jnp.ones_like(reached_upto[..., :1]),
         ~reached_upto[..., :-1]], axis=-1)
    refuted = jnp.any(blocked & not_reached_before, axis=-1)  # [J, G]

    way_ok = way_has_target & way_has_req & ~refuted
    any_way = jnp.any(way_ok, axis=-1)

    maybe = topo_maybe & (any_way | same)
    verdict = jnp.where(true_same | true_anc, TRUE,
                        jnp.where(maybe, UNKNOWN, FALSE))
    # u==v with required labels: no path; it's FALSE only if no self-loop
    # cycle can satisfy -- conservative: keep UNKNOWN path for same-vertex
    # queries with labels (cycles through u can satisfy the pattern).
    verdict = jnp.where(same & ~req_empty,
                        jnp.where(any_way, UNKNOWN, FALSE), verdict)
    return verdict


# ----------------------------------------------------------- phase 2 (jit)
@functools.partial(jax.jit, static_argnames=("v_n", "n_states", "max_rounds"))
def _exact_expand(u, v, edge_ok, edge_sbit, full_mask, corridor,
                  edge_src, edge_dst, *, v_n: int, n_states: int,
                  max_rounds: int):
    """Batched product-graph reachability.

    Args:
      u, v:        [Q] endpoints
      edge_ok:     [Q, E] edge not forbidden
      edge_sbit:   [Q, E] subset bit contributed by the edge's label (0 if
                   the label is not required)
      full_mask:   [Q]    target subset state
      corridor:    [Q, V] Bloom corridor V_out(u) ∩ V_in(v)
    Returns: reached [Q] bool, rounds int32
    """
    q_n, e_n = edge_ok.shape
    states = jnp.arange(n_states, dtype=jnp.int32)

    f0 = jnp.zeros((q_n, n_states, v_n), dtype=jnp.bool_)
    f0 = f0.at[jnp.arange(q_n), 0, u].set(True)

    def one_round(f):
        def per_query(fq, okq, sbitq, corq):
            val = fq[:, edge_src] & okq[None, :]          # [S, E]
            tgt_state = states[:, None] | sbitq[None, :]   # [S, E]
            seg = tgt_state * v_n + edge_dst[None, :]
            upd = jax.ops.segment_max(
                val.reshape(-1).astype(jnp.uint8), seg.reshape(-1),
                num_segments=n_states * v_n)
            upd = upd.reshape(n_states, v_n).astype(jnp.bool_)
            return fq | (upd & corq[None, :])
        return jax.vmap(per_query)(f, edge_ok, edge_sbit, corridor)

    def done_of(f):
        return f[jnp.arange(q_n), full_mask, v]

    def cond(state):
        f, prev_f, it, _ = state
        changed = jnp.any(f != prev_f)
        return jnp.logical_and(changed, jnp.logical_and(
            ~jnp.all(done_of(f)), it < max_rounds))

    def body(state):
        f, _, it, _ = state
        nf = one_round(f)
        return nf, f, it + 1, done_of(nf)

    f1 = one_round(f0)
    state = (f1, f0, jnp.int32(1), done_of(f1))
    f, _, rounds, _ = jax.lax.while_loop(cond, body, state)
    return done_of(f), rounds


# ----------------------------------------------------------------- driver
def _pad_pow2(n: int, lo: int = 16) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def answer_batch(index: TDRIndex,
                 queries: Sequence[tuple[int, int, pat.Pattern]],
                 *, max_m: int = 4, exact_chunk: int = 16,
                 stats: QueryStats | None = None,
                 filters_only: bool = False) -> np.ndarray:
    """Answer a batch of PCR queries.  Returns bool [n_queries]."""
    g = index.graph
    batch = compile_queries(index, queries, max_m=max_m)
    stats = stats if stats is not None else QueryStats()
    stats.n_queries += batch.n_queries
    stats.n_jobs += len(batch.qid)
    answers = np.zeros(batch.n_queries, dtype=bool)
    if len(batch.qid) == 0:
        return answers

    # pad the job axis to a power of two so jit shapes stay stable across
    # batches (padding rows are self-queries with empty patterns -> TRUE,
    # but their qid=-1 so they never land in `answers`)
    j = len(batch.qid)
    jp = _pad_pow2(j)
    if jp != j:
        pad = jp - j
        batch = QueryBatch(
            np.concatenate([batch.qid, np.full(pad, -1, np.int32)]),
            np.concatenate([batch.u, np.zeros(pad, np.int32)]),
            np.concatenate([batch.v, np.zeros(pad, np.int32)]),
            np.concatenate([batch.req_plane,
                            np.zeros((pad,) + batch.req_plane.shape[1:],
                                     bool)]),
            np.concatenate([batch.forb_plane,
                            np.zeros((pad,) + batch.forb_plane.shape[1:],
                                     bool)]),
            np.concatenate([batch.req_labels,
                            np.full((pad, max_m), -1, np.int32)]),
            np.concatenate([batch.forb_raw,
                            np.zeros((pad,) + batch.forb_raw.shape[1:],
                                     bool)]),
            batch.n_queries)

    vtx_packed = index.vtx_packed
    null_plane_np = np.zeros(index.cfg.lab_bits, dtype=bool)
    null_plane_np[index.cfg.null_bit] = True
    null_plane = bitset.pack_bits(jnp.asarray(null_plane_np))
    verdict = np.asarray(_filter_cascade(
        jnp.asarray(batch.u), jnp.asarray(batch.v),
        jnp.asarray(batch.req_plane), jnp.asarray(batch.forb_plane),
        null_plane,
        vtx_packed, index.h_vtx, index.h_lab, index.v_vtx, index.v_lab,
        index.n_out, index.n_in, index.push, index.pop, k=index.cfg.k))

    real = batch.qid >= 0
    stats.filter_false += int(((verdict == FALSE) & real).sum())
    stats.filter_true += int(((verdict == TRUE) & real).sum())
    for j in np.flatnonzero((verdict == TRUE) & real):
        answers[batch.qid[j]] = True

    pending = np.flatnonzero((verdict == UNKNOWN) & real)
    # jobs whose query is already TRUE need no exact work
    pending = np.asarray([j for j in pending if not answers[batch.qid[j]]],
                         dtype=np.int64)
    if filters_only:
        # treat UNKNOWN as reachable (upper bound) -- used to measure the
        # cascade's pruning power in benchmarks
        for j in pending:
            answers[batch.qid[j]] = True
        return answers
    stats.exact_jobs += len(pending)
    if len(pending) == 0:
        return answers

    edge_src = jnp.asarray(g.src)
    edge_dst = jnp.asarray(g.indices)
    elab = np.asarray(g.labels)
    n_states = 1 << max_m
    max_rounds = g.n_vertices * n_states + 1

    for c0 in range(0, len(pending), exact_chunk):
        jobs = pending[c0:c0 + exact_chunk]
        real_n = len(jobs)
        if real_n < exact_chunk:   # pad to a stable jit shape
            jobs = np.concatenate(
                [jobs, np.full(exact_chunk - real_n, jobs[0], np.int64)])
        q_n = len(jobs)
        ok = ~batch.forb_raw[jobs][:, elab]                 # [q, E]
        sbit = np.zeros((q_n, g.n_edges), dtype=np.int32)
        full = np.zeros(q_n, dtype=np.int32)
        for row, j in enumerate(jobs):
            req = [l for l in batch.req_labels[j] if l >= 0]
            full[row] = (1 << len(req)) - 1
            for s, l in enumerate(req):
                sbit[row][elab == l] = 1 << s
        # Bloom corridor: x ∈ V_out(u) ∩ V_in(v)
        uu, vv = batch.u[jobs], batch.v[jobs]
        cor = np.array(
            bitset.words_contain(index.n_out[uu][:, None, :],
                                 vtx_packed[None, :, :]) &
            bitset.words_contain(index.n_in[vv][:, None, :],
                                 vtx_packed[None, :, :]))
        cor[np.arange(q_n), vv] = True
        cor[np.arange(q_n), uu] = True
        reached, rounds = _exact_expand(
            jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ok),
            jnp.asarray(sbit), jnp.asarray(full), jnp.asarray(cor),
            edge_src, edge_dst, v_n=g.n_vertices, n_states=n_states,
            max_rounds=max_rounds)
        stats.exact_rounds += int(rounds)
        for row, j in enumerate(jobs[:real_n]):
            if bool(reached[row]):
                answers[batch.qid[j]] = True
    return answers


def answer(index: TDRIndex, u: int, v: int, p: pat.Pattern, **kw) -> bool:
    return bool(answer_batch(index, [(u, v, p)], **kw)[0])

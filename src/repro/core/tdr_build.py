"""TDR index construction (paper §IV, Alg. 1) — TPU-native formulation.

The paper builds the index by a bottom-up DFS merging child bitsets into
parents.  That is a pointer-chasing, serially-dependent loop; here the same
fixpoint is computed *level-synchronously*:

    R ← R  ∨  (A ⊗ R)        (boolean-OR semiring, one round per level)

which converges in ≤ diameter rounds and makes every round a dense batched
OR-reduction.  All rounds run through ``repro.core.engine`` **on packed
uint32 words end-to-end** — no ``[V, nbits]`` boolean plane is ever
materialized — and, with the ``pallas`` backend, each round is one
``repro.kernels.bitset_matmul`` call on the packed adjacency bit-matrix.
The result is bit-identical to the DFS build: both compute the closure of
the OR-recurrence ``R[u] = ⋁_{(u,v,l)∈E} (bit(v) ∨ R[v])``.

Index anatomy (per vertex ``u``, ``G`` ways, ``k`` vertical levels):

* ``H_vtx [V,G,Wv]``  — horizontal reachable-vertex Bloom masks per way
* ``H_lab [V,G,Wl]``  — horizontal path-label masks per way
* ``V_vtx [V,G,k,Wv]``— vertical per-level vertex masks (hop ℓ+1)
* ``V_lab [V,G,k,Wl]``— vertical per-level label masks (+ NULL bit for
  paths that ended before the level — the paper's virtual null edges)
* ``N_out/N_in [V,Wv]`` — 1-way global closure Blooms (forward / reverse)
* ``push/pop [V]``    — DFS-forest intervals (ancestor ⇒ reachable)

Hashing follows the paper: label bits are identity-mapped while they fit
(else multiplicative), vertex bits use *discovery-order block hashing* — the
paper's "hash consecutive vertices along the path to the same value" trick —
plus an optional second multiplicative hash (Bloom double-hashing).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from . import compressed as compressed_mod
from . import engine as engine_mod
from .graph import Graph, GraphDelta, csr_row_edges, pad_bucket


# ---------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class TDRConfig:
    vtx_bits: int = 256          # Bloom width for vertex sets (per way)
    lab_slots: int = 63          # label slots (identity if n_labels fits)
    g_max: int = 4               # max ways per vertex
    succ_per_way: int = 4        # target successors per way (sets g(u))
    k: int = 3                   # vertical levels
    n_hashes: int = 2            # Bloom hashes per vertex
    hash_scheme: str = "dfs-block"   # "dfs-block" | "mult"
    max_fixpoint_iters: int = 0  # 0 -> |V| (safe upper bound)
    bit_chunk: int = 64          # word-chunk for segment-backend ORs

    @property
    def lab_bits(self) -> int:
        return self.lab_slots + 1  # + NULL bit

    @property
    def null_bit(self) -> int:
        return self.lab_slots


# ----------------------------------------------------------------- index
@dataclasses.dataclass
class TDRIndex:
    cfg: TDRConfig
    graph: Graph
    # packed uint32 device arrays
    h_vtx: jax.Array      # [V, G, Wv]
    h_lab: jax.Array      # [V, G, Wl]
    v_vtx: jax.Array      # [V, G, k, Wv]
    v_lab: jax.Array      # [V, G, k, Wl]
    n_out: jax.Array      # [V, Wv]
    n_in: jax.Array       # [V, Wv]
    push: jax.Array       # [V] int32
    pop: jax.Array        # [V] int32
    g_count: jax.Array    # [V] int32 (ways actually used)
    # host-side hash tables
    vtx_words: np.ndarray      # uint32 [V, Wv] — packed hash row per vertex
    lab_slot: np.ndarray       # int32 [L] — label -> slot
    fixpoint_rounds: int = 0
    _vtx_packed: Any = dataclasses.field(default=None, repr=False)
    _engines: dict = dataclasses.field(default_factory=dict, repr=False)
    # two-level compressed form of each plane (name -> CompressedPlanes),
    # built lazily and row-patched across updates (never silently stale:
    # every code path that rewrites a plane either patches or drops it)
    _comp: dict = dataclasses.field(default_factory=dict, repr=False)
    _sat_dev: Any = dataclasses.field(default=None, repr=False)
    # per-mesh replicated copies of the query-side planes (the distributed
    # cascade broadcasts them once per mesh, not once per batch)
    _replicated: dict = dataclasses.field(default_factory=dict, repr=False)
    # ---- incremental-maintenance state (see update_index) ----
    # The hash layout is *frozen* at first build: ``disc`` pins the
    # discovery-order vertex hashing so updated indexes stay comparable
    # bit-for-bit with ``build_index(new_graph, layout=disc)``.  The raw
    # closure/base/vertical planes are retained so updates can warm-start
    # the fixpoints and patch only affected rows; ``None`` on indexes that
    # predate PR 5 or came from a path that does not populate them (the
    # distributed build keeps only ``disc`` — updates fall back to a
    # layout-pinned rebuild there).
    disc: np.ndarray | None = dataclasses.field(default=None, repr=False)
    base_v: Any = dataclasses.field(default=None, repr=False)  # [V, Wv]
    base_l: Any = dataclasses.field(default=None, repr=False)  # [V, Wl]
    base_r: Any = dataclasses.field(default=None, repr=False)  # [V, Wv]
    r_vtx: Any = dataclasses.field(default=None, repr=False)   # [V, Wv]
    r_lab: Any = dataclasses.field(default=None, repr=False)   # [V, Wl]
    r_in: Any = dataclasses.field(default=None, repr=False)    # [V, Wv]
    d_vtx: Any = dataclasses.field(default=None, repr=False)   # [V, k, Wv]
    d_lab: Any = dataclasses.field(default=None, repr=False)   # [V, k, Wl]

    @property
    def vtx_packed(self) -> jax.Array:
        """Device copy of the per-vertex packed hash rows (cached)."""
        if self._vtx_packed is None:
            self._vtx_packed = jnp.asarray(self.vtx_words)
        return self._vtx_packed

    @property
    def vtx_bit_rows(self) -> np.ndarray:
        """Unpacked bool [V, vtx_bits] hash rows (compat/debug view only —
        the build and query hot paths never materialize this)."""
        return np.unpackbits(
            self.vtx_words.view(np.uint8), axis=1,
            bitorder="little")[:, :self.cfg.vtx_bits].astype(bool)

    def engine(self, backend: str | None = None,
               config: "engine_mod.EngineConfig | None" = None
               ) -> "engine_mod.Engine":
        """Cached packed-word engine over this index's graph.

        The engine holds the packed adjacency bit-matrix, so repeated query
        batches (and rebuilds) reuse both the operands and the jit caches.
        """
        key = engine_mod.resolve_backend(
            backend or (config.backend if config else "auto"))
        if key not in self._engines:
            self._engines[key] = engine_mod.make_engine(
                self.graph, backend=key, config=config)
        return self._engines[key]

    def adj_packed(self, *, reverse: bool = False) -> jax.Array:
        """Packed adjacency bit-matrix for the engine (cached)."""
        return self.engine().adjacency(reverse=reverse)

    def plane_specs(self) -> dict:
        """Every packed plane of the index with its valid-bit width:
        ``name -> (array, nbits)``.  Aux closure planes (``r_*``) are
        included when present — they are what updates warm-start from, so
        their footprint is part of the maintained index."""
        cfg = self.cfg
        specs = {
            "h_vtx": (self.h_vtx, cfg.vtx_bits),
            "h_lab": (self.h_lab, cfg.lab_bits),
            "v_vtx": (self.v_vtx, cfg.vtx_bits),
            "v_lab": (self.v_lab, cfg.lab_bits),
            "n_out": (self.n_out, cfg.vtx_bits),
            "n_in": (self.n_in, cfg.vtx_bits),
            "r_vtx": (self.r_vtx, cfg.vtx_bits),
            "r_lab": (self.r_lab, cfg.lab_bits),
            "r_in": (self.r_in, cfg.vtx_bits),
        }
        return {k: v for k, v in specs.items() if v[0] is not None}

    def aux_plane_specs(self) -> dict:
        """The incremental-maintenance planes with their valid-bit
        widths: ``name -> (array, nbits)`` for the one-hop bases,
        converged closures already in ``plane_specs``, and the vertical
        working planes.  ``repro.core.snapshot`` serializes the union of
        this and ``plane_specs`` so a restored index chains
        ``update_index`` exactly like the one that was saved."""
        cfg = self.cfg
        specs = {
            "base_v": (self.base_v, cfg.vtx_bits),
            "base_l": (self.base_l, cfg.lab_bits),
            "base_r": (self.base_r, cfg.vtx_bits),
            "d_vtx": (self.d_vtx, cfg.vtx_bits),
            "d_lab": (self.d_lab, cfg.lab_bits),
        }
        return {k: v for k, v in specs.items() if v[0] is not None}

    def compressed_planes(self) -> dict:
        """Two-level compressed form of every plane (lazily built, cached
        on the index, row-patched by ``update_index``)."""
        for name, (arr, nbits) in self.plane_specs().items():
            if name not in self._comp:
                self._comp[name] = compressed_mod.compress(
                    np.asarray(arr), nbits=nbits)
        return dict(self._comp)

    def summary_flags(self) -> dict:
        """Host row-summary flags from the compressed planes (level 1):
        ``sat_out[u]`` / ``sat_in[v]`` mark vertices whose global Bloom
        row is ALL_ONE — their membership filter passes for *every*
        counterpart and their query corridor is the whole vertex set, so
        the query path can answer containment and skip corridor probes
        without materializing the dense rows."""
        comp = self.compressed_planes()
        return {
            "sat_out": comp["n_out"].row_states == compressed_mod.ALL_ONE,
            "sat_in": comp["n_in"].row_states == compressed_mod.ALL_ONE,
        }

    def summary_flags_dev(self) -> tuple:
        """Device (sat_out, sat_in) bool [V] for the filter cascade."""
        if self._sat_dev is None:
            flags = self.summary_flags()
            self._sat_dev = (jnp.asarray(flags["sat_out"]),
                             jnp.asarray(flags["sat_in"]))
        return self._sat_dev

    def index_memory_stats(self) -> dict:
        """Per-plane and total footprint, dense vs two-level compressed."""
        planes = {}
        dense = comp = 0
        for name, c in sorted(self.compressed_planes().items()):
            planes[name] = {"dense_bytes": c.dense_nbytes,
                            "compressed_bytes": c.nbytes,
                            "ratio": round(c.ratio, 3)}
            dense += c.dense_nbytes
            comp += c.nbytes
        return {"planes": planes, "dense_bytes": dense,
                "compressed_bytes": comp,
                "ratio": round(dense / max(comp, 1), 3)}

    def size_bytes(self, logical: bool = True) -> int:
        """Index footprint.  ``logical`` counts only the ways in use (the
        paper's accounting); otherwise the dense padded layout."""
        g = np.asarray(self.g_count)
        wv = self.h_vtx.shape[-1]
        wl = self.h_lab.shape[-1]
        k = self.v_lab.shape[2]
        per_way = 4 * (wv + wl + k * (wv + wl))
        ways = int(g.sum()) if logical else int(g.shape[0] * self.cfg.g_max)
        fixed = self.n_out.size * 4 + self.n_in.size * 4 + 2 * 4 * g.shape[0]
        return ways * per_way + fixed


# --------------------------------------------------------- host precompute
def dfs_intervals(graph: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Iterative DFS forest: push/pop counters + discovery order."""
    v_n = graph.n_vertices
    indptr, indices = graph.indptr, graph.indices
    push = np.full(v_n, -1, dtype=np.int64)
    pop = np.full(v_n, -1, dtype=np.int64)
    disc = np.full(v_n, -1, dtype=np.int64)
    t = 0
    d = 0
    # prefer true roots (no predecessors) first, matching the paper
    in_deg = np.zeros(v_n, dtype=np.int64)
    np.add.at(in_deg, indices, 1)
    order = np.concatenate([np.flatnonzero(in_deg == 0),
                            np.flatnonzero(in_deg != 0)])
    for root in order:
        if push[root] >= 0:
            continue
        stack = [(int(root), int(indptr[root]))]
        push[root] = t; t += 1
        disc[root] = d; d += 1
        while stack:
            u, i = stack[-1]
            if i < indptr[u + 1]:
                stack[-1] = (u, i + 1)
                w = int(indices[i])
                if push[w] < 0:
                    push[w] = t; t += 1
                    disc[w] = d; d += 1
                    stack.append((w, int(indptr[w])))
            else:
                stack.pop()
                pop[u] = t; t += 1
    return push.astype(np.int32), pop.astype(np.int32), disc.astype(np.int32)


def _hash_keys(n: int) -> list:
    """``n`` distinct odd 64-bit multipliers for the Bloom hash schedule.

    The first three are the historical golden-ratio constants (so indexes
    built with ``n_hashes <= 4`` are unchanged); beyond that, keys are
    derived per-index with splitmix64.  The pre-fix schedule wrapped
    (``ks[(i - 1) % 3]``), so hash 4 duplicated hash 1 bit-for-bit —
    silently adding zero Bloom selectivity.
    """
    ks = [0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9]
    mask = (1 << 64) - 1
    x = ks[-1]
    while len(ks) < n:
        x = (x + 0x9E3779B97F4A7C15) & mask
        z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
        ks.append((z ^ (z >> 31)) | 1)
    return [np.uint64(k) for k in ks[:n]]


def _vertex_hash_positions(cfg: TDRConfig, disc: np.ndarray) -> list:
    """Bloom bit positions per vertex: one int64 [V] array per hash."""
    v_n = disc.shape[0]
    ids = np.arange(v_n, dtype=np.uint64)
    if cfg.hash_scheme == "dfs-block":
        # consecutive discovery order -> same bit (paper's locality hashing)
        h0 = (disc.astype(np.uint64) * np.uint64(cfg.vtx_bits)) // np.uint64(
            max(v_n, 1))
    else:
        h0 = ((ids + 1) * np.uint64(2654435761)) % np.uint64(cfg.vtx_bits)
    positions = [h0.astype(np.int64) % cfg.vtx_bits]
    ks = _hash_keys(max(cfg.n_hashes - 1, 0))
    for i in range(1, cfg.n_hashes):
        h = (((ids + 1) * ks[i - 1]) >> np.uint64(17)) % np.uint64(
            cfg.vtx_bits)
        positions.append(h.astype(np.int64))
    return positions


def _vertex_bit_words(cfg: TDRConfig, disc: np.ndarray) -> np.ndarray:
    """Packed Bloom pattern per vertex (uint32 [V, ceil(vtx_bits/32)])."""
    v_n = disc.shape[0]
    words = np.zeros((v_n, bitset.n_words(cfg.vtx_bits)), dtype=np.uint32)
    for pos in _vertex_hash_positions(cfg, disc):
        bitset.set_bits_np(words, (np.arange(v_n),), pos)
    return words


def _vertex_bit_rows(cfg: TDRConfig, disc: np.ndarray) -> np.ndarray:
    """Bloom bit pattern per vertex (bool [V, vtx_bits]) — unpacked view
    for tests/debug only; every runtime path (including the distributed
    exchange) works on the packed words of ``_vertex_bit_words``."""
    v_n = disc.shape[0]
    rows = np.zeros((v_n, cfg.vtx_bits), dtype=bool)
    for pos in _vertex_hash_positions(cfg, disc):
        rows[np.arange(v_n), pos] = True
    return rows


def _label_slots(cfg: TDRConfig, n_labels: int) -> np.ndarray:
    ids = np.arange(n_labels, dtype=np.uint64)
    if n_labels <= cfg.lab_slots:
        return ids.astype(np.int32)
    return (((ids + 1) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(13)
            ).astype(np.int64).astype(np.int32) % np.int32(cfg.lab_slots)


def _edge_label_words(cfg: TDRConfig, lab_slot: np.ndarray,
                      labels: np.ndarray) -> np.ndarray:
    """Per-edge packed label plane (uint32 [E, ceil(lab_bits/32)])."""
    e_n = labels.shape[0]
    words = np.zeros((e_n, bitset.n_words(cfg.lab_bits)), dtype=np.uint32)
    bitset.set_bits_np(words, (np.arange(e_n),), lab_slot[labels])
    return words


def _null_words(cfg: TDRConfig) -> np.ndarray:
    """Packed NULL-bit plane (uint32 [ceil(lab_bits/32)])."""
    w = np.zeros(bitset.n_words(cfg.lab_bits), dtype=np.uint32)
    w[cfg.null_bit >> 5] = np.uint32(1) << np.uint32(cfg.null_bit & 31)
    return w


def way_assignment(cfg: TDRConfig, graph: Graph,
                   disc: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex way count g(u) and per-edge way id.

    The paper sets ``g = hash(|Suc(u)|)`` (degree-adaptive); we use the same
    intent with a static cap: ``g(u) = min(next_pow2(ceil(deg/succ_per_way)),
    g_max)``; successors are routed by discovery-order hash for locality.
    """
    deg = graph.out_degree().astype(np.int64)
    g = np.zeros_like(deg)
    nz = deg > 0
    tgt = np.maximum(1, -(-deg[nz] // cfg.succ_per_way))
    g[nz] = np.minimum(2 ** np.ceil(np.log2(tgt)).astype(np.int64), cfg.g_max)
    src = graph.src
    way = (disc[graph.indices].astype(np.int64) % np.maximum(g[src], 1))
    return g.astype(np.int32), way.astype(np.int32)


# ----------------------------------------------------------- device build
def build_index(graph: Graph, cfg: TDRConfig = TDRConfig(), *,
                backend: str | None = None,
                engine_config: "engine_mod.EngineConfig | None" = None,
                mesh=None, layout: np.ndarray | None = None) -> TDRIndex:
    """Construct the full TDR index for every vertex of ``graph``.

    All semiring math runs through the packed-word engine; ``backend``
    (or ``engine_config`` / ``REPRO_ENGINE_BACKEND``) selects segment vs
    pallas per the contract in ``repro.core.engine``.  ``mesh`` (a
    ``jax.sharding.Mesh``) routes to the vertex-sharded distributed build
    (``repro.core.distributed.build_index``) — bit-identical planes, with
    the per-round exchange packed uint32 words.

    ``layout`` pins the discovery-order hash layout (an int32 ``[V]``
    array, normally ``TDRIndex.disc`` of an earlier build over the same
    vertex set) instead of deriving it from this graph's DFS forest.
    Incremental maintenance (``update_index``) freezes that layout, so a
    from-scratch rebuild is bit-identical to an updated index exactly
    when it pins the same one.  DFS push/pop intervals are *always*
    recomputed from ``graph`` — they are exact structure, not hashing.
    """
    if mesh is not None:
        if layout is not None:
            raise ValueError("layout pinning is single-device only; the "
                             "distributed build derives its own")
        from . import distributed  # deferred: distributed imports us back
        return distributed.build_index(graph, cfg, mesh=mesh)
    v_n = graph.n_vertices
    push, pop, disc = dfs_intervals(graph)
    if layout is not None:
        disc = np.asarray(layout, dtype=np.int32)
        if disc.shape != (v_n,):
            raise ValueError(
                f"layout must be an int [{v_n}] discovery-order array")
    vtx_words_np = _vertex_bit_words(cfg, disc)
    lab_slot = _label_slots(cfg, graph.n_labels)
    g_count, way = way_assignment(cfg, graph, disc)

    if engine_config is None:
        engine_config = engine_mod.EngineConfig(bit_chunk=cfg.bit_chunk)
    eng = engine_mod.make_engine(graph, backend=backend,
                                 config=engine_config)

    vtx_w = jnp.asarray(vtx_words_np)                     # [V, Wv]
    lab_w = jnp.asarray(_edge_label_words(cfg, lab_slot, graph.labels))
    max_iters = cfg.max_fixpoint_iters or v_n

    # ---- the three closure fixpoints (forward vtx/lab, reverse) --------
    base_v = eng.propagate(vtx_w)         # R[u] = OR (bit(v) | R[v])
    r_vtx, rounds = eng.closure(base_v, max_iters=max_iters)
    base_l = eng.segment_or(lab_w, eng.edge_src, v_n)
    r_lab, _ = eng.closure(base_l, max_iters=max_iters)
    base_r = eng.propagate(vtx_w, reverse=True)
    r_in, _ = eng.closure(base_r, reverse=True, max_iters=max_iters)

    idx = _assemble_planes(graph, cfg, eng, vtx_w=vtx_w, lab_w=lab_w,
                           base_v=base_v, base_l=base_l, base_r=base_r,
                           r_vtx=r_vtx, r_lab=r_lab, r_in=r_in,
                           g_count=g_count, way=way, push=push, pop=pop,
                           disc=disc, vtx_words_np=vtx_words_np,
                           lab_slot=lab_slot, rounds=int(rounds))
    idx._engines[eng.backend] = eng
    return idx


def _assemble_planes(graph: Graph, cfg: TDRConfig, eng, *, vtx_w, lab_w,
                     base_v, base_l, base_r, r_vtx, r_lab, r_in, g_count,
                     way, push, pop, disc, vtx_words_np, lab_slot,
                     rounds: int) -> TDRIndex:
    """Shared tail of Alg. 1: vertical k-level propagation + per-way
    projections + index wrap-up, given already-converged closures.

    Used by the from-scratch build and by ``update_index`` when the
    affected-row set is too large for row patching (the closures still
    warm-started — only the tail recomputes fully)."""
    v_n = graph.n_vertices
    src, dst = eng.edge_src, eng.edge_dst
    null_w = jnp.asarray(_null_words(cfg))                # [Wl]
    is_leaf = jnp.asarray(graph.out_degree()) == 0

    # ---- vertical levels (exact k-round propagation) --------------------
    d_lab_levels = []   # D_lab[:, l] — labels at hop l+1 from each vertex
    d_vtx_levels = []   # D_vtx[:, l] — vertices at hop l+1
    cur_lab = jnp.where(is_leaf[:, None], null_w[None, :], base_l)
    cur_vtx = base_v
    d_lab_levels.append(cur_lab)
    d_vtx_levels.append(cur_vtx)
    for _ in range(1, cfg.k):
        nxt_lab = eng.propagate(cur_lab)
        nxt_lab = jnp.where(is_leaf[:, None], null_w[None, :], nxt_lab)
        nxt_vtx = eng.propagate(cur_vtx)
        nxt_vtx = jnp.where(is_leaf[:, None], jnp.uint32(0), nxt_vtx)
        d_lab_levels.append(nxt_lab)
        d_vtx_levels.append(nxt_vtx)
        cur_lab, cur_vtx = nxt_lab, nxt_vtx
    d_lab = jnp.stack(d_lab_levels, axis=1)   # [V, k, Wl]
    d_vtx = jnp.stack(d_vtx_levels, axis=1)   # [V, k, Wv]

    # ---- per-way projections --------------------------------------------
    gmax = cfg.g_max
    seg = src * gmax + jnp.asarray(way)
    n_seg = v_n * gmax

    h_vtx = eng.segment_or(vtx_w[dst] | r_vtx[dst], seg, n_seg)
    h_lab = eng.segment_or(lab_w | r_lab[dst], seg, n_seg)
    v_lab_lv = [eng.segment_or(lab_w, seg, n_seg)]
    v_vtx_lv = [eng.segment_or(vtx_w[dst], seg, n_seg)]
    for l in range(1, cfg.k):
        v_lab_lv.append(eng.segment_or(d_lab[dst, l - 1], seg, n_seg))
        v_vtx_lv.append(eng.segment_or(d_vtx[dst, l - 1], seg, n_seg))

    wv = vtx_w.shape[-1]
    wl = lab_w.shape[-1]
    h_vtx = h_vtx.reshape(v_n, gmax, wv)
    h_lab = h_lab.reshape(v_n, gmax, wl)
    v_lab_p = jnp.stack(v_lab_lv, axis=1).reshape(v_n, gmax, cfg.k, wl)
    v_vtx_p = jnp.stack(v_vtx_lv, axis=1).reshape(v_n, gmax, cfg.k, wv)

    # the vertex hashes itself into each *used* way (paper Alg. 1 line 10)
    way_used = jnp.arange(gmax)[None, :] < jnp.asarray(g_count)[:, None]
    h_vtx = h_vtx | jnp.where(way_used[:, :, None], vtx_w[:, None, :],
                              jnp.uint32(0))

    n_out = bitset.or_reduce(h_vtx, axis=1) if gmax > 0 else r_vtx
    n_out = n_out | vtx_w  # self is "reachable" for membership filtering

    return TDRIndex(
        cfg=cfg, graph=graph,
        h_vtx=h_vtx, h_lab=h_lab, v_vtx=v_vtx_p, v_lab=v_lab_p,
        n_out=n_out, n_in=r_in | vtx_w,
        push=jnp.asarray(push), pop=jnp.asarray(pop),
        g_count=jnp.asarray(g_count),
        vtx_words=vtx_words_np, lab_slot=lab_slot,
        fixpoint_rounds=rounds, disc=disc,
        base_v=base_v, base_l=base_l, base_r=base_r,
        r_vtx=r_vtx, r_lab=r_lab, r_in=r_in, d_vtx=d_vtx, d_lab=d_lab)


def _carry_compressed(old_comp: dict, idx2: TDRIndex,
                      row_sets: dict) -> dict:
    """Carry an index's compressed-plane cache across a row-granular
    update: for each cached plane, only the sub-rows derived from the
    vertex rows that could have changed are re-summarized
    (``CompressedPlanes.patch_rows``) — the update never densifies."""
    out = {}
    v_n = idx2.graph.n_vertices
    specs = idx2.plane_specs()
    for name, c in old_comp.items():
        if name not in specs or name not in row_sets:
            continue
        arr, _ = specs[name]
        vrows = np.asarray(row_sets[name], dtype=np.int64)
        flat = arr.reshape(-1, c.n_words)
        mult = flat.shape[0] // max(v_n, 1)
        sub = (vrows[:, None] * mult
               + np.arange(mult, dtype=np.int64)[None, :]).reshape(-1)
        if sub.size == 0:
            out[name] = c
            continue
        out[name] = c.patch_rows(sub, np.asarray(flat[jnp.asarray(sub)]))
    return out


# ------------------------------------------------------ incremental update
@dataclasses.dataclass
class UpdateStats:
    """Counters filled by one ``update_index`` call.

    ``mode`` is "noop" | "incremental" | "rebuild"; ``tail`` refines the
    incremental path: "patch" (row-granular plane rewrite) or "full" (the
    shared build tail, when the affected-row set crossed the threshold
    but the closures still warm-started)."""
    mode: str = ""
    tail: str = ""
    n_added: int = 0
    n_removed: int = 0
    dirty_fwd: int = 0     # rows re-seeded in the forward closures
    dirty_rev: int = 0     # rows re-seeded in the reverse closure
    changed_rows: int = 0  # rows whose closure words actually changed
    patch_rows: int = 0    # rows re-derived by the plane patch
    rounds: int = 0        # warm-start rounds of the forward fixpoint
    wall_s: float = 0.0


def _bfs_mask(indptr: np.ndarray, indices: np.ndarray, seeds,
              v_n: int) -> np.ndarray:
    """Reachable-set bool [V] from ``seeds`` (inclusive) over one CSR —
    the host-side over-invalidation probe for deletions."""
    seen = np.zeros(v_n, dtype=bool)
    frontier = np.unique(np.asarray(seeds, dtype=np.int64))
    while frontier.size:
        seen[frontier] = True
        nbr = indices[csr_row_edges(indptr, frontier)]
        frontier = np.unique(nbr[~seen[nbr]])
    return seen


def _pad_patch(rows: np.ndarray, v_n: int, lo: int = 8) -> np.ndarray:
    """Pad a patch-row id list onto the ``{2^k, 3*2^(k-1)}`` bucket grid.
    Padding slots hold the out-of-range sentinel ``v_n`` — jax drops
    out-of-bounds scatter rows, so padded writes vanish."""
    rp = pad_bucket(max(rows.shape[0], 1), lo=lo)
    out = np.full(rp, v_n, dtype=np.int32)
    out[:rows.shape[0]] = rows
    return out


def _pad_edges(arrs: list, e_n: int, lo: int = 8):
    """Pad per-edge patch operands to a bucket; returns (padded arrays,
    uint32 [Ep, 1] validity mask ANDed into every gathered value so the
    padding contributes nothing to the ORs)."""
    ep = pad_bucket(max(e_n, 1), lo=lo)
    valid = np.zeros((ep, 1), dtype=np.uint32)
    valid[:e_n] = np.uint32(0xFFFFFFFF)
    out = []
    for a in arrs:
        pad_shape = (ep - e_n,) + a.shape[1:]
        out.append(np.concatenate([a, np.zeros(pad_shape, a.dtype)]))
    return out, valid


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def _patch_bases(base_v, base_l, base_r, vtx_w, rows_o, spos_o, dst_o,
                 labw_o, valid_o, rows_i, dpos_i, src_i, valid_i, *,
                 chunk_words: int):
    """Re-derive the one-hop base planes for the rows whose edge set
    changed: out-edge rows for ``base_v``/``base_l``, in-edge rows for
    ``base_r``.  All operands packed uint32; shapes bucket-padded."""
    ro = rows_o.shape[0]
    bv = bitset.segment_or_words(vtx_w[dst_o] & valid_o, spos_o,
                                 num_segments=ro, chunk_words=chunk_words)
    bl = bitset.segment_or_words(labw_o & valid_o, spos_o,
                                 num_segments=ro, chunk_words=chunk_words)
    ri = rows_i.shape[0]
    br = bitset.segment_or_words(vtx_w[src_i] & valid_i, dpos_i,
                                 num_segments=ri, chunk_words=chunk_words)
    return (base_v.at[rows_o].set(bv), base_l.at[rows_o].set(bl),
            base_r.at[rows_i].set(br))


@functools.partial(jax.jit, static_argnames=("k", "gmax", "chunk_words"))
def _patch_tail(d_vtx, d_lab, h_vtx, h_lab, v_vtx, v_lab, base_v2, base_l2,
                r_vtx2, r_lab2, r_in2, vtx_w, null_w, leaf_full, rows,
                leaf_rows, g_rows, spos, dst, labw, way, valid, *,
                k: int, gmax: int, chunk_words: int):
    """Row-granular rewrite of the vertical planes and per-way
    projections for the affected rows only (``rows``, bucket-padded with
    the dropped sentinel).  ``spos`` renumbers each subset edge's source
    to its position in ``rows``; every gathered value is masked by
    ``valid`` so edge padding is inert.  Exactness: a recomputed row uses
    the same formula as the full build over the same (patched) operands,
    and rows outside the patch set are provably unchanged."""
    r = rows.shape[0]

    def seg_rows(vals):
        return bitset.segment_or_words(vals & valid, spos, num_segments=r,
                                       chunk_words=chunk_words)

    # vertical planes: level 0 *is* the (already patched) base planes
    d_vtx2 = d_vtx.at[:, 0].set(base_v2)
    d_lab2 = d_lab.at[:, 0].set(jnp.where(leaf_full[:, None],
                                          null_w[None, :], base_l2))
    for l in range(1, k):
        row_l = seg_rows(d_lab2[dst, l - 1])
        row_l = jnp.where(leaf_rows[:, None], null_w[None, :], row_l)
        d_lab2 = d_lab2.at[rows, l].set(row_l)
        row_v = seg_rows(d_vtx2[dst, l - 1])
        row_v = jnp.where(leaf_rows[:, None], jnp.uint32(0), row_v)
        d_vtx2 = d_vtx2.at[rows, l].set(row_v)

    # per-way projections over the affected rows
    seg = spos * gmax + way

    def proj(vals):
        return bitset.segment_or_words(vals & valid, seg,
                                       num_segments=r * gmax,
                                       chunk_words=chunk_words)

    wv = vtx_w.shape[-1]
    wl = null_w.shape[-1]
    hv = proj(vtx_w[dst] | r_vtx2[dst]).reshape(r, gmax, wv)
    hl = proj(labw | r_lab2[dst]).reshape(r, gmax, wl)
    vl_lv = [proj(labw)]
    vv_lv = [proj(vtx_w[dst])]
    for l in range(1, k):
        vl_lv.append(proj(d_lab2[dst, l - 1]))
        vv_lv.append(proj(d_vtx2[dst, l - 1]))
    vl = jnp.stack(vl_lv, axis=1).reshape(r, gmax, k, wl)
    vv = jnp.stack(vv_lv, axis=1).reshape(r, gmax, k, wv)
    way_used = jnp.arange(gmax)[None, :] < g_rows[:, None]
    hv = hv | jnp.where(way_used[:, :, None], vtx_w[rows][:, None, :],
                        jnp.uint32(0))
    h_vtx2 = h_vtx.at[rows].set(hv)
    h_lab2 = h_lab.at[rows].set(hl)
    v_vtx2 = v_vtx.at[rows].set(vv)
    v_lab2 = v_lab.at[rows].set(vl)
    n_out2 = bitset.or_reduce(h_vtx2, axis=1) | vtx_w
    n_in2 = r_in2 | vtx_w
    return d_vtx2, d_lab2, h_vtx2, h_lab2, v_vtx2, v_lab2, n_out2, n_in2


def update_index(index: TDRIndex, delta: "GraphDelta | None" = None, *,
                 edges_added=(), edges_removed=(),
                 rebuild_threshold: float = 0.5,
                 backend: str | None = None,
                 engine_config: "engine_mod.EngineConfig | None" = None,
                 stats: UpdateStats | None = None) -> TDRIndex:
    """Maintain the TDR index under edge insertions/deletions.

    Returns a *new* ``TDRIndex`` over ``delta.graph`` (``index`` is left
    untouched, so in-flight readers stay consistent); planes are
    bit-identical to ``build_index(delta.graph, cfg,
    layout=index.disc)`` — the frozen-layout rebuild — on every plane.
    ``delta`` is a ``graph.GraphDelta`` from ``Graph.apply_updates``;
    alternatively pass raw ``edges_added``/``edges_removed`` triples.

    Strategy (packed-word delta propagation):

    * **Insertions are monotone** under the OR semiring: the one-hop base
      planes are re-derived for the touched rows only, and the three
      closure fixpoints re-enter ``engine.closure`` *from the previous
      converged state* — the unique least fixpoint is reached in however
      many rounds the delta needs to drain (typically 1-2) instead of a
      diameter's worth.
    * **Deletions are not**: every vertex that could reach a removed
      edge's source (old-graph reachability, a sound superset computed by
      host BFS) is over-invalidated — its closure rows reset to the new
      base — and the same warm fixpoint re-converges them.  When the
      dirty set exceeds ``rebuild_threshold * V`` the update falls back
      to a full (still layout-pinned) rebuild.
    * **Plane patching**: the vertical k-level planes and per-way
      projections are rewritten only for rows that can differ — touched
      sources, the radius-k predecessor ball, and predecessors of rows
      whose closure words actually changed (one device compare) — unless
      that set also crosses the threshold, in which case the shared build
      tail recomputes them in full (closure savings kept either way).

    The hash layout (``disc`` and everything derived from it) stays
    frozen across updates; DFS push/pop intervals and way routing are
    recomputed from the new graph exactly as the pinned rebuild would.
    """
    t0 = time.perf_counter()
    st = stats if stats is not None else UpdateStats()
    if delta is None:
        delta = index.graph.apply_updates(edges_added, edges_removed)
    if not isinstance(delta, GraphDelta):
        raise TypeError("delta must be a graph.GraphDelta "
                        "(the result of Graph.apply_updates)")
    g2 = delta.graph
    if (g2.n_vertices != index.graph.n_vertices
            or g2.n_labels != index.graph.n_labels):
        raise ValueError("updates must preserve the vertex/label universe")
    st.n_added = int(delta.added.shape[0])
    st.n_removed = int(delta.removed.shape[0])
    if delta.n_changes == 0:
        st.mode = "noop"
        st.wall_s = time.perf_counter() - t0
        return index

    cfg = index.cfg
    v_n = g2.n_vertices
    aux_ok = (index.disc is not None and index.base_v is not None
              and index.r_vtx is not None and index.d_vtx is not None
              and cfg.g_max > 0)

    def rebuild():
        st.mode = "rebuild"
        idx2 = build_index(g2, cfg, backend=backend,
                           engine_config=engine_config, layout=index.disc)
        st.wall_s = time.perf_counter() - t0
        return idx2

    if not aux_ok:
        return rebuild()

    # ---- deletion over-invalidation scope (host BFS, sound superset) ----
    if st.n_removed:
        rev_old = index.graph.reverse()
        d_fwd = _bfs_mask(rev_old.indptr, rev_old.indices,
                          delta.removed[:, 0], v_n)
        d_rev = _bfs_mask(index.graph.indptr, index.graph.indices,
                          delta.removed[:, 1], v_n)
    else:
        d_fwd = np.zeros(v_n, dtype=bool)
        d_rev = d_fwd
    st.dirty_fwd = int(d_fwd.sum())
    st.dirty_rev = int(d_rev.sum())
    # inclusive compare: rebuild_threshold=0 always rebuilds, >=1 never
    # does on the dirty check (the patch-scope check below still can)
    if max(st.dirty_fwd, st.dirty_rev) >= rebuild_threshold * v_n:
        return rebuild()

    st.mode = "incremental"
    key = engine_mod.resolve_backend(
        backend or (engine_config.backend if engine_config else "auto"))
    old_eng = index._engines.get(key)
    if old_eng is not None and old_eng.graph is index.graph:
        eng = old_eng.apply_delta(g2, delta.added, delta.removed)
    else:
        ecfg = engine_config or engine_mod.EngineConfig(
            bit_chunk=cfg.bit_chunk)
        eng = engine_mod.make_engine(g2, backend=key, config=ecfg)

    push, pop, _ = dfs_intervals(g2)     # intervals track the new forest
    g_count, way = way_assignment(cfg, g2, index.disc)  # frozen hashing
    vtx_w = index.vtx_packed
    cw = eng.config.chunk_words
    src2 = g2.src

    # ---- one-hop base planes: re-derive touched rows only ---------------
    s_all = np.unique(np.concatenate([delta.added[:, 0],
                                      delta.removed[:, 0]]))
    t_all = np.unique(np.concatenate([delta.added[:, 1],
                                      delta.removed[:, 1]]))
    s_mask = np.zeros(v_n, dtype=bool)
    s_mask[s_all] = True
    keep_o = s_mask[src2]
    so, do, lo_ = src2[keep_o], g2.indices[keep_o], g2.labels[keep_o]
    (spos_o, do_p, labw_o), valid_o = _pad_edges(
        [np.searchsorted(s_all, so).astype(np.int32), do.astype(np.int32),
         _edge_label_words(cfg, index.lab_slot, lo_)], so.shape[0])
    t_mask = np.zeros(v_n, dtype=bool)
    t_mask[t_all] = True
    keep_i = t_mask[g2.indices]
    si, di = src2[keep_i], g2.indices[keep_i]
    (dpos_i, si_p), valid_i = _pad_edges(
        [np.searchsorted(t_all, di).astype(np.int32),
         si.astype(np.int32)], si.shape[0])
    base_v2, base_l2, base_r2 = _patch_bases(
        index.base_v, index.base_l, index.base_r, vtx_w,
        jnp.asarray(_pad_patch(s_all, v_n)), jnp.asarray(spos_o),
        jnp.asarray(do_p), jnp.asarray(labw_o), jnp.asarray(valid_o),
        jnp.asarray(_pad_patch(t_all, v_n)), jnp.asarray(dpos_i),
        jnp.asarray(si_p), jnp.asarray(valid_i), chunk_words=cw)

    # ---- warm-start closures (fwd vtx+lab fused along the word axis) ----
    wv = int(index.base_v.shape[-1])
    max_iters = cfg.max_fixpoint_iters or v_n
    dm = jnp.asarray(d_fwd)
    old_f = jnp.concatenate([index.r_vtx, index.r_lab], axis=1)
    f0 = jnp.concatenate(
        [jnp.where(dm[:, None], base_v2, index.r_vtx) | base_v2,
         jnp.where(dm[:, None], base_l2, index.r_lab) | base_l2], axis=1)
    rf, rounds = eng.closure(f0, max_iters=max_iters)
    r_vtx2, r_lab2 = rf[:, :wv], rf[:, wv:]
    rm = jnp.asarray(d_rev)
    b0 = jnp.where(rm[:, None], base_r2, index.r_in) | base_r2
    r_in2, _ = eng.closure(b0, reverse=True, max_iters=max_iters)
    st.rounds = int(rounds)

    # ---- exact changed-row scope for the plane patch --------------------
    changed = np.asarray(jnp.any(rf != old_f, axis=1))
    st.changed_rows = int(changed.sum())
    rev2 = g2.reverse()

    def with_preds(mask):
        ids = np.flatnonzero(mask)
        out = mask.copy()
        if ids.size:
            out[rev2.indices[csr_row_edges(rev2.indptr, ids)]] = True
        return out

    ball = s_mask
    for _ in range(1, cfg.k):
        ball = with_preds(ball)
    p_mask = s_mask | ball | with_preds(changed)
    st.patch_rows = int(p_mask.sum())

    if st.patch_rows > min(rebuild_threshold, 1.0) * v_n:
        # patch scope too wide: reuse the warm closures, full tail
        st.tail = "full"
        lab_w_all = jnp.asarray(
            _edge_label_words(cfg, index.lab_slot, g2.labels))
        idx2 = _assemble_planes(
            g2, cfg, eng, vtx_w=vtx_w, lab_w=lab_w_all, base_v=base_v2,
            base_l=base_l2, base_r=base_r2, r_vtx=r_vtx2, r_lab=r_lab2,
            r_in=r_in2, g_count=g_count, way=way, push=push, pop=pop,
            disc=index.disc, vtx_words_np=index.vtx_words,
            lab_slot=index.lab_slot, rounds=int(rounds))
        idx2._engines[eng.backend] = eng
        st.wall_s = time.perf_counter() - t0
        return idx2

    # ---- row-granular plane patch ---------------------------------------
    st.tail = "patch"
    rows = np.flatnonzero(p_mask)
    eidx_p = np.flatnonzero(p_mask[src2])
    sp, dp, lp = src2[eidx_p], g2.indices[eidx_p], g2.labels[eidx_p]
    (spos, dp_p, labw_p, way_p), valid_p = _pad_edges(
        [np.searchsorted(rows, sp).astype(np.int32), dp.astype(np.int32),
         _edge_label_words(cfg, index.lab_slot, lp),
         way[eidx_p].astype(np.int32)], sp.shape[0])
    rows_p = _pad_patch(rows, v_n)
    leaf2 = g2.out_degree() == 0
    leaf_rows = np.zeros(rows_p.shape[0], dtype=bool)
    leaf_rows[:rows.shape[0]] = leaf2[rows]
    g_rows = np.zeros(rows_p.shape[0], dtype=np.int32)
    g_rows[:rows.shape[0]] = g_count[rows]
    (d_vtx2, d_lab2, h_vtx2, h_lab2, v_vtx2, v_lab2, n_out2,
     n_in2) = _patch_tail(
        index.d_vtx, index.d_lab, index.h_vtx, index.h_lab, index.v_vtx,
        index.v_lab, base_v2, base_l2, r_vtx2, r_lab2, r_in2, vtx_w,
        jnp.asarray(_null_words(cfg)), jnp.asarray(leaf2),
        jnp.asarray(rows_p), jnp.asarray(leaf_rows), jnp.asarray(g_rows),
        jnp.asarray(spos), jnp.asarray(dp_p), jnp.asarray(labw_p),
        jnp.asarray(way_p), jnp.asarray(valid_p),
        k=cfg.k, gmax=cfg.g_max, chunk_words=cw)
    idx2 = TDRIndex(
        cfg=cfg, graph=g2, h_vtx=h_vtx2, h_lab=h_lab2, v_vtx=v_vtx2,
        v_lab=v_lab2, n_out=n_out2, n_in=n_in2, push=jnp.asarray(push),
        pop=jnp.asarray(pop), g_count=jnp.asarray(g_count),
        vtx_words=index.vtx_words, lab_slot=index.lab_slot,
        fixpoint_rounds=int(rounds), disc=index.disc,
        base_v=base_v2, base_l=base_l2, base_r=base_r2,
        r_vtx=r_vtx2, r_lab=r_lab2, r_in=r_in2,
        d_vtx=d_vtx2, d_lab=d_lab2)
    idx2._engines[eng.backend] = eng
    if index._comp:
        chg_fwd = np.flatnonzero(changed)
        chg_rev = np.flatnonzero(
            np.asarray(jnp.any(r_in2 != index.r_in, axis=1)))
        idx2._comp = _carry_compressed(
            index._comp, idx2,
            {"h_vtx": rows, "h_lab": rows, "v_vtx": rows, "v_lab": rows,
             "n_out": rows, "n_in": chg_rev, "r_vtx": chg_fwd,
             "r_lab": chg_fwd, "r_in": chg_rev})
    st.wall_s = time.perf_counter() - t0
    return idx2

"""TDR index construction (paper §IV, Alg. 1) — TPU-native formulation.

The paper builds the index by a bottom-up DFS merging child bitsets into
parents.  That is a pointer-chasing, serially-dependent loop; here the same
fixpoint is computed *level-synchronously*:

    R ← R  ∨  (A ⊗ R)        (boolean-OR semiring, one round per level)

which converges in ≤ diameter rounds and makes every round a dense batched
OR-reduction.  All rounds run through ``repro.core.engine`` **on packed
uint32 words end-to-end** — no ``[V, nbits]`` boolean plane is ever
materialized — and, with the ``pallas`` backend, each round is one
``repro.kernels.bitset_matmul`` call on the packed adjacency bit-matrix.
The result is bit-identical to the DFS build: both compute the closure of
the OR-recurrence ``R[u] = ⋁_{(u,v,l)∈E} (bit(v) ∨ R[v])``.

Index anatomy (per vertex ``u``, ``G`` ways, ``k`` vertical levels):

* ``H_vtx [V,G,Wv]``  — horizontal reachable-vertex Bloom masks per way
* ``H_lab [V,G,Wl]``  — horizontal path-label masks per way
* ``V_vtx [V,G,k,Wv]``— vertical per-level vertex masks (hop ℓ+1)
* ``V_lab [V,G,k,Wl]``— vertical per-level label masks (+ NULL bit for
  paths that ended before the level — the paper's virtual null edges)
* ``N_out/N_in [V,Wv]`` — 1-way global closure Blooms (forward / reverse)
* ``push/pop [V]``    — DFS-forest intervals (ancestor ⇒ reachable)

Hashing follows the paper: label bits are identity-mapped while they fit
(else multiplicative), vertex bits use *discovery-order block hashing* — the
paper's "hash consecutive vertices along the path to the same value" trick —
plus an optional second multiplicative hash (Bloom double-hashing).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from . import engine as engine_mod
from .graph import Graph


# ---------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class TDRConfig:
    vtx_bits: int = 256          # Bloom width for vertex sets (per way)
    lab_slots: int = 63          # label slots (identity if n_labels fits)
    g_max: int = 4               # max ways per vertex
    succ_per_way: int = 4        # target successors per way (sets g(u))
    k: int = 3                   # vertical levels
    n_hashes: int = 2            # Bloom hashes per vertex
    hash_scheme: str = "dfs-block"   # "dfs-block" | "mult"
    max_fixpoint_iters: int = 0  # 0 -> |V| (safe upper bound)
    bit_chunk: int = 64          # word-chunk for segment-backend ORs

    @property
    def lab_bits(self) -> int:
        return self.lab_slots + 1  # + NULL bit

    @property
    def null_bit(self) -> int:
        return self.lab_slots


# ----------------------------------------------------------------- index
@dataclasses.dataclass
class TDRIndex:
    cfg: TDRConfig
    graph: Graph
    # packed uint32 device arrays
    h_vtx: jax.Array      # [V, G, Wv]
    h_lab: jax.Array      # [V, G, Wl]
    v_vtx: jax.Array      # [V, G, k, Wv]
    v_lab: jax.Array      # [V, G, k, Wl]
    n_out: jax.Array      # [V, Wv]
    n_in: jax.Array       # [V, Wv]
    push: jax.Array       # [V] int32
    pop: jax.Array        # [V] int32
    g_count: jax.Array    # [V] int32 (ways actually used)
    # host-side hash tables
    vtx_words: np.ndarray      # uint32 [V, Wv] — packed hash row per vertex
    lab_slot: np.ndarray       # int32 [L] — label -> slot
    fixpoint_rounds: int = 0
    _vtx_packed: Any = dataclasses.field(default=None, repr=False)
    _engines: dict = dataclasses.field(default_factory=dict, repr=False)
    # per-mesh replicated copies of the query-side planes (the distributed
    # cascade broadcasts them once per mesh, not once per batch)
    _replicated: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def vtx_packed(self) -> jax.Array:
        """Device copy of the per-vertex packed hash rows (cached)."""
        if self._vtx_packed is None:
            self._vtx_packed = jnp.asarray(self.vtx_words)
        return self._vtx_packed

    @property
    def vtx_bit_rows(self) -> np.ndarray:
        """Unpacked bool [V, vtx_bits] hash rows (compat/debug view only —
        the build and query hot paths never materialize this)."""
        return np.unpackbits(
            self.vtx_words.view(np.uint8), axis=1,
            bitorder="little")[:, :self.cfg.vtx_bits].astype(bool)

    def engine(self, backend: str | None = None,
               config: "engine_mod.EngineConfig | None" = None
               ) -> "engine_mod.Engine":
        """Cached packed-word engine over this index's graph.

        The engine holds the packed adjacency bit-matrix, so repeated query
        batches (and rebuilds) reuse both the operands and the jit caches.
        """
        key = engine_mod.resolve_backend(
            backend or (config.backend if config else "auto"))
        if key not in self._engines:
            self._engines[key] = engine_mod.make_engine(
                self.graph, backend=key, config=config)
        return self._engines[key]

    def adj_packed(self, *, reverse: bool = False) -> jax.Array:
        """Packed adjacency bit-matrix for the engine (cached)."""
        return self.engine().adjacency(reverse=reverse)

    def size_bytes(self, logical: bool = True) -> int:
        """Index footprint.  ``logical`` counts only the ways in use (the
        paper's accounting); otherwise the dense padded layout."""
        g = np.asarray(self.g_count)
        wv = self.h_vtx.shape[-1]
        wl = self.h_lab.shape[-1]
        k = self.v_lab.shape[2]
        per_way = 4 * (wv + wl + k * (wv + wl))
        ways = int(g.sum()) if logical else int(g.shape[0] * self.cfg.g_max)
        fixed = self.n_out.size * 4 + self.n_in.size * 4 + 2 * 4 * g.shape[0]
        return ways * per_way + fixed


# --------------------------------------------------------- host precompute
def dfs_intervals(graph: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Iterative DFS forest: push/pop counters + discovery order."""
    v_n = graph.n_vertices
    indptr, indices = graph.indptr, graph.indices
    push = np.full(v_n, -1, dtype=np.int64)
    pop = np.full(v_n, -1, dtype=np.int64)
    disc = np.full(v_n, -1, dtype=np.int64)
    t = 0
    d = 0
    # prefer true roots (no predecessors) first, matching the paper
    in_deg = np.zeros(v_n, dtype=np.int64)
    np.add.at(in_deg, indices, 1)
    order = np.concatenate([np.flatnonzero(in_deg == 0),
                            np.flatnonzero(in_deg != 0)])
    for root in order:
        if push[root] >= 0:
            continue
        stack = [(int(root), int(indptr[root]))]
        push[root] = t; t += 1
        disc[root] = d; d += 1
        while stack:
            u, i = stack[-1]
            if i < indptr[u + 1]:
                stack[-1] = (u, i + 1)
                w = int(indices[i])
                if push[w] < 0:
                    push[w] = t; t += 1
                    disc[w] = d; d += 1
                    stack.append((w, int(indptr[w])))
            else:
                stack.pop()
                pop[u] = t; t += 1
    return push.astype(np.int32), pop.astype(np.int32), disc.astype(np.int32)


def _hash_keys(n: int) -> list:
    """``n`` distinct odd 64-bit multipliers for the Bloom hash schedule.

    The first three are the historical golden-ratio constants (so indexes
    built with ``n_hashes <= 4`` are unchanged); beyond that, keys are
    derived per-index with splitmix64.  The pre-fix schedule wrapped
    (``ks[(i - 1) % 3]``), so hash 4 duplicated hash 1 bit-for-bit —
    silently adding zero Bloom selectivity.
    """
    ks = [0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9]
    mask = (1 << 64) - 1
    x = ks[-1]
    while len(ks) < n:
        x = (x + 0x9E3779B97F4A7C15) & mask
        z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
        ks.append((z ^ (z >> 31)) | 1)
    return [np.uint64(k) for k in ks[:n]]


def _vertex_hash_positions(cfg: TDRConfig, disc: np.ndarray) -> list:
    """Bloom bit positions per vertex: one int64 [V] array per hash."""
    v_n = disc.shape[0]
    ids = np.arange(v_n, dtype=np.uint64)
    if cfg.hash_scheme == "dfs-block":
        # consecutive discovery order -> same bit (paper's locality hashing)
        h0 = (disc.astype(np.uint64) * np.uint64(cfg.vtx_bits)) // np.uint64(
            max(v_n, 1))
    else:
        h0 = ((ids + 1) * np.uint64(2654435761)) % np.uint64(cfg.vtx_bits)
    positions = [h0.astype(np.int64) % cfg.vtx_bits]
    ks = _hash_keys(max(cfg.n_hashes - 1, 0))
    for i in range(1, cfg.n_hashes):
        h = (((ids + 1) * ks[i - 1]) >> np.uint64(17)) % np.uint64(
            cfg.vtx_bits)
        positions.append(h.astype(np.int64))
    return positions


def _vertex_bit_words(cfg: TDRConfig, disc: np.ndarray) -> np.ndarray:
    """Packed Bloom pattern per vertex (uint32 [V, ceil(vtx_bits/32)])."""
    v_n = disc.shape[0]
    words = np.zeros((v_n, bitset.n_words(cfg.vtx_bits)), dtype=np.uint32)
    for pos in _vertex_hash_positions(cfg, disc):
        bitset.set_bits_np(words, (np.arange(v_n),), pos)
    return words


def _vertex_bit_rows(cfg: TDRConfig, disc: np.ndarray) -> np.ndarray:
    """Bloom bit pattern per vertex (bool [V, vtx_bits]) — unpacked view
    for tests/debug only; every runtime path (including the distributed
    exchange) works on the packed words of ``_vertex_bit_words``."""
    v_n = disc.shape[0]
    rows = np.zeros((v_n, cfg.vtx_bits), dtype=bool)
    for pos in _vertex_hash_positions(cfg, disc):
        rows[np.arange(v_n), pos] = True
    return rows


def _label_slots(cfg: TDRConfig, n_labels: int) -> np.ndarray:
    ids = np.arange(n_labels, dtype=np.uint64)
    if n_labels <= cfg.lab_slots:
        return ids.astype(np.int32)
    return (((ids + 1) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(13)
            ).astype(np.int64).astype(np.int32) % np.int32(cfg.lab_slots)


def _edge_label_words(cfg: TDRConfig, lab_slot: np.ndarray,
                      labels: np.ndarray) -> np.ndarray:
    """Per-edge packed label plane (uint32 [E, ceil(lab_bits/32)])."""
    e_n = labels.shape[0]
    words = np.zeros((e_n, bitset.n_words(cfg.lab_bits)), dtype=np.uint32)
    bitset.set_bits_np(words, (np.arange(e_n),), lab_slot[labels])
    return words


def _null_words(cfg: TDRConfig) -> np.ndarray:
    """Packed NULL-bit plane (uint32 [ceil(lab_bits/32)])."""
    w = np.zeros(bitset.n_words(cfg.lab_bits), dtype=np.uint32)
    w[cfg.null_bit >> 5] = np.uint32(1) << np.uint32(cfg.null_bit & 31)
    return w


def way_assignment(cfg: TDRConfig, graph: Graph,
                   disc: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex way count g(u) and per-edge way id.

    The paper sets ``g = hash(|Suc(u)|)`` (degree-adaptive); we use the same
    intent with a static cap: ``g(u) = min(next_pow2(ceil(deg/succ_per_way)),
    g_max)``; successors are routed by discovery-order hash for locality.
    """
    deg = graph.out_degree().astype(np.int64)
    g = np.zeros_like(deg)
    nz = deg > 0
    tgt = np.maximum(1, -(-deg[nz] // cfg.succ_per_way))
    g[nz] = np.minimum(2 ** np.ceil(np.log2(tgt)).astype(np.int64), cfg.g_max)
    src = graph.src
    way = (disc[graph.indices].astype(np.int64) % np.maximum(g[src], 1))
    return g.astype(np.int32), way.astype(np.int32)


# ----------------------------------------------------------- device build
def build_index(graph: Graph, cfg: TDRConfig = TDRConfig(), *,
                backend: str | None = None,
                engine_config: "engine_mod.EngineConfig | None" = None,
                mesh=None) -> TDRIndex:
    """Construct the full TDR index for every vertex of ``graph``.

    All semiring math runs through the packed-word engine; ``backend``
    (or ``engine_config`` / ``REPRO_ENGINE_BACKEND``) selects segment vs
    pallas per the contract in ``repro.core.engine``.  ``mesh`` (a
    ``jax.sharding.Mesh``) routes to the vertex-sharded distributed build
    (``repro.core.distributed.build_index``) — bit-identical planes, with
    the per-round exchange packed uint32 words.
    """
    if mesh is not None:
        from . import distributed  # deferred: distributed imports us back
        return distributed.build_index(graph, cfg, mesh=mesh)
    v_n, e_n = graph.n_vertices, graph.n_edges
    push, pop, disc = dfs_intervals(graph)
    vtx_words_np = _vertex_bit_words(cfg, disc)
    lab_slot = _label_slots(cfg, graph.n_labels)
    g_count, way = way_assignment(cfg, graph, disc)

    if engine_config is None:
        engine_config = engine_mod.EngineConfig(bit_chunk=cfg.bit_chunk)
    eng = engine_mod.make_engine(graph, backend=backend,
                                 config=engine_config)

    src, dst = eng.edge_src, eng.edge_dst
    vtx_w = jnp.asarray(vtx_words_np)                     # [V, Wv]
    lab_w = jnp.asarray(_edge_label_words(cfg, lab_slot, graph.labels))
    null_w = jnp.asarray(_null_words(cfg))                # [Wl]
    deg = jnp.asarray(graph.out_degree())
    is_leaf = deg == 0

    max_iters = cfg.max_fixpoint_iters or v_n

    # ---- forward vertex closure  R[u] = OR (bit(v) | R[v]) --------------
    base_v = eng.propagate(vtx_w)
    r_vtx, rounds = eng.closure(base_v, max_iters=max_iters)

    # ---- forward label closure  Rl[u] = OR (bit(l) | Rl[v]) -------------
    base_l = eng.segment_or(lab_w, src, v_n)
    r_lab, _ = eng.closure(base_l, max_iters=max_iters)

    # ---- reverse closure for N_in ---------------------------------------
    base_r = eng.propagate(vtx_w, reverse=True)
    n_in, _ = eng.closure(base_r, reverse=True, max_iters=max_iters)

    # ---- vertical levels (exact k-round propagation) --------------------
    d_lab_levels = []   # D_lab[:, l] — labels at hop l+1 from each vertex
    d_vtx_levels = []   # D_vtx[:, l] — vertices at hop l+1
    cur_lab = jnp.where(is_leaf[:, None], null_w[None, :], base_l)
    cur_vtx = base_v
    d_lab_levels.append(cur_lab)
    d_vtx_levels.append(cur_vtx)
    for _ in range(1, cfg.k):
        nxt_lab = eng.propagate(cur_lab)
        nxt_lab = jnp.where(is_leaf[:, None], null_w[None, :], nxt_lab)
        nxt_vtx = eng.propagate(cur_vtx)
        nxt_vtx = jnp.where(is_leaf[:, None], jnp.uint32(0), nxt_vtx)
        d_lab_levels.append(nxt_lab)
        d_vtx_levels.append(nxt_vtx)
        cur_lab, cur_vtx = nxt_lab, nxt_vtx
    d_lab = jnp.stack(d_lab_levels, axis=1)   # [V, k, Wl]
    d_vtx = jnp.stack(d_vtx_levels, axis=1)   # [V, k, Wv]

    # ---- per-way projections --------------------------------------------
    gmax = cfg.g_max
    seg = src * gmax + jnp.asarray(way)
    n_seg = v_n * gmax

    h_vtx = eng.segment_or(vtx_w[dst] | r_vtx[dst], seg, n_seg)
    h_lab = eng.segment_or(lab_w | r_lab[dst], seg, n_seg)
    v_lab_lv = [eng.segment_or(lab_w, seg, n_seg)]
    v_vtx_lv = [eng.segment_or(vtx_w[dst], seg, n_seg)]
    for l in range(1, cfg.k):
        v_lab_lv.append(eng.segment_or(d_lab[dst, l - 1], seg, n_seg))
        v_vtx_lv.append(eng.segment_or(d_vtx[dst, l - 1], seg, n_seg))

    wv = vtx_w.shape[-1]
    wl = lab_w.shape[-1]
    h_vtx = h_vtx.reshape(v_n, gmax, wv)
    h_lab = h_lab.reshape(v_n, gmax, wl)
    v_lab = jnp.stack(v_lab_lv, axis=1).reshape(v_n, gmax, cfg.k, wl)
    v_vtx = jnp.stack(v_vtx_lv, axis=1).reshape(v_n, gmax, cfg.k, wv)

    # the vertex hashes itself into each *used* way (paper Alg. 1 line 10)
    way_used = jnp.arange(gmax)[None, :] < jnp.asarray(g_count)[:, None]
    h_vtx = h_vtx | jnp.where(way_used[:, :, None], vtx_w[:, None, :],
                              jnp.uint32(0))

    n_out = bitset.or_reduce(h_vtx, axis=1) if gmax > 0 else r_vtx
    n_out = n_out | vtx_w  # self is "reachable" for membership filtering

    idx = TDRIndex(
        cfg=cfg, graph=graph,
        h_vtx=h_vtx, h_lab=h_lab, v_vtx=v_vtx, v_lab=v_lab,
        n_out=n_out, n_in=n_in | vtx_w,
        push=jnp.asarray(push), pop=jnp.asarray(pop),
        g_count=jnp.asarray(g_count),
        vtx_words=vtx_words_np, lab_slot=lab_slot,
        fixpoint_rounds=int(rounds),
    )
    idx._engines[eng.backend] = eng
    return idx

"""TDR index construction (paper §IV, Alg. 1) — TPU-native formulation.

The paper builds the index by a bottom-up DFS merging child bitsets into
parents.  That is a pointer-chasing, serially-dependent loop; here the same
fixpoint is computed *level-synchronously*:

    R ← R  ∨  (A ⊗ R)        (boolean-OR semiring, one round per level)

which converges in ≤ diameter rounds and makes every round a dense batched
OR-reduction — the shape TPUs (and ``repro.kernels.bitset_matmul``) want.
The result is bit-identical to the DFS build: both compute the closure of the
OR-recurrence ``R[u] = ⋁_{(u,v,l)∈E} (bit(v) ∨ R[v])``.

Index anatomy (per vertex ``u``, ``G`` ways, ``k`` vertical levels):

* ``H_vtx [V,G,Wv]``  — horizontal reachable-vertex Bloom masks per way
* ``H_lab [V,G,Wl]``  — horizontal path-label masks per way
* ``V_vtx [V,G,k,Wv]``— vertical per-level vertex masks (hop ℓ+1)
* ``V_lab [V,G,k,Wl]``— vertical per-level label masks (+ NULL bit for
  paths that ended before the level — the paper's virtual null edges)
* ``N_out/N_in [V,Wv]`` — 1-way global closure Blooms (forward / reverse)
* ``push/pop [V]``    — DFS-forest intervals (ancestor ⇒ reachable)

Hashing follows the paper: label bits are identity-mapped while they fit
(else multiplicative), vertex bits use *discovery-order block hashing* — the
paper's "hash consecutive vertices along the path to the same value" trick —
plus an optional second multiplicative hash (Bloom double-hashing).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset
from .graph import Graph


# ---------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class TDRConfig:
    vtx_bits: int = 256          # Bloom width for vertex sets (per way)
    lab_slots: int = 63          # label slots (identity if n_labels fits)
    g_max: int = 4               # max ways per vertex
    succ_per_way: int = 4        # target successors per way (sets g(u))
    k: int = 3                   # vertical levels
    n_hashes: int = 2            # Bloom hashes per vertex
    hash_scheme: str = "dfs-block"   # "dfs-block" | "mult"
    max_fixpoint_iters: int = 0  # 0 -> |V| (safe upper bound)
    bit_chunk: int = 64          # word-chunk for segment ORs

    @property
    def lab_bits(self) -> int:
        return self.lab_slots + 1  # + NULL bit

    @property
    def null_bit(self) -> int:
        return self.lab_slots


# ----------------------------------------------------------------- index
@dataclasses.dataclass
class TDRIndex:
    cfg: TDRConfig
    graph: Graph
    # packed uint32 device arrays
    h_vtx: jax.Array      # [V, G, Wv]
    h_lab: jax.Array      # [V, G, Wl]
    v_vtx: jax.Array      # [V, G, k, Wv]
    v_lab: jax.Array      # [V, G, k, Wl]
    n_out: jax.Array      # [V, Wv]
    n_in: jax.Array       # [V, Wv]
    push: jax.Array       # [V] int32
    pop: jax.Array        # [V] int32
    g_count: jax.Array    # [V] int32 (ways actually used)
    # host-side hash tables
    vtx_bit_rows: np.ndarray   # bool [V, vtx_bits] — hash pattern of each vertex
    lab_slot: np.ndarray       # int32 [L] — label -> slot
    fixpoint_rounds: int = 0
    _vtx_packed: "jax.Array | None" = None   # cached packed hash rows

    @property
    def vtx_packed(self) -> jax.Array:
        if self._vtx_packed is None:
            object.__setattr__ if False else setattr(
                self, "_vtx_packed",
                jnp.asarray(bitset.pack_bits_np(self.vtx_bit_rows)))
        return self._vtx_packed

    def size_bytes(self, logical: bool = True) -> int:
        """Index footprint.  ``logical`` counts only the ways in use (the
        paper's accounting); otherwise the dense padded layout."""
        g = np.asarray(self.g_count)
        wv = self.h_vtx.shape[-1]
        wl = self.h_lab.shape[-1]
        k = self.v_lab.shape[2]
        per_way = 4 * (wv + wl + k * (wv + wl))
        ways = int(g.sum()) if logical else int(g.shape[0] * self.cfg.g_max)
        fixed = self.n_out.size * 4 + self.n_in.size * 4 + 2 * 4 * g.shape[0]
        return ways * per_way + fixed


# --------------------------------------------------------- host precompute
def dfs_intervals(graph: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Iterative DFS forest: push/pop counters + discovery order."""
    v_n = graph.n_vertices
    indptr, indices = graph.indptr, graph.indices
    push = np.full(v_n, -1, dtype=np.int64)
    pop = np.full(v_n, -1, dtype=np.int64)
    disc = np.full(v_n, -1, dtype=np.int64)
    t = 0
    d = 0
    # prefer true roots (no predecessors) first, matching the paper
    in_deg = np.zeros(v_n, dtype=np.int64)
    np.add.at(in_deg, indices, 1)
    order = np.concatenate([np.flatnonzero(in_deg == 0),
                            np.flatnonzero(in_deg != 0)])
    for root in order:
        if push[root] >= 0:
            continue
        stack = [(int(root), int(indptr[root]))]
        push[root] = t; t += 1
        disc[root] = d; d += 1
        while stack:
            u, i = stack[-1]
            if i < indptr[u + 1]:
                stack[-1] = (u, i + 1)
                w = int(indices[i])
                if push[w] < 0:
                    push[w] = t; t += 1
                    disc[w] = d; d += 1
                    stack.append((w, int(indptr[w])))
            else:
                stack.pop()
                pop[u] = t; t += 1
    return push.astype(np.int32), pop.astype(np.int32), disc.astype(np.int32)


def _vertex_bit_rows(cfg: TDRConfig, disc: np.ndarray) -> np.ndarray:
    """Bloom bit pattern per vertex (bool [V, vtx_bits])."""
    v_n = disc.shape[0]
    rows = np.zeros((v_n, cfg.vtx_bits), dtype=bool)
    ids = np.arange(v_n, dtype=np.uint64)
    if cfg.hash_scheme == "dfs-block":
        # consecutive discovery order -> same bit (paper's locality hashing)
        h0 = (disc.astype(np.uint64) * np.uint64(cfg.vtx_bits)) // np.uint64(
            max(v_n, 1))
    else:
        h0 = ((ids + 1) * np.uint64(2654435761)) % np.uint64(cfg.vtx_bits)
    rows[np.arange(v_n), h0.astype(np.int64) % cfg.vtx_bits] = True
    ks = [np.uint64(0x9E3779B97F4A7C15), np.uint64(0xC2B2AE3D27D4EB4F),
          np.uint64(0x165667B19E3779F9)]
    for i in range(1, cfg.n_hashes):
        h = (((ids + 1) * ks[(i - 1) % len(ks)]) >> np.uint64(17)) % np.uint64(
            cfg.vtx_bits)
        rows[np.arange(v_n), h.astype(np.int64)] = True
    return rows


def _label_slots(cfg: TDRConfig, n_labels: int) -> np.ndarray:
    ids = np.arange(n_labels, dtype=np.uint64)
    if n_labels <= cfg.lab_slots:
        return ids.astype(np.int32)
    return (((ids + 1) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(13)
            ).astype(np.int64).astype(np.int32) % np.int32(cfg.lab_slots)


def way_assignment(cfg: TDRConfig, graph: Graph,
                   disc: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex way count g(u) and per-edge way id.

    The paper sets ``g = hash(|Suc(u)|)`` (degree-adaptive); we use the same
    intent with a static cap: ``g(u) = min(next_pow2(ceil(deg/succ_per_way)),
    g_max)``; successors are routed by discovery-order hash for locality.
    """
    deg = graph.out_degree().astype(np.int64)
    g = np.zeros_like(deg)
    nz = deg > 0
    tgt = np.maximum(1, -(-deg[nz] // cfg.succ_per_way))
    g[nz] = np.minimum(2 ** np.ceil(np.log2(tgt)).astype(np.int64), cfg.g_max)
    src = graph.src
    way = (disc[graph.indices].astype(np.int64) % np.maximum(g[src], 1))
    return g.astype(np.int32), way.astype(np.int32)


# ----------------------------------------------------------- device build
@functools.partial(jax.jit, static_argnames=("v_n", "nbits", "max_iters",
                                             "chunk"))
def _closure_fixpoint(base: jax.Array, edge_src: jax.Array,
                      edge_dst: jax.Array, *, v_n: int, nbits: int,
                      max_iters: int, chunk: int) -> tuple[jax.Array, jax.Array]:
    """R = lfp( R ∨ base ∨ OR_{(u,v)} R[v] ) as level-synchronous rounds."""

    def round_(r):
        gathered = r[edge_dst]
        upd = bitset.segment_or(gathered, edge_src, num_segments=v_n,
                                chunk=chunk)
        return r | upd

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        nr = round_(r)
        return nr, jnp.any(nr != r), it + 1

    r0 = base
    r, _, rounds = jax.lax.while_loop(cond, body,
                                      (r0, jnp.bool_(True), jnp.int32(0)))
    return r, rounds


def build_index(graph: Graph, cfg: TDRConfig = TDRConfig()) -> TDRIndex:
    """Construct the full TDR index for every vertex of ``graph``."""
    v_n, e_n = graph.n_vertices, graph.n_edges
    push, pop, disc = dfs_intervals(graph)
    vtx_rows_np = _vertex_bit_rows(cfg, disc)
    lab_slot = _label_slots(cfg, graph.n_labels)
    g_count, way = way_assignment(cfg, graph, disc)

    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.indices)
    elab = jnp.asarray(graph.labels)
    vtx_rows = jnp.asarray(vtx_rows_np)
    deg = jnp.asarray(graph.out_degree())
    is_leaf = deg == 0

    # per-edge label bit plane [E, lab_bits]
    lab_rows = jnp.zeros((e_n, cfg.lab_bits), dtype=jnp.bool_)
    lab_rows = lab_rows.at[jnp.arange(e_n),
                           jnp.asarray(lab_slot)[elab]].set(True)

    max_iters = cfg.max_fixpoint_iters or v_n
    chunk = cfg.bit_chunk

    # ---- forward vertex closure  R[u] = OR (bit(v) | R[v]) --------------
    base_v = bitset.segment_or(vtx_rows[dst], src, num_segments=v_n,
                               chunk=chunk)
    r_vtx, rounds = _closure_fixpoint(base_v, src, dst, v_n=v_n,
                                      nbits=cfg.vtx_bits,
                                      max_iters=max_iters, chunk=chunk)

    # ---- forward label closure  Rl[u] = OR (bit(l) | Rl[v]) -------------
    base_l = bitset.segment_or(lab_rows, src, num_segments=v_n, chunk=chunk)
    r_lab, _ = _closure_fixpoint(base_l, src, dst, v_n=v_n,
                                 nbits=cfg.lab_bits, max_iters=max_iters,
                                 chunk=chunk)

    # ---- reverse closure for N_in ---------------------------------------
    base_r = bitset.segment_or(vtx_rows[src], dst, num_segments=v_n,
                               chunk=chunk)
    n_in, _ = _closure_fixpoint(base_r, dst, src, v_n=v_n,
                                nbits=cfg.vtx_bits, max_iters=max_iters,
                                chunk=chunk)

    # ---- vertical levels (exact k-round propagation) --------------------
    null_row = jnp.zeros((cfg.lab_bits,), jnp.bool_).at[cfg.null_bit].set(True)
    d_lab_levels = []   # D_lab[:, l] — labels at hop l+1 from each vertex
    d_vtx_levels = []   # D_vtx[:, l] — vertices at hop l+1
    cur_lab = jnp.where(is_leaf[:, None], null_row[None, :], base_l)
    cur_vtx = base_v
    d_lab_levels.append(cur_lab)
    d_vtx_levels.append(cur_vtx)
    for _ in range(1, cfg.k):
        nxt_lab = bitset.segment_or(cur_lab[dst], src, num_segments=v_n,
                                    chunk=chunk)
        nxt_lab = jnp.where(is_leaf[:, None], null_row[None, :], nxt_lab)
        nxt_vtx = bitset.segment_or(cur_vtx[dst], src, num_segments=v_n,
                                    chunk=chunk)
        nxt_vtx = jnp.where(is_leaf[:, None], False, nxt_vtx)
        d_lab_levels.append(nxt_lab)
        d_vtx_levels.append(nxt_vtx)
        cur_lab, cur_vtx = nxt_lab, nxt_vtx
    d_lab = jnp.stack(d_lab_levels, axis=1)   # [V, k, lab_bits]
    d_vtx = jnp.stack(d_vtx_levels, axis=1)   # [V, k, vtx_bits]

    # ---- per-way projections --------------------------------------------
    gmax = cfg.g_max
    seg = src * gmax + jnp.asarray(way)
    n_seg = v_n * gmax

    h_vtx = bitset.segment_or(vtx_rows[dst] | r_vtx[dst], seg,
                              num_segments=n_seg, chunk=chunk)
    h_lab = bitset.segment_or(lab_rows | r_lab[dst], seg,
                              num_segments=n_seg, chunk=chunk)
    v_lab0 = bitset.segment_or(lab_rows, seg, num_segments=n_seg, chunk=chunk)
    v_vtx0 = bitset.segment_or(vtx_rows[dst], seg, num_segments=n_seg,
                               chunk=chunk)
    v_lab_lv = [v_lab0]
    v_vtx_lv = [v_vtx0]
    for l in range(1, cfg.k):
        v_lab_lv.append(bitset.segment_or(d_lab[dst, l - 1], seg,
                                          num_segments=n_seg, chunk=chunk))
        v_vtx_lv.append(bitset.segment_or(d_vtx[dst, l - 1], seg,
                                          num_segments=n_seg, chunk=chunk))

    h_vtx = h_vtx.reshape(v_n, gmax, cfg.vtx_bits)
    h_lab = h_lab.reshape(v_n, gmax, cfg.lab_bits)
    v_lab = jnp.stack(v_lab_lv, axis=1).reshape(v_n, gmax, cfg.k,
                                                cfg.lab_bits)
    v_vtx = jnp.stack(v_vtx_lv, axis=1).reshape(v_n, gmax, cfg.k,
                                                cfg.vtx_bits)

    # the vertex hashes itself into each *used* way (paper Alg. 1 line 10)
    way_used = jnp.arange(gmax)[None, :] < jnp.asarray(g_count)[:, None]
    h_vtx = h_vtx | (vtx_rows[:, None, :] & way_used[:, :, None])

    n_out = jnp.any(h_vtx, axis=1) if gmax > 0 else r_vtx
    n_out = n_out | vtx_rows  # self is "reachable" for membership filtering

    idx = TDRIndex(
        cfg=cfg, graph=graph,
        h_vtx=bitset.pack_bits(h_vtx),
        h_lab=bitset.pack_bits(h_lab),
        v_vtx=bitset.pack_bits(v_vtx),
        v_lab=bitset.pack_bits(v_lab),
        n_out=bitset.pack_bits(n_out),
        n_in=bitset.pack_bits(n_in | vtx_rows),
        push=jnp.asarray(push), pop=jnp.asarray(pop),
        g_count=jnp.asarray(g_count),
        vtx_bit_rows=vtx_rows_np, lab_slot=lab_slot,
        fixpoint_rounds=int(rounds),
    )
    return idx

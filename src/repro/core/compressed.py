"""Two-level compressed bit-plane layout (§IV block decomposition).

The dense index planes are packed uint32 ``[..., W]`` arrays whose words
are overwhelmingly uniform: exactly-ℓ-hop level sets and empty ways leave
long all-zero runs, and converged closures over a graph with a giant
component leave all-one runs (measured on the ER/PA smoke graphs: ~60% of
words all-zero, ~20% all-one).  This module stores such planes in a
hierarchical two-level form:

* **Level 1 — row summary.**  One 2-bit state per row-block:
  ``ALL_ZERO`` / ``ALL_ONE`` / ``MIXED``.  Uniform rows (an empty way, a
  saturated closure row) cost 2 bits total; the query filter cascade and
  the phase-2 corridor probe read this level directly (a saturated
  ``n_out``/``n_in`` row answers containment without touching words).
* **Level 2 — word detail.**  For MIXED rows only, one 2-bit state per
  word-block, again ZERO/ONE/MIXED.
* **Pool.**  The MIXED detail words, compacted row-major.  Everything
  else (mixed-row ids, pool offsets) is derivable by prefix sums and is
  cached but not counted in ``nbytes``.

Row-blocks are a single row and word-blocks a single word by default: a
geometry sweep on the smoke indexes showed multi-row blocks dilute the
uniform runs (4.0x -> 1.3x as rows-per-block grows from 1 to 8), while
the two-level row/word split beats a flat per-word summary (4.5x vs 4.0x
on ER, 5.0x vs 4.2x on PA).

``BlockCompressed`` is the *device-facing* sibling used by the engine's
block-sparse fixpoint: a ``(row-block × word-block)`` state grid over the
packed adjacency plus a compacted pool of MIXED detail blocks, shaped for
``repro.kernels.block_sparse`` (ZERO blocks are skipped, ONE blocks
short-circuit to a column-OR, MIXED blocks are gathered from the pool).

All states are monotone under OR-semiring growth: ZERO -> MIXED -> ONE
(promotion only); demotion happens only through ``patch_rows`` when an
update rewrites a row outright.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from . import bitset
from .graph import pad_bucket

WORD = 32
ALL_ZERO, ALL_ONE, MIXED = 0, 1, 2
_FULL = np.uint32(0xFFFFFFFF)


def _valid_masks(w: int, nbits: int | None) -> np.ndarray:
    """Per-word valid-bit mask uint32 [w] (tail word may be partial)."""
    nbits = w * WORD if nbits is None else int(nbits)
    bits = np.minimum(np.maximum(nbits - WORD * np.arange(w), 0), WORD)
    return ((np.uint64(1) << bits.astype(np.uint64)) - 1).astype(np.uint32)


def _row_word_states(rows: np.ndarray, masks: np.ndarray):
    """(row_states uint8 [R], word_states uint8 [R, W]) of a dense plane."""
    zero = rows == 0
    ones = (rows == masks[None, :]) & (masks[None, :] != 0)
    wstates = np.where(zero, ALL_ZERO,
                       np.where(ones, ALL_ONE, MIXED)).astype(np.uint8)
    rstates = np.full(rows.shape[0], MIXED, dtype=np.uint8)
    rstates[zero.all(axis=1)] = ALL_ZERO
    rstates[ones.all(axis=1)] = ALL_ONE
    return rstates, wstates


@dataclasses.dataclass(frozen=True)
class CompressedPlanes:
    """Two-level compressed form of one packed plane (host-resident).

    ``decompress()`` is bit-identical to the dense plane it was built
    from; ``nbytes`` counts the canonical storage only (2-bit packed
    states + pool words) — the unpacked state views and prefix offsets
    are derivable caches.
    """
    shape: tuple                 # original plane shape (..., W)
    nbits: int                   # valid bits per row (tail words partial)
    row_states: np.ndarray       # uint8 [R]         (level 1)
    mix_rows: np.ndarray         # int64 [MR]        rows with state MIXED
    word_states: np.ndarray      # uint8 [MR, W]     (level 2, mixed rows)
    pool: np.ndarray             # uint32 [NW]       mixed words, row-major
    pool_off: np.ndarray         # int64 [MR + 1]    prefix into ``pool``

    # ------------------------------------------------------------- sizes
    @property
    def n_rows(self) -> int:
        return int(self.row_states.shape[0])

    @property
    def n_words(self) -> int:
        return int(self.shape[-1])

    @property
    def dense_nbytes(self) -> int:
        return self.n_rows * self.n_words * 4

    @property
    def nbytes(self) -> int:
        states = -(-self.n_rows // 4) - (-self.word_states.size // 4)
        return states + self.pool.size * 4

    @property
    def ratio(self) -> float:
        return self.dense_nbytes / max(self.nbytes, 1)

    # ------------------------------------------------------------ codecs
    def decompress(self) -> np.ndarray:
        masks = _valid_masks(self.n_words, self.nbits)
        out = np.zeros((self.n_rows, self.n_words), dtype=np.uint32)
        out[self.row_states == ALL_ONE] = masks[None, :]
        mixed = self.word_states == MIXED
        rows = np.where(self.word_states == ALL_ONE,
                        masks[None, :], np.uint32(0))
        rows[mixed] = self.pool
        out[self.mix_rows] = rows
        return out.reshape(self.shape)

    def same_as(self, other: "CompressedPlanes") -> bool:
        return (self.shape == other.shape and self.nbits == other.nbits
                and np.array_equal(self.row_states, other.row_states)
                and np.array_equal(self.word_states, other.word_states)
                and np.array_equal(self.pool, other.pool))

    # ----------------------------------------------------------- updates
    def patch_rows(self, rows: np.ndarray,
                   new_rows: np.ndarray) -> "CompressedPlanes":
        """Re-summarize ``rows`` from their new dense words; every other
        row's states and pool segment are carried over untouched, so an
        update's cost is O(|patch| + pool) with no full decompress."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        if rows.size == 0:
            return self
        new_rows = np.asarray(new_rows, dtype=np.uint32)
        new_rows = new_rows.reshape(rows.size, self.n_words)
        masks = _valid_masks(self.n_words, self.nbits)
        r_new, w_new = _row_word_states(new_rows, masks)

        row_states = self.row_states.copy()
        row_states[rows] = r_new

        patched = np.zeros(self.n_rows, dtype=bool)
        patched[rows] = True
        keep = ~patched[self.mix_rows]
        pool_row = np.repeat(self.mix_rows,
                             np.diff(self.pool_off))        # [NW]
        pool_keep = keep[np.searchsorted(self.mix_rows, pool_row)]

        add = r_new == MIXED
        mix_ids = np.concatenate([self.mix_rows[keep], rows[add]])
        order = np.argsort(mix_ids, kind="stable")
        wstack = np.concatenate([self.word_states[keep], w_new[add]])
        pool_ids = np.concatenate(
            [pool_row[pool_keep],
             np.repeat(rows[add], (w_new[add] == MIXED).sum(axis=1))])
        pool_vals = np.concatenate(
            [self.pool[pool_keep], new_rows[add][w_new[add] == MIXED]])
        pool_order = np.argsort(pool_ids, kind="stable")
        wstates = wstack[order]
        counts = (wstates == MIXED).sum(axis=1, dtype=np.int64)
        return CompressedPlanes(
            shape=self.shape, nbits=self.nbits, row_states=row_states,
            mix_rows=mix_ids[order], word_states=wstates,
            pool=pool_vals[pool_order],
            pool_off=np.concatenate([[0], np.cumsum(counts)]))


def compress(plane, *, nbits: int | None = None) -> CompressedPlanes:
    """Compress a packed uint32 plane ``[..., W]`` (any leading dims)."""
    dense = np.asarray(plane, dtype=np.uint32)
    shape = dense.shape
    w = shape[-1] if dense.ndim else 1
    rows = dense.reshape(-1, w)
    nbits = w * WORD if nbits is None else int(nbits)
    masks = _valid_masks(w, nbits)
    rstates, wstates = _row_word_states(rows, masks)
    mix_rows = np.flatnonzero(rstates == MIXED).astype(np.int64)
    wstates = wstates[mix_rows]
    mixed = wstates == MIXED
    counts = mixed.sum(axis=1, dtype=np.int64)
    return CompressedPlanes(
        shape=shape, nbits=nbits, row_states=rstates, mix_rows=mix_rows,
        word_states=wstates, pool=rows[mix_rows][mixed],
        pool_off=np.concatenate([[0], np.cumsum(counts)]))


# ---------------------------------------------------- device block operand
@dataclasses.dataclass(frozen=True)
class BlockCompressed:
    """Block-state form of a packed bit-matrix for the block-sparse
    fixpoint kernel: states over ``(br rows × bw words)`` blocks plus a
    compacted pool of the MIXED blocks (bucket-padded so one closure's
    jit signature is stable).  Fields are jax arrays, ready to feed
    ``repro.kernels.block_sparse`` / its jnp oracle."""
    shape: tuple                 # dense packed shape (M, Kw)
    nbits: int                   # valid columns (K bits)
    br: int
    bw: int
    states: object               # uint8 [MB, KB]
    slots: object                # int32 [MB, KB] pool slot (0 if uniform)
    pool: object                 # uint32 [P, br, bw] compacted MIXED blocks
    mix_bi: object               # int32 [P] row-block of pool slot
    mix_bj: object               # int32 [P] word-block of pool slot
    n_mixed: int                 # live pool slots (<= P, rest padding)

    @property
    def grid(self) -> tuple:
        return self.states.shape

    @property
    def nbytes(self) -> int:
        mb, kb = self.states.shape
        return -(-mb * kb // 4) + int(self.n_mixed) * self.br * self.bw * 4

    @property
    def dense_nbytes(self) -> int:
        return int(self.shape[0] * self.shape[1] * 4)


def compress_blocks(a_packed: np.ndarray, *, br: int = 8, bw: int = 1,
                    nbits: int | None = None) -> BlockCompressed:
    """Build the block-state operand from a dense packed bit-matrix.

    Blocks straddling the row or valid-column tail never classify
    ``ALL_ONE`` (the padding is zero and the tail mask partial), so the
    ONE short-circuit stays exact without per-block tail handling.
    """
    import jax.numpy as jnp

    a = np.asarray(a_packed, dtype=np.uint32)
    m, kw = a.shape
    nbits = kw * WORD if nbits is None else int(nbits)
    mb, kb = -(-m // br), -(-kw // bw)
    pad = np.zeros((mb * br, kb * bw), dtype=np.uint32)
    pad[:m, :kw] = a
    blocks = (pad.reshape(mb, br, kb, bw).transpose(0, 2, 1, 3)
              .reshape(mb, kb, br, bw))
    full = np.zeros((mb * br, kb * bw), dtype=np.uint32)
    full[:m, :kw] = _valid_masks(kw, nbits)[None, :]
    full = (full.reshape(mb, br, kb, bw).transpose(0, 2, 1, 3)
            .reshape(mb, kb, br, bw))
    zero = (blocks == 0).all(axis=(2, 3))
    ones = ((blocks == full).all(axis=(2, 3))
            & (full != 0).all(axis=(2, 3)))
    states = np.where(zero, ALL_ZERO,
                      np.where(ones, ALL_ONE, MIXED)).astype(np.uint8)
    bi, bj = np.nonzero(states == MIXED)
    n_mixed = bi.size
    p = max(pad_bucket(max(n_mixed, 1), lo=8), 1)
    pool = np.zeros((p, br, bw), dtype=np.uint32)
    pool[:n_mixed] = blocks[bi, bj]
    slots = np.zeros((mb, kb), dtype=np.int32)
    slots[bi, bj] = np.arange(n_mixed, dtype=np.int32)
    pad_i = np.full(p - n_mixed, mb, dtype=np.int32)   # OOB segment sentinel
    return BlockCompressed(
        shape=(m, kw), nbits=nbits, br=br, bw=bw,
        states=jnp.asarray(states), slots=jnp.asarray(slots),
        pool=jnp.asarray(pool),
        mix_bi=jnp.asarray(np.concatenate([bi.astype(np.int32), pad_i])),
        mix_bj=jnp.asarray(np.concatenate([bj.astype(np.int32),
                                           np.zeros(p - n_mixed,
                                                    np.int32)])),
        n_mixed=n_mixed)


def _bc_flatten(c: BlockCompressed):
    # n_mixed travels as a () int32 leaf, NOT static aux: its value changes
    # under updates, and only shapes/dtypes may key the jit cache — a
    # same-bucket pool must hit the already-compiled fixpoint.
    return ((c.states, c.slots, c.pool, c.mix_bi, c.mix_bj,
             np.int32(c.n_mixed)),
            (c.shape, c.nbits, c.br, c.bw))


def _bc_unflatten(aux, children) -> BlockCompressed:
    shape, nbits, br, bw = aux
    states, slots, pool, mix_bi, mix_bj, n_mixed = children
    return BlockCompressed(shape=shape, nbits=nbits, br=br, bw=bw,
                           states=states, slots=slots, pool=pool,
                           mix_bi=mix_bi, mix_bj=mix_bj, n_mixed=n_mixed)


# Pytree registration lets jitted fixpoints close over the block operand
# directly; the geometry fields are static aux data, so a re-bucketed pool
# (different P) is a fresh jit signature while same-shape updates hit the
# compiled closure.
jax.tree_util.register_pytree_node(BlockCompressed, _bc_flatten,
                                   _bc_unflatten)


def patch_blocks(comp: BlockCompressed, rows: np.ndarray,
                 row_words: np.ndarray) -> BlockCompressed:
    """Re-summarize only the row-block strips touched by ``rows`` (new
    dense words ``row_words`` uint32 [len(rows), Kw]); untouched strips
    keep their states, and the pool is re-compacted host-side in O(P)."""
    import jax.numpy as jnp

    rows = np.asarray(rows, dtype=np.int64).reshape(-1)
    if rows.size == 0:
        return comp
    m, kw = comp.shape
    br, bw = comp.br, comp.bw
    mb, kb = comp.grid
    states = np.asarray(comp.states).copy()
    slots_old = np.asarray(comp.slots)
    pool_old = np.asarray(comp.pool)

    bi_aff = np.unique(rows // br)
    # materialize the affected strips from the old block form
    strip = np.zeros((bi_aff.size, br, kb * bw), dtype=np.uint32)
    full_row = np.zeros(kb * bw, dtype=np.uint32)
    full_row[:kw] = _valid_masks(kw, comp.nbits)
    for s, bi in enumerate(bi_aff):
        for bj in np.flatnonzero(states[bi] != ALL_ZERO):
            blk = (full_row[None, bj * bw:(bj + 1) * bw].repeat(br, axis=0)
                   if states[bi, bj] == ALL_ONE
                   else pool_old[slots_old[bi, bj]])
            strip[s, :, bj * bw:(bj + 1) * bw] = blk
    # zero rows beyond M in the last strip stay zero; scatter the patch
    strip_rows = strip.reshape(bi_aff.size * br, kb * bw)
    local = np.searchsorted(bi_aff, rows // br) * br + rows % br
    strip_rows[local, :kw] = np.asarray(row_words, dtype=np.uint32)
    strip_rows[:, kw:] = 0

    blocks = (strip_rows.reshape(bi_aff.size, br, kb, bw)
              .transpose(0, 2, 1, 3))
    fullb = np.zeros((bi_aff.size * br, kb * bw), dtype=np.uint32)
    valid = (bi_aff[:, None] * br + np.arange(br)[None, :]).reshape(-1) < m
    fullb[valid] = full_row
    fullb = fullb.reshape(bi_aff.size, br, kb, bw).transpose(0, 2, 1, 3)
    zero = (blocks == 0).all(axis=(2, 3))
    ones = ((blocks == fullb).all(axis=(2, 3))
            & (fullb != 0).all(axis=(2, 3)))
    states[bi_aff] = np.where(zero, ALL_ZERO,
                              np.where(ones, ALL_ONE, MIXED)).astype(np.uint8)

    # re-compact the pool: untouched strips keep their blocks verbatim
    bi, bj = np.nonzero(states == MIXED)
    n_mixed = bi.size
    touched = np.isin(bi, bi_aff)
    vals = np.empty((n_mixed, br, bw), dtype=np.uint32)
    vals[~touched] = pool_old[slots_old[bi[~touched], bj[~touched]]]
    vals[touched] = blocks[np.searchsorted(bi_aff, bi[touched]),
                           bj[touched]]
    p = max(pad_bucket(max(n_mixed, 1), lo=8), 1)
    pool = np.zeros((p, br, bw), dtype=np.uint32)
    pool[:n_mixed] = vals
    slots = np.zeros((mb, kb), dtype=np.int32)
    slots[bi, bj] = np.arange(n_mixed, dtype=np.int32)
    pad_i = np.full(p - n_mixed, mb, dtype=np.int32)
    return BlockCompressed(
        shape=comp.shape, nbits=comp.nbits, br=br, bw=bw,
        states=jnp.asarray(states), slots=jnp.asarray(slots),
        pool=jnp.asarray(pool),
        mix_bi=jnp.asarray(np.concatenate([bi.astype(np.int32), pad_i])),
        mix_bj=jnp.asarray(np.concatenate([bj.astype(np.int32),
                                           np.zeros(p - n_mixed,
                                                    np.int32)])),
        n_mixed=n_mixed)


def decompress_blocks(comp: BlockCompressed) -> np.ndarray:
    """Dense packed bit-matrix back from the block form (bit-identical)."""
    m, kw = comp.shape
    mb, kb = comp.grid
    states = np.asarray(comp.states)
    slots = np.asarray(comp.slots)
    pool = np.asarray(comp.pool)
    full = np.zeros((mb * comp.br, kb * comp.bw), dtype=np.uint32)
    full[:m, :kw] = _valid_masks(kw, comp.nbits)[None, :]
    full = (full.reshape(mb, comp.br, kb, comp.bw).transpose(0, 2, 1, 3)
            .reshape(mb, kb, comp.br, comp.bw))
    blocks = np.where((states == ALL_ONE)[:, :, None, None], full, 0)
    bi, bj = np.nonzero(states == MIXED)
    blocks = blocks.astype(np.uint32)
    blocks[bi, bj] = pool[slots[bi, bj]]
    dense = (blocks.reshape(mb, kb, comp.br, comp.bw)
             .transpose(0, 2, 1, 3).reshape(mb * comp.br, kb * comp.bw))
    return dense[:m, :kw]

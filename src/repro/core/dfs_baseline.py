"""The paper's DFS comparison baseline (§VI-A) — also the exact oracle.

Answering a PCR query exactly is a search over the *pattern product graph*:
states are ``(vertex, subset-of-required-labels-seen)`` for one DNF term,
with edges carrying a forbidden label deleted.  The DFS baseline explores it
depth-first with memoisation, exactly terminating on cyclic graphs.  All
property tests compare the TDR engine against this module bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import pattern as pat
from .graph import Graph


@dataclasses.dataclass
class SearchStats:
    states_visited: int = 0
    edges_scanned: int = 0


def answer_pcr(graph: Graph, u: int, v: int, p: pat.Pattern,
               stats: SearchStats | None = None) -> bool:
    """Exact PCR answer by product-graph DFS (no index)."""
    stats = stats or SearchStats()
    for term in pat.to_dnf(p):
        if _answer_term(graph, u, v, term, stats):
            return True
    return False


def _answer_term(graph: Graph, u: int, v: int, term: pat.DnfTerm,
                 stats: SearchStats) -> bool:
    req = sorted(term.require)
    slot = {l: i for i, l in enumerate(req)}
    full = (1 << len(req)) - 1
    forbid = term.forbid

    if u == v and full == 0:
        return True  # empty path, empty label set

    # iterative DFS over (vertex, mask) states
    start = (u, 0)
    seen = {start}
    stack = [start]
    indptr, indices, labels = graph.indptr, graph.indices, graph.labels
    while stack:
        x, m = stack.pop()
        stats.states_visited += 1
        for i in range(indptr[x], indptr[x + 1]):
            stats.edges_scanned += 1
            l = int(labels[i])
            if l in forbid:
                continue
            nm = m | (1 << slot[l]) if l in slot else m
            y = int(indices[i])
            if y == v and nm == full:
                return True
            st = (y, nm)
            if st not in seen:
                seen.add(st)
                stack.append(st)
    return False


def answer_lcr(graph: Graph, u: int, v: int, allowed: set[int],
               stats: SearchStats | None = None) -> bool:
    """Exact LCR answer (BFS restricted to allowed labels)."""
    return answer_pcr(graph, u, v, pat.lcr(sorted(allowed), graph.n_labels),
                      stats)


def reachable_set(graph: Graph, u: int) -> np.ndarray:
    """Plain topological closure of ``u`` (bool [V])."""
    out = np.zeros(graph.n_vertices, dtype=bool)
    stack = [u]
    while stack:
        x = stack.pop()
        for y in graph.successors(x):
            if not out[y]:
                out[y] = True
                stack.append(int(y))
    return out

"""The paper's DFS comparison baseline (§VI-A) — also the exact oracle.

Answering a PCR query exactly is a search over the *pattern product graph*:
states are ``(vertex, subset-of-required-labels-seen)`` for one DNF term,
with edges carrying a forbidden label deleted.  The DFS baseline explores it
depth-first with memoisation, exactly terminating on cyclic graphs.  All
property tests compare the TDR engine against this module bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import pattern as pat
from .graph import Graph


@dataclasses.dataclass
class SearchStats:
    states_visited: int = 0
    edges_scanned: int = 0


def answer_pcr(graph: Graph, u: int, v: int, p: pat.Pattern,
               stats: SearchStats | None = None) -> bool:
    """Exact PCR answer by product-graph DFS (no index)."""
    stats = stats or SearchStats()
    for term in pat.to_dnf(p):
        if _answer_term(graph, u, v, term, stats):
            return True
    return False


def _answer_term(graph: Graph, u: int, v: int, term: pat.DnfTerm,
                 stats: SearchStats) -> bool:
    req = sorted(term.require)
    slot = {l: i for i, l in enumerate(req)}
    full = (1 << len(req)) - 1
    forbid = term.forbid

    if u == v and full == 0:
        return True  # empty path, empty label set

    # iterative DFS over (vertex, mask) states
    start = (u, 0)
    seen = {start}
    stack = [start]
    indptr, indices, labels = graph.indptr, graph.indices, graph.labels
    while stack:
        x, m = stack.pop()
        stats.states_visited += 1
        for i in range(indptr[x], indptr[x + 1]):
            stats.edges_scanned += 1
            l = int(labels[i])
            if l in forbid:
                continue
            nm = m | (1 << slot[l]) if l in slot else m
            y = int(indices[i])
            if y == v and nm == full:
                return True
            st = (y, nm)
            if st not in seen:
                seen.add(st)
                stack.append(st)
    return False


def shortest_pcr(graph: Graph, u: int, v: int, p: pat.Pattern,
                 stats: SearchStats | None = None) -> int:
    """Exact shortest pattern-constrained path length (hops), or -1.

    BFS over the same product graph ``answer_pcr`` searches; the min over
    DNF terms.  The oracle for ``tdr_query.dist`` / ``witness``."""
    stats = stats or SearchStats()
    best = -1
    for term in pat.to_dnf(p):
        d = _shortest_term(graph, u, v, term, stats)
        if d >= 0 and (best < 0 or d < best):
            best = d
    return best


def _shortest_term(graph: Graph, u: int, v: int, term: pat.DnfTerm,
                   stats: SearchStats) -> int:
    req = sorted(term.require)
    slot = {l: i for i, l in enumerate(req)}
    full = (1 << len(req)) - 1
    forbid = term.forbid

    if u == v and full == 0:
        return 0  # empty path, empty label set

    indptr, indices, labels = graph.indptr, graph.indices, graph.labels
    frontier = [(u, 0)]
    seen = {(u, 0)}
    depth = 0
    while frontier:
        depth += 1
        nxt = []
        for x, m in frontier:
            stats.states_visited += 1
            for i in range(indptr[x], indptr[x + 1]):
                stats.edges_scanned += 1
                l = int(labels[i])
                if l in forbid:
                    continue
                nm = m | (1 << slot[l]) if l in slot else m
                y = int(indices[i])
                if y == v and nm == full:
                    return depth
                st = (y, nm)
                if st not in seen:
                    seen.add(st)
                    nxt.append(st)
        frontier = nxt
    return -1


def count_routes(graph: Graph, u: int, v: int, p: pat.Pattern, *,
                 hops: int, cap: int,
                 stats: SearchStats | None = None) -> int:
    """Reference bounded route count with saturating add.

    Number of walks u→v of length <= ``hops`` satisfying the (single-term)
    pattern, every partial sum clamped at ``cap`` — the exact semantics of
    ``tdr_query.count_routes`` (per-round clamping equals clamping the
    total: saturating add of non-negative values is associative).  Walks,
    not simple paths: a cycle re-entering a vertex counts each traversal,
    matching the product-graph DP.  Multi-term patterns are rejected —
    terms overlap, so a per-term sum would double-count.
    """
    stats = stats or SearchStats()
    terms = pat.to_dnf(p)
    if len(terms) != 1:
        raise ValueError(
            f"count_routes needs a single-DNF-term pattern, got "
            f"{len(terms)} terms")
    term = terms[0]
    req = sorted(term.require)
    slot = {l: i for i, l in enumerate(req)}
    full = (1 << len(req)) - 1
    forbid = term.forbid
    indptr, indices, labels = graph.indptr, graph.indices, graph.labels

    # walk-count DP over (vertex, mask), one layer per hop, clamped
    w = {(u, 0): 1}
    total = 1 if (u == v and full == 0) else 0
    for _ in range(hops):
        nw: dict = {}
        for (x, m), c in w.items():
            stats.states_visited += 1
            for i in range(indptr[x], indptr[x + 1]):
                stats.edges_scanned += 1
                l = int(labels[i])
                if l in forbid:
                    continue
                nm = m | (1 << slot[l]) if l in slot else m
                st = (int(indices[i]), nm)
                nw[st] = min(nw.get(st, 0) + c, cap)
        w = nw
        if not w:
            break
        total = min(total + w.get((v, full), 0), cap)
    return total


def verify_witness(graph: Graph, u: int, v: int, p: pat.Pattern,
                   path) -> bool:
    """Check a witness path: edges exist in the graph, endpoints chain
    u→v, and the label sequence satisfies some DNF term of ``p``."""
    if path is None:
        return False
    cur = u
    seen_labels: set[int] = set()
    for (x, y, l) in path:
        if x != cur:
            return False
        lo, hi = graph.indptr[x], graph.indptr[x + 1]
        hit = any(int(graph.indices[i]) == y and int(graph.labels[i]) == l
                  for i in range(lo, hi))
        if not hit:
            return False
        seen_labels.add(int(l))
        cur = y
    if cur != v:
        return False
    return any(t.satisfied_by(seen_labels) for t in pat.to_dnf(p))


def answer_rpq(graph: Graph, u: int, v: int, r,
               stats: SearchStats | None = None) -> bool:
    """Exact RPQ answer: BFS over the product of the graph with the
    Glushkov NFA of ``r`` (states ``(vertex, nfa_state)``), the oracle
    every RPQ executor is tested against.  A u→v path answers True iff
    its label *sequence* is a word of ``L(r)``; ``u == v`` answers True
    iff ε ∈ L(r) (``rpq.nullable``)."""
    from . import rpq as rpq_mod
    stats = stats or SearchStats()
    nfa = rpq_mod.compile_nfa(r, graph.n_labels)
    if u == v and nfa.nullable:
        return True  # empty path, empty word
    tab = nfa.tab
    indptr, indices, labels = graph.indptr, graph.indices, graph.labels
    # seed: every NFA state reachable from the start on zero edges is
    # just the start state (Glushkov has no ε-transitions)
    start = (int(u), 0)
    seen = {start}
    stack = [start]
    while stack:
        x, q = stack.pop()
        stats.states_visited += 1
        row = tab[:, q]
        for i in range(indptr[x], indptr[x + 1]):
            stats.edges_scanned += 1
            nxt = int(row[int(labels[i])])
            if not nxt:
                continue
            y = int(indices[i])
            for p in range(nfa.n_states):
                if not (nxt >> p) & 1:
                    continue
                if y == v and (nfa.accept >> p) & 1:
                    return True
                st = (y, p)
                if st not in seen:
                    seen.add(st)
                    stack.append(st)
    return False


def answer_lcr(graph: Graph, u: int, v: int, allowed: set[int],
               stats: SearchStats | None = None) -> bool:
    """Exact LCR answer (BFS restricted to allowed labels)."""
    return answer_pcr(graph, u, v, pat.lcr(sorted(allowed), graph.n_labels),
                      stats)


def reachable_set(graph: Graph, u: int) -> np.ndarray:
    """Plain topological closure of ``u`` (bool [V])."""
    out = np.zeros(graph.n_vertices, dtype=bool)
    stack = [u]
    while stack:
        x = stack.pop()
        for y in graph.successors(x):
            if not out[y]:
                out[y] = True
                stack.append(int(y))
    return out

"""Distributed TDR on the packed-word engine: sharded build + query.

Scaling posture (the multi-pod story for the paper's engine):

* The vertex set is 1-D partitioned over every device of the mesh (the
  flattened axes, contiguous blocks of ``ceil(V/n)`` rows per device).
  Each device owns the index rows of its vertex shard plus the out-edges
  of its shard (for forward propagation and the per-way projections) and
  the in-edges of its shard (for the reverse closure).  The adjacency
  never moves.
* One fixpoint round = ``all_gather`` of the **packed uint32 closure
  words** (``V × W`` words — 32× fewer gather bytes than the retired
  bool-plane exchange) followed by a purely local packed OR-reduction for
  owned vertices (``bitset.segment_or_words``).  On a 512-chip mesh with
  V=10M and 256-bit Blooms that is 320 MB per round over ICI — a few ms —
  against an embarrassingly parallel local update.
* Convergence is a ``changed`` flag derived from the round's own new bits
  (``upd & ~r``) and all-reduced over the mesh every round
  (``engine.closure_sharded``) — every device stops at the same globally
  converged round; callers never guess a round count.
* ``build_index(graph, cfg, mesh=...)`` shards **all** of Alg. 1 this
  way — forward/reverse closures, vertical k-level propagation, and the
  per-way projections — and is bit-identical to the single-device
  ``tdr_build.build_index`` (the OR fixpoint has a unique least solution
  and every reduction is exact bitwise OR).
* ``answer_batch(index, queries, mesh=...)`` broadcasts the compiled
  ``QueryPlan``, runs the phase-1 filter cascade with the job axis
  sharded over the mesh, and round-robins *compacted* phase-2 expansion
  chunks across the mesh's devices (their operands are per-chunk host
  data that transfers anyway; dispatch is async, so devices expand
  concurrently — full-graph chunks stay with the V-sized shared
  operands on the lead device).

The same code runs on 1 CPU device in tests, on the 8-fake-device mesh in
``tests/multidevice_check.py``, and on the 512-way fake-device mesh in the
dry-run (``repro/launch/dryrun.py --arch tdr-graph``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import bitset
from . import engine as engine_mod
from . import tdr_build as build_mod
from . import tdr_query as query_mod
from .graph import Graph

try:  # jax>=0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def _pad_to(x: np.ndarray, n: int, axis: int = 0, fill=0) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


@dataclasses.dataclass(frozen=True)
class ShardEdges:
    """Dense per-shard edge layout (static shapes for any mesh).

    ``local`` is the shard-owned endpoint as a shard-local row id,
    ``remote`` the other endpoint as a *global* id (it indexes the
    all_gathered closure table), ``eidx`` the global edge id (aligning
    per-edge payloads such as label planes and way ids to the shard
    layout), and ``valid`` masks the padding slots.
    """
    local: np.ndarray    # int32 [S, e_max]
    remote: np.ndarray   # int32 [S, e_max]
    eidx: np.ndarray     # int32 [S, e_max]
    valid: np.ndarray    # bool  [S, e_max]


def partition_graph(graph: Graph, n_shards: int, *,
                    by: str = "src") -> tuple[int, ShardEdges]:
    """Pad V to a multiple of shards; group edges by the owning endpoint.

    ``by="src"`` assigns each edge to the shard owning its source (forward
    propagation / projections); ``by="dst"`` to the shard owning its
    destination (reverse propagation).  Returns ``(v_pad, ShardEdges)``.
    """
    if by not in ("src", "dst"):
        raise ValueError(f"partition_graph: by={by!r}")
    v_pad = -(-graph.n_vertices // n_shards) * n_shards
    per = v_pad // n_shards
    src, dst = graph.src, graph.indices
    own, other = (src, dst) if by == "src" else (dst, src)
    shard_of = own // per
    e_max = int(max(1, np.bincount(shard_of, minlength=n_shards).max()))
    local = np.zeros((n_shards, e_max), dtype=np.int32)
    remote = np.zeros((n_shards, e_max), dtype=np.int32)
    eidx = np.zeros((n_shards, e_max), dtype=np.int32)
    valid = np.zeros((n_shards, e_max), dtype=bool)
    for s in range(n_shards):
        ids = np.flatnonzero(shard_of == s)
        k = ids.shape[0]
        local[s, :k] = own[ids] - s * per
        remote[s, :k] = other[ids]
        eidx[s, :k] = ids
        valid[s, :k] = True
    return v_pad, ShardEdges(local, remote, eidx, valid)


def _put(mesh: Mesh, spec: P, *arrays):
    sh = NamedSharding(mesh, spec)
    out = tuple(jax.device_put(a, sh) for a in arrays)
    return out if len(out) > 1 else out[0]


# ------------------------------------------------------------ closure only
def distributed_closure(graph: Graph, seed_words: np.ndarray, mesh: Mesh,
                        *, max_iters: int | None = None,
                        chunk_words: int = 2,
                        row_budget: int | None = None) -> jax.Array:
    """Reachability-closure fixpoint, vertex-sharded over ``mesh``.

    ``seed_words`` is the packed uint32 ``[V, W]`` per-vertex hash
    pattern; the result is the packed closure with semantics **identical
    to the single-device ``tdr_build`` fixpoint**:

        R[u] = OR_{u →+ v} seed[v]

    i.e. the vertex's own seed bits are *not* included unless ``u`` lies
    on a cycle (``tdr_build`` ORs ``vtx_w`` into ``n_out`` separately).
    Convergence comes from the all-reduced changed flag — no caller-
    guessed round count — and the per-round exchange payload is the
    packed word table, never a bool plane.

    ``row_budget`` switches the exchange to the delta-row scheme
    (``engine.closure_sharded_delta``): per round each device ships at
    most that many *changed* rows as sentinel-padded ``(id, payload)``
    pairs — the row-granular analogue of the two-level compressed
    planes — instead of all-gathering its full word block.  The result
    is bit-identical for any budget ≥ 1 (the OR fixpoint has a unique
    least solution; an overflowing budget only adds rounds).
    """
    seed_words = np.asarray(seed_words)
    if seed_words.dtype != np.uint32:
        raise TypeError(
            "distributed_closure takes packed uint32 seed words "
            f"(got {seed_words.dtype}); pack bool planes with "
            "bitset.pack_bits_np first")
    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    v_pad, ed = partition_graph(graph, n_shards, by="src")
    per = v_pad // n_shards
    w = seed_words.shape[1]
    rows = _pad_to(seed_words, v_pad).reshape(n_shards, per, w)
    iters = max_iters or v_pad
    spec = P(axes)

    # check_rep=False: jax's replication checker has no rule for the
    # converged while_loop (the psum'd changed flag is replicated by
    # construction — every device sees the same reduction)
    @functools.partial(shard_map, mesh=mesh, check_rep=False,
                       in_specs=(spec, spec, spec, spec), out_specs=spec)
    def run(rows_s, local_s, remote_s, valid_s):
        rows_l = rows_s[0]
        loc, rem = local_s[0], remote_s[0]
        okw = bitset.full_words_where(valid_s[0])[:, None]

        def step(r):
            return engine_mod.propagate_sharded(
                r, rem, loc, okw, axes, num_segments=per,
                chunk_words=chunk_words)

        base = step(rows_l)  # successor seeds: self excluded, as in build
        if row_budget is not None:
            # a binding budget trades rounds for traffic: scale the
            # dense-round bound by the worst-case per-device backlog
            backlog = -(-per // max(1, min(row_budget, per)))
            r, _ = engine_mod.closure_sharded_delta(
                base, rem, loc, okw, axes, per=per, v_pad=v_pad,
                chunk_words=chunk_words, row_budget=row_budget,
                max_iters=iters * backlog)
        else:
            r, _ = engine_mod.closure_sharded(base, step, axes,
                                              max_iters=iters)
        return r[None]

    out = run(_put(mesh, spec, rows),
              *_put(mesh, spec, ed.local, ed.remote, ed.valid))
    return jnp.asarray(np.asarray(out).reshape(v_pad, w)
                       [:graph.n_vertices])


# ------------------------------------------------------------ index build
def build_index(graph: Graph, cfg: "build_mod.TDRConfig | None" = None, *,
                mesh: Mesh, chunk_words: int | None = None
                ) -> "build_mod.TDRIndex":
    """Vertex-sharded construction of the full TDR index (Alg. 1).

    Host precompute (DFS intervals, hash rows, label slots, way routing)
    is identical to the single-device path; every device-side fixpoint and
    projection is sharded over ``mesh`` with the packed-word exchange
    described in the module docstring.  The result is bit-identical to
    ``tdr_build.build_index(graph, cfg)`` on all index planes.
    """
    cfg = cfg or build_mod.TDRConfig()
    v_n = graph.n_vertices
    push, pop, disc = build_mod.dfs_intervals(graph)
    vtx_words_np = build_mod._vertex_bit_words(cfg, disc)      # [V, Wv]
    lab_slot = build_mod._label_slots(cfg, graph.n_labels)
    g_count, way = build_mod.way_assignment(cfg, graph, disc)
    lab_words = build_mod._edge_label_words(cfg, lab_slot, graph.labels)
    null_w = build_mod._null_words(cfg)                        # [Wl]

    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    v_pad, fwd = partition_graph(graph, n_shards, by="src")
    _, rev = partition_graph(graph, n_shards, by="dst")
    per = v_pad // n_shards
    gmax = cfg.g_max
    cw = chunk_words or max(1, cfg.bit_chunk // bitset.WORD)
    iters = cfg.max_fixpoint_iters or v_n
    wv, wl = vtx_words_np.shape[1], lab_words.shape[1]

    # per-edge payloads aligned to the forward shard layout (zeroed pads;
    # an edgeless graph has nothing to gather — every slot is padding)
    if graph.n_edges:
        labw_f = np.where(fwd.valid[:, :, None], lab_words[fwd.eidx],
                          np.uint32(0)).astype(np.uint32)
        way_f = np.where(fwd.valid, way[fwd.eidx], 0).astype(np.int32)
    else:
        labw_f = np.zeros(fwd.eidx.shape + (wl,), dtype=np.uint32)
        way_f = np.zeros(fwd.eidx.shape, dtype=np.int32)

    rows = _pad_to(vtx_words_np, v_pad).reshape(n_shards, per, wv)
    leaf = _pad_to(graph.out_degree() == 0, v_pad).reshape(n_shards, per)
    g_sh = _pad_to(g_count, v_pad).reshape(n_shards, per)
    spec = P(axes)
    null_j = jnp.asarray(null_w)

    # check_rep=False: see distributed_closure (while_loop has no
    # replication rule in this jax version)
    @functools.partial(shard_map, mesh=mesh, check_rep=False,
                       in_specs=(spec,) * 11, out_specs=(spec,) * 7)
    def run(rows_s, leaf_s, g_s, floc_s, frem_s, fok_s, flab_s, fway_s,
            rloc_s, rrem_s, rok_s):
        vtx_l = rows_s[0]                       # [per, Wv]
        leaf_l, g_l = leaf_s[0], g_s[0]
        f_loc, f_rem = floc_s[0], frem_s[0]
        labw, way_l = flab_s[0], fway_s[0]
        r_loc, r_rem = rloc_s[0], rrem_s[0]
        fokw = bitset.full_words_where(fok_s[0])[:, None]
        rokw = bitset.full_words_where(rok_s[0])[:, None]

        def prop_f(x):
            return engine_mod.propagate_sharded(
                x, f_rem, f_loc, fokw, axes, num_segments=per,
                chunk_words=cw)

        def prop_r(x):
            return engine_mod.propagate_sharded(
                x, r_rem, r_loc, rokw, axes, num_segments=per,
                chunk_words=cw)

        # ---- forward vertex closure  R[u] = OR (bit(v) | R[v]) ----------
        base_v = prop_f(vtx_l)
        r_vtx, rounds = engine_mod.closure_sharded(base_v, prop_f, axes,
                                                   max_iters=iters)
        # ---- forward label closure --------------------------------------
        base_l = bitset.segment_or_words(labw, f_loc, num_segments=per,
                                         chunk_words=cw)
        r_lab, _ = engine_mod.closure_sharded(base_l, prop_f, axes,
                                              max_iters=iters)
        # ---- reverse closure for N_in -----------------------------------
        base_r = prop_r(vtx_l)
        n_in, _ = engine_mod.closure_sharded(base_r, prop_r, axes,
                                             max_iters=iters)

        # ---- vertical levels (exact k-round propagation) ----------------
        cur_lab = jnp.where(leaf_l[:, None], null_j[None, :], base_l)
        cur_vtx = base_v
        d_lab, d_vtx = [cur_lab], [cur_vtx]
        for _ in range(1, cfg.k):
            nxt_lab = jnp.where(leaf_l[:, None], null_j[None, :],
                                prop_f(cur_lab))
            nxt_vtx = jnp.where(leaf_l[:, None], jnp.uint32(0),
                                prop_f(cur_vtx))
            d_lab.append(nxt_lab)
            d_vtx.append(nxt_vtx)
            cur_lab, cur_vtx = nxt_lab, nxt_vtx

        # ---- per-way projections (packed-word gathers + segment ORs) ----
        full_vtx = engine_mod.all_gather_words(vtx_l, axes)
        full_rvtx = engine_mod.all_gather_words(r_vtx, axes)
        full_rlab = engine_mod.all_gather_words(r_lab, axes)
        seg = f_loc * gmax + way_l
        n_seg = per * gmax

        def proj(vals):
            return bitset.segment_or_words(vals & fokw, seg,
                                           num_segments=n_seg,
                                           chunk_words=cw)

        h_vtx = proj(full_vtx[f_rem] | full_rvtx[f_rem])
        h_lab = proj(labw | full_rlab[f_rem])
        v_lab_lv = [proj(labw)]
        v_vtx_lv = [proj(full_vtx[f_rem])]
        for l in range(1, cfg.k):
            v_lab_lv.append(proj(engine_mod.all_gather_words(
                d_lab[l - 1], axes)[f_rem]))
            v_vtx_lv.append(proj(engine_mod.all_gather_words(
                d_vtx[l - 1], axes)[f_rem]))

        h_vtx = h_vtx.reshape(per, gmax, wv)
        h_lab = h_lab.reshape(per, gmax, wl)
        v_lab_p = jnp.stack(v_lab_lv, axis=1).reshape(per, gmax, cfg.k, wl)
        v_vtx_p = jnp.stack(v_vtx_lv, axis=1).reshape(per, gmax, cfg.k, wv)

        # the vertex hashes itself into each *used* way (Alg. 1 line 10)
        way_used = jnp.arange(gmax)[None, :] < g_l[:, None]
        h_vtx = h_vtx | jnp.where(way_used[:, :, None], vtx_l[:, None, :],
                                  jnp.uint32(0))
        n_out = bitset.or_reduce(h_vtx, axis=1) if gmax > 0 else r_vtx
        return (h_vtx[None], h_lab[None], v_vtx_p[None], v_lab_p[None],
                (n_out | vtx_l)[None], (n_in | vtx_l)[None],
                rounds.reshape(1))

    outs = run(*_put(mesh, spec, rows, leaf, g_sh, fwd.local, fwd.remote,
                     fwd.valid, labw_f, way_f, rev.local, rev.remote,
                     rev.valid))
    h_vtx, h_lab, v_vtx, v_lab, n_out, n_in, rounds = (
        np.asarray(o) for o in outs)
    idx = build_mod.TDRIndex(
        cfg=cfg, graph=graph,
        h_vtx=jnp.asarray(h_vtx.reshape(v_pad, gmax, wv)[:v_n]),
        h_lab=jnp.asarray(h_lab.reshape(v_pad, gmax, wl)[:v_n]),
        v_vtx=jnp.asarray(v_vtx.reshape(v_pad, gmax, cfg.k, wv)[:v_n]),
        v_lab=jnp.asarray(v_lab.reshape(v_pad, gmax, cfg.k, wl)[:v_n]),
        n_out=jnp.asarray(n_out.reshape(v_pad, wv)[:v_n]),
        n_in=jnp.asarray(n_in.reshape(v_pad, wv)[:v_n]),
        push=jnp.asarray(push), pop=jnp.asarray(pop),
        g_count=jnp.asarray(g_count),
        vtx_words=vtx_words_np, lab_slot=lab_slot,
        fixpoint_rounds=int(rounds.max()),
        # pin the hash layout so tdr_build.update_index on a
        # distributed-built index can fall back to a layout-pinned
        # rebuild (the sharded build keeps no raw closure planes)
        disc=disc,
    )
    return idx


# -------------------------------------------------------- query answering
def filter_cascade_sharded(index: "build_mod.TDRIndex",
                           plan: "query_mod.QueryPlan", mesh: Mesh,
                           mode: str) -> np.ndarray:
    """Phase-1 filter cascade with the job axis sharded over ``mesh``.

    The (small) plan rows are the only job-axis traffic; the index planes
    are broadcast once.  Each device runs the vectorized cascade for its
    job shard; the verdicts concatenate back — no collectives needed.
    ``plan.n_jobs`` must be a multiple of the mesh size (pad with
    ``QueryPlan.pad_to``).
    """
    axes = tuple(mesh.axis_names)
    n_dev = mesh.devices.size
    if plan.n_jobs % n_dev:
        raise ValueError(
            f"job axis {plan.n_jobs} not divisible by mesh size {n_dev}")
    spec_j = P(axes)
    k = index.cfg.k

    # check_rep=False: the replication checker has no rule for the
    # pallas_call the cascade's fused way filter lowers to
    @functools.partial(
        shard_map, mesh=mesh, check_rep=False,
        in_specs=(spec_j,) * 4 + (P(),) * 12, out_specs=spec_j)
    def run(u, v, req_w, forb_w, null_w, vtx_packed, h_vtx, h_lab, v_vtx,
            v_lab, n_out, n_in, sat_out, sat_in, push, pop):
        return query_mod._filter_cascade(
            u, v, req_w, forb_w, null_w, vtx_packed, h_vtx, h_lab, v_vtx,
            v_lab, n_out, n_in, sat_out, sat_in, push, pop, k=k, mode=mode)

    job_args = _put(mesh, spec_j, plan.u.astype(np.int32),
                    plan.v.astype(np.int32), plan.req_w, plan.forb_w)
    # the index planes replicate once per mesh, not once per batch
    key = (tuple(mesh.axis_names),
           tuple(int(d.id) for d in mesh.devices.flat))
    bcast = index._replicated.get(key)
    if bcast is None:
        sat_out_d, sat_in_d = index.summary_flags_dev()
        bcast = _put(mesh, P(), query_mod._null_words_dev(index.cfg),
                     index.vtx_packed, index.h_vtx, index.h_lab,
                     index.v_vtx, index.v_lab, index.n_out, index.n_in,
                     sat_out_d, sat_in_d, index.push, index.pop)
        index._replicated[key] = bcast
    return np.asarray(run(*job_args, *bcast))


def answer_batch(index: "build_mod.TDRIndex", queries, *, mesh: Mesh,
                 **kw) -> np.ndarray:
    """Distributed PCR answering: ``tdr_query.answer_batch`` with the
    phase-1 cascade job-sharded over ``mesh`` and compacted phase-2
    chunks round-robined across its devices."""
    return query_mod.answer_batch(index, queries, mesh=mesh, **kw)


# ------------------------------------------------- shape-only lowerings
def lower_distributed_closure(mesh: Mesh, v_global: int, e_max: int,
                              nbits: int, rounds: int, chunk: int = 64):
    """Shape-only lowering of the distributed fixpoint (for the dry-run).

    Returns the lowered computation for ``.compile()`` — proving the
    sharding/collective schedule is coherent on the production mesh
    without allocating the graph.  The per-round exchange is the packed
    uint32 word table (``all_gather`` of ``[per, W]`` uint32 blocks).
    Unlike the runtime paths, the round count here is *static* (a
    ``fori_loop``) so the dry-run's loop-aware HLO cost accounting sees a
    fixed trip count; ``distributed_closure``/``build_index`` converge via
    the all-reduced changed flag instead.
    """
    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    per = -(-v_global // n_shards)
    words = bitset.n_words(nbits)
    cw = max(1, chunk // bitset.WORD)
    spec = P(axes)
    sharding = NamedSharding(mesh, spec)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(spec, spec, spec, spec), out_specs=spec)
    def run(rows_s, local_s, remote_s, valid_s):
        rows_l = rows_s[0]
        loc, rem = local_s[0], remote_s[0]
        okw = bitset.full_words_where(valid_s[0])[:, None]

        def step(r):
            return engine_mod.propagate_sharded(
                r, rem, loc, okw, axes, num_segments=per, chunk_words=cw)

        def body(_, r):
            return r | step(r)

        return jax.lax.fori_loop(0, rounds, body, step(rows_l))[None]

    args = (
        jax.ShapeDtypeStruct((n_shards, per, words), jnp.uint32,
                             sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.int32, sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.int32, sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.bool_, sharding=sharding),
    )
    return jax.jit(run).lower(*args)


def lower_distributed_closure_2d(mesh: Mesh, v_global: int, e_max: int,
                                 nbits: int, rounds: int, *,
                                 word_shards: int = 8, chunk: int = 64):
    """§Perf iteration T1/T2: 2-D (vertex × word) partitioning.

    The 1-D layout gathers the full packed table (V × W words) on every
    device every round.  But the OR-recurrence is elementwise in the word
    dimension, so a device that owns only ``W/word_shards`` words needs
    only those words of every referenced row: re-viewing the flattened
    mesh as ``(vertex_shards × word_shards)`` divides per-round gather
    traffic by ``word_shards`` at identical per-device compute.  State is
    packed uint32 at rest *and* in flight — the word axis shards on whole
    words, so no pack/unpack transposes the exchange.  Edge lists are
    replicated across the word axis (static, once).
    """
    n_dev = mesh.devices.size
    assert n_dev % word_shards == 0
    v_shards = n_dev // word_shards
    mesh2 = Mesh(mesh.devices.reshape(v_shards, word_shards),
                 ("vtx", "word"))
    per_v = -(-v_global // v_shards)
    w_words = bitset.n_words(nbits)
    assert w_words % word_shards == 0, (w_words, word_shards)
    per_w = w_words // word_shards
    cw = min(max(1, chunk // bitset.WORD), per_w)
    sh_r = NamedSharding(mesh2, P("vtx", None, "word"))
    sh_e = NamedSharding(mesh2, P("vtx", None))

    @functools.partial(
        shard_map, mesh=mesh2,
        in_specs=(P("vtx", None, "word"), P("vtx", None), P("vtx", None),
                  P("vtx", None)),
        out_specs=P("vtx", None, "word"))
    def run(rows_s, local_s, remote_s, valid_s):
        rows_l = rows_s[0]                  # [per_v, per_w] packed uint32
        loc, rem = local_s[0], remote_s[0]
        okw = bitset.full_words_where(valid_s[0])[:, None]

        def round_(r_local):
            # gather over the vertex axis ONLY; each device pulls just its
            # own word slice of every row, already packed (no transient
            # bool plane anywhere in the exchange)
            full = jax.lax.all_gather(r_local, axis_name="vtx",
                                      tiled=True)      # [v_pad, per_w]
            vals = full[rem] & okw
            upd = bitset.segment_or_words(vals, loc, num_segments=per_v,
                                          chunk_words=cw)
            return r_local | upd

        def body(_, r):
            return round_(r)

        return jax.lax.fori_loop(0, rounds, body, round_(rows_l))[None]

    args = (
        jax.ShapeDtypeStruct((v_shards, per_v, w_words), jnp.uint32,
                             sharding=sh_r),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.int32, sharding=sh_e),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.int32, sharding=sh_e),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.bool_, sharding=sh_e),
    )
    with mesh2:
        return jax.jit(run).lower(*args)

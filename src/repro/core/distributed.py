"""Distributed TDR: vertex-partitioned index build + query over shard_map.

Scaling posture (the multi-pod story for the paper's engine):

* The vertex set is partitioned 1-D over every device of the mesh (the
  flattened ``(pod, data, model)`` axes).  Each device owns the index rows
  of its vertex shard and the *out-edges of its shard* (CSR slice).
* One closure-fixpoint round = ``all_gather`` of the closure bitsets
  (``V × W`` words — the only cross-device traffic; the adjacency never
  moves) followed by a purely local OR-reduction for owned vertices.
  On a 512-chip mesh with V=10M and 256-bit Blooms that is 320 MB per
  round over ICI — a few ms — against an embarrassingly parallel local
  update.
* Query answering distributes the same way by design: broadcast the
  (small) query batch, each device runs the filter cascade for queries
  whose source it owns, verdicts combine with a max-reduction.  The
  single-mesh engine (`tdr_query`) plus this module's closure fixpoint
  carry the measured multi-pod story (ARCHITECTURE.md §Perf cell T).

The same code runs on 1 CPU device in tests and on the 512-way fake-device
mesh in the dry-run (see ``repro/launch/dryrun.py --arch tdr-graph``).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import bitset
from .graph import Graph

try:  # jax>=0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def _pad_to(x: np.ndarray, n: int, axis: int = 0, fill=0) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def partition_graph(graph: Graph, n_shards: int):
    """Pad V to a multiple of shards; group edges by source shard.

    Returns (v_pad, shard_edges) where shard_edges is a dense
    ``[n_shards, e_max]`` (src_local, dst, valid) triple — static shapes so
    the whole build jits/lowers for any mesh.
    """
    v_pad = -(-graph.n_vertices // n_shards) * n_shards
    per = v_pad // n_shards
    src, dst = graph.src, graph.indices
    shard_of = src // per
    e_max = int(max(1, np.bincount(shard_of, minlength=n_shards).max()))
    src_l = np.zeros((n_shards, e_max), dtype=np.int32)
    dst_g = np.zeros((n_shards, e_max), dtype=np.int32)
    valid = np.zeros((n_shards, e_max), dtype=bool)
    for s in range(n_shards):
        m = shard_of == s
        k = int(m.sum())
        src_l[s, :k] = src[m] - s * per
        dst_g[s, :k] = dst[m]
        valid[s, :k] = True
    return v_pad, (src_l, dst_g, valid)


def distributed_closure(graph: Graph, seed_rows: np.ndarray, mesh: Mesh,
                        *, rounds: int, chunk: int = 64) -> jax.Array:
    """Closure Bloom fixpoint, vertex-sharded over every axis of ``mesh``.

    ``seed_rows`` is the bool [V, nbits] per-vertex hash pattern; the result
    is the packed closure (R[u] = OR over reachable v of bits(v)), identical
    to the single-device `tdr_build` fixpoint.
    """
    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    v_pad, (src_l, dst_g, valid) = partition_graph(graph, n_shards)
    nbits = seed_rows.shape[1]
    per = v_pad // n_shards

    rows = _pad_to(seed_rows.astype(np.uint8), v_pad)
    rows = rows.reshape(n_shards, per, nbits)

    spec = P(axes)  # shard leading dim over the whole mesh
    sharding = NamedSharding(mesh, spec)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec)
    def run(rows_s, src_s, dst_s, valid_s):
        # local block shapes: rows_s [1, per, nbits]; edges [1, e_max]
        rows_l = rows_s[0].astype(jnp.bool_)
        src_e, dst_e, ok = src_s[0], dst_s[0], valid_s[0]

        def round_(r_local):
            # exchange: full closure table (the only cross-device traffic).
            # Gather innermost mesh axis first so the flattened ordering
            # matches the axis-major shard numbering.
            r_full = r_local
            for ax in reversed(axes):
                r_full = jax.lax.all_gather(r_full, axis_name=ax, tiled=True)
            gathered = r_full[dst_e] & ok[:, None]
            upd = bitset.segment_or(gathered, src_e, num_segments=per,
                                    chunk=chunk)
            return r_local | upd

        base = round_(rows_l)  # first round seeds with neighbor bits

        def body(_, r):
            return round_(r)

        r = jax.lax.fori_loop(0, rounds, body, base)
        return r[None]

    out = run(jax.device_put(rows, sharding),
              jax.device_put(src_l, sharding),
              jax.device_put(dst_g, sharding),
              jax.device_put(valid, sharding))
    out = out.reshape(v_pad, nbits)[:graph.n_vertices]
    return bitset.pack_bits(out)


def lower_distributed_closure_2d(mesh: Mesh, v_global: int, e_max: int,
                                 nbits: int, rounds: int, *,
                                 word_shards: int = 8, chunk: int = 64):
    """§Perf iteration T1: 2-D (vertex × word) partitioning.

    The baseline gathers the *full* closure table (V × W words) on every
    device every round.  But the OR-recurrence is elementwise in the word
    dimension, so a device that owns only ``W/word_shards`` words needs only
    those words of every referenced row: re-viewing the flattened mesh as
    ``(vertex_shards × word_shards)`` divides per-round gather traffic by
    ``word_shards`` at identical per-device compute (each vertex shard is
    ``word_shards×`` coarser, but processes ``word_shards×`` fewer words).
    Edge lists are replicated across the word axis (static, once).
    """
    import numpy as _np
    n_dev = mesh.devices.size
    assert n_dev % word_shards == 0
    v_shards = n_dev // word_shards
    mesh2 = Mesh(mesh.devices.reshape(v_shards, word_shards),
                 ("vtx", "word"))
    per_v = -(-v_global // v_shards)
    w_words = -(-nbits // 32)
    assert w_words % word_shards == 0, (w_words, word_shards)
    per_w = w_words // word_shards

    spec_r = P("vtx", None, "word")       # [v_shards*?, per_v, words]
    spec_e = P("vtx", None)               # edges replicated over word axis
    sh_r = NamedSharding(mesh2, P("vtx", None, "word"))
    sh_e = NamedSharding(mesh2, P("vtx", None))

    @functools.partial(
        shard_map, mesh=mesh2,
        in_specs=(P("vtx", None, "word"), P("vtx", None), P("vtx", None),
                  P("vtx", None)),
        out_specs=P("vtx", None, "word"))
    def run(rows_s, src_s, dst_s, valid_s):
        rows_l = rows_s[0]                  # [per_v, per_w*32] bits as u8
        src_e, dst_e, ok = src_s[0], dst_s[0], valid_s[0]
        rows_l = rows_l.astype(jnp.bool_)
        nb = rows_l.shape[-1]

        def round_(r_local):
            # gather over the vertex axis ONLY, with the payload PACKED
            # into uint32 words (§Perf iteration T2: 32× fewer gather
            # bytes than the bool-plane exchange; unpack is local VPU)
            packed = bitset.pack_bits(r_local)
            p_col = jax.lax.all_gather(packed, axis_name="vtx",
                                       tiled=True)     # [V, per_w]
            r_col = bitset.unpack_bits(p_col, nb)
            gathered = r_col[dst_e] & ok[:, None]
            upd = bitset.segment_or(gathered, src_e,
                                    num_segments=r_local.shape[0],
                                    chunk=chunk)
            return r_local | upd

        def body(_, r):
            return round_(r)

        return jax.lax.fori_loop(0, rounds, body, round_(rows_l))[None]

    args = (
        jax.ShapeDtypeStruct((v_shards, per_v, per_w * 32 * word_shards),
                             jnp.uint8,
                             sharding=NamedSharding(mesh2,
                                                    P("vtx", None, "word"))),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.int32, sharding=sh_e),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.int32, sharding=sh_e),
        jax.ShapeDtypeStruct((v_shards, e_max), jnp.bool_, sharding=sh_e),
    )
    with mesh2:
        return jax.jit(run).lower(*args)


def lower_distributed_closure(mesh: Mesh, v_global: int, e_max: int,
                              nbits: int, rounds: int, chunk: int = 64):
    """Shape-only lowering of the distributed fixpoint (for the dry-run).

    Returns the lowered computation for ``.compile()`` — proving the
    sharding/collective schedule is coherent on the production mesh without
    allocating the graph.
    """
    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    per = -(-v_global // n_shards)
    v_pad = per * n_shards
    spec = P(axes)
    sharding = NamedSharding(mesh, spec)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(spec, spec, spec, spec), out_specs=spec)
    def run(rows_s, src_s, dst_s, valid_s):
        rows_l = rows_s[0].astype(jnp.bool_)
        src_e, dst_e, ok = src_s[0], dst_s[0], valid_s[0]

        def round_(r_local):
            r_full = r_local
            for ax in reversed(axes):
                r_full = jax.lax.all_gather(r_full, axis_name=ax, tiled=True)
            gathered = r_full[dst_e] & ok[:, None]
            upd = bitset.segment_or(gathered, src_e, num_segments=per,
                                    chunk=chunk)
            return r_local | upd

        def body(_, r):
            return round_(r)

        return jax.lax.fori_loop(0, rounds, body, round_(rows_l))[None]

    args = (
        jax.ShapeDtypeStruct((n_shards, per, nbits), jnp.uint8, sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.int32, sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.int32, sharding=sharding),
        jax.ShapeDtypeStruct((n_shards, e_max), jnp.bool_, sharding=sharding),
    )
    return jax.jit(run).lower(*args)

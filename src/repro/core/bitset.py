"""Packed-bitset utilities.

The TDR index stores Bloom-style summaries as packed ``uint32`` words (the
storage/kernel layout).  Since the packed-word engine refactor the *build*
and *query* math also runs end-to-end on packed words via
``segment_or_words`` (word-chunked unpack transients only — no full-width
boolean plane is ever materialized at rest).  On TPU the packed layout feeds
``repro.kernels.bitset_matmul`` directly (32 graph columns per lane
element).  The distributed exchange (``repro.core.distributed``) also ships
packed words only; ``segment_or`` (boolean-plane input) survives solely as
the reference oracle in ``tests/test_engine.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


def n_words(nbits: int) -> int:
    return (nbits + WORD - 1) // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a boolean array ``[..., nbits]`` into uint32 ``[..., W]``."""
    nbits = bits.shape[-1]
    w = n_words(nbits)
    pad = w * WORD - nbits
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (w, WORD)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return (b * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, nbits: int) -> jax.Array:
    """Unpack uint32 ``[..., W]`` into boolean ``[..., nbits]``."""
    w = words.shape[-1]
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (w * WORD,))
    return bits[..., :nbits].astype(jnp.bool_)


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    nbits = bits.shape[-1]
    w = n_words(nbits)
    pad = w * WORD - nbits
    if pad:
        bits = np.concatenate(
            [bits, np.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (w, WORD)).astype(np.uint32)
    weights = (np.uint32(1) << np.arange(WORD, dtype=np.uint32))
    return (b * weights).sum(axis=-1, dtype=np.uint32)


@functools.partial(jax.jit, static_argnames=("num_segments", "chunk"))
def segment_or(values: jax.Array, segment_ids: jax.Array, *, num_segments: int,
               chunk: int = 64) -> jax.Array:
    """OR-reduce boolean planes ``[E, nbits]`` by segment.

    Reference oracle for ``segment_or_words`` in tests — no runtime path
    ships bool planes anymore.  Implemented as chunked ``segment_max`` over
    uint8 planes so the transient gather stays ``E x chunk`` instead of
    ``E x nbits``.
    """
    e, nbits = values.shape
    nchunks = -(-nbits // chunk)
    pad = nchunks * chunk - nbits
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((e, pad), dtype=values.dtype)], axis=1)
    v = values.reshape(e, nchunks, chunk).transpose(1, 0, 2).astype(jnp.uint8)

    def body(plane):
        return jax.ops.segment_max(plane, segment_ids,
                                   num_segments=num_segments)

    out = jax.lax.map(body, v)  # [nchunks, S, chunk]
    out = out.transpose(1, 0, 2).reshape(num_segments, nchunks * chunk)
    return out[:, :nbits].astype(jnp.bool_)


def set_bits_np(words: np.ndarray, idx: tuple, positions: np.ndarray) -> None:
    """``words[idx + (positions >> 5,)] |= 1 << (positions & 31)`` in place.

    The one packed-word bit-scatter used to build hash rows, label planes,
    and adjacency bit-matrices; ``idx`` is the tuple of leading index
    arrays (may be empty for a flat word row)."""
    pos = positions.astype(np.int64)
    np.bitwise_or.at(words, tuple(idx) + (pos >> 5,),
                     (np.int64(1) << (pos & 31)).astype(np.uint32))


@functools.partial(jax.jit, static_argnames=("num_segments", "chunk_words"))
def segment_or_words(values: jax.Array, segment_ids: jax.Array, *,
                     num_segments: int, chunk_words: int = 2) -> jax.Array:
    """OR-reduce packed uint32 rows ``[E, W]`` by segment -> ``[S, W]``.

    Bitwise OR is not a ``segment_max`` on uint32 values, so the reduction
    unpacks ``chunk_words`` words at a time, max-reduces the bit plane, and
    repacks.  Operands stay packed at rest; the only transient is one
    ``[E, chunk_words*32]`` uint8 plane per chunk.
    """
    e, w = values.shape
    nchunks = -(-w // chunk_words)
    pad = nchunks * chunk_words - w
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((e, pad), dtype=jnp.uint32)], axis=1)
    v = values.reshape(e, nchunks, chunk_words).transpose(1, 0, 2)

    def body(chunk):  # [E, chunk_words] uint32
        bits = unpack_bits(chunk, chunk_words * WORD).astype(jnp.uint8)
        red = jax.ops.segment_max(bits, segment_ids,
                                  num_segments=num_segments)
        return pack_bits(red.astype(jnp.bool_))

    out = jax.lax.map(body, v)  # [nchunks, S, chunk_words]
    out = out.transpose(1, 0, 2).reshape(num_segments, nchunks * chunk_words)
    return out[:, :w]


def or_reduce(words: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR reduction of packed words along ``axis``."""
    return jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or,
                          (axis % words.ndim,))


def full_words_where(cond: jax.Array) -> jax.Array:
    """Broadcast a boolean mask to all-ones/all-zeros uint32 words."""
    return jnp.where(cond, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))


def words_contain(a: jax.Array, b: jax.Array) -> jax.Array:
    """``b ⊆ a`` elementwise over trailing word axis -> bool [...]."""
    return jnp.all((a & b) == b, axis=-1)


def words_intersect(a: jax.Array, b: jax.Array) -> jax.Array:
    """``a ∩ b ≠ ∅`` over trailing word axis -> bool [...]."""
    return jnp.any((a & b) != 0, axis=-1)


def popcount(words: jax.Array) -> jax.Array:
    """Population count over the trailing word axis."""
    x = words
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    return x.astype(jnp.int32).sum(axis=-1)

"""Semiring carriers for the packed-plane fixpoint engine.

The TDR engine iterates ``r <- r (+) step(r)`` until a fixpoint.  PR 1-7
hard-coded the boolean OR semiring over packed uint32 words; this module
names the algebra so the same closure/propagate cores (and the pallas
kernels under ``repro.kernels``) run three instantiations:

``BOOLEAN``
    the original packed carrier — 32 graph bits per uint32 lane,
    ``combine`` = bitwise OR, ``extend`` = identity.  The generic code
    paths emit *literally the same traced ops* as the pre-refactor
    engine, so every plane (build, update, distributed exchange,
    snapshot round-trip) stays bit-identical on both backends.

``DIST16`` / ``DIST8``
    hop-distance (min, +) over saturating unsigned lanes.  One lane per
    query/state column, ``INF`` = dtype max, ``extend`` = saturating +1
    (``d + (d < INF)`` — branch-free, never wraps).  Idempotent, so the
    closure fixpoint converges; drives ``tdr_query.dist`` / ``witness``.

``COUNT``
    bounded route counting with saturating add, capped at ``cap`` so a
    dense graph cannot overflow the uint32 lane (and so that per-round
    clamping is exact: saturating add is associative for non-negative
    values).  NOT idempotent — ``closure()`` refuses it; route counting
    runs a hop-bounded DP in ``tdr_query.count_routes`` instead.

Instances are frozen and hashable, so they ride through ``jax.jit`` as
static arguments: each semiring gets its own compiled specialization and
the boolean one keeps its pre-refactor HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from . import bitset

#: saturation cap for COUNT: 2^15 - 1.  With E <= 2^16 corridor edges a
#: per-round segment_sum accumulates at most 2*cap per edge pair, i.e.
#: 2^16 * 2^16 < 2^32, so uint32 lane sums cannot wrap before the clamp.
COUNT_CAP = (1 << 15) - 1


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (+)/(x) algebra over one carrier lane.

    ``op`` names the lane-level combine the kernels implement
    ("or" | "min" | "sum"); ``packed`` marks the 32-bits-per-word boolean
    carrier (the only one the bit-plane layout applies to).  ``zero`` is
    the (+)-identity (absorbing for paths that do not exist), ``one``
    the path-weight of the empty path.  ``idempotent`` is the convergence
    predicate's precondition: ``closure`` fixpoints are only defined when
    ``combine(a, a) == a``.
    """

    name: str
    op: str                   # lane combine: "or" | "min" | "sum"
    dtype_name: str           # carrier lane dtype
    packed: bool              # 32 graph bits per uint32 lane?
    idempotent: bool          # combine(a, a) == a (closure well-defined)
    cap: int = 0              # saturation cap ("sum" only)

    # -- carrier ----------------------------------------------------------
    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def zero(self) -> int:
        """(+)-identity scalar: 0 for or/sum, dtype-max (INF) for min."""
        if self.op == "min":
            return int(jnp.iinfo(self.dtype).max)
        return 0

    @property
    def one(self) -> int:
        """(x)-identity scalar: the weight of the empty path."""
        return 0 if self.op == "min" else 1

    @property
    def inf(self) -> int:
        """Alias for the min-semiring unreachable sentinel."""
        if self.op != "min":
            raise ValueError(f"{self.name}: inf only defined for min")
        return self.zero

    def init(self, shape) -> jax.Array:
        """A carrier plane of (+)-identities."""
        return jnp.full(shape, self.zero, self.dtype)

    # -- algebra (trace-time; jnp in, jnp out) ----------------------------
    def combine(self, a, b):
        """(+): OR / elementwise min / saturating add."""
        if self.op == "or":
            return a | b
        if self.op == "min":
            return jnp.minimum(a, b)
        return jnp.minimum(a + b, jnp.asarray(self.cap, self.dtype))

    def extend(self, vals):
        """(x) with a unit edge weight: identity for or/sum, saturating
        +1 for min (INF stays INF, INF-1 saturates to INF)."""
        if self.op == "min":
            return vals + (vals < jnp.asarray(self.zero, self.dtype)
                           ).astype(self.dtype)
        return vals

    def segment_combine(self, vals, segment_ids, *, num_segments: int,
                        chunk_words: int = 0):
        """(+)-reduce ``vals`` rows into ``num_segments`` rows.

        The boolean carrier keeps the chunked packed-word OR (identical
        traced ops to the pre-refactor engine); min/sum use the native
        scatter reductions with the matching identity fill.
        """
        if self.op == "or":
            return bitset.segment_or_words(
                vals, segment_ids, num_segments=num_segments,
                chunk_words=chunk_words)
        if self.op == "min":
            return jax.ops.segment_min(
                vals, segment_ids, num_segments=num_segments)
        out = jax.ops.segment_sum(
            vals.astype(jnp.uint32), segment_ids, num_segments=num_segments)
        return jnp.minimum(out, jnp.uint32(self.cap)).astype(self.dtype)

    def accumulate(self, r, upd) -> Tuple[jax.Array, jax.Array]:
        """One fixpoint round: fold ``upd`` into ``r``.

        Returns ``(new_r, changed)``.  The boolean branch keeps the
        ``upd & ~r`` new-bits idiom verbatim (bit-identity contract);
        min compares planes (monotone decreasing, so inequality is
        exactly "some lane improved")."""
        if not self.idempotent:
            raise ValueError(
                f"{self.name}: accumulate/closure need an idempotent (+)")
        if self.op == "or":
            new = upd & ~r
            return r | new, jnp.any(new != 0)
        new_r = jnp.minimum(r, upd)
        return new_r, jnp.any(new_r != r)


BOOLEAN = Semiring(name="boolean", op="or", dtype_name="uint32",
                   packed=True, idempotent=True)
DIST16 = Semiring(name="dist16", op="min", dtype_name="uint16",
                  packed=False, idempotent=True)
DIST8 = Semiring(name="dist8", op="min", dtype_name="uint8",
                 packed=False, idempotent=True)
COUNT = Semiring(name="count", op="sum", dtype_name="uint32",
                 packed=False, idempotent=False, cap=COUNT_CAP)

_BY_NAME = {s.name: s for s in (BOOLEAN, DIST16, DIST8, COUNT)}


def by_name(name: str) -> Semiring:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise ValueError(
            f"unknown semiring {name!r}; have {sorted(_BY_NAME)}") from None

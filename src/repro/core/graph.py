"""Edge-labeled digraphs (paper Def. 1) + the generators used in §VI.

A multigraph edge with several labels is stored as several parallel edges,
exactly as the paper prescribes.  Host representation is CSR (sorted by
source) with a parallel label array; reverse CSR is derived lazily.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """CSR edge-labeled digraph."""
    n_vertices: int
    n_labels: int
    indptr: np.ndarray    # int32 [V+1]
    indices: np.ndarray   # int32 [E]   destination of each edge
    labels: np.ndarray    # int32 [E]   label of each edge

    # ---------------------------------------------------------------- basic
    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def src(self) -> np.ndarray:
        """Edge source array [E] (expanded from indptr)."""
        return np.repeat(np.arange(self.n_vertices, dtype=np.int32),
                         np.diff(self.indptr))

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def successors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def out_edges(self, u: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[u], self.indptr[u + 1]
        return self.indices[s:e], self.labels[s:e]

    def reverse(self) -> "Graph":
        src = self.src
        order = np.argsort(self.indices, kind="stable")
        rsrc = self.indices[order]
        rdst = src[order]
        rlab = self.labels[order]
        rptr = np.zeros(self.n_vertices + 1, dtype=np.int64)
        np.add.at(rptr, rsrc + 1, 1)
        rptr = np.cumsum(rptr)
        return Graph(self.n_vertices, self.n_labels,
                     rptr.astype(np.int32), rdst.astype(np.int32),
                     rlab.astype(np.int32))

    # ------------------------------------------------------------ builders
    @staticmethod
    def from_edges(n_vertices: int, n_labels: int,
                   edges: Iterable[tuple[int, int, int]]) -> "Graph":
        arr = np.asarray(sorted(set(edges)), dtype=np.int64)
        if arr.size == 0:
            arr = np.zeros((0, 3), dtype=np.int64)
        src, dst, lab = arr[:, 0], arr[:, 1], arr[:, 2]
        order = np.lexsort((dst, src))
        src, dst, lab = src[order], dst[order], lab[order]
        indptr = np.zeros(n_vertices + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n_vertices, n_labels, indptr.astype(np.int32),
                     dst.astype(np.int32), lab.astype(np.int32))


# -------------------------------------------------------------- generators
def erdos_renyi(n_vertices: int, avg_degree: float, n_labels: int,
                seed: int = 0) -> Graph:
    """ER digraph (§VI-A): ~uniform out-degree, labels uniform on edges."""
    rng = np.random.default_rng(seed)
    n_edges = int(n_vertices * avg_degree)
    src = rng.integers(0, n_vertices, size=n_edges)
    dst = rng.integers(0, n_vertices, size=n_edges)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lab = rng.integers(0, n_labels, size=src.shape[0])
    return Graph.from_edges(n_vertices, n_labels,
                            zip(src.tolist(), dst.tolist(), lab.tolist()))


def preferential_attachment(n_vertices: int, avg_degree: float,
                            n_labels: int, seed: int = 0) -> Graph:
    """PA digraph (§VI-A): skewed out-degree (Barabási–Albert flavoured).

    Each new vertex attaches ``m = avg_degree/2`` out-edges to targets drawn
    proportionally to in-degree+1, plus receives edges from random earlier
    vertices — yielding the skew the paper relies on.
    """
    rng = np.random.default_rng(seed)
    m = max(1, int(round(avg_degree / 2)))
    edges: list[tuple[int, int, int]] = []
    weight = np.ones(n_vertices, dtype=np.float64)
    for v in range(1, n_vertices):
        w = weight[:v] / weight[:v].sum()
        k = min(m, v)
        targets = rng.choice(v, size=k, replace=False, p=w)
        for t in targets:
            edges.append((v, int(t), int(rng.integers(0, n_labels))))
            weight[t] += 1.0
        sources = rng.integers(0, v, size=m)
        for s in sources:
            edges.append((int(s), v, int(rng.integers(0, n_labels))))
            weight[v] += 1.0
    return Graph.from_edges(n_vertices, n_labels, edges)


def fig2_example() -> Graph:
    """A 10-vertex, 5-label digraph consistent with the paper's Fig. 2 /
    Examples 1–3 (labels a..e = 0..4)."""
    a, b, c, d, e = range(5)
    edges = [
        (0, 1, a), (0, 2, a), (0, 2, b), (0, 8, e),
        (1, 3, d),
        (2, 5, c),
        (3, 5, b),
        (4, 6, b),
        (5, 9, c),
        (7, 2, a), (7, 8, a), (7, 9, b), (7, 9, e),
        (8, 4, b),
    ]
    return Graph.from_edges(10, 5, edges)


def random_graph(kind: str, n_vertices: int, avg_degree: float,
                 n_labels: int, seed: int = 0) -> Graph:
    if kind == "er":
        return erdos_renyi(n_vertices, avg_degree, n_labels, seed)
    if kind == "pa":
        return preferential_attachment(n_vertices, avg_degree, n_labels, seed)
    raise ValueError(f"unknown graph kind {kind!r}")

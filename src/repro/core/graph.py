"""Edge-labeled digraphs (paper Def. 1) + the generators used in §VI.

A multigraph edge with several labels is stored as several parallel edges,
exactly as the paper prescribes.  Host representation is CSR (sorted by
source) with a parallel label array; reverse CSR is derived lazily.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """CSR edge-labeled digraph."""
    n_vertices: int
    n_labels: int
    indptr: np.ndarray    # int32 [V+1]
    indices: np.ndarray   # int32 [E]   destination of each edge
    labels: np.ndarray    # int32 [E]   label of each edge

    # ---------------------------------------------------------------- basic
    @property
    def n_edges(self) -> int:
        """Edge count |E| (parallel-labeled edges counted separately)."""
        return int(self.indices.shape[0])

    @property
    def src(self) -> np.ndarray:
        """Edge source array [E] (expanded from indptr)."""
        return np.repeat(np.arange(self.n_vertices, dtype=np.int32),
                         np.diff(self.indptr))

    def out_degree(self) -> np.ndarray:
        """Per-vertex out-degree int32 [V]."""
        return np.diff(self.indptr).astype(np.int32)

    def successors(self, u: int) -> np.ndarray:
        """Destination ids of u's out-edges (int32 view into the CSR)."""
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def out_edges(self, u: int) -> tuple[np.ndarray, np.ndarray]:
        """(destinations, labels) of u's out-edges (int32 CSR views)."""
        s, e = self.indptr[u], self.indptr[u + 1]
        return self.indices[s:e], self.labels[s:e]

    def reverse(self) -> "Graph":
        """Edge-reversed CSR (sorted by destination): the operand for
        reverse closures and predecessor walks."""
        src = self.src
        order = np.argsort(self.indices, kind="stable")
        rsrc = self.indices[order]
        rdst = src[order]
        rlab = self.labels[order]
        rptr = np.zeros(self.n_vertices + 1, dtype=np.int64)
        np.add.at(rptr, rsrc + 1, 1)
        rptr = np.cumsum(rptr)
        return Graph(self.n_vertices, self.n_labels,
                     rptr.astype(np.int32), rdst.astype(np.int32),
                     rlab.astype(np.int32))

    # ------------------------------------------------------------ builders
    @staticmethod
    def from_edges(n_vertices: int, n_labels: int,
                   edges: Iterable[tuple[int, int, int]]) -> "Graph":
        """Build from an iterable of ``(src, dst, label)`` triples.

        Duplicates collapse (the graph is an edge *set*); parallel edges
        with different labels are distinct edges, as the paper prescribes.
        """
        arr = np.asarray(sorted(set(edges)), dtype=np.int64)
        if arr.size == 0:
            arr = np.zeros((0, 3), dtype=np.int64)
        src, dst, lab = arr[:, 0], arr[:, 1], arr[:, 2]
        order = np.lexsort((dst, src))
        return Graph._from_sorted(n_vertices, n_labels, src[order],
                                  dst[order], lab[order])

    @staticmethod
    def _from_sorted(n_vertices: int, n_labels: int, src: np.ndarray,
                     dst: np.ndarray, lab: np.ndarray) -> "Graph":
        """CSR assembly from already (src, dst, lab)-sorted, deduped
        int64 edge arrays (the fast path ``apply_updates`` uses)."""
        indptr = np.zeros(n_vertices + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n_vertices, n_labels, indptr.astype(np.int32),
                     dst.astype(np.int32), lab.astype(np.int32))

    # ------------------------------------------------------------- updates
    def _edge_keys(self, arr: np.ndarray) -> np.ndarray:
        """Encode ``[N, 3]`` (src, dst, lab) rows as sortable int64 keys
        ordered exactly like the CSR edge order (src-major, then dst,
        then label)."""
        v = np.int64(max(self.n_vertices, 1))
        l = np.int64(max(self.n_labels, 1))
        return (arr[:, 0] * v + arr[:, 1]) * l + arr[:, 2]

    def _decode_keys(self, keys: np.ndarray) -> tuple[np.ndarray,
                                                      np.ndarray,
                                                      np.ndarray]:
        v = np.int64(max(self.n_vertices, 1))
        l = np.int64(max(self.n_labels, 1))
        lab = keys % l
        uv = keys // l
        return uv // v, uv % v, lab

    def apply_updates(self, edges_added: Iterable = (),
                      edges_removed: Iterable = ()) -> "GraphDelta":
        """Apply a batch of edge insertions/deletions; returns a
        ``GraphDelta`` holding the post-update graph plus the *effective*
        delta (int64 ``[N, 3]`` (src, dst, label) rows).

        Set semantics: removals are applied first, then additions —
        adding an existing edge or removing a missing one is a no-op, and
        an edge both removed and added survives.  ``delta.added`` /
        ``delta.removed`` record only real changes (``new - old`` /
        ``old - new``), so downstream incremental maintenance
        (``tdr_build.update_index``) never over-invalidates on no-ops.
        Vertex and label universes are fixed: endpoints must lie in
        ``[0, n_vertices)`` and labels in ``[0, n_labels)``.
        """
        def as_rows(edges):
            rows = np.asarray(list(edges), dtype=np.int64)
            rows = rows.reshape(-1, 3) if rows.size else np.zeros(
                (0, 3), dtype=np.int64)
            if rows.size and (
                    rows[:, :2].min(initial=0) < 0
                    or rows[:, :2].max(initial=0) >= self.n_vertices
                    or rows[:, 2].min(initial=0) < 0
                    or rows[:, 2].max(initial=0) >= self.n_labels):
                raise ValueError(
                    f"edge update outside the graph's universe "
                    f"(|V|={self.n_vertices}, |L|={self.n_labels})")
            return rows

        add = as_rows(edges_added)
        rem = as_rows(edges_removed)
        old_k = self._edge_keys(
            np.stack([self.src.astype(np.int64),
                      self.indices.astype(np.int64),
                      self.labels.astype(np.int64)], axis=1)
            if self.n_edges else np.zeros((0, 3), np.int64))
        new_k = np.union1d(np.setdiff1d(old_k, self._edge_keys(rem)),
                           self._edge_keys(add))
        added_eff = np.setdiff1d(new_k, old_k)
        removed_eff = np.setdiff1d(old_k, new_k)
        src, dst, lab = self._decode_keys(new_k)   # union1d is sorted
        g2 = Graph._from_sorted(self.n_vertices, self.n_labels, src, dst,
                                lab)
        return GraphDelta(
            graph=g2,
            added=np.stack(self._decode_keys(added_eff), axis=1),
            removed=np.stack(self._decode_keys(removed_eff), axis=1))


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """Effective result of one ``Graph.apply_updates`` call.

    ``graph`` is the post-update graph; ``added``/``removed`` are int64
    ``[N, 3]`` (src, dst, label) rows of the edges that actually changed
    (no-op adds/removes are filtered out).  This is the unit
    ``tdr_build.update_index`` consumes.
    """
    graph: Graph
    added: np.ndarray     # int64 [Na, 3]
    removed: np.ndarray   # int64 [Nr, 3]

    @property
    def n_changes(self) -> int:
        return int(self.added.shape[0] + self.removed.shape[0])


# ------------------------------------------------- subgraph/layout helpers
def csr_row_edges(indptr: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Edge-index array (int64) of all CSR slots belonging to ``rows`` —
    the vectorized form of ``concat(arange(indptr[r], indptr[r+1]) for r
    in rows)``.  Shared by BFS frontiers, predecessor walks, and the
    incremental adjacency patch."""
    starts = indptr[rows].astype(np.int64)
    counts = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
    tot = int(counts.sum())
    return np.repeat(starts, counts) + (
        np.arange(tot) - np.repeat(np.cumsum(counts) - counts, counts))


def pad_pow2(n: int, lo: int = 1) -> int:
    """Smallest power of two >= max(n, lo) (stable-shape bucketing)."""
    p = lo
    while p < n:
        p *= 2
    return p


def pad_bucket(n: int, lo: int = 1) -> int:
    """Smallest value >= max(n, lo) on the {2^k, 3·2^(k-1)} grid
    (powers of two plus midpoints: 32, 48, 64, 96, 128, ...).

    Halves the worst-case padding of pure pow2 buckets — decisive for
    corridor compaction, where a union just over V/2 must not round up
    past V — while keeping the distinct-jit-shape count logarithmic."""
    p = lo
    while p < n:
        q = p + p // 2
        if q >= n and q > p:
            return q
        p *= 2
    return p


def induced_edges(graph: Graph, active: np.ndarray, src: np.ndarray | None
                  = None) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Renumbered edge list of the subgraph induced by ``active`` (bool [V]).

    Returns ``(sub_ids, renum, sub_src, sub_dst, sub_lab)``: the active
    vertex ids, the V-sized old->new map (-1 outside), and the edges whose
    endpoints both lie in the active set, renumbered.  ``src`` lets callers
    pass a cached expanded source array (``graph.src`` rebuilds it)."""
    sub_ids = np.flatnonzero(active).astype(np.int32)
    renum = np.full(graph.n_vertices, -1, dtype=np.int32)
    renum[sub_ids] = np.arange(sub_ids.shape[0], dtype=np.int32)
    s = graph.src if src is None else src
    keep = active[s] & active[graph.indices]
    return (sub_ids, renum, renum[s[keep]], renum[graph.indices[keep]],
            graph.labels[keep])


def padded_incidence(keys: np.ndarray, n_segments: int, sentinel: int,
                     lo: int = 8) -> np.ndarray:
    """Group edge indices by ``keys`` into a padded ``[n_segments, D]``
    gather matrix (D = max group size rounded to a power of two; empty
    slots hold ``sentinel``).

    This converts a scatter-reduce (segment OR) into a dense gather +
    OR-reduce: callers append one zero row at index ``sentinel`` to the
    per-edge value array so padding slots contribute nothing."""
    e_n = int(keys.shape[0])
    counts = np.bincount(keys, minlength=n_segments) if e_n else np.zeros(
        n_segments, dtype=np.int64)
    d = int(counts.max()) if e_n else 0
    ids = np.full((n_segments, pad_bucket(max(d, 1), lo)), sentinel,
                  dtype=np.int32)
    if e_n:
        order = np.argsort(keys, kind="stable").astype(np.int32)
        sk = keys[order]
        pos = np.arange(e_n) - np.repeat(np.cumsum(counts) - counts, counts)
        ids[sk, pos] = order
    return ids


def incidence_plan(keys: np.ndarray, n_segments: int, sentinel: int,
                   cap: int = 16, lo: int = 8) -> tuple:
    """One- or two-level padded incidence, chosen by degree skew.

    Low skew -> ``(ids,)`` as from ``padded_incidence``.  With a heavy
    tail (padded width > 2*cap) a single level would pay max-degree
    padding on *every* segment, so groups are split into virtual rows of
    at most ``cap`` edges: ``(ids1 [n_virt, cap], ids2 [n_segments, D2])``
    — reduce the per-edge values by ``ids1``, then the virtual rows by
    ``ids2``.  ``n_virt`` is padded to a power of two with at least one
    all-``sentinel`` row, so the reduced virtual rows end with a zero row
    that ``ids2``'s padding can safely point at."""
    e_n = int(keys.shape[0])
    counts = np.bincount(keys, minlength=n_segments) if e_n else np.zeros(
        n_segments, dtype=np.int64)
    d = int(counts.max()) if e_n else 0
    if pad_bucket(max(d, 1), lo) <= 2 * cap:
        return (padded_incidence(keys, n_segments, sentinel, lo),)
    ngrp = np.maximum(1, -(-counts // cap))
    n_virt = int(ngrp.sum())
    base = np.cumsum(ngrp) - ngrp
    ids1 = np.full((pad_bucket(n_virt + 1, lo), cap), sentinel,
                   dtype=np.int32)
    order = np.argsort(keys, kind="stable").astype(np.int32)
    sk = keys[order]
    pos = np.arange(e_n) - np.repeat(np.cumsum(counts) - counts, counts)
    ids1[base[sk] + pos // cap, pos % cap] = order
    d2 = pad_bucket(int(ngrp.max()), 2)
    ids2 = np.full((n_segments, d2), n_virt, dtype=np.int32)
    grp = np.repeat(np.arange(n_segments), ngrp)
    gpos = np.arange(n_virt) - np.repeat(base, ngrp)
    ids2[grp, gpos] = np.arange(n_virt, dtype=np.int32)
    return (ids1, ids2)


# -------------------------------------------------------------- generators
def erdos_renyi(n_vertices: int, avg_degree: float, n_labels: int,
                seed: int = 0) -> Graph:
    """ER digraph (§VI-A): ~uniform out-degree, labels uniform on edges."""
    rng = np.random.default_rng(seed)
    n_edges = int(n_vertices * avg_degree)
    src = rng.integers(0, n_vertices, size=n_edges)
    dst = rng.integers(0, n_vertices, size=n_edges)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lab = rng.integers(0, n_labels, size=src.shape[0])
    return Graph.from_edges(n_vertices, n_labels,
                            zip(src.tolist(), dst.tolist(), lab.tolist()))


def preferential_attachment(n_vertices: int, avg_degree: float,
                            n_labels: int, seed: int = 0) -> Graph:
    """PA digraph (§VI-A): skewed out-degree (Barabási–Albert flavoured).

    Each new vertex attaches ``m = avg_degree/2`` out-edges to targets drawn
    proportionally to in-degree+1, plus receives edges from random earlier
    vertices — yielding the skew the paper relies on.
    """
    rng = np.random.default_rng(seed)
    m = max(1, int(round(avg_degree / 2)))
    edges: list[tuple[int, int, int]] = []
    weight = np.ones(n_vertices, dtype=np.float64)
    for v in range(1, n_vertices):
        w = weight[:v] / weight[:v].sum()
        k = min(m, v)
        targets = rng.choice(v, size=k, replace=False, p=w)
        for t in targets:
            edges.append((v, int(t), int(rng.integers(0, n_labels))))
            weight[t] += 1.0
        sources = rng.integers(0, v, size=m)
        for s in sources:
            edges.append((int(s), v, int(rng.integers(0, n_labels))))
            weight[v] += 1.0
    return Graph.from_edges(n_vertices, n_labels, edges)


def fig2_example() -> Graph:
    """A 10-vertex, 5-label digraph consistent with the paper's Fig. 2 /
    Examples 1–3 (labels a..e = 0..4)."""
    a, b, c, d, e = range(5)
    edges = [
        (0, 1, a), (0, 2, a), (0, 2, b), (0, 8, e),
        (1, 3, d),
        (2, 5, c),
        (3, 5, b),
        (4, 6, b),
        (5, 9, c),
        (7, 2, a), (7, 8, a), (7, 9, b), (7, 9, e),
        (8, 4, b),
    ]
    return Graph.from_edges(10, 5, edges)


def random_graph(kind: str, n_vertices: int, avg_degree: float,
                 n_labels: int, seed: int = 0) -> Graph:
    """Synthetic-graph dispatcher: ``kind`` is "er" (Erdős–Rényi) or
    "pa" (preferential attachment), matching the paper's §VI-A sweep."""
    if kind == "er":
        return erdos_renyi(n_vertices, avg_degree, n_labels, seed)
    if kind == "pa":
        return preferential_attachment(n_vertices, avg_degree, n_labels, seed)
    raise ValueError(f"unknown graph kind {kind!r}")

"""Sharded checkpointing with elastic restore.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json  (atomic rename from a
``.tmp`` staging dir so a crash mid-save never corrupts the latest step).

* ``save`` gathers to host (fine at example scale; at fleet scale the same
  manifest format supports per-host shard files — see README Ops notes) and
  can run asynchronously on a background thread so the step loop never
  blocks on disk.
* ``restore`` rebuilds the pytree and ``device_put``s each leaf with the
  sharding the *caller* provides — restoring onto a different mesh than the
  one that saved is therefore the default behaviour (elastic re-shard).
* ``keep`` bounds disk usage; the training driver uses save+restore for its
  failure-injection recovery test.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any) -> None:
        flat, _ = _flatten_with_paths(host_state)
        tmp = os.path.join(self.directory, f".tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{name: arr for name, arr in flat})
        manifest = {
            "step": step,
            "arrays": [{"name": n, "shape": list(a.shape),
                        "dtype": str(a.dtype)} for n, a in flat],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of ``target``.

        ``shardings``: optional pytree (same structure) of
        ``jax.sharding.Sharding`` — restoring onto any mesh, not just the
        one that saved (elastic scaling).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}", "arrays.npz")
        data = np.load(path)
        flat, treedef = _flatten_with_paths(target)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (name, ref), sh in zip(flat, shard_flat):
            arr = data[name]
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.device_put(arr))
        return step, treedef.unflatten(leaves)

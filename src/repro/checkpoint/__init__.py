from .checkpointer import Checkpointer

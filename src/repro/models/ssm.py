"""State-space / linear-attention mixers: Mamba2 (SSD) and RWKV6 (Finch).

Mamba2 uses the chunked SSD algorithm — intra-chunk attention-like matmuls
plus an inter-chunk state scan — so training is MXU-dominated and HLO FLOPs
reflect the real O(S·d·N) cost (no associative-scan 2× blowup).  RWKV6 ships
two formulations: the baseline per-step ``lax.scan`` recurrence (the paper
architecture's natural RNN form) and a chunked parallel form
(``rwkv6_chunked``) used by the §Perf hillclimb.  Both are exact and
cross-checked in tests.

Decode for both is O(1)/token on a small carried state — which is why these
archs run the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers, pspec
from repro.configs.base import ModelConfig


# =============================================================== Mamba2 ==
def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    p_heads = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 6)
    return {
        # fused in_proj: [z | x | B | C | dt]
        "in_proj": layers.truncated_normal(
            ks[0], (d, 2 * d_in + 2 * n + p_heads), d ** -0.5, dtype),
        "conv_w": layers.truncated_normal(
            ks[1], (cfg.conv_kernel, conv_ch), cfg.conv_kernel ** -0.5,
            dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, p_heads)).astype(
            jnp.float32),
        "d_skip": jnp.ones((p_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, p_heads))).astype(jnp.float32),
        "norm": layers.init_rms_norm(d_in, dtype),
        "out_proj": layers.truncated_normal(ks[2], (d_in, d), d_in ** -0.5,
                                            dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x [B,S,C], w [K,C] -> (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return jax.nn.silu(y + b), new_state


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    p_heads = d_in // cfg.ssm_head_dim
    z = proj[..., :d_in]
    rest = proj[..., d_in:]
    xbc = rest[..., :d_in + 2 * n]
    dt = rest[..., d_in + 2 * n:]
    return z, xbc, dt, d_in, n, p_heads


def mamba2_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                   state: Optional[dict] = None):
    """Mamba2 SSD mixer.  x [B,S,D] -> (y, new_state).

    ``state`` (decode): {"h": [B,P,N,hd], "conv": [B,K-1,C]}.  When state is
    None a full chunked-SSD pass runs and the final state is returned (for
    prefill→decode handoff).
    """
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    proj = pspec.constrain(x @ p["in_proj"], "batch", None, "ff")
    z, xbc, dt, d_in, n, ph = _split_proj(cfg, proj)

    if state is not None and s == 1:
        return _mamba2_step(p, cfg, x, z, xbc, dt, state)

    xbc, conv_state = _causal_conv(
        xbc, p["conv_w"], p["conv_b"],
        state["conv"] if state is not None else None)

    # pad S to a chunk multiple with dt≈0 steps (decay 1, zero input) so the
    # final state is untouched by padding
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    if pad:
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-20.0)
    sp = s + pad
    xs = pspec.constrain(xbc[..., :d_in].reshape(b, sp, ph, hd),
                         "batch", None, "heads", None)
    bs = pspec.constrain(xbc[..., d_in:d_in + n], "batch", None, None)
    cs = pspec.constrain(xbc[..., d_in + n:], "batch", None, None)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,P]
    a = -jnp.exp(p["a_log"])                                     # [P] (<0)
    la = dt * a[None, None, :]                                   # log-decay

    h0 = state["h"] if state is not None else jnp.zeros(
        (b, ph, n, hd), jnp.float32)
    y, h_last = _ssd_chunked(xs.astype(jnp.float32),
                             bs.astype(jnp.float32),
                             cs.astype(jnp.float32), dt, la, h0,
                             chunk=chunk)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    if pad:
        y = y[:, :s]
        xs = xs[:, :s]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"h": h_last, "conv": conv_state}


def _ssd_chunked(xs, bs, cs, dt, la, h0, chunk: int):
    """Chunked SSD.  xs [B,S,P,hd] bs/cs [B,S,N] dt/la [B,S,P].

    Returns (y [B,S,P,hd] f32, h_last [B,P,N,hd] f32).
    """
    b, s, ph, hd = xs.shape
    n = bs.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    r = lambda t: t.reshape((b, nc, chunk) + t.shape[2:])
    xs, bs, cs, dt, la = map(r, (xs, bs, cs, dt, la))

    cum = jnp.cumsum(la, axis=2)                     # [B,nc,L,P]
    total = cum[:, :, -1, :]                         # [B,nc,P]

    # intra-chunk: y[t] = C_t · Σ_{s<=t} exp(cum_t - cum_s) dt_s B_s x_s
    cb = jnp.einsum("bcln,bcmn->bclm", cs, bs)       # [B,nc,L,L]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,L,L,P]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    m = cb[..., None] * w                            # [B,nc,L,L,P]
    dx = dt[..., None] * xs                          # [B,nc,L,P,hd]
    y_intra = jnp.einsum("bclmp,bcmph->bclph", m, dx)

    # chunk summaries: S_c = Σ_s exp(total - cum_s) dt_s B_s ⊗ x_s
    wend = jnp.exp(total[:, :, None, :] - cum)       # [B,nc,L,P]
    sc = jnp.einsum("bcln,bclp,bclph->bcpnh", bs, wend * dt, xs)

    # inter-chunk scan: H_{c+1} = exp(total_c) H_c + S_c
    decay = jnp.exp(total)                           # [B,nc,P]

    def scan_fn(h, inp):
        dec, s_c = inp                               # [B,P], [B,P,N,hd]
        h_new = dec[:, :, None, None] * h + s_c
        return h_new, h

    (h_last, h_starts) = jax.lax.scan(
        scan_fn, h0, (decay.swapaxes(0, 1), sc.swapaxes(0, 1)))
    h_starts = h_starts.swapaxes(0, 1)               # [B,nc,P,N,hd] (entry)

    # inter-chunk contribution: y[t] += C_t · exp(cum_t) H_cstart
    y_inter = jnp.einsum("bcln,bclp,bcpnh->bclph", cs, jnp.exp(cum),
                         h_starts)
    y = (y_intra + y_inter).reshape(b, s, ph, hd)
    return y, h_last


def _mamba2_step(p, cfg, x, z, xbc, dt, state):
    """O(1) decode step."""
    b = x.shape[0]
    hd = cfg.ssm_head_dim
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    ph = d_in // hd
    k = p["conv_w"].shape[0]
    conv = state["conv"]
    xp = jnp.concatenate([conv, xbc], axis=1)        # [B, K, C]
    y = (xp * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    xbc1 = jax.nn.silu(y)                            # [B, C]
    new_conv = xp[:, 1:, :]
    xs = xbc1[:, :d_in].reshape(b, ph, hd).astype(jnp.float32)
    bs = xbc1[:, d_in:d_in + n].astype(jnp.float32)
    cs = xbc1[:, d_in + n:].astype(jnp.float32)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dtp * a[None, :])                  # [B,P]
    h = state["h"] * dec[:, :, None, None] + jnp.einsum(
        "bn,bp,bph->bpnh", bs, dtp, xs)
    yh = jnp.einsum("bn,bpnh->bph", cs, h)
    yh = yh + p["d_skip"][None, :, None] * xs
    yh = yh.reshape(b, 1, d_in).astype(x.dtype)
    yh = layers.rms_norm(yh * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return yh @ p["out_proj"], {"h": h, "conv": new_conv}


# ================================================================ RWKV6 ==
def init_rwkv6(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = max(1, d // cfg.ssm_head_dim)
    hd = d // h
    lora = max(32, d // 16)
    ks = jax.random.split(key, 10)
    std = d ** -0.5
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,w,g token-shift
        "w_r": layers.truncated_normal(ks[0], (d, d), std, dtype),
        "w_k": layers.truncated_normal(ks[1], (d, d), std, dtype),
        "w_v": layers.truncated_normal(ks[2], (d, d), std, dtype),
        "w_g": layers.truncated_normal(ks[3], (d, d), std, dtype),
        "w_o": layers.truncated_normal(ks[4], (d, d), std, dtype),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),    # decay base
        "w_lora_a": layers.truncated_normal(ks[5], (d, lora), std,
                                            jnp.float32),
        "w_lora_b": layers.truncated_normal(ks[6], (lora, d),
                                            lora ** -0.5, jnp.float32),
        "u": layers.truncated_normal(ks[7], (h, hd), hd ** -0.5,
                                     jnp.float32),
        "ln_x": layers.init_rms_norm(d, dtype),
    }


def init_rwkv6_cm(key, cfg: ModelConfig, dtype) -> dict:
    """RWKV channel-mix (the arch's FFN)."""
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "w_r": layers.truncated_normal(ks[0], (d, d), d ** -0.5, dtype),
        "w_k": layers.truncated_normal(ks[1], (d, f), d ** -0.5, dtype),
        "w_v": layers.truncated_normal(ks[2], (f, d), f ** -0.5, dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]):
    """x [B,S,D] -> x shifted right by one (first uses ``last`` or zeros)."""
    b, s, d = x.shape
    if last is None:
        last = jnp.zeros((b, 1, d), x.dtype)
    else:
        last = last.reshape(b, 1, d).astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def rwkv6_time_mix(p: dict, cfg: ModelConfig, x: jax.Array,
                   state: Optional[dict] = None, chunked: bool = False):
    """WKV6 time-mix.  x [B,S,D] -> (y, new_state).

    state: {"s": [B,H,hd,hd], "last": [B,D]}
    """
    b, s, d = x.shape
    h = max(1, d // cfg.ssm_head_dim)
    hd = d // h
    last = state["last"] if state is not None else None
    xs = _token_shift(x, last)
    mix = lambda i: x + (xs - x) * p["mu"][i].astype(x.dtype)
    # stay in the model dtype across the TP projection boundary (backward
    # d(mix) all-reduces then run at bf16 width -- §Perf iteration R3);
    # the recurrence itself upcasts to f32 below.
    r = pspec.constrain((mix(0) @ p["w_r"]).reshape(b, s, h, hd),
                        "batch", None, "heads", None)
    k = pspec.constrain((mix(1) @ p["w_k"]).reshape(b, s, h, hd),
                        "batch", None, "heads", None)
    v = pspec.constrain((mix(2) @ p["w_v"]).reshape(b, s, h, hd),
                        "batch", None, "heads", None)
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x_shift))).
    # The per-step log-decay is floored so the chunked formulation's
    # exp(-cumsum) stays in f32 range: floor = 80/chunk (e^80 < f32 max).
    # Scan and chunked share the floor, so they remain bit-comparable.
    chunk_len = max(1, min(cfg.ssm_chunk, 32, s))
    floor = 80.0 / chunk_len
    wlog = p["w0"] + jnp.tanh(mix(3).astype(jnp.float32) @ p["w_lora_a"]) \
        @ p["w_lora_b"]
    logw = -jnp.minimum(jnp.exp(wlog), floor)
    w = jnp.exp(logw).reshape(b, s, h, hd)               # decay in (0,1)
    g = jax.nn.silu(mix(4) @ p["w_g"])

    s0 = state["s"] if state is not None else jnp.zeros((b, h, hd, hd),
                                                        jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if chunked and s > 1:
        y, s_last = _wkv6_chunked(rf, kf, vf, w, p["u"], s0,
                                  chunk=chunk_len)
    else:
        y, s_last = _wkv6_scan(rf, kf, vf, w, p["u"], s0)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = layers.rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    out = y @ p["w_o"]
    return out, {"s": s_last, "last": x[:, -1, :]}


def _wkv6_scan(r, k, v, w, u, s0):
    """Reference recurrence.  r,k,v,w [B,S,H,hd]; u [H,hd]; s0 [B,H,hd,hd].

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t) S_{t-1}
          + k_t v_tᵀ
    """
    def step(s_prev, inp):
        rt, kt, vt, wt = inp                          # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]      # [B,H,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       s_prev + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s_prev + kv
        return s_new, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s_last                  # [B,S,H,hd]


def _wkv6_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked-parallel WKV6 (exact given the shared decay floor; §Perf).

    Factorized intra-chunk form: exp(cum_excl_t - cum_s) = exp(cum_excl_t)
    · exp(-cum_s), so the pairwise decay matrix never materialises at
    [L, L, D] — intra-chunk work is two plain [L, L] matmuls per head.
    The decay floor (see ``rwkv6_time_mix``) bounds exp(-cum_s) ≤ e^{5·L},
    with floor = 80/chunk everything stays in f32 range.
    """
    b, s, h, hd = r.shape
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    rs = lambda t: t.reshape(b, nc, chunk, h, hd)
    r, k, v, w = map(rs, (r, k, v, w))
    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=2)                    # inclusive prefix
    cum_excl = cum - logw                             # exclusive prefix
    total = cum[:, :, -1]                             # [B,nc,H,hd]

    # intra-chunk strict-lower-triangular linear attention
    r_dec = r * jnp.exp(cum_excl)                     # exp <= 1, safe
    k_dec = k * jnp.exp(-cum)                         # bounded by floor
    att = jnp.einsum("bclhd,bcmhd->bclmh", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
    att = jnp.where(tri[None, None, :, :, None], att, 0.0)
    y_intra = jnp.einsum("bclmh,bcmhd->bclhd", att, v)
    # diagonal bonus term
    y_diag = jnp.einsum("bclhd,bclhd,bclhe->bclhe",
                        r * u[None, None, None], k, v)

    # chunk summary: S_c_add = Σ_s exp(total - cum_s) k_s v_sᵀ
    wk = jnp.exp(total[:, :, None] - cum) * k
    sc = jnp.einsum("bclhd,bclhe->bchde", wk, v)

    def scan_fn(s_prev, inp):
        dec, s_add = inp                              # [B,H,hd],[B,H,hd,hd]
        s_new = dec[..., None] * s_prev + s_add
        return s_new, s_prev

    dec_c = jnp.exp(total).swapaxes(0, 1)             # [nc,B,H,hd]
    s_last, s_starts = jax.lax.scan(scan_fn, s0,
                                    (dec_c, sc.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                # [B,nc,H,hd,hd]

    y_inter = jnp.einsum("bclhd,bchde->bclhe",
                         r * jnp.exp(cum_excl), s_starts)
    y = (y_intra + y_diag + y_inter).reshape(b, s, h, hd)
    return y, s_last


def rwkv6_channel_mix(p: dict, cfg: ModelConfig, x: jax.Array,
                      state: Optional[jax.Array] = None):
    """RWKV FFN.  state = last token [B,D] for decode."""
    xs = _token_shift(x, state)
    mix = lambda i: x + (xs - x) * p["mu"][i].astype(x.dtype)
    r = jax.nn.sigmoid(mix(0) @ p["w_r"])
    kk = jnp.square(jax.nn.relu(mix(1) @ p["w_k"]))
    return r * (kk @ p["w_v"]), x[:, -1, :]

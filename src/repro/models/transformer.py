"""Backbone composition: scan-over-layers for every family.

Families and their block structure:
  * attn (dense / moe / vlm / audio):  x += Attn(LN(x));  x += FFN(LN(x))
    FFN = SwiGLU or MoE (+ shared experts).  gemma3's 5:1 local:global
    striping rides through the scan as per-layer (use_window, theta) xs.
  * mla: same with MLA attention (deepseek-v2).
  * mamba2 (+ zamba2 hybrid): x += Mamba2(LN(x)); hybrid applies one
    *shared-weight* attention+MLP block after every ``hybrid_attn_every``
    mamba layers (zamba2's signature weight sharing).
  * rwkv6: x += TimeMix(LN(x)); x += ChannelMix(LN(x)).

All layers are stacked ([L, ...] leading dim) and driven by ``lax.scan`` so
tracing/compile cost is O(1) in depth — required for the 62-layer 27B and
60-layer 236B dry-run cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, layers, moe, pspec, ssm
from repro.configs.base import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ------------------------------------------------------------------ init
def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": layers.init_embedding(keys[0], cfg.vocab, cfg.d_model, dt,
                                       cfg.tie_embeddings),
        "final_norm": layers.init_rms_norm(cfg.d_model, dt),
    }
    l = cfg.n_layers
    if cfg.block_type == "attn":
        attn_init = (functools.partial(attention.init_mla, cfg=cfg, dtype=dt)
                     if cfg.mla else
                     functools.partial(attention.init_gqa, cfg=cfg, dtype=dt))
        params["blocks"] = {
            "attn": _stack_init(lambda k: attn_init(k), keys[1], l),
            "ln1": jnp.zeros((l, cfg.d_model), jnp.float32),
            "ln2": jnp.zeros((l, cfg.d_model), jnp.float32),
        }
        if cfg.is_moe:
            params["blocks"]["ffn"] = _stack_init(
                lambda k: moe.init_moe(k, cfg, dt), keys[2], l)
        else:
            params["blocks"]["ffn"] = _stack_init(
                lambda k: layers.init_swiglu(k, cfg.d_model, cfg.d_ff, dt),
                keys[2], l)
    elif cfg.block_type == "mamba2":
        params["blocks"] = {
            "mixer": _stack_init(lambda k: ssm.init_mamba2(k, cfg, dt),
                                 keys[1], l),
            "ln": jnp.zeros((l, cfg.d_model), jnp.float32),
        }
        if cfg.hybrid_attn_every:
            params["shared"] = {
                "attn": attention.init_gqa(keys[3], cfg, dt),
                "ffn": layers.init_swiglu(keys[4], cfg.d_model, cfg.d_ff,
                                          dt),
                "ln_a": layers.init_rms_norm(cfg.d_model, dt),
                "ln_f": layers.init_rms_norm(cfg.d_model, dt),
            }
    elif cfg.block_type == "rwkv6":
        params["blocks"] = {
            "tm": _stack_init(lambda k: ssm.init_rwkv6(k, cfg, dt),
                              keys[1], l),
            "cm": _stack_init(lambda k: ssm.init_rwkv6_cm(k, cfg, dt),
                              keys[2], l),
            "ln1": jnp.zeros((l, cfg.d_model), jnp.float32),
            "ln2": jnp.zeros((l, cfg.d_model), jnp.float32),
        }
    else:
        raise ValueError(cfg.block_type)
    return params


# -------------------------------------------------------- per-layer flags
def layer_flags(cfg: ModelConfig):
    """(use_window [L] bool, theta [L] f32) for gemma3-style striping."""
    l = cfg.n_layers
    if cfg.local_per_global:
        # pattern L,L,L,L,L,G repeating (last of each group is global)
        idx = np.arange(l)
        is_global = (idx % (cfg.local_per_global + 1)
                     == cfg.local_per_global)
    else:
        is_global = np.ones(l, dtype=bool) if cfg.sliding_window == 0 \
            else np.zeros(l, dtype=bool)
    theta = np.where(is_global,
                     cfg.rope_theta_global or cfg.rope_theta,
                     cfg.rope_theta)
    use_window = ~is_global
    return jnp.asarray(use_window), jnp.asarray(theta, np.float32)


# --------------------------------------------------------------- forward
def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            media: Optional[jax.Array] = None, *, remat: bool = False,
            remat_policy: str = "", collect_cache: bool = False,
            q_chunk: int = 1024, rwkv_chunked: bool = False):
    """Full-sequence pass.  Returns (logits, aux, cache_seeds).

    ``cache_seeds`` (when collect_cache) holds per-layer KV/state needed to
    continue decoding after prefill.
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    x = layers.embed(params["embed"], tokens, media, cfg.n_media_tokens)
    x = pspec.constrain(x, "batch", "seq", "embed")

    rm = (remat, remat_policy)
    if cfg.block_type == "attn":
        x, aux, seeds = _attn_stack(cfg, params, x, positions, rm,
                                    collect_cache, q_chunk)
    elif cfg.block_type == "mamba2":
        x, aux, seeds = _mamba_stack(cfg, params, x, positions, rm,
                                     collect_cache, q_chunk)
    else:
        x, aux, seeds = _rwkv_stack(cfg, params, x, rm, collect_cache,
                                    rwkv_chunked)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)
    logits = pspec.constrain(logits, "batch", "seq", "vocab")
    return logits, aux, seeds


def _maybe_remat(fn, remat, policy_name: str = ""):
    if not remat:
        return fn
    if policy_name == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _attn_stack(cfg, params, x, positions, remat, collect_cache, q_chunk):
    use_window, thetas = layer_flags(cfg)
    blocks = params["blocks"]

    def body(carry, xs):
        x, aux = carry
        blk, use_w, theta = xs
        h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
        if cfg.mla:
            a, kv = attention.mla_forward(blk["attn"], cfg, h, positions,
                                          q_chunk=q_chunk)
        else:
            a, kv = attention.gqa_forward(
                blk["attn"], cfg, h, positions,
                window=cfg.sliding_window, use_window=use_w, theta=theta,
                q_chunk=q_chunk)
        x = x + a
        h = layers.rms_norm(x, blk["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            f, a_loss = moe.moe_forward(blk["ffn"], cfg, h)
            aux = aux + a_loss
        else:
            f = layers.swiglu(blk["ffn"], h)
        x = pspec.constrain(x + f, "batch", "seq", "embed")
        out = kv if collect_cache else None
        return (x, aux), out

    body = _maybe_remat(body, *remat)
    (x, aux), seeds = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (blocks, use_window, thetas))
    return x, aux, seeds


def _mamba_stack(cfg, params, x, positions, remat, collect_cache, q_chunk):
    blocks = params["blocks"]
    every = cfg.hybrid_attn_every
    l = cfg.n_layers

    def mamba_body(carry, blk):
        x = carry
        h = layers.rms_norm(x, blk["ln"], cfg.norm_eps)
        y, st = ssm.mamba2_forward(blk["mixer"], cfg, h)
        out = st if collect_cache else None
        return pspec.constrain(x + y, "batch", "seq", "embed"), out

    mamba_body = _maybe_remat(mamba_body, *remat)

    if not every:
        x, seeds = jax.lax.scan(mamba_body, x, blocks)
        return x, jnp.float32(0.0), {"mamba": seeds}

    shared = params["shared"]
    n_groups = l // every
    rem = l - n_groups * every
    tree_take = lambda t, a, b_: jax.tree.map(lambda v: v[a:b_], t)
    grouped = jax.tree.map(
        lambda v: v[:n_groups * every].reshape((n_groups, every)
                                               + v.shape[1:]), blocks)
    attn_seeds = []
    mamba_seeds = []

    def shared_attn(x):
        h = layers.rms_norm(x, shared["ln_a"], cfg.norm_eps)
        a, kv = attention.gqa_forward(shared["attn"], cfg, h, positions,
                                      q_chunk=q_chunk)
        x = x + a
        h = layers.rms_norm(x, shared["ln_f"], cfg.norm_eps)
        return x + layers.swiglu(shared["ffn"], h), kv

    def group_body(carry, grp):
        x = carry
        x, seeds = jax.lax.scan(mamba_body, x, grp)
        x, kv = shared_attn(x)
        return x, (seeds, kv)

    x, (m_seeds, a_seeds) = jax.lax.scan(group_body, x, grouped)
    if rem:
        tail = tree_take(blocks, n_groups * every, l)
        x, t_seeds = jax.lax.scan(mamba_body, x, tail)
    else:
        t_seeds = None
    seeds = {"mamba_groups": m_seeds, "attn": a_seeds, "mamba_tail": t_seeds}
    return x, jnp.float32(0.0), seeds


def _rwkv_stack(cfg, params, x, remat, collect_cache, chunked):
    blocks = params["blocks"]

    def body(carry, blk):
        x = carry
        h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
        y, st = ssm.rwkv6_time_mix(blk["tm"], cfg, h, chunked=chunked)
        x = x + y
        h = layers.rms_norm(x, blk["ln2"], cfg.norm_eps)
        y, last_cm = ssm.rwkv6_channel_mix(blk["cm"], cfg, h)
        x = pspec.constrain(x + y, "batch", "seq", "embed")
        out = (st, last_cm) if collect_cache else None
        return x, out

    body = _maybe_remat(body, remat)
    x, seeds = jax.lax.scan(body, x, blocks)
    return x, jnp.float32(0.0), seeds

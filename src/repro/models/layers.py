"""Shared neural layers: norms, RoPE, SwiGLU, embeddings, frontend stubs.

Everything is functional: ``init_*`` builds a param dict, ``apply`` is a pure
function.  Layer stacking for ``lax.scan`` is done by the transformer via
``jax.vmap`` over per-layer RNG keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype) -> jax.Array:
    # stored as (scale - 1) so zero-init == identity
    return jnp.zeros((d,), dtype=jnp.float32)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions [...,] -> angles [..., dim/2] (float32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """Rotate pairs.  x [B, S, H, hd]; positions [B, S]."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)            # [B, S, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ SwiGLU
def init_swiglu(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "w_gate": truncated_normal(k1, (d, f), std, dtype),
        "w_up": truncated_normal(k2, (d, f), std, dtype),
        "w_down": truncated_normal(k3, (f, d), f ** -0.5, dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# -------------------------------------------------------------- embeddings
def init_embedding(key, vocab: int, d: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": truncated_normal(k1, (vocab, d), d ** -0.5, dtype)}
    if not tie:
        p["unembed"] = truncated_normal(k2, (vocab, d), d ** -0.5, dtype)
    return p


def embed(p: dict, tokens: jax.Array, media: jax.Array | None = None,
          n_media: int = 0) -> jax.Array:
    """Token embedding with modality-stub injection.

    ``media`` [B, n_media, D] are *precomputed* frontend embeddings (the
    CLIP/EnCodec frontend is a stub per the assignment).  They overwrite the
    first ``n_media`` positions of the sequence.
    """
    x = p["tok"][tokens]
    if media is not None and n_media:
        prefix = media.astype(x.dtype)
        x = jnp.concatenate([prefix, x[:, n_media:, :]], axis=1)
    return x


def unembed(p: dict, x: jax.Array) -> jax.Array:
    w = p.get("unembed", p["tok"])
    return x @ w.T


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE in f32 (stable logsumexp).

    The label logit is extracted with an iota-compare masked sum rather
    than ``take_along_axis`` so a vocab-sharded logits tensor reduces
    locally + one small all-reduce (GSPMD would otherwise replicate the
    full [B, S, V] f32 logits per chip).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    hit = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
           == labels[..., None])
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()

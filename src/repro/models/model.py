"""Public model API: init / forward / prefill / decode_step / init_cache.

``decode_step`` is the unit the ``decode_32k`` / ``long_500k`` dry-run cells
lower: one new token against a seq_len-deep cache.  Cache layouts per family
are documented on ``init_cache``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, layers, ssm, transformer
from .transformer import forward, init_params, layer_flags
from repro.configs.base import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------- init_cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               index: int = 0) -> dict:
    """Decode cache.

    attn:   {k, v: [L, B, T, KV, hd], index}
    mla:    {c_kv: [L, B, T, ckv], k_rope: [L, B, T, 1, dr], index}
    mamba2: {h: [L, B, P, N, hd], conv: [L, B, K-1, C]}
            (+ hybrid: attn_k/attn_v [G, B, T, KV, hd], index)
    rwkv6:  {s: [L, B, H, hd, hd], last_tm/last_cm: [L, B, D]}
    """
    dt = _dtype(cfg)
    l, d = cfg.n_layers, cfg.d_model
    if cfg.block_type == "attn":
        if cfg.mla:
            return {
                "c_kv": jnp.zeros((l, batch, max_len, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((l, batch, max_len, 1, cfg.qk_rope_dim),
                                    dt),
                "index": jnp.int32(index),
            }
        hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
        return {
            "k": jnp.zeros((l, batch, max_len, kv, hd), dt),
            "v": jnp.zeros((l, batch, max_len, kv, hd), dt),
            "index": jnp.int32(index),
        }
    if cfg.block_type == "mamba2":
        d_in = cfg.ssm_expand * d
        ph = d_in // cfg.ssm_head_dim
        n = cfg.ssm_state
        conv_ch = d_in + 2 * n
        cache = {
            "h": jnp.zeros((l, batch, ph, n, cfg.ssm_head_dim),
                           jnp.float32),
            "conv": jnp.zeros((l, batch, cfg.conv_kernel - 1, conv_ch), dt),
        }
        if cfg.hybrid_attn_every:
            g = cfg.n_layers // cfg.hybrid_attn_every
            hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
            cache["attn_k"] = jnp.zeros((g, batch, max_len, kv, hd), dt)
            cache["attn_v"] = jnp.zeros((g, batch, max_len, kv, hd), dt)
            cache["index"] = jnp.int32(index)
        return cache
    if cfg.block_type == "rwkv6":
        h = max(1, d // cfg.ssm_head_dim)
        hd = d // h
        return {
            "s": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
            "last_tm": jnp.zeros((l, batch, d), dt),
            "last_cm": jnp.zeros((l, batch, d), dt),
        }
    raise ValueError(cfg.block_type)


# ---------------------------------------------------------------- prefill
def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            media: Optional[jax.Array] = None, *, max_len: int,
            q_chunk: int = 1024):
    """Run the full prompt, return (last-token logits, primed cache)."""
    b, s = tokens.shape
    logits, _, seeds = forward(cfg, params, tokens, media,
                               collect_cache=True, q_chunk=q_chunk)
    cache = init_cache(cfg, b, max_len)
    if cfg.block_type == "attn":
        if cfg.mla:
            c_kv, k_rope = seeds
            cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=2)
            cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0,
                axis=2)
        else:
            k, v = seeds
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
        cache["index"] = jnp.int32(s)
    elif cfg.block_type == "mamba2":
        if cfg.hybrid_attn_every:
            m_seeds, a_seeds = seeds["mamba_groups"], seeds["attn"]
            every = cfg.hybrid_attn_every
            g = cfg.n_layers // every
            h = m_seeds["h"].reshape((g * every,) + m_seeds["h"].shape[2:])
            cv = m_seeds["conv"].reshape((g * every,)
                                         + m_seeds["conv"].shape[2:])
            if seeds["mamba_tail"] is not None:
                h = jnp.concatenate([h, seeds["mamba_tail"]["h"]], axis=0)
                cv = jnp.concatenate([cv, seeds["mamba_tail"]["conv"]],
                                     axis=0)
            cache["h"], cache["conv"] = h, cv
            ak, av = a_seeds
            cache["attn_k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["attn_k"], ak.astype(cache["attn_k"].dtype), 0, axis=2)
            cache["attn_v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["attn_v"], av.astype(cache["attn_v"].dtype), 0, axis=2)
            cache["index"] = jnp.int32(s)
        else:
            cache["h"] = seeds["mamba"]["h"]
            cache["conv"] = seeds["mamba"]["conv"]
    else:  # rwkv6
        st, last_cm = seeds
        cache["s"] = st["s"]
        cache["last_tm"] = st["last"]
        cache["last_cm"] = last_cm
    return logits[:, -1, :], cache


# ------------------------------------------------------------ decode_step
def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array):
    """One token for every sequence.  tokens [B] -> (logits [B, V], cache)."""
    b = tokens.shape[0]
    x = params["embed"]["tok"][tokens][:, None, :]      # [B, 1, D]
    if cfg.block_type == "attn":
        x, cache = _decode_attn(cfg, params, cache, x)
    elif cfg.block_type == "mamba2":
        x, cache = _decode_mamba(cfg, params, cache, x)
    else:
        x, cache = _decode_rwkv(cfg, params, cache, x)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)
    return logits[:, 0, :], cache


def _decode_attn(cfg, params, cache, x):
    use_window, thetas = layer_flags(cfg)
    idx = cache["index"]
    positions = jnp.full((x.shape[0], 1), idx, jnp.int32)
    blocks = params["blocks"]

    def body(x, xs):
        if cfg.mla:
            blk, ckv_l, kr_l = xs
            h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
            a, (ckv, kr) = attention.mla_forward(
                blk["attn"], cfg, h, positions,
                cache={"c_kv": ckv_l, "k_rope": kr_l, "index": idx})
            new_slices = (ckv, kr)
        else:
            blk, use_w, theta, k_l, v_l = xs
            h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
            a, (ck, cv) = attention.gqa_forward(
                blk["attn"], cfg, h, positions, window=cfg.sliding_window,
                use_window=use_w, theta=theta,
                cache={"k": k_l, "v": v_l, "index": idx})
            new_slices = (ck, cv)
        x = x + a
        h = layers.rms_norm(x, blk["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            f, _ = moe_forward_decode(blk["ffn"], cfg, h)
        else:
            f = layers.swiglu(blk["ffn"], h)
        return x + f, new_slices

    if cfg.mla:
        x, (ckv, kr) = jax.lax.scan(body, x,
                                    (blocks, cache["c_kv"],
                                     cache["k_rope"]))
        cache = dict(cache, c_kv=ckv, k_rope=kr, index=idx + 1)
    else:
        x, (k, v) = jax.lax.scan(
            body, x, (blocks, use_window, thetas, cache["k"], cache["v"]))
        cache = dict(cache, k=k, v=v, index=idx + 1)
    return x, cache


def moe_forward_decode(p, cfg, x):
    """MoE for tiny token counts (decode): group = the whole batch row."""
    from . import moe as moe_mod
    b, s, d = x.shape
    return moe_mod.moe_forward(p, cfg, x, group_size=b * s)


def _decode_mamba(cfg, params, cache, x):
    blocks = params["blocks"]

    def mamba_body(x, xs):
        blk, h_l, conv_l = xs
        h = layers.rms_norm(x, blk["ln"], cfg.norm_eps)
        y, st = ssm.mamba2_forward(blk["mixer"], cfg, h,
                                   state={"h": h_l, "conv": conv_l})
        return x + y, (st["h"], st["conv"])

    every = cfg.hybrid_attn_every
    l = cfg.n_layers
    if not every:
        x, (h, conv) = jax.lax.scan(mamba_body, x,
                                    (blocks, cache["h"], cache["conv"]))
        return x, dict(cache, h=h, conv=conv)

    shared = params["shared"]
    idx = cache["index"]
    positions = jnp.full((x.shape[0], 1), idx, jnp.int32)
    g = l // every
    rem = l - g * every
    grouped = jax.tree.map(
        lambda v: v[:g * every].reshape((g, every) + v.shape[1:]), blocks)
    h_g = cache["h"][:g * every].reshape((g, every)
                                         + cache["h"].shape[1:])
    c_g = cache["conv"][:g * every].reshape((g, every)
                                            + cache["conv"].shape[1:])

    def group_body(x, xs):
        grp, h_l, c_l, ak_l, av_l = xs
        x, (h_new, c_new) = jax.lax.scan(mamba_body, x, (grp, h_l, c_l))
        hh = layers.rms_norm(x, shared["ln_a"], cfg.norm_eps)
        a, (ck, cv) = attention.gqa_forward(
            shared["attn"], cfg, hh, positions,
            cache={"k": ak_l, "v": av_l, "index": idx})
        x = x + a
        hh = layers.rms_norm(x, shared["ln_f"], cfg.norm_eps)
        x = x + layers.swiglu(shared["ffn"], hh)
        return x, (h_new, c_new, ck, cv)

    x, (h_new, c_new, ak, av) = jax.lax.scan(
        group_body, x, (grouped, h_g, c_g, cache["attn_k"],
                        cache["attn_v"]))
    h_new = h_new.reshape((g * every,) + h_new.shape[2:])
    c_new = c_new.reshape((g * every,) + c_new.shape[2:])
    if rem:
        tail = jax.tree.map(lambda v: v[g * every:], blocks)
        x, (h_t, c_t) = jax.lax.scan(
            mamba_body, x, (tail, cache["h"][g * every:],
                            cache["conv"][g * every:]))
        h_new = jnp.concatenate([h_new, h_t], axis=0)
        c_new = jnp.concatenate([c_new, c_t], axis=0)
    return x, dict(cache, h=h_new, conv=c_new, attn_k=ak, attn_v=av,
                   index=idx + 1)


def _decode_rwkv(cfg, params, cache, x):
    blocks = params["blocks"]

    def body(x, xs):
        blk, s_l, ltm_l, lcm_l = xs
        h = layers.rms_norm(x, blk["ln1"], cfg.norm_eps)
        y, st = ssm.rwkv6_time_mix(blk["tm"], cfg, h,
                                   state={"s": s_l, "last": ltm_l})
        x = x + y
        h = layers.rms_norm(x, blk["ln2"], cfg.norm_eps)
        y, lcm = ssm.rwkv6_channel_mix(blk["cm"], cfg, h, state=lcm_l)
        x = x + y
        return x, (st["s"], st["last"], lcm)

    x, (s_new, ltm, lcm) = jax.lax.scan(
        body, x, (blocks, cache["s"], cache["last_tm"], cache["last_cm"]))
    return x, dict(cache, s=s_new, last_tm=ltm, last_cm=lcm)

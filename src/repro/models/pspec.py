"""Logical-axis activation sharding constraints.

Models call ``constrain(x, "batch", None, "heads", None)``; the launcher
installs a mesh + logical→physical mapping before lowering
(``set_mesh(mesh, {"batch": ("pod","data"), "heads": "model", ...})``).
Without an installed mesh every call is a no-op, so tests/examples on one
CPU device never notice.

Divisibility guard: a logical axis resolves to its physical axis only when
the dimension divides evenly; otherwise that dim is left unsharded (e.g.
a 40-head model on a 16-wide model axis — documented in
ARCHITECTURE.md §Perf as a padding opportunity).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh], mapping: Optional[dict] = None) -> None:
    _state.mesh = mesh
    _state.mapping = mapping or {}


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, mapping: dict):
    prev = (getattr(_state, "mesh", None), getattr(_state, "mapping", {}))
    set_mesh(mesh, mapping)
    try:
        yield
    finally:
        set_mesh(*prev)


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, (tuple, list)):
        n = 1
        for a in phys:
            n *= mesh.shape[a]
        return n
    return mesh.shape[phys]


def constrain(x: jax.Array, *logical) -> jax.Array:
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    mapping = getattr(_state, "mapping", {})
    spec = []
    for dim, name in zip(x.shape, logical):
        phys = mapping.get(name) if name is not None else None
        if phys is None:
            spec.append(None)
            continue
        size = _axis_size(mesh, phys)
        spec.append(tuple(phys) if isinstance(phys, (tuple, list)) else phys
                    if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def logical_axis_size(name: str) -> int:
    """Physical size of a logical axis under the installed mapping (1 if
    no mesh/mapping)."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return 1
    phys = getattr(_state, "mapping", {}).get(name)
    return _axis_size(mesh, phys) if phys is not None else 1


def default_mapping(multi_pod: bool) -> dict:
    return {
        "batch": ("pod", "data") if multi_pod else ("data",),
        "heads": "model",
        "kv": "model",
        "vocab": "model",
        "ff": "model",
        "experts": "model",
        "embed": None,
        "seq": None,
        "sp": "data",     # sequence-parallel axis for batch-1 long context
    }

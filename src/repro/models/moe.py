"""Mixture-of-Experts FFN (dbrx 16e top-4, deepseek-v2 2 shared + 160e top-6).

GShard-style grouped one-hot dispatch: tokens are reshaped into groups of
``group_size``, each group gets a static per-expert capacity
``C = ceil(group_size · top_k / E · capacity_factor)`` and dispatch/combine
are einsums — so expert compute is top-k-proportional (HLO FLOPs track
6·N_active·D, which §Roofline checks), the dispatch one-hots stay
``group_size × E × C`` (never token-count quadratic), and GSPMD turns the
token→expert regrouping into all-to-alls when experts are sharded over the
``model`` axis (EP).

Router is deterministic (no jitter), gates are the softmax of the top-k
logits, plus the switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers, pspec
from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.truncated_normal(ks[0], (d, e), d ** -0.5,
                                          jnp.float32),
        "w_gate": layers.truncated_normal(ks[1], (e, d, f), d ** -0.5, dtype),
        "w_up": layers.truncated_normal(ks[2], (e, d, f), d ** -0.5, dtype),
        "w_down": layers.truncated_normal(ks[3], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_swiglu(
            ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def moe_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                group_size: int = 512) -> tuple[jax.Array, jax.Array]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    gs = min(group_size, t)
    assert t % gs == 0, (t, gs)
    g = t // gs
    cap = max(1, int(gs * k / e * cfg.capacity_factor))

    xg = x.reshape(g, gs, d)
    logits = (xg.astype(jnp.float32) @ p["router"])          # [g, gs, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # [g, gs, K]
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) selection within its expert's capacity
    sel = jax.nn.one_hot(top_i, e, dtype=jnp.int32)          # [g, gs, K, E]
    sel_flat = sel.reshape(g, gs * k, e)
    pos = jnp.cumsum(sel_flat, axis=1) - sel_flat            # [g, gs*K, E]
    pos = pos.reshape(g, gs, k, e)
    in_cap = (pos < cap) & (sel > 0)
    slot = jnp.where(in_cap, pos, cap)                       # cap = dropped

    disp = jax.nn.one_hot(slot, cap, dtype=x.dtype) \
        * sel.astype(x.dtype)[..., None]                     # [g,gs,K,E,C]
    dispatch = disp.sum(axis=2)                              # [g, gs, E, C]
    combine = (disp * gates.astype(x.dtype)[..., None, None]).sum(axis=2)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)          # [g, E, C, D]
    xe = pspec.constrain(xe, "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = pspec.constrain(h, "batch", "experts", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # [g, E, C, D]
    ye = pspec.constrain(ye, "batch", "experts", None, None)
    y = jnp.einsum("gsec,gecd->gsd", combine, ye).reshape(b, s, d)
    y = pspec.constrain(y, "batch", None, None)

    # switch-style load-balance loss
    me = probs.mean(axis=(0, 1))                             # [E]
    ce = sel.astype(jnp.float32).sum(2).mean(axis=(0, 1)) / k
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    if "shared" in p:
        y = y + layers.swiglu(p["shared"], x)
    return y, aux

"""Attention variants: GQA (full / sliding-window) and MLA (DeepSeek-V2).

All paths are exact; prefill uses query-block chunking so the score matrix
never materialises at [S, S] (required for the 32k dry-run cells to fit), and
decode attends a single query row against the cache.  Masks are computed from
iota comparisons inline — nothing quadratic is ever stored.

MLA keeps the paper's latent formulation: the KV cache stores the compressed
``c_kv`` (kv_lora_rank) plus the shared rotary key (qk_rope_dim) — 576 floats
per token per layer for deepseek-v2-236b instead of 2·128·192.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers, pspec
from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ----------------------------------------------------------------- params
def init_gqa(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "wq": layers.truncated_normal(ks[0], (d, h * hd), std, dtype),
        "wk": layers.truncated_normal(ks[1], (d, kv * hd), std, dtype),
        "wv": layers.truncated_normal(ks[2], (d, kv * hd), std, dtype),
        "wo": layers.truncated_normal(ks[3], (h * hd, d),
                                      (h * hd) ** -0.5, dtype),
    }


def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "wq_a": layers.truncated_normal(ks[0], (d, cfg.q_lora_rank), std,
                                        dtype),
        "q_norm": layers.init_rms_norm(cfg.q_lora_rank, dtype),
        "wq_b": layers.truncated_normal(ks[1], (cfg.q_lora_rank, h * qk),
                                        cfg.q_lora_rank ** -0.5, dtype),
        "wkv_a": layers.truncated_normal(
            ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), std, dtype),
        "kv_norm": layers.init_rms_norm(cfg.kv_lora_rank, dtype),
        "wkv_b": layers.truncated_normal(
            ks[3], (cfg.kv_lora_rank,
                    h * (cfg.qk_nope_dim + cfg.v_head_dim)),
            cfg.kv_lora_rank ** -0.5, dtype),
        "wo": layers.truncated_normal(ks[4], (h * cfg.v_head_dim, d),
                                      (h * cfg.v_head_dim) ** -0.5, dtype),
    }


# ------------------------------------------------------------- mask logic
def _score_mask(q_pos, k_pos, window: int, use_window):
    """Causal (+ optional sliding window) mask from position vectors.

    ``window`` is static (the layer's window size, 0 = full attention);
    ``use_window`` may be a *traced* scalar bool so gemma3's 5:1
    local:global striping can ride through one scanned layer body.
    """
    causal = k_pos[None, :] <= q_pos[:, None]
    if window == 0:
        return causal
    in_window = (q_pos[:, None] - k_pos[None, :]) < window
    return causal & (in_window | jnp.logical_not(use_window))


def _sdpa(q, k, v, q_pos, k_pos, window, scale, use_window=True):
    """softmax(q k^T / sqrt) v with mask; q [B,Sq,H,hd] k/v [B,Sk,KV,hd].

    Operands stay in the model dtype (bf16); accumulation is f32 via
    ``preferred_element_type`` — flash-attention numerics without 2× HBM
    traffic from f32 upcasts of the K/V stream.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = (q * scale).reshape(b, sq, kvh, rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k,
                        preferred_element_type=jnp.float32)
    mask = _score_mask(q_pos, k_pos, window, use_window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def _chunked_sdpa(q, k, v, positions, window, scale, q_chunk: int,
                  use_window=True):
    """Exact attention with query-block chunking (scores stay [.., qc, S])."""
    b, s, h, hd = q.shape
    if s <= q_chunk:
        return _sdpa(q, k, v, positions, positions, window, scale,
                     use_window)
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    k_pos = positions

    def body(i, out):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        q_pos = jax.lax.dynamic_slice_in_dim(positions, i * q_chunk, q_chunk)
        o = _sdpa(q_blk, k, v, q_pos, k_pos, window, scale, use_window)
        return jax.lax.dynamic_update_slice_in_dim(out, o, i * q_chunk,
                                                   axis=1)

    init = jnp.zeros((b, s, h, v.shape[-1]), q.dtype)
    return jax.lax.fori_loop(0, nq, body, init)


# ---------------------------------------------------------------- GQA fwd
def gqa_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, *, window: int = 0,
                use_window=True, theta: Optional[float] = None,
                cache: Optional[dict] = None,
                q_chunk: int = 1024):
    """GQA attention.

    Without cache: full/prefill pass over x [B,S,D]; returns (y, kv) where
    kv = (k, v) for cache seeding.
    With cache: single-step decode; x [B,1,D], cache {k, v [B,T,KV,hd],
    index}; returns (y, updated (k, v)).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    theta = theta if theta is not None else cfg.rope_theta
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    kk = (x @ p["wk"]).reshape(b, s, kv, hd)
    vv = (x @ p["wv"]).reshape(b, s, kv, hd)
    pos2 = positions if positions.ndim == 2 else positions[None, :]
    q = layers.apply_rope(q, pos2, theta)
    kk = layers.apply_rope(kk, pos2, theta)
    q = pspec.constrain(q, "batch", None, "heads", None)
    kk = pspec.constrain(kk, "batch", None, "kv", None)
    vv = pspec.constrain(vv, "batch", None, "kv", None)
    scale = hd ** -0.5
    # TP shardability: when KV heads don't divide the model axis but H
    # does, expand KV to full heads (fused broadcast) so the attention
    # einsums shard head-wise instead of replicating.
    tp = pspec.logical_axis_size("heads")
    expand = (h > kv) and (kv % tp != 0) and (h % tp == 0)

    if cache is None:
        pos1 = pos2[0]
        kc, vc = kk, vv
        if expand:
            kc = pspec.constrain(jnp.repeat(kk, h // kv, axis=2),
                                 "batch", None, "heads", None)
            vc = pspec.constrain(jnp.repeat(vv, h // kv, axis=2),
                                 "batch", None, "heads", None)
        y = _chunked_sdpa(q, kc, vc, pos1, window, scale, q_chunk,
                          use_window)
        y = y.reshape(b, s, h * hd) @ p["wo"]
        return y, (kk, vv)

    # decode: write new kv at cache index, attend over [0, index]
    idx = cache["index"]                       # scalar int32
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kk, idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vv, idx, axis=1)
    t = ck.shape[1]
    k_pos = jnp.arange(t, dtype=jnp.int32)
    valid = k_pos <= idx
    if window:
        valid &= ((idx - k_pos) < window) | jnp.logical_not(use_window)
    qg = (q * scale).reshape(b, 1, kv, h // kv, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bgrqk,bkgh->bqgrh", prob.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    y = y.reshape(b, 1, h * hd).astype(x.dtype) @ p["wo"]
    return y, (ck, cv)


# ---------------------------------------------------------------- MLA fwd
def mla_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, *, cache: Optional[dict] = None,
                q_chunk: int = 1024):
    """DeepSeek-V2 multi-head latent attention.

    Cache stores the latent (c_kv, k_rope) only.  For prefill/training the
    latent is up-projected and attention runs like MHA; decode re-derives
    per-head keys from the cached latent.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos2 = positions if positions.ndim == 2 else positions[None, :]

    q_lat = layers.rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(b, s, h, dn + dr)
    q = pspec.constrain(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, pos2, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                       # [B,S,kv_lora+dr]
    c_kv = layers.rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"],
                           cfg.norm_eps)
    k_rope = layers.apply_rope(kv_a[..., None, cfg.kv_lora_rank:], pos2,
                               cfg.rope_theta)  # [B,S,1,dr]

    if cache is not None:
        idx = cache["index"]
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv, idx, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, idx, axis=1)

    kv = (c_kv @ p["wkv_b"]).reshape(b, c_kv.shape[1], h, dn + dv)
    kv = pspec.constrain(kv, "batch", None, "heads", None)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    scale = (dn + dr) ** -0.5
    t = k_nope.shape[1]
    k_pos = jnp.arange(t, dtype=jnp.int32)
    if cache is None:
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, t, h, dr))], axis=-1)
        y = _chunked_sdpa(q_full, k_full, v, pos2[0], 0, scale, q_chunk)
        y = y.reshape(b, s, h * dv) @ p["wo"]
        return y, (c_kv, k_rope)

    idx = cache["index"]
    valid = k_pos <= idx
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, h, dr))], axis=-1)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf * scale, kf,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhqk,bkhd->bqhd", prob.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    y = y.reshape(b, 1, h * dv).astype(x.dtype) @ p["wo"]
    return y, (c_kv, k_rope)

"""LM substrate: model families for the assigned architecture pool."""
from . import attention, layers, model, moe, ssm, transformer
from .model import decode_step, init_cache, prefill
from .transformer import forward, init_params

__all__ = ["attention", "layers", "model", "moe", "ssm", "transformer",
           "forward", "init_params", "decode_step", "init_cache", "prefill"]

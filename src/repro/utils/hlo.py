"""Loop-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation **once**, so a
scan-over-layers model under-reports FLOPs by ~n_layers and collectives
inside the loop are counted once.  This module re-derives loop-aware,
per-device costs directly from ``compiled.as_text()``:

1. parse computations + instructions (shapes, opcodes, operands),
2. build the call graph (while bodies/conditions, fusions, calls,
   conditionals),
3. extract while trip counts from the loop-condition ``compare(iter,
   constant)`` pattern,
4. propagate multipliers: cost(computation) × Π trip-counts of enclosing
   loops,
5. aggregate:
     * flops            — 2·M·N·K per ``dot`` (+ batch dims), anywhere,
     * hbm_bytes        — Σ operand+output bytes of top-level *memory-
                          moving* ops (fusion, dot, copy, slices,
                          collectives); fused subcomputations excluded,
     * collective_bytes — per-device link traffic with a ring model:
                          all-reduce 2·in, all-gather out, reduce-scatter
                          in, all-to-all in, collective-permute in,
     * per-collective-op breakdown for §Perf drill-downs.

Shapes in post-SPMD text are already per-device, so every number reported
here is per-chip.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# opcode = first bare word directly followed by "(" after the type (types
# may be tuples with /*index=N*/ comments, so no assumptions about "=")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation|"
    r"branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
# Ops that genuinely move HBM bytes on TPU.  Pure-layout ops (reshape,
# broadcast, transpose, iota, pad, slice, concatenate) and elementwise
# chains fuse on TPU, so the CPU backend's standalone instances are
# excluded -- see ARCHITECTURE.md §Roofline "methodology".
_MEM_OPS = ("fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
            "reduce", "scatter", "gather", "select-and-scatter",
            "convolution") + COLLECTIVE_OPS


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_fused: bool = False


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line):
            cur = Computation(hdr.group(1), [])
            comps[cur.name] = cur
            if "fused_computation" in cur.name:
                cur.is_fused = True
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2).strip(),
                                    m.group(3), line))
    return comps


def _called_comps(line: str) -> list[str]:
    out = []
    for m in _CALL_ATTR_RE.finditer(line):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def _operand_names(line: str) -> list[str]:
    """Operand instruction names: the %refs inside the first paren group."""
    try:
        args = line.split("(", 1)[1]
        args = args.split(")", 1)[0]
    except IndexError:
        return []
    return re.findall(r"%([\w.\-]+)", args)


def _dot_flops(line: str, out_type: str,
               table: Dict[str, str]) -> float:
    """2 × prod(output dims) × prod(contracting dims)."""
    out_dims = _shape_dims(out_type)
    out_n = math.prod(out_dims[0]) if out_dims else 0
    opnds = _operand_names(line)
    lhs_type = table.get(opnds[0], "") if opnds else ""
    lhs_dims = _shape_dims(lhs_type)
    lhs = lhs_dims[0] if lhs_dims else []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not lhs or mc is None:
        k = lhs[-1] if lhs else 1
        return 2.0 * out_n * k
    k = 1
    for d in mc.group(1).split(","):
        if d != "":
            k *= lhs[int(d)]
    return 2.0 * out_n * k


def _trip_count(cond: Computation) -> int:
    """Trip count heuristic: the max s32 constant in the loop condition."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.line):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    # drill-down: (total_bytes, mult, opcode, out_type, metadata-op-name)
    top_collectives: list = dataclasses.field(default_factory=list)
    top_memory: list = dataclasses.field(default_factory=list)

    def finalize(self, keep: int = 20):
        self.top_collectives = sorted(self.top_collectives,
                                      reverse=True)[:keep]
        self.top_memory = sorted(self.top_memory, reverse=True)[:keep]
        return self


def analyze(text: str) -> HloCost:
    comps = parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: computation named main*
        for name in comps:
            if name.startswith("main"):
                entry = name
                break
    # build weighted call edges, then propagate multipliers in topo order
    edges: Dict[str, list] = {name: [] for name in comps}
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip = 1
                mcond = re.search(r"condition=%?([\w.\-]+)", ins.line)
                mbody = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mcond and mcond.group(1) in comps:
                    trip = _trip_count(comps[mcond.group(1)])
                    edges[cname].append((mcond.group(1), trip + 1))
                if mbody and mbody.group(1) in comps:
                    edges[cname].append((mbody.group(1), trip))
            else:
                for cn in _called_comps(ins.line):
                    if cn in comps:
                        edges[cname].append((cn, 1))

    # topological order via DFS from entry (HLO call graph is a DAG)
    topo: list[str] = []
    state: Dict[str, int] = {}

    def visit(n: str):
        stack = [(n, 0)]
        while stack:
            node, i = stack.pop()
            if i == 0:
                if state.get(node, 0):
                    continue
                state[node] = 1
            kids = edges.get(node, [])
            if i < len(kids):
                stack.append((node, i + 1))
                kid = kids[i][0]
                if state.get(kid, 0) == 0:
                    stack.append((kid, 0))
            else:
                state[node] = 2
                topo.append(node)

    visit(entry)
    topo.reverse()  # callers before callees

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for cname in topo:
        m = mult[cname]
        if m == 0.0:
            continue
        for cn, w in edges.get(cname, []):
            mult[cn] += m * w

    cost = HloCost()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        inside_fusion = comp.is_fused
        table = {ins.name: ins.out_type for ins in comp.instrs}
        for ins in comp.instrs:
            if ins.opcode == "dot":
                cost.flops += m * _dot_flops(ins.line, ins.out_type, table)
            if inside_fusion:
                continue
            in_b = sum(shape_bytes(table.get(o, ""))
                       for o in _operand_names(ins.line))
            out_b = shape_bytes(ins.out_type)
            if ins.opcode in _MEM_OPS:
                if ins.opcode == "dynamic-update-slice":
                    # in-place on TPU: only the update slice moves
                    upd = _operand_names(ins.line)
                    upd_b = shape_bytes(table.get(upd[1], "")) \
                        if len(upd) > 1 else out_b
                    moved = 2.0 * upd_b
                elif ins.opcode == "dynamic-slice":
                    moved = 2.0 * out_b
                elif ins.opcode == "fusion" and \
                        "dynamic_update_slice" in ins.line:
                    # DUS-rooted fusion: in-place update; count the inputs
                    # except the big aliased buffer (first operand)
                    ops_n = _operand_names(ins.line)
                    rest = sum(shape_bytes(table.get(o, ""))
                               for o in ops_n[1:])
                    moved = 2.0 * rest if rest else out_b + in_b
                else:
                    moved = out_b + in_b
                cost.hbm_bytes += m * moved
                mo = re.search(r'op_name="([^"]*)"', ins.line)
                cost.top_memory.append(
                    (m * moved, m, ins.opcode, ins.out_type[:60],
                     mo.group(1)[-80:] if mo else ""))
            base = ins.opcode.replace("-start", "").replace("-done", "")
            for cop in COLLECTIVE_OPS:
                if base == cop and not ins.opcode.endswith("-done"):
                    if cop == "all-reduce":
                        traffic = 2.0 * in_b
                    elif cop == "all-gather":
                        traffic = out_b
                    else:
                        traffic = in_b
                    cost.collective_bytes += m * traffic
                    cost.collectives[cop] += m * traffic
                    cost.collective_counts[cop] += int(m)
                    mo = re.search(r'op_name="([^"]*)"', ins.line)
                    cost.top_collectives.append(
                        (m * traffic, m, cop, ins.out_type[:60],
                         mo.group(1)[-80:] if mo else ""))
    return cost.finalize()

from . import hlo, roofline

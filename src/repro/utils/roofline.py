"""Three-term roofline model for TPU v5e.

    compute_s    = per_chip_FLOPs   / 197e12      (bf16 MXU peak)
    memory_s     = per_chip_bytes   / 819e9       (HBM bandwidth)
    collective_s = per_chip_link_B  / 50e9        (one ICI link; the ring
                   traffic model in utils/hlo.py already reduces each
                   collective to per-chip link bytes)

All inputs come from the loop-aware HLO analysis of the compiled dry-run
(per-device shapes), so every term is per-chip seconds for one step.
``model_flops_ratio`` = MODEL_FLOPS / HLO_FLOPs measures how much compiled
compute is "useful" (remat/dispatch/recompute waste shows up here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .hlo import HloCost

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float
    model_flops_ratio: float
    dominant: str
    step_s: float            # max of the three terms (perfect overlap)
    mfu: float               # model_flops / (chips · peak · step_s)

    @staticmethod
    def from_cost(cost: HloCost, *, chips: int, model_flops: float
                  ) -> "Roofline":
        c = cost.flops / PEAK_FLOPS
        m = cost.hbm_bytes / HBM_BW
        k = cost.collective_bytes / LINK_BW
        step = max(c, m, k, 1e-12)
        dom = {c: "compute", m: "memory", k: "collective"}[max(c, m, k)]
        ratio = model_flops / max(cost.flops * chips, 1.0)
        return Roofline(
            compute_s=c, memory_s=m, collective_s=k,
            flops=cost.flops, hbm_bytes=cost.hbm_bytes,
            collective_bytes=cost.collective_bytes,
            model_flops=model_flops, model_flops_ratio=ratio,
            dominant=dom, step_s=step,
            mfu=model_flops / (chips * PEAK_FLOPS * step))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """6·N·D (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_forward(n_params_active: int, n_tokens: int) -> float:
    return 2.0 * n_params_active * n_tokens

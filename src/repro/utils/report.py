"""ARCHITECTURE.md table generator.

Reads experiments/dryrun_{single,multi}.json (+ perf_iterations.json) and
emits the §Dry-run / §Roofline markdown tables.  MODEL_FLOPS is recomputed
from the current configs (6·N_active·D for train, 2·N_active·D forward) so
formula fixes don't require re-compiling the sweep; the HLO-derived terms
come from the stored analysis.

Usage: PYTHONPATH=src python -m repro.utils.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import sys

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.utils import roofline as roof


def model_flops_of(arch: str, shape_name: str) -> float:
    cfg = configs.get(arch)
    sh = SHAPES[shape_name]
    n_tokens = sh.global_batch * sh.seq_len if sh.kind != "decode" \
        else sh.global_batch
    n = cfg.n_active_params()
    return (6.0 if sh.kind == "train" else 2.0) * n * n_tokens


def derive(rec: dict) -> dict:
    """Recompute roofline columns from stored per-chip HLO numbers."""
    h = rec.get("hlo")
    if not h:
        return {}
    chips = rec["chips"]
    c = h["flops_per_chip"] / roof.PEAK_FLOPS
    m = h["hbm_bytes_per_chip"] / roof.HBM_BW
    k = h["collective_bytes_per_chip"] / roof.LINK_BW
    step = max(c, m, k, 1e-12)
    dom = {c: "compute", m: "memory", k: "collective"}[max(c, m, k)]
    if rec["arch"] == "tdr-graph":
        mf = rec.get("roofline", {}).get("model_flops", 0.0)
    else:
        mf = model_flops_of(rec["arch"], rec["shape"])
    return {
        "compute_s": c, "memory_s": m, "collective_s": k, "dominant": dom,
        "model_flops": mf,
        "ratio": mf / max(h["flops_per_chip"] * chips, 1.0),
        "mfu": mf / (chips * roof.PEAK_FLOPS * step),
        "step_s": step,
    }


def dryrun_table(results: list) -> str:
    out = ["| arch | shape | mesh | chips | compile s | peak GB/chip | "
           "HLO GFLOP/chip | HBM GB/chip | coll GB/chip |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"SKIP ({r['skipped']}) | — | — | — | — |")
            continue
        h = r.get("hlo", {})
        mem = r["memory"]
        peak = mem.get("peak_gb", mem.get("temp_gb", 0)
                       + mem.get("argument_gb", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r.get('compile_s', '—')} | {peak:.1f} | "
            f"{h.get('flops_per_chip', 0) / 1e9:.0f} | "
            f"{h.get('hbm_bytes_per_chip', 0) / 1e9:.0f} | "
            f"{h.get('collective_bytes_per_chip', 0) / 1e9:.1f} |")
    return "\n".join(out)


def roofline_table(results: list) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful ratio | roofline MFU |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if "skipped" in r or not r.get("hlo"):
            continue
        d = derive(r)
        if r["arch"] == "tdr-graph":
            # OR-semiring work doesn't register as HLO dots; ratio/MFU
            # are not meaningful for the engine cell
            out.append(
                f"| {r['arch']} | {r['shape']} | {d['compute_s']:.3f} | "
                f"{d['memory_s']:.3f} | {d['collective_s']:.3f} | "
                f"**{d['dominant']}** | {d['model_flops']:.2e} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {d['compute_s']:.3f} | "
            f"{d['memory_s']:.3f} | {d['collective_s']:.3f} | "
            f"**{d['dominant']}** | {d['model_flops']:.2e} | "
            f"{d['ratio']:.2f} | {d['mfu']:.4f} |")
    return "\n".join(out)


def main() -> None:
    single = json.load(open("experiments/dryrun_single.json"))["results"]
    try:
        multi = json.load(open("experiments/dryrun_multi.json"))["results"]
    except FileNotFoundError:
        multi = []
    print("## Dry-run (single-pod 16×16 = 256 chips)\n")
    print(dryrun_table(single))
    print("\n## Dry-run (multi-pod 2×16×16 = 512 chips)\n")
    print(dryrun_table(multi))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(single))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(multi))
    try:
        perf = json.load(open("experiments/perf_iterations.json"))
        print("\n## Perf iterations\n")
        out = ["| iteration | compute s | memory s | collective s | "
               "dominant | MFU |", "|---|---|---|---|---|---|"]
        for name, rec in perf["iterations"].items():
            d = derive(rec) if rec.get("hlo") and rec.get("arch") else \
                rec.get("roofline", {})
            out.append(f"| {name} | {d.get('compute_s', 0):.3f} | "
                       f"{d.get('memory_s', 0):.3f} | "
                       f"{d.get('collective_s', 0):.3f} | "
                       f"{d.get('dominant')} | {d.get('mfu', 0):.4f} |")
        print("\n".join(out))
    except FileNotFoundError:
        pass


if __name__ == "__main__":
    main()

"""Pure-JAX AdamW with f32 master weights, global-norm clipping and a
warmup-stable-decay schedule.  (No optax in this environment — the optimizer
is part of the substrate, per the assignment.)

State layout (all f32, sharded like the params by the launcher):
    {"master": params_f32, "m": ..., "v": ..., "count": int32}

``update`` returns the new *compute* params in the model dtype — the classic
mixed-precision arrangement (bf16 matmuls, f32 accumulation/update), which is
also what the dry-run memory accounting assumes (14 bytes/param).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # dtype for the m/v moments.  bf16 moments are the standard memory
    # lever for 100B+ models (master weights always stay f32).
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any, moment_dtype="float32") -> dict:
    md = jnp.dtype(moment_dtype)
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads: Any, state: dict,
           compute_dtype) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_compute_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    md = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step_ = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        p_new = p - lr * (step_ + cfg.weight_decay * p)
        return m_new.astype(md), v_new.astype(md), p_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(state["master"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_master = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(compute_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v,
                 "count": count}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics

"""Train step: loss, grads, microbatch accumulation, remat policy.

``make_train_step(cfg, opt_cfg, n_microbatches, remat)`` returns a pure
function ``(state, batch) -> (state, metrics)`` ready for ``jax.jit`` with
shardings.  Microbatching runs as a ``lax.scan`` over gradient accumulation
slices — the standard memory/throughput lever for the big dry-run cells.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, layers
from . import optimizer


def loss_fn(cfg: ModelConfig, params: dict, tokens: jax.Array,
            media: Optional[jax.Array] = None, *, remat: bool = False,
            remat_policy: str = "", rwkv_chunked: bool = False):
    """Next-token CE (+ MoE aux).  tokens [B, S]."""
    logits, aux, _ = forward(cfg, params, tokens, media, remat=remat,
                             remat_policy=remat_policy,
                             rwkv_chunked=rwkv_chunked)
    ce = layers.cross_entropy(logits[:, :-1, :], tokens[:, 1:])
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig,
                    opt_cfg: optimizer.AdamWConfig = optimizer.AdamWConfig(),
                    *, n_microbatches: int = 1, remat: bool = False,
                    remat_policy: str = "", rwkv_chunked: bool = False):
    compute_dtype = jnp.dtype(cfg.dtype)

    def grads_of(params, tokens, media):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, media, remat=remat,
                              remat_policy=remat_policy,
                              rwkv_chunked=rwkv_chunked),
            has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        tokens = batch["tokens"]
        media = batch.get("media")

        if n_microbatches == 1:
            loss, metrics, grads = grads_of(params, tokens, media)
        else:
            b = tokens.shape[0]
            mb = b // n_microbatches
            tok_mb = tokens.reshape(n_microbatches, mb, *tokens.shape[1:])
            med_mb = (media.reshape(n_microbatches, mb, *media.shape[1:])
                      if media is not None else None)

            def acc(carry, xs):
                g_acc, l_acc = carry
                t = xs[0]
                m = xs[1] if media is not None else None
                loss, _, grads = grads_of(params, t, m)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            xs = (tok_mb, med_mb) if media is not None else (tok_mb,)
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.float32(0)), xs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = {"ce": loss, "aux": jnp.float32(0)}

        new_params, new_opt, opt_metrics = optimizer.update(
            opt_cfg, grads, state["opt"], compute_dtype)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, params: dict,
                     opt_cfg: optimizer.AdamWConfig =
                     optimizer.AdamWConfig()) -> dict:
    return {"params": params,
            "opt": optimizer.init(params, opt_cfg.moment_dtype)}

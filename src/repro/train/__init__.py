from . import optimizer, serve_step, train_step
from .optimizer import AdamWConfig
from .serve_step import make_serve_step
from .train_step import init_train_state, loss_fn, make_train_step

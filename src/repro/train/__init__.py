from . import optimizer, train_step
from .optimizer import AdamWConfig
from .train_step import init_train_state, loss_fn, make_train_step

from . import pipeline
from .pipeline import DataConfig, batch_for_step

"""Deterministic, shard-aware, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step), so:
  * restart/resume needs no pipeline state (fault tolerance for free),
  * each data-parallel shard slices its rows deterministically,
  * repeated steps reproduce bit-identically (checkpoint-restart tests).

Two tasks:
  * ``lm``    — uniform random tokens (throughput/dry-run shape stand-in)
  * ``copy``  — second half of the sequence repeats the first half; a small
                model drives CE -> ~0, which the examples/tests use to prove
                training works end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    task: str = "copy"            # "copy" | "lm"
    vocab: int = 512
    seq_len: int = 64
    global_batch: int = 32
    seed: int = 0
    n_media_tokens: int = 0
    d_model: int = 0              # for media stubs


def batch_for_step(cfg: DataConfig, step: int,
                   shard: tuple[int, int] = (0, 1)) -> dict:
    """Batch for ``step``; ``shard=(rank, world)`` slices rows."""
    rank, world = shard
    assert cfg.global_batch % world == 0
    rows = cfg.global_batch // world
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    if cfg.task == "lm":
        toks = jax.random.randint(key, (cfg.global_batch, cfg.seq_len), 0,
                                  cfg.vocab, dtype=jnp.int32)
    elif cfg.task == "copy":
        half = cfg.seq_len // 2
        first = jax.random.randint(key, (cfg.global_batch, half), 2,
                                   cfg.vocab, dtype=jnp.int32)
        toks = jnp.concatenate([first, first], axis=1)
        if toks.shape[1] < cfg.seq_len:
            pad = jnp.ones((cfg.global_batch,
                            cfg.seq_len - toks.shape[1]), jnp.int32)
            toks = jnp.concatenate([toks, pad], axis=1)
    else:
        raise ValueError(cfg.task)
    batch = {"tokens": toks[rank * rows:(rank + 1) * rows]}
    if cfg.n_media_tokens:
        mkey = jax.random.fold_in(key, 1)
        media = jax.random.normal(
            mkey, (cfg.global_batch, cfg.n_media_tokens, cfg.d_model),
            jnp.float32)
        batch["media"] = media[rank * rows:(rank + 1) * rows]
    return batch

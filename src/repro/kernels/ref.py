"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitset

WORD = 32


def bitset_matmul_ref(a_packed: jax.Array, x: jax.Array) -> jax.Array:
    """OR_j (A[i,j] & X[j,:]) — dense oracle via unpack + int matmul."""
    m, kw = a_packed.shape
    k, w = x.shape
    a_bool = bitset.unpack_bits(a_packed, k)                # [M, K]
    x_bits = bitset.unpack_bits(x, w * WORD)                # [K, W*32]
    prod = jnp.dot(a_bool.astype(jnp.int32), x_bits.astype(jnp.int32)) > 0
    return bitset.pack_bits(prod)                           # [M, W]


def way_filter_ref(h_vtx, h_lab, v_vtx, v_lab, vbits, req, forb, null_plane):
    """Reference way-viability predicate (mirrors tdr_query phase 1)."""
    has_tgt = bitset.words_contain(h_vtx, vbits[:, None, :])
    has_req = bitset.words_contain(h_lab, req[:, None, :])
    real = v_lab & ~forb[:, None, None, :] & ~null_plane[None, None, None, :]
    blocked = jnp.all(real == 0, axis=-1)
    reached = bitset.words_contain(v_vtx, vbits[:, None, None, :])
    reached_upto = jnp.cumsum(reached.astype(jnp.int32), axis=-1) > 0
    not_before = jnp.concatenate(
        [jnp.ones_like(reached_upto[..., :1]), ~reached_upto[..., :-1]],
        axis=-1)
    refuted = jnp.any(blocked & not_before, axis=-1)
    return has_tgt & has_req & ~refuted


def popcount_rows_ref(words: jax.Array) -> jax.Array:
    return bitset.popcount(words)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitset

WORD = 32


def bitset_matmul_ref(a_packed: jax.Array, x: jax.Array) -> jax.Array:
    """OR_j (A[i,j] & X[j,:]) — dense oracle via unpack + int matmul."""
    m, kw = a_packed.shape
    k, w = x.shape
    a_bool = bitset.unpack_bits(a_packed, k)                # [M, K]
    x_bits = bitset.unpack_bits(x, w * WORD)                # [K, W*32]
    prod = jnp.dot(a_bool.astype(jnp.int32), x_bits.astype(jnp.int32)) > 0
    return bitset.pack_bits(prod)                           # [M, W]


def lane_matmul_ref(a_packed: jax.Array, x: jax.Array, *, op: str,
                    cap: int = 0) -> jax.Array:
    """``(+)_j (A[i,j] (x) X[j,:])`` over semiring carrier lanes.

    Dense oracle for ``bitset_matmul.lane_matmul``: unpack the adjacency
    bits and reduce along K with the lane combine (OR / min-with-INF /
    saturating sum).  Materializes an [M, K, W] transient — fine at the
    test/smoke scales the oracle runs at, not a production path.
    """
    m, kw = a_packed.shape
    k, w = x.shape
    a_bool = bitset.unpack_bits(a_packed, k)                # [M, K]
    sel = a_bool[:, :, None]                                # [M, K, 1]
    if op == "or":
        vals = jnp.where(sel, x[None], jnp.zeros((), x.dtype))
        return jax.lax.reduce(vals, jnp.zeros((), x.dtype),
                              jnp.bitwise_or, (1,))
    if op == "min":
        inf = jnp.array(jnp.iinfo(x.dtype).max, x.dtype)
        vals = jnp.where(sel, x[None], inf)
        return jnp.min(vals, axis=1)
    assert op == "sum", op
    # inputs are <= cap (the DP clamps every round), so a uint32 accumulator
    # cannot wrap before the clamp: K * cap <= 2^16 * (2^15-1) < 2^32
    vals = jnp.where(sel, x[None].astype(jnp.uint32), jnp.uint32(0))
    return jnp.minimum(jnp.sum(vals, axis=1),
                       jnp.uint32(cap)).astype(x.dtype)


def way_filter_ref(h_vtx, h_lab, v_vtx, v_lab, vbits, req, forb, null_plane):
    """Reference way-viability predicate (mirrors tdr_query phase 1)."""
    has_tgt = bitset.words_contain(h_vtx, vbits[:, None, :])
    has_req = bitset.words_contain(h_lab, req[:, None, :])
    real = v_lab & ~forb[:, None, None, :] & ~null_plane[None, None, None, :]
    blocked = jnp.all(real == 0, axis=-1)
    reached = bitset.words_contain(v_vtx, vbits[:, None, None, :])
    reached_upto = jnp.cumsum(reached.astype(jnp.int32), axis=-1) > 0
    not_before = jnp.concatenate(
        [jnp.ones_like(reached_upto[..., :1]), ~reached_upto[..., :-1]],
        axis=-1)
    refuted = jnp.any(blocked & not_before, axis=-1)
    return has_tgt & has_req & ~refuted


def popcount_rows_ref(words: jax.Array) -> jax.Array:
    return bitset.popcount(words)

"""Pallas TPU kernels for the TDR engine hot spots.

Each kernel module carries the ``pl.pallas_call`` + BlockSpec tiling;
``ops.py`` is the public jit'd surface (with interpret/ref fallbacks for
CPU) and ``ref.py`` the pure-jnp oracles the tests allclose against.
"""
from . import ops, ref
from .bitset_matmul import bitset_matmul
from .pattern_filter import way_filter
from .popcount import popcount_rows

__all__ = ["ops", "ref", "bitset_matmul", "way_filter", "popcount_rows"]

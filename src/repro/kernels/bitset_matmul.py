"""Boolean-OR-semiring bit-matmul Pallas kernel.

This is the compute hot-spot of the TPU-adapted TDR engine: one fixpoint
round of the closure build and one round of product-graph frontier expansion
are both

    out[i, w] = OR_j ( A[i, j]  AND  X[j, w] )

with ``A`` a packed adjacency bit-matrix (bit j of row i = edge i→j) and
``X`` packed reachability bitsets (32 graph columns per uint32 lane).  The
kernel runs on the VPU: each (TI, TW) tile accumulates TK selected-row ORs
consumed 32 columns at a time straight from the packed adjacency words
(see ``_kernel`` for the two inner forms), i.e. TI·TK·TW word-ops per tile
at 32 useful graph-bits per op — the arithmetic shape of a matmul without
an MXU contraction (OR is not ⊕ the MXU supports).  ``repro.kernels.ops`` also exposes an MXU variant that
unpacks to bf16 and thresholds a real matmul — see ARCHITECTURE.md
("Kernel lowerings") for the roofline comparison.

Both the index-build closure fixpoint and the query-side product-graph
expansion dispatch here when ``repro.core.engine`` selects the ``pallas``
backend (interpret mode off-TPU); see ARCHITECTURE.md for the layering.

Tiling: grid (M/TI, W/TW, K/TK); K is the innermost ("arbitrary") axis so
the output tile stays resident in VMEM while adjacency/frontier tiles
stream through.  VMEM per step = TI·TK/32·4 + TK·TW·4 + TI·TW·4 bytes
(defaults 128·128·4 ≈ 64 KiB + 2 KiB) — far under the ~16 MiB v5e VMEM,
leaving room for double-buffered pipelining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32

# jax renamed the TPU compiler-params container across releases
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or getattr(pltpu, "TPUCompilerParams"))


def _kernel(a_ref, x_ref, o_ref, *, tk: int):
    """One grid step: o[TI,TW] |= OR_j in TK (a_bit[i,j] & x[j,:]).

    Word-parallel bit-plane formulation: adjacency columns are consumed
    32 at a time straight from the packed words — ``0 - bit`` wraps a
    0/1 lane to an all-zeros/all-ones uint32 mask that gates a full
    ``[TI, TW]`` sheet of ``x`` into the accumulator.  The loop is a
    static unroll, not the former serial ``fori_loop`` of per-column
    dynamic slices, so the compiler sees one flat associative
    accumulation chain over the tile and fuses it into a single
    vectorized pass (measured 3–10× per round in interpret mode)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_words = a_ref[...]                       # [TI, TK//32] uint32
    x = x_ref[...]                             # [TK, TW]     uint32

    acc = jnp.zeros_like(o_ref[...])
    for wk in range(tk // WORD):               # static unroll over words
        col = a_words[:, wk]
        for b in range(WORD):                  # ...and their 32 lanes
            sel = jnp.uint32(0) - ((col >> jnp.uint32(b)) & 1)
            acc |= sel[:, None] & x[wk * WORD + b][None, :]
    o_ref[...] |= acc


@functools.partial(jax.jit,
                   static_argnames=("ti", "tk", "tw", "interpret"))
def bitset_matmul(a_packed: jax.Array, x: jax.Array, *, ti: int = 128,
                  tk: int = 128, tw: int = 128,
                  interpret: bool = False) -> jax.Array:
    """``OR_j (A[i,j] & X[j,:])`` over packed uint32 operands.

    Args:
      a_packed: uint32 [M, K//32] adjacency bit-rows.
      x:        uint32 [K, W] packed bitsets.
    Returns:
      uint32 [M, W].
    """
    m, kw = a_packed.shape
    k, w = x.shape
    assert kw * WORD == k, (a_packed.shape, x.shape)
    ti = min(ti, m) or 1
    tk = min(tk, k) or WORD
    tk = max(WORD, (tk // WORD) * WORD)
    tw = min(tw, w) or 1

    m_pad = -(-m // ti) * ti
    k_pad = -(-k // tk) * tk
    w_pad = -(-w // tw) * tw
    a_p = jnp.pad(a_packed, ((0, m_pad - m), (0, (k_pad - k) // WORD)))
    x_p = jnp.pad(x, ((0, k_pad - k), (0, w_pad - w)))

    grid = (m_pad // ti, w_pad // tw, k_pad // tk)
    out = pl.pallas_call(
        functools.partial(_kernel, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, tk // WORD), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tw), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((ti, tw), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, w_pad), jnp.uint32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_p, x_p)
    return out[:m, :w]


# ---------------------------------------------------------------------------
# lane-width-generic semiring variant
# ---------------------------------------------------------------------------
# Same streaming structure as ``_kernel`` — adjacency consumed 32 columns
# per packed word, a 0/1 bit wrapped to an all-ones lane mask — but the
# carrier ``x`` holds one semiring lane per element (uint8/uint16/uint32)
# instead of 32 packed graph bits, and the accumulation is the semiring
# combine:
#
#   or :  acc |= sel & x[j]            (identity 0)
#   min:  acc  = min(acc, x[j] | ~sel) (non-selected lanes become
#                                       dtype-max = INF; identity INF)
#   sum:  acc  = min(acc + (sel & x[j]), cap)
#                                      (identity 0; the per-step clamp is
#                                       exact — saturating add of
#                                       non-negative values is associative)
#
# All three forms are branch-free: selection is the same mask trick, with
# ``x | ~sel`` turning a de-selected lane into the min-identity.

_ACC_INIT = {"or": lambda dt: jnp.zeros((), dt),
             "min": lambda dt: jnp.array(jnp.iinfo(dt).max, dt),
             "sum": lambda dt: jnp.zeros((), dt)}


def _lane_kernel(a_ref, x_ref, o_ref, *, tk: int, op: str, cap: int):
    k_step = pl.program_id(2)
    dt = o_ref.dtype
    ident = _ACC_INIT[op](dt)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, ident)

    a_words = a_ref[...]                       # [TI, TK//32] uint32
    x = x_ref[...]                             # [TK, TW]     carrier lanes

    acc = jnp.full_like(o_ref[...], ident)
    for wk in range(tk // WORD):               # static unroll over words
        col = a_words[:, wk]
        for b in range(WORD):
            bit = ((col >> jnp.uint32(b)) & 1).astype(dt)
            sel = (jnp.zeros((), dt) - bit)[:, None]     # 0x00.. / 0xFF..
            row = x[wk * WORD + b][None, :]
            if op == "or":
                acc |= sel & row
            elif op == "min":
                acc = jnp.minimum(acc, row | ~sel)
            else:
                acc = jnp.minimum(acc + (sel & row), jnp.array(cap, dt))
    if op == "or":
        o_ref[...] |= acc
    elif op == "min":
        o_ref[...] = jnp.minimum(o_ref[...], acc)
    else:
        o_ref[...] = jnp.minimum(o_ref[...] + acc, jnp.array(cap, dt))


@functools.partial(jax.jit,
                   static_argnames=("op", "cap", "ti", "tk", "tw",
                                    "interpret"))
def lane_matmul(a_packed: jax.Array, x: jax.Array, *, op: str,
                cap: int = 0, ti: int = 128, tk: int = 128, tw: int = 128,
                interpret: bool = False) -> jax.Array:
    """``(+)_j (A[i,j] (x) X[j,:])`` — packed-bit adjacency, lane carrier.

    Args:
      a_packed: uint32 [M, K//32] adjacency bit-rows (bit j of row i).
      x:        [K, W] semiring carrier lanes (uint8/uint16/uint32).
      op:       lane combine — "or", "min" (identity dtype-max) or
                "sum" (saturating at ``cap``).
    Returns:
      [M, W] in ``x.dtype``.  Padding rows of ``a_packed`` have no bits
      set, so pad lanes never leak into real outputs regardless of op.
    """
    assert op in ("or", "min", "sum"), op
    m, kw = a_packed.shape
    k, w = x.shape
    assert kw * WORD == k, (a_packed.shape, x.shape)
    ti = min(ti, m) or 1
    tk = min(tk, k) or WORD
    tk = max(WORD, (tk // WORD) * WORD)
    tw = min(tw, w) or 1

    m_pad = -(-m // ti) * ti
    k_pad = -(-k // tk) * tk
    w_pad = -(-w // tw) * tw
    a_p = jnp.pad(a_packed, ((0, m_pad - m), (0, (k_pad - k) // WORD)))
    x_p = jnp.pad(x, ((0, k_pad - k), (0, w_pad - w)))

    grid = (m_pad // ti, w_pad // tw, k_pad // tk)
    out = pl.pallas_call(
        functools.partial(_lane_kernel, tk=tk, op=op, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, tk // WORD), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tw), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((ti, tw), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, w_pad), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_p, x_p)
    return out[:m, :w]

"""Fused phase-1 filter-cascade Pallas kernel.

Evaluates the per-(job, way) group-pruning predicate of ``tdr_query`` in a
single VPU pass over packed words — the query-side hot loop when millions of
PCR queries are screened per second:

    way_ok[j,g] =   (vbits[j] ⊆ H_vtx[j,g])            # target containment
                  ∧ (req[j]   ⊆ H_lab[j,g])            # required labels
                  ∧ ¬ ∃ℓ<k: blocked(j,g,ℓ) ∧ ¬reached_before(j,g,ℓ)

    blocked(j,g,ℓ)  = (V_lab[j,g,ℓ] ∧ ¬forb[j] ∧ ¬NULL) = ∅
    reached(j,g,ℓ)  =  vbits[j] ⊆ V_vtx[j,g,ℓ]

Inputs arrive pre-gathered per job (the ``u``-row gather is a plain XLA op
outside the kernel), so every ref is contiguous and the kernel is a pure
streaming elementwise+reduce pass: bytes dominate, arithmetic intensity
≈ 1 op/byte — firmly memory-bound, which is why fusing the whole cascade
into one pass (instead of 5 separate XLA reductions) is the win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hv_ref, hl_ref, vv_ref, vl_ref, vbits_ref, req_ref, forb_ref,
            null_ref, o_ref, *, k: int):
    hv = hv_ref[...]        # [TJ, G, Wv]
    hl = hl_ref[...]        # [TJ, G, Wl]
    vv = vv_ref[...]        # [TJ, G, k, Wv]
    vl = vl_ref[...]        # [TJ, G, k, Wl]
    vbits = vbits_ref[...]  # [TJ, Wv]
    req = req_ref[...]      # [TJ, Wl]
    forb = forb_ref[...]    # [TJ, Wl]
    null = null_ref[...]    # [1, Wl]

    has_tgt = jnp.all((hv & vbits[:, None, :]) == vbits[:, None, :], axis=-1)
    has_req = jnp.all((hl & req[:, None, :]) == req[:, None, :], axis=-1)

    real = vl & ~forb[:, None, None, :] & ~null[None, None, :, :]
    blocked = jnp.all(real == 0, axis=-1)                        # [TJ,G,k]
    reached = jnp.all((vv & vbits[:, None, None, :])
                      == vbits[:, None, None, :], axis=-1)       # [TJ,G,k]
    reached_upto = jnp.cumsum(reached.astype(jnp.int32), axis=-1) > 0
    not_before = jnp.concatenate(
        [jnp.ones_like(reached_upto[..., :1]), ~reached_upto[..., :-1]],
        axis=-1)
    refuted = jnp.any(blocked & not_before, axis=-1)             # [TJ, G]

    o_ref[...] = (has_tgt & has_req & ~refuted)


@functools.partial(jax.jit, static_argnames=("tj", "interpret"))
def way_filter(h_vtx: jax.Array, h_lab: jax.Array, v_vtx: jax.Array,
               v_lab: jax.Array, vbits: jax.Array, req: jax.Array,
               forb: jax.Array, null_plane: jax.Array, *, tj: int = 256,
               interpret: bool = False) -> jax.Array:
    """Fused way-viability predicate -> bool [J, G].

    All inputs packed uint32, already gathered per job:
      h_vtx [J,G,Wv] h_lab [J,G,Wl] v_vtx [J,G,k,Wv] v_lab [J,G,k,Wl]
      vbits [J,Wv] req/forb [J,Wl] null_plane [Wl]
    """
    j, g, wv = h_vtx.shape
    k = v_vtx.shape[2]
    wl = h_lab.shape[-1]
    tj = max(1, min(tj, j))
    j_pad = -(-j // tj) * tj

    def padj(x):
        return jnp.pad(x, ((0, j_pad - j),) + ((0, 0),) * (x.ndim - 1))

    grid = (j_pad // tj,)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tj, g, wv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tj, g, wl), lambda i: (i, 0, 0)),
            pl.BlockSpec((tj, g, k, wv), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tj, g, k, wl), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tj, wv), lambda i: (i, 0)),
            pl.BlockSpec((tj, wl), lambda i: (i, 0)),
            pl.BlockSpec((tj, wl), lambda i: (i, 0)),
            pl.BlockSpec((1, wl), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tj, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((j_pad, g), jnp.bool_),
        interpret=interpret,
    )(padj(h_vtx), padj(h_lab), padj(v_vtx), padj(v_lab), padj(vbits),
      padj(req), padj(forb), null_plane[None, :])
    return out[:j]

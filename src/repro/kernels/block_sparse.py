"""Block-sparse boolean-OR bit-matmul Pallas kernel.

Same contraction as ``bitset_matmul`` —

    out[i, w] = OR_j ( A[i, j]  AND  X[j, w] )

— but ``A`` arrives in the two-level block form of
``repro.core.compressed.BlockCompressed``: a 2-bit state per
``(row-block × word-block)`` tile (ALL_ZERO / ALL_ONE / MIXED) plus a
compacted pool holding only the MIXED detail blocks.  The kernel's grid
runs over ``(row-block, out-word tile, k-block)`` and per step

* **skips** the whole k-step when the A-block is ALL_ZERO *or* the
  corresponding X k-block carries no set bits this round (``x_any`` —
  the per-round frontier summary the delta fixpoint recomputes, which is
  what makes late closure rounds nearly free),
* **short-circuits** ALL_ONE blocks to a precomputed per-k-block
  column-OR of X (``col_or`` — a full block contributes the OR of its
  columns, no contraction needed),
* **gathers** MIXED blocks from the pool via scalar-prefetched slot ids
  (``pltpu.PrefetchScalarGridSpec``: the slot indirection is resolved in
  SMEM before the block's DMA is issued) and contracts them with the
  same static bit-unrolled VPU accumulation as the dense kernel.

States and slots are *inputs*, not statics, so one compiled closure
serves every round of a fixpoint while the frontier summary changes
underneath it.  ``block_sparse_matmul_ref`` is the pure-jnp oracle (and
the segment-family lowering): identical semantics via a gathered
batched unpack-matmul over pool blocks plus a segment-OR, bit-for-bit
equal to the dense ``ref.bitset_matmul_ref``.

Tile notes: the out tile is ``(br, TW)`` (``br`` defaults to 8, the
uint32 sublane minimum) and pool blocks are ``(br, bw)`` words — narrow
lanes relative to the 128-lane register shape, which interpret mode (CI)
does not care about; on hardware the pool would be laid out lane-padded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitset
from repro.core.compressed import ALL_ONE, MIXED, BlockCompressed

WORD = 32

_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or getattr(pltpu, "TPUCompilerParams"))


def _kernel(states_ref, slots_ref, xany_ref, pool_ref, x_ref, colr_ref,
            o_ref, *, bw: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    st = states_ref[i, k]
    live = xany_ref[k] != 0

    @pl.when(live & (st == ALL_ONE))
    def _one():
        o_ref[...] |= colr_ref[0][None, :]

    @pl.when(live & (st == MIXED))
    def _mixed():
        a = pool_ref[0]                        # [br, bw] uint32
        x = x_ref[...]                         # [bw*32, TW] uint32
        acc = jnp.zeros_like(o_ref[...])
        for wk in range(bw):                   # static bit-plane unroll
            col = a[:, wk]
            for b in range(WORD):
                sel = jnp.uint32(0) - ((col >> jnp.uint32(b))
                                       & jnp.uint32(1))
                acc |= sel[:, None] & x[wk * WORD + b][None, :]
        o_ref[...] |= acc


@functools.partial(jax.jit,
                   static_argnames=("br", "bw", "tw", "interpret"))
def _block_sparse_call(states, slots, xany, pool, x, colr, *, br: int,
                       bw: int, tw: int, interpret: bool):
    mb, kb = states.shape
    bk = bw * WORD
    w = x.shape[1]
    tw = min(tw, w) or 1
    w_pad = -(-w // tw) * tw
    x_p = jnp.pad(x, ((0, 0), (0, w_pad - w)))
    colr_p = jnp.pad(colr, ((0, 0), (0, w_pad - w)))

    grid = (mb, w_pad // tw, kb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                 # states, slots, x_any
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bw),
                         lambda i, j, k, st, sl, xa: (sl[i, k], 0, 0)),
            pl.BlockSpec((bk, tw), lambda i, j, k, st, sl, xa: (k, j)),
            pl.BlockSpec((1, tw), lambda i, j, k, st, sl, xa: (k, j)),
        ],
        out_specs=pl.BlockSpec((br, tw),
                               lambda i, j, k, st, sl, xa: (i, j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bw=bw),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mb * br, w_pad), jnp.uint32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(states.astype(jnp.int32), slots, xany, pool, x_p, colr_p)
    return out[:, :w]


def _pad_k(x: jax.Array, k_pad: int) -> jax.Array:
    if x.shape[0] < k_pad:
        x = jnp.concatenate(
            [x, jnp.zeros((k_pad - x.shape[0],) + x.shape[1:], x.dtype)],
            axis=0)
    return x


def _k_block_summaries(x: jax.Array, kb: int, bk: int):
    """Per-k-block column-OR and any-bit flags of the X operand."""
    xr = _pad_k(x, kb * bk).reshape(kb, bk, x.shape[1])
    colr = jax.lax.reduce(xr, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    xany = jnp.any(xr != 0, axis=(1, 2)).astype(jnp.int32)
    return colr, xany


def block_sparse_matmul(comp: BlockCompressed, x: jax.Array, *,
                        tw: int = 128,
                        interpret: bool = False) -> jax.Array:
    """``OR_j (A[i,j] & X[j,:])`` with A in block-compressed form.

    Args:
      comp: block states/slots/pool of the packed A ``[M, K//32]``.
      x:    uint32 ``[V, W]`` packed bitsets, ``V <= K`` (zero-padded).
    Returns:
      uint32 ``[M, W]`` — bit-identical to the dense kernel.
    """
    m, _ = comp.shape
    mb, kb = comp.grid
    bk = comp.bw * WORD
    colr, xany = _k_block_summaries(x, kb, bk)
    out = _block_sparse_call(comp.states, comp.slots, xany, comp.pool,
                             _pad_k(x, kb * bk), colr, br=comp.br,
                             bw=comp.bw, tw=tw, interpret=interpret)
    return out[:m]


# ----------------------------------------------- lane-width-generic variant
# Same two-level traversal, but X carries one semiring lane per element
# (uint8/uint16/uint32) instead of 32 packed bits, and the per-block
# short-circuits generalize: ALL_ZERO contributes the (+)-identity (skip),
# ALL_ONE contributes the k-block column-(+) of X, MIXED contracts the
# pool block with the lane combine.  ``op`` in {"or", "min", "sum"}; the
# min identity is dtype-max (INF) and sum saturates at ``cap``.

def _lane_ident(op: str, dt):
    if op == "min":
        return jnp.array(jnp.iinfo(dt).max, dt)
    return jnp.zeros((), dt)


def _lane_kernel(states_ref, slots_ref, xany_ref, pool_ref, x_ref, colr_ref,
                 o_ref, *, bw: int, op: str, cap: int):
    i = pl.program_id(0)
    k = pl.program_id(2)
    dt = o_ref.dtype
    ident = _lane_ident(op, dt)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, ident)

    st = states_ref[i, k]
    live = xany_ref[k] != 0

    @pl.when(live & (st == ALL_ONE))
    def _one():
        row = colr_ref[0][None, :]
        if op == "or":
            o_ref[...] |= row
        elif op == "min":
            o_ref[...] = jnp.minimum(o_ref[...], row)
        else:
            o_ref[...] = jnp.minimum(o_ref[...] + row, jnp.array(cap, dt))

    @pl.when(live & (st == MIXED))
    def _mixed():
        a = pool_ref[0]                        # [br, bw] uint32
        x = x_ref[...]                         # [bw*32, TW] carrier lanes
        acc = jnp.full_like(o_ref[...], ident)
        for wk in range(bw):                   # static bit-plane unroll
            col = a[:, wk]
            for b in range(WORD):
                bit = ((col >> jnp.uint32(b)) & jnp.uint32(1)).astype(dt)
                sel = (jnp.zeros((), dt) - bit)[:, None]
                row = x[wk * WORD + b][None, :]
                if op == "or":
                    acc |= sel & row
                elif op == "min":
                    acc = jnp.minimum(acc, row | ~sel)
                else:
                    acc = jnp.minimum(acc + (sel & row), jnp.array(cap, dt))
        if op == "or":
            o_ref[...] |= acc
        elif op == "min":
            o_ref[...] = jnp.minimum(o_ref[...], acc)
        else:
            o_ref[...] = jnp.minimum(o_ref[...] + acc, jnp.array(cap, dt))


@functools.partial(jax.jit,
                   static_argnames=("br", "bw", "tw", "op", "cap",
                                    "interpret"))
def _block_sparse_lane_call(states, slots, xany, pool, x, colr, *, br: int,
                            bw: int, tw: int, op: str, cap: int,
                            interpret: bool):
    mb, kb = states.shape
    w = x.shape[1]
    bk = bw * WORD
    tw = min(tw, w) or 1
    w_pad = -(-w // tw) * tw
    ident = _lane_ident(op, x.dtype)
    x_p = jnp.pad(x, ((0, 0), (0, w_pad - w)), constant_values=ident)
    colr_p = jnp.pad(colr, ((0, 0), (0, w_pad - w)), constant_values=ident)

    grid = (mb, w_pad // tw, kb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bw),
                         lambda i, j, k, st, sl, xa: (sl[i, k], 0, 0)),
            pl.BlockSpec((bk, tw), lambda i, j, k, st, sl, xa: (k, j)),
            pl.BlockSpec((1, tw), lambda i, j, k, st, sl, xa: (k, j)),
        ],
        out_specs=pl.BlockSpec((br, tw),
                               lambda i, j, k, st, sl, xa: (i, j)),
    )
    out = pl.pallas_call(
        functools.partial(_lane_kernel, bw=bw, op=op, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mb * br, w_pad), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(states.astype(jnp.int32), slots, xany, pool, x_p, colr_p)
    return out[:, :w]


def _pad_k_lanes(x: jax.Array, k_pad: int, op: str) -> jax.Array:
    """K-pad with the (+)-identity so pad rows cannot perturb any op.

    (The bit-selection already masks pad rows out for ZERO/MIXED blocks,
    but an ALL_ONE block spanning the pad region reduces over them.)"""
    if x.shape[0] < k_pad:
        pad = jnp.full((k_pad - x.shape[0],) + x.shape[1:],
                       _lane_ident(op, x.dtype), x.dtype)
        x = jnp.concatenate([x, pad], axis=0)
    return x


def _k_block_lane_summaries(x: jax.Array, kb: int, bk: int, op: str,
                            cap: int):
    """Per-k-block column-(+) and liveness flags of the lane operand."""
    xr = _pad_k_lanes(x, kb * bk, op).reshape(kb, bk, x.shape[1])
    ident = _lane_ident(op, x.dtype)
    if op == "or":
        colr = jax.lax.reduce(xr, jnp.zeros((), x.dtype),
                              jax.lax.bitwise_or, (1,))
    elif op == "min":
        colr = jnp.min(xr, axis=1)
    else:
        colr = jnp.minimum(jnp.sum(xr.astype(jnp.uint32), axis=1),
                           jnp.uint32(cap)).astype(x.dtype)
    xany = jnp.any(xr != ident, axis=(1, 2)).astype(jnp.int32)
    return colr, xany


def block_sparse_lane_matmul(comp: BlockCompressed, x: jax.Array, *,
                             op: str, cap: int = 0, tw: int = 128,
                             interpret: bool = False) -> jax.Array:
    """``(+)_j (A[i,j] (x) X[j,:])`` with A block-compressed, X in
    semiring carrier lanes.  Identical to ``lane_matmul`` on the
    decompressed adjacency."""
    m, _ = comp.shape
    mb, kb = comp.grid
    bk = comp.bw * WORD
    colr, xany = _k_block_lane_summaries(x, kb, bk, op, cap)
    out = _block_sparse_lane_call(
        comp.states, comp.slots, xany, comp.pool,
        _pad_k_lanes(x, kb * bk, op), colr, br=comp.br, bw=comp.bw,
        tw=tw, op=op, cap=cap, interpret=interpret)
    return out[:m]


def block_sparse_lane_matmul_ref(comp: BlockCompressed, x: jax.Array, *,
                                 op: str, cap: int = 0) -> jax.Array:
    """Pure-jnp oracle for ``block_sparse_lane_matmul``."""
    m, _ = comp.shape
    mb, kb = comp.grid
    br, bw = comp.br, comp.bw
    bk = bw * WORD
    w = x.shape[1]
    ident = _lane_ident(op, x.dtype)
    xr = _pad_k_lanes(x, kb * bk, op).reshape(kb, bk, w)
    colr, xany = _k_block_lane_summaries(x, kb, bk, op, cap)

    one = (comp.states == ALL_ONE) & (xany != 0)[None, :]
    one_vals = jnp.where(one[:, :, None], colr[None, :, :], ident)
    if op == "or":
        one_c = jax.lax.reduce(one_vals, jnp.zeros((), x.dtype),
                               jax.lax.bitwise_or, (1,))
    elif op == "min":
        one_c = jnp.min(one_vals, axis=1)
    else:
        one_c = jnp.minimum(jnp.sum(one_vals.astype(jnp.uint32), axis=1),
                            jnp.uint32(cap)).astype(x.dtype)

    def blk(a_blk, x_blk):                                # [br,bw],[bk,W]
        a_bool = bitset.unpack_bits(a_blk, bk)[:, :, None]
        if op == "or":
            vals = jnp.where(a_bool, x_blk[None], jnp.zeros((), x.dtype))
            return jax.lax.reduce(vals, jnp.zeros((), x.dtype),
                                  jax.lax.bitwise_or, (1,))
        if op == "min":
            return jnp.min(jnp.where(a_bool, x_blk[None], ident), axis=1)
        vals = jnp.where(a_bool, x_blk[None].astype(jnp.uint32),
                         jnp.uint32(0))
        return jnp.minimum(jnp.sum(vals, axis=1),
                           jnp.uint32(cap)).astype(x.dtype)

    contrib = jax.vmap(blk)(comp.pool, xr[comp.mix_bj])   # [P, br, W]
    flat = contrib.reshape(contrib.shape[0], br * w)
    if op == "or":
        mix = bitset.segment_or_words(flat, comp.mix_bi, num_segments=mb)
    elif op == "min":
        mix = jax.ops.segment_min(flat, comp.mix_bi, num_segments=mb)
    else:
        mix = jnp.minimum(
            jax.ops.segment_sum(flat.astype(jnp.uint32), comp.mix_bi,
                                num_segments=mb),
            jnp.uint32(cap)).astype(x.dtype)
    mix = mix.reshape(mb, br, w)
    if op == "or":
        out = mix | one_c[:, None, :]
    elif op == "min":
        out = jnp.minimum(mix, one_c[:, None, :])
    else:
        out = jnp.minimum(mix.astype(jnp.uint32)
                          + one_c[:, None, :].astype(jnp.uint32),
                          jnp.uint32(cap)).astype(x.dtype)
    return out.reshape(mb * br, w)[:m]


# ------------------------------------------------------------- jnp oracle
def block_sparse_matmul_ref(comp: BlockCompressed,
                            x: jax.Array) -> jax.Array:
    """Pure-jnp lowering of the same block-sparse contraction (the
    segment-family path): ONE blocks resolve through the k-block
    column-OR, MIXED blocks are gathered from the pool and contracted by
    a vmapped unpack-matmul, then segment-OR'd into their row-blocks."""
    m, _ = comp.shape
    mb, kb = comp.grid
    br, bw = comp.br, comp.bw
    bk = bw * WORD
    w = x.shape[1]
    xr = _pad_k(x, kb * bk).reshape(kb, bk, w)
    colr, xany = _k_block_summaries(x, kb, bk)

    one = (comp.states == ALL_ONE) & (xany != 0)[None, :]
    one_or = jax.lax.reduce(
        jnp.where(one[:, :, None], colr[None, :, :], jnp.uint32(0)),
        jnp.uint32(0), jax.lax.bitwise_or, (1,))         # [MB, W]

    def blk(a_blk, x_blk):                               # [br,bw],[bk,W]
        a_bool = bitset.unpack_bits(a_blk, bk)
        x_bits = bitset.unpack_bits(x_blk, w * WORD)
        prod = jnp.dot(a_bool.astype(jnp.int32),
                       x_bits.astype(jnp.int32)) > 0
        return bitset.pack_bits(prod)                    # [br, W]

    contrib = jax.vmap(blk)(comp.pool, xr[comp.mix_bj])  # [P, br, W]
    mix_or = bitset.segment_or_words(
        contrib.reshape(contrib.shape[0], br * w), comp.mix_bi,
        num_segments=mb).reshape(mb, br, w)
    out = (mix_or | one_or[:, None, :]).reshape(mb * br, w)
    return out[:m]

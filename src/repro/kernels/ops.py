"""Public jit'd wrappers for the TDR kernels.

On TPU these lower to the Pallas kernels; on CPU (this container) they run
the kernels in ``interpret=True`` mode, or — for the big batched call sites
where interpret-mode Python execution would dominate — the pure-jnp oracle,
which is numerically identical.  Selection is explicit so tests can force
either path; ``repro.core.engine`` maps its backend choice onto these modes
(the backend-selection contract is documented in ARCHITECTURE.md).

``frontier_step_mxu`` is the beyond-paper MXU lowering of the same semiring
step (unpack → bf16 matmul → threshold → repack): ARCHITECTURE.md ("Kernel
lowerings") compares its roofline against the VPU kernel.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.core import bitset
from . import block_sparse, ref
from .bitset_matmul import bitset_matmul, lane_matmul
from .pattern_filter import way_filter
from .popcount import popcount_rows

WORD = 32

# Trace-time invocation counter per kernel: incremented whenever a Pallas
# lowering (real or interpret) is routed to, i.e. whenever the kernel ends
# up in the compiled computation.  Tests assert on deltas to prove the
# kernels are load-bearing for a given engine backend.
KERNEL_INVOCATIONS: collections.Counter = collections.Counter()


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier_step(a_packed: jax.Array, x: jax.Array, *,
                  mode: str = "auto",
                  tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """One boolean-semiring expansion round: OR_j (A[i,j] & X[j,:]).

    mode: "auto" | "pallas" | "interpret" | "ref" | "mxu"
    tiles: optional (ti, tk, tw) override for the Pallas lowering, for
      callers and benchmarks that need to pin tile shapes.  The defaults
      already clamp to the operand (``ti = min(ti, m)`` etc.), so small
      operands collapse their grid without an override.
    """
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    tile_kw = dict(zip(("ti", "tk", "tw"), tiles)) if tiles else {}
    if mode == "pallas":
        KERNEL_INVOCATIONS["bitset_matmul"] += 1
        return bitset_matmul(a_packed, x, **tile_kw)
    if mode == "interpret":
        KERNEL_INVOCATIONS["bitset_matmul"] += 1
        return bitset_matmul(a_packed, x, interpret=True, **tile_kw)
    if mode == "mxu":
        return frontier_step_mxu(a_packed, x)
    if mode == "ref":
        return ref.bitset_matmul_ref(a_packed, x)
    raise ValueError(mode)


def frontier_step_lanes(a_packed: jax.Array, x: jax.Array, *, op: str,
                        cap: int = 0, mode: str = "auto",
                        tiles: tuple[int, int, int] | None = None
                        ) -> jax.Array:
    """One semiring expansion round over carrier *lanes* (uint8/16/32
    per element, not packed bits): ``(+)_j (A[i,j] (x) X[j,:])``.

    ``op`` selects the lane combine ("or" | "min" | "sum"); the min
    identity is dtype-max (= INF), sum saturates at ``cap``.  Same
    mode contract as ``frontier_step``.
    """
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    tile_kw = dict(zip(("ti", "tk", "tw"), tiles)) if tiles else {}
    if mode in ("pallas", "interpret"):
        KERNEL_INVOCATIONS["lane_matmul"] += 1
        return lane_matmul(a_packed, x, op=op, cap=cap,
                           interpret=(mode == "interpret"), **tile_kw)
    if mode == "ref":
        return ref.lane_matmul_ref(a_packed, x, op=op, cap=cap)
    raise ValueError(mode)


def frontier_step_sparse(comp, x: jax.Array, *,
                         mode: str = "auto") -> jax.Array:
    """Block-sparse expansion round over a ``BlockCompressed`` adjacency:
    ZERO blocks skipped, ONE blocks short-circuited to a column-OR, MIXED
    blocks gathered from the pool (see ``kernels.block_sparse``).

    mode: "auto" | "pallas" | "interpret" | "ref" — same contract as
    ``frontier_step``; "ref" is the pure-jnp segment-family lowering.
    """
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode in ("pallas", "interpret"):
        KERNEL_INVOCATIONS["block_sparse_matmul"] += 1
        return block_sparse.block_sparse_matmul(
            comp, x, interpret=(mode == "interpret"))
    if mode == "ref":
        return block_sparse.block_sparse_matmul_ref(comp, x)
    raise ValueError(mode)


@jax.jit
def frontier_step_mxu(a_packed: jax.Array, x: jax.Array) -> jax.Array:
    """MXU lowering: unpack to bf16, real matmul, threshold, repack.

    32× the bytes of the packed VPU path but contraction runs at MXU rate;
    wins when K (graph block) is reused across many frontier columns.
    """
    m, kw = a_packed.shape
    k, w = x.shape
    a_bool = bitset.unpack_bits(a_packed, k).astype(jnp.bfloat16)
    x_bits = bitset.unpack_bits(x, w * WORD).astype(jnp.bfloat16)
    y = jax.lax.dot_general(a_bool, x_bits, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return bitset.pack_bits(y > 0)


def filter_ways(h_vtx, h_lab, v_vtx, v_lab, vbits, req, forb, null_plane,
                *, mode: str = "auto") -> jax.Array:
    """Fused per-(job, way) viability predicate -> bool [J, G]."""
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "pallas":
        KERNEL_INVOCATIONS["way_filter"] += 1
        return way_filter(h_vtx, h_lab, v_vtx, v_lab, vbits, req, forb,
                          null_plane)
    if mode == "interpret":
        KERNEL_INVOCATIONS["way_filter"] += 1
        return way_filter(h_vtx, h_lab, v_vtx, v_lab, vbits, req, forb,
                          null_plane, interpret=True)
    if mode == "ref":
        return ref.way_filter_ref(h_vtx, h_lab, v_vtx, v_lab, vbits, req,
                                  forb, null_plane)
    raise ValueError(mode)


def popcount(words: jax.Array, *, mode: str = "auto") -> jax.Array:
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    if mode == "pallas":
        return popcount_rows(words)
    if mode == "interpret":
        return popcount_rows(words, interpret=True)
    if mode == "ref":
        return ref.popcount_rows_ref(words)
    raise ValueError(mode)

"""Population-count reduction Pallas kernel.

Used for way-saturation diagnostics (how full each Bloom way is — drives the
``succ_per_way`` rebalancing heuristic) and for index-size accounting.  A
pure streaming reduce: SWAR popcount per word, sum over the trailing word
axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    o_ref[...] = x.astype(jnp.int32).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("tr", "interpret"))
def popcount_rows(words: jax.Array, *, tr: int = 512,
                  interpret: bool = False) -> jax.Array:
    """Popcount over the trailing axis of uint32 [N, W] -> int32 [N]."""
    n, w = words.shape
    tr = max(1, min(tr, n))
    n_pad = -(-n // tr) * tr
    x = jnp.pad(words, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // tr,),
        in_specs=[pl.BlockSpec((tr, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:n]

#!/usr/bin/env python
"""Intra-repo markdown link checker for the docs CI job.

Scans the given markdown files for ``[text](target)`` links and fails on:

* relative-path targets that do not exist in the repo,
* ``#anchor`` fragments that match no heading in the target file
  (GitHub's slug rules: lowercase, punctuation stripped, spaces to
  hyphens),
* bare intra-repo file mentions in backticks that name a path under
  ``src/``/``tests/``/``benchmarks/``/``examples/`` which no longer
  exists (doc rot on renames).

External http(s)/mailto links are ignored — CI must not depend on the
network.

  python tools/check_links.py README.md ARCHITECTURE.md ROADMAP.md
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(
    r"`((?:src|tests|benchmarks|examples|tools)/[A-Za-z0-9_./-]+"
    r"\.(?:py|md|json|yml))`")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)      # drop punctuation (keep - and _)
    return s.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    return {slug(h) for h in HEADING.findall(path.read_text())}


def check(files: list) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    errors = []
    for name in files:
        path = (root / name).resolve()
        text = path.read_text()
        for target in LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            ref, _, frag = target.partition("#")
            tgt = path if not ref else (path.parent / ref).resolve()
            if ref and not tgt.exists():
                errors.append(f"{name}: dead link -> {target}")
                continue
            if frag and tgt.suffix == ".md" and frag not in anchors_of(tgt):
                errors.append(f"{name}: dead anchor -> {target}")
        for ref in CODE_PATH.findall(text):
            if not (root / ref).exists():
                errors.append(f"{name}: stale file mention -> `{ref}`")
    for e in errors:
        print(f"ERROR {e}", file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAILED' if errors else 'ok'} ({len(errors)} errors)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:] or
                   ["README.md", "ARCHITECTURE.md", "ROADMAP.md"]))

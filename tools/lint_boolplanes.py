"""Lint: the packed-at-rest invariant, greppably enforced.

PR 1 established — and the semiring refactor must preserve — that no
layer above the engine materializes full-width boolean planes: operands
are packed uint32 at rest, and the only unpacked transients live inside
the kernel lowerings and ``bitset.segment_or_words``'s bounded chunks.
Two call sites give the invariant away when it erodes, so CI greps for
them:

* ``bitset.segment_or(`` — the boolean-plane-*input* reference
  reduction.  It survives solely as a test oracle; any runtime module
  calling it is unpacking a plane.  Allowed only in its home module
  (``src/repro/core/bitset.py``), ``tests/`` and ``attic/``.

* ``unpack_bits(`` — the full-width jax unpacker.  Allowed in the
  kernel lowerings (``src/repro/kernels/``: the mxu/ref/block-sparse
  paths unpack *tiles* inside a kernel body), its home module, tests
  and attic.  Everything above the kernels must stay packed.

    python tools/lint_boolplanes.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

RULES = [
    # (pattern, allowed path prefixes, message)
    (re.compile(r"\bsegment_or\((?!\w)"),
     ("src/repro/core/bitset.py", "tests/", "attic/"),
     "bitset.segment_or is a test-only boolean-plane oracle; runtime "
     "code must use segment_or_words (packed) or a Semiring"),
    (re.compile(r"\bunpack_bits\("),
     ("src/repro/core/bitset.py", "src/repro/kernels/", "tests/",
      "attic/"),
     "full-width unpack_bits outside the kernel layer breaks the "
     "packed-at-rest invariant"),
]


def main() -> int:
    failures = []
    checked = 0
    for path in sorted(ROOT.rglob("*.py")):
        rel = path.relative_to(ROOT).as_posix()
        if rel.startswith((".git/", "tools/")):
            continue
        checked += 1
        text = path.read_text()
        for lineno, line in enumerate(text.splitlines(), 1):
            for rx, allowed, msg in RULES:
                if rx.search(line) and not rel.startswith(allowed):
                    failures.append(f"{rel}:{lineno}: {line.strip()}"
                                    f"\n    -> {msg}")
    if failures:
        print(f"packed-plane lint FAILED ({len(failures)} hit(s)):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"packed-plane lint: {checked} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Online PCR query serving: the micro-batching scheduler end-to-end.

Builds a TDR index, warms the server's jit bucket grid, then fires a
burst of concurrent clients at it — demonstrating the plan/result caches,
batch coalescing, and the zero-recompile steady state.  Answers are
hard-asserted against the DFS oracle.

The second act is durability: the server persists a checksummed snapshot
plus a write-ahead delta log, takes live updates, is abandoned without a
final checkpoint (a crash, as far as the on-disk state is concerned),
and a fresh process image recovers it — snapshot restore + log replay —
then answers queries on the post-update graph, re-checked against DFS.

  PYTHONPATH=src python examples/serve_queries.py
"""
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import dfs_baseline, engine, graph, tdr_build
from repro.launch.serve import QueryServer, mixed_pool, percentile

g = graph.erdos_renyi(1_000, 1.5, 8, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges}")
idx = tdr_build.build_index(g, tdr_build.TDRConfig())

pool = mixed_pool(g, 128)
oracle = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in pool]

with QueryServer(idx) as server:
    t0 = time.time()
    added = server.warmup(pool)
    print(f"warmup: {time.time() - t0:.1f}s, {added} jit variants "
          f"(the {{2^k, 3*2^(k-1)}} job-bucket grid)")

    n0 = engine.jit_cache_entries()
    lat, got = [], {}
    lock = threading.Lock()
    order = np.random.default_rng(1).permutation(
        np.tile(np.arange(len(pool)), 6))

    def client(ids):
        for i in ids:
            u, v, p = pool[int(i)]
            t = time.perf_counter()
            ans = server.submit(u, v, p).result()
            with lock:
                lat.append(time.perf_counter() - t)
                got.setdefault(int(i), ans)

    t0 = time.time()
    threads = [threading.Thread(target=client, args=(ids,))
               for ids in np.array_split(order, 16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0
    # oracle check in the main thread — an assert inside a client thread
    # would kill only that thread, not the script
    assert len(got) == len(pool)
    for i, ans in got.items():
        assert ans == oracle[i], (i, pool[i], ans, oracle[i])

    st = server.stats
    print(f"{len(order)} requests / 16 clients in {wall:.2f}s "
          f"= {len(order) / wall:.0f} q/s")
    print(f"p50={percentile(lat, 50) * 1e3:.1f}ms "
          f"p95={percentile(lat, 95) * 1e3:.1f}ms "
          f"p99={percentile(lat, 99) * 1e3:.1f}ms")
    print(f"batches={st.batches} mean_batch={st.mean_batch:.1f} "
          f"result_cache_hits={st.cache_hits} dedup={st.dedup_hits}")
    assert engine.jit_cache_entries() == n0, "steady state recompiled!"
    print("all answers match the DFS oracle; zero recompiles after warmup")

    # mixed-kind traffic: the same scheduler serves distances, witness
    # paths and route counts (kind rides in the request and the cache
    # key); warmup already pinned each kind's executor, so this burst
    # also compiles nothing
    dist_done = wit_done = 0
    for u, v, p in pool:
        if dist_done < 8:
            d = server.submit(u, v, p, kind="dist").result()
            assert d == dfs_baseline.shortest_pcr(g, u, v, p)
            dist_done += 1
        elif wit_done < 3:
            w = server.submit(u, v, p, kind="witness").result()
            want = dfs_baseline.shortest_pcr(g, u, v, p)
            assert (w is None) == (want < 0)
            if w is not None:
                assert len(w) == want
                assert dfs_baseline.verify_witness(g, u, v, p, w)
                wit_done += 1
        else:
            break
    from repro.core import pattern
    cq = next(q for q in pool if len(pattern.to_dnf(q[2])) == 1)
    c = server.submit(*cq, kind="count", hops=5).result()
    assert c == dfs_baseline.count_routes(g, *cq, hops=5, cap=32767)
    assert engine.jit_cache_entries() == n0, "mixed kinds recompiled!"
    print(f"mixed kinds: {dist_done} dist + {wit_done} witness + 1 count "
          "served, oracle-checked, still zero recompiles")

# ---- durability: persist → crash → recover ------------------------------
workdir = tempfile.mkdtemp(prefix="tdr-serve-demo-")
try:
    rng = np.random.default_rng(7)
    server = QueryServer(idx)
    server.start()
    snap_lsn = server.persist_to(workdir)
    print(f"\npersisted to {workdir}: snapshot at lsn={snap_lsn} + delta log")

    for k in range(3):
        u, v = int(rng.integers(g.n_vertices)), int(rng.integers(g.n_vertices))
        st = server.submit_update(edges_added=[(u, v, int(rng.integers(8)))])
        print(f"update {k + 1}: +edge ({u},{v}) mode={st.mode} "
              f"applied_lsn={server.stats.applied_lsn}")
    final_graph = server.index.graph

    # crash: stop serving and walk away — no checkpoint, no clean log
    # close.  Everything the recovery can use is what the write-ahead
    # ordering already fsync'd to disk before each update was acked.
    server.stop()
    del server
    print("crashed (no final checkpoint); on disk: "
          + ", ".join(sorted(os.listdir(workdir))))

    recovered = QueryServer.recover(workdir)
    assert recovered.stats.applied_lsn == 3, recovered.stats.applied_lsn
    assert recovered.index.graph.n_edges == final_graph.n_edges
    with recovered:
        check = mixed_pool(recovered.index.graph, 16)
        for u, v, p in check:
            want = dfs_baseline.answer_pcr(recovered.index.graph, u, v, p)
            assert recovered.submit(u, v, p).result() == want
    recovered.close_persistence()
    print(f"recovered at lsn={recovered.stats.applied_lsn} "
          f"(snapshot restore + log replay); {len(check)} post-crash "
          "answers match the DFS oracle on the updated graph")
finally:
    shutil.rmtree(workdir, ignore_errors=True)

"""Batched serving demo: prefill + incremental decode with KV/SSM caches.

  PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b
"""
import argparse

from repro.launch.serve import main as serve_main
import sys

if __name__ == "__main__":
    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "rwkv6-3b"]
    serve_main()

"""Quickstart: the paper's system in a screenful.

Build an edge-labeled digraph, construct the TDR index, answer
pattern-constrained reachability queries, then update the graph in
place — the incremental index maintenance is bit-identical to a
from-scratch rebuild.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import graph, pattern, tdr_build, tdr_query

# the paper's Fig. 2 graph: 10 vertices, labels a..e = 0..4
g = graph.fig2_example()
print(f"graph: |V|={g.n_vertices} |E|={g.n_edges} |labels|={g.n_labels}")

idx = tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=32, g_max=2,
                                                   k=2))
print(f"TDR index: {idx.size_bytes()} bytes, "
      f"{idx.fixpoint_rounds} fixpoint rounds")

queries = [
    (0, 5, pattern.parse("l1 & l3")),     # b AND d   (paper Example 1)
    (0, 4, pattern.none_of([0, 1])),      # NOT{a,b}  -> false
    (7, 4, pattern.none_of([0])),         # NOT{a}    (paper Example 3)
    (0, 6, pattern.parse("l1 & l4")),     # b AND e   -> true
    (0, 9, pattern.parse("(l0 | l4) & !l1")),
]
answers = tdr_query.answer_batch(idx, queries)
for (u, v, p), a in zip(queries, answers):
    print(f"  v{u} ->({p})-> v{v}: {'reachable' if a else 'unreachable'}")

# LCR is a special case of PCR
from repro.core import lcr
print("LCR (allowed={a,d}):",
      lcr.answer_lcr_batch(idx, [(0, 5, [0, 3])])[0])

# dynamic graphs: insert an edge and maintain the index incrementally
# (warm-started fixpoints + row-patched planes; bit-identical to a
# layout-pinned rebuild — see ARCHITECTURE.md §Dynamic updates)
delta = g.apply_updates(edges_added=[(4, 0, 3)])   # v4 -d-> v0
st = tdr_build.UpdateStats()
idx2 = tdr_build.update_index(idx, delta, stats=st)
print(f"update: +{st.n_added} edge ({st.mode}/{st.tail}, "
      f"{st.rounds} warm rounds, {st.patch_rows} rows patched)")
# 7 -(b AND d)-> 3 needed the new back-edge: false before, true after
q = (7, 3, pattern.parse("l1 & l3"))
print(f"  v7 ->(l1 & l3)-> v3: before={bool(tdr_query.answer_batch(idx, [q])[0])} "
      f"after={bool(tdr_query.answer_batch(idx2, [q])[0])}")

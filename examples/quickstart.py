"""Quickstart: the paper's system in 30 lines.

Build an edge-labeled digraph, construct the TDR index, answer
pattern-constrained reachability queries.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import graph, pattern, tdr_build, tdr_query

# the paper's Fig. 2 graph: 10 vertices, labels a..e = 0..4
g = graph.fig2_example()
print(f"graph: |V|={g.n_vertices} |E|={g.n_edges} |labels|={g.n_labels}")

idx = tdr_build.build_index(g, tdr_build.TDRConfig(vtx_bits=32, g_max=2,
                                                   k=2))
print(f"TDR index: {idx.size_bytes()} bytes, "
      f"{idx.fixpoint_rounds} fixpoint rounds")

queries = [
    (0, 5, pattern.parse("l1 & l3")),     # b AND d   (paper Example 1)
    (0, 4, pattern.none_of([0, 1])),      # NOT{a,b}  -> false
    (7, 4, pattern.none_of([0])),         # NOT{a}    (paper Example 3)
    (0, 6, pattern.parse("l1 & l4")),     # b AND e   -> true
    (0, 9, pattern.parse("(l0 | l4) & !l1")),
]
answers = tdr_query.answer_batch(idx, queries)
for (u, v, p), a in zip(queries, answers):
    print(f"  v{u} ->({p})-> v{v}: {'reachable' if a else 'unreachable'}")

# LCR is a special case of PCR
from repro.core import lcr
print("LCR (allowed={a,d}):",
      lcr.answer_lcr_batch(idx, [(0, 5, [0, 3])])[0])

"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --tiny     # smoke variant
"""
import argparse
import dataclasses
import tempfile

import repro.configs as C
from repro.checkpoint import Checkpointer
from repro.data import DataConfig
from repro.launch.train import train_loop
from repro.train import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()

    base = C.get("phi3-mini-3.8b")
    if args.tiny:
        cfg = base.reduced()
        steps = args.steps or 60
    else:
        # ~100M params: 12 layers, d=768 of the same family
        cfg = dataclasses.replace(
            base.reduced(), name="phi3-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=12, head_dim=64, d_ff=2048, vocab=8192)
        steps = args.steps or 300
    print(f"model: {cfg.name}  params≈{cfg.n_params()/1e6:.1f}M")

    dc = DataConfig(task="copy", vocab=cfg.vocab, seq_len=64,
                    global_batch=16)
    opt = AdamWConfig(lr=3e-3, warmup_steps=30, decay_steps=steps)
    with tempfile.TemporaryDirectory() as d:
        ckpt = Checkpointer(d, keep=2, async_save=True)
        train_loop(cfg, dc, opt, steps, ckpt, ckpt_every=100,
                   fail_at_step=args.fail_at_step, log_every=20)


if __name__ == "__main__":
    main()

"""PCR query-engine tour: pattern language, pruning stats, the sharded
distributed build + query, and the DFS-baseline comparison (paper
Tables III-style numbers at laptop scale).

  PYTHONPATH=src python examples/pcr_queries.py
"""
import time

import numpy as np

from repro.core import (dfs_baseline, distributed, graph, pattern,
                        tdr_build, tdr_query)

# sparse regime: the paper's datasets are sparse (most pairs unreachable),
# which is exactly where a refutation index shines
g = graph.erdos_renyi(2_000, 1.5, 8, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges}")

t0 = time.time()
idx = tdr_build.build_index(g, tdr_build.TDRConfig())
print(f"index build: {time.time()-t0:.2f}s, {idx.size_bytes()/1e3:.1f} KB, "
      f"{idx.fixpoint_rounds} rounds")

rng = np.random.default_rng(0)
queries = []
for i in range(100):
    u, v = int(rng.integers(2000)), int(rng.integers(2000))
    labs = rng.choice(8, size=3, replace=False).tolist()
    p = [pattern.all_of(labs[:2]), pattern.any_of(labs),
         pattern.none_of(labs[:2]),
         pattern.parse(f"(l{labs[0]} | l{labs[1]}) & !l{labs[2]}")][i % 4]
    queries.append((u, v, p))

# warm up jit once so timings reflect steady-state answering (the full
# set, so the corridor-compacted executor's chunk-shape buckets compile)
tdr_query.answer_batch(idx, queries)
stats = tdr_query.QueryStats()
t0 = time.time()
ans = tdr_query.answer_batch(idx, queries, stats=stats)
tdr_t = time.time() - t0
t0 = time.time()
oracle = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
dfs_t = time.time() - t0
assert ans.tolist() == oracle
print(f"100 mixed PCR queries: TDR {tdr_t*1e3:.0f}ms "
      f"vs DFS {dfs_t*1e3:.0f}ms ({dfs_t/tdr_t:.1f}x)")
print(f"pruning: {stats.filter_false}/{stats.n_jobs} jobs refuted by the "
      f"index, {stats.exact_jobs} needed exact search")

# --- beyond boolean: the semiring-generalized engine ---------------------
# The same packed planes and corridor machinery answer richer queries by
# swapping the carrier algebra: hop distances ((min,+) over saturating
# uint16 lanes), verified witness paths (parent pointers alongside the
# distance DP), and bounded route counts (saturating add).
dq = queries[:30]
tdr_query.dist_batch(idx, dq)             # warm the distance executor
t0 = time.time()
dists = tdr_query.dist_batch(idx, dq)
dist_t = time.time() - t0
t0 = time.time()
dist_oracle = [dfs_baseline.shortest_pcr(g, u, v, p) for u, v, p in dq]
dfs_d_t = time.time() - t0
assert dists.tolist() == dist_oracle
n_reach = int((dists >= 0).sum())
print(f"30 shortest-path queries: TDR {dist_t*1e3:.0f}ms vs DFS "
      f"{dfs_d_t*1e3:.0f}ms; {n_reach} reachable, "
      f"max dist {int(dists.max())}")

# k-hop-bounded variant: same compiled executor, k is a traced argument
d3 = tdr_query.dist_batch(idx, dq, k=3)
assert d3.tolist() == [d if 0 <= d <= 3 else -1 for d in dist_oracle]
print(f"k=3 bound: {int((d3 >= 0).sum())}/{n_reach} reachable pairs "
      "within 3 hops (no recompile — the bound is traced)")

# witness: an actual edge path realizing the shortest distance, replayed
# edge-by-edge against the graph and the pattern before it is returned
shown = 0
for (u, v, p), d in zip(dq, dists.tolist()):
    if d <= 0 or shown == 3:
        continue
    w = tdr_query.witness(idx, u, v, p)
    assert len(w) == d and dfs_baseline.verify_witness(g, u, v, p, w)
    hops = " -> ".join([str(w[0][0])] + [f"{y} (l{l})" for _, y, l in w])
    print(f"witness {u}->{v} [{pattern.canonical_key(p)}]: {hops}")
    shown += 1

# bounded route counting (single-DNF-term patterns; saturating at cap):
# count walks within a couple of hops past the shortest reachable pair
single = [(q, d) for q, d in zip(dq, dist_oracle)
          if len(pattern.to_dnf(q[2])) == 1 and d >= 0]
(u, v, p), d = min(single, key=lambda t: t[1])
hops = d + 2
c = tdr_query.count_routes(idx, u, v, p, hops=hops)
assert c == dfs_baseline.count_routes(g, u, v, p, hops=hops,
                                      cap=tdr_query.COUNT_CAP)
print(f"route count {u}->{v} within {hops} hops "
      f"(shortest is {d}): {c}")

# distributed build + query (all local devices here — 1 on a laptop, 8
# fake in tests/multidevice_check.py, 512 in the dry-run).  The sharded
# build is bit-identical to the single-device index; the per-round
# exchange ships packed uint32 words and converges via an all-reduced
# changed flag, so there is no round count to guess.
import jax
from jax.sharding import Mesh
mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
t0 = time.time()
idx_d = distributed.build_index(g, tdr_build.TDRConfig(), mesh=mesh)
dist_t = time.time() - t0
same = all(
    np.array_equal(np.asarray(getattr(idx_d, f)), np.asarray(getattr(idx, f)))
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"))
print(f"distributed build on {mesh.devices.size} device(s): {dist_t:.2f}s, "
      f"bit-identical={same}, {idx_d.fixpoint_rounds} converged rounds")
ans_d = distributed.answer_batch(idx_d, queries, mesh=mesh)
assert ans_d.tolist() == oracle
print("distributed answer_batch matches the DFS oracle")

"""PCR query-engine tour: pattern language, pruning stats, distributed
closure, and the DFS-baseline comparison (paper Tables III-style numbers
at laptop scale).

  PYTHONPATH=src python examples/pcr_queries.py
"""
import time

import numpy as np

from repro.core import (dfs_baseline, distributed, graph, pattern,
                        tdr_build, tdr_query)

# sparse regime: the paper's datasets are sparse (most pairs unreachable),
# which is exactly where a refutation index shines
g = graph.erdos_renyi(2_000, 1.5, 8, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges}")

t0 = time.time()
idx = tdr_build.build_index(g, tdr_build.TDRConfig())
print(f"index build: {time.time()-t0:.2f}s, {idx.size_bytes()/1e3:.1f} KB, "
      f"{idx.fixpoint_rounds} rounds")

rng = np.random.default_rng(0)
queries = []
for i in range(100):
    u, v = int(rng.integers(2000)), int(rng.integers(2000))
    labs = rng.choice(8, size=3, replace=False).tolist()
    p = [pattern.all_of(labs[:2]), pattern.any_of(labs),
         pattern.none_of(labs[:2]),
         pattern.parse(f"(l{labs[0]} | l{labs[1]}) & !l{labs[2]}")][i % 4]
    queries.append((u, v, p))

# warm up jit once so timings reflect steady-state answering (the full
# set, so the corridor-compacted executor's chunk-shape buckets compile)
tdr_query.answer_batch(idx, queries)
stats = tdr_query.QueryStats()
t0 = time.time()
ans = tdr_query.answer_batch(idx, queries, stats=stats)
tdr_t = time.time() - t0
t0 = time.time()
oracle = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
dfs_t = time.time() - t0
assert ans.tolist() == oracle
print(f"100 mixed PCR queries: TDR {tdr_t*1e3:.0f}ms "
      f"vs DFS {dfs_t*1e3:.0f}ms ({dfs_t/tdr_t:.1f}x)")
print(f"pruning: {stats.filter_false}/{stats.n_jobs} jobs refuted by the "
      f"index, {stats.exact_jobs} needed exact search")

# distributed build (1 device here; 512 fake devices in the dry-run)
import jax
from jax.sharding import Mesh
mesh = Mesh(np.array(jax.devices()).reshape(1,), ("data",))
_, _, disc = tdr_build.dfs_intervals(g)
rows = tdr_build._vertex_bit_rows(tdr_build.TDRConfig(), disc)
closure = distributed.distributed_closure(g, rows, mesh, rounds=24)
print(f"distributed closure: {closure.shape} packed words on "
      f"{mesh.devices.size} device(s)")

"""PCR query-engine tour: pattern language, pruning stats, the sharded
distributed build + query, and the DFS-baseline comparison (paper
Tables III-style numbers at laptop scale).

  PYTHONPATH=src python examples/pcr_queries.py
"""
import time

import numpy as np

from repro.core import (dfs_baseline, distributed, graph, pattern,
                        tdr_build, tdr_query)

# sparse regime: the paper's datasets are sparse (most pairs unreachable),
# which is exactly where a refutation index shines
g = graph.erdos_renyi(2_000, 1.5, 8, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges}")

t0 = time.time()
idx = tdr_build.build_index(g, tdr_build.TDRConfig())
print(f"index build: {time.time()-t0:.2f}s, {idx.size_bytes()/1e3:.1f} KB, "
      f"{idx.fixpoint_rounds} rounds")

rng = np.random.default_rng(0)
queries = []
for i in range(100):
    u, v = int(rng.integers(2000)), int(rng.integers(2000))
    labs = rng.choice(8, size=3, replace=False).tolist()
    p = [pattern.all_of(labs[:2]), pattern.any_of(labs),
         pattern.none_of(labs[:2]),
         pattern.parse(f"(l{labs[0]} | l{labs[1]}) & !l{labs[2]}")][i % 4]
    queries.append((u, v, p))

# warm up jit once so timings reflect steady-state answering (the full
# set, so the corridor-compacted executor's chunk-shape buckets compile)
tdr_query.answer_batch(idx, queries)
stats = tdr_query.QueryStats()
t0 = time.time()
ans = tdr_query.answer_batch(idx, queries, stats=stats)
tdr_t = time.time() - t0
t0 = time.time()
oracle = [dfs_baseline.answer_pcr(g, u, v, p) for u, v, p in queries]
dfs_t = time.time() - t0
assert ans.tolist() == oracle
print(f"100 mixed PCR queries: TDR {tdr_t*1e3:.0f}ms "
      f"vs DFS {dfs_t*1e3:.0f}ms ({dfs_t/tdr_t:.1f}x)")
print(f"pruning: {stats.filter_false}/{stats.n_jobs} jobs refuted by the "
      f"index, {stats.exact_jobs} needed exact search")

# distributed build + query (all local devices here — 1 on a laptop, 8
# fake in tests/multidevice_check.py, 512 in the dry-run).  The sharded
# build is bit-identical to the single-device index; the per-round
# exchange ships packed uint32 words and converges via an all-reduced
# changed flag, so there is no round count to guess.
import jax
from jax.sharding import Mesh
mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
t0 = time.time()
idx_d = distributed.build_index(g, tdr_build.TDRConfig(), mesh=mesh)
dist_t = time.time() - t0
same = all(
    np.array_equal(np.asarray(getattr(idx_d, f)), np.asarray(getattr(idx, f)))
    for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"))
print(f"distributed build on {mesh.devices.size} device(s): {dist_t:.2f}s, "
      f"bit-identical={same}, {idx_d.fixpoint_rounds} converged rounds")
ans_d = distributed.answer_batch(idx_d, queries, mesh=mesh)
assert ans_d.tolist() == oracle
print("distributed answer_batch matches the DFS oracle")

"""RPQ front-end tour: regex syntax, the index-expressible fragment and
its rewrite onto the DNF planner, the NFA-product executor for ordered
patterns, and mixed-kind serving — all oracle-checked.

  PYTHONPATH=src python examples/rpq_queries.py
"""
import time

import numpy as np

from repro.core import (dfs_baseline, engine as engine_mod, graph, pattern,
                        rpq, tdr_build, tdr_query)
from repro.launch.serve import QueryServer

g = graph.erdos_renyi(400, 4.0, 6, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges} L={g.n_labels}")
idx = tdr_build.build_index(g, tdr_build.TDRConfig())

# --- the regex language --------------------------------------------------
# Atoms are edge labels l0..l5; operators: concatenation (. or simple
# juxtaposition), alternation |, closures * + ?, grouping ().
r = rpq.parse("l0 . (l1 | l2)* . l3?")
print("parsed:", rpq.unparse(rpq.canonicalize(r)))

# Unions of single-atom stars are exactly the label-constrained
# fragment: (l0|l1)* is "a path using only labels {0,1}" — order-free,
# so it lowers onto the existing DNF plan and rides the TDR filter
# cascade with zero automaton work.  Anything order-sensitive routes to
# the Glushkov-NFA product executor instead.
for txt in ("(l0 | l1)*", "l0 . (l1 | l2)* . l3?"):
    rows = tdr_query.rpq_rows(idx, rpq.parse(txt))
    print(f"{txt!r:26} -> "
          + ("lowered to DNF plan" if rows.lowered is not None
             else f"NFA product route ({rows.nfa_states} Glushkov states)"))

# --- batch answering vs the product-graph oracle -------------------------
# Reachable (oracle-true) queries, like the tableIII rpq-true rows:
# that's where the product BFS actually has to walk the graph.
rng = np.random.default_rng(1)
qs = []
while len(qs) < 96:
    u, v = int(rng.integers(400)), int(rng.integers(400))
    a, b, c = rng.choice(6, size=3, replace=False).tolist()
    r2 = rpq.parse([f"(l{a} | l{b})*", f"l{a} . (l{b} | l{c})*",
                    f"(l{a} | l{b} | l{c})+", f"l{a} . l{b}"][len(qs) % 4])
    if dfs_baseline.answer_rpq(g, u, v, r2):
        qs.append((u, v, r2))

tdr_query.rpq_batch(idx, qs)              # warm the NFA-product shapes
t0 = time.time()
ans = tdr_query.rpq_batch(idx, qs)
rpq_t = time.time() - t0
t0 = time.time()
oracle = [dfs_baseline.answer_rpq(g, u, v, r) for u, v, r in qs]
dfs_t = time.time() - t0
assert ans.tolist() == oracle
print(f"96 RPQs: TDR {rpq_t*1e3:.0f}ms vs product-BFS {dfs_t*1e3:.0f}ms "
      f"({dfs_t/max(rpq_t, 1e-9):.1f}x), all oracle-correct")

# --- mixed-kind serving --------------------------------------------------
# One server answers bool / rpq traffic off the same micro-batch loop;
# warmup() pre-compiles every executor so live traffic never jits.
warm = [(int(rng.integers(400)), int(rng.integers(400)),
         pattern.any_of([0, 1])), (3, 3, pattern.all_of([2]))]
with QueryServer(idx, max_wait_ms=1.0) as srv:
    srv.warmup(warm)
    n0 = engine_mod.jit_cache_entries()
    futs = [srv.submit(u, v, r, kind="rpq") for (u, v, r) in qs[:8]]
    futs.append(srv.submit(*warm[0][:2], warm[0][2]))
    got = [f.result(timeout=120) for f in futs]
    assert got[:8] == oracle[:8]
    print("serving: 8 rpq + 1 bool answered,",
          f"{engine_mod.jit_cache_entries() - n0} recompiles after warmup")
print("rpq tour OK")

"""Replicated PCR serving: writer → shared delta log → replica fleet.

Builds a TDR index, publishes it as a shared store (snapshot + delta
log), then brings up a **fleet of replica processes** behind a router:

* the single ``FleetWriter`` publishes edge deltas to the log — append
  is the commit point;
* each replica bootstraps from the newest snapshot, tails the log
  through ``update_index``, and advertises its applied LSN over
  heartbeats;
* the ``FleetRouter`` load-balances reads, and a **consistent read**
  (``min_lsn=L``) is only answered by an index at or past L — the
  answer comes back stamped with the exact LSN it was computed at.

Every answer is hard-asserted against the DFS oracle of the graph *at
that stamped LSN*, including while a replica is SIGKILLed mid-traffic
(the fleet evicts it, the router re-dispatches its in-flight requests,
and a replacement re-spawns from the snapshot).

  PYTHONPATH=src python examples/serve_fleet.py
"""
import shutil
import tempfile
import time

import numpy as np

from repro.core import dfs_baseline, graph, tdr_build
from repro.launch import fleet
from repro.launch.router import FleetRouter
from repro.launch.serve import mixed_pool

g = graph.erdos_renyi(600, 1.5, 8, seed=0)
print(f"ER graph |V|={g.n_vertices} |E|={g.n_edges}")
idx = tdr_build.build_index(g, tdr_build.TDRConfig())

workdir = tempfile.mkdtemp(prefix="tdr-fleet-demo-")
try:
    fleet.init_store(idx, workdir)
    writer = fleet.FleetWriter(workdir)
    print(f"shared store at {workdir}: snapshot + delta log, "
          f"writer at lsn={writer.last_lsn}")

    pool = mixed_pool(g, 48)
    graphs = {0: g}                      # graph as of each published LSN

    with fleet.Fleet(workdir, n=2, hb_s=0.2) as flt:
        router = FleetRouter(flt)
        t0 = time.time()
        flt.warm(pool)
        print(f"2 replica processes up + warm in {time.time() - t0:.1f}s, "
              f"fleet at lsn={flt.max_lsn()}")

        # load-balanced reads, each validated at its stamped LSN
        t0 = time.time()
        futs = [(u, v, p, router.submit(u, v, p)) for u, v, p in pool]
        for u, v, p, f in futs:
            ans, lsn = f.result(timeout=300)
            assert ans == dfs_baseline.answer_pcr(graphs[lsn], u, v, p)
        print(f"{len(futs)} answers in {time.time() - t0:.2f}s, "
              "all equal to the DFS oracle at their read LSN")

        # live updates: publish, then read *consistently* at the new LSN
        rng = np.random.default_rng(7)
        for _ in range(3):
            u, v = (int(rng.integers(g.n_vertices)),
                    int(rng.integers(g.n_vertices)))
            lsn = writer.publish([(u, v, int(rng.integers(8)))], [])
            graphs[lsn] = writer.graph
        tip = writer.last_lsn
        futs = [(u, v, p, router.submit(u, v, p, min_lsn=tip,
                                        lsn_timeout=240))
                for u, v, p in pool[:12]]
        for u, v, p, f in futs:
            ans, lsn = f.result(timeout=300)
            assert lsn >= tip, "consistent read served by a stale index"
            assert ans == dfs_baseline.answer_pcr(graphs[lsn], u, v, p)
        print(f"3 deltas published; {len(futs)} consistent reads at "
              f"lsn>={tip} match the oracle on the updated graph")

        # kill a replica mid-traffic: eviction + re-dispatch + re-spawn
        victim = flt.members()[0]
        futs = [(u, v, p, router.submit(u, v, p, min_lsn=tip,
                                        lsn_timeout=240))
                for u, v, p in pool]
        victim.kill()
        for u, v, p, f in futs:
            ans, lsn = f.result(timeout=300)
            assert ans == dfs_baseline.answer_pcr(graphs[lsn], u, v, p)
        deadline = time.time() + 120
        while len(flt.members()) < 2 and time.time() < deadline:
            time.sleep(0.1)
        assert len(flt.members()) == 2, "replacement replica never came up"
        print(f"replica SIGKILLed mid-stream: {len(futs)} in-flight + "
              f"subsequent answers all correct "
              f"(re-dispatched={router.redispatched}), victim evicted "
              f"and re-spawned from the snapshot")
    writer.close()
    print("fleet demo OK")
finally:
    shutil.rmtree(workdir, ignore_errors=True)

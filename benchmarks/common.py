"""Shared benchmark machinery: paper-protocol query generation + timing.

The paper's protocol (§VI-A): per dataset, 2k true- + 2k false-queries per
operator family (AND / OR / NOT / LCR) with |labels| = 2 (small-|ζ| sets)
or 4.  This module reproduces the generator at configurable scale (the
container is a single CPU, so the default scale is reduced; pass
``--scale full`` for paper-sized graphs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import dfs_baseline, graph as G, pattern as pat
from repro.core import tdr_build, tdr_query

SCALES = {
    # n_vertices for synthetic sweeps; queries per set
    "smoke": {"v": 400, "queries": 30, "d": [2, 4], "labels": [4, 8],
              "scal_v": [200, 400]},
    "small": {"v": 2_000, "queries": 100, "d": [2, 4, 6, 8],
              "labels": [8, 16, 32], "scal_v": [500, 1_000, 2_000, 4_000]},
    "full": {"v": 200_000, "queries": 2_000, "d": [2, 4, 6, 8],
             "labels": [8, 16, 32, 64],
             "scal_v": [200_000, 400_000, 600_000, 1_000_000]},
}


@dataclasses.dataclass
class QuerySet:
    name: str
    queries: list        # [(u, v, pattern)]
    truth: list          # oracle answers


def make_query_sets(g: G.Graph, n_per_set: int, n_labels_in_query: int,
                    seed: int = 0) -> dict:
    """AND/OR/NOT/LCR true+false query sets following the paper's §VI-A."""
    rng = np.random.default_rng(seed)
    sets: dict[str, QuerySet] = {}
    makers = {
        "AND": lambda labs: pat.all_of(labs),
        "OR": lambda labs: pat.any_of(labs),
        "NOT": lambda labs: pat.none_of(labs),
        "LCR": lambda labs: pat.lcr(labs, g.n_labels),
    }
    for fam, mk in makers.items():
        true_q, false_q = [], []
        tries = 0
        while (len(true_q) < n_per_set or len(false_q) < n_per_set) \
                and tries < n_per_set * 300:
            tries += 1
            u = int(rng.integers(g.n_vertices))
            v = int(rng.integers(g.n_vertices))
            k = min(n_labels_in_query, g.n_labels)
            labs = rng.choice(g.n_labels, size=k, replace=False).tolist()
            p = mk(labs)
            ans = dfs_baseline.answer_pcr(g, u, v, p)
            if ans and len(true_q) < n_per_set:
                true_q.append((u, v, p))
            elif not ans and len(false_q) < n_per_set:
                false_q.append((u, v, p))
        sets[f"{fam}-true"] = QuerySet(f"{fam}-true", true_q,
                                       [True] * len(true_q))
        sets[f"{fam}-false"] = QuerySet(f"{fam}-false", false_q,
                                        [False] * len(false_q))
    return sets


def time_call(fn: Callable, *args, repeat: int = 1, **kw):
    """(result, seconds) — min over repeats, first call excluded if >1."""
    best = float("inf")
    out = None
    for i in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return out, best


def time_tdr(idx, qs: QuerySet, repeat: int = 2, backend: str | None = None,
             stats: "tdr_query.QueryStats | None" = None):
    """TDR batch answering time (jit warm on first repeat); ``backend``
    selects the packed-word engine backend (None = engine default).
    ``stats`` (if given) collects the *final* timed call's executor
    counters — rounds, corridor occupancy, phase-1/phase-2 split — so
    stats collection costs no extra call."""
    best = float("inf")
    ans = None
    for i in range(repeat):
        t0 = time.perf_counter()
        ans = tdr_query.answer_batch(
            idx, qs.queries, backend=backend,
            stats=stats if i == repeat - 1 else None)
        best = min(best, time.perf_counter() - t0)
    correct = ans.tolist() == qs.truth
    return best, correct


def time_dfs(g, qs: QuerySet):
    stats = dfs_baseline.SearchStats()
    t0 = time.perf_counter()
    for (u, v, p) in qs.queries:
        dfs_baseline.answer_pcr(g, u, v, p, stats)
    return time.perf_counter() - t0, stats


def emit(rows: list, header: Sequence[str]):
    print(",".join(header))
    for row in rows:
        print(",".join(str(x) for x in row))
    print()

"""Paper Table IV: indexing time and space — TDR vs P2H-lite full index.

P2H-lite (the full-closure baseline) only builds on small graphs — exactly
the paper's point about full LCR indices not scaling.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G, lcr, tdr_build
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        g = G.random_graph(kind, sc["v"], 4.0, 8, seed=seed)
        t0 = time.perf_counter()
        idx = tdr_build.build_index(g, tdr_build.TDRConfig())
        tdr_t = time.perf_counter() - t0
        rows.append((f"tableIV/{kind}/TDR-index",
                     round(tdr_t * 1e6, 1),
                     f"bytes={idx.size_bytes()};"
                     f"rounds={idx.fixpoint_rounds}"))
        # full index only feasible on a small sub-scale graph (paper: P2H+
        # times out / OOMs on the large datasets)
        g_small = G.random_graph(kind, min(sc["v"], 300), 2.0, 4, seed=seed)
        t0 = time.perf_counter()
        full = lcr.P2HLite.build(g_small)
        full_t = time.perf_counter() - t0
        t0 = time.perf_counter()
        idx_small = tdr_build.build_index(g_small, tdr_build.TDRConfig())
        tdr_small_t = time.perf_counter() - t0
        rows.append((f"tableIV/{kind}/P2HLite-vs-TDR@{g_small.n_vertices}",
                     round(full_t * 1e6, 1),
                     f"tdr_us={tdr_small_t * 1e6:.0f};"
                     f"full_bytes={full.size_bytes()};"
                     f"tdr_bytes={idx_small.size_bytes()};"
                     f"space_ratio={full.size_bytes() / max(idx_small.size_bytes(), 1):.1f}x"))
    return rows

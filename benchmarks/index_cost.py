"""Paper Table IV: indexing time and space — TDR vs P2H-lite full index,
plus the compressed-plane footprint and the sparse-closure build rows.

P2H-lite (the full-closure baseline) only builds on small graphs — exactly
the paper's point about full LCR indices not scaling.

PR-6 additions:

* ``tableIV/{kind}/index-bytes`` — dense vs two-level-compressed bytes of
  every index plane (``TDRIndex.index_memory_stats``) with the build wall
  time as ``us_per_call``; the guard gates both the byte count (directly —
  bytes are deterministic, no drift normalization) and the build time
  (drift-normalized, like every other timing row).
* ``tableIV/{kind}/closure{n}-sparse`` / ``-dense`` — the engine closure
  fixpoint with and without the sparse path (block-compressed adjacency
  on ``pallas``, frontier-compacted gathers on ``segment``) at n=512 and
  the largest smoke closure scale.  The sparse row is the gated one; the
  dense row rides along for the speedup denominator.  Results are
  asserted bit-identical in-process.  pallas-on-CPU runs the kernels in
  interpret mode where per-grid-step dispatch dominates (the engine's
  default policy routes those closures dense for exactly that reason), so
  its rows carry ``gated: false``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_mod, graph as G, lcr, tdr_build
from . import common

# closure-row scales: the small anchor (sparse must not lose to dense
# there) and the largest smoke-scale closure (sparse must win there)
CLOSURE_NS = (512, 2048)
# in-process floors, with slack under the measured margins (1.08x /
# 1.30x on this container) so shared-host noise cannot flake the guard
MIN_SPEEDUP_SMALL, MIN_SPEEDUP_LARGE = 0.75, 0.9
MIN_RATIO = 4.0          # acceptance: >=4x compression on smoke graphs


def _interpret(backend: str | None) -> bool:
    return (engine_mod.resolve_backend(backend or "auto") == "pallas"
            and jax.default_backend() != "tpu")


def _time_closure(eng, base, sparse):
    (r, rounds) = eng.closure(base, sparse=sparse)   # warm jit variants
    np.asarray(r)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        r2, _ = eng.closure(base, sparse=sparse)
        np.asarray(r2)
        best = min(best, time.perf_counter() - t0)
    return best, int(rounds), np.asarray(r)


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    interp = _interpret(backend)
    flag = {"gated": False} if interp else {}
    rows = []
    for kind in ("er", "pa"):
        g = G.random_graph(kind, sc["v"], 4.0, 8, seed=seed)
        t0 = time.perf_counter()
        idx = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                    backend=backend)
        tdr_t = time.perf_counter() - t0
        rows.append((f"tableIV/{kind}/TDR-index",
                     round(tdr_t * 1e6, 1),
                     f"bytes={idx.size_bytes()};"
                     f"rounds={idx.fixpoint_rounds}"))

        # ---- two-level compressed plane footprint -----------------------
        mem = idx.index_memory_stats()
        if mem["ratio"] < MIN_RATIO:
            raise RuntimeError(
                f"index-bytes/{kind}: compression ratio {mem['ratio']:.2f}x "
                f"below the {MIN_RATIO}x floor")
        rows.append((f"tableIV/{kind}/index-bytes",
                     round(tdr_t * 1e6, 1),
                     f"dense_bytes={mem['dense_bytes']};"
                     f"compressed_bytes={mem['compressed_bytes']};"
                     f"ratio={mem['ratio']:.2f}",
                     dict(flag)))

        # ---- sparse vs dense closure fixpoint ---------------------------
        for n in CLOSURE_NS:
            gc = G.random_graph(kind, n, 4.0, 8, seed=seed)
            eng = engine_mod.Engine(
                gc, engine_mod.EngineConfig(backend=backend))
            _, _, disc = tdr_build.dfs_intervals(gc)
            base = eng.propagate(jnp.asarray(
                tdr_build._vertex_bit_words(tdr_build.TDRConfig(), disc)))
            t_dense, rounds, r_dense = _time_closure(eng, base, False)
            # None = the engine's default policy (what builds actually
            # run): sparse on segment / TPU-pallas, dense under interpret
            t_sparse, _, r_sparse = _time_closure(eng, base, None)
            if (r_dense != r_sparse).any():
                raise RuntimeError(
                    f"closure{n}/{kind}: sparse closure diverged from "
                    "dense — bit-identity contract broken")
            speedup = t_dense / t_sparse
            floor = (MIN_SPEEDUP_SMALL if n == min(CLOSURE_NS)
                     else MIN_SPEEDUP_LARGE)
            if not interp and speedup < floor:
                raise RuntimeError(
                    f"closure{n}/{kind}: sparse fixpoint is only "
                    f"{speedup:.2f}x dense (floor {floor}x) — the sparse "
                    "path has regressed")
            rows.append((f"tableIV/{kind}/closure{n}-sparse",
                         round(t_sparse * 1e6, 1),
                         f"dense_us={t_dense * 1e6:.1f};"
                         f"speedup={speedup:.2f};rounds={rounds};"
                         f"correct=True",
                         dict(flag)))
            rows.append((f"tableIV/{kind}/closure{n}-dense",
                         round(t_dense * 1e6, 1),
                         f"rounds={rounds}"))

        # full index only feasible on a small sub-scale graph (paper: P2H+
        # times out / OOMs on the large datasets)
        g_small = G.random_graph(kind, min(sc["v"], 300), 2.0, 4, seed=seed)
        t0 = time.perf_counter()
        full = lcr.P2HLite.build(g_small)
        full_t = time.perf_counter() - t0
        t0 = time.perf_counter()
        idx_small = tdr_build.build_index(g_small, tdr_build.TDRConfig(),
                                          backend=backend)
        tdr_small_t = time.perf_counter() - t0
        rows.append((f"tableIV/{kind}/P2HLite-vs-TDR@{g_small.n_vertices}",
                     round(full_t * 1e6, 1),
                     f"tdr_us={tdr_small_t * 1e6:.0f};"
                     f"full_bytes={full.size_bytes()};"
                     f"tdr_bytes={idx_small.size_bytes()};"
                     f"space_ratio={full.size_bytes() / max(idx_small.size_bytes(), 1):.1f}x"))
    return rows

"""Replicated-fleet benchmark: closed-loop saturation over N replicas.

Drives the multi-process serving tier (``launch.fleet`` +
``launch.router``) the way the single-process serving suite drives
``QueryServer``: a closed loop of concurrent clients, each submitting
its next query when the previous answer lands, against a router over
N = 1/2/4 replica processes sharing one snapshot + delta log.  Reports
sustained q/s and per-request p50/p95/p99 per replica count, plus a
**write-while-read consistency row**: a writer publishes deltas
mid-stream and *every* answer is checked against the DFS oracle at the
answer's stamped read LSN — zero wrong answers is the contract, at any
replica count, under concurrent replication.

Gating (``benchmarks.guard``): the ``serving/fleet/n*/closed-p95`` rows
ride the standard drift-normalized ``/closed-p95`` gate, and N=2 must
clear ``MIN_SCALING`` x the N=1 throughput — but only where the host
can physically show it: replica scaling needs real cores
(``os.cpu_count() >= 4``; a 1-core container timeslices the replicas
and N=2 ~= N=1) and real kernels (the pallas-interpret leg is
Python-dominated, as in ``benchmarks.serving``).  Legs that fail either
precondition carry ``"gated": false`` on the row itself — same
mechanism as the serving interpret carve-out — and report q/s without
failing the build.  Correctness (oracle equality at the read LSN)
asserts unconditionally everywhere.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.core import dfs_baseline, engine as engine_mod, graph as G
from repro.core import tdr_build
from repro.launch import fleet as fleet_mod
from repro.launch.router import FleetRouter

from . import common, serving

CLIENTS = 8             # closed-loop concurrency (per fleet size)
REQUESTS_PER_CLIENT = 6
MIN_SCALING = 1.1       # N=2 over N=1 q/s floor (gated legs only)
N_SWEEP = {"smoke": (1, 2), "small": (1, 2, 4), "full": (1, 2, 4)}
N_PUBLISHES = 4         # write-while-read: deltas published mid-stream


def _closed_loop(router, pool, truth_at, rng):
    """CLIENTS threads, each replaying a shard of the shuffled pool;
    every answer is validated at its own read LSN via ``truth_at``."""
    n_req = CLIENTS * REQUESTS_PER_CLIENT
    order = rng.permutation(
        np.tile(np.arange(len(pool)),
                n_req // len(pool) + 1))[:n_req]
    shards = np.array_split(order, CLIENTS)
    lat, wrong = [], []
    lock = threading.Lock()

    def client(ids):
        for i in ids:
            u, v, p = pool[int(i)]
            t0 = time.perf_counter()
            ans, lsn = router.submit(u, v, p).result(timeout=600)
            dt = time.perf_counter() - t0
            want = truth_at(int(i), lsn)
            with lock:
                lat.append(dt)
                if ans != want:
                    wrong.append((int(i), lsn, ans, want))

    threads = [threading.Thread(target=client, args=(s,)) for s in shards]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return len(order) / wall, lat, wrong


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    g = G.random_graph("er", sc["v"], 4.0, 8, seed=seed)
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(), backend=backend)
    pool, truth = serving._pool(g, max(8, sc["queries"] // 3), seed)
    rng = np.random.default_rng(seed + 1)

    # DFS drift anchor (same pure-python code on every host)
    t0 = time.perf_counter()
    for (u, v, p) in pool:
        dfs_baseline.answer_pcr(g, u, v, p)
    dfs_us = (time.perf_counter() - t0) / len(pool) * 1e6

    import jax
    interpret = (engine_mod.resolve_backend(backend or "auto")
                 == "pallas" and jax.default_backend() != "tpu")
    cores = os.cpu_count() or 1
    # replica scaling is only demonstrable with real cores and real
    # kernels; elsewhere the rows report but carry "gated": false
    gate_ok = cores >= 4 and not interpret
    carve = {} if gate_ok else {"gated": False}

    rows = []
    with tempfile.TemporaryDirectory() as store:
        d = os.path.join(store, "fleet")
        fleet_mod.init_store(idx, d)

        static_truth = lambda i, lsn: truth[i]  # noqa: E731
        qps_by_n = {}
        for n in N_SWEEP[scale]:
            with fleet_mod.Fleet(d, n, backend, hb_s=0.1) as flt:
                router = FleetRouter(flt)
                flt.warm(pool)
                qps, lat, wrong = _closed_loop(router, pool,
                                               static_truth, rng)
            assert not wrong, \
                f"fleet n={n}: {len(wrong)} wrong answers: {wrong[:3]}"
            qps_by_n[n] = qps
            cp = serving._percentiles(lat)
            speedup = qps / qps_by_n[N_SWEEP[scale][0]]
            rows.append((
                f"serving/fleet/n{n}/closed-p95", cp["p95_us"],
                f"dfs_us={dfs_us:.1f};qps={qps:.0f};"
                f"speedup_vs_n1={speedup:.2f}x;replicas={n};"
                f"cores={cores};correct=True",
                {**cp, "replicas": n, "cores": cores, **carve}))
        if gate_ok and 2 in qps_by_n:
            assert qps_by_n[2] >= MIN_SCALING * qps_by_n[1], \
                f"n=2 replicas ({qps_by_n[2]:.0f} q/s) below " \
                f"{MIN_SCALING}x the n=1 floor ({qps_by_n[1]:.0f} q/s)"

        # ---- write-while-read: publish deltas mid-stream, validate
        # every answer against the oracle at its stamped read LSN
        writer = fleet_mod.FleetWriter(d)
        graphs = {writer.last_lsn: writer.graph}
        cache: dict = {}

        def truth_at(i, lsn):
            key = (i, lsn)
            if key not in cache:
                u, v, p = pool[i]
                cache[key] = dfs_baseline.answer_pcr(
                    graphs[lsn], u, v, p)
            return cache[key]

        step_rng = np.random.default_rng(seed + 7)

        def publish_stream():
            for _ in range(N_PUBLISHES):
                time.sleep(0.15)
                add = [(int(step_rng.integers(g.n_vertices)),
                        int(step_rng.integers(g.n_vertices)),
                        int(step_rng.integers(g.n_labels)))
                       for _ in range(3)]
                # record the post-publish graph *before* the append: a
                # replica may apply (and stamp) the LSN the instant the
                # record is durable, racing this thread
                nxt = writer.last_lsn + 1
                graphs[nxt] = writer.graph.apply_updates(add, []).graph
                assert writer.publish(add, []) == nxt

        n_rr = N_SWEEP[scale][-1] if scale == "smoke" else 2
        with fleet_mod.Fleet(d, n_rr, backend, hb_s=0.1) as flt:
            router = FleetRouter(flt)
            flt.warm(pool)
            pub = threading.Thread(target=publish_stream)
            pub.start()
            qps, lat, wrong = _closed_loop(router, pool, truth_at, rng)
            pub.join()
            # a consistent read pinned at the final LSN, post-stream
            tip = writer.last_lsn
            u, v, p = pool[0]
            ans, lsn = router.submit(
                u, v, p, min_lsn=tip).result(timeout=600)
            assert lsn >= tip and ans == truth_at(0, lsn)
        writer.close()
        assert not wrong, \
            f"write-while-read: {len(wrong)} answers disagreed with " \
            f"the oracle at their read LSN: {wrong[:3]}"
        cp = serving._percentiles(lat)
        rows.append((
            "serving/fleet/write-read", cp["p95_us"],
            f"dfs_us={dfs_us:.1f};qps={qps:.0f};"
            f"published={N_PUBLISHES};replicas={n_rr};correct=True",
            {**cp, "replicas": n_rr, "cores": cores, **carve}))
    return rows

"""tableIII + tableIV + serving + fleet + recovery regression guard.

Re-runs the tableIII, tableIV, serving, fleet and recovery smoke
benchmarks and compares
each gated row's ``us_per_call`` against the committed rows in
``BENCH_queries.json`` (the newest ``pr`` generation per (name,
backend)).  Gated rows are the reachable-query (``*-true``) tableIII
rows, the serving closed-loop p95-latency row
(``serving/er/closed-p95``), the index build+footprint rows
(``*/index-bytes`` — build time drift-normalized like every timing row,
plus ``compressed_bytes`` compared *directly*: bytes are deterministic,
so a >``--factor`` growth of the compressed index fails without any
drift allowance), the sparse-closure rows (``*closure*-sparse``), the
snapshot-restore row (``recovery/*/restore`` — restore must stay
cheap relative to rebuild; the ≥5x contract itself asserts in-module),
and the replicated-fleet closed-loop rows
(``serving/fleet/n*/closed-p95`` — same ``/closed-p95`` gate +
``SERVING_SLACK``, plus a cross-row check that N=2 replicas beat the
N=1 throughput on hosts where scaling is demonstrable; single-core or
pallas-interpret legs carry ``"gated": false`` on the rows).
Timing rows are DFS-normalized with the same drift factor (the serving
row gets ``SERVING_SLACK`` on top: concurrent-client queueing latency is
far noisier than single-thread us/call, and its tight contract lives in
the serving module's own asserts); ``--backends segment,pallas`` (the
ci.yml setting) gates both engine backends.  A committed or fresh row
carrying ``"gated": false`` (the pallas-interpret legs, where kernel
dispatch is Python-dominated) reports but never fails — the flag lives
on the rows themselves, not in prose carve-outs here.  A row fails the
build if it regresses more than ``--factor`` (default 1.5×) after
machine-drift normalization, or if any row reports ``correct=False``, or
if a benchmark module crashes (the serving and index-cost modules
deliberately raise when their contracts break: answers must match the
DFS oracle, steady-state traffic must trigger zero jit recompiles,
closed-loop throughput must clear its serial-1 floor, compressed planes
must hold their ratio floor and bit-identity).
The benchmark is measured twice and each row keeps its best pass —
shared CI hosts spike individual runs 2-3× on scheduler noise, which the
gate must not fire on.

Machine-drift normalization: absolute microseconds are not comparable
across hosts (CI runners vs the machine that produced the committed
rows), so the guard scales the committed numbers by the median ratio of
fresh-DFS to committed-DFS time over the same rows — the DFS baseline is
identical pure-Python code in both runs, so its ratio estimates how much
slower/faster this host is.

    PYTHONPATH=src python -m benchmarks.guard [--factor 1.5]
        [--backends segment] [--baseline BENCH_queries.json]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

from . import run as run_mod


def _derived_field(derived: str, key: str) -> float | None:
    m = re.search(rf"{key}=([0-9.]+)", derived or "")
    return float(m.group(1)) if m else None


# extra allowance for the serving latency row: p95 under CLIENTS
# concurrent threads varies with host core count and scheduler load in a
# way the single-thread DFS drift anchor cannot track (a 2-core CI
# runner queues 32 clients far deeper than the committing machine while
# DFS barely moves), so the gate only fires on order-of-magnitude
# regressions there — the serving module's own in-process asserts
# (speedup floor, zero recompiles, oracle equality) carry the tight
# contract
SERVING_SLACK = 3.0


def _gated(name: str) -> bool:
    """Rows whose us_per_call regressions fail the build: reachable
    tableIII rows, the serving closed-loop p95 latency row, the index
    build+footprint rows, the sparse-closure rows, and the snapshot
    restore row (the ≥5x-vs-rebuild contract also asserts in-module)."""
    return (name.endswith("-true") or name.endswith("/closed-p95")
            or name.endswith("/index-bytes") or name.endswith("-sparse")
            or name.endswith("/restore"))


def _slack(name: str) -> float:
    return SERVING_SLACK if name.endswith("/closed-p95") else 1.0


def latest_rows(records: list) -> dict:
    """Newest-generation committed row per (name, backend): highest
    ``pr`` tag wins, later file position breaks ties."""
    best: dict = {}
    for rec in records:
        key = (rec["name"], rec.get("backend", ""))
        gen = rec.get("pr", 0)
        if key not in best or gen >= best[key].get("pr", 0):
            best[key] = rec
    return best


def check(baseline_path: str, backends: list, factor: float,
          scale: str = "smoke", passes: int = 2) -> int:
    with open(baseline_path) as f:
        base = latest_rows(json.load(f))
    # measure ``passes`` times and keep each row's best — single runs on
    # shared CI hosts spike 2-3× on scheduler noise, which is exactly
    # what a regression gate must not fire on
    best: dict = {}
    order = []
    for _ in range(max(passes, 1)):
        for rec in run_mod.collect(
                scale, only="tableIII,tableIV,serving,fleet,recovery",
                backends=backends):
            key = (rec["name"], rec["backend"])
            if key not in best:
                order.append(key)
                best[key] = rec
            elif rec["us_per_call"] < best[key]["us_per_call"]:
                best[key] = rec
    fresh = [best[k] for k in order]

    # machine-drift scale from the shared pure-python DFS baseline
    ratios = []
    for rec in fresh:
        key = (rec["name"], rec["backend"])
        if key not in base:
            continue
        f_dfs = _derived_field(rec["derived"], "dfs_us")
        b_dfs = _derived_field(base[key]["derived"], "dfs_us")
        if f_dfs and b_dfs:
            ratios.append(f_dfs / b_dfs)
    drift = sorted(ratios)[len(ratios) // 2] if ratios else 1.0

    failures = []
    compared = 0
    print(f"# drift={drift:.2f} factor={factor}")
    print("name,backend,us_per_call,committed_us,allowed_us,verdict")
    for rec in fresh:
        key = (rec["name"], rec["backend"])
        if "/ERROR" in rec["name"]:
            # run.collect turns module crashes into */ERROR rows — a
            # broken benchmark must fail the gate, not slip past it
            failures.append(f"{key}: benchmark crashed: {rec['derived']}")
            verdict = "CRASHED"
            allowed = committed = float("nan")
        elif "correct=False" in (rec["derived"] or ""):
            failures.append(f"{key}: correct=False")
            verdict = "WRONG"
            allowed = committed = float("nan")
        elif (key in base and _gated(rec["name"])
              and base[key].get("gated", True) is not False
              and rec.get("gated", True) is not False):
            committed = base[key]["us_per_call"]
            allowed = committed * drift * factor * _slack(rec["name"])
            ok = rec["us_per_call"] <= allowed
            verdict = "ok" if ok else "REGRESSED"
            compared += 1
            if not ok:
                failures.append(
                    f"{key}: {rec['us_per_call']}us > "
                    f"{allowed:.1f}us allowed "
                    f"({committed}us committed × {drift:.2f} drift × "
                    f"{factor})")
            if rec["name"].endswith("/index-bytes"):
                # bytes are deterministic for a fixed graph + block
                # layout: compare directly, no drift normalization
                f_b = _derived_field(rec["derived"], "compressed_bytes")
                b_b = _derived_field(base[key]["derived"],
                                     "compressed_bytes")
                if f_b and b_b and f_b > b_b * factor:
                    verdict = "GREW"
                    failures.append(
                        f"{key}: compressed index {f_b:.0f}B > "
                        f"{b_b * factor:.0f}B allowed "
                        f"({b_b:.0f}B committed × {factor})")
        elif key in base and _gated(rec["name"]):
            # name-gated but flagged ``gated: false`` on the row itself
            # (the pallas-interpret legs) — report, never fail
            committed = base[key]["us_per_call"]
            allowed = float("nan")
            verdict = "ungated"
        else:
            committed = base.get(key, {}).get("us_per_call", float("nan"))
            allowed = float("nan")
            verdict = "info"
        print(f"{rec['name']},{rec['backend']},{rec['us_per_call']},"
              f"{committed},{allowed:.1f},{verdict}")

    # fleet replica-scaling floor: where both generations ran gated
    # (multi-core host, real kernels — the rows themselves carry
    # ``gated: false`` otherwise), fresh N=2 throughput must beat the
    # N=1 floor; the in-module assert enforces the 1.1x contract, this
    # cross-row check just refuses a silently flat-scaled fresh run
    for be in {r["backend"] for r in fresh}:
        by_n = {n: best.get((f"serving/fleet/n{n}/closed-p95", be))
                for n in (1, 2)}
        if all(by_n.values()) and all(
                r.get("gated", True) is not False for r in by_n.values()):
            q1 = _derived_field(by_n[1]["derived"], "qps")
            q2 = _derived_field(by_n[2]["derived"], "qps")
            compared += 1
            if q1 and q2 and q2 <= q1:
                failures.append(
                    f"fleet[{be}]: n=2 replicas ({q2:.0f} q/s) did not "
                    f"beat n=1 ({q1:.0f} q/s)")

    if not compared:
        # e.g. a row rename detached every fresh row from the baseline —
        # zero comparisons is a silently toothless gate, so fail loudly
        failures.append("no fresh gated (*-true / closed-p95) row matched "
                        "a committed baseline row; regenerate "
                        "BENCH_queries.json")
    if failures:
        print("\nREGRESSION GUARD FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("# guard passed")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_queries.json")
    ap.add_argument("--backends", default="segment",
                    help="comma-separated engine backends to check")
    ap.add_argument("--factor", type=float, default=1.5)
    ap.add_argument("--scale", default="smoke")
    args = ap.parse_args()
    backends = [b for b in args.backends.split(",") if b]
    sys.exit(check(args.baseline, backends, args.factor, args.scale))


if __name__ == "__main__":
    main()

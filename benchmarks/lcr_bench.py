"""Paper Table V: LCR query time — TDR (via PCR translation) vs P2H-lite."""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G, lcr, tdr_build
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    sc = common.SCALES[scale]
    rows = []
    v_small = min(sc["v"], 400)   # P2H-lite needs small graphs
    for kind in ("er", "pa"):
        g = G.random_graph(kind, v_small, 2.0, 4, seed=seed)
        idx = tdr_build.build_index(g, tdr_build.TDRConfig())
        full = lcr.P2HLite.build(g)
        sets = common.make_query_sets(g, sc["queries"], 2, seed=seed)
        for tf in ("true", "false"):
            qs = sets[f"LCR-{tf}"]
            if not qs.queries:
                continue
            n = len(qs.queries)
            tdr_s, ok = common.time_tdr(idx, qs)
            # recover the allowed-label set from the LCR pattern's
            # (single) DNF term: allowed = ζ \ forbidden
            from repro.core import pattern as pat
            lcr_qs = []
            for (u, v, p) in qs.queries:
                terms = pat.to_dnf(p)
                forbid = terms[0].forbid if terms else frozenset()
                lcr_qs.append(
                    (u, v, sorted(set(range(g.n_labels)) - forbid)))
            t0 = time.perf_counter()
            for (u, v, allowed) in lcr_qs:
                full.query(u, v, allowed)
            full_s = time.perf_counter() - t0
            rows.append((f"tableV/{kind}/LCR-{tf}",
                         round(tdr_s / n * 1e6, 1),
                         f"p2h_us={full_s / max(len(lcr_qs),1) * 1e6:.1f};"
                         f"correct={ok}"))
    return rows

"""Dynamic-graph benchmark: incremental TDR maintenance vs full rebuild.

Grades ``tdr_build.update_index`` the way a live system would use it:

* **insert** — a chain of single-edge insertions applied incrementally
  (warm-start closures + row-patched planes).  Reports mean us/update,
  updates/sec, and the cost ratio against a layout-pinned from-scratch
  rebuild of the final graph.  The acceptance contract is ratio < 0.3 at
  ER n=512 scale on the real-kernel path (segment everywhere, pallas on
  TPU) and is asserted with slack against noise; pallas-on-CPU runs the
  kernels in interpret mode, where the rebuild baseline is dispatch-
  bound and artificially cheap relative to the update's fixed host work,
  so the interpret leg reports its ratio without gating it (the same
  carve-out as ``benchmarks.serving.MIN_SPEEDUP``).  The module always
  *asserts* bit-identity of the update chain against the rebuild (a
  silent divergence must fail the run, not write a pretty row).
* **delete** — single-edge deletions under the default over-invalidation
  threshold; the derived field records how many fell back to a rebuild
  (dense ER graphs usually do — deletion dirties every ancestor).
* **post-update p95** — the serving harness (``QueryServer``): a closed
  query wave right after a ``submit_update``, measuring the latency of
  requests answered on the freshly swapped index (recompiles for the new
  edge-count shapes are warmed by a prior update, as a steady
  update-serving system would be).

Timings are steady-state: a warm pass first compiles every edge-count
shape the chain visits, then the same deltas are re-applied from the
same starting index for the timed pass.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core import engine as engine_mod, graph as G, tdr_build
from repro.launch import serve

from . import common

N_UPDATES = 8
CLIENTS = 8             # post-update closed-wave concurrency


def _block(idx):
    jax.block_until_ready((idx.h_vtx, idx.v_lab, idx.n_in, idx.r_vtx))


def _planes_equal(a, b) -> bool:
    for p in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in",
              "push", "pop", "g_count"):
        if not np.array_equal(np.asarray(getattr(a, p)),
                              np.asarray(getattr(b, p))):
            return False
    return True


def _insert_chain(g0, rng, n):
    """n single-edge insertion deltas chained from g0."""
    deltas, gc = [], g0
    while len(deltas) < n:
        u, v = int(rng.integers(g0.n_vertices)), int(
            rng.integers(g0.n_vertices))
        if u == v:
            continue
        d = gc.apply_updates([(u, v, int(rng.integers(8)))], [])
        if d.n_changes:
            deltas.append(d)
            gc = d.graph
    return deltas


def _delete_chain(g0, rng, n):
    deltas, gc = [], g0
    for _ in range(n):
        e = list(zip(gc.src.tolist(), gc.indices.tolist(),
                     gc.labels.tolist()))
        d = gc.apply_updates([], [e[int(rng.integers(len(e)))]])
        deltas.append(d)
        gc = d.graph
    return deltas


def _apply_chain(idx0, deltas, backend, timed: bool):
    cur = idx0
    times, stats = [], []
    for d in deltas:
        st = tdr_build.UpdateStats()
        t0 = time.perf_counter()
        cur = tdr_build.update_index(cur, d, backend=backend, stats=st)
        _block(cur)
        times.append(time.perf_counter() - t0)
        stats.append(st)
    return cur, (times if timed else []), stats


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    v = max(sc["v"], 512)     # the acceptance contract is ER n=512 scale
    g0 = G.erdos_renyi(v, 4.0, 8, seed=seed)
    idx0 = tdr_build.build_index(g0, tdr_build.TDRConfig(),
                                 backend=backend)
    _block(idx0)
    rng = np.random.default_rng(seed + 1)

    prefix = f"updates/er{v}"
    rows = []
    # ---- insert chain ---------------------------------------------------
    ins = _insert_chain(g0, rng, N_UPDATES)
    _apply_chain(idx0, ins, backend, timed=False)           # warm shapes
    cur, times, stats = _apply_chain(idx0, ins, backend, timed=True)
    t_ins = float(np.mean(times))

    g_fin = ins[-1].graph
    ref = tdr_build.build_index(g_fin, tdr_build.TDRConfig(),
                                layout=idx0.disc, backend=backend)
    _block(ref)
    t_reb = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        ref = tdr_build.build_index(g_fin, tdr_build.TDRConfig(),
                                    layout=idx0.disc, backend=backend)
        _block(ref)
        t_reb = min(t_reb, time.perf_counter() - t0)
    if not _planes_equal(cur, ref):
        raise RuntimeError(
            "updates: incremental chain diverged from the layout-pinned "
            "rebuild — bit-identity contract broken")
    ratio = t_ins / t_reb
    interpret = (engine_mod.resolve_backend(backend or "auto") == "pallas"
                 and jax.default_backend() != "tpu")
    if not interpret and ratio >= 0.45:
        # committed contract is <0.3 (see BENCH_queries.json); the
        # in-process assert leaves headroom for shared-host noise
        raise RuntimeError(
            f"updates: incremental insert cost is {ratio:.2f}x a full "
            "rebuild; the incremental path has regressed")
    inc = sum(s.mode == "incremental" for s in stats)
    rows.append((
        f"{prefix}/insert", round(t_ins * 1e6, 1),
        f"rebuild_us={t_reb * 1e6:.1f};ratio={ratio:.2f};"
        f"updates_per_s={1.0 / t_ins:.1f};incremental={inc}/{len(stats)};"
        f"correct=True",
        {"mean_rounds": round(float(np.mean([s.rounds for s in stats])),
                              1),
         "mean_patch_rows": round(float(np.mean(
             [s.patch_rows for s in stats])), 1),
         # interpret-mode pallas: dispatch-dominated, report-only
         **({"gated": False} if interpret else {})}))

    # ---- delete chain (default threshold; rebuild fallback is normal) ---
    dels = _delete_chain(g_fin, rng, N_UPDATES)
    _apply_chain(ref, dels, backend, timed=False)
    cur_d, times_d, stats_d = _apply_chain(ref, dels, backend, timed=True)
    ref_d = tdr_build.build_index(dels[-1].graph, tdr_build.TDRConfig(),
                                  layout=idx0.disc, backend=backend)
    if not _planes_equal(cur_d, ref_d):
        raise RuntimeError("updates: delete chain diverged from rebuild")
    n_reb = sum(s.mode == "rebuild" for s in stats_d)
    rows.append((
        f"{prefix}/delete", round(float(np.mean(times_d)) * 1e6, 1),
        f"rebuild_us={t_reb * 1e6:.1f};rebuild_fallbacks="
        f"{n_reb}/{len(stats_d)};"
        f"mean_dirty={np.mean([s.dirty_fwd for s in stats_d]):.0f};"
        f"correct=True"))

    # ---- post-update serving latency ------------------------------------
    sets = common.make_query_sets(dels[-1].graph,
                                  max(8, sc["queries"] // 4), 2, seed=seed)
    flat = [q for s in sets.values() for q in s.queries][:48]
    with serve.QueryServer(ref_d, backend=backend,
                           result_cache=0) as server:
        server.warmup(flat[:16])
        # first update warms the post-swap jit shapes, second is measured
        e0 = list(zip(dels[-1].graph.src.tolist(),
                      dels[-1].graph.indices.tolist(),
                      dels[-1].graph.labels.tolist()))
        uu, vv, ll = e0[0]
        server.submit_update([], [(uu, vv, ll)], timeout=300)
        for (u, v, p) in flat:
            server.submit(u, v, p).result(timeout=300)
        t0 = time.perf_counter()
        server.submit_update([(uu, vv, ll)], [], timeout=300)
        t_upd = time.perf_counter() - t0
        lat: list = []
        lock = threading.Lock()

        def client(qs):
            for (u, v, p) in qs:
                t1 = time.perf_counter()
                server.submit(u, v, p).result(timeout=300)
                with lock:
                    lat.append(time.perf_counter() - t1)

        shards = np.array_split(np.arange(len(flat)), CLIENTS)
        threads = [threading.Thread(
            target=client, args=([flat[int(i)] for i in s],))
            for s in shards]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        p95 = serve.percentile(lat, 95) * 1e6
        rows.append((
            f"{prefix}/post-update-p95", round(p95, 1),
            f"update_wall_us={t_upd * 1e6:.0f};served={len(lat)};"
            f"updates={server.stats.updates};correct=True"))
    return rows

"""Durability benchmark: snapshot restore + log replay vs full rebuild.

Grades the recovery path the way a restarting replica would use it:

* **restore** — ``snapshot.load_index`` wall time (validate CRCs,
  decompress the two-level planes, seed the compressed cache) against a
  from-scratch layout-pinned ``build_index`` of the same graph.  The
  whole point of the snapshot subsystem is that restarting is cheap:
  the committed contract is restore ≥5x faster than rebuild at ER n=512
  and the module *asserts* it (with the usual pallas-on-CPU interpret
  carve-out, where the rebuild baseline is dispatch-bound and
  artificially cheap — that leg reports ``gated: false``).  Bit-identity
  of the restored planes is asserted unconditionally: a fast restore of
  the wrong bits must fail the run, not write a pretty row.
* **replay** — recovery tail latency: per-record cost of replaying a
  write-ahead delta log (``deltalog.DeltaLog``) through
  ``tdr_build.update_index`` on top of the loaded snapshot, asserted
  bit-identical to a rebuild of the final graph.

Timings are min-of-3 like the other rebuild baselines (with a second
measurement attempt folded in before the floor may fire — shared CI
hosts spike single windows on scheduler noise); save cost and snapshot
size ride along in the derived field.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.core import deltalog, engine as engine_mod, graph as G
from repro.core import snapshot, tdr_build

N_RECORDS = 8
MIN_SPEEDUP = 5.0        # restore vs rebuild, ER n=512 contract


def _block(idx):
    jax.block_until_ready((idx.h_vtx, idx.v_lab, idx.n_in, idx.r_vtx))


def _planes_equal(a, b) -> bool:
    for p in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in",
              "push", "pop", "g_count", "r_vtx", "r_lab", "r_in"):
        if not np.array_equal(np.asarray(getattr(a, p)),
                              np.asarray(getattr(b, p))):
            return False
    return True


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    from . import common
    sc = common.SCALES[scale]
    v = max(sc["v"], 512)     # the speedup contract is ER n=512 scale
    g = G.erdos_renyi(v, 4.0, 8, seed=seed)
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(), backend=backend)
    _block(idx)
    idx.compressed_planes()   # canonical compressed form, cached
    prefix = f"recovery/er{v}"
    workdir = tempfile.mkdtemp(prefix="tdr-recovery-bench-")
    try:
        path = os.path.join(workdir, "snap.tdr")
        interpret = (engine_mod.resolve_backend(backend or "auto")
                     == "pallas" and jax.default_backend() != "tpu")
        t_save = t_load = t_reb = float("inf")
        n_bytes = 0
        loaded = ref = None
        # two measurement attempts, mins accumulated across both — a
        # single scheduler-noise window on a shared host must not trip
        # the speedup floor (same best-of philosophy as benchmarks.guard)
        for attempt in range(2):
            for _ in range(3):
                t0 = time.perf_counter()
                n_bytes = snapshot.save_index(idx, path, lsn=0)
                t_save = min(t_save, time.perf_counter() - t0)
            for _ in range(3):
                t0 = time.perf_counter()
                loaded, _lsn = snapshot.load_index(path)
                _block(loaded)
                t_load = min(t_load, time.perf_counter() - t0)
            for _ in range(3):
                t0 = time.perf_counter()
                ref = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                            layout=idx.disc,
                                            backend=backend)
                _block(ref)
                t_reb = min(t_reb, time.perf_counter() - t0)
            if interpret or t_reb / t_load >= MIN_SPEEDUP:
                break

        if not _planes_equal(loaded, ref):
            raise RuntimeError(
                "recovery: restored snapshot diverged from the layout-"
                "pinned rebuild — bit-identity contract broken")
        speedup = t_reb / t_load
        if not interpret and speedup < MIN_SPEEDUP:
            raise RuntimeError(
                f"recovery: restore is only {speedup:.1f}x faster than a "
                f"rebuild at ER n={v} (contract: >={MIN_SPEEDUP}x); the "
                "snapshot load path has regressed")
        rows = [(
            f"{prefix}/restore", round(t_load * 1e6, 1),
            f"rebuild_us={t_reb * 1e6:.1f};save_us={t_save * 1e6:.1f};"
            f"speedup={speedup:.1f};snapshot_bytes={n_bytes};"
            f"correct=True",
            # interpret-mode pallas: rebuild baseline is dispatch-bound,
            # report the leg without gating it
            {**({"gated": False} if interpret else {})})]

        # ---- log replay tail -------------------------------------------
        rng = np.random.default_rng(seed + 1)
        lp = os.path.join(workdir, "deltas.wal")
        log = deltalog.DeltaLog(lp)
        gc = g
        for _ in range(N_RECORDS):
            while True:
                u, w = int(rng.integers(v)), int(rng.integers(v))
                if u != w:
                    break
            d = gc.apply_updates([(u, w, int(rng.integers(8)))], [])
            log.append(d.added, d.removed)
            gc = d.graph

        def replay(base):
            cur = base
            for _lsn, added, removed in log.replay(0):
                delta = cur.graph.apply_updates(added, removed)
                cur = tdr_build.update_index(cur, delta, backend=backend)
            _block(cur)
            return cur

        replay(loaded)                        # warm the update shapes
        t0 = time.perf_counter()
        final = replay(loaded)
        t_replay = time.perf_counter() - t0
        log.close()

        ref_fin = tdr_build.build_index(gc, tdr_build.TDRConfig(),
                                        layout=idx.disc, backend=backend)
        if not _planes_equal(final, ref_fin):
            raise RuntimeError(
                "recovery: snapshot + log replay diverged from a rebuild "
                "of the final graph — bit-identity contract broken")
        rows.append((
            f"{prefix}/replay", round(t_replay / N_RECORDS * 1e6, 1),
            f"records={N_RECORDS};total_us={t_replay * 1e6:.1f};"
            f"restore_plus_replay_us={(t_load + t_replay) * 1e6:.1f};"
            f"correct=True"))
        return rows
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

"""Kernel micro-benchmarks: frontier_step lowering paths (ref vs mxu) and
the fused way-filter — CPU wall-time (structural; TPU numbers come from the
dry-run roofline)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import bitset
from repro.kernels import ops
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    n = {"smoke": 512, "small": 2048, "full": 8192}[scale]
    a = rng.random((n, n)) < (8.0 / n)
    ap = jnp.asarray(bitset.pack_bits_np(a))
    x = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 8), dtype=np.uint32))
    rows = []
    for mode in ("ref", "mxu"):
        (_, sec) = common.time_call(
            lambda: np.asarray(ops.frontier_step(ap, x, mode=mode)),
            repeat=3)
        rows.append((f"kernels/frontier_step/{mode}/V{n}",
                     round(sec * 1e6, 1), "per_round"))
    return rows
